"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs jnp oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel
from repro.kernels import ops, ref


def _data(key, n, m, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    X = jax.random.uniform(k1, (n, d), dtype=jnp.float32).astype(dtype)
    Y = jax.random.uniform(k2, (m, d), dtype=jnp.float32).astype(dtype)
    return X, Y


KERNELS = [
    Kernel("rbf", gamma=4.0),
    Kernel("poly", gamma=0.5, degree=3, coef0=1.0),
    Kernel("linear"),
]
SHAPES = [(64, 64, 8), (256, 128, 32), (100, 300, 17), (512, 256, 3)]


@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
@pytest.mark.parametrize("shape", SHAPES)
def test_kermat_matches_ref(kern, shape):
    n, m, d = shape
    X, Y = _data(n + m + d, n, m, d, jnp.float32)
    got = ops.kernel_matrix(X, Y, kern, bm=64, bn=64)
    want = ref.kermat_ref(X, Y, kind=kern.kind, gamma=kern.gamma,
                          degree=kern.degree, coef0=kern.coef0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kermat_dtypes(dtype):
    X, Y = _data(0, 128, 128, 16, dtype)
    kern = Kernel("rbf", gamma=2.0)
    got = ops.kernel_matrix(X, Y, kern, bm=64, bn=64)
    want = ref.kermat_ref(X.astype(jnp.float32), Y.astype(jnp.float32),
                          kind="rbf", gamma=2.0)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)
    assert got.dtype == jnp.float32  # f32 accumulation policy


@pytest.mark.parametrize("n,m,k,d", [(256, 64, 4, 8), (300, 128, 16, 32), (64, 32, 3, 5)])
def test_kmeans_assign_matches_ref(n, m, k, d):
    key = jax.random.PRNGKey(n + k)
    X, Xm = _data(n, n, m, d, jnp.float32)
    assign_init = jax.random.randint(key, (m,), 0, k)
    H = jax.nn.one_hot(assign_init, k)
    W = H / jnp.maximum(H.sum(0), 1.0)
    Kmm = ref.kermat_ref(Xm, Xm, gamma=4.0)
    s = jnp.einsum("mk,mn,nk->k", W, Kmm, W)
    got_a, got_s = ops.kmeans_assign(X, Xm, W, s, gamma=4.0, bm=64)
    want_a, want_s = ref.kmeans_assign_ref(
        X, Xm, W, jnp.asarray(s)[None, :], gamma=4.0)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s[:, :k]),
                               rtol=1e-4, atol=1e-4)
    # argmin may differ only on exact ties — require score-equivalence
    gs = np.asarray(want_s[:, :k])
    np.testing.assert_allclose(gs[np.arange(n), np.asarray(got_a)],
                               gs.min(axis=1), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
@pytest.mark.parametrize("n,B,d", [(256, 32, 8), (512, 64, 16), (100, 16, 7)])
def test_cd_column_update_matches_ref(kern, n, B, d):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n + B), 3)
    X = jax.random.uniform(k1, (n, d))
    y = jnp.sign(jax.random.normal(k2, (n,)))
    Xb = X[:B]
    w = jax.random.normal(k3, (B,))
    got = ops.cd_column_update(X, y, Xb, w, kern, bm=64)
    want = ref.cd_column_update_ref(X, y, Xb, w, kind=kern.kind,
                                    gamma=kern.gamma, degree=kern.degree,
                                    coef0=kern.coef0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
@pytest.mark.parametrize("n,m,d", [(128, 128, 16), (100, 300, 17), (512, 96, 5)])
def test_kernel_matvec_matches_ref(kern, n, m, d):
    """Streaming K(X, Z) @ v kernel vs jnp oracle, incl. non-tile-multiple
    shapes (ops.py pads; padded Z rows carry zero v weight)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n + m + d), 3)
    X = jax.random.uniform(k1, (n, d))
    Z = jax.random.uniform(k2, (m, d))
    v = jax.random.normal(k3, (m,))
    got = ops.kernel_matvec(X, Z, v, kern, bm=64, bn=64)
    want = ref.kernel_matvec_ref(X, Z, v, kind=kern.kind, gamma=kern.gamma,
                                 degree=kern.degree, coef0=kern.coef0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_kernel_matvec_f32_accumulation():
    X, Z = _data(0, 256, 256, 16, jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(1), (256,), dtype=jnp.float32)
    kern = Kernel("rbf", gamma=2.0)
    got = ops.kernel_matvec(X, Z, v, kern, bm=64, bn=64)
    assert got.dtype == jnp.float32  # accumulator policy
    want = ref.kernel_matvec_ref(X.astype(jnp.float32), Z.astype(jnp.float32),
                                 v, kind="rbf", gamma=2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2)


def test_core_gram_pallas_path_consistent():
    """core.kernels.gram(use_pallas=True) must agree with the jnp path."""
    from repro.core.kernels import gram
    X, Y = _data(1, 200, 150, 12, jnp.float32)
    kern = Kernel("rbf", gamma=8.0)
    a = gram(kern, X, Y, use_pallas=True)
    b = gram(kern, X, Y, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
