"""Tests for two-step kernel kmeans + balanced partitioning."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    Kernel,
    Partition,
    assign_points,
    balanced_assign,
    gram,
    kernel_kmeans,
    two_step_kernel_kmeans,
)
from repro.core.bounds import d_pi
from repro.data import gaussian_mixture


def test_kernel_kmeans_recovers_separated_blobs():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    centers = jnp.array([[0.0, 0.0], [5.0, 5.0], [0.0, 5.0], [5.0, 0.0]])
    lab = jax.random.randint(k1, (400,), 0, 4)
    X = centers[lab] + 0.1 * jax.random.normal(k2, (400, 2))
    kern = Kernel("rbf", gamma=1.0)
    K = gram(kern, X, X)
    assign, W, s = kernel_kmeans(K, 4, jax.random.PRNGKey(1), iters=30)
    # perfect clustering up to label permutation: each true blob maps to one cluster
    assign = np.asarray(assign)
    lab = np.asarray(lab)
    for b in range(4):
        vals = assign[lab == b]
        assert (vals == vals[0]).all()


def test_two_step_assignment_matches_full_on_sample():
    X, y = gaussian_mixture(jax.random.PRNGKey(2), 600, d=6, modes_per_class=3)
    kern = Kernel("rbf", gamma=4.0)
    part = two_step_kernel_kmeans(kern, X, k=6, key=jax.random.PRNGKey(3), m=200,
                                  balanced=False)
    # routing model assigns consistently with the stored partition
    a2, _ = assign_points(kern, part.model, X)
    assert (np.asarray(a2) == part.assign).mean() > 0.999


def test_balanced_assign_exact_capacity():
    rng = np.random.default_rng(0)
    D = rng.random((128, 4))
    out = balanced_assign(D, capacity=32)
    counts = np.bincount(out, minlength=4)
    assert (counts == 32).all()


def test_balanced_assign_prefers_near_centers():
    # two tight groups, two centers: balanced assignment should match argmin
    D = np.array([[0.1, 5.0]] * 8 + [[5.0, 0.1]] * 8)
    out = balanced_assign(D, capacity=8)
    assert (out[:8] == 0).all() and (out[8:] == 1).all()


def test_partition_gather_scatter_roundtrip():
    X, _ = gaussian_mixture(jax.random.PRNGKey(4), 300, d=4)
    kern = Kernel("rbf", gamma=2.0)
    part = two_step_kernel_kmeans(kern, X, k=5, key=jax.random.PRNGKey(5), m=100)
    v = jnp.arange(300, dtype=jnp.float32)
    vc = jnp.where(jnp.asarray(part.mask), part.gather(v), 0.0)
    back = part.scatter(vc, 300)
    assert np.allclose(np.asarray(back), np.asarray(v))


def test_kkmeans_partition_beats_random_on_dpi():
    """The reason kernel kmeans is the right divide step (paper Fig. 1):
    D(pi) from kernel kmeans is far below D(pi) of a random partition."""
    X, _ = gaussian_mixture(jax.random.PRNGKey(6), 800, d=8, modes_per_class=4,
                            spread=0.08)
    kern = Kernel("rbf", gamma=16.0)
    part = two_step_kernel_kmeans(kern, X, k=8, key=jax.random.PRNGKey(7), m=300)
    d_kk = float(d_pi(kern, X, jnp.asarray(part.assign)))
    rng = np.random.default_rng(0)
    rand_assign = rng.integers(0, 8, size=800)
    d_rand = float(d_pi(kern, X, jnp.asarray(rand_assign)))
    assert d_kk < 0.5 * d_rand


def test_empty_cluster_reseeding():
    # k larger than natural cluster count still yields k populated clusters
    X = jnp.concatenate([jnp.zeros((50, 2)), jnp.ones((50, 2))], 0)
    X = X + 0.01 * jax.random.normal(jax.random.PRNGKey(8), X.shape)
    kern = Kernel("rbf", gamma=1.0)
    part = two_step_kernel_kmeans(kern, X, k=4, key=jax.random.PRNGKey(9), m=100)
    counts = np.bincount(part.assign, minlength=4)
    assert (counts > 0).all()


def test_reseed_all_empties_in_one_iteration():
    """Regression: when argmin collapses many clusters at once, reseeding one
    empty per iteration leaves phantom centers whenever iters < #empties.
    With a constant kernel matrix every point collapses into cluster 0 each
    iteration, so only reseed-ALL keeps k clusters populated within 2 iters."""
    Kmm = jnp.ones((12, 12))
    assign, W, s = kernel_kmeans(Kmm, 4, jax.random.PRNGKey(0), iters=2)
    counts = np.bincount(np.asarray(assign), minlength=4)
    assert (counts > 0).all(), f"phantom empty clusters: counts={counts}"


def test_reseed_handles_more_clusters_than_points():
    """k > m degenerate case: reseeding must not crash, and with an identity
    kernel (all points mutually orthogonal) every point keeps its own
    singleton cluster — m of the k clusters populated, one point each."""
    assign, W, s = kernel_kmeans(jnp.eye(8), 12, jax.random.PRNGKey(0), iters=3)
    counts = np.bincount(np.asarray(assign), minlength=12)
    assert counts.max() == 1
    assert (counts > 0).sum() == 8


def test_assign_points_masks_empty_centers():
    """Regression: an empty center has W[:, c] = 0 and s[c] = 0, so its
    distance column degenerates to K(x, x) = 1 (RBF) and can win argmin for
    far-away queries.  Routing must never send a query to an empty center."""
    from repro.core import KKMeansModel

    Xm = jnp.asarray([[0.0, 0.0], [0.0, 0.1]])
    W = jnp.asarray([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]])   # center 2 is empty
    kern = Kernel("rbf", gamma=1.0)
    Kmm = gram(kern, Xm, Xm)
    s = jnp.asarray([1.0, 1.0, 0.0])
    model = KKMeansModel(Xm=Xm, W=W, s=s)
    # far query: distance to the real centers ~2, to the phantom center 1
    Xq = jnp.asarray([[10.0, 10.0], [0.0, 0.0]])
    assign, D = assign_points(kern, model, Xq)
    assert np.asarray(D)[0, 2] == np.inf
    assert int(assign[0]) in (0, 1)
    assert int(assign[1]) == 0   # near queries still route normally


def test_two_step_splits_sample_and_init_keys():
    """Regression: the m-point sample and the kmeans init permutation must be
    INDEPENDENT streams split from the caller's key, not two consumers of the
    same key (correlated sample/init defeats the two-step scheme's
    randomization).  Pins the documented contract: sample stream =
    split(key)[0]."""
    from repro.data import gaussian_mixture

    X, _ = gaussian_mixture(jax.random.PRNGKey(30), 300, d=4)
    key = jax.random.PRNGKey(42)
    part = two_step_kernel_kmeans(Kernel("rbf", gamma=2.0), X, k=3, key=key,
                                  m=64, iters=2, balanced=False)
    key_sample, _ = jax.random.split(key)
    expected = X[jax.random.choice(key_sample, 300, shape=(64,), replace=False)]
    np.testing.assert_array_equal(np.asarray(part.model.Xm),
                                  np.asarray(expected))
