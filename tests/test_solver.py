"""Unit tests for the box-QP coordinate-descent solvers."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    Kernel,
    gram,
    kkt_residual,
    objective,
    proj_grad,
    solve_box_qp,
    solve_box_qp_block,
    solve_box_qp_matvec,
    solve_with_shrinking,
)


def make_qp(key, n, d=6, gamma=4.0, jitter=1e-3):
    k1, k2 = jax.random.split(key)
    X = jax.random.uniform(k1, (n, d))
    y = jnp.sign(jax.random.normal(k2, (n,)))
    K = Kernel("rbf", gamma=gamma).pairwise(X, X) + jitter * jnp.eye(n)
    Q = (y[:, None] * y[None, :]) * K
    return X, y, Q


def brute_force_alpha(Q, C, iters=200_000, tol=1e-7):
    """Long-run CD as the reference optimum (convex problem, CD converges)."""
    res = solve_box_qp(Q, C, tol=tol, max_iters=iters)
    return res.alpha


@pytest.mark.parametrize("n,C", [(40, 1.0), (120, 10.0), (80, 0.1)])
def test_greedy_cd_reaches_kkt(n, C):
    _, _, Q = make_qp(jax.random.PRNGKey(n), n)
    res = solve_box_qp(Q, C, tol=1e-5, max_iters=100_000)
    assert float(res.pg_max) <= 1e-5 * 1.5
    assert float(kkt_residual(Q, res.alpha, C)) <= 1e-4
    assert bool(jnp.all(res.alpha >= 0)) and bool(jnp.all(res.alpha <= C))


def test_greedy_cd_matches_reference_objective():
    _, _, Q = make_qp(jax.random.PRNGKey(7), 100)
    C = 5.0
    ref = brute_force_alpha(Q, C)
    f_ref = 0.5 * ref @ Q @ ref - ref.sum()
    res = solve_box_qp(Q, C, tol=1e-4, max_iters=100_000)
    f = 0.5 * res.alpha @ Q @ res.alpha - res.alpha.sum()
    assert float(f) <= float(f_ref) + 1e-3 * abs(float(f_ref)) + 1e-5


@pytest.mark.parametrize("block", [4, 16])
def test_block_cd_matches_greedy(block):
    _, _, Q = make_qp(jax.random.PRNGKey(3), 96)
    C = 2.0
    a1 = solve_box_qp(Q, C, tol=1e-5, max_iters=100_000).alpha
    a2 = solve_box_qp_block(Q, C, tol=1e-5, max_iters=20_000, block=block).alpha
    f1 = 0.5 * a1 @ Q @ a1 - a1.sum()
    f2 = 0.5 * a2 @ Q @ a2 - a2.sum()
    assert abs(float(f1 - f2)) <= 1e-3 * (abs(float(f1)) + 1e-6)
    assert float(kkt_residual(Q, a2, C)) <= 1e-4


def test_matvec_solver_matches_dense():
    X, y, Q = make_qp(jax.random.PRNGKey(11), 128, jitter=0.0)
    kern = Kernel("rbf", gamma=4.0)
    C = 2.0
    a_dense = solve_box_qp(Q, C, tol=1e-5, max_iters=100_000).alpha
    res = solve_box_qp_matvec(X, y, kern, C, tol=1e-5, max_iters=5_000, block=16)
    f1 = 0.5 * a_dense @ Q @ a_dense - a_dense.sum()
    f2 = 0.5 * res.alpha @ Q @ res.alpha - res.alpha.sum()
    assert abs(float(f1 - f2)) <= 2e-3 * (abs(float(f1)) + 1e-6)


def test_warm_start_reduces_iterations():
    _, _, Q = make_qp(jax.random.PRNGKey(5), 150)
    C = 1.0
    cold = solve_box_qp(Q, C, tol=1e-5, max_iters=200_000)
    # perturb the solution slightly: warm restart should converge much faster
    warm0 = jnp.clip(cold.alpha + 0.01 * jax.random.normal(jax.random.PRNGKey(0), cold.alpha.shape), 0.0, C)
    warm = solve_box_qp(Q, C, alpha0=warm0, tol=1e-5, max_iters=200_000)
    assert int(warm.iters) < int(cold.iters)


def test_shrinking_returns_full_problem_kkt():
    _, _, Q = make_qp(jax.random.PRNGKey(9), 200)
    C = 3.0
    res = solve_with_shrinking(Q, C, tol=1e-4, max_iters=100_000, rounds=3)
    # the final round unshrinks: the residual must hold on the FULL problem
    assert float(kkt_residual(Q, res.alpha, C)) <= 1e-3


@pytest.mark.parametrize("max_iters", [25, 100_000])
def test_shrinking_pg_max_is_residual_at_returned_alpha(max_iters):
    """Regression (documented contract): ``pg_max`` must be the KKT residual
    of the FULL problem at the RETURNED alpha.  The inner solvers report the
    stopping value from the last pre-update iterate, which is stale — most
    visibly when the iteration cap bites mid-descent."""
    _, _, Q = make_qp(jax.random.PRNGKey(21), 150)
    C = 3.0
    res = solve_with_shrinking(Q, C, tol=1e-9, max_iters=max_iters, rounds=2)
    np.testing.assert_allclose(float(res.pg_max),
                               float(kkt_residual(Q, res.alpha, C)),
                               rtol=1e-5, atol=1e-6)


def test_active_mask_freezes_coordinates():
    _, _, Q = make_qp(jax.random.PRNGKey(13), 60)
    C = 1.0
    mask = jnp.arange(60) < 30
    res = solve_box_qp(Q, C, tol=1e-5, max_iters=50_000, active_mask=mask)
    assert bool(jnp.all(res.alpha[30:] == 0.0))


def test_objective_helper_consistent():
    _, _, Q = make_qp(jax.random.PRNGKey(1), 50)
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (50,)))
    g = Q @ a - 1.0
    f_direct = 0.5 * a @ Q @ a - a.sum()
    assert abs(float(objective(a, g) - f_direct)) < 1e-4 * (1 + abs(float(f_direct)))


@pytest.mark.parametrize("n,seed", [(16, 0), (64, 1), (200, 2)])
def test_objective_identity_pinned(n, seed):
    """Pin objective(a, g) against the explicit 1/2 a'Qa - e'a in f64: the
    identity f = 1/2 a'g - 1/2 e'a with g = Qa - e must hold exactly."""
    rng = np.random.default_rng(seed)
    Q = rng.standard_normal((n, n)).astype(np.float32)
    Q = (Q + Q.T) / 2  # any symmetric matrix, PSD not required for the identity
    a = rng.uniform(0.0, 3.0, size=n).astype(np.float32)
    g = Q @ a - 1.0
    f_explicit = 0.5 * a @ Q @ a - a.sum()
    f_helper = float(objective(jnp.asarray(a), jnp.asarray(g)))
    np.testing.assert_allclose(f_helper, f_explicit,
                               rtol=1e-5, atol=1e-4 * (1 + abs(f_explicit)))


def test_vmapped_solver_batches_independent_problems():
    keys = jax.random.split(jax.random.PRNGKey(17), 4)
    Qs = jnp.stack([make_qp(k, 48)[2] for k in keys])
    C = 1.5
    batched = jax.vmap(lambda Q: solve_box_qp(Q, C, tol=1e-5, max_iters=50_000).alpha)(Qs)
    for i in range(4):
        single = solve_box_qp(Qs[i], C, tol=1e-5, max_iters=50_000).alpha
        f_b = 0.5 * batched[i] @ Qs[i] @ batched[i] - batched[i].sum()
        f_s = 0.5 * single @ Qs[i] @ single - single.sum()
        assert abs(float(f_b - f_s)) < 1e-3 * (1 + abs(float(f_s)))
