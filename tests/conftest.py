import os

# Tests run on the single real CPU device (the 512-device override belongs
# ONLY to repro.launch.dryrun). Force determinism-friendly settings.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest
import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Cap the per-process compile-cache footprint at one module's worth.

    The full suite compiles thousands of distinct executables in one
    process; letting them all accumulate eventually segfaults the XLA CPU
    compiler mid-``backend_compile`` (reproducibly, ~270 tests in).  Tests
    never share jit signatures across modules, so dropping the caches at
    module boundaries costs nothing and keeps the process healthy."""
    yield
    jax.clear_caches()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
    config.addinivalue_line(
        "markers",
        "properties: hypothesis-backed (or fixed-seed fallback) solver "
        "conformance suite — skipped by scripts/ci.sh --fast")
