import os

# Tests run on the single real CPU device (the 512-device override belongs
# ONLY to repro.launch.dryrun). Force determinism-friendly settings.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest
import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
    config.addinivalue_line(
        "markers",
        "properties: hypothesis-backed (or fixed-seed fallback) solver "
        "conformance suite — skipped by scripts/ci.sh --fast")
