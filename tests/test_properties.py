"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

import jax
import jax.numpy as jnp

from repro.core import (
    Kernel, balanced_assign, gram, kkt_residual, objective, proj_grad,
    solve_box_qp, solve_box_qp_block,
)
from repro.core.bounds import d_pi
from repro.optim.grad_compress import compress, decompress
from repro.kernels import ops, ref

SETTINGS = dict(deadline=None, max_examples=12,
                suppress_health_check=[HealthCheck.too_slow])


@st.composite
def qp_problem(draw):
    n = draw(st.integers(8, 48))
    d = draw(st.integers(2, 8))
    gamma = draw(st.floats(0.5, 8.0))
    C = draw(st.floats(0.1, 10.0))
    seed = draw(st.integers(0, 2**30))
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    X = jax.random.uniform(k1, (n, d))
    y = jnp.sign(jax.random.normal(k2, (n,)))
    y = jnp.where(y == 0, 1.0, y)
    K = Kernel("rbf", gamma=gamma).pairwise(X, X) + 1e-4 * jnp.eye(n)
    Q = (y[:, None] * y[None, :]) * K
    return X, y, Q, float(C)


@given(qp_problem())
@settings(**SETTINGS)
def test_solver_always_feasible_and_kkt(prob):
    """For ANY box QP from a PSD kernel: the solver output is feasible and
    satisfies KKT to tolerance."""
    _, _, Q, C = prob
    res = solve_box_qp(Q, C, tol=1e-5, max_iters=100_000)
    assert bool(jnp.all(res.alpha >= -1e-7))
    assert bool(jnp.all(res.alpha <= C + 1e-6))
    assert float(kkt_residual(Q, res.alpha, C)) < 1e-3


@given(qp_problem())
@settings(**SETTINGS)
def test_block_solver_objective_matches_greedy(prob):
    _, _, Q, C = prob
    a1 = solve_box_qp(Q, C, tol=1e-5, max_iters=100_000).alpha
    a2 = solve_box_qp_block(Q, C, tol=1e-5, max_iters=50_000,
                            block=min(8, Q.shape[0])).alpha
    f1 = float(0.5 * a1 @ Q @ a1 - a1.sum())
    f2 = float(0.5 * a2 @ Q @ a2 - a2.sum())
    assert abs(f1 - f2) < 1e-3 * (1 + abs(f1))


@given(qp_problem())
@settings(**SETTINGS)
def test_objective_decreases_from_feasible_start(prob):
    """Solving from any feasible start never increases the objective."""
    _, _, Q, C = prob
    n = Q.shape[0]
    a0 = jnp.clip(jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (n,))), 0, C)
    g0 = Q @ a0 - 1.0
    f0 = float(objective(a0, g0))
    res = solve_box_qp(Q, C, alpha0=a0, tol=1e-5, max_iters=100_000)
    f1 = float(objective(res.alpha, res.grad))
    assert f1 <= f0 + 1e-5 * (1 + abs(f0))


@given(st.integers(2, 6), st.integers(20, 100), st.integers(0, 2**30))
@settings(**SETTINGS)
def test_dpi_vanishes_iff_single_cluster(k, n, seed):
    """D(pi) >= 0 always; == 0 when everything is one cluster."""
    key = jax.random.PRNGKey(seed)
    X = jax.random.uniform(key, (n, 4))
    kern = Kernel("rbf", gamma=2.0)
    rng = np.random.default_rng(seed)
    assign = jnp.asarray(rng.integers(0, k, n))
    D = float(d_pi(kern, X, assign))
    assert D >= 0.0
    D_one = float(d_pi(kern, X, jnp.zeros(n, jnp.int32)))
    assert D_one == pytest.approx(0.0, abs=1e-5)


@given(st.integers(1, 6), st.integers(10, 80), st.integers(0, 2**30))
@settings(**SETTINGS)
def test_balanced_assign_respects_capacity(k, n, seed):
    rng = np.random.default_rng(seed)
    D = rng.random((n, k))
    cap = -(-n // k)
    out = balanced_assign(D, cap)
    counts = np.bincount(out, minlength=k)
    assert counts.max() <= cap
    assert counts.sum() == n


@given(st.integers(1, 2**30), st.integers(10, 400))
@settings(**SETTINGS)
def test_compression_error_bound(seed, n):
    """Blockwise int8 quantization error is bounded by blockmax/127."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 10.0
    q, s = compress(x)
    x2 = decompress(q, s, x.shape)
    assert float(jnp.max(jnp.abs(x - x2))) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-5


@given(st.integers(8, 80), st.integers(8, 80), st.integers(1, 16),
       st.sampled_from(["rbf", "poly", "linear"]), st.integers(0, 2**30))
@settings(**SETTINGS)
def test_pallas_kermat_matches_ref_any_shape(n, m, d, kind, seed):
    """Pallas kernel == jnp oracle for arbitrary (n, m, d, kernel kind)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    X = jax.random.uniform(k1, (n, d))
    Y = jax.random.uniform(k2, (m, d))
    kern = Kernel(kind, gamma=1.5, degree=2, coef0=0.5)
    got = ops.kernel_matrix(X, Y, kern, bm=32, bn=32)
    want = ref.kermat_ref(X, Y, kind=kind, gamma=1.5, degree=2, coef0=0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@given(st.integers(0, 2**30))
@settings(**SETTINGS)
def test_proj_grad_zero_iff_optimal(seed):
    """proj_grad == 0 implies no coordinate can improve the objective."""
    key = jax.random.PRNGKey(seed)
    n, C = 24, 2.0
    X = jax.random.uniform(key, (n, 3))
    y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (n,)))
    y = jnp.where(y == 0, 1.0, y)
    Q = (y[:, None] * y[None, :]) * (Kernel("rbf", gamma=2.0).pairwise(X, X)
                                     + 1e-4 * jnp.eye(n))
    res = solve_box_qp(Q, C, tol=1e-6, max_iters=200_000)
    # single-coordinate perturbations cannot improve
    f0 = float(0.5 * res.alpha @ Q @ res.alpha - res.alpha.sum())
    for i in range(0, n, 5):
        for eps in (1e-3, -1e-3):
            a = res.alpha.at[i].set(jnp.clip(res.alpha[i] + eps, 0, C))
            f = float(0.5 * a @ Q @ a - a.sum())
            assert f >= f0 - 1e-5 * (1 + abs(f0))
