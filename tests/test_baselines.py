"""Baseline solvers: sanity + the paper's qualitative orderings."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.baselines import (
    train_cascade,
    train_exact,
    train_llsvm,
    train_ltpu,
    train_rff,
)
from repro.core import Kernel, accuracy, gram, kkt_residual
from repro.data import gaussian_mixture, train_test_split

KERN = Kernel("rbf", gamma=8.0)


@pytest.fixture(scope="module")
def data():
    X, y = gaussian_mixture(jax.random.PRNGKey(0), 1200, d=8, modes_per_class=4,
                            spread=0.15)
    return train_test_split(jax.random.PRNGKey(1), X, y)


def test_exact_solver_kkt_and_accuracy(data):
    Xtr, ytr, Xte, yte = data
    m = train_exact(Xtr, ytr, KERN, C=4.0, tol=1e-4)
    K = gram(KERN, Xtr, Xtr)
    Q = (ytr[:, None] * ytr[None, :]) * K
    assert float(kkt_residual(Q, m.alpha, 4.0)) <= 1e-3
    assert accuracy(yte, m.predict(Xte)) > 0.95


def test_cascade_trains_and_predicts(data):
    Xtr, ytr, Xte, yte = data
    m = train_cascade(Xtr, ytr, KERN, C=4.0, levels=3, tol=1e-3)
    assert accuracy(yte, m.predict(Xte)) > 0.9
    assert len(m.sv_index) < Xtr.shape[0]


def test_llsvm_accuracy_grows_with_landmarks(data):
    Xtr, ytr, Xte, yte = data
    accs = []
    for b in (8, 64):
        m = train_llsvm(Xtr, ytr, KERN, C=4.0, num_landmarks=b)
        accs.append(accuracy(yte, m.predict(Xte)))
    assert accs[1] >= accs[0] - 0.01      # more landmarks, no worse
    assert accs[1] > 0.85


def test_rff_approximates_rbf(data):
    Xtr, ytr, Xte, yte = data
    m = train_rff(Xtr, ytr, KERN, C=4.0, num_features=512)
    assert accuracy(yte, m.predict(Xte)) > 0.85
    # feature inner products approximate the kernel
    Z = m.features(Xtr[:200])
    Kapprox = Z @ Z.T
    Ktrue = gram(KERN, Xtr[:200], Xtr[:200])
    err = float(jnp.mean(jnp.abs(Kapprox - Ktrue)))
    assert err < 0.1


def test_ltpu_trains(data):
    Xtr, ytr, Xte, yte = data
    m = train_ltpu(Xtr, ytr, KERN, num_units=128)
    assert accuracy(yte, m.predict(Xte)) > 0.85


def test_exact_beats_approximate_baselines():
    """The paper's headline ordering: the exact solution's accuracy is an
    upper envelope for the approximate solvers at modest capacity.

    Uses checkerboard data, where the decision boundary genuinely needs
    kernel capacity — on an easy gaussian mixture a low-rank smoother can
    *outscore* the exact SVM by regularizing harder, which is not the
    ordering this test pins."""
    from repro.data import checkerboard

    kern = Kernel("rbf", gamma=40.0)
    X, y = checkerboard(jax.random.PRNGKey(21), 1600, cells=3)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(22), X, y)
    exact = train_exact(Xtr, ytr, kern, C=16.0, tol=1e-3)
    acc_exact = accuracy(yte, exact.predict(Xte))
    acc_ll = accuracy(yte, train_llsvm(Xtr, ytr, kern, 16.0, num_landmarks=16).predict(Xte))
    acc_rff = accuracy(yte, train_rff(Xtr, ytr, kern, 16.0, num_features=32).predict(Xte))
    assert acc_exact >= max(acc_ll, acc_rff) - 0.005
