"""Serving-engine tests: export round-trip, strategy parity, request loop."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig,
    Kernel,
    accuracy_multiclass,
    decision_early,
    decision_exact,
    fit,
    fit_ova,
    predict_early,
    predict_exact,
)
from repro.core.predict import decision_early_ova, decision_exact_ova
from repro.data import (
    gaussian_mixture,
    gaussian_mixture_multiclass,
    train_test_split,
)
from repro.launch.serve_svm import (
    export_serving_model,
    run_request_loop,
    serve_batch,
)

KERN = Kernel("rbf", gamma=16.0)


@pytest.fixture(scope="module")
def ova_model():
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), 900, n_classes=3,
                                       d=8, spread=0.10)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    cfg = DCSVMConfig(kernel=KERN, C=4.0, k=4, levels=2, m=300, tol=1e-3)
    return fit_ova(cfg, Xtr, ytr), Xte, yte


@pytest.fixture(scope="module")
def binary_model():
    X, y = gaussian_mixture(jax.random.PRNGKey(2), 800, d=6, modes_per_class=3)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(3), X, y)
    cfg = DCSVMConfig(kernel=KERN, C=4.0, k=4, levels=1, m=200, tol=1e-3,
                      early_stop_level=1)
    return fit(cfg, Xtr, ytr), Xte, yte


def test_export_drops_non_svs(ova_model):
    mc, _, _ = ova_model
    sm = export_serving_model(mc)
    assert sm.Xall.shape[0] == len(mc.sv_union) < mc.X.shape[0]
    # every packed per-cluster slot is either a real SV or zero-weighted
    wm = np.asarray(sm.Wsv)
    svm = np.asarray(sm.svmask)
    assert np.all(wm[~svm] == 0.0)


def test_serve_exact_roundtrip_ova(ova_model):
    mc, Xte, _ = ova_model
    sm = export_serving_model(mc)
    pred, scores = serve_batch(sm, Xte, KERN, "exact")
    ref = decision_exact_ova(mc, Xte)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(ref), atol=1e-4)
    ref_pred = np.asarray(mc.classes)[np.argmax(np.asarray(ref), axis=1)]
    assert (np.asarray(pred) == ref_pred).all()


def test_serve_early_roundtrip_ova(ova_model):
    """Serving 'early' == predict_early_ova: dropping zero-weight non-SVs
    from the packed blocks must not change any decision value."""
    mc, Xte, yte = ova_model
    sm = export_serving_model(mc)
    pred, scores = serve_batch(sm, Xte, KERN, "early")
    ref = decision_early_ova(mc, Xte)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(ref), atol=1e-4)
    assert accuracy_multiclass(yte, pred) >= 0.95


def test_serve_bcm_reasonable(ova_model):
    mc, Xte, yte = ova_model
    sm = export_serving_model(mc)
    pred, _ = serve_batch(sm, Xte, KERN, "bcm")
    assert accuracy_multiclass(yte, pred) >= 0.9


def test_serve_binary_roundtrip(binary_model):
    """A binary model exports with (-w, +w) columns: scores[:, 1] is f(x) and
    the argmax label equals sign(f)."""
    mb, Xte, _ = binary_model
    sm = export_serving_model(mb)
    assert np.asarray(sm.classes).tolist() == [-1.0, 1.0]
    pred, scores = serve_batch(sm, Xte, KERN, "exact")
    np.testing.assert_allclose(np.asarray(scores[:, 1]),
                               np.asarray(decision_exact(mb, Xte)), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.asarray(predict_exact(mb, Xte)))
    pred_e, scores_e = serve_batch(sm, Xte, KERN, "early")
    np.testing.assert_allclose(np.asarray(scores_e[:, 1]),
                               np.asarray(decision_early(mb, Xte)), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(pred_e),
                                  np.asarray(predict_early(mb, Xte)))


def test_request_loop_report(ova_model):
    mc, Xte, _ = ova_model
    sm = export_serving_model(mc)
    idx = np.random.default_rng(0).integers(0, Xte.shape[0], size=(3, 32))
    batches = jnp.asarray(np.asarray(Xte)[idx])
    rep = run_request_loop(sm, KERN, "early", batches, warmup=1)
    assert rep["qps"] > 0 and rep["lat_ms_p95"] >= rep["lat_ms_p50"] > 0
    assert rep["batches"] == 3 and rep["batch"] == 32


@pytest.mark.parametrize("strategy", ["exact", "early", "bcm"])
def test_serve_empty_batch(ova_model, strategy):
    """An empty request batch returns empty results instead of crashing
    (regression: jnp.max over zero-size pos array in the bucketed path)."""
    mc, Xte, _ = ova_model
    sm = export_serving_model(mc)
    pred, scores = serve_batch(sm, Xte[:0], KERN, strategy)
    assert pred.shape == (0,) and scores.shape == (0, mc.n_classes)


def test_serve_unknown_strategy_raises(ova_model):
    mc, Xte, _ = ova_model
    sm = export_serving_model(mc)
    with pytest.raises(ValueError):
        serve_batch(sm, Xte[:4], KERN, "nope")


def test_export_without_bcm(ova_model):
    """with_bcm=False skips the (k, msv, msv) Gram factorization; exact and
    early still serve, bcm raises a clear error."""
    mc, Xte, _ = ova_model
    sm = export_serving_model(mc, with_bcm=False)
    assert sm.Lchol.shape[1] == 0
    pred, _ = serve_batch(sm, Xte[:16], KERN, "early")
    assert pred.shape == (16,)
    with pytest.raises(ValueError, match="with_bcm"):
        serve_batch(sm, Xte[:4], KERN, "bcm")
