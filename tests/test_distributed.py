"""Distributed DC-SVM: sharded parallel-block conquer vs the dense solution.

The multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the dryrun pattern); the
in-process tests exercise the same code paths on a 1-device mesh.

Covers the communication-efficient parallel block minimization (CE-PBM)
conquer: both modes reach dense-solver parity, cached and uncached parallel
paths agree exactly, padding removes the n % P == 0 restriction, the returned
pg_max is the residual at the RETURNED alpha (regression: it used to be the
stale pre-update stopping value), and the conquer while-loop stays free of
device-to-host syncs.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import DCSVMConfig, Kernel, gram, kkt_residual
from repro.core.distributed import (
    ConquerConfig,
    conquer_step,
    divide_step,
    fit_distributed,
    fit_distributed_model,
)
from repro.core.solver import combination_step_size, solve_with_shrinking
from repro.core.tasks import EpsilonSVR, OneClassSVM, WeightedCSVC
from repro.data import gaussian_mixture
from repro.launch.mesh import make_host_mesh

KERN = Kernel("rbf", gamma=8.0)


def _mesh1():
    return jax.make_mesh((1,), ("i",))


def _svc_objective(Q, alpha):
    return float(0.5 * jnp.vdot(alpha, Q @ alpha) - jnp.sum(alpha))


@pytest.mark.parametrize("mode", ["parallel", "replicated"])
def test_conquer_single_device_mesh_matches_dense(mode):
    X, y = gaussian_mixture(jax.random.PRNGKey(0), 512, d=6, modes_per_class=3)
    cfg = ConquerConfig(kernel=KERN, C=2.0, tol=1e-4, max_iters=3000,
                        block=32, mode=mode)
    alpha, iters, pg = conquer_step(_mesh1(), "i", cfg, X, y, jnp.zeros(512))
    Q = (y[:, None] * y[None, :]) * gram(KERN, X, X)
    assert float(pg) <= 1e-4 * 1.5
    assert float(kkt_residual(Q, alpha, 2.0)) <= 1e-3


def test_conquer_cache_path_matches_uncached():
    X, y = gaussian_mixture(jax.random.PRNGKey(4), 384, d=6, modes_per_class=3)
    base = ConquerConfig(kernel=KERN, C=2.0, tol=1e-4, max_iters=3000,
                         block=16)
    a0, r0, pg0 = conquer_step(_mesh1(), "i", base, X, y, jnp.zeros(384))
    cached = dataclasses.replace(base, cache_cap=256)
    a1, r1, pg1 = conquer_step(_mesh1(), "i", cached, X, y, jnp.zeros(384))
    # the cache only changes WHERE Q rows come from, never their values;
    # the served path contracts (PB,)@(PB,n) instead of (n,PB)@(PB,), so
    # float32 reassociation allows ~1e-6 drift but the trajectory (round
    # count) and the iterate must agree
    assert int(r0) == int(r1)
    np.testing.assert_allclose(np.asarray(a0), np.asarray(a1), atol=1e-4)


def test_conquer_pg_max_is_residual_at_returned_alpha():
    """Regression: conquer_step used to report the stopping value measured
    BEFORE the final update — one stale round behind the returned alpha."""
    X, y = gaussian_mixture(jax.random.PRNGKey(2), 256, d=6, modes_per_class=3)
    Q = (y[:, None] * y[None, :]) * gram(KERN, X, X)
    cfg = ConquerConfig(kernel=KERN, C=2.0, tol=1e-9, max_iters=1, block=32)
    alpha, iters, pg = conquer_step(_mesh1(), "i", cfg, X, y, jnp.zeros(256))
    assert int(iters) == 1
    after = float(kkt_residual(Q, alpha, 2.0))
    before = float(kkt_residual(Q, jnp.zeros(256), 2.0))
    assert abs(float(pg) - after) <= 1e-5 * (1.0 + after)
    # the stale value (residual at the starting point) is far away
    assert abs(float(pg) - before) > 1e-3


def test_conquer_vector_box_and_linear_term():
    """Weighted per-coordinate box + nonuniform linear term (the TaskDual
    generalization) against the dense shrinking solver."""
    X, y = gaussian_mixture(jax.random.PRNGKey(5), 300, d=6, modes_per_class=3)
    td = WeightedCSVC(w_pos=2.0, w_neg=0.5).build(X, y[None, :], 2.0)
    s, p, c = td.S[0], td.P[0], td.Cvec[0]
    Q = (s[:, None] * s[None, :]) * gram(KERN, X, X)
    ref = solve_with_shrinking(Q, c, tol=1e-6, max_iters=200_000, block=32,
                               p=p)
    cfg = ConquerConfig(kernel=KERN, C=2.0, tol=1e-4, max_iters=4000,
                        block=16)
    alpha, _, pg = conquer_step(_mesh1(), "i", cfg, X, s, jnp.zeros(300),
                                p=p, c=c)
    f = lambda a: float(0.5 * jnp.vdot(a, Q @ a) + jnp.vdot(p, a))
    rel = abs(f(alpha) - f(ref.alpha)) / abs(f(ref.alpha))
    assert float(pg) <= 1e-3
    assert rel <= 1e-3


def test_conquer_pads_unaligned_n():
    """n need not divide the device count: rows are padded with c=0
    coordinates that can never move nor report violations."""
    X, y = gaussian_mixture(jax.random.PRNGKey(6), 333, d=6, modes_per_class=3)
    cfg = ConquerConfig(kernel=KERN, C=2.0, tol=1e-4, max_iters=3000,
                        block=16)
    alpha, _, pg = conquer_step(_mesh1(), "i", cfg, X, y, jnp.zeros(333))
    assert alpha.shape == (333,)
    Q = (y[:, None] * y[None, :]) * gram(KERN, X, X)
    assert float(kkt_residual(Q, alpha, 2.0)) <= 1e-3


def test_conquer_loop_is_host_sync_free():
    """The conquer while-loop must run device-resident: no host round-trips
    between rounds (transfer_guard trips on any device->host copy)."""
    X, y = gaussian_mixture(jax.random.PRNGKey(7), 256, d=6, modes_per_class=3)
    cfg = ConquerConfig(kernel=KERN, C=2.0, tol=1e-4, max_iters=2000,
                        block=16)
    # warm call compiles (compilation itself may inspect host values)
    conquer_step(_mesh1(), "i", cfg, X, y, jnp.zeros(256))
    with jax.transfer_guard_device_to_host("disallow"):
        alpha, iters, pg = conquer_step(_mesh1(), "i", cfg, X, y,
                                        jnp.zeros(256))
    Q = (y[:, None] * y[None, :]) * gram(KERN, X, X)
    assert float(kkt_residual(Q, alpha, 2.0)) <= 1e-3


@pytest.mark.parametrize("mode,cache", [("parallel", 0), ("parallel", 128),
                                        ("replicated", 0)])
def test_conquer_trace_bit_identical_and_host_sync_free(mode, cache):
    """trace_cap > 0 threads a device-resident ConvTrace through the conquer
    rounds: the iterate must stay bit-identical to the untraced run, the
    traced loop must add no device->host sync (the ring is fetched after),
    and the per-round samples must line up with the round count."""
    from repro.obs.trace import trace_fetch

    X, y = gaussian_mixture(jax.random.PRNGKey(9), 256, d=6, modes_per_class=3)
    base = ConquerConfig(kernel=KERN, C=2.0, tol=1e-4, max_iters=2000,
                         block=16, mode=mode, cache_cap=cache)
    traced = dataclasses.replace(base, trace_cap=64)
    a0, r0, pg0 = conquer_step(_mesh1(), "i", base, X, y, jnp.zeros(256))
    conquer_step(_mesh1(), "i", traced, X, y, jnp.zeros(256))   # warm compile
    with jax.transfer_guard_device_to_host("disallow"):
        a1, r1, pg1, tr = conquer_step(_mesh1(), "i", traced, X, y,
                                       jnp.zeros(256))
        a1.block_until_ready()
    assert np.array_equal(np.asarray(a0), np.asarray(a1))
    assert int(r0) == int(r1)
    out = trace_fetch(tr)
    assert out["samples"] + out["dropped"] == int(r1)
    # per-round pg is the selection-time violation (pre-update), so the last
    # sample sits one round behind the exit residual but the same order
    assert all(np.isfinite(v) and v > 0 for v in out["pg_max"])
    assert out["pg_max"][-1] >= float(pg1) * 0.1
    assert all(np.isfinite(v) for v in out["objective"])
    if mode == "parallel":
        assert "gamma" in out       # CE-PBM records the combination step γ*
        assert all(0.0 <= g <= 1.0 for g in out["gamma"])
    else:
        assert "gamma" not in out   # replicated has no combination step
    if cache:
        assert "cache_hits" in out  # per-round hit deltas


def test_combination_step_size_properties():
    # interior optimum of the 1-d quadratic: gamma = -g*d/(d*Q*d)
    assert float(combination_step_size(jnp.float32(-1.0),
                                       jnp.float32(4.0))) == 0.25
    # descent directions want gamma >= 0; clip at the full block step
    assert float(combination_step_size(jnp.float32(-8.0),
                                       jnp.float32(4.0))) == 1.0
    # degenerate curvature falls back to the full step
    assert float(combination_step_size(jnp.float32(-1.0),
                                       jnp.float32(0.0))) == 1.0
    # ascent direction (cannot happen for exact block solves) is rejected
    assert float(combination_step_size(jnp.float32(2.0),
                                       jnp.float32(4.0))) == 0.0


def test_divide_single_device_mesh():
    X, y = gaussian_mixture(jax.random.PRNGKey(1), 256, d=6)
    cfg = DCSVMConfig(kernel=KERN, C=2.0, tol=1e-4)
    Xc = X.reshape(4, 64, 6)
    yc = y.reshape(4, 64)
    mask = jnp.ones((4, 64), bool)
    pc = jnp.full((4, 64), -1.0)
    cc = jnp.full((4, 64), 2.0)
    ac = divide_step(_mesh1(), "i", cfg, Xc, yc, pc, cc,
                     jnp.zeros((4, 64)), mask)
    # each block solves its own subproblem to KKT
    for c in range(4):
        Qc = (yc[c][:, None] * yc[c][None, :]) * gram(KERN, Xc[c], Xc[c])
        assert float(kkt_residual(Qc, ac[c], 2.0)) <= 1e-3


def test_divide_sequential_fallback_matches_vmap():
    """gram_budget too small for per-device Gram residency -> lax.map path;
    the answer must not change."""
    X, y = gaussian_mixture(jax.random.PRNGKey(8), 256, d=6)
    Xc, yc = X.reshape(4, 64, 6), y.reshape(4, 64)
    mask = jnp.ones((4, 64), bool)
    pc = jnp.full((4, 64), -1.0)
    cc = jnp.full((4, 64), 2.0)
    a0 = jnp.zeros((4, 64))
    cfg = DCSVMConfig(kernel=KERN, C=2.0, tol=1e-4)
    small = dataclasses.replace(cfg, gram_budget=1)
    av = divide_step(_mesh1(), "i", cfg, Xc, yc, pc, cc, a0, mask)
    As = divide_step(_mesh1(), "i", small, Xc, yc, pc, cc, a0, mask)
    np.testing.assert_allclose(np.asarray(av), np.asarray(As), atol=1e-6)


def test_fit_distributed_svr_single_device():
    key = jax.random.PRNGKey(9)
    X = jax.random.uniform(key, (300, 6))
    yr = jnp.sin(3.0 * X[:, 0]) + 0.5 * X[:, 1]
    task = EpsilonSVR(eps=0.1)
    td = task.build(X, yr[None, :], 2.0)
    s, p, c = td.S[0], td.P[0], td.Cvec[0]
    Q = (s[:, None] * s[None, :]) * gram(KERN, td.Xd, td.Xd)
    ref = solve_with_shrinking(Q, c, tol=1e-6, max_iters=400_000, block=32,
                               p=p)
    cfg = DCSVMConfig(kernel=KERN, C=2.0, k=4, levels=1, m=128, tol=1e-4,
                      use_pallas=False)
    alpha, stats = fit_distributed(cfg, _mesh1(), "i", X, yr, task=task,
                                   conquer_block=16, conquer_iters=6000)
    f = lambda a: float(0.5 * jnp.vdot(a, Q @ a) + jnp.vdot(p, a))
    rel = abs(f(alpha) - f(ref.alpha)) / abs(f(ref.alpha))
    assert rel <= 1e-3
    # stats must already be host scalars (no lingering device arrays)
    for row in stats:
        for v in row.values():
            assert isinstance(v, (int, float)), type(v)


def test_fit_distributed_model_builds_beta():
    X, y = gaussian_mixture(jax.random.PRNGKey(10), 256, d=6,
                            modes_per_class=3)
    cfg = DCSVMConfig(kernel=KERN, C=2.0, k=4, levels=1, m=128, tol=1e-4,
                      use_pallas=False)
    model = fit_distributed_model(cfg, _mesh1(), "i", X, y, conquer_block=16)
    from repro.core.predict import predict_exact
    acc = float(jnp.mean(jnp.sign(predict_exact(model, X)) == y))
    assert acc >= 0.9
    assert model.beta is not None and model.beta.shape == (256,)


def test_fit_distributed_rejects_equality_tasks():
    X, _ = gaussian_mixture(jax.random.PRNGKey(11), 64, d=4)
    cfg = DCSVMConfig(kernel=KERN, C=1.0, levels=1, tol=1e-3)
    with pytest.raises(NotImplementedError, match="equality"):
        fit_distributed(cfg, _mesh1(), "i", X, task=OneClassSVM(nu=0.5))


def test_conquer_rejects_unknown_mode():
    X, y = gaussian_mixture(jax.random.PRNGKey(12), 64, d=4)
    cfg = ConquerConfig(kernel=KERN, C=1.0, mode="gossip")
    with pytest.raises(ValueError, match="mode"):
        conquer_step(_mesh1(), "i", cfg, X, y, jnp.zeros(64))


def test_make_host_mesh_clear_error_on_bad_axis():
    with pytest.raises(ValueError, match="model_axis"):
        make_host_mesh(model_axis=3 * jax.device_count())


_SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import DCSVMConfig, Kernel, gram, kkt_residual
    from repro.core.distributed import (ConquerConfig, conquer_step,
                                        fit_distributed)
    from repro.core.solver import solve_with_shrinking
    from repro.core.tasks import EpsilonSVR, WeightedCSVC
    from repro.data import gaussian_mixture

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((8,), ("i",))
    KERN = Kernel("rbf", gamma=8.0)
    # 1001 % 8 != 0: exercises the padded shards on every device
    X, y = gaussian_mixture(jax.random.PRNGKey(0), 1001, d=8,
                            modes_per_class=4)
    Q = (y[:, None] * y[None, :]) * gram(KERN, X, X)
    f = lambda a: float(0.5 * a @ Q @ a - a.sum())
    ref = solve_with_shrinking(Q, 4.0, tol=1e-5, max_iters=200_000, block=64)
    fref = f(ref.alpha)

    # parallel-block conquer from zero: dense parity + STRICTLY fewer
    # communication rounds than the replicated single-block baseline
    cfg = ConquerConfig(kernel=KERN, C=4.0, tol=1e-4, max_iters=4000,
                        block=16, mode="parallel")
    alpha, rounds_p, pg = conquer_step(mesh, "i", cfg, X, y, jnp.zeros(1001))
    rel = abs(f(alpha) - fref) / abs(fref)
    assert rel <= 1e-3, rel
    rcfg = dataclasses.replace(cfg, mode="replicated")
    alpha_r, rounds_r, _ = conquer_step(mesh, "i", rcfg, X, y,
                                        jnp.zeros(1001))
    rel_r = abs(f(alpha_r) - fref) / abs(fref)
    assert rel_r <= 1e-3, rel_r
    assert int(rounds_p) < int(rounds_r), (int(rounds_p), int(rounds_r))

    # full multilevel distributed fit matches the dense objective
    dcfg = DCSVMConfig(kernel=KERN, C=4.0, k=8, levels=2, m=256, tol=1e-4,
                       use_pallas=False)
    alpha2, stats = fit_distributed(dcfg, mesh, "i", X, y, conquer_block=16)
    rel2 = abs(f(alpha2) - fref) / abs(fref)
    assert rel2 <= 1e-3, rel2

    # weighted-class box on 8 devices
    wt = WeightedCSVC(w_pos=2.0, w_neg=0.5)
    tdw = wt.build(X, y[None, :], 4.0)
    sw, pw, cw = tdw.S[0], tdw.P[0], tdw.Cvec[0]
    Qw = (sw[:, None] * sw[None, :]) * gram(KERN, X, X)
    refw = solve_with_shrinking(Qw, cw, tol=1e-5, max_iters=200_000,
                                block=64, p=pw)
    fw = lambda a: float(0.5 * a @ Qw @ a + pw @ a)
    aw, s2 = fit_distributed(dcfg, mesh, "i", X, y, task=wt,
                             conquer_block=16)
    relw = abs(fw(aw) - fw(refw.alpha)) / abs(fw(refw.alpha))
    assert relw <= 1e-3, relw

    # epsilon-SVR (2n mirrored dual) on 8 devices
    key = jax.random.PRNGKey(1)
    Xr = jax.random.uniform(key, (600, 6))
    yr = jnp.sin(3.0 * Xr[:, 0]) + 0.5 * Xr[:, 1]
    KR = Kernel("rbf", gamma=2.0)
    task = EpsilonSVR(eps=0.1)
    td = task.build(Xr, yr[None, :], 2.0)
    Qr = (td.S[0][:, None] * td.S[0][None, :]) * gram(KR, td.Xd, td.Xd)
    refr = solve_with_shrinking(Qr, td.Cvec[0], tol=1e-5,
                                max_iters=400_000, block=64, p=td.P[0])
    fr = lambda a: float(0.5 * a @ Qr @ a + td.P[0] @ a)
    rcfg2 = DCSVMConfig(kernel=KR, C=2.0, k=8, levels=1, m=200, tol=1e-4,
                        use_pallas=False)
    ar, s3 = fit_distributed(rcfg2, mesh, "i", Xr, yr, task=task,
                             conquer_block=16, conquer_iters=6000)
    relr = abs(fr(ar) - fr(refr.alpha)) / abs(fr(refr.alpha))
    assert relr <= 1e-3, relr
    print("OK", rel, rel2, relw, relr, int(rounds_p), int(rounds_r))
    """
)


@pytest.mark.slow
def test_multi_device_conquer_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
