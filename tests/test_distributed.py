"""Distributed DC-SVM: shard_map divide/conquer vs the single-device solution.

The multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the dryrun pattern); the
in-process tests exercise the same code path on a 1-device mesh.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import DCSVMConfig, Kernel, gram, kkt_residual
from repro.core.distributed import ConquerConfig, conquer_step, divide_step, fit_distributed
from repro.data import gaussian_mixture

KERN = Kernel("rbf", gamma=8.0)


def _mesh1():
    return jax.make_mesh((1,), ("i",))


def test_conquer_single_device_mesh_matches_dense():
    X, y = gaussian_mixture(jax.random.PRNGKey(0), 512, d=6, modes_per_class=3)
    cfg = ConquerConfig(kernel=KERN, C=2.0, tol=1e-4, max_iters=3000, block=32)
    alpha, iters, pg = conquer_step(_mesh1(), "i", cfg, X, y, jnp.zeros(512))
    Q = (y[:, None] * y[None, :]) * gram(KERN, X, X)
    assert float(pg) <= 1e-4 * 1.5
    assert float(kkt_residual(Q, alpha, 2.0)) <= 1e-3


def test_divide_single_device_mesh():
    X, y = gaussian_mixture(jax.random.PRNGKey(1), 256, d=6)
    cfg = DCSVMConfig(kernel=KERN, C=2.0, tol=1e-4)
    Xc = X.reshape(4, 64, 6)
    yc = y.reshape(4, 64)
    mask = jnp.ones((4, 64), bool)
    ac = divide_step(_mesh1(), "i", cfg, Xc, yc, jnp.zeros((4, 64)), mask)
    # each block solves its own subproblem to KKT
    for c in range(4):
        Qc = (yc[c][:, None] * yc[c][None, :]) * gram(KERN, Xc[c], Xc[c])
        assert float(kkt_residual(Qc, ac[c], 2.0)) <= 1e-3


_SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import DCSVMConfig, Kernel, gram, kkt_residual
    from repro.core.distributed import ConquerConfig, conquer_step, fit_distributed
    from repro.data import gaussian_mixture

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((8,), ("i",))
    KERN = Kernel("rbf", gamma=8.0)
    X, y = gaussian_mixture(jax.random.PRNGKey(0), 1024, d=8, modes_per_class=4)
    Q = (y[:, None] * y[None, :]) * gram(KERN, X, X)

    # conquer from zero on 8 devices reaches full-problem KKT
    cfg = ConquerConfig(kernel=KERN, C=4.0, tol=1e-4, max_iters=4000, block=16)
    alpha, iters, pg = conquer_step(mesh, "i", cfg, X, y, jnp.zeros(1024))
    kkt = float(kkt_residual(Q, alpha, 4.0))
    assert kkt <= 1e-3, kkt

    # full distributed multilevel run matches the dense objective
    dcfg = DCSVMConfig(kernel=KERN, C=4.0, k=4, levels=2, m=256, tol=1e-4)
    alpha2, stats = fit_distributed(dcfg, mesh, "i", X, y, conquer_block=16)
    kkt2 = float(kkt_residual(Q, alpha2, 4.0))
    assert kkt2 <= 1e-3, kkt2

    f = lambda a: float(0.5 * a @ Q @ a - a.sum())
    rel = abs(f(alpha2) - f(alpha)) / abs(f(alpha))
    assert rel < 1e-3, rel
    print("OK", kkt, kkt2, rel, int(iters))
    """
)


@pytest.mark.slow
def test_multi_device_conquer_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
