"""Substrate tests: optimizer, schedule, checkpointing, data pipeline,
gradient compression, roofline parser."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager, load_pytree, save_pytree
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.grad_compress import compress, compress_ef, decompress
from repro.roofline.analysis import collective_bytes, roofline_terms


# ---------------------------------------------------------------------- optim

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(cfg, params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        params, state, _ = adamw_update(cfg, grads, state, params,
                                        jnp.asarray(0.1))
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_grad_clip():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(cfg, params)
    _, _, m = adamw_update(cfg, {"w": jnp.full(4, 100.0)}, state, params,
                           jnp.asarray(1e-3))
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_adamw_master_fp32_for_bf16_params():
    cfg = AdamWConfig()
    params = {"w": jnp.zeros(8, jnp.bfloat16)}
    state = adamw_init(cfg, params)
    assert "master" in state and state["master"]["w"].dtype == jnp.float32
    # f32 params: no master (avoids donation aliasing)
    state2 = adamw_init(cfg, {"w": jnp.zeros(8, jnp.float32)})
    assert "master" not in state2


def test_cosine_schedule_shape():
    s = cosine_schedule(1e-3, warmup=100, total=1000)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(100))) == pytest.approx(1e-3, rel=1e-5)
    assert float(s(jnp.asarray(1000))) == pytest.approx(1e-4, rel=1e-3)


# ----------------------------------------------------------------------- ckpt

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5, dtype=jnp.float32),
            "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    p = str(tmp_path / "x.npz")
    save_pytree(p, tree)
    out = load_pytree(p, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    assert np.allclose(out["a"], np.arange(5))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_keep_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in (1, 2, 3):
        mgr.save(step, {"w": jnp.full(3, float(step))})
    assert mgr.steps() == [2, 3]
    out = mgr.restore({"w": jax.ShapeDtypeStruct((3,), jnp.float32)})
    assert np.allclose(out["w"], 3.0)
    # atomic: no tmp debris
    assert not [f for f in os.listdir(tmp_path) if f.startswith(".tmp")]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(7, {"w": jnp.ones(4)})
    mgr.wait()
    assert mgr.latest_step() == 7


# ----------------------------------------------------------------------- data

def test_token_pipeline_deterministic_and_restart_safe():
    cfg = TokenPipelineConfig(vocab_size=1000, global_batch=8, seq_len=32, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    a1, t1 = p1.global_batch_at(jnp.asarray(17))
    a2, t2 = p2.global_batch_at(jnp.asarray(17))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(t1), np.asarray(t2))
    b1, _ = p1.global_batch_at(jnp.asarray(18))
    assert not np.array_equal(np.asarray(a1), np.asarray(b1))
    # host shard slicing is consistent with the global batch
    s0, _ = p1.host_shard_at(17, 0, 4)
    assert np.array_equal(np.asarray(s0), np.asarray(a1[:2]))
    assert int(a1.max()) < 1000 and int(a1.min()) >= 0


# ----------------------------------------------------------------- compression

def test_compress_roundtrip_error_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = compress(x)
    x2 = decompress(q, s, x.shape)
    err = float(jnp.max(jnp.abs(x - x2)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_error_feedback_accumulates():
    x = jax.random.normal(jax.random.PRNGKey(1), (512,)) * 1e-3
    residual = jnp.zeros_like(x)
    total_sent = jnp.zeros_like(x)
    for _ in range(50):
        q, s, residual = compress_ef(x, residual)
        total_sent = total_sent + decompress(q, s, x.shape)
    # over many steps the *sum* of transmitted grads converges to 50x
    rel = float(jnp.linalg.norm(total_sent - 50 * x) / jnp.linalg.norm(50 * x))
    assert rel < 0.05


def test_compressed_psum_multi_device_subprocess():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.optim.grad_compress import compressed_psum
        mesh = jax.make_mesh((4,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 300))
        out = compressed_psum(x, mesh, "pod")
        want = jnp.sum(x, 0)
        for i in range(4):
            rel = float(jnp.linalg.norm(out[i] - want) / jnp.linalg.norm(want))
            assert rel < 0.02, rel
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr


# -------------------------------------------------------------------- roofline

def test_collective_parser_counts_ops():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[256]{0} all-reduce(%y), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%z), dimensions={0}
  %aa = bf16[8,8]{1,0} all-to-all(%w), dimensions={0}
  %cp = f32[4]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %other = f32[2]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-reduce"] == 1
    ag = 16 * 1024 * 2
    ar = 256 * 4 * 2.0      # 2x multiplier
    rs = 64 * 32 * 4
    aa = 8 * 8 * 2
    cp = 4 * 4
    assert out["total_bytes"] == pytest.approx(ag + ar + rs + aa + cp)


def test_roofline_terms_bottleneck():
    cost = {"flops": 1e15, "bytes accessed": 1e9}
    t = roofline_terms(cost, coll_bytes=1e6)
    assert t["bottleneck"] == "compute"
    t2 = roofline_terms({"flops": 1e9, "bytes accessed": 1e12}, 1e6)
    assert t2["bottleneck"] == "memory"
    t3 = roofline_terms({"flops": 1e9, "bytes accessed": 1e9}, 1e12)
    assert t3["bottleneck"] == "collective"
