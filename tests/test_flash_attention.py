"""Flash-attention Pallas kernel: shape/dtype sweeps vs the naive oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


def _qkv(key, BH, Sq, Sk, hd, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(k1, (BH, Sq, hd), dtype)
    k = jax.random.normal(k2, (BH, Sk, hd), dtype)
    v = jax.random.normal(k3, (BH, Sk, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("BH,Sq,Sk,hd,bq,bk", [
    (2, 128, 128, 32, 64, 64),
    (1, 256, 256, 64, 64, 128),
    (3, 64, 192, 16, 32, 64),     # rectangular (cross-attention shape)
    (2, 128, 128, 128, 128, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(BH, Sq, Sk, hd, bq, bk, causal):
    if causal and Sq != Sk:
        pytest.skip("causal assumes square here")
    q, k, v = _qkv(BH + Sq, BH, Sq, Sk, hd)
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_inputs():
    q, k, v = _qkv(7, 2, 128, 128, 64, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
    assert got.dtype == jnp.bfloat16


def test_flash_online_softmax_stability():
    """Large score magnitudes: online max-subtraction must not overflow."""
    q, k, v = _qkv(9, 1, 128, 128, 32)
    q = q * 30.0
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    assert bool(jnp.all(jnp.isfinite(got)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_matches_model_attention():
    """Equivalence with the model library's chunked attention (GQA folded)."""
    from repro.models.layers import chunked_attention
    B, S, Hq, Hkv, hd = 2, 128, 4, 2, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    want = chunked_attention(q, k, v, causal=True, chunk=64)
    # fold GQA: repeat kv heads, flatten (B, H) into batch
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    kf = kr.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    vf = vr.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    got = flash_attention(qf, kf, vf, causal=True, bq=64, bk=64, interpret=True)
    got = got.reshape(B, Hq, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
