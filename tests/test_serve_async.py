"""Async serving engine + versioned registry tests.

Covers the ragged-batch recompile fixes (bucket-derived capacity, ONE
compile across ragged sizes sharing a bucket), queue/bucketing determinism
(async results bit-equal to direct ``serve_batch``), registry
resolve/hot-swap under in-flight requests, manifest round-trips for all
three tasks, and the engine's zero-recompiles-after-warmup invariant under
a Poisson trace with mixed request sizes and two registered versions.
"""
import asyncio
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import DCSVMConfig, Kernel, fit, fit_ova
from repro.core.predict import _early_program, bucket_size
from repro.core.tasks import EpsilonSVR, OneClassSVM
from repro.data import (
    friedman1,
    gaussian_mixture_multiclass,
    gaussian_with_outliers,
    train_test_split,
)
from repro.launch.engine import (
    AsyncServingEngine,
    DeadlineExceeded,
    EngineConfig,
    EngineOverloaded,
)
from repro.launch.registry import ModelManifest, ModelRegistry
from repro.launch.serve_svm import (
    export_serving_model,
    run_request_loop,
    serve_batch,
    serving_cache_size,
)

KERN = Kernel("rbf", gamma=16.0)


@pytest.fixture(scope="module")
def ova_models():
    """Two versions of a 3-class OVA model (different C) + a query pool."""
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), 700,
                                       n_classes=3, d=8, spread=0.10)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    cfg1 = DCSVMConfig(kernel=KERN, C=4.0, k=4, levels=1, m=200, tol=1e-3)
    cfg2 = DCSVMConfig(kernel=KERN, C=2.0, k=4, levels=1, m=200, tol=1e-3)
    return fit_ova(cfg1, Xtr, ytr), fit_ova(cfg2, Xtr, ytr), np.asarray(Xte)


@pytest.fixture(scope="module")
def registry2(ova_models):
    m1, m2, _ = ova_models
    reg = ModelRegistry()
    reg.register("mix", m1)
    reg.register("mix", m2)
    return reg


def _mixed_batches(Xpool, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [Xpool[rng.integers(0, Xpool.shape[0], size=s)] for s in sizes]


# ---------------------------------------------------------------------------
# bucket-shape capacity: the ragged-batch recompile fixes
# ---------------------------------------------------------------------------

def test_bucket_size_policy():
    assert [bucket_size(n) for n in (0, 1, 7, 8, 9, 64, 100, 300)] == \
        [8, 8, 8, 8, 16, 64, 128, 512]
    # past hi: multiples of hi, not the next power of two
    assert bucket_size(5000, hi=4096) == 8192
    assert bucket_size(9000, hi=4096) == 12288


def test_one_compile_across_ragged_sizes(ova_models):
    """THE recompile bug: unbucketed, every distinct batch size is a fresh
    ``early_capacity`` static arg and a fresh compile of the early program.
    Bucketed, ragged sizes sharing one bucket share ONE compile."""
    m1, _, Xpool = ova_models
    sm = export_serving_model(m1)
    sizes = [33, 50, 64, 40, 57]                  # all bucket to 64
    batches = _mixed_batches(Xpool, sizes)
    before = _early_program._cache_size()
    for b in batches:
        serve_batch(sm, jnp.asarray(b), KERN, "early", bucket=64)
    assert _early_program._cache_size() - before == 1
    # the unbucketed path compiles per distinct size (the defect this PR
    # fixes in every serving loop; kept for single-shot compatibility).
    # size 64 is excluded: its raw signature equals the warmed bucket's.
    ragged = [b for b in batches if b.shape[0] != 64]
    before = _early_program._cache_size()
    for b in ragged:
        serve_batch(sm, jnp.asarray(b), KERN, "early")
    assert _early_program._cache_size() - before == len(ragged)


@pytest.mark.parametrize("strategy", ["exact", "early", "bcm"])
def test_bucketed_bit_identical_to_unbucketed(ova_models, strategy):
    """Padding rows must not perturb the real rows: bucketed scores are
    bit-identical to the unbucketed ``serve_batch`` on the same rows."""
    m1, _, Xpool = ova_models
    sm = export_serving_model(m1)
    for size in (3, 17, 33):
        Xq = jnp.asarray(_mixed_batches(Xpool, [size], seed=size)[0])
        pred_u, scores_u = serve_batch(sm, Xq, KERN, strategy)
        pred_b, scores_b = serve_batch(sm, Xq, KERN, strategy,
                                       bucket=bucket_size(size))
        np.testing.assert_array_equal(np.asarray(scores_u),
                                      np.asarray(scores_b))
        np.testing.assert_array_equal(np.asarray(pred_u), np.asarray(pred_b))


def test_serve_batch_rejects_undersized_bucket(ova_models):
    m1, _, Xpool = ova_models
    sm = export_serving_model(m1)
    with pytest.raises(ValueError, match="bucket"):
        serve_batch(sm, jnp.asarray(Xpool[:32]), KERN, "early", bucket=16)


def test_request_loop_warms_every_ragged_shape(ova_models):
    """Pre-fix, ``run_request_loop`` warmed only the first batch's shape, so
    ragged streams compiled INSIDE the timed region (corrupting p95/p99).
    Now every distinct bucket signature is warmed first: the report's
    ``compiles_timed`` (jit-cache growth across the timed loop) is zero."""
    m1, _, Xpool = ova_models
    sm = export_serving_model(m1)
    batches = _mixed_batches(Xpool, [5, 12, 33, 64, 9, 50, 2])
    rep = run_request_loop(sm, KERN, "early", batches, warmup=1,
                           bucketed=True)
    assert rep["compiles_timed"] == 0
    assert rep["batch"] == 0 and rep["batches"] == 7
    assert rep["queries"] == 5 + 12 + 33 + 64 + 9 + 50 + 2
    assert rep["lat_ms_p99"] >= rep["lat_ms_p50"] > 0


# ---------------------------------------------------------------------------
# manifests
# ---------------------------------------------------------------------------

def test_manifest_roundtrip_all_tasks():
    """svc / svr / ocsvm (incl. per-cluster rho_c of an early-stopped
    one-class model) manifests all survive the JSON round trip."""
    reg = ModelRegistry()
    kern = Kernel("rbf", gamma=4.0)
    # svc
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(2), 300,
                                       n_classes=3, d=6, spread=0.1)
    cfg = DCSVMConfig(kernel=kern, C=4.0, k=2, levels=1, m=100, tol=1e-2)
    reg.register("svc", fit_ova(cfg, X, y))
    # svr
    Xr, yr = friedman1(jax.random.PRNGKey(3), 300)
    reg.register("svr", fit(cfg, Xr, yr, task=EpsilonSVR(eps=0.2)),
                 with_bcm=False)
    # ocsvm, early-stopped => per-cluster rho_c
    Xo, _ = gaussian_with_outliers(jax.random.PRNGKey(4), 300)
    cfg_o = DCSVMConfig(kernel=kern, C=1.0, k=2, levels=1, m=100, tol=1e-2,
                        early_stop_level=1)
    reg.register("ocsvm", fit(cfg_o, Xo, task=OneClassSVM(nu=0.2)))

    for name, task, n_classes in (("svc", "svc", 3), ("svr", "svr", 0),
                                  ("ocsvm", "ocsvm", 1)):
        man = reg.resolve(name).manifest
        assert man.task == task and man.n_classes == n_classes
        rt = ModelManifest.from_json(man.to_json())
        assert rt == man
        assert rt.make_kernel() == kern
    assert reg.resolve("svr").manifest.eps == pytest.approx(0.2)
    assert reg.resolve("svr").manifest.strategies == ("exact", "early")
    oc = reg.resolve("ocsvm").manifest
    assert oc.nu == pytest.approx(0.2)
    assert len(oc.rho_c) == 2            # k=2 per-cluster offsets survived
    # manifests JSON is what --registry dumps
    j = reg.to_json()
    assert {m["name"] for m in j["models"]} == {"svc", "svr", "ocsvm"}


def test_registry_versioning_and_routing(registry2):
    assert registry2.versions("mix") == [1, 2]
    assert registry2.default_version("mix") == 1        # first stays default
    assert registry2.resolve("mix").version == 1
    assert registry2.resolve("mix", 2).version == 2
    with pytest.raises(KeyError):
        registry2.resolve("mix", 9)
    with pytest.raises(KeyError):
        registry2.resolve("nope")
    with pytest.raises(ValueError, match="default"):
        registry2.drop("mix", 1)                        # routed default
    with pytest.raises(ValueError, match="registered"):
        registry2.register("mix", object(), version=2)  # duplicate version


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

def test_async_bit_equal_to_direct_serve(registry2):
    """Queue/bucketing determinism: whatever the batch manager merges, each
    request's rows come back bit-identical to a direct ``serve_batch`` on
    those rows (per-row scores are independent of batch-mates/padding)."""
    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)   # any pool works
    sizes = [1, 7, 33, 12, 64, 50, 3, 28]
    reqs = _mixed_batches(Xpool, sizes, seed=5)

    async def main():
        engine = AsyncServingEngine(registry2, EngineConfig(max_batch=64))
        engine.warmup("mix", strategies=["early", "exact"])
        async with engine:
            outs = await asyncio.gather(*[
                engine.submit(r, "mix", strategy="early") for r in reqs])
        return outs

    outs = asyncio.run(main())
    entry = registry2.resolve("mix")
    for r, (pred, scores) in zip(reqs, outs):
        dp, ds = serve_batch(entry.sm, jnp.asarray(r), entry.kern, "early",
                             bucket=bucket_size(len(r)))
        np.testing.assert_array_equal(np.asarray(scores), np.asarray(ds))
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(dp))


def test_engine_zero_compiles_after_warmup_poisson(registry2):
    """Acceptance: Poisson arrivals, mixed sizes, BOTH registered versions —
    zero recompiles after warmup, pinned by the compile counter AND the raw
    jit-cache size."""
    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)
    rng = np.random.default_rng(7)
    n_req = 40
    sizes = rng.choice([1, 4, 16, 64], size=n_req, p=[0.35, 0.3, 0.25, 0.1])
    gaps = rng.exponential(1.0 / 2000.0, size=n_req)

    engine = AsyncServingEngine(registry2, EngineConfig(max_batch=64))
    engine.warmup("mix", strategies=["early"])
    cache_after_warmup = serving_cache_size()

    async def main():
        async with engine:
            async def one(i):
                await asyncio.sleep(float(np.sum(gaps[: i + 1])))
                X = Xpool[rng.integers(0, Xpool.shape[0], size=int(sizes[i]))]
                return await engine.submit(X, "mix", version=1 + i % 2,
                                           strategy="early")
            await asyncio.gather(*[one(i) for i in range(n_req)])

    asyncio.run(main())
    assert serving_cache_size() == cache_after_warmup
    st = engine.stats()
    assert st["compiles_after_warmup"] == 0
    assert st["requests"] == n_req and st["queries"] == int(sizes.sum())
    # engine metrics made it through: per-version latency histograms,
    # fill-ratio histogram, queue-depth gauge
    j = engine.metrics.to_json()
    assert any('version="1"' in k for k in j["histograms"])
    assert any('version="2"' in k for k in j["histograms"])
    assert any(k.startswith("serve_batch_fill_ratio")
               for k in j["histograms"])
    assert j["gauges"]["serve_queue_depth"] == 0


def test_hot_swap_under_inflight_requests(ova_models):
    """Swap repoints NEW submits atomically; requests already queued on the
    old version drain on it, then the old version is dropped."""
    m1, m2, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1)
    reg.register("m", m2)
    results = {}

    async def main():
        engine = AsyncServingEngine(reg, EngineConfig(max_batch=32))
        engine.warmup("m", strategies=["early"])
        async with engine:
            pre = [asyncio.ensure_future(
                engine.submit(Xpool[i * 8:(i + 1) * 8], "m",
                              strategy="early")) for i in range(4)]
            # let the submit coroutines run to their enqueue point so they
            # resolve v1 (the route table as of NOW) before the swap lands
            await asyncio.sleep(0)
            old = await engine.swap("m", 2)
            assert old == 1
            post = await engine.submit(Xpool[:8], "m", strategy="early")
            results["pre"] = [await f for f in pre]
            results["post"] = post
        assert reg.versions("m") == [2]       # drained, then dropped
        assert reg.default_version("m") == 2

    asyncio.run(main())
    # pre-swap requests were served by v1, post-swap by v2 — each matches a
    # direct serve against the respective model
    sm2 = reg.resolve("m", 2).sm
    sm1 = export_serving_model(m1)
    for i, (pred, scores) in enumerate(results["pre"]):
        _, ref = serve_batch(sm1, jnp.asarray(Xpool[i * 8:(i + 1) * 8]),
                             KERN, "early", bucket=bucket_size(8))
        np.testing.assert_array_equal(np.asarray(scores), np.asarray(ref))
    _, ref2 = serve_batch(sm2, jnp.asarray(Xpool[:8]), KERN, "early",
                          bucket=bucket_size(8))
    np.testing.assert_array_equal(np.asarray(results["post"][1]),
                                  np.asarray(ref2))


def test_engine_rejects_unserveable_strategy(ova_models):
    """A with_bcm=False export's manifest caps the strategy set; the engine
    refuses at submit instead of crashing inside the batch loop."""
    m1, _, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1, with_bcm=False)

    async def main():
        async with AsyncServingEngine(reg) as engine:
            with pytest.raises(ValueError, match="does not serve"):
                await engine.submit(Xpool[:4], "m", strategy="bcm")

    asyncio.run(main())


def test_engine_submit_requires_running_loop(registry2):
    engine = AsyncServingEngine(registry2)
    with pytest.raises(RuntimeError, match="not running"):
        asyncio.run(engine.submit(np.zeros((2, 8), np.float32), "mix"))


# ---------------------------------------------------------------------------
# overload robustness: shed / deadlines / liveness / supervision
# ---------------------------------------------------------------------------

class _GatedServe:
    """Wraps ``serve_batch`` behind a threading gate: the batch loop's
    executor thread blocks in ``__call__`` until ``release`` is set, giving
    tests a deterministic window in which the loop is mid-batch (popped,
    computing) while the event loop itself stays live."""

    def __init__(self):
        self.release = threading.Event()
        self.entered = threading.Event()

    def __call__(self, *a, **kw):
        self.entered.set()
        assert self.release.wait(30), "gate never released"
        return serve_batch(*a, **kw)


async def _until_inflight(gate: _GatedServe) -> None:
    while not gate.entered.is_set():
        await asyncio.sleep(0.001)


def _hist_count(engine, name):
    return sum(h["count"] for k, h in
               engine.metrics.to_json()["histograms"].items()
               if k.startswith(name))


def test_engine_death_surfaces_in_stop_submit_drain(ova_models):
    """Satellite 1 regression: a poisoned registry entry kills the batch
    loop at batch formation; pre-fix, ``stop()``/``drain()`` spun forever
    on a queue that never empties and the task's exception was swallowed.
    Now the death is supervised: queued futures fail, ``submit`` re-raises,
    and ``stop()`` surfaces the error in bounded time."""
    m1, _, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1)

    async def main():
        engine = AsyncServingEngine(reg, EngineConfig(max_batch=32))
        engine.warmup("m", strategies=["early"])
        await engine.start()
        fut = asyncio.ensure_future(
            engine.submit(Xpool[:8], "m", strategy="early"))
        await asyncio.sleep(0)           # submit enqueued; loop not yet run
        reg._entries[("m", 1)] = None    # poison: formation resolve raises
        await asyncio.sleep(0.05)        # let the loop die on the poison
        # the queued request's future was failed by the supervisor
        with pytest.raises(KeyError, match="version"):
            await fut
        # submit fails fast with the loop's exception, not a hang
        with pytest.raises(KeyError, match="version"):
            await engine.submit(Xpool[:4], "m", strategy="early")
        # drain and stop surface the death in bounded time (pre-fix: hang)
        with pytest.raises(KeyError, match="version"):
            await asyncio.wait_for(engine.drain(), timeout=10)
        with pytest.raises(KeyError, match="version"):
            await asyncio.wait_for(engine.stop(), timeout=10)

    asyncio.run(main())


def test_cancelled_request_not_served_not_observed(ova_models, monkeypatch):
    """Satellite 2 regression: a caller-cancelled request must be reaped
    before batch formation — its rows never reach the device and it never
    lands in the latency histogram (pre-fix it was concatenated, served,
    and observed, skewing p99)."""
    import repro.launch.engine as engine_mod

    m1, _, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1)
    engine = AsyncServingEngine(reg, EngineConfig(max_batch=64))
    engine.warmup("m", strategies=["early"])
    gate = _GatedServe()
    monkeypatch.setattr(engine_mod, "serve_batch", gate)

    async def main():
        async with engine:
            fA = asyncio.ensure_future(
                engine.submit(Xpool[:8], "m", strategy="early"))
            await _until_inflight(gate)            # A popped, mid-batch
            fB = asyncio.ensure_future(
                engine.submit(Xpool[:5], "m", strategy="early"))
            await asyncio.sleep(0)                 # B enqueued
            fB.cancel()                            # caller gave up (e.g.
            await asyncio.sleep(0)                 # asyncio.wait_for)
            gate.release.set()
            predA, _ = await fA
            assert predA.shape[0] == 8
            with pytest.raises(asyncio.CancelledError):
                await fB
            await engine.drain()                   # loop reaps B

    asyncio.run(main())
    st = engine.stats()
    # B's 5 rows never entered a batch; only A was delivered and observed
    assert st["queries"] == 8 and st["requests"] == 1
    assert _hist_count(engine, "serve_latency_seconds") == 1
    assert _hist_count(engine, "serve_queue_wait_seconds") == 1
    assert st["queue_depth"] == 0


def test_shed_at_max_queue_rows(ova_models, monkeypatch):
    """Admission control: with the loop mid-batch, submits past
    ``max_queue_rows`` fail fast with the typed ``EngineOverloaded`` and
    count into ``serve_shed_total``; admitted requests all deliver."""
    import repro.launch.engine as engine_mod

    m1, _, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1)
    engine = AsyncServingEngine(
        reg, EngineConfig(max_batch=64, max_queue_rows=32))
    engine.warmup("m", strategies=["early"])
    gate = _GatedServe()
    monkeypatch.setattr(engine_mod, "serve_batch", gate)

    async def main():
        async with engine:
            fA = asyncio.ensure_future(
                engine.submit(Xpool[:8], "m", strategy="early"))
            await _until_inflight(gate)            # loop blocked mid-batch
            subs = [asyncio.ensure_future(
                engine.submit(Xpool[i * 8:(i + 1) * 8], "m",
                              strategy="early")) for i in range(10)]
            await asyncio.sleep(0)                 # all ten hit admission
            shed = [t for t in subs if t.done()]
            # 32-row bound admits exactly the first four 8-row requests
            assert len(shed) == 6
            for t in shed:
                with pytest.raises(EngineOverloaded, match="queue full"):
                    await t
            gate.release.set()
            await fA
            for t in subs:
                if t not in shed:
                    pred, _ = await t
                    assert pred.shape[0] == 8

    asyncio.run(main())
    st = engine.stats()
    assert st["shed"] == 6
    assert st["requests"] == 5 and st["queries"] == 40   # A + 4 admitted


def test_deadline_expiry_while_queued(ova_models, monkeypatch):
    """A queued request whose deadline expires mid-batch (the event loop
    stays live during device compute) resolves with ``DeadlineExceeded``
    and is reaped before the next batch forms — no device time burned."""
    import repro.launch.engine as engine_mod

    m1, _, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1)
    engine = AsyncServingEngine(reg, EngineConfig(max_batch=64))
    engine.warmup("m", strategies=["early"])
    gate = _GatedServe()
    monkeypatch.setattr(engine_mod, "serve_batch", gate)

    async def main():
        async with engine:
            fA = asyncio.ensure_future(
                engine.submit(Xpool[:8], "m", strategy="early"))
            await _until_inflight(gate)
            fB = asyncio.ensure_future(
                engine.submit(Xpool[:5], "m", strategy="early",
                              timeout_s=0.02))
            # the timer fires while the loop is still blocked in compute —
            # liveness: deadline timers don't wait for the batch
            await asyncio.sleep(0.1)
            assert fB.done()
            with pytest.raises(DeadlineExceeded, match="expired"):
                await fB
            gate.release.set()
            await fA
            await engine.drain()

    asyncio.run(main())
    st = engine.stats()
    assert st["deadline_exceeded"] == 1
    assert st["queries"] == 8 and st["requests"] == 1    # B never served
    assert _hist_count(engine, "serve_latency_seconds") == 1


def test_pre_expired_deadline_never_enqueues(registry2):
    """``timeout_s<=0`` is already expired at submit: it resolves with
    ``DeadlineExceeded`` immediately, without enqueueing or burning a
    batch slot (the bench's deterministic deadline probe)."""
    engine = AsyncServingEngine(registry2, EngineConfig(max_batch=64))
    engine.warmup("mix", strategies=["early"])
    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)

    async def main():
        async with engine:
            with pytest.raises(DeadlineExceeded):
                await engine.submit(Xpool[:4], "mix", strategy="early",
                                    timeout_s=0.0)

    asyncio.run(main())
    st = engine.stats()
    assert st["deadline_exceeded"] == 1
    assert st["queries"] == 0 and st["queue_depth"] == 0


def test_deadline_vs_hot_swap_drain(ova_models, monkeypatch):
    """Swap/drain interaction: a queued old-version request that expires
    during the drain is reaped, not served — the drain completes, the old
    version drops, and the caller sees ``DeadlineExceeded``."""
    import repro.launch.engine as engine_mod

    m1, m2, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1)
    reg.register("m", m2)
    engine = AsyncServingEngine(reg, EngineConfig(max_batch=32))
    engine.warmup("m", strategies=["early"])
    gate = _GatedServe()
    monkeypatch.setattr(engine_mod, "serve_batch", gate)

    async def main():
        async with engine:
            fA = asyncio.ensure_future(
                engine.submit(Xpool[:8], "m", strategy="early"))
            await _until_inflight(gate)
            fB = asyncio.ensure_future(
                engine.submit(Xpool[:5], "m", strategy="early",
                              timeout_s=0.02))
            await asyncio.sleep(0)                 # B queued on v1
            swap = asyncio.ensure_future(engine.swap("m", 2))
            await asyncio.sleep(0.1)               # B expires mid-drain
            gate.release.set()
            await fA                               # v1's in-flight batch
            assert await asyncio.wait_for(swap, timeout=10) == 1
            with pytest.raises(DeadlineExceeded):
                await fB
            post, _ = await engine.submit(Xpool[:4], "m", strategy="early")

    asyncio.run(main())
    assert reg.versions("m") == [2]                # drained, then dropped
    assert engine.stats()["deadline_exceeded"] == 1


def test_drain_bounded_wakeups(registry2):
    """Satellite 3 regression: ``drain`` is event-driven (one wakeup per
    queue progression), not a 100%-CPU ``sleep(0)`` busy-wait — draining a
    long queue costs O(batches) loop wakeups."""
    class _CountingEvent(asyncio.Event):
        def __init__(self):
            super().__init__()
            self.waits = 0

        async def wait(self):
            self.waits += 1
            return await super().wait()

    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)
    engine = AsyncServingEngine(registry2, EngineConfig(max_batch=64))
    engine.warmup("mix", strategies=["early"])
    counted = {}

    async def main():
        async with engine:
            ev = _CountingEvent()
            engine._served = ev
            subs = [asyncio.ensure_future(
                engine.submit(Xpool[i * 16:(i + 1) * 16], "mix",
                              strategy="early")) for i in range(12)]
            await asyncio.sleep(0)                 # all twelve enqueue
            await engine.drain()
            counted["waits"] = ev.waits
            for t in subs:
                await t

    asyncio.run(main())
    # 12 x 16 rows / 64-row batches = 3 batches; a few extra wakeups for
    # pops that interleave with the drain loop are fine — hundreds are not
    assert counted["waits"] <= 8, counted


def test_registry_version_coercion(ova_models):
    """Satellite 4 regression: ``register(version="2")`` must coerce once
    at entry — pre-fix the duplicate check keyed ``(name, int(v))`` but the
    insert used ``(name, v)``, so "2" and 2 silently coexisted."""
    m1, _, _ = ova_models
    reg = ModelRegistry()
    man = reg.register("m", m1, version="2")
    assert man.version == 2
    assert reg.versions("m") == [2]
    assert reg.resolve("m").version == 2
    assert reg.resolve("m", "2").version == 2
    with pytest.raises(ValueError, match="registered"):
        reg.register("m", m1, version=2)
    with pytest.raises(ValueError, match="registered"):
        reg.register("m", m1, version="2")


def test_zero_compiles_after_warmup_under_overload(registry2):
    """Acceptance: an overload burst against a bounded queue with default
    deadlines sheds/expires some requests and delivers the rest — and the
    jit cache stays exactly at its warmup mark throughout."""
    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)
    engine = AsyncServingEngine(
        registry2, EngineConfig(max_batch=64, max_queue_rows=64,
                                timeout_s=0.25))
    engine.warmup("mix", strategies=["early"])
    mark = serving_cache_size()
    rng = np.random.default_rng(11)
    sizes = rng.choice([1, 4, 16, 64], size=60, p=[0.35, 0.3, 0.25, 0.1])

    async def main():
        async with engine:
            async def one(i):
                X = Xpool[rng.integers(0, Xpool.shape[0],
                                       size=int(sizes[i]))]
                return await engine.submit(X, "mix", version=1 + i % 2,
                                           strategy="early")
            return await asyncio.gather(
                *[one(i) for i in range(60)], return_exceptions=True)

    outs = asyncio.run(main())
    ok = [o for o in outs if not isinstance(o, BaseException)]
    bad = [o for o in outs if isinstance(o, BaseException)]
    assert all(isinstance(o, (EngineOverloaded, DeadlineExceeded))
               for o in bad), bad
    assert ok, "burst delivered nothing"
    assert serving_cache_size() == mark
    st = engine.stats()
    assert st["compiles_after_warmup"] == 0
    assert st["requests"] == len(ok)


def test_slo_report_schema(registry2):
    """The SLO driver's per-QPS record carries the dashboard keys."""
    from benchmarks.bench_slo import _drive

    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)

    async def main():
        engine = AsyncServingEngine(registry2, EngineConfig(max_batch=64))
        engine.warmup("mix", strategies=["early"])
        async with engine:
            return await _drive(engine, Xpool, qps=500.0, n_requests=12,
                                seed=0)

    rec = asyncio.run(main())
    for key in ("offered_qps", "achieved_rps", "achieved_qps", "requests",
                "queries", "p50_ms", "p95_ms", "p99_ms", "mean_ms"):
        assert key in rec, f"SLO record missing {key}"
    assert rec["requests"] == 12
    assert np.isfinite(rec["p99_ms"]) and rec["p99_ms"] >= rec["p50_ms"] > 0
