"""Async serving engine + versioned registry tests.

Covers the ragged-batch recompile fixes (bucket-derived capacity, ONE
compile across ragged sizes sharing a bucket), queue/bucketing determinism
(async results bit-equal to direct ``serve_batch``), registry
resolve/hot-swap under in-flight requests, manifest round-trips for all
three tasks, and the engine's zero-recompiles-after-warmup invariant under
a Poisson trace with mixed request sizes and two registered versions.
"""
import asyncio

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import DCSVMConfig, Kernel, fit, fit_ova
from repro.core.predict import _early_program, bucket_size
from repro.core.tasks import EpsilonSVR, OneClassSVM
from repro.data import (
    friedman1,
    gaussian_mixture_multiclass,
    gaussian_with_outliers,
    train_test_split,
)
from repro.launch.engine import AsyncServingEngine, EngineConfig
from repro.launch.registry import ModelManifest, ModelRegistry
from repro.launch.serve_svm import (
    export_serving_model,
    run_request_loop,
    serve_batch,
    serving_cache_size,
)

KERN = Kernel("rbf", gamma=16.0)


@pytest.fixture(scope="module")
def ova_models():
    """Two versions of a 3-class OVA model (different C) + a query pool."""
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), 700,
                                       n_classes=3, d=8, spread=0.10)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    cfg1 = DCSVMConfig(kernel=KERN, C=4.0, k=4, levels=1, m=200, tol=1e-3)
    cfg2 = DCSVMConfig(kernel=KERN, C=2.0, k=4, levels=1, m=200, tol=1e-3)
    return fit_ova(cfg1, Xtr, ytr), fit_ova(cfg2, Xtr, ytr), np.asarray(Xte)


@pytest.fixture(scope="module")
def registry2(ova_models):
    m1, m2, _ = ova_models
    reg = ModelRegistry()
    reg.register("mix", m1)
    reg.register("mix", m2)
    return reg


def _mixed_batches(Xpool, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [Xpool[rng.integers(0, Xpool.shape[0], size=s)] for s in sizes]


# ---------------------------------------------------------------------------
# bucket-shape capacity: the ragged-batch recompile fixes
# ---------------------------------------------------------------------------

def test_bucket_size_policy():
    assert [bucket_size(n) for n in (0, 1, 7, 8, 9, 64, 100, 300)] == \
        [8, 8, 8, 8, 16, 64, 128, 512]
    # past hi: multiples of hi, not the next power of two
    assert bucket_size(5000, hi=4096) == 8192
    assert bucket_size(9000, hi=4096) == 12288


def test_one_compile_across_ragged_sizes(ova_models):
    """THE recompile bug: unbucketed, every distinct batch size is a fresh
    ``early_capacity`` static arg and a fresh compile of the early program.
    Bucketed, ragged sizes sharing one bucket share ONE compile."""
    m1, _, Xpool = ova_models
    sm = export_serving_model(m1)
    sizes = [33, 50, 64, 40, 57]                  # all bucket to 64
    batches = _mixed_batches(Xpool, sizes)
    before = _early_program._cache_size()
    for b in batches:
        serve_batch(sm, jnp.asarray(b), KERN, "early", bucket=64)
    assert _early_program._cache_size() - before == 1
    # the unbucketed path compiles per distinct size (the defect this PR
    # fixes in every serving loop; kept for single-shot compatibility).
    # size 64 is excluded: its raw signature equals the warmed bucket's.
    ragged = [b for b in batches if b.shape[0] != 64]
    before = _early_program._cache_size()
    for b in ragged:
        serve_batch(sm, jnp.asarray(b), KERN, "early")
    assert _early_program._cache_size() - before == len(ragged)


@pytest.mark.parametrize("strategy", ["exact", "early", "bcm"])
def test_bucketed_bit_identical_to_unbucketed(ova_models, strategy):
    """Padding rows must not perturb the real rows: bucketed scores are
    bit-identical to the unbucketed ``serve_batch`` on the same rows."""
    m1, _, Xpool = ova_models
    sm = export_serving_model(m1)
    for size in (3, 17, 33):
        Xq = jnp.asarray(_mixed_batches(Xpool, [size], seed=size)[0])
        pred_u, scores_u = serve_batch(sm, Xq, KERN, strategy)
        pred_b, scores_b = serve_batch(sm, Xq, KERN, strategy,
                                       bucket=bucket_size(size))
        np.testing.assert_array_equal(np.asarray(scores_u),
                                      np.asarray(scores_b))
        np.testing.assert_array_equal(np.asarray(pred_u), np.asarray(pred_b))


def test_serve_batch_rejects_undersized_bucket(ova_models):
    m1, _, Xpool = ova_models
    sm = export_serving_model(m1)
    with pytest.raises(ValueError, match="bucket"):
        serve_batch(sm, jnp.asarray(Xpool[:32]), KERN, "early", bucket=16)


def test_request_loop_warms_every_ragged_shape(ova_models):
    """Pre-fix, ``run_request_loop`` warmed only the first batch's shape, so
    ragged streams compiled INSIDE the timed region (corrupting p95/p99).
    Now every distinct bucket signature is warmed first: the report's
    ``compiles_timed`` (jit-cache growth across the timed loop) is zero."""
    m1, _, Xpool = ova_models
    sm = export_serving_model(m1)
    batches = _mixed_batches(Xpool, [5, 12, 33, 64, 9, 50, 2])
    rep = run_request_loop(sm, KERN, "early", batches, warmup=1,
                           bucketed=True)
    assert rep["compiles_timed"] == 0
    assert rep["batch"] == 0 and rep["batches"] == 7
    assert rep["queries"] == 5 + 12 + 33 + 64 + 9 + 50 + 2
    assert rep["lat_ms_p99"] >= rep["lat_ms_p50"] > 0


# ---------------------------------------------------------------------------
# manifests
# ---------------------------------------------------------------------------

def test_manifest_roundtrip_all_tasks():
    """svc / svr / ocsvm (incl. per-cluster rho_c of an early-stopped
    one-class model) manifests all survive the JSON round trip."""
    reg = ModelRegistry()
    kern = Kernel("rbf", gamma=4.0)
    # svc
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(2), 300,
                                       n_classes=3, d=6, spread=0.1)
    cfg = DCSVMConfig(kernel=kern, C=4.0, k=2, levels=1, m=100, tol=1e-2)
    reg.register("svc", fit_ova(cfg, X, y))
    # svr
    Xr, yr = friedman1(jax.random.PRNGKey(3), 300)
    reg.register("svr", fit(cfg, Xr, yr, task=EpsilonSVR(eps=0.2)),
                 with_bcm=False)
    # ocsvm, early-stopped => per-cluster rho_c
    Xo, _ = gaussian_with_outliers(jax.random.PRNGKey(4), 300)
    cfg_o = DCSVMConfig(kernel=kern, C=1.0, k=2, levels=1, m=100, tol=1e-2,
                        early_stop_level=1)
    reg.register("ocsvm", fit(cfg_o, Xo, task=OneClassSVM(nu=0.2)))

    for name, task, n_classes in (("svc", "svc", 3), ("svr", "svr", 0),
                                  ("ocsvm", "ocsvm", 1)):
        man = reg.resolve(name).manifest
        assert man.task == task and man.n_classes == n_classes
        rt = ModelManifest.from_json(man.to_json())
        assert rt == man
        assert rt.make_kernel() == kern
    assert reg.resolve("svr").manifest.eps == pytest.approx(0.2)
    assert reg.resolve("svr").manifest.strategies == ("exact", "early")
    oc = reg.resolve("ocsvm").manifest
    assert oc.nu == pytest.approx(0.2)
    assert len(oc.rho_c) == 2            # k=2 per-cluster offsets survived
    # manifests JSON is what --registry dumps
    j = reg.to_json()
    assert {m["name"] for m in j["models"]} == {"svc", "svr", "ocsvm"}


def test_registry_versioning_and_routing(registry2):
    assert registry2.versions("mix") == [1, 2]
    assert registry2.default_version("mix") == 1        # first stays default
    assert registry2.resolve("mix").version == 1
    assert registry2.resolve("mix", 2).version == 2
    with pytest.raises(KeyError):
        registry2.resolve("mix", 9)
    with pytest.raises(KeyError):
        registry2.resolve("nope")
    with pytest.raises(ValueError, match="default"):
        registry2.drop("mix", 1)                        # routed default
    with pytest.raises(ValueError, match="registered"):
        registry2.register("mix", object(), version=2)  # duplicate version


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

def test_async_bit_equal_to_direct_serve(registry2):
    """Queue/bucketing determinism: whatever the batch manager merges, each
    request's rows come back bit-identical to a direct ``serve_batch`` on
    those rows (per-row scores are independent of batch-mates/padding)."""
    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)   # any pool works
    sizes = [1, 7, 33, 12, 64, 50, 3, 28]
    reqs = _mixed_batches(Xpool, sizes, seed=5)

    async def main():
        engine = AsyncServingEngine(registry2, EngineConfig(max_batch=64))
        engine.warmup("mix", strategies=["early", "exact"])
        async with engine:
            outs = await asyncio.gather(*[
                engine.submit(r, "mix", strategy="early") for r in reqs])
        return outs

    outs = asyncio.run(main())
    entry = registry2.resolve("mix")
    for r, (pred, scores) in zip(reqs, outs):
        dp, ds = serve_batch(entry.sm, jnp.asarray(r), entry.kern, "early",
                             bucket=bucket_size(len(r)))
        np.testing.assert_array_equal(np.asarray(scores), np.asarray(ds))
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(dp))


def test_engine_zero_compiles_after_warmup_poisson(registry2):
    """Acceptance: Poisson arrivals, mixed sizes, BOTH registered versions —
    zero recompiles after warmup, pinned by the compile counter AND the raw
    jit-cache size."""
    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)
    rng = np.random.default_rng(7)
    n_req = 40
    sizes = rng.choice([1, 4, 16, 64], size=n_req, p=[0.35, 0.3, 0.25, 0.1])
    gaps = rng.exponential(1.0 / 2000.0, size=n_req)

    engine = AsyncServingEngine(registry2, EngineConfig(max_batch=64))
    engine.warmup("mix", strategies=["early"])
    cache_after_warmup = serving_cache_size()

    async def main():
        async with engine:
            async def one(i):
                await asyncio.sleep(float(np.sum(gaps[: i + 1])))
                X = Xpool[rng.integers(0, Xpool.shape[0], size=int(sizes[i]))]
                return await engine.submit(X, "mix", version=1 + i % 2,
                                           strategy="early")
            await asyncio.gather(*[one(i) for i in range(n_req)])

    asyncio.run(main())
    assert serving_cache_size() == cache_after_warmup
    st = engine.stats()
    assert st["compiles_after_warmup"] == 0
    assert st["requests"] == n_req and st["queries"] == int(sizes.sum())
    # engine metrics made it through: per-version latency histograms,
    # fill-ratio histogram, queue-depth gauge
    j = engine.metrics.to_json()
    assert any('version="1"' in k for k in j["histograms"])
    assert any('version="2"' in k for k in j["histograms"])
    assert any(k.startswith("serve_batch_fill_ratio")
               for k in j["histograms"])
    assert j["gauges"]["serve_queue_depth"] == 0


def test_hot_swap_under_inflight_requests(ova_models):
    """Swap repoints NEW submits atomically; requests already queued on the
    old version drain on it, then the old version is dropped."""
    m1, m2, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1)
    reg.register("m", m2)
    results = {}

    async def main():
        engine = AsyncServingEngine(reg, EngineConfig(max_batch=32))
        engine.warmup("m", strategies=["early"])
        async with engine:
            pre = [asyncio.ensure_future(
                engine.submit(Xpool[i * 8:(i + 1) * 8], "m",
                              strategy="early")) for i in range(4)]
            # let the submit coroutines run to their enqueue point so they
            # resolve v1 (the route table as of NOW) before the swap lands
            await asyncio.sleep(0)
            old = await engine.swap("m", 2)
            assert old == 1
            post = await engine.submit(Xpool[:8], "m", strategy="early")
            results["pre"] = [await f for f in pre]
            results["post"] = post
        assert reg.versions("m") == [2]       # drained, then dropped
        assert reg.default_version("m") == 2

    asyncio.run(main())
    # pre-swap requests were served by v1, post-swap by v2 — each matches a
    # direct serve against the respective model
    sm2 = reg.resolve("m", 2).sm
    sm1 = export_serving_model(m1)
    for i, (pred, scores) in enumerate(results["pre"]):
        _, ref = serve_batch(sm1, jnp.asarray(Xpool[i * 8:(i + 1) * 8]),
                             KERN, "early", bucket=bucket_size(8))
        np.testing.assert_array_equal(np.asarray(scores), np.asarray(ref))
    _, ref2 = serve_batch(sm2, jnp.asarray(Xpool[:8]), KERN, "early",
                          bucket=bucket_size(8))
    np.testing.assert_array_equal(np.asarray(results["post"][1]),
                                  np.asarray(ref2))


def test_engine_rejects_unserveable_strategy(ova_models):
    """A with_bcm=False export's manifest caps the strategy set; the engine
    refuses at submit instead of crashing inside the batch loop."""
    m1, _, Xpool = ova_models
    reg = ModelRegistry()
    reg.register("m", m1, with_bcm=False)

    async def main():
        async with AsyncServingEngine(reg) as engine:
            with pytest.raises(ValueError, match="does not serve"):
                await engine.submit(Xpool[:4], "m", strategy="bcm")

    asyncio.run(main())


def test_engine_submit_requires_running_loop(registry2):
    engine = AsyncServingEngine(registry2)
    with pytest.raises(RuntimeError, match="not running"):
        asyncio.run(engine.submit(np.zeros((2, 8), np.float32), "mix"))


def test_slo_report_schema(registry2):
    """The SLO driver's per-QPS record carries the dashboard keys."""
    from benchmarks.bench_slo import _drive

    Xpool = np.asarray(registry2.resolve("mix").sm.Xall)

    async def main():
        engine = AsyncServingEngine(registry2, EngineConfig(max_batch=64))
        engine.warmup("mix", strategies=["early"])
        async with engine:
            return await _drive(engine, Xpool, qps=500.0, n_requests=12,
                                seed=0)

    rec = asyncio.run(main())
    for key in ("offered_qps", "achieved_rps", "achieved_qps", "requests",
                "queries", "p50_ms", "p95_ms", "p99_ms", "mean_ms"):
        assert key in rec, f"SLO record missing {key}"
    assert rec["requests"] == 12
    assert np.isfinite(rec["p99_ms"]) and rec["p99_ms"] >= rec["p50_ms"] > 0
