"""End-to-end Pallas/XLA parity for the streaming conquer engine.

Covers the ISSUE-1 acceptance criteria: ``solve_box_qp_matvec`` with
``use_pallas=True`` (fused cd_column_update + kernel_matvec) and with the
device-resident column cache must match the XLA reference path to 1e-5,
and the serving paths (decision_exact / decision_early) must agree across
backends.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig,
    Kernel,
    colcache,
    fit,
    gram_matvec,
    objective_value,
    solve_box_qp_matvec,
    solve_with_shrinking,
)
from repro.core.predict import decision_early, decision_exact
from repro.data import gaussian_mixture, train_test_split

KERNELS = [
    Kernel("rbf", gamma=4.0),
    Kernel("poly", gamma=1.0, degree=3, coef0=1.0),
    Kernel("linear"),
]


def _problem(n=160, d=7, key=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    # centered data keeps the poly/linear Grams well-conditioned
    X = (jax.random.uniform(k1, (n, d)) - 0.5) * 2.0
    y = jnp.sign(jax.random.normal(k2, (n,)))
    return X, y


# (n, d) per kernel sized so the Gram is generically full-rank and the dual
# optimum unique — otherwise both backends converge to *different* optima of
# a singular QP and alpha-level parity is meaningless (poly rank is
# C(d+deg, deg), linear rank is d)
PARITY_SHAPES = {"rbf": (160, 7), "poly": (64, 7), "linear": (32, 40)}


@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
def test_matvec_solver_pallas_parity(kern):
    """use_pallas=True vs False: alphas within 1e-5 (acceptance criterion)."""
    n, d = PARITY_SHAPES[kern.kind]
    X, y = _problem(n=n, d=d)
    C = 2.0
    r_x = solve_box_qp_matvec(X, y, kern, C, tol=1e-6, max_iters=4000, block=16)
    r_p = solve_box_qp_matvec(X, y, kern, C, tol=1e-6, max_iters=4000, block=16,
                              use_pallas=True)
    np.testing.assert_allclose(np.asarray(r_p.alpha), np.asarray(r_x.alpha),
                               atol=1e-5)
    assert float(r_p.pg_max) <= 1e-6 * 1.5


@pytest.mark.parametrize("use_pallas", [False, True])
def test_matvec_solver_cache_parity(use_pallas):
    """Column cache on/off must not change the solution; counters must add up."""
    X, y = _problem(key=3)
    C = 2.0
    base = solve_box_qp_matvec(X, y, kern := Kernel("rbf", gamma=4.0), C,
                               tol=1e-6, max_iters=4000, block=16)
    res = solve_box_qp_matvec(X, y, kern, C, tol=1e-6, max_iters=4000, block=16,
                              use_pallas=use_pallas, cache_cap=X.shape[0])
    np.testing.assert_allclose(np.asarray(res.alpha), np.asarray(base.alpha),
                               atol=1e-5)
    hits, misses = int(res.cache_hits), int(res.cache_misses)
    assert hits + misses == int(res.iters) * 16
    # cap = n: once the active set is resident the solver must start hitting
    assert hits > 0


def test_matvec_solver_warm_start_pallas():
    """Warm-started fused path converges immediately at the optimum."""
    X, y = _problem(key=5)
    kern = Kernel("rbf", gamma=4.0)
    C = 1.0
    ref = solve_box_qp_matvec(X, y, kern, C, tol=1e-6, max_iters=4000, block=16)
    warm = solve_box_qp_matvec(X, y, kern, C, alpha0=ref.alpha, tol=1e-5,
                               max_iters=4000, block=16, use_pallas=True)
    assert int(warm.iters) == 0
    np.testing.assert_allclose(np.asarray(warm.alpha), np.asarray(ref.alpha))


def test_gram_matvec_pallas_parity():
    X, _ = _problem(n=130, d=9, key=7)
    v = jax.random.normal(jax.random.PRNGKey(8), (130,))
    for kern in KERNELS:
        a = gram_matvec(kern, X, v, num_chunks=4)
        b = gram_matvec(kern, X, v, use_pallas=True)
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-5, atol=2e-5)


def test_objective_value_pallas_parity():
    X, y = _problem(n=120, key=9)
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(10), (120,))) * 0.1
    cfg_x = DCSVMConfig(kernel=Kernel("rbf", gamma=4.0), C=2.0, use_pallas=False)
    cfg_p = dataclasses.replace(cfg_x, use_pallas=True)
    fx = float(objective_value(cfg_x, X, y, a))
    fp = float(objective_value(cfg_p, X, y, a))
    assert abs(fx - fp) < 1e-4 * (1 + abs(fx))


def test_colcache_lru_semantics():
    """Unit-level: insert fills LRU slots, touch refreshes, eviction unmaps."""
    cache = colcache.init(cap=4, n=10)
    idx = jnp.array([1, 2])
    slots, hit = colcache.lookup(cache, idx)
    assert not bool(jnp.any(hit))
    rows = jnp.arange(20, dtype=jnp.float32).reshape(2, 10)
    cache = colcache.update(cache, idx, rows, jnp.asarray(False), slots, hit)
    assert int(cache.misses) == 2 and int(cache.hits) == 0

    # both rows now resident, served block counts as hits and touches stamps
    slots, hit = colcache.lookup(cache, idx)
    assert bool(jnp.all(hit))
    served_rows = cache.cols[slots]
    np.testing.assert_array_equal(np.asarray(served_rows), np.asarray(rows))
    cache = colcache.update(cache, idx, served_rows, jnp.asarray(True), slots, hit)
    assert int(cache.hits) == 2

    # insert 2+2 more rows: cap=4 forces eviction of the original two
    for a, b in ((3, 4), (5, 6)):
        idx2 = jnp.array([a, b])
        slots2, hit2 = colcache.lookup(cache, idx2)
        cache = colcache.update(cache, idx2, rows, jnp.asarray(False), slots2, hit2)
    _, hit = colcache.lookup(cache, jnp.array([1, 2]))
    assert not bool(jnp.any(hit)), "LRU rows must be evicted and unmapped"
    _, hit2 = colcache.lookup(cache, jnp.array([3, 4, 5, 6]))
    assert bool(jnp.all(hit2))


def test_fit_backend_parity_and_cache_stats():
    """fit() through the matvec conquer path: XLA vs Pallas backends agree and
    the level-0 stats surface cache hit counters."""
    X, y = gaussian_mixture(jax.random.PRNGKey(0), 700, d=8, modes_per_class=4,
                            spread=0.15)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    kern = Kernel("rbf", gamma=8.0)
    cfg_x = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=1, m=200, tol=1e-4,
                        use_pallas=False, full_gram_threshold=64, block=32,
                        col_cache_cap=512)
    cfg_p = dataclasses.replace(cfg_x, use_pallas=True)
    m_x = fit(cfg_x, Xtr, ytr)
    m_p = fit(cfg_p, Xtr, ytr)
    st = m_x.level_stats[-1]
    assert {"cache_hits", "cache_misses", "cache_hit_rate"} <= set(st)
    assert 0.0 <= st["cache_hit_rate"] <= 1.0
    # same conquer trajectory to CD tolerance on both backends
    assert float(jnp.max(jnp.abs(m_x.alpha - m_p.alpha))) < 5e-4

    d_x = decision_exact(m_x, Xte, use_pallas=False)
    d_p = decision_exact(m_x, Xte, use_pallas=True)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_x),
                               rtol=1e-4, atol=1e-4)


def test_decision_early_pallas_parity():
    X, y = gaussian_mixture(jax.random.PRNGKey(2), 600, d=8, modes_per_class=4,
                            spread=0.15)
    Xtr, ytr, Xte, _ = train_test_split(jax.random.PRNGKey(3), X, y)
    cfg = DCSVMConfig(kernel=Kernel("rbf", gamma=8.0), C=4.0, k=4, levels=1,
                      m=200, tol=1e-3, early_stop_level=1, use_pallas=False)
    model = fit(cfg, Xtr, ytr)
    d_x = decision_early(model, Xte, use_pallas=False)
    d_p = decision_early(model, Xte, use_pallas=True)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_x),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ISSUE-3: signed-weight parity on the generalized (task) dual.  The SVR dual
# runs the SAME fused kernels with a mixed-sign s vector (+1/-1 mirrored
# coordinate pairs) over duplicated, non-tile-aligned rows — pin Pallas/XLA
# parity there too.
# ---------------------------------------------------------------------------

def _svr_problem(n=75, d=5, key=21, eps=0.05, C=2.0):
    from repro.core.tasks import EpsilonSVR

    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    X = (jax.random.uniform(k1, (n, d)) - 0.5) * 2.0
    y = jnp.sum(jnp.sin(2.0 * X), axis=-1) / d \
        + 0.02 * jax.random.normal(k2, (n,))
    task = EpsilonSVR(eps=eps)
    td = task.build(X, y[None, :], C)
    return td


@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
def test_cd_column_update_signed_weights_parity(kern):
    """Fused cd_column_update with a mixed-sign s vector (the SVR case) on
    non-tile-aligned shapes: Pallas == XLA reference to 1e-5."""
    from repro.kernels import ops as kops

    td = _svr_problem(n=83, d=7)           # nd = 166: not a multiple of 8/128
    s = td.S[0]
    idx = jnp.asarray([3, 82, 83, 165, 40, 123, 7])   # mirrored pairs included
    Xb, sb = td.Xd[idx], s[idx]
    delta = jax.random.normal(jax.random.PRNGKey(5), (idx.shape[0],)) * 0.1
    got = kops.cd_column_update(td.Xd, s, Xb, sb * delta, kern)
    Kb = kern.pairwise(td.Xd, Xb)
    want = s * (Kb @ (sb * delta))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
def test_matvec_solver_svr_pallas_parity(kern):
    """solve_box_qp_matvec on the 2n SVR dual (signed weights through the
    fused kernels, per-coordinate p): XLA and Pallas agree on the collapsed
    beta to 1e-5 (beta — not the raw 2n dual — is the well-posed quantity:
    Q is rank-deficient by construction on duplicated rows)."""
    td = _svr_problem(n=60, d=5)
    s, p, cvec = td.S[0], td.P[0], td.Cvec[0]
    r_x = solve_box_qp_matvec(td.Xd, s, kern, cvec, tol=1e-6, max_iters=4000,
                              block=16, p=p)
    r_p = solve_box_qp_matvec(td.Xd, s, kern, cvec, tol=1e-6, max_iters=4000,
                              block=16, p=p, use_pallas=True)
    assert float(r_p.pg_max) <= 1e-6 * 1.5
    beta_x = np.asarray(td.collapse(r_x.alpha[None, :])[0])
    beta_p = np.asarray(td.collapse(r_p.alpha[None, :])[0])
    np.testing.assert_allclose(beta_p, beta_x, atol=1e-5)


def test_svr_fit_backend_parity():
    """End-to-end epsilon-SVR fit through the divide/conquer driver: XLA and
    Pallas backends produce the same decision function."""
    from repro.core.tasks import EpsilonSVR
    from repro.data import friedman1

    X, y = friedman1(jax.random.PRNGKey(4), 300)
    kern = Kernel("rbf", gamma=1.0)
    cfg_x = DCSVMConfig(kernel=kern, C=4.0, k=3, levels=1, m=150, tol=1e-4,
                        kmeans_iters=10, use_pallas=False,
                        full_gram_threshold=64, block=32)
    cfg_p = dataclasses.replace(cfg_x, use_pallas=True)
    task = EpsilonSVR(eps=0.1)
    m_x = fit(cfg_x, X, y, task=task)
    m_p = fit(cfg_p, X, y, task=task)
    d_x = decision_exact(m_x, X[:64], use_pallas=False)
    d_p = decision_exact(m_p, X[:64], use_pallas=True)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_x),
                               rtol=1e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# ISSUE-4: pairwise (equality-constrained) CD parity.  The pairwise engine
# runs the SAME fused kernels (cd_column_update rank-2 updates, streaming
# kernel_matvec gradient init) with mixed-sign equality coefficients a over
# non-tile-aligned shapes — pin Pallas/XLA parity and the on-device property.
# ---------------------------------------------------------------------------

def _eq_problem(kern, key=31):
    """Non-tile-aligned n per kernel kind (full-rank Grams => the strictly
    convex equality QP has a unique optimum, so alpha parity is well
    posed), mixed-sign a bounded away from zero, interior target d."""
    shapes = {"rbf": (83, 7), "poly": (61, 7), "linear": (37, 40)}
    n, d_feat = shapes[kern.kind]
    rng = np.random.default_rng(key)
    X = jnp.asarray(((rng.uniform(size=(n, d_feat)) - 0.5) * 2.0)
                    .astype(np.float32))
    y = jnp.asarray(np.where(rng.uniform(size=n) > 0.5, 1.0, -1.0)
                    .astype(np.float32))
    a = jnp.asarray((np.where(rng.uniform(size=n) > 0.5, 1.0, -1.0)
                     * rng.uniform(0.5, 1.5, size=n)).astype(np.float32))
    ac = np.asarray(a, np.float64)
    lo, hi = np.minimum(ac, 0).sum(), np.maximum(ac, 0).sum()
    d = float(lo + 0.4 * (hi - lo))
    return X, y, a, d


@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
def test_eq_pairwise_cd_pallas_parity(kern):
    """solve_eq_qp_matvec with mixed-sign a on non-tile-aligned shapes:
    use_pallas=True (fused rank-2 cd_column_update + streaming matvec init)
    must match the XLA reference path to 1e-5, stay box- and equality-
    feasible, and reach the same stopping residual.  tol is scale-aware:
    the poly kernel's values reach (1 + d)^3 here, so 1e-6 sits below the
    f32 resolution of the multiplier bracket."""
    from repro.core import solve_eq_qp_matvec

    tol = {"rbf": 1e-6, "poly": 1e-5, "linear": 1e-6}[kern.kind]
    X, y, a, d = _eq_problem(kern)
    r_x = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, tol=tol,
                             max_iters=100_000)
    r_p = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, tol=tol,
                             max_iters=100_000, use_pallas=True)
    np.testing.assert_allclose(np.asarray(r_p.alpha), np.asarray(r_x.alpha),
                               atol=1e-5)
    for res in (r_x, r_p):
        u = np.asarray(res.alpha, np.float64)
        an = np.asarray(a, np.float64)
        assert int(res.iters) < 100_000
        assert u.min() >= -1e-7 and u.max() <= 1.0 + 1e-6
        scale = np.abs(an * u).sum() + abs(d)
        assert abs(an @ u - d) <= 4e-6 * max(scale, 1.0)
        assert float(res.pg_max) <= tol * 1.5


def test_eq_pairwise_warm_start_pallas():
    """Warm-started fused pairwise path converges immediately at the
    optimum (the feasible-projection entry step must not perturb it)."""
    from repro.core import solve_eq_qp_matvec

    kern = Kernel("rbf", gamma=2.0)
    X, y, a, d = _eq_problem(kern, key=33)
    ref = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, tol=1e-5,
                             max_iters=200_000)
    warm = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, alpha0=ref.alpha,
                              tol=1e-4, max_iters=200_000, use_pallas=True)
    assert int(warm.iters) <= 2
    np.testing.assert_allclose(np.asarray(warm.alpha), np.asarray(ref.alpha),
                               atol=1e-5)


def test_eq_solve_loop_stays_on_device():
    """Satellite: the whole pairwise solve (projection, selection, rank-2
    updates, feasibility restore) is ONE jitted program — no device-to-host
    transfer once compiled."""
    from repro.core import solve_eq_qp_matvec

    kern = Kernel("rbf", gamma=2.0)
    X, y, a, d = _eq_problem(kern, key=35)
    args = (X, y, kern, 1.0, a, d)
    kw = dict(tol=1e-5, max_iters=50_000, use_pallas=True)
    warm = solve_eq_qp_matvec(*args, **kw)       # compile outside the guard
    with jax.transfer_guard_device_to_host("disallow"):
        res = solve_eq_qp_matvec(*args, **kw)
    np.testing.assert_allclose(np.asarray(res.alpha), np.asarray(warm.alpha))


def test_oneclass_fit_backend_parity():
    """End-to-end one-class fit through the divide/conquer driver: XLA and
    Pallas backends produce the same decision function and offset."""
    from repro.core import OneClassSVM
    from repro.core.predict import decision_exact
    from repro.data import gaussian_with_outliers

    X, _ = gaussian_with_outliers(jax.random.PRNGKey(6), 700)
    kern = Kernel("rbf", gamma=4.0)
    cfg_x = DCSVMConfig(kernel=kern, k=3, levels=1, m=250, tol=1e-4,
                        kmeans_iters=8, use_pallas=False,
                        full_gram_threshold=64)
    cfg_p = dataclasses.replace(cfg_x, use_pallas=True)
    task = OneClassSVM(nu=0.1)
    m_x = fit(cfg_x, X, task=task)
    m_p = fit(cfg_p, X, task=task)
    assert abs(m_x.rho - m_p.rho) < 1e-3 * (1 + abs(m_x.rho))
    d_x = decision_exact(m_x, X[:64], use_pallas=False)
    d_p = decision_exact(m_p, X[:64], use_pallas=True)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_x),
                               rtol=1e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# ISSUE-5: rank-2B blocked pairwise CD parity.  The blocked engine routes its
# gradient update through the SAME fused cd_column_update kernel with a
# (2B,) delta instead of a rank-2 one — pin Pallas/XLA parity on mixed-sign
# non-tile-aligned shapes, warm starts, and the on-device property.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
def test_eq_block_cd_pallas_parity(kern):
    """solve_eq_qp_matvec with block=8 (fused rank-2B cd_column_update +
    streaming matvec init) must match the XLA reference blocked path to
    1e-5 on mixed-sign non-tile-aligned shapes, stay box- and equality-
    feasible, and reach the same stopping residual.  tol is scale-aware:
    poly/linear kernel values reach ~(1+d)^3 / ~d here, so the f32 noise
    of measuring the multiplier gap itself sits above 1e-6."""
    from repro.core import solve_eq_qp_matvec

    tol = {"rbf": 1e-6, "poly": 1e-5, "linear": 1e-5}[kern.kind]
    X, y, a, d = _eq_problem(kern)
    r_x = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, tol=tol,
                             max_iters=50_000, block=8)
    r_p = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, tol=tol,
                             max_iters=50_000, block=8, use_pallas=True)
    np.testing.assert_allclose(np.asarray(r_p.alpha), np.asarray(r_x.alpha),
                               atol=1e-5)
    an = np.asarray(a, np.float64)
    for res in (r_x, r_p):
        u = np.asarray(res.alpha, np.float64)
        assert int(res.iters) < 50_000
        assert u.min() >= -1e-7 and u.max() <= 1.0 + 1e-6
        scale = np.abs(an * u).sum() + abs(d)
        assert abs(an @ u - d) <= 4e-6 * max(scale, 1.0)
        assert float(res.pg_max) <= tol * 1.5


def test_eq_block_matches_rank2_across_backends():
    """The blocked engine and the rank-2 engine land on the same optimum of
    the strictly convex equality QP, on both backends."""
    from repro.core import solve_eq_qp_matvec

    kern = Kernel("rbf", gamma=2.0)
    X, y, a, d = _eq_problem(kern, key=37)
    ref = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, tol=1e-6,
                             max_iters=200_000)
    for up in (False, True):
        blk = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, tol=1e-6,
                                 max_iters=50_000, block=8, use_pallas=up)
        np.testing.assert_allclose(np.asarray(blk.alpha),
                                   np.asarray(ref.alpha), atol=2e-5)


def test_eq_block_warm_start_pallas():
    """Warm-started fused rank-2B path converges immediately at the optimum
    (the grouped feasible-projection entry step must not perturb it)."""
    from repro.core import solve_eq_qp_matvec

    kern = Kernel("rbf", gamma=2.0)
    X, y, a, d = _eq_problem(kern, key=39)
    ref = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, tol=1e-5,
                             max_iters=50_000, block=8)
    warm = solve_eq_qp_matvec(X, y, kern, 1.0, a, d, alpha0=ref.alpha,
                              tol=1e-4, max_iters=50_000, block=8,
                              use_pallas=True)
    assert int(warm.iters) <= 2
    np.testing.assert_allclose(np.asarray(warm.alpha), np.asarray(ref.alpha),
                               atol=1e-5)


def test_eq_block_solve_loop_stays_on_device():
    """The whole blocked solve (grouped projection, top-k pair selection,
    2Bx2B sub-QP, rank-2B updates, feasibility restore) is ONE jitted
    program — no device-to-host transfer once compiled."""
    from repro.core import solve_eq_qp_matvec

    kern = Kernel("rbf", gamma=2.0)
    X, y, a, d = _eq_problem(kern, key=41)
    args = (X, y, kern, 1.0, a, d)
    kw = dict(tol=1e-5, max_iters=50_000, block=8, use_pallas=True)
    warm = solve_eq_qp_matvec(*args, **kw)       # compile outside the guard
    with jax.transfer_guard_device_to_host("disallow"):
        res = solve_eq_qp_matvec(*args, **kw)
    np.testing.assert_allclose(np.asarray(res.alpha), np.asarray(warm.alpha))


def test_oneclass_blocked_fit_backend_parity():
    """End-to-end one-class fit with eq_block_size=8 through the divide/
    conquer driver: XLA and Pallas backends agree, and the blocked fit
    matches the rank-2 fit's decision function."""
    from repro.core import OneClassSVM
    from repro.data import gaussian_with_outliers

    X, _ = gaussian_with_outliers(jax.random.PRNGKey(8), 700)
    kern = Kernel("rbf", gamma=4.0)
    cfg_x = DCSVMConfig(kernel=kern, k=3, levels=1, m=250, tol=1e-4,
                        kmeans_iters=8, use_pallas=False,
                        full_gram_threshold=64, eq_block_size=8)
    cfg_p = dataclasses.replace(cfg_x, use_pallas=True)
    cfg_r2 = dataclasses.replace(cfg_x, eq_block_size=1)
    task = OneClassSVM(nu=0.1)
    m_x = fit(cfg_x, X, task=task)
    m_p = fit(cfg_p, X, task=task)
    m_r2 = fit(cfg_r2, X, task=task)
    assert abs(m_x.rho - m_p.rho) < 1e-3 * (1 + abs(m_x.rho))
    assert abs(m_x.rho - m_r2.rho) < 1e-3 * (1 + abs(m_x.rho))
    d_x = decision_exact(m_x, X[:64], use_pallas=False)
    d_p = decision_exact(m_p, X[:64], use_pallas=True)
    d_r = decision_exact(m_r2, X[:64], use_pallas=False)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_x),
                               rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(d_r), np.asarray(d_x),
                               rtol=1e-3, atol=5e-3)


def test_shrinking_iters_accumulate_on_device():
    """Satellite: solve_with_shrinking returns a device scalar equal to the
    sum of per-round iteration counts (no per-round host sync)."""
    X, y = _problem(n=100, key=13)
    K = Kernel("rbf", gamma=4.0).pairwise(X, X) + 1e-3 * jnp.eye(100)
    Q = (y[:, None] * y[None, :]) * K
    res = solve_with_shrinking(Q, 2.0, tol=1e-4, max_iters=50_000, rounds=3)
    assert isinstance(res.iters, jax.Array)
    assert res.iters.dtype == jnp.int32
    assert int(res.iters) > 0
