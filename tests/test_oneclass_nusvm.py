"""Equality-constrained tasks end-to-end: one-class SVM and nu-SVC (ISSUE-4).

(a) nu/C equivalence regression: a C-SVC solve implies nu = sum(alpha)/(C n);
    the bias-free NuSVC at that nu must reproduce the decision function up
    to the positive scale C (KKT mapping beta = alpha / C);
(b) one-class SVM vs sklearn/libsvm: identical parameterization
    (0 <= alpha <= 1, sum alpha = nu n), so decision functions are directly
    comparable on gaussian_with_outliers;
(c) acceptance criterion: multilevel one-class DC-SVM matches a dense
    reference equality-constrained solve to 1e-4 in decision values;
(d) the nu property (outlier fraction <= nu <= SV fraction), rho recovery,
    per-cluster rho for early prediction, and the ocsvm serving export.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import (
    DCSVMConfig,
    Kernel,
    NuSVC,
    OneClassSVM,
    accuracy,
    f1,
    fit,
    kkt_residual_eq,
    predict_early,
    predict_exact,
    recall,
    solve_box_qp,
    solve_eq_qp,
)
from repro.core.predict import decision_early, decision_exact
from repro.core.solver import equality_rho
from repro.data import gaussian_mixture, gaussian_with_outliers, \
    train_test_split


def _ocsvm_problem(n=500, key=0, spread=0.07, outlier_frac=0.06):
    X, y = gaussian_with_outliers(jax.random.PRNGKey(key), n, spread=spread,
                                  outlier_frac=outlier_frac)
    return X, y


# ---------------------------------------------------------------------------
# (a) nu/C equivalence
# ---------------------------------------------------------------------------

def test_nu_c_equivalence_decision_functions():
    """Fit C-SVC at cost C, read off nu = sum(alpha)/(C n), fit NuSVC at
    that nu: the decision functions must match to 1e-4 on held-out points
    after removing the positive scale C (beta = alpha / C maps one KKT
    system onto the other)."""
    X, y = gaussian_mixture(jax.random.PRNGKey(0), 400, d=6,
                            modes_per_class=3, spread=0.15)
    Xtr, ytr, Xte, _ = train_test_split(jax.random.PRNGKey(1), X, y)
    n = Xtr.shape[0]
    kern = Kernel("rbf", gamma=4.0)
    C = 2.0
    cfg = DCSVMConfig(kernel=kern, C=C, k=3, levels=1, m=200, tol=1e-7,
                      kmeans_iters=8, use_pallas=False)
    m_c = fit(cfg, Xtr, ytr)
    nu = float(m_c.alpha.sum()) / (C * n)
    assert 0.0 < nu < 1.0
    m_nu = fit(cfg, Xtr, ytr, task=NuSVC(nu=nu))
    # the mass constraint holds exactly
    assert abs(float(m_nu.alpha.sum()) - nu * n) <= 1e-3
    f_c = np.asarray(decision_exact(m_c, Xte), np.float64)
    f_nu = np.asarray(decision_exact(m_nu, Xte), np.float64)
    np.testing.assert_allclose(C * f_nu, f_c, atol=1e-4)


def test_nusvc_fit_accuracy_and_mass():
    """NuSVC through the multilevel driver: accurate on the mixture and the
    dual mass lands exactly on nu * n (the equality the box dual cannot
    express)."""
    X, y = gaussian_mixture(jax.random.PRNGKey(2), 900, d=8,
                            modes_per_class=4, spread=0.12)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(3), X, y)
    kern = Kernel("rbf", gamma=8.0)
    cfg = DCSVMConfig(kernel=kern, k=3, levels=2, m=300, tol=1e-5,
                      kmeans_iters=8, use_pallas=False)
    nu = 0.3
    model = fit(cfg, Xtr, ytr, task=NuSVC(nu=nu))
    n = Xtr.shape[0]
    assert abs(float(model.alpha.sum()) - nu * n) <= 1e-2
    assert accuracy(yte, predict_exact(model, Xte)) >= 0.95
    # nu bounds the support mass: at least nu*n coordinates-worth of mass,
    # each coordinate capped at 1 => at least nu*n support vectors
    assert len(model.sv_index) >= nu * n - 1


def test_nusvc_rejects_bad_nu():
    X = jnp.zeros((4, 2))
    y = jnp.asarray([1.0, -1.0, 1.0, -1.0])
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError):
            NuSVC(nu=bad).build(X, y[None, :], 1.0)
    with pytest.raises(ValueError):
        OneClassSVM(nu=0.0).build(X, y[None, :], 1.0)
    # with bias: class-balance feasibility nu <= 2 min(n+, n-)/n
    y_imb = jnp.asarray([1.0, 1.0, 1.0, -1.0])
    with pytest.raises(ValueError):
        NuSVC(nu=0.9, with_bias=True).build(X, y_imb[None, :], 1.0)
    td = NuSVC(nu=0.4, with_bias=True).build(X, y_imb[None, :], 1.0)
    assert td.n_groups == 2 and td.Geq is not None


# ---------------------------------------------------------------------------
# (a') two-constraint nu-SVC: the bias restored via per-label-group
# constraints sum_{y=+1} u = sum_{y=-1} u = nu n / 2 (ISSUE-5)
# ---------------------------------------------------------------------------

def _nusvc_problem(n=400, key=0, d=6):
    X, y = gaussian_mixture(jax.random.PRNGKey(key), n, d=d,
                            modes_per_class=3, spread=0.15)
    return train_test_split(jax.random.PRNGKey(key + 1), X, y)


def _nusvc_margin_and_bias(model, kern):
    """(rho_m, b) of a fitted two-constraint NuSVC from the per-group
    multiplier brackets at the returned dual: r_+/- are the free-SV levels
    of g_i per class group, rho_m = (r_+ + r_-)/2, b = (r_- - r_+)/2."""
    from repro.core.solver import equality_rho_grouped
    from repro.core.kernels import gram_matvec

    td = model.task.build(model.X, model.y[None, :], model.config.C)
    s = td.S[0]
    g = s * gram_matvec(kern, model.X, s * model.alpha) + td.P[0]
    r = equality_rho_grouped(model.alpha, g, td.Cvec[0], td.A[0],
                             td.group_ids[0], 2)
    return 0.5 * float(r[0] + r[1]), 0.5 * float(r[1] - r[0])


def test_nusvc_bias_decision_matches_sklearn():
    """Decision parity vs sklearn.svm.NuSVC (rbf): libsvm rescales the
    dual by the margin rho_m so free SVs sit at +/-1 — dividing our raw
    decision (sum u_i y_i K + b) by rho_m must reproduce sklearn's
    decision_function to 2e-4, and b/rho_m its intercept."""
    sklearn_svm = pytest.importorskip("sklearn.svm")

    Xtr, ytr, Xte, _ = _nusvc_problem(n=400, key=0)
    gamma, nu = 4.0, 0.3
    kern = Kernel("rbf", gamma=gamma)
    cfg = DCSVMConfig(kernel=kern, k=3, levels=1, m=200, tol=1e-7,
                      kmeans_iters=8, use_pallas=False)
    model = fit(cfg, Xtr, ytr, task=NuSVC(nu=nu, with_bias=True))
    rho_m, b = _nusvc_margin_and_bias(model, kern)
    assert rho_m > 0
    # model.rho is -b: the uniform offset convention f = sum beta K - rho
    assert abs(model.rho + b) <= 1e-5 * (1 + abs(b))
    f_raw = np.asarray(decision_exact(model, Xte), np.float64)  # already + b
    f_ours = f_raw / rho_m

    sk = sklearn_svm.NuSVC(nu=nu, kernel="rbf", gamma=gamma,
                           tol=1e-8).fit(np.asarray(Xtr), np.asarray(ytr))
    f_sk = sk.decision_function(np.asarray(Xte))
    np.testing.assert_allclose(f_ours, f_sk, atol=2e-4)
    assert abs(b / rho_m - float(sk.intercept_[0])) <= 2e-4


def test_nusvc_bias_group_feasibility_sandwich():
    """Per class group g: the group mass lands exactly on nu n / 2, and the
    nu sandwich holds groupwise — #(bound SVs in g) <= nu n / 2 <= #(SVs
    in g) (each coordinate is capped at 1, so the mass constraint forces
    at least nu n/2 supports and at most nu n/2 cap-pinned coordinates)."""
    Xtr, ytr, Xte, yte = _nusvc_problem(n=600, key=4, d=8)
    n = Xtr.shape[0]
    nu = 0.3
    cfg = DCSVMConfig(kernel=Kernel("rbf", gamma=8.0), k=3, levels=2, m=250,
                      tol=1e-5, kmeans_iters=8, use_pallas=False,
                      eq_block_size=8)
    model = fit(cfg, Xtr, ytr, task=NuSVC(nu=nu, with_bias=True))
    u = np.asarray(model.alpha, np.float64)
    yn = np.asarray(model.y)
    for sign in (1.0, -1.0):
        grp = yn * sign > 0
        mass = u[grp].sum()
        assert abs(mass - nu * n / 2) <= 1e-2, (sign, mass)
        n_sv = int((u[grp] > 1e-6).sum())
        n_bound = int((u[grp] >= 1.0 - 1e-6).sum())
        assert n_bound <= nu * n / 2 + 1, sign
        assert n_sv >= nu * n / 2 - 1, sign
    assert accuracy(yte, predict_exact(model, Xte)) >= 0.9


def test_nusvc_bias_serving_round_trip():
    """export_serving_model/serve_batch with the recovered bias: the export
    carries rho = -b through the offset-threshold path (shared with
    one-class), exact serving reproduces decision_exact, predictions are
    the +/-1 sign labels, and the early export carries per-cluster
    offsets."""
    from repro.launch.serve_svm import export_serving_model, serve_batch

    Xtr, ytr, Xte, _ = _nusvc_problem(n=500, key=8)
    kern = Kernel("rbf", gamma=4.0)
    cfg = DCSVMConfig(kernel=kern, k=3, levels=1, m=200, tol=1e-5,
                      kmeans_iters=8, use_pallas=False, eq_block_size=4)
    task = NuSVC(nu=0.3, with_bias=True)
    model = fit(cfg, Xtr, ytr, task=task)
    assert model.rho is not None
    sm = export_serving_model(model, with_bcm=False)
    assert float(sm.rho) == pytest.approx(model.rho, abs=1e-7)
    Xq = Xte[:100]
    pred, scores = serve_batch(sm, Xq, kern, "exact")
    assert bool(jnp.all(jnp.abs(pred) == 1.0))
    d_ref = decision_exact(model, Xq)
    np.testing.assert_allclose(np.asarray(scores[:, 0]), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(pred), np.asarray(predict_exact(model, Xq)))

    model_e = fit(dataclasses.replace(cfg, early_stop_level=1), Xtr, ytr,
                  task=task)
    assert model_e.rho_clusters is not None
    sm_e = export_serving_model(model_e, with_bcm=False)
    assert sm_e.rho_c.shape == (model_e.partition.k,)
    pred_e, scores_e = serve_batch(sm_e, Xq, kern, "early")
    np.testing.assert_allclose(np.asarray(scores_e[:, 0]),
                               np.asarray(decision_early(model_e, Xq)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# (b) one-class vs sklearn
# ---------------------------------------------------------------------------

def test_oneclass_dense_matches_sklearn_decision_boundary():
    """Same parameterization as libsvm (0 <= a <= 1, sum a = nu n): our
    dense equality solve must reproduce sklearn's OneClassSVM decision
    function and inlier/outlier boundary on gaussian_with_outliers."""
    sklearn_svm = pytest.importorskip("sklearn.svm")

    X, y = _ocsvm_problem(n=300, key=5)
    n = X.shape[0]
    gamma, nu = 2.0, 0.25
    kern = Kernel("rbf", gamma=gamma)
    K = kern.pairwise(X, X)
    res = solve_eq_qp(K, 1.0, 1.0, nu * n, tol=1e-7, max_iters=400_000)
    rho = float(equality_rho(res.alpha, res.grad, jnp.ones(n), jnp.ones(n)))
    f_ours = np.asarray(K, np.float64) @ np.asarray(res.alpha, np.float64) \
        - rho

    sk = sklearn_svm.OneClassSVM(kernel="rbf", gamma=gamma, nu=nu,
                                 tol=1e-9).fit(np.asarray(X))
    f_sk = sk.decision_function(np.asarray(X))
    np.testing.assert_allclose(f_ours, f_sk, atol=2e-4)
    # the decision boundary agrees wherever sklearn is not razor-thin
    clear = np.abs(f_sk) > 1e-3
    assert clear.mean() > 0.5
    assert (np.sign(f_ours[clear]) == np.sign(f_sk[clear])).all()


# ---------------------------------------------------------------------------
# (c) acceptance: multilevel DC-SVM vs dense reference to 1e-4
# ---------------------------------------------------------------------------

def test_oneclass_multilevel_matches_dense_reference():
    """Acceptance criterion: the multilevel (divide -> conquer) one-class
    fit matches a direct dense equality-constrained solve to 1e-4 in
    decision values, and |sum alpha - nu n| <= 1e-6.  x64: at f32 the KKT
    residual itself cannot be measured below ~1e-4 at these scales."""
    with enable_x64():
        X, y = _ocsvm_problem(n=400, key=0)
        X = jnp.asarray(X, jnp.float64)
        n = X.shape[0]
        nu = 0.12
        kern = Kernel("rbf", gamma=4.0)
        cfg = DCSVMConfig(kernel=kern, k=3, levels=2, m=250, tol=1e-8,
                          kmeans_iters=8, use_pallas=False)
        model = fit(cfg, X, task=OneClassSVM(nu=nu))
        assert model.alpha.dtype == jnp.float64
        assert abs(float(model.alpha.sum()) - nu * n) <= 1e-6

        K = kern.pairwise(X, X)
        ref = solve_eq_qp(K, 1.0, 1.0, nu * n, tol=1e-8, max_iters=600_000)
        rho_ref = float(equality_rho(ref.alpha, ref.grad, jnp.ones(n),
                                     jnp.ones(n)))
        assert float(kkt_residual_eq(K, model.alpha, 1.0, 1.0)) <= 1e-6
        f_fit = np.asarray(K) @ np.asarray(model.alpha) - model.rho
        f_ref = np.asarray(K) @ np.asarray(ref.alpha) - rho_ref
        np.testing.assert_allclose(f_fit, f_ref, atol=1e-4)


# ---------------------------------------------------------------------------
# (d) nu property, rho, early prediction, serving
# ---------------------------------------------------------------------------

def test_oneclass_nu_sandwich_and_detection():
    """The nu property: margin-error fraction <= nu <= SV fraction (to
    discretization slack), and the detector actually finds the planted
    outliers."""
    X, y = _ocsvm_problem(n=1000, key=7)
    n_all = X.shape[0]
    nu = 0.1
    kern = Kernel("rbf", gamma=4.0)
    cfg = DCSVMConfig(kernel=kern, k=3, levels=1, m=300, tol=1e-5,
                      kmeans_iters=8, use_pallas=False)
    model = fit(cfg, X, task=OneClassSVM(nu=nu))
    f_tr = np.asarray(decision_exact(model, X), np.float64)
    out_frac = float((f_tr < -1e-6).mean())
    sv_frac = len(model.sv_index) / n_all
    slack = 2.0 / n_all
    assert out_frac <= nu + slack, (out_frac, nu)
    assert sv_frac >= nu - slack, (sv_frac, nu)
    # detection: all planted outliers are far off the inlier modes here
    pred = predict_exact(model, X)
    assert recall(y, pred, -1.0) >= 0.9
    assert f1(y, pred, -1.0) >= 0.5


def test_oneclass_label_free_fit_and_y_required_elsewhere():
    """fit() accepts y=None only for label-free tasks."""
    X, _ = _ocsvm_problem(n=120, key=9)
    cfg = DCSVMConfig(kernel=Kernel("rbf", gamma=2.0), k=2, levels=1, m=60,
                      tol=1e-3, kmeans_iters=5, use_pallas=False)
    model = fit(cfg, X, task=OneClassSVM(nu=0.3))
    assert model.rho is not None
    with pytest.raises(ValueError):
        fit(cfg, X)          # default C-SVC needs labels


def test_oneclass_early_uses_per_cluster_rho():
    """Early-stopped one-class models carry per-cluster multipliers; eq.-11
    routing must subtract the assigned cluster's rho_c (the local levels
    differ by O(1), so a global offset misgrades whole clusters)."""
    X, y = _ocsvm_problem(n=1000, key=11)
    kern = Kernel("rbf", gamma=4.0)
    cfg = DCSVMConfig(kernel=kern, k=4, levels=1, m=300, tol=1e-4,
                      kmeans_iters=8, use_pallas=False, early_stop_level=1)
    model = fit(cfg, X, task=OneClassSVM(nu=0.1))
    assert model.is_early and model.rho_clusters is not None
    assert model.rho_clusters.shape == (model.partition.k,)

    # reference: per-cluster scoring with the cluster's own rho_c
    from repro.core.kkmeans import assign_points

    cid = np.asarray(assign_points(kern, model.partition.model, X)[0])
    u = np.asarray(model.alpha)
    rho_c = np.asarray(model.rho_clusters)
    raw = np.zeros(X.shape[0])
    for c in range(model.partition.k):
        mem = model.partition.idx[c][model.partition.mask[c]]
        q = np.where(cid == c)[0]
        if len(q):
            Kq = np.asarray(kern.pairwise(X[jnp.asarray(q)],
                                          X[jnp.asarray(mem)]))
            raw[q] = Kq @ u[mem] - rho_c[c]
    got = np.asarray(decision_early(model, X))
    np.testing.assert_allclose(got, raw, atol=1e-4)


def test_nusvc_bias_early_single_class_clusters():
    """Regression: an early-stopped biased NuSVC whose clusters are PURE
    (label-free kmeans on well-separated class blobs splits by class) has
    one empty constraint group per cluster — its local bias is undefined,
    and the recovery must fall back to a ZERO offset (the cluster scores
    with its raw own-class-signed decision), not to a half-level shift
    toward the absent class."""
    rng = np.random.default_rng(0)
    n_half, dim = 150, 4
    Xp = rng.normal(size=(n_half, dim)) * 0.2 + 3.0
    Xm = rng.normal(size=(n_half, dim)) * 0.2 - 3.0
    X = jnp.asarray(np.vstack([Xp, Xm]).astype(np.float32))
    y = jnp.asarray(np.concatenate([np.ones(n_half), -np.ones(n_half)])
                    .astype(np.float32))
    kern = Kernel("rbf", gamma=0.5)
    cfg = DCSVMConfig(kernel=kern, k=2, levels=1, m=150, tol=1e-5,
                      kmeans_iters=10, use_pallas=False, early_stop_level=1)
    model = fit(cfg, X, y, task=NuSVC(nu=0.3, with_bias=True))
    assert model.rho_clusters is not None
    rho_c = np.asarray(model.rho_clusters)
    assert np.isfinite(rho_c).all()
    # the clusters really are single-class (the premise of the regression)
    assign = np.asarray(model.partition.assign)
    yn = np.asarray(y)
    purity = [np.abs(yn[assign == c].mean()) for c in range(2)]
    assert min(purity) > 0.99, purity
    # a pure cluster's offset is exactly 0 -> every query routed to it is
    # graded by the raw own-class-signed score, i.e. predicted as ITS class
    np.testing.assert_allclose(rho_c, 0.0, atol=1e-6)
    pred = np.asarray(predict_early(model, X))
    assert (pred == yn).mean() == 1.0


def test_oneclass_early_prediction_bound_holds():
    """ROADMAP item 3 pinned: on fixed-seed gaussian_with_outliers data the
    measured early-prediction error max |f_early(x) - f(x)| respects the
    D(pi) + rho_c-spread bound of ``bounds.oneclass_early_gap_bound`` —
    both the a-priori form (Theorem-1 drift through sigma_n-strong
    convexity) and the semi-empirical form with the measured dual drift."""
    from repro.core.bounds import oneclass_early_gap_bound
    from repro.core.kkmeans import assign_points

    X, _ = _ocsvm_problem(n=500, key=21)
    kern = Kernel("rbf", gamma=4.0)
    nu = 0.15
    cfg = DCSVMConfig(kernel=kern, k=3, levels=1, m=250, tol=1e-5,
                      kmeans_iters=8, use_pallas=False,
                      full_gram_threshold=64)
    model_e = fit(dataclasses.replace(cfg, early_stop_level=1), X,
                  task=OneClassSVM(nu=nu))
    model = fit(cfg, X, task=OneClassSVM(nu=nu))
    Xq = X[:200]
    f_e = np.asarray(decision_early(model_e, Xq), np.float64)
    f = np.asarray(decision_exact(model, Xq), np.float64)
    gap = float(np.max(np.abs(f_e - f)))

    sigma_n = float(np.linalg.eigvalsh(
        np.asarray(kern.pairwise(X, X), np.float64)).min())
    cid_q = assign_points(kern, model_e.partition.model, Xq)[0]
    b = oneclass_early_gap_bound(
        kern, X, model_e.partition.assign, model_e.alpha, model.rho,
        model_e.rho_clusters, Xq, cid_q, sigma_n,
        alpha_exact=model.alpha)
    assert np.isfinite(b["bound"]) and np.isfinite(b["bound_measured"])
    # the semi-empirical bound is the tight(er) one; both must hold
    assert gap <= b["bound_measured"] * (1 + 1e-6) + 1e-6, (gap, b)
    assert gap <= b["bound"] * (1 + 1e-6) + 1e-6, (gap, b)
    assert b["term_rho"] > 0.0       # the clusters really carry distinct rho_c


def test_oneclass_serving_export_round_trip():
    """export_serving_model/serve_batch for task "ocsvm": single beta
    column + rho, exact strategy reproduces decision_exact, early strategy
    reproduces predict_early (per-cluster rho_c travels with the export),
    predictions are +/-1."""
    from repro.launch.serve_svm import export_serving_model, serve_batch

    X, y = _ocsvm_problem(n=800, key=13)
    kern = Kernel("rbf", gamma=4.0)
    cfg = DCSVMConfig(kernel=kern, k=3, levels=1, m=250, tol=1e-4,
                      kmeans_iters=8, use_pallas=False)
    model = fit(cfg, X, task=OneClassSVM(nu=0.1))
    sm = export_serving_model(model, with_bcm=False)
    assert sm.task == "ocsvm"
    assert sm.n_classes == 1 and sm.Wsv.shape[-1] == 1
    Xq = X[:100]
    pred, scores = serve_batch(sm, Xq, kern, "exact")
    assert bool(jnp.all(jnp.abs(pred) == 1.0))
    np.testing.assert_allclose(np.asarray(scores[:, 0]),
                               np.asarray(decision_exact(model, Xq)),
                               rtol=1e-4, atol=1e-4)

    model_e = fit(dataclasses.replace(cfg, early_stop_level=1), X,
                  task=OneClassSVM(nu=0.1))
    sm_e = export_serving_model(model_e, with_bcm=False)
    assert sm_e.rho_c.shape == (model_e.partition.k,)
    pred_e, scores_e = serve_batch(sm_e, Xq, kern, "early")
    np.testing.assert_allclose(np.asarray(scores_e[:, 0]),
                               np.asarray(predict_early_raw := decision_early(
                                   model_e, Xq)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(pred_e),
        np.where(np.asarray(predict_early_raw) >= 0, 1.0, -1.0))
