"""Property-based solver conformance suite (ISSUE-4, extended by ISSUE-5).

Every solver variant — box family (``solve_box_qp``, ``solve_box_qp_block``,
``solve_with_shrinking``, ``solve_box_qp_matvec``) and equality family
(``solve_eq_qp``, ``solve_eq_qp_block``, ``solve_eq_qp_shrink``,
``solve_eq_qp_matvec``) — is run on randomized problems (random SPD Q,
random linear term p, scalar-or-vector box c, and for the equality family
random mixed-sign a with a strictly interior target d) and must return
iterates that are

* box-feasible (0 <= u <= c),
* equality-feasible to 1e-6 where applicable (x64 pass; the f32 pass is
  bounded by the f32 summation noise of measuring a'u itself),
* monotonically non-increasing in objective as the iteration budget grows,
* KKT-consistent with ``proj_grad``/``kkt_residual`` (box) and
  ``kkt_residual_eq`` (equality),
* no worse than an independent scipy reference solve (L-BFGS-B for the box
  family, SLSQP for the equality family) in final objective.

New in ISSUE-5: the rank-2B blocked variants run the same conformance
properties, plus a cross-engine property — ``solve_eq_qp_block(B)`` agrees
with ``solve_eq_qp`` in final objective to 1e-5 for B in {1, 2, 8} on
non-tile-aligned sizes — and a grouped (two-constraint) conformance pass
against scipy SLSQP with both constraints active.

The suite is hypothesis-driven when hypothesis is installed (CI pins
--hypothesis-seed); in this container hypothesis is absent, so the same
property functions run over a fixed seed grid — deterministic either way,
with a bounded example budget so tier-1 stays fast.  The whole module is
marked ``properties`` so ``scripts/ci.sh --fast`` can skip it
(``pytest -m "not properties"``) for a quick local loop.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import (
    Kernel,
    kkt_residual,
    kkt_residual_eq,
    objective,
    proj_grad,
    project_box_equality,
    solve_box_qp,
    solve_box_qp_block,
    solve_box_qp_matvec,
    solve_eq_qp,
    solve_eq_qp_block,
    solve_eq_qp_matvec,
    solve_eq_qp_shrink,
    solve_with_shrinking,
)

pytestmark = pytest.mark.properties

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_EXAMPLES = 10
FALLBACK_SEEDS = [17 * i + 3 for i in range(N_EXAMPLES)]


def each_seed(fn):
    """Run ``fn(seed)`` over random seeds: hypothesis-drawn when available,
    else a fixed deterministic grid of the same size."""
    if HAVE_HYPOTHESIS:
        return settings(
            deadline=None, max_examples=N_EXAMPLES,
            suppress_health_check=[HealthCheck.too_slow],
        )(given(st.integers(0, 2**30))(fn))
    return pytest.mark.parametrize("seed", FALLBACK_SEEDS)(fn)


# ---------------------------------------------------------------------------
# problem generators (numpy-rng from an integer seed -> deterministic)
# ---------------------------------------------------------------------------

def _box_qp(seed, f64=False):
    """Random SPD Q (not necessarily a kernel), random p, scalar-or-vector c.
    Scales kept O(1) so absolute tolerances are meaningful.  Sizes are drawn
    from a small fixed grid so the jitted solvers recompile once per shape,
    not once per example (the suite's runtime is compile-bound)."""
    rng = np.random.default_rng(seed)
    n = int(rng.choice([12, 24, 40]))
    B = rng.normal(size=(n, n)) / np.sqrt(n)
    Q = B @ B.T + 0.05 * np.eye(n)
    p = rng.normal(size=n)
    if rng.integers(2) == 0:
        c = float(rng.uniform(0.2, 2.0))
    else:
        c = rng.uniform(0.2, 2.0, size=n)
    dt = np.float64 if f64 else np.float32
    cj = jnp.asarray(np.broadcast_to(c, (n,)).astype(dt)) \
        if np.ndim(c) else float(c)
    return jnp.asarray(Q.astype(dt)), jnp.asarray(p.astype(dt)), cj, n


def _eq_extras(seed, cvec, n, f64=False):
    """Mixed-sign a bounded away from 0 and a strictly interior target d."""
    rng = np.random.default_rng(seed + 1)
    a = np.where(rng.uniform(size=n) > 0.5, 1.0, -1.0) \
        * rng.uniform(0.3, 2.0, size=n)
    cn = np.broadcast_to(np.asarray(cvec, np.float64), (n,))
    ac = a * cn
    lo, hi = np.minimum(ac, 0).sum(), np.maximum(ac, 0).sum()
    d = float(lo + rng.uniform(0.15, 0.85) * (hi - lo))
    dt = np.float64 if f64 else np.float32
    return jnp.asarray(a.astype(dt)), d


def _np_obj(Q, p, u):
    Qn, pn, un = (np.asarray(v, np.float64) for v in (Q, p, u))
    return 0.5 * un @ Qn @ un + pn @ un


# ---------------------------------------------------------------------------
# box family
# ---------------------------------------------------------------------------

@each_seed
def test_box_solvers_feasible_kkt_and_vs_reference(seed):
    """All dense box solvers: box-feasible, KKT <= tol headroom, proj_grad
    consistent with the returned gradient, and objective no worse than an
    independent scipy L-BFGS-B solve of the same QP."""
    from scipy.optimize import minimize

    Q, p, c, n = _box_qp(seed)
    cn = np.broadcast_to(np.asarray(c, np.float64), (n,))
    solvers = {
        "greedy": lambda: solve_box_qp(Q, c, tol=1e-5, max_iters=200_000,
                                       p=p),
        "block": lambda: solve_box_qp_block(Q, c, tol=1e-5, max_iters=50_000,
                                            block=min(8, n), p=p),
        "shrink": lambda: solve_with_shrinking(Q, c, tol=1e-5,
                                               max_iters=200_000, p=p),
    }
    Qn, pn = np.asarray(Q, np.float64), np.asarray(p, np.float64)
    ref = minimize(lambda u: (0.5 * u @ Qn @ u + pn @ u, Qn @ u + pn),
                   np.zeros(n), jac=True, method="L-BFGS-B",
                   bounds=list(zip(np.zeros(n), cn)),
                   options={"maxiter": 20_000, "ftol": 1e-16, "gtol": 1e-10})
    for name, run in solvers.items():
        res = run()
        u = np.asarray(res.alpha, np.float64)
        assert u.min() >= -1e-7, name
        assert (u <= cn + 1e-6).all(), name
        assert float(kkt_residual(Q, res.alpha, c, p=p)) <= 1e-4, name
        # the maintained gradient matches Q u + p (drift bounded)
        g_dev = np.abs(np.asarray(res.grad, np.float64) - (Qn @ u + pn)).max()
        assert g_dev <= 1e-3, (name, g_dev)
        # proj_grad is the KKT residual field: zero on free optimal coords
        pg = np.asarray(proj_grad(res.alpha, res.grad, c))
        assert np.abs(pg).max() <= 1e-3, name
        assert _np_obj(Q, p, u) <= ref.fun + 1e-5 * (1 + abs(ref.fun)), name


@each_seed
def test_box_matvec_solver_conformance(seed):
    """solve_box_qp_matvec (kernel columns on the fly) agrees with the dense
    greedy solver on the same kernel box QP."""
    rng = np.random.default_rng(seed)
    n, dfeat = int(rng.choice([24, 48])), 5
    X = jnp.asarray(rng.uniform(-1, 1, size=(n, dfeat)).astype(np.float32))
    y = jnp.asarray(np.where(rng.uniform(size=n) > 0.5, 1.0, -1.0)
                    .astype(np.float32))
    p = jnp.asarray(rng.normal(size=n).astype(np.float32)) - 1.0
    C = float(rng.uniform(0.5, 3.0))
    kern = Kernel("rbf", gamma=2.0)
    Q = (y[:, None] * y[None, :]) * kern.pairwise(X, X)
    dense = solve_box_qp(Q, C, tol=1e-6, max_iters=200_000, p=p)
    mv = solve_box_qp_matvec(X, y, kern, C, tol=1e-6, max_iters=20_000,
                             block=min(16, n), p=p)
    u = np.asarray(mv.alpha, np.float64)
    assert u.min() >= -1e-7 and u.max() <= C + 1e-6
    f_mv, f_dense = _np_obj(Q, p, mv.alpha), _np_obj(Q, p, dense.alpha)
    assert f_mv <= f_dense + 1e-4 * (1 + abs(f_dense))
    assert float(kkt_residual(Q, mv.alpha, C, p=p)) <= 1e-4


# ---------------------------------------------------------------------------
# equality family
# ---------------------------------------------------------------------------

@each_seed
def test_eq_solver_feasible_kkt_and_vs_reference_x64(seed):
    """Acceptance criterion: |a'u - d| <= 1e-6 at every returned iterate and
    KKT residual at tolerance, cross-checked against scipy SLSQP.  Runs in
    x64, where the 1e-6 bound is met with orders of magnitude to spare
    (f32 cannot even MEASURE a'u to 1e-6 at these scales)."""
    from scipy.optimize import minimize

    with enable_x64():
        Q, p, c, n = _box_qp(seed, f64=True)
        a, d = _eq_extras(seed, c, n, f64=True)
        an = np.asarray(a)
        cn = np.broadcast_to(np.asarray(c, np.float64), (n,))
        for name, run in {
            "pairwise": lambda: solve_eq_qp(Q, c, a, d, tol=1e-8,
                                            max_iters=500_000, p=p),
            "block": lambda: solve_eq_qp_block(Q, c, a, d, tol=1e-8,
                                               max_iters=100_000, block=4,
                                               p=p),
            "shrink": lambda: solve_eq_qp_shrink(Q, c, a, d, tol=1e-8,
                                                 max_iters=500_000, p=p),
            "shrink_block": lambda: solve_eq_qp_shrink(Q, c, a, d, tol=1e-8,
                                                       max_iters=100_000,
                                                       block=4, p=p),
        }.items():
            res = run()
            u = np.asarray(res.alpha)
            assert u.min() >= -1e-12, name
            assert (u <= cn + 1e-12).all(), name
            assert abs(an @ u - d) <= 1e-6, (name, abs(an @ u - d))
            assert float(kkt_residual_eq(Q, res.alpha, c, a, p=p)) <= 1e-6, \
                name

        ref = minimize(
            lambda u: 0.5 * u @ np.asarray(Q) @ u + np.asarray(p) @ u,
            np.clip(np.full(n, d / an.sum() if abs(an.sum()) > 1e-9 else 0.0),
                    0, cn),
            jac=lambda u: np.asarray(Q) @ u + np.asarray(p),
            method="SLSQP", bounds=list(zip(np.zeros(n), cn)),
            constraints=[{"type": "eq", "fun": lambda u: an @ u - d,
                          "jac": lambda u: an}],
            options={"maxiter": 3000, "ftol": 1e-14})
        res = solve_eq_qp(Q, c, a, d, tol=1e-8, max_iters=500_000, p=p)
        f_ours = _np_obj(Q, p, res.alpha)
        if ref.success:
            assert f_ours <= ref.fun + 1e-6 * (1 + abs(ref.fun))


@each_seed
def test_eq_solver_f32_feasibility_floor(seed):
    """The f32 path keeps |a'u - d| at the f32 summation-noise floor of the
    constraint itself (scale-relative 1e-6-grade), not at accumulated-drift
    scale — for the rank-2 AND the rank-2B blocked engine."""
    Q, p, c, n = _box_qp(seed)
    a, d = _eq_extras(seed, c, n)
    for run in (
        lambda: solve_eq_qp(Q, c, a, d, tol=1e-5, max_iters=300_000, p=p),
        lambda: solve_eq_qp_block(Q, c, a, d, tol=1e-5, max_iters=100_000,
                                  block=8, p=p),
    ):
        res = run()
        u = np.asarray(res.alpha, np.float64)
        an = np.asarray(a, np.float64)
        scale = np.abs(an * u).sum() + abs(d)
        assert abs(an @ u - d) <= 4e-6 * max(scale, 1.0)
        assert float(kkt_residual_eq(Q, res.alpha, c, a, p=p)) <= 1e-3


@each_seed
def test_eq_block_matches_pairwise_objective(seed):
    """Acceptance criterion (cross-engine property): solve_eq_qp_block
    reaches the same final objective as the rank-2 pairwise engine to 1e-5
    for B in {1, 2, 8} on the non-tile-aligned conformance grid, while
    staying box- and equality-feasible at the returned iterate."""
    with enable_x64():
        Q, p, c, n = _box_qp(seed, f64=True)
        a, d = _eq_extras(seed, c, n, f64=True)
        an = np.asarray(a)
        cn = np.broadcast_to(np.asarray(c, np.float64), (n,))
        ref = solve_eq_qp(Q, c, a, d, tol=1e-8, max_iters=500_000, p=p)
        f_ref = _np_obj(Q, p, ref.alpha)
        for B in (1, 2, 8):
            res = solve_eq_qp_block(Q, c, a, d, tol=1e-8, max_iters=100_000,
                                    block=B, p=p)
            u = np.asarray(res.alpha)
            assert u.min() >= -1e-12, B
            assert (u <= cn + 1e-12).all(), B
            assert abs(an @ u - d) <= 1e-6, (B, abs(an @ u - d))
            f_b = _np_obj(Q, p, res.alpha)
            assert abs(f_b - f_ref) <= 1e-5 * (1 + abs(f_ref)), (B, f_b, f_ref)


@each_seed
def test_eq_grouped_two_constraints_vs_slsqp(seed):
    """Grouped decomposition (the two-constraint nu-SVC machinery): random
    two-group partition, one interior mass target per group.  Both engines
    must satisfy BOTH constraints to 1e-6, pass the grouped KKT residual,
    and match a scipy SLSQP solve of the doubly-constrained QP."""
    from scipy.optimize import minimize

    with enable_x64():
        Q, p, c, n = _box_qp(seed, f64=True)
        a, _ = _eq_extras(seed, c, n, f64=True)
        rng = np.random.default_rng(seed + 7)
        gid_n = (rng.uniform(size=n) > 0.5).astype(np.int32)
        if gid_n.min() == gid_n.max():       # degenerate draw: force 2 groups
            gid_n[: n // 2] = 1 - gid_n[0]
        an = np.asarray(a)
        cn = np.broadcast_to(np.asarray(c, np.float64), (n,))
        d2 = []
        for g in (0, 1):
            acg = (an * cn)[gid_n == g]
            lo, hi = np.minimum(acg, 0).sum(), np.maximum(acg, 0).sum()
            d2.append(float(lo + rng.uniform(0.2, 0.8) * (hi - lo)))
        gid = jnp.asarray(gid_n)
        d = jnp.asarray(d2)
        for name, run in {
            "pairwise": lambda: solve_eq_qp(Q, c, a, d, tol=1e-8,
                                            max_iters=500_000, p=p, gid=gid,
                                            n_groups=2),
            "block": lambda: solve_eq_qp_block(Q, c, a, d, tol=1e-8,
                                               max_iters=100_000, block=4,
                                               p=p, gid=gid, n_groups=2),
        }.items():
            res = run()
            u = np.asarray(res.alpha)
            assert u.min() >= -1e-12 and (u <= cn + 1e-12).all(), name
            for g in (0, 1):
                got = (an * u)[gid_n == g].sum()
                assert abs(got - d2[g]) <= 1e-6, (name, g, got, d2[g])
            assert float(kkt_residual_eq(Q, res.alpha, c, a, p=p, gid=gid,
                                         n_groups=2)) <= 1e-6, name

        cons = [{"type": "eq",
                 "fun": (lambda u, g=g: (an * u)[gid_n == g].sum() - d2[g]),
                 "jac": (lambda u, g=g: np.where(gid_n == g, an, 0.0))}
                for g in (0, 1)]
        x0 = np.clip(np.full(n, 0.5) * cn, 0, cn)
        ref = minimize(
            lambda u: 0.5 * u @ np.asarray(Q) @ u + np.asarray(p) @ u,
            x0, jac=lambda u: np.asarray(Q) @ u + np.asarray(p),
            method="SLSQP", bounds=list(zip(np.zeros(n), cn)),
            constraints=cons, options={"maxiter": 3000, "ftol": 1e-14})
        res = solve_eq_qp_block(Q, c, a, d, tol=1e-8, max_iters=100_000,
                                block=4, p=p, gid=gid, n_groups=2)
        if ref.success:
            f_ours = _np_obj(Q, p, res.alpha)
            assert f_ours <= ref.fun + 1e-6 * (1 + abs(ref.fun))


@each_seed
def test_eq_matvec_matches_dense(seed):
    """solve_eq_qp_matvec (on-the-fly kernel columns) reaches the dense
    pairwise solution on the same strictly convex kernel QP."""
    rng = np.random.default_rng(seed)
    n, dfeat = int(rng.choice([24, 48])), 5
    X = jnp.asarray(rng.uniform(-1, 1, size=(n, dfeat)).astype(np.float32))
    y = jnp.asarray(np.where(rng.uniform(size=n) > 0.5, 1.0, -1.0)
                    .astype(np.float32))
    kern = Kernel("rbf", gamma=2.0)
    c = 1.0
    a, d = _eq_extras(seed, c, n)
    p = 0.0
    Q = (y[:, None] * y[None, :]) * kern.pairwise(X, X)
    dense = solve_eq_qp(Q, c, a, d, tol=1e-6, max_iters=400_000, p=p)
    mv = solve_eq_qp_matvec(X, y, kern, c, a, d, tol=1e-6, max_iters=400_000,
                            p=p)
    f_d, f_m = _np_obj(Q, jnp.zeros(n), dense.alpha), \
        _np_obj(Q, jnp.zeros(n), mv.alpha)
    assert abs(f_d - f_m) <= 1e-4 * (1 + abs(f_d))
    # the RBF Gram on distinct points is PD -> unique optimum
    np.testing.assert_allclose(np.asarray(mv.alpha), np.asarray(dense.alpha),
                               atol=5e-4)
    an = np.asarray(a, np.float64)
    u = np.asarray(mv.alpha, np.float64)
    assert abs(an @ u - d) <= 4e-6 * max(np.abs(an * u).sum() + abs(d), 1.0)


@each_seed
def test_objective_monotone_in_iteration_budget(seed):
    """Greedy/pairwise CD is a descent method: the objective after k
    iterations is non-increasing in k, for both dual families (the equality
    family measures from the projected feasible start)."""
    Q, p, c, n = _box_qp(seed)
    a, d = _eq_extras(seed, c, n)
    budgets = [0, 1, 2, 4, 8, 16, 32, 64, 128]
    for run in (
        lambda k: solve_box_qp(Q, c, tol=0.0, max_iters=k, p=p),
        lambda k: solve_eq_qp(Q, c, a, d, tol=0.0, max_iters=k, p=p),
        lambda k: solve_eq_qp_block(Q, c, a, d, tol=0.0, max_iters=k,
                                    block=4, p=p),
    ):
        objs = [_np_obj(Q, p, run(k).alpha) for k in budgets]
        for f_prev, f_next in zip(objs, objs[1:]):
            assert f_next <= f_prev + 1e-5 * (1 + abs(f_prev))


@each_seed
def test_objective_identity_from_maintained_gradient(seed):
    """objective(u, g, p) == 1/2 u'Qu + p'u when g = Qu + p, for random
    generalized (p, c) — the identity every solver's bookkeeping rests on."""
    Q, p, c, n = _box_qp(seed)
    rng = np.random.default_rng(seed + 2)
    u = jnp.asarray(np.clip(rng.normal(size=n), 0,
                            np.broadcast_to(np.asarray(c), (n,)))
                    .astype(np.float32))
    g = Q @ u + p
    f_id = float(objective(u, g, p=p))
    assert abs(f_id - _np_obj(Q, p, u)) <= 1e-4 * (1 + abs(f_id))


@each_seed
def test_projection_box_equality_properties(seed):
    """project_box_equality output is box-feasible, hits a'u = d for
    attainable targets (x64 exactness), and is a fixed point on already
    feasible inputs."""
    with enable_x64():
        rng = np.random.default_rng(seed)
        n = int(rng.choice([12, 24, 40]))
        c = jnp.asarray(rng.uniform(0.2, 2.0, size=n))
        a, d = _eq_extras(seed, c, n, f64=True)
        u0 = jnp.asarray(rng.normal(size=n))       # wildly infeasible start
        u = project_box_equality(u0, c, a, d)
        un, an, cn = (np.asarray(v) for v in (u, a, c))
        assert un.min() >= -1e-12 and (un <= cn + 1e-12).all()
        assert abs(an @ un - d) <= 1e-8
        # fixed point: projecting the projection changes nothing measurable
        u2 = project_box_equality(u, c, a, d)
        np.testing.assert_allclose(np.asarray(u2), un, atol=1e-9)
