"""Telemetry subsystem (repro.obs): convergence-trace rings, span tracing,
serving metrics, and the bit-identity / host-sync contracts they must keep.

The load-bearing guarantees pinned here:

* ``trace=None`` (the default) leaves every solver trajectory bit-identical
  to the untraced build — tracing is a pure observer, and enabling it must
  not move the iterate either.
* A trace-enabled matvec solve stays free of device->host syncs (the ring
  lives on device; the fetch happens once, after).
* The ring keeps the LAST ``cap`` samples with an exact dropped count.
* Chrome trace exports are schema-valid (complete ``X`` events, sorted,
  non-negative durations); histograms/registries expose Prometheus text.
"""
import json
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import Kernel
from repro.core.solver import (solve_box_qp, solve_box_qp_matvec,
                               solve_eq_qp, solve_with_shrinking)
from repro.data import gaussian_mixture
from repro.obs.metrics import Counter, Gauge, LatencyHistogram, MetricsRegistry
from repro.obs.spans import SpanTracer, span
from repro.obs.trace import (TRACE_COLS, ConvTrace, trace_fetch, trace_init,
                             trace_record, trace_summary)

KERN = Kernel("rbf", gamma=4.0)


def _problem(n=96, seed=0):
    X, y = gaussian_mixture(jax.random.PRNGKey(seed), n, d=5,
                            modes_per_class=3)
    return X, y


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------

def test_trace_truncated_fill():
    tr = trace_init(8)
    for i in range(3):
        tr = trace_record(tr, pg_max=float(i), objective=float(10 + i))
    out = trace_fetch(tr)
    assert out["samples"] == 3 and out["dropped"] == 0
    assert out["pg_max"] == [0.0, 1.0, 2.0]
    assert out["objective"] == [10.0, 11.0, 12.0]
    # never-recorded columns are omitted, not NaN-filled
    assert "gamma" not in out and "cache_hits" not in out


def test_trace_wraparound_keeps_last_cap_in_order():
    tr = trace_init(4)
    for i in range(10):
        tr = trace_record(tr, pg_max=float(i))
    out = trace_fetch(tr)
    assert out["samples"] == 4 and out["dropped"] == 6
    assert out["pg_max"] == [6.0, 7.0, 8.0, 9.0]   # chronological tail
    s = trace_summary(out)
    assert s["pg_first"] == 6.0 and s["pg_last"] == 9.0


def test_trace_init_rejects_nonpositive_capacity():
    with pytest.raises(ValueError):
        trace_init(0)


def test_trace_record_under_jit_and_vmap():
    def record_k(pg):
        tr = trace_init(4)
        def body(i, t):
            return trace_record(t, pg_max=pg * (i + 1.0))
        return jax.lax.fori_loop(0, 3, body, tr)

    tr = jax.jit(jax.vmap(record_k))(jnp.asarray([1.0, 10.0]))
    out = trace_fetch(tr)
    assert isinstance(out, list) and len(out) == 2
    assert out[0]["pg_max"] == [1.0, 2.0, 3.0]
    assert out[1]["pg_max"] == [10.0, 20.0, 30.0]
    merged = trace_summary(out)
    assert merged["samples"] == 6 and merged["pg_last"] == 30.0


# ---------------------------------------------------------------------------
# solver bit-identity: tracing observes, never steers
# ---------------------------------------------------------------------------

def test_traced_box_solve_is_bit_identical():
    X, y = _problem()
    Q = (y[:, None] * y[None, :]) * (KERN.pairwise(X, X))
    r0 = solve_box_qp(Q, 2.0, tol=1e-5, max_iters=2000)
    r1 = solve_box_qp(Q, 2.0, tol=1e-5, max_iters=2000, trace=trace_init(32))
    assert np.array_equal(np.asarray(r0.alpha), np.asarray(r1.alpha))
    assert int(r0.iters) == int(r1.iters)
    out = trace_fetch(r1.trace)
    assert out["samples"] + out["dropped"] == int(r1.iters)
    # the recorded columns carry real values
    assert out["pg_max"][-1] == pytest.approx(float(r1.pg_max), rel=1e-6)
    assert all(f == int(f) and 0 <= f <= Q.shape[0] for f in out["n_free"])


def test_traced_shrinking_solve_is_bit_identical():
    X, y = _problem(seed=1)
    Q = (y[:, None] * y[None, :]) * (KERN.pairwise(X, X))
    r0 = solve_with_shrinking(Q, 2.0, tol=1e-4, max_iters=4000, rounds=3)
    r1 = solve_with_shrinking(Q, 2.0, tol=1e-4, max_iters=4000, rounds=3,
                              trace=trace_init(64))
    assert np.array_equal(np.asarray(r0.alpha), np.asarray(r1.alpha))
    assert trace_fetch(r1.trace)["samples"] > 0


def test_traced_eq_solve_is_bit_identical():
    X, _ = _problem(seed=2)
    n = X.shape[0]
    Q = KERN.pairwise(X, X)
    kw = dict(tol=1e-4, max_iters=4000)
    r0 = solve_eq_qp(Q, 1.0, 1.0, 0.3 * n, **kw)
    r1 = solve_eq_qp(Q, 1.0, 1.0, 0.3 * n, trace=trace_init(32), **kw)
    assert np.array_equal(np.asarray(r0.alpha), np.asarray(r1.alpha))
    out = trace_fetch(r1.trace)
    assert out["samples"] > 0 and "pg_max" in out


def test_traced_matvec_solve_stays_host_sync_free():
    """The trace ring must live on device: recording adds no host round-trip
    to the matvec CD loop (same pin as the cache/spill counters)."""
    X, y = _problem(n=128, seed=3)
    kw = dict(tol=1e-4, max_iters=2000, block=16, sweeps=2)
    r0 = solve_box_qp_matvec(X, y, KERN, 2.0, **kw)
    # warm the traced program (compilation may inspect host values)
    solve_box_qp_matvec(X, y, KERN, 2.0, trace=trace_init(32), **kw)
    with jax.transfer_guard_device_to_host("disallow"):
        r1 = solve_box_qp_matvec(X, y, KERN, 2.0, trace=trace_init(32), **kw)
        r1.alpha.block_until_ready()
    assert np.array_equal(np.asarray(r0.alpha), np.asarray(r1.alpha))
    assert trace_fetch(r1.trace)["samples"] > 0


def test_fit_trace_config_is_bit_identical_and_fetched_once():
    from repro.core.dcsvm import DCSVMConfig, fit

    X, y = _problem(n=120, seed=4)
    base = dict(kernel=KERN, C=2.0, k=2, levels=1, m=64, tol=1e-4,
                max_iters=2000, seed=0)
    m0 = fit(DCSVMConfig(**base), X, y)
    m1 = fit(DCSVMConfig(**base, trace=16), X, y)
    assert np.array_equal(np.asarray(m0.alpha), np.asarray(m1.alpha))
    st0, st1 = m0.level_stats[-1], m1.level_stats[-1]
    assert "trace" not in st0                       # default: no trace key
    assert st1["trace_summary"]["samples"] > 0
    assert st1["trace_summary"]["pg_last"] <= st1["trace_summary"]["pg_first"]


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------

def test_span_tree_chrome_trace_schema(tmp_path):
    tracer = SpanTracer()
    with tracer.activate():
        with span("fit"):
            with span("divide/level1/solve"):
                pass
            with span("conquer/solve"):
                pass
    with span("outside"):                           # inactive: not recorded
        pass
    ct = tracer.chrome_trace()
    events = ct["traceEvents"]
    assert [e["name"] for e in events][0] == "fit"
    assert {e["name"] for e in events} == {"fit", "divide/level1/solve",
                                           "conquer/solve"}
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in events)
    assert all(events[i]["ts"] <= events[i + 1]["ts"]
               for i in range(len(events) - 1))
    # parent span covers its children
    fit_ev = next(e for e in events if e["name"] == "fit")
    child_dur = sum(e["dur"] for e in events if e["name"] != "fit")
    assert fit_ev["dur"] >= child_dur * (1 - 1e-6)
    path = tmp_path / "trace.json"
    tracer.write_chrome_trace(str(path))
    assert json.loads(path.read_text())["traceEvents"]
    table = tracer.summary()
    assert "fit" in table and "conquer/solve" in table


def test_span_nesting_restores_active_tracer():
    t1, t2 = SpanTracer(), SpanTracer()
    with t1.activate():
        with span("outer"):
            with t2.activate():
                with span("inner"):
                    pass
            with span("outer2"):
                pass
    assert {s.name for s in t1.roots} == {"outer"}
    assert {s.name for s in t2.roots} == {"inner"}
    assert [c.name for c in t1.roots[0].children] == ["outer2"]


# ---------------------------------------------------------------------------
# serving metrics
# ---------------------------------------------------------------------------

def test_latency_histogram_streaming_stats():
    h = LatencyHistogram()
    vals = [1e-4, 2e-4, 5e-4, 1e-3, 5e-3, 2e-2, 0.5]
    for v in vals:
        h.observe(v)
    assert h.total == len(vals)
    assert h.sum == pytest.approx(sum(vals))
    assert h.vmin == min(vals) and h.vmax == max(vals)
    assert min(vals) <= h.quantile(0.5) <= max(vals)
    assert h.quantile(0.5) <= h.quantile(0.95) <= h.quantile(0.99)
    j = h.to_json()
    assert j["count"] == len(vals)
    assert sum(j["buckets"].values()) == len(vals)
    # an observation past the top bound lands in +Inf
    h.observe(100.0)
    assert h.to_json()["buckets"]["+Inf"] == 1


def test_latency_histogram_empty():
    j = LatencyHistogram().to_json()
    assert j["count"] == 0 and j["p50"] is None and j["buckets"] == {}
    assert math.isnan(LatencyHistogram().quantile(0.5))


def test_metrics_registry_labels_and_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("serve_requests_total", strategy="early").inc(3)
    reg.counter("serve_requests_total", strategy="exact").inc()
    assert reg.counter("serve_requests_total", strategy="early").value == 3
    h = reg.histogram("serve_latency_seconds", strategy="early")
    h.observe(1e-3)
    h.observe(2e-3)
    j = reg.to_json()
    assert j["counters"]['serve_requests_total{strategy="early"}'] == 3
    assert j["counters"]['serve_requests_total{strategy="exact"}'] == 1
    text = reg.to_prometheus_text()
    assert "# TYPE serve_requests_total counter" in text
    assert "# TYPE serve_latency_seconds histogram" in text
    # cumulative buckets: the +Inf bucket equals _count
    inf_line = [l for l in text.splitlines()
                if l.startswith("serve_latency_seconds_bucket")
                and 'le="+Inf"' in l]
    assert inf_line and inf_line[0].split()[-1] == "2"
    assert 'serve_latency_seconds_count{strategy="early"} 2' in text


def test_prometheus_type_lines_not_shared_across_kinds():
    """Regression: ``to_prometheus_text`` used ONE ``seen_types`` set for
    counters and histograms, so a histogram sharing a counter's base name
    lost its ``# TYPE`` line.  Per-kind tracking emits both."""
    reg = MetricsRegistry()
    reg.counter("serve_work").inc(2)
    reg.histogram("serve_work").observe(0.5)     # same base name, other kind
    text = reg.to_prometheus_text()
    assert "# TYPE serve_work counter" in text
    assert "# TYPE serve_work histogram" in text
    # and each exposition family got a HELP line
    assert text.count("# HELP serve_work ") == 2


def test_prometheus_empty_registry_is_empty_string():
    """Regression: an empty registry emitted ``"\\n"`` (one blank line) —
    scrapers treat that differently from "no metrics"."""
    assert MetricsRegistry().to_prometheus_text() == ""


def test_prometheus_help_and_gauge_exposition():
    reg = MetricsRegistry()
    reg.describe("serve_queue_depth", "query rows currently queued")
    g = reg.gauge("serve_queue_depth")
    g.set(7)
    g.inc(2)
    g.dec()
    assert isinstance(g, Gauge) and g.value == 8
    text = reg.to_prometheus_text()
    assert "# HELP serve_queue_depth query rows currently queued" in text
    assert "# TYPE serve_queue_depth gauge" in text
    assert "serve_queue_depth 8" in text
    assert text.endswith("\n")
    # undescribed metrics fall back to the base name as HELP text
    reg.counter("serve_requests_total").inc()
    assert ("# HELP serve_requests_total serve_requests_total"
            in reg.to_prometheus_text())
    # gauges only appear in to_json when present (schema compatibility)
    assert "gauges" in reg.to_json()
    assert MetricsRegistry().to_json().keys() == {"counters", "histograms"}


def test_metrics_registry_dump(tmp_path):
    reg = MetricsRegistry()
    reg.counter("requests_total").inc(5)
    reg.histogram("latency_seconds").observe(0.01)
    jpath = tmp_path / "metrics.json"
    prom = reg.dump(str(jpath))
    assert json.loads(jpath.read_text())["counters"]["requests_total"] == 5
    assert prom.endswith(".prom")
    assert "latency_seconds_bucket" in open(prom).read()


def test_counter_is_plain_int():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5


# ---------------------------------------------------------------------------
# benchmark artifact merge
# ---------------------------------------------------------------------------

def test_emit_json_merge_keeps_other_sections(tmp_path, monkeypatch):
    from benchmarks.common import emit_json

    path = str(tmp_path / "BENCH.json")
    emit_json(path, {"kernels": {"a": 1}})
    emit_json(path, {"outofcore": {"b": 2}}, merge=True)
    d = json.load(open(path))
    assert d["kernels"] == {"a": 1} and d["outofcore"] == {"b": 2}
    # merge replaces a same-named section wholesale
    emit_json(path, {"outofcore": {"c": 3}}, merge=True)
    assert json.load(open(path))["outofcore"] == {"c": 3}
    # a corrupt artifact starts fresh instead of crashing the bench
    with open(path, "w") as f:
        f.write("{not json")
    emit_json(path, {"trace": {"d": 4}}, merge=True)
    assert json.load(open(path))["trace"] == {"d": 4}
