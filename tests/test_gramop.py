"""GramOperator layer (DESIGN.md §12): precision policy parity, base-index
dedup transparency, byte-denominated budgets, and the host-spill solver.

Covers the PR-7 acceptance gates: bf16-vs-f32 parity for every kernel op on
non-tile-aligned mixed-sign shapes; ``compute_dtype`` None/f32 bit-identity;
SVR dedup fit parity; out-of-core fit matching the in-memory fit to 1e-3
relative objective.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig,
    DEFAULT_GRAM_BUDGET,
    EpsilonSVR,
    Kernel,
    auto_num_chunks,
    colcache,
    fit,
    gram_matvec,
    solve_box_qp_matvec,
)
from repro.core.gramop import (
    GramOperator,
    fits_budget,
    resolve_compute_dtype,
    solve_box_qp_spill,
)
from repro.core.solver import objective
from repro.data import gaussian_mixture, sinc1d
from repro.kernels import ops as kops

KERNELS = [
    Kernel("rbf", gamma=0.5),
    Kernel("poly", gamma=0.5, degree=3, coef0=1.0),
    Kernel("linear"),
]
KIDS = [k.kind for k in KERNELS]


def _data(n, m, d, key=0):
    """Mixed-sign, non-tile-aligned data (n, m deliberately not multiples of
    the 8/128-lane tiles)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    X = jax.random.uniform(k1, (n, d), minval=-0.7, maxval=0.7)
    Y = jax.random.uniform(k2, (m, d), minval=-0.7, maxval=0.7)
    return X, Y


def _signs(n, key=3):
    return jnp.where(jax.random.bernoulli(jax.random.PRNGKey(key), 0.5, (n,)),
                     1.0, -1.0)


# ---------------------------------------------------------------------------
# Precision policy: bf16 operand tiles ~ f32 reference; f32 policy is a no-op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kern", KERNELS, ids=KIDS)
def test_kernel_matrix_bf16_parity(kern):
    X, Y = _data(100, 53, 9)
    ref = kops.kernel_matrix(X, Y, kern, bm=64, bn=64)
    low = kops.kernel_matrix(X, Y, kern, bm=64, bn=64,
                             compute_dtype="bfloat16")
    assert low.dtype == jnp.float32          # f32 accumulation policy
    np.testing.assert_allclose(np.asarray(low), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("kern", KERNELS, ids=KIDS)
def test_kernel_matvec_bf16_parity(kern):
    X, Z = _data(75, 41, 9, key=1)
    v = jax.random.normal(jax.random.PRNGKey(7), (41,))
    ref = kops.kernel_matvec(X, Z, v, kern)
    low = kops.kernel_matvec(X, Z, v, kern, compute_dtype="bfloat16")
    np.testing.assert_allclose(np.asarray(low), np.asarray(ref),
                               rtol=5e-2, atol=5e-2 * float(jnp.sum(jnp.abs(v))))


@pytest.mark.parametrize("kern", KERNELS, ids=KIDS)
def test_q_rows_bf16_parity(kern):
    X, _ = _data(90, 1, 9, key=2)
    y = _signs(90)
    idx = jnp.asarray([3, 17, 41, 88])
    ref = kops.q_rows(X, y, X[idx], y[idx], kern, bm=64, bn=64)
    low = kops.q_rows(X, y, X[idx], y[idx], kern, bm=64, bn=64,
                      compute_dtype="bfloat16")
    np.testing.assert_allclose(np.asarray(low), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("kern", KERNELS, ids=KIDS)
def test_cd_column_update_bf16_parity(kern):
    X, _ = _data(85, 1, 9, key=4)
    y = _signs(85)
    idx = jnp.asarray([0, 12, 60])
    w = jnp.asarray([0.3, -0.2, 0.5]) * y[idx]
    ref = kops.cd_column_update(X, y, X[idx], w, kern)
    low = kops.cd_column_update(X, y, X[idx], w, kern,
                                compute_dtype="bfloat16")
    np.testing.assert_allclose(np.asarray(low), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("kern", KERNELS, ids=KIDS)
def test_pairwise_bf16_parity(kern):
    X, Y = _data(64, 37, 9, key=5)
    ref = kern.pairwise(X, Y)
    low = kern.pairwise(X, Y, compute_dtype="bfloat16")
    assert low.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(low), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("kern", KERNELS, ids=KIDS)
def test_f32_policy_is_bit_identical(kern):
    """``compute_dtype`` None / "float32" produce the SAME arrays: the
    policy normalizes away (no cast nodes), keeping pre-policy trajectories
    bit-exact — the acceptance gate for the default config."""
    X, Y = _data(70, 33, 9, key=6)
    y = _signs(70, key=8)
    v = jax.random.normal(jax.random.PRNGKey(9), (70,))
    for cd in (None, "float32"):
        assert resolve_compute_dtype(cd, X.dtype) is None
    np.testing.assert_array_equal(
        np.asarray(kern.pairwise(X, Y, compute_dtype="float32")),
        np.asarray(kern.pairwise(X, Y)))
    np.testing.assert_array_equal(
        np.asarray(kops.kernel_matrix(X, Y, kern, compute_dtype="float32")),
        np.asarray(kops.kernel_matrix(X, Y, kern)))
    np.testing.assert_array_equal(
        np.asarray(gram_matvec(kern, X, v, compute_dtype="float32")),
        np.asarray(gram_matvec(kern, X, v)))


# ---------------------------------------------------------------------------
# Base-indexed dedup view: sign expansion is exactly the 2n-wide operator
# ---------------------------------------------------------------------------

def _svr_ops(n=57, d=6, use_pallas=False, kern=KERNELS[0]):
    Xb, _ = _data(n, 1, d, key=10)
    bidx = jnp.concatenate([jnp.arange(n), jnp.arange(n)]).astype(jnp.int32)
    s = jnp.concatenate([jnp.ones(n), -jnp.ones(n)])
    Xd = Xb[bidx]
    full = GramOperator(Xd=Xd, s=s, kernel=kern, use_pallas=use_pallas)
    dd = GramOperator(Xd=Xd, s=s, Xb=Xb, bidx=bidx, kernel=kern,
                      use_pallas=use_pallas)
    return full, dd


@pytest.mark.parametrize("use_pallas", [False, True], ids=["xla", "pallas"])
def test_dedup_q_rows_matches_full(use_pallas):
    full, dd = _svr_ops(use_pallas=use_pallas)
    assert dd.dedup and dd.kwidth == full.kwidth // 2
    idx = jnp.asarray([0, 5, 57, 90, 113])   # both mirror halves
    np.testing.assert_array_equal(np.asarray(dd.q_rows(idx)),
                                  np.asarray(full.q_rows(idx)))
    np.testing.assert_array_equal(np.asarray(dd.q_block(idx)),
                                  np.asarray(full.q_block(idx)))
    np.testing.assert_array_equal(np.asarray(dd.qbb(idx)),
                                  np.asarray(full.qbb(idx)))
    # mirrored coordinates share one cache key (the raw row dedup)
    keys = np.asarray(dd.cache_keys(jnp.asarray([3, 3 + 57])))
    assert keys[0] == keys[1] == 3


def test_dedup_matvec_and_col_update():
    full, dd = _svr_ops()
    v = jax.random.normal(jax.random.PRNGKey(11), (dd.n_dual,))
    # default matvec path ignores dedup entirely -> bit-identical
    np.testing.assert_array_equal(np.asarray(dd.matvec(v, num_chunks=4)),
                                  np.asarray(full.matvec(v, num_chunks=4)))
    # via_base re-associates the sum: equal to fp tolerance, 4x fewer evals
    np.testing.assert_allclose(
        np.asarray(dd.matvec(v, num_chunks=4, via_base=True)),
        np.asarray(full.matvec(v, num_chunks=4)), rtol=1e-5, atol=1e-5)
    g = jnp.zeros(dd.n_dual)
    idx = jnp.asarray([2, 59, 100])
    delta = jnp.asarray([0.4, -0.1, 0.25])
    np.testing.assert_allclose(np.asarray(dd.col_update(g, idx, delta)),
                               np.asarray(full.col_update(g, idx, delta)),
                               rtol=1e-6, atol=1e-6)


def test_storage_dtype_and_budget():
    _, dd = _svr_ops()
    assert dd.storage_dtype(jnp.float32) == jnp.dtype(jnp.float32)
    low = dataclasses.replace(dd, compute_dtype="bfloat16")
    assert low.storage_dtype(jnp.float32) == jnp.dtype(jnp.bfloat16)
    assert fits_budget(4, 16, jnp.float32)
    assert not fits_budget(5, 16, jnp.float32)
    assert fits_budget(8, 16, jnp.bfloat16)   # bf16 fits 2x the rows


# ---------------------------------------------------------------------------
# Byte-denominated chunking
# ---------------------------------------------------------------------------

def test_auto_num_chunks_budget():
    # default budget == historical 2**27 f32 slots -> tiny problems: 1 chunk
    assert auto_num_chunks(512, 512) == 1
    # exactly 4 budget-sized row blocks
    assert auto_num_chunks(1024, 256, budget_bytes=1024 * 256) == 4
    # never more chunks than rows
    assert auto_num_chunks(8, 10 ** 9, budget_bytes=1) == 8


def test_gram_matvec_auto_chunks_bit_identical():
    """Chunk count only partitions output rows — any choice is bit-exact."""
    X, _ = _data(130, 1, 7, key=12)
    v = jax.random.normal(jax.random.PRNGKey(13), (130,))
    kern = KERNELS[0]
    ref = gram_matvec(kern, X, v, num_chunks=8)
    np.testing.assert_array_equal(np.asarray(gram_matvec(kern, X, v)),
                                  np.asarray(ref))
    np.testing.assert_array_equal(
        np.asarray(gram_matvec(kern, X, v, budget_bytes=130 * 7 * 4)),
        np.asarray(ref))


# ---------------------------------------------------------------------------
# Column-cache eviction accounting
# ---------------------------------------------------------------------------

def test_colcache_eviction_counter():
    cache = colcache.init(2, 6)
    rows = jnp.ones((2, 6))
    served = jnp.asarray(False)

    def insert(cache, ids):
        idx = jnp.asarray(ids, jnp.int32)
        slots, hit = colcache.lookup(cache, idx)
        return colcache.update(cache, idx, rows, served, slots, hit)

    cache = insert(cache, [0, 1])            # fills empty slots
    assert int(cache.evictions) == 0
    cache = insert(cache, [2, 3])            # displaces live rows 0, 1
    assert int(cache.evictions) == 2
    assert int(cache.misses) == 4 and int(cache.hits) == 0


# ---------------------------------------------------------------------------
# Host-spill out-of-core solver
# ---------------------------------------------------------------------------

def test_spill_solver_matches_in_memory():
    n, d, C = 160, 6, 1.0
    X, _ = _data(n, 1, d, key=14)
    y = _signs(n, key=15)
    kern = KERNELS[0]
    ref = solve_box_qp_matvec(X, y, kern, C, tol=1e-4, max_iters=20_000,
                              block=16, use_pallas=False)
    op = GramOperator(Xd=X, s=y, kernel=kern, use_pallas=False)
    # budget sized to ~48 rows/panel -> 4 panels, device LRU capacity 1
    res = solve_box_qp_spill(op, C, tol=1e-4, max_iters=20_000, block=16,
                             device_budget_bytes=48 * n * 4)
    assert float(res.pg_max) <= 1e-4
    f_ref = float(objective(ref.alpha, ref.grad))
    f_sp = float(objective(res.alpha, res.grad))
    assert abs(f_sp - f_ref) <= 1e-3 * (1 + abs(f_ref))
    # tier counters: panels were computed, written to host, and re-served
    assert int(res.spills) >= 4
    assert int(res.spill_hits) > 0
    assert int(res.cache_evictions) > 0


def test_spill_solver_dedup_svr_dual():
    """Out-of-core + dedup: the 2n SVR dual spills n-wide raw-row panels."""
    n = 90
    X, y = sinc1d(jax.random.PRNGKey(16), n, noise=0.05)
    kern = Kernel("rbf", gamma=2.0)
    td = EpsilonSVR(eps=0.05).build(X, y, 2.0)
    Xb, bidx = td.base_view()
    op = GramOperator(Xd=td.Xd, s=td.S[0], Xb=Xb, bidx=bidx, kernel=kern,
                      use_pallas=False)
    ref = solve_box_qp_matvec(td.Xd, td.S[0], kern, td.Cvec[0], tol=1e-4,
                              max_iters=20_000, block=16, p=td.P[0])
    res = solve_box_qp_spill(op, td.Cvec[0], tol=1e-4, max_iters=20_000,
                             block=16, p=td.P[0],
                             device_budget_bytes=40 * n * 4)
    f_ref = float(objective(ref.alpha, ref.grad, p=td.P[0]))
    f_sp = float(objective(res.alpha, res.grad, p=td.P[0]))
    assert abs(f_sp - f_ref) <= 1e-3 * (1 + abs(f_ref))


# ---------------------------------------------------------------------------
# End-to-end fits through the driver
# ---------------------------------------------------------------------------

def _cls_data(n=240, key=17):
    return gaussian_mixture(jax.random.PRNGKey(key), n, d=8,
                            modes_per_class=4, spread=0.15)


def test_fit_host_spill_matches_in_memory():
    from repro.core import objective_value

    X, y = _cls_data()
    kern = Kernel("rbf", gamma=4.0)
    base = dict(kernel=kern, C=2.0, k=2, levels=1, m=100, tol=1e-4,
                kmeans_iters=8, use_pallas=False,
                gram_budget=65_536)          # < n^2 f32 -> no dense fallback
    m_mem = fit(DCSVMConfig(**base), X, y)
    m_sp = fit(DCSVMConfig(**base, host_spill=True), X, y)
    f_mem = float(objective_value(m_mem.config, X, y, m_mem.alpha))
    f_sp = float(objective_value(m_mem.config, X, y, m_sp.alpha))
    assert abs(f_sp - f_mem) <= 1e-3 * (1 + abs(f_mem))
    st = m_sp.level_stats[-1]
    assert st.get("spills", 0) > 0 and st.get("spill_hits", 0) > 0


@pytest.mark.parametrize("budget", [DEFAULT_GRAM_BUDGET, 131_072],
                         ids=["dense", "matvec"])
def test_fit_svr_dedup_parity(budget):
    """gram_dedup on/off is decision-function-transparent on both the dense
    (gathered base Gram) and matvec (base-row cache) level-0 paths."""
    n = 150
    X, y = sinc1d(jax.random.PRNGKey(18), n, noise=0.03)
    kern = Kernel("rbf", gamma=2.0)
    base = dict(kernel=kern, C=2.0, k=2, levels=1, m=80, tol=1e-4,
                kmeans_iters=8, use_pallas=False, gram_budget=budget)
    m_dd = fit(DCSVMConfig(**base), X, y, task=EpsilonSVR(eps=0.05))
    m_full = fit(DCSVMConfig(**base, gram_dedup=False), X, y,
                 task=EpsilonSVR(eps=0.05))
    np.testing.assert_allclose(np.asarray(m_dd.beta), np.asarray(m_full.beta),
                               rtol=1e-6, atol=1e-6)


def test_fit_bf16_end_to_end():
    """A bf16-policy fit trains a real classifier (the policy composes with
    the whole pipeline, not just isolated kernels)."""
    from repro.core import accuracy, predict_exact

    X, y = _cls_data(key=19)
    kern = Kernel("rbf", gamma=4.0)
    base = dict(kernel=kern, C=2.0, k=2, levels=1, m=100, tol=1e-3,
                kmeans_iters=8, use_pallas=False)
    m32 = fit(DCSVMConfig(**base), X, y)
    m16 = fit(DCSVMConfig(**base, compute_dtype="bfloat16"), X, y)
    acc32 = accuracy(y, predict_exact(m32, X))
    acc16 = accuracy(y, predict_exact(m16, X))
    assert acc16 >= acc32 - 0.05
