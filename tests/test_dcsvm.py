"""Integration tests for multilevel DC-SVM (paper Algorithm 1 + Theorems)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig,
    Kernel,
    accuracy,
    fit,
    gram,
    kkt_residual,
    objective_value,
    predict_early,
    predict_exact,
    solve_with_shrinking,
)
from repro.core.bounds import d_pi, theorem1_bound
from repro.data import gaussian_mixture, checkerboard, train_test_split


KERN = Kernel("rbf", gamma=8.0)


def _dataset(n=1200, key=0):
    X, y = gaussian_mixture(jax.random.PRNGKey(key), n, d=8, modes_per_class=4,
                            spread=0.15)
    return train_test_split(jax.random.PRNGKey(key + 1), X, y)


def _full_Q(X, y, kern=KERN):
    K = gram(kern, X, X)
    return (y[:, None] * y[None, :]) * K


def test_dcsvm_reaches_exact_objective():
    Xtr, ytr, _, _ = _dataset()
    C = 4.0
    Q = _full_Q(Xtr, ytr)
    exact = solve_with_shrinking(Q, C, tol=1e-4, max_iters=300_000)
    f_exact = 0.5 * exact.alpha @ Q @ exact.alpha - exact.alpha.sum()

    cfg = DCSVMConfig(kernel=KERN, C=C, k=4, levels=2, m=300, tol=1e-4)
    model = fit(cfg, Xtr, ytr)
    f_dc = 0.5 * model.alpha @ Q @ model.alpha - model.alpha.sum()
    # paper's criterion: relative objective error under 1e-3 at matched tol
    assert abs(float(f_dc - f_exact)) <= 1e-3 * abs(float(f_exact))
    assert float(kkt_residual(Q, model.alpha, C)) <= 1e-3


def test_theorem1_bound_holds():
    """0 <= f(a_bar) - f(a*) <= 0.5 C^2 D(pi)  (paper Thm 1 / Fig 1)."""
    Xtr, ytr, _, _ = _dataset(800, key=5)
    C = 2.0
    Q = _full_Q(Xtr, ytr)
    exact = solve_with_shrinking(Q, C, tol=1e-5, max_iters=300_000)
    f_star = float(0.5 * exact.alpha @ Q @ exact.alpha - exact.alpha.sum())

    # a_bar: solve each cluster independently (single level, no conquer)
    cfg = DCSVMConfig(kernel=KERN, C=C, k=4, levels=1, m=300, tol=1e-5,
                      early_stop_level=1)
    model = fit(cfg, Xtr, ytr)
    f_bar = float(0.5 * model.alpha @ Q @ model.alpha - model.alpha.sum())
    bound = theorem1_bound(KERN, Xtr, jnp.asarray(model.partition.assign), C)
    gap = f_bar - f_star
    assert gap >= -1e-3 * abs(f_star)          # f(a_bar) >= f(a*)
    assert gap <= bound + 1e-3 * abs(f_star)   # Thm 1 upper bound


def test_sv_propagation_across_levels():
    """Theorem 2 in practice: lower-level SVs approximately contain the final
    SV set (high recall of final SVs among level-1 SVs)."""
    Xtr, ytr, _, _ = _dataset(1000, key=9)
    C = 4.0
    sv_sets = {}

    def cb(level, alpha, st):
        sv_sets[level] = set(np.nonzero(np.asarray(alpha) > 0)[0].tolist())

    cfg = DCSVMConfig(kernel=KERN, C=C, k=4, levels=2, m=300, tol=1e-4)
    fit(cfg, Xtr, ytr, callback=cb)
    final = sv_sets[0]
    lvl1 = sv_sets[1]
    recall = len(final & lvl1) / max(len(final), 1)
    assert recall > 0.9


def test_early_stop_returns_partitioned_model():
    Xtr, ytr, Xte, yte = _dataset(1000, key=3)
    cfg = DCSVMConfig(kernel=KERN, C=4.0, k=4, levels=2, m=300, tol=1e-3,
                      early_stop_level=1)
    model = fit(cfg, Xtr, ytr)
    assert model.is_early and model.partition is not None
    acc = accuracy(yte, predict_early(model, Xte))
    assert acc > 0.9


def test_multilevel_warm_start_speeds_final_solve():
    """The conquer step with warm start takes far fewer CD iterations than
    solving from zero (the paper's core speed claim)."""
    Xtr, ytr, _, _ = _dataset(1200, key=13)
    C = 4.0
    Q = _full_Q(Xtr, ytr)
    cold = solve_with_shrinking(Q, C, tol=1e-4, max_iters=300_000)

    iters_final = {}

    def cb(level, alpha, st):
        if level == 0:
            iters_final["iters"] = st["iters"]

    cfg = DCSVMConfig(kernel=KERN, C=C, k=4, levels=2, m=300, tol=1e-4)
    fit(cfg, Xtr, ytr, callback=cb)
    assert iters_final["iters"] < int(cold.iters) * 0.5


def test_checkerboard_accuracy():
    """Non-linearly-separable data: kernel machinery actually matters."""
    X, y = checkerboard(jax.random.PRNGKey(21), 1600, cells=3)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(22), X, y)
    kern = Kernel("rbf", gamma=40.0)
    cfg = DCSVMConfig(kernel=kern, C=16.0, k=4, levels=1, m=400, tol=1e-3)
    model = fit(cfg, Xtr, ytr)
    assert accuracy(yte, predict_exact(model, Xte)) > 0.90


def test_polynomial_kernel_path():
    Xtr, ytr, Xte, yte = _dataset(800, key=31)
    kern = Kernel("poly", gamma=1.0, degree=3)
    cfg = DCSVMConfig(kernel=kern, C=1.0, k=4, levels=1, m=300, tol=1e-3)
    model = fit(cfg, Xtr, ytr)
    Q = _full_Q(Xtr, ytr, kern)
    assert float(kkt_residual(Q, model.alpha, 1.0)) <= 1e-2
    assert accuracy(yte, predict_exact(model, Xte)) > 0.85


def _early_reference(model, Xq):
    """Per-query reference for eq. 11: score against the assigned cluster's
    members with a plain host-side loop."""
    from repro.core import assign_points

    kern = model.config.kernel
    cid, _ = assign_points(kern, model.partition.model, Xq)
    w = np.asarray(model.alpha * model.y)
    out = []
    for i in range(Xq.shape[0]):
        c = int(cid[i])
        mem = model.partition.idx[c][model.partition.mask[c]]
        out.append(float(kern.pairwise(Xq[i][None], model.X[mem])[0]
                         @ jnp.asarray(w[mem])))
    return np.asarray(out)


def test_decision_early_no_host_sync():
    """Regression: the serving hot path must never force a device-to-host
    transfer (the pre-fix code synced on ``int(jnp.sum(~keep))`` on EVERY
    call, overflow or not)."""
    from repro.core import decision_early

    Xtr, ytr, Xte, _ = _dataset(800, key=23)
    cfg = DCSVMConfig(kernel=KERN, C=4.0, k=4, levels=1, m=200, tol=1e-3,
                      early_stop_level=1)
    model = fit(cfg, Xtr, ytr)
    out_warm = decision_early(model, Xte)          # compile outside the guard
    with jax.transfer_guard_device_to_host("disallow"):
        out = decision_early(model, Xte)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_warm))
    np.testing.assert_allclose(np.asarray(out), _early_reference(model, Xte),
                               atol=1e-4)


def test_decision_early_overflow_path():
    """Regression: queries beyond a cluster's buffer capacity must be scored
    exactly (extra on-device rounds), not dropped or collided into slot 0."""
    from repro.core import decision_early

    Xtr, ytr, _, _ = _dataset(800, key=25)
    cfg = DCSVMConfig(kernel=KERN, C=4.0, k=4, levels=1, m=200, tol=1e-3,
                      early_stop_level=1)
    model = fit(cfg, Xtr, ytr)
    # route every query to ONE cluster: cap = 2 * nq / k < nq forces overflow
    anchor = model.X[0]
    Xq = anchor[None, :] + 0.01 * jax.random.normal(jax.random.PRNGKey(0),
                                                    (64, Xtr.shape[1]))
    Xq = Xq.astype(Xtr.dtype)
    from repro.core import assign_points
    cid, _ = assign_points(KERN, model.partition.model, Xq)
    counts = np.bincount(np.asarray(cid), minlength=model.partition.k)
    from repro.core import early_capacity
    assert counts.max() > early_capacity(64, model.partition.k), \
        "test setup must overflow the per-cluster buffer"
    with jax.transfer_guard_device_to_host("disallow"):
        out = decision_early(model, Xq)
    np.testing.assert_allclose(np.asarray(out), _early_reference(model, Xq),
                               atol=1e-4)


def test_objective_value_matches_dense():
    Xtr, ytr, _, _ = _dataset(400, key=41)
    cfg = DCSVMConfig(kernel=KERN, C=2.0)
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (Xtr.shape[0],))) * 0.1
    Q = _full_Q(Xtr, ytr)
    f_dense = float(0.5 * a @ Q @ a - a.sum())
    f_chunk = float(objective_value(cfg, Xtr, ytr, a))
    assert abs(f_dense - f_chunk) < 1e-3 * (1 + abs(f_dense))
