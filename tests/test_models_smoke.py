"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness asserts; decode-path consistency for each family."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.models import lm as LM
from repro.models import model as M
from repro.models.param import init_tree

B, S = 2, 32


def _params(cfg, seed=0):
    return init_tree(M.build_decls_any(cfg), jax.random.PRNGKey(seed),
                     jnp.dtype(cfg.param_dtype))


def _batch(cfg, key=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    if cfg.enc_dec:
        return {
            "frames": jax.random.normal(k1, (B, cfg.enc_frames, cfg.d_model)) * 0.1,
            "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab),
            "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab),
        }
    batch = {"tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab)}
    batch["targets"] = jax.random.randint(k1, (B, S), 0, cfg.vocab)
    if cfg.num_patches > 0:
        batch["prefix_embeds"] = jax.random.normal(
            k1, (B, cfg.num_patches, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = _params(cfg)
    batch = _batch(cfg)

    def loss(p):
        return M.loss_fn(cfg, p, batch, chunk=16)[0]

    l, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l)), (arch, float(l))
    # a cold model's CE should be ~log(vocab)
    assert float(l) < np.log(cfg.vocab) * 2.5 + 5.0
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_runs(arch):
    cfg = get_config(arch, reduced=True)
    params = _params(cfg)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         M.cache_decls_any(cfg, B, S))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = M.decode_step_any(cfg, params, cache, tok,
                                       jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen3_8b", "gemma_2b", "jamba_v01_52b",
                                  "xlstm_125m", "deepseek_moe_16b"])
def test_prefill_decode_matches_forward(arch):
    """Cache correctness: prefill S-1 tokens then decode token S-1 must
    reproduce the full-forward logits at the last position."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        # exactness requires no token drops: capacity == all tokens
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts) / cfg.moe.top_k))
    params = _params(cfg)
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    full_logits, _, _ = LM.forward(cfg, params, tokens, chunk=16, mode="train")
    want = full_logits[:, -1, :]

    _, cache = M.forward_prefill(cfg, params, {"tokens": tokens[:, : S - 1]},
                                 S_max=S, chunk=16)
    # pad attention caches from S-1 to S slots
    def pad_cache(sds, arr):
        pads = [(0, a - b) for a, b in zip(sds.shape, arr.shape)]
        return jnp.pad(arr, pads)

    target = M.cache_decls_any(cfg, B, S)
    cache = jax.tree.map(pad_cache, target, cache)
    got_logits, _ = M.decode_step_any(cfg, params, cache, tokens[:, -1:],
                                      jnp.asarray(S - 1, jnp.int32))
    got = got_logits[:, 0, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_whisper_prefill_decode_consistency():
    cfg = get_config("whisper_medium", reduced=True)
    params = _params(cfg)
    key = jax.random.PRNGKey(4)
    frames = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model)) * 0.1
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    from repro.models import encdec as ED
    enc = ED.encode(cfg, params, frames, chunk=16)
    full = ED.decode_train(cfg, params, enc, tokens, chunk=16)
    want = np.asarray(full[:, -1, :], np.float32)

    _, cache = ED.prefill(cfg, params, frames, tokens[:, : S - 1], S_max=S, chunk=16)
    got_logits, _ = ED.decode_step(cfg, params, cache, tokens[:, -1:],
                                   jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(got_logits[:, 0, :], np.float32),
                               want, rtol=2e-3, atol=2e-3)


def test_moe_aux_losses_present():
    cfg = get_config("phi35_moe_42b", reduced=True)
    params = _params(cfg)
    batch = _batch(cfg)
    loss, metrics = M.loss_fn(cfg, params, batch, chunk=16)
    assert "moe_lb" in metrics and np.isfinite(float(metrics["moe_lb"]))
    assert float(metrics["moe_drop_frac"]) < 0.5


def test_full_configs_param_counts():
    """Full configs match the published sizes (sanity on the exact configs)."""
    expect = {
        "jamba_v01_52b": (45e9, 56e9),
        "qwen3_8b": (7.5e9, 8.5e9),
        "gemma_2b": (2.2e9, 2.8e9),
        "yi_6b": (5.5e9, 6.5e9),
        "deepseek_moe_16b": (15e9, 17.5e9),
        "phi35_moe_42b": (40e9, 43e9),
        "whisper_medium": (0.7e9, 0.85e9),
        "xlstm_125m": (0.1e9, 0.25e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
