"""Tests for one-vs-all multiclass DC-SVM (shared-partition class batching)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig,
    Kernel,
    accuracy_multiclass,
    fit,
    fit_ova,
    labels_to_ova,
    predict_bcm_ova,
    predict_early_ova,
    predict_exact_ova,
)
from repro.core.predict import decision_exact_ova
from repro.data import gaussian_mixture_multiclass, train_test_split


def _dataset(n=900, n_classes=3, key=0, d=8):
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(key), n,
                                       n_classes=n_classes, d=d)
    return train_test_split(jax.random.PRNGKey(key + 1), X, y)


def test_labels_to_ova_roundtrip():
    y = jnp.asarray([2, 0, 1, 1, 2, 0])
    classes, Y = labels_to_ova(y)
    assert list(classes) == [0, 1, 2]
    assert Y.shape == (3, 6)
    # exactly one +1 per column, at the row of the true class
    np.testing.assert_array_equal(np.asarray(jnp.argmax(Y, axis=0)),
                                  np.asarray(y))
    np.testing.assert_array_equal(np.asarray(jnp.sum(Y == 1.0, axis=0)),
                                  np.ones(6))


def test_labels_to_ova_explicit_n_classes():
    """With n_classes the class set is exactly 0..n_classes-1: absent classes
    get an all-negative machine and labels outside the range are rejected
    (regression: padding once duplicated observed non-contiguous labels)."""
    classes, Y = labels_to_ova(jnp.asarray([0, 2, 0, 2]), n_classes=4)
    assert list(classes) == [0, 1, 2, 3]
    assert Y.shape == (4, 4)
    np.testing.assert_array_equal(np.asarray(Y[1]), -np.ones(4))
    np.testing.assert_array_equal(np.asarray(Y[3]), -np.ones(4))
    np.testing.assert_array_equal(np.asarray(Y[2]), [-1, 1, -1, 1])
    with pytest.raises(ValueError):
        labels_to_ova(jnp.asarray([0, 4]), n_classes=3)
    with pytest.raises(ValueError):
        labels_to_ova(np.asarray([0.5, 1.0]), n_classes=2)


@pytest.mark.parametrize("kern", [
    Kernel("rbf", gamma=8.0),
    Kernel("poly", gamma=1.0, degree=3),
    Kernel("linear"),
], ids=["rbf", "poly", "linear"])
def test_ova_matches_per_class_binary_fit(kern):
    """Parity: the class-stacked vmapped solve must produce the same machines
    as n_classes independent binary ``fit`` calls on the same data (the
    partition is label-independent, so with adaptive sampling off the two
    paths see identical subproblems)."""
    Xtr, ytr, _, _ = _dataset(500, key=7)
    cfg = DCSVMConfig(kernel=kern, C=2.0, k=3, levels=1, m=200, tol=1e-4,
                      adaptive=False, refine=False)
    mc = fit_ova(cfg, Xtr, ytr)
    assert mc.alpha.shape == (3, Xtr.shape[0])
    for c in range(mc.n_classes):
        mb = fit(cfg, Xtr, mc.Y[c])
        np.testing.assert_allclose(np.asarray(mc.alpha[c]),
                                   np.asarray(mb.alpha), atol=5e-3)
        # same dual objective to solver tolerance
        from repro.core import gram
        K = gram(kern, Xtr, Xtr)
        Q = (mc.Y[c][:, None] * mc.Y[c][None, :]) * K
        f_ova = float(0.5 * mc.alpha[c] @ Q @ mc.alpha[c] - mc.alpha[c].sum())
        f_bin = float(0.5 * mb.alpha @ Q @ mb.alpha - mb.alpha.sum())
        assert abs(f_ova - f_bin) <= 1e-3 * (abs(f_bin) + 1e-6)


def test_ova_three_class_accuracy_exact_and_early():
    """Acceptance: >= 95% accuracy on a 3-class mixture via the exact OVA
    decision and via the early (clustered, eq. 11) path."""
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), 1200,
                                       n_classes=3, d=8, spread=0.10)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    kern = Kernel("rbf", gamma=16.0)
    cfg = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=2, m=300, tol=1e-3)
    mc = fit_ova(cfg, Xtr, ytr)
    assert accuracy_multiclass(yte, predict_exact_ova(mc, Xte)) >= 0.95
    assert mc.partition is not None
    assert accuracy_multiclass(yte, predict_early_ova(mc, Xte)) >= 0.95


def test_ova_early_stop_and_bcm():
    Xtr, ytr, Xte, yte = _dataset(1000, key=3)
    kern = Kernel("rbf", gamma=8.0)
    cfg = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=2, m=300, tol=1e-3,
                      early_stop_level=1)
    mc = fit_ova(cfg, Xtr, ytr)
    assert mc.is_early and mc.partition is not None
    assert accuracy_multiclass(yte, predict_early_ova(mc, Xte)) >= 0.9
    assert accuracy_multiclass(yte, predict_bcm_ova(mc, Xte)) >= 0.9


def test_ova_binary_view_matches_exact_decision():
    """MulticlassModel.binary(c) exposes class-c's machine as a DCSVMModel
    whose decision values equal column c of the OVA decision matrix."""
    from repro.core import decision_exact

    Xtr, ytr, Xte, _ = _dataset(500, key=11)
    kern = Kernel("rbf", gamma=8.0)
    cfg = DCSVMConfig(kernel=kern, C=2.0, k=3, levels=1, m=200, tol=1e-3)
    mc = fit_ova(cfg, Xtr, ytr)
    scores = decision_exact_ova(mc, Xte)
    for c in range(mc.n_classes):
        f_c = decision_exact(mc.binary(c), Xte)
        np.testing.assert_allclose(np.asarray(scores[:, c]), np.asarray(f_c),
                                   atol=1e-4)


def test_ova_gram_budget_fallback_matches_vmapped():
    """The sequential lax.map sweep taken when the class-stacked cluster
    Grams exceed gram_budget must produce the same solution as the vmapped
    path (regression: the fallback crashed — lax.map passes ONE tuple arg)."""
    Xtr, ytr, _, _ = _dataset(400, key=17)
    kern = Kernel("rbf", gamma=8.0)
    base = dict(kernel=kern, C=2.0, k=3, levels=1, m=150, tol=1e-3,
                adaptive=False, refine=False)
    mc_big = fit_ova(DCSVMConfig(**base), Xtr, ytr)
    mc_small = fit_ova(DCSVMConfig(**base, gram_budget=64), Xtr, ytr)
    np.testing.assert_allclose(np.asarray(mc_small.alpha),
                               np.asarray(mc_big.alpha), atol=5e-3)


def test_ova_cost_vectors_construction():
    """ova_cost_vectors: machine c's box is C*w_c on its positive side and C
    elsewhere; dict and array forms agree; bad inputs are rejected."""
    from repro.core import labels_to_ova, ova_cost_vectors

    classes, Y = labels_to_ova(jnp.asarray([0, 1, 2, 0]))
    cv = ova_cost_vectors(Y, 2.0, {0: 5.0}, classes)
    np.testing.assert_allclose(np.asarray(cv[0]), [10.0, 2.0, 2.0, 10.0])
    np.testing.assert_allclose(np.asarray(cv[1]), [2.0, 2.0, 2.0, 2.0])
    cv2 = ova_cost_vectors(Y, 2.0, [5.0, 1.0, 1.0], classes)
    np.testing.assert_allclose(np.asarray(cv2), np.asarray(cv))
    with pytest.raises(ValueError):
        ova_cost_vectors(Y, 2.0, {7: 3.0}, classes)
    with pytest.raises(ValueError):
        ova_cost_vectors(Y, 2.0, [1.0, 2.0], classes)


def test_weighted_ova_improves_minority_recall():
    """ROADMAP item: per-class cost vectors through fit_ova.  On a heavily
    imbalanced, overlapping 3-class mixture the plain OVA abandons the
    minority class; upweighting its machine's positive box buys recall back
    without collapsing the majority classes."""
    from repro.core import predict_exact_ova
    from repro.data import stratified_split

    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), 4000,
                                       n_classes=3, d=8, spread=0.45)
    Xn, yn = np.asarray(X), np.asarray(y)
    keep = (yn != 0) | (np.random.default_rng(0).uniform(size=len(yn)) < 0.06)
    Xtr, ytr, Xte, yte = stratified_split(
        jax.random.PRNGKey(1), jnp.asarray(Xn[keep]), jnp.asarray(yn[keep]),
        test_frac=0.25)
    cfg = DCSVMConfig(kernel=Kernel("rbf", gamma=0.5), C=1.0, k=3, levels=1,
                      m=300, tol=1e-3, kmeans_iters=8, use_pallas=False)
    plain = fit_ova(cfg, Xtr, ytr)
    weighted = fit_ova(cfg, Xtr, ytr, class_weight={0: 20.0})
    pred_plain = np.asarray(predict_exact_ova(plain, Xte))
    pred_weighted = np.asarray(predict_exact_ova(weighted, Xte))

    def per_class_recall(pred, c):
        mask = np.asarray(yte) == c
        return float(np.mean(pred[mask] == c))

    rec_plain = per_class_recall(pred_plain, 0)
    rec_weighted = per_class_recall(pred_weighted, 0)
    assert rec_plain <= 0.1, rec_plain           # the failure mode is real
    assert rec_weighted >= rec_plain + 0.25, (rec_weighted, rec_plain)
    # majority classes must not collapse
    assert per_class_recall(pred_weighted, 1) >= 0.7
    assert per_class_recall(pred_weighted, 2) >= 0.7


def test_ova_sv_union_covers_class_svs():
    Xtr, ytr, _, _ = _dataset(500, key=13)
    cfg = DCSVMConfig(kernel=Kernel("rbf", gamma=8.0), C=2.0, k=3, levels=1,
                      m=200, tol=1e-3)
    mc = fit_ova(cfg, Xtr, ytr)
    union = set(mc.sv_union.tolist())
    for c in range(mc.n_classes):
        assert set(mc.binary(c).sv_index.tolist()) <= union
