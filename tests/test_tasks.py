"""Task-abstraction tests: the generalized dual engine (ISSUE-3).

(a) hinge equivalence — the generalized (p, s, cvec) solver path with
    explicit vector arguments reproduces the scalar hinge path to <= 1e-6
    on all three kernel kinds, for every solver variant;
(b) tiny-problem epsilon-SVR correctness vs. an independent dense reference
    QP solve (scipy L-BFGS-B on the box QP), KKT residual at tolerance and
    the eps-tube property |f(x_i) - y_i| < eps  =>  beta_i = 0;
(c) weighted C-SVC recovers minority-class recall on the imbalanced
    mixture generator;
plus end-to-end SVR through ``fit`` (multilevel, warm-started) and the
beta-form serving export.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    CSVC,
    DCSVMConfig,
    EpsilonSVR,
    Kernel,
    WeightedCSVC,
    fit,
    kkt_residual,
    mae,
    mse,
    predict_early,
    predict_exact,
    proj_grad,
    recall,
    solve_box_qp,
    solve_box_qp_block,
    solve_box_qp_matvec,
    solve_with_shrinking,
)
from repro.core.predict import decision_exact
from repro.data import (
    friedman1,
    gaussian_mixture_imbalanced,
    sinc1d,
    stratified_split,
    train_test_split,
)

KERNELS = [
    Kernel("rbf", gamma=4.0),
    Kernel("poly", gamma=1.0, degree=3, coef0=1.0),
    Kernel("linear"),
]


def _problem(n=96, d=6, key=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    X = (jax.random.uniform(k1, (n, d)) - 0.5) * 2.0
    y = jnp.sign(jax.random.normal(k2, (n,)))
    return X, y


# ---------------------------------------------------------------------------
# (a) hinge equivalence: generalized engine == pre-refactor scalar path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
def test_hinge_equivalence_dense_solvers(kern):
    """CSVC through the generalized dual (explicit p=-1 vector, s=y,
    cvec=C vector) must reproduce the scalar hinge path to <= 1e-6 for the
    greedy, block, and shrinking solvers."""
    X, y = _problem(key=11)
    n = X.shape[0]
    C = 2.0
    K = kern.pairwise(X, X) + 1e-3 * jnp.eye(n)
    Q = (y[:, None] * y[None, :]) * K
    pvec = -jnp.ones(n)
    cvec = C * jnp.ones(n)

    legacy = solve_box_qp(Q, C, tol=1e-5, max_iters=100_000)
    gen = solve_box_qp(Q, cvec, tol=1e-5, max_iters=100_000, p=pvec)
    np.testing.assert_allclose(np.asarray(gen.alpha), np.asarray(legacy.alpha),
                               atol=1e-6)
    np.testing.assert_allclose(float(gen.pg_max), float(legacy.pg_max),
                               atol=1e-6)

    legacy_b = solve_box_qp_block(Q, C, tol=1e-5, max_iters=20_000, block=16)
    gen_b = solve_box_qp_block(Q, cvec, tol=1e-5, max_iters=20_000, block=16,
                               p=pvec)
    np.testing.assert_allclose(np.asarray(gen_b.alpha),
                               np.asarray(legacy_b.alpha), atol=1e-6)

    legacy_s = solve_with_shrinking(Q, C, tol=1e-4, max_iters=50_000, rounds=3)
    gen_s = solve_with_shrinking(Q, cvec, tol=1e-4, max_iters=50_000, rounds=3,
                                 p=pvec)
    np.testing.assert_allclose(np.asarray(gen_s.alpha),
                               np.asarray(legacy_s.alpha), atol=1e-6)


@pytest.mark.parametrize("kern", KERNELS, ids=[k.kind for k in KERNELS])
def test_hinge_equivalence_matvec_solver(kern):
    X, y = _problem(key=13)
    n = X.shape[0]
    C = 2.0
    legacy = solve_box_qp_matvec(X, y, kern, C, tol=1e-5, max_iters=3000,
                                 block=16)
    gen = solve_box_qp_matvec(X, y, kern, C * jnp.ones(n), tol=1e-5,
                              max_iters=3000, block=16, p=-jnp.ones(n))
    np.testing.assert_allclose(np.asarray(gen.alpha), np.asarray(legacy.alpha),
                               atol=1e-6)


def test_csvc_task_reduction_matches_direct_labels():
    """The CSVC task's (p, s, cvec) is exactly (-1, y, C)."""
    X, y = _problem(n=40, key=1)
    td = CSVC().build(X, y[None, :], 3.0)
    assert td.n_dual == 40 and td.n_base == 40
    np.testing.assert_array_equal(np.asarray(td.S[0]), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(td.P), -np.ones((1, 40)))
    np.testing.assert_array_equal(np.asarray(td.Cvec), 3.0 * np.ones((1, 40)))
    np.testing.assert_array_equal(td.base_index, np.arange(40))
    # collapse is beta = y * alpha
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (1, 40)))
    np.testing.assert_allclose(np.asarray(td.collapse(a)),
                               np.asarray(y[None, :] * a), rtol=1e-6)


# ---------------------------------------------------------------------------
# (b) epsilon-SVR vs. an independent dense reference QP solve
# ---------------------------------------------------------------------------

def _svr_dual(X, y, eps, C, kern, jitter=0.0):
    task = EpsilonSVR(eps=eps)
    td = task.build(X, y[None, :], C)
    Kd = kern.pairwise(td.Xd, td.Xd) + jitter * jnp.eye(td.n_dual)
    Q = (td.S[0][:, None] * td.S[0][None, :]) * Kd
    return task, td, Q


def test_svr_tiny_vs_dense_reference_qp():
    """Tiny SVR: our generalized CD solution vs scipy L-BFGS-B on the same
    box QP — objectives agree, betas agree, KKT residual at tolerance, and
    the eps-tube property holds (strict-interior residuals => beta = 0)."""
    from scipy.optimize import minimize

    n, eps, C = 36, 0.1, 4.0
    X, y = sinc1d(jax.random.PRNGKey(0), n, noise=0.05)
    kern = Kernel("rbf", gamma=2.0)
    task, td, Q = _svr_dual(X, y, eps, C, kern)
    p = td.P[0]

    res = solve_box_qp(Q, td.Cvec[0], tol=1e-7, max_iters=400_000, p=p)
    # KKT residual of the generalized dual at the returned solution
    assert float(kkt_residual(Q, res.alpha, td.Cvec[0], p=p)) <= 1e-6

    Q_np, p_np = np.asarray(Q, np.float64), np.asarray(p, np.float64)

    def f_and_g(u):
        g = Q_np @ u + p_np
        return 0.5 * u @ (Q_np @ u) + p_np @ u, g

    ref = minimize(f_and_g, np.zeros(2 * n), jac=True, method="L-BFGS-B",
                   bounds=[(0.0, C)] * (2 * n),
                   options={"maxiter": 20_000, "ftol": 1e-16, "gtol": 1e-10})
    f_cd = float(0.5 * res.alpha @ (Q @ res.alpha) + p @ res.alpha)
    assert f_cd <= ref.fun + 1e-6 + 1e-6 * abs(ref.fun)

    # the collapsed beta is the unique decision coefficient vector
    beta_cd = np.asarray(td.collapse(res.alpha[None, :])[0])
    beta_ref = ref.x[:n] - ref.x[n:]
    np.testing.assert_allclose(beta_cd, beta_ref, atol=5e-4)

    # eps-tube: strictly inside the tube => not a support vector
    f_tr = np.asarray(kern.pairwise(X, X)) @ beta_cd
    inside = np.abs(f_tr - np.asarray(y)) < eps - 1e-3
    assert inside.any(), "degenerate test setup: nothing strictly in-tube"
    assert np.all(np.abs(beta_cd[inside]) <= 1e-5)


def test_svr_mirrored_pair_complementarity():
    """At the optimum min(alpha_i, alpha*_i) = 0 (the two coordinate
    gradients sum to 2 eps > 0), so the 2n dual collapses losslessly."""
    n, eps, C = 48, 0.05, 2.0
    X, y = sinc1d(jax.random.PRNGKey(3), n, noise=0.1)
    _, td, Q = _svr_dual(X, y, eps, C, Kernel("rbf", gamma=1.0))
    res = solve_box_qp(Q, td.Cvec[0], tol=1e-7, max_iters=400_000, p=td.P[0])
    a, astar = np.asarray(res.alpha[:n]), np.asarray(res.alpha[n:])
    assert float(np.max(np.minimum(a, astar))) <= 1e-6


def test_svr_fit_end_to_end_multilevel():
    """EpsilonSVR trains through ``fit`` (multilevel, warm-started): the
    final beta matches a direct dense solve of the full generalized dual,
    and both mirrored coordinates of each sample share a cluster."""
    n, eps, C = 220, 0.05, 4.0
    X, y = sinc1d(jax.random.PRNGKey(1), n, noise=0.03)
    kern = Kernel("rbf", gamma=2.0)
    cfg = DCSVMConfig(kernel=kern, C=C, k=3, levels=2, m=120, tol=1e-5,
                      kmeans_iters=10, use_pallas=False)
    task = EpsilonSVR(eps=eps)
    model = fit(cfg, X, y, task=task)
    assert model.alpha.shape == (2 * n,)
    assert model.beta is not None and model.beta.shape == (n,)

    # the returned dual satisfies the FULL generalized problem's KKT system
    # (10x headroom over tol: f32 gradient recompute noise, same margin as
    # test_shrinking_returns_full_problem_kkt)
    _, td, Q = _svr_dual(X, y, eps, C, kern)
    assert float(kkt_residual(Q, model.alpha, td.Cvec[0],
                              p=td.P[0])) <= cfg.tol * 10

    # reference: one dense generalized solve, no divide step.  The 1-D RBF
    # Gram is near-singular, so individual betas are only loosely pinned at
    # CD tolerance — the decision function K @ beta is the well-conditioned
    # comparison (plus the objective value).
    ref = solve_box_qp(Q, td.Cvec[0], tol=1e-6, max_iters=600_000, p=td.P[0])
    beta_ref = np.asarray(td.collapse(ref.alpha[None, :])[0])
    K = np.asarray(kern.pairwise(X, X))
    np.testing.assert_allclose(K @ np.asarray(model.beta), K @ beta_ref,
                               atol=5e-3)
    f_fit = float(0.5 * model.alpha @ (Q @ model.alpha) + td.P[0] @ model.alpha)
    f_ref = float(0.5 * ref.alpha @ (Q @ ref.alpha) + td.P[0] @ ref.alpha)
    assert f_fit <= f_ref + 1e-4 * (1 + abs(f_ref))

    # the fit is a real fit: far below the predict-the-mean baseline
    pred = predict_exact(model, X)
    assert mse(y, pred) < 0.2 * float(jnp.var(y))
    assert mae(y, pred) <= mae(y, jnp.full_like(y, float(jnp.mean(y))))

    # paper eq. 11 for regression: early-stopped model (per-cluster local
    # SVRs) + nearest-cluster routing returns raw values and a real fit
    cfg_e = dataclasses.replace(cfg, early_stop_level=1)
    model_e = fit(cfg_e, X, y, task=EpsilonSVR(eps=eps))
    assert model_e.is_early and model_e.partition is not None
    pred_e = predict_early(model_e, X)
    assert pred_e.shape == (n,)
    assert mse(y, pred_e) < 0.2 * float(jnp.var(y))


def test_svr_serving_export_and_batch():
    """export_serving_model/serve_batch on a regression model: beta-form
    single-column export, task == "svr", predictions == exact decision (no
    argmax), early strategy routes through the shared program."""
    from repro.launch.serve_svm import export_serving_model, serve_batch

    n = 180
    X, y = friedman1(jax.random.PRNGKey(2), n)
    kern = Kernel("rbf", gamma=1.0)
    cfg = DCSVMConfig(kernel=kern, C=4.0, k=3, levels=1, m=100, tol=1e-4,
                      kmeans_iters=10, use_pallas=False)
    model = fit(cfg, X, y, task=EpsilonSVR(eps=0.1))
    sm = export_serving_model(model, with_bcm=False)
    assert sm.task == "svr"
    assert sm.n_classes == 0
    assert sm.Wsv.shape[-1] == 1

    Xq = X[:64]
    pred, scores = serve_batch(sm, Xq, kern, "exact")
    assert pred.shape == (64,) and scores.shape == (64, 1)
    np.testing.assert_allclose(np.asarray(pred),
                               np.asarray(decision_exact(model, Xq)),
                               rtol=1e-4, atol=1e-4)
    pred_early, _ = serve_batch(sm, Xq, kern, "early")
    np.testing.assert_allclose(np.asarray(pred_early),
                               np.asarray(predict_early(model, Xq)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# (c) weighted C-SVC on imbalanced data
# ---------------------------------------------------------------------------

def test_weighted_svc_improves_minority_recall():
    """On the ~1:20 imbalanced mixture, upweighting the minority box
    (c_i = C * w_{y_i}) must raise minority-class recall vs. the plain
    hinge, without collapsing overall accuracy."""
    X, y = gaussian_mixture_imbalanced(jax.random.PRNGKey(0), 2400, d=8,
                                       pos_frac=0.05, spread=0.45)
    Xtr, ytr, Xte, yte = stratified_split(jax.random.PRNGKey(1), X, y)
    kern = Kernel("rbf", gamma=0.5)
    cfg = DCSVMConfig(kernel=kern, C=1.0, k=4, levels=1, m=300, tol=1e-3,
                      kmeans_iters=10, use_pallas=False)

    plain = fit(cfg, Xtr, ytr)
    weighted = fit(cfg, Xtr, ytr, task=WeightedCSVC(w_pos=20.0))

    rec_plain = recall(yte, predict_exact(plain, Xte), 1.0)
    rec_weighted = recall(yte, predict_exact(weighted, Xte), 1.0)
    # heavy overlap: the plain hinge all but abandons the minority class
    # (recall ~0 at these settings); the weighted box buys most of it back
    assert rec_weighted >= rec_plain + 0.3, (rec_weighted, rec_plain)
    assert rec_weighted >= 0.5
    # majority class must not collapse
    assert recall(yte, predict_exact(weighted, Xte), -1.0) >= 0.7


def test_weighted_task_box_construction():
    y = jnp.asarray([1.0, -1.0, 1.0, -1.0])
    X = jnp.zeros((4, 2))
    td = WeightedCSVC(w_pos=5.0, w_neg=0.5).build(X, y[None, :], 2.0)
    np.testing.assert_allclose(np.asarray(td.Cvec[0]),
                               [10.0, 1.0, 10.0, 1.0])
    td2 = WeightedCSVC(w_pos=2.0, sample_weight=jnp.asarray(
        [1.0, 2.0, 3.0, 4.0])).build(X, y[None, :], 1.0)
    np.testing.assert_allclose(np.asarray(td2.Cvec[0]), [2.0, 2.0, 6.0, 4.0])


def test_weighted_box_binds_at_per_coordinate_bound():
    """Solver-level: with per-coordinate cvec, saturated coordinates stop
    at THEIR bound, not the scalar C."""
    X, y = _problem(n=48, key=17)
    K = Kernel("rbf", gamma=4.0).pairwise(X, X) + 1e-3 * jnp.eye(48)
    Q = (y[:, None] * y[None, :]) * K
    cvec = jnp.where(y > 0, 0.05, 5.0)
    res = solve_box_qp(Q, cvec, tol=1e-6, max_iters=200_000)
    a = np.asarray(res.alpha)
    cv = np.asarray(cvec)
    assert np.all(a <= cv + 1e-7)
    pg = proj_grad(res.alpha, res.grad, cvec)
    assert float(jnp.max(jnp.abs(pg))) <= 1e-5
    # the tight minority box actually binds somewhere
    assert np.any(a[np.asarray(y) > 0] >= 0.05 - 1e-6)


# ---------------------------------------------------------------------------
# regression data generators
# ---------------------------------------------------------------------------

def test_regression_generators_shapes_and_determinism():
    X1, y1 = sinc1d(jax.random.PRNGKey(7), 100)
    X2, y2 = sinc1d(jax.random.PRNGKey(7), 100)
    np.testing.assert_array_equal(np.asarray(X1), np.asarray(X2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert X1.shape == (100, 1) and y1.shape == (100,)

    Xf, yf = friedman1(jax.random.PRNGKey(8), 200, d=10)
    assert Xf.shape == (200, 10) and yf.shape == (200,)
    assert abs(float(jnp.mean(yf))) < 1e-4          # standardized
    assert abs(float(jnp.std(yf)) - 1.0) < 1e-3
    with pytest.raises(ValueError):
        friedman1(jax.random.PRNGKey(9), 50, d=3)


def test_imbalanced_generator_ratio_and_stratified_split():
    X, y = gaussian_mixture_imbalanced(jax.random.PRNGKey(0), 4000,
                                       pos_frac=0.05)
    frac = float(jnp.mean(y > 0))
    assert 0.02 < frac < 0.09
    Xtr, ytr, Xte, yte = stratified_split(jax.random.PRNGKey(1), X, y,
                                          test_frac=0.25)
    assert Xtr.shape[0] + Xte.shape[0] == 4000
    # both sides keep minority representation near the global ratio
    assert float(jnp.mean(ytr > 0)) == pytest.approx(frac, abs=0.02)
    assert float(jnp.mean(yte > 0)) == pytest.approx(frac, abs=0.02)
