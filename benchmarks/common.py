"""Shared benchmark utilities: datasets, timing, CSV row + JSON artifact emission."""
from __future__ import annotations

import json
import sys
import time
from typing import Callable, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Kernel, gram, solve_with_shrinking
from repro.data import covtype_like, gaussian_mixture, train_test_split, webspam_like

Row = Tuple[str, float, str]


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def emit_json(path: str, payload: dict, merge: bool = False) -> None:
    """Write a structured benchmark artifact (e.g. BENCH_conquer.json).

    ``merge=True`` read-merges into an existing artifact: top-level keys in
    ``payload`` replace/extend the file's, other sections survive — for
    benches that share one JSON (a corrupt/missing file starts fresh)."""
    if merge:
        base = {}
        try:
            with open(path) as f:
                base = json.load(f)
        except (OSError, json.JSONDecodeError):
            base = {}
        if isinstance(base, dict):
            payload = {**base, **payload}
    payload = dict(payload, backend=jax.default_backend())
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr, flush=True)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0] if jax.tree.leaves(out) else out)
    return out, time.perf_counter() - t0


def bench_dataset(name: str, n: int, seed: int = 0):
    # gammas are scaled to the data dimension (gamma ~ 1/median ||x-x'||^2),
    # matching the paper's cross-validated parameter regime: meaningful SV
    # sparsity, kernel matrix far from identity
    key = jax.random.PRNGKey(seed)
    if name == "covtype_like":
        X, y = covtype_like(key, n)
        kern, C = Kernel("rbf", gamma=1.0), 8.0
    elif name == "webspam_like":
        X, y = webspam_like(key, n)
        kern, C = Kernel("rbf", gamma=0.5), 8.0
    else:
        X, y = gaussian_mixture(key, n, d=16, modes_per_class=8, spread=0.12)
        kern, C = Kernel("rbf", gamma=2.0), 4.0
    Xtr, ytr, Xte, yte = train_test_split(jax.random.fold_in(key, 7), X, y)
    return Xtr, ytr, Xte, yte, kern, C


def full_Q(kern: Kernel, X, y):
    return (y[:, None] * y[None, :]) * gram(kern, X, X)


def exact_reference(kern, C, Xtr, ytr, tol=1e-4):
    """High-accuracy reference solution + objective."""
    Q = full_Q(kern, Xtr, ytr)
    res = solve_with_shrinking(Q, C, tol=tol, max_iters=500_000)
    f = float(0.5 * res.alpha @ Q @ res.alpha - res.alpha.sum())
    return Q, res, f
