"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table3]

Prints ``name,us_per_call,derived`` CSV rows (stdout), one per measurement.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

from benchmarks.common import emit

ALL = ["fig1", "fig2", "fig3", "table1", "table3", "table6", "kernels",
       "outofcore", "trace", "serve", "slo", "svr", "oneclass", "eq_block",
       "dist"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes / few iterations: CI smoke, not timing")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or ALL
    failures = []
    print("name,us_per_call,derived")
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            kw = {}
            if args.dry_run and "dry_run" in inspect.signature(mod.run).parameters:
                kw["dry_run"] = True
            rows = mod.run(**kw)
            emit(rows)
            print(f"# bench_{name}: ok in {time.perf_counter()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
