"""One-class SVM conquer benchmark: XLA vs Pallas on the equality dual.

Solves the equality-constrained one-class dual (sum alpha = nu * n) of the
gaussian_with_outliers mixture through ``solve_eq_qp_matvec`` (the pairwise
maximal-violating-pair engine with on-the-fly kernel columns) on both
backends, then runs the full multilevel ``fit`` + beta-plus-rho serving
export.  Emits BENCH_oneclass.json with wall times, backend parity, the
equality-feasibility residual, and outlier-detection F1 vs the
predict-the-majority baseline.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, emit_json, timed
from repro.core import (
    DCSVMConfig, Kernel, OneClassSVM, f1, fit, predict_exact, recall,
)
from repro.core.solver import solve_eq_qp_matvec
from repro.data import gaussian_with_outliers, train_test_split
from repro.launch.serve_svm import export_serving_model, serve_batch


def run(dry_run: bool = False) -> list:
    n, tol = (240, 1e-4) if dry_run else (1536, 1e-4)
    nu, gamma = 0.1, 4.0
    kern = Kernel("rbf", gamma=gamma)
    X, y = gaussian_with_outliers(jax.random.PRNGKey(0), n)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    ntr = Xtr.shape[0]
    ones = jnp.ones(ntr, Xtr.dtype)
    d = nu * ntr
    max_iters = 4_000 if dry_run else 40_000

    def solve(**kw):
        return solve_eq_qp_matvec(Xtr, ones, kern, 1.0, 1.0, d, tol=tol,
                                  max_iters=max_iters, **kw)

    rows, results, alphas = [], {}, {}
    for name, kw in {"xla": dict(), "pallas": dict(use_pallas=True)}.items():
        solve(**kw).alpha.block_until_ready()       # warm (compile)
        res, t = timed(solve, **kw)
        alphas[name] = res.alpha
        feas = abs(float(np.asarray(res.alpha, np.float64).sum()) - d)
        results[name] = {"wall_s": t, "iters": int(res.iters),
                         "pg_max": float(res.pg_max), "eq_residual": feas}
        rows.append((f"oneclass.conquer.{name}.{ntr}x{Xtr.shape[1]}",
                     t * 1e6, f"iters={int(res.iters)};eq_res={feas:.2e}"))

    # the RBF Gram is PD on distinct points, so the equality dual is strictly
    # convex and alpha itself is the parity quantity
    dev = float(jnp.max(jnp.abs(alphas["pallas"] - alphas["xla"])))
    results["alpha_max_dev_vs_xla"] = dev
    assert dev < 1e-3, dev

    # end-to-end: multilevel fit + compiled serving round trip
    cfg = DCSVMConfig(kernel=kern, k=4, levels=1 if dry_run else 2,
                      m=min(500, ntr), tol=1e-3, kmeans_iters=10,
                      use_pallas=False)
    task = OneClassSVM(nu=nu)
    model, t_fit = timed(lambda: fit(cfg, Xtr, task=task))
    pred = predict_exact(model, Xte)
    test_f1 = f1(yte, pred, -1.0)
    # baseline: call everything an inlier — outlier recall/F1 are zero
    sm = export_serving_model(model, with_bcm=False)
    assert sm.task == "ocsvm"
    pred_s, t_serve = timed(serve_batch, sm, Xte, kern, "exact")
    model_e = fit(dataclasses.replace(cfg, early_stop_level=1), Xtr, task=task)
    sm_e = export_serving_model(model_e, with_bcm=False)
    pred_e, t_serve_e = timed(serve_batch, sm_e, Xte, kern, "early")
    results["fit"] = {"wall_s": t_fit, "n_sv": int(len(model.sv_index)),
                      "rho": float(model.rho),
                      "test_f1": test_f1,
                      "test_outlier_recall": recall(yte, pred, -1.0),
                      "serve_exact_f1": f1(yte, pred_s[0], -1.0),
                      "serve_exact_wall_s": t_serve,
                      "serve_early_f1": f1(yte, pred_e[0], -1.0),
                      "serve_early_wall_s": t_serve_e}
    results["problem"] = {"n_train": int(ntr), "nu": nu, "gamma": gamma,
                          "tol": tol, "kernel": "rbf", "dry_run": dry_run}
    assert test_f1 > 0.0, "detector must beat the all-inlier baseline"
    rows.append((f"oneclass.fit.{ntr}", t_fit * 1e6,
                 f"f1={test_f1:.4f};n_sv={len(model.sv_index)}"))
    emit_json("BENCH_oneclass.json", results)
    return rows


if __name__ == "__main__":
    emit(run())
