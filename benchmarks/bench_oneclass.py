"""One-class SVM conquer benchmark: XLA vs Pallas on the equality dual.

Solves the equality-constrained one-class dual (sum alpha = nu * n) of the
gaussian_with_outliers mixture through ``solve_eq_qp_matvec`` (the pairwise
maximal-violating-pair engine with on-the-fly kernel columns) on both
backends, then runs the full multilevel ``fit`` + beta-plus-rho serving
export.  Emits BENCH_oneclass.json with wall times, backend parity, the
equality-feasibility residual, and outlier-detection F1 vs the
predict-the-majority baseline.

Also runs the early-prediction bound experiment (ROADMAP item 3): measures
``max |f_early(x) - f(x)|`` of eq.-11 one-class serving against the
``D(pi)`` + rho_c-spread bound of ``bounds.oneclass_early_gap_bound`` —
both the a-priori Theorem-1 form and the semi-empirical form with the
measured dual drift — and records the per-term decomposition plus
tightness ratios under ``early_bound``.  (The blocked-vs-pairwise conquer
comparison lives in ``bench_eq_block.py`` and merges into the same
BENCH_oneclass.json.)
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, emit_json, timed
from repro.core import (
    DCSVMConfig, Kernel, OneClassSVM, f1, fit, predict_exact, recall,
)
from repro.core.solver import solve_eq_qp_matvec
from repro.data import gaussian_with_outliers, train_test_split
from repro.launch.serve_svm import export_serving_model, serve_batch


def run(dry_run: bool = False) -> list:
    n, tol = (240, 1e-4) if dry_run else (1536, 1e-4)
    nu, gamma = 0.1, 4.0
    kern = Kernel("rbf", gamma=gamma)
    X, y = gaussian_with_outliers(jax.random.PRNGKey(0), n)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    ntr = Xtr.shape[0]
    ones = jnp.ones(ntr, Xtr.dtype)
    d = nu * ntr
    max_iters = 4_000 if dry_run else 40_000

    def solve(**kw):
        return solve_eq_qp_matvec(Xtr, ones, kern, 1.0, 1.0, d, tol=tol,
                                  max_iters=max_iters, **kw)

    rows, results, alphas = [], {}, {}
    for name, kw in {"xla": dict(), "pallas": dict(use_pallas=True)}.items():
        solve(**kw).alpha.block_until_ready()       # warm (compile)
        res, t = timed(solve, **kw)
        alphas[name] = res.alpha
        feas = abs(float(np.asarray(res.alpha, np.float64).sum()) - d)
        results[name] = {"wall_s": t, "iters": int(res.iters),
                         "pg_max": float(res.pg_max), "eq_residual": feas}
        rows.append((f"oneclass.conquer.{name}.{ntr}x{Xtr.shape[1]}",
                     t * 1e6, f"iters={int(res.iters)};eq_res={feas:.2e}"))

    # the RBF Gram is PD on distinct points, so the equality dual is strictly
    # convex and alpha itself is the parity quantity
    dev = float(jnp.max(jnp.abs(alphas["pallas"] - alphas["xla"])))
    results["alpha_max_dev_vs_xla"] = dev
    assert dev < 1e-3, dev

    # end-to-end: multilevel fit + compiled serving round trip
    cfg = DCSVMConfig(kernel=kern, k=4, levels=1 if dry_run else 2,
                      m=min(500, ntr), tol=1e-3, kmeans_iters=10,
                      use_pallas=False)
    task = OneClassSVM(nu=nu)
    model, t_fit = timed(lambda: fit(cfg, Xtr, task=task))
    pred = predict_exact(model, Xte)
    test_f1 = f1(yte, pred, -1.0)
    # baseline: call everything an inlier — outlier recall/F1 are zero
    sm = export_serving_model(model, with_bcm=False)
    assert sm.task == "ocsvm"
    pred_s, t_serve = timed(serve_batch, sm, Xte, kern, "exact")
    model_e = fit(dataclasses.replace(cfg, early_stop_level=1), Xtr, task=task)
    sm_e = export_serving_model(model_e, with_bcm=False)
    pred_e, t_serve_e = timed(serve_batch, sm_e, Xte, kern, "early")
    results["fit"] = {"wall_s": t_fit, "n_sv": int(len(model.sv_index)),
                      "rho": float(model.rho),
                      "test_f1": test_f1,
                      "test_outlier_recall": recall(yte, pred, -1.0),
                      "serve_exact_f1": f1(yte, pred_s[0], -1.0),
                      "serve_exact_wall_s": t_serve,
                      "serve_early_f1": f1(yte, pred_e[0], -1.0),
                      "serve_early_wall_s": t_serve_e}
    results["problem"] = {"n_train": int(ntr), "nu": nu, "gamma": gamma,
                          "tol": tol, "kernel": "rbf", "dry_run": dry_run}
    assert test_f1 > 0.0, "detector must beat the all-inlier baseline"
    rows.append((f"oneclass.fit.{ntr}", t_fit * 1e6,
                 f"f1={test_f1:.4f};n_sv={len(model.sv_index)}"))

    # ---- early-prediction bound experiment (ROADMAP item 3) --------------
    from repro.core.bounds import oneclass_early_gap_bound
    from repro.core.kkmeans import assign_points
    from repro.core.predict import decision_early, decision_exact

    nq = min(256, Xte.shape[0])
    Xq = Xte[:nq]
    f_e = np.asarray(decision_early(model_e, Xq), np.float64)
    f_x = np.asarray(decision_exact(model, Xq), np.float64)
    gap = float(np.max(np.abs(f_e - f_x)))
    sigma_n = float(np.linalg.eigvalsh(
        np.asarray(kern.pairwise(Xtr, Xtr), np.float64)).min())
    cid_q = assign_points(kern, model_e.partition.model, Xq)[0]
    b = oneclass_early_gap_bound(
        kern, Xtr, model_e.partition.assign, model_e.alpha, model.rho,
        model_e.rho_clusters, Xq, cid_q, sigma_n, alpha_exact=model.alpha)
    assert gap <= b["bound_measured"] * (1 + 1e-6) + 1e-6, (gap, b)
    assert gap <= b["bound"] * (1 + 1e-6) + 1e-6, (gap, b)
    results["early_bound"] = dict(
        b, measured_gap=gap, n_queries=int(nq),
        tightness_measured=gap / max(b["bound_measured"], 1e-12),
        tightness_apriori=gap / max(b["bound"], 1e-12))
    rows.append((f"oneclass.early_bound.{ntr}", 0.0,
                 f"gap={gap:.4f};bound_meas={b['bound_measured']:.4f};"
                 f"bound={b['bound']:.2e}"))
    emit_json("BENCH_oneclass.json", results)
    return rows


if __name__ == "__main__":
    emit(run())
