"""Out-of-core / precision-policy benchmark: the GramOperator curve.

Solves the same C-SVC conquer dual through ``solve_box_qp_matvec``
(in-memory) and ``solve_box_qp_spill`` (host-RAM panel tier with a device
LRU sized to ~1/4 of the Gram) under both precision policies (f32 and
bf16-operand/f32-accumulate), emitting wall time, iterations, objective gap
vs the f32 in-memory solution, and the spill-tier counters.

Merges the ``outofcore`` section into BENCH_conquer.json alongside
bench_kernels' cache results (``emit_json(..., merge=True)`` keeps the
artifact's other sections).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_dataset, emit_json, timed
from repro.core.gramop import GramOperator, solve_box_qp_spill
from repro.core.solver import objective, solve_box_qp_matvec

ARTIFACT = "BENCH_conquer.json"


def run(dry_run: bool = False) -> list:
    n, block, tol = (160, 16, 1e-3) if dry_run else (1536, 32, 1e-3)
    max_iters = 400 if dry_run else 4000
    Xtr, ytr, _, _, kern, C = bench_dataset("gaussian", n)
    n = Xtr.shape[0]
    # device tier holds ~1/4 of the raw kernel rows -> real panel traffic
    dev_budget = max(block, n // 4) * n * 4

    rows, results = [], {}
    f_ref = None
    for cd in (None, "bfloat16"):
        tag = cd or "f32"
        op = GramOperator(Xd=Xtr, s=ytr, kernel=kern, compute_dtype=cd)

        def in_mem():
            return solve_box_qp_matvec(Xtr, ytr, kern, C, tol=tol,
                                       max_iters=max_iters, block=block,
                                       compute_dtype=cd)

        in_mem().alpha.block_until_ready()          # warm (compile)
        res_m, t_m = timed(in_mem)
        f_m = float(objective(res_m.alpha, res_m.grad))
        if f_ref is None:
            f_ref = f_m                             # f32 in-memory anchor
        res_s, t_s = timed(
            solve_box_qp_spill, op, C, tol=tol, max_iters=max_iters,
            block=block, device_budget_bytes=dev_budget)
        f_s = float(objective(res_s.alpha, res_s.grad))
        gap = lambda f: abs(f - f_ref) / (1 + abs(f_ref))
        results[tag] = {
            "in_memory": {"wall_s": t_m, "iters": int(res_m.iters),
                          "obj_rel_gap": gap(f_m)},
            "spilled": {"wall_s": t_s, "iters": int(res_s.iters),
                        "obj_rel_gap": gap(f_s),
                        "spills": int(res_s.spills),
                        "spill_hits": int(res_s.spill_hits),
                        "panel_hits": int(res_s.cache_hits),
                        "panel_evictions": int(res_s.cache_evictions)},
        }
        rows.append((f"outofcore.{tag}.in_memory.{n}", t_m * 1e6,
                     f"gap={gap(f_m):.2e}"))
        rows.append((f"outofcore.{tag}.spilled.{n}", t_s * 1e6,
                     f"gap={gap(f_s):.2e};spills={int(res_s.spills)}"))
        assert gap(f_s) < (5e-2 if cd else 1e-3), (tag, gap(f_s))

    emit_json(ARTIFACT, {"outofcore": results}, merge=True)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
