"""Paper Figure 1: Theorem-1 bound tightness, kernel kmeans vs random.

For k in {8,16,32,64}: partition by two-step kernel kmeans, solve the
subproblems, and compare f(a_bar) - f(a*) against (1/2) C^2 D(pi), plus the
same gap under a RANDOM partition (the paper's control showing the clustering
is what makes the bound small).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench_dataset, emit, exact_reference, timed
from repro.core import DCSVMConfig, fit
from repro.core.bounds import d_pi, theorem1_bound


def run(n: int = 2000) -> list:
    Xtr, ytr, _, _, kern, C = bench_dataset("gaussian", n)
    Q, ref, f_star = exact_reference(kern, C, Xtr, ytr)
    rows = []
    rng = np.random.default_rng(0)
    for k_log in (1, 2, 3):
        k = 4 ** k_log
        cfg = DCSVMConfig(kernel=kern, C=C, k=k, levels=1, m=400, tol=1e-4,
                          early_stop_level=1)
        model, dt = timed(fit, cfg, Xtr, ytr)
        f_bar = float(0.5 * model.alpha @ Q @ model.alpha - model.alpha.sum())
        bound = theorem1_bound(kern, Xtr, jnp.asarray(model.partition.assign), C)
        gap = f_bar - f_star

        rand_assign = rng.integers(0, k, size=Xtr.shape[0]).astype(np.int32)
        # random-partition a_bar: solve per random cluster via the same machinery
        from repro.core.kkmeans import Partition
        from repro.core.dcsvm import _solve_clusters
        part = Partition.build(rand_assign, k, model.partition.model)
        mask = jnp.asarray(part.mask)
        # _solve_clusters takes class-stacked (k, n_rows, nc) sign/linear/
        # box/dual vectors (the generalized dual; hinge: s=y, p=-1, c=C)
        yc = part.gather(ytr)[:, None, :]
        pc = jnp.full_like(yc, -1.0)
        cc = jnp.full_like(yc, C)
        ac = jnp.where(mask, part.gather(jnp.zeros(Xtr.shape[0])), 0.0)[:, None, :]
        ac = _solve_clusters(cfg, part.gather(Xtr), yc, pc, cc, ac, mask)
        a_rand = part.scatter(ac[:, 0, :], Xtr.shape[0])
        f_rand = float(0.5 * a_rand @ Q @ a_rand - a_rand.sum())
        bound_rand = theorem1_bound(kern, Xtr, jnp.asarray(rand_assign), C)

        rows += [
            (f"fig1.gap_kkmeans.k{k}", dt * 1e6,
             f"gap={gap:.4f};bound={bound:.4f};fstar={f_star:.2f}"),
            (f"fig1.gap_random.k{k}", 0.0,
             f"gap={f_rand - f_star:.4f};bound={bound_rand:.4f}"),
        ]
        # Theorem 1 must hold; kkmeans partition should beat random clearly
        assert -1e-2 * abs(f_star) <= gap <= bound * 1.01 + 1e-2 * abs(f_star)
        assert gap <= (f_rand - f_star) + 1e-2 * abs(f_star)
    return rows


if __name__ == "__main__":
    emit(run())
