"""Serving-engine benchmark: exact vs early vs bcm request strategies.

Trains a one-vs-all DC-SVM on a 3-class synthetic mixture, exports the
compacted serving model, and drives the batched request loop per strategy —
the paper's Table-1 comparison recast as a throughput/latency benchmark.
Emits ``BENCH_serve.json``.
"""
from __future__ import annotations

from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, emit_json
from repro.core import DCSVMConfig, Kernel, accuracy_multiclass, fit_ova
from repro.data import gaussian_mixture_multiclass, train_test_split
from repro.launch.serve_svm import (
    export_serving_model,
    run_request_loop,
    serve_batch,
)

STRATEGIES = ["exact", "early", "bcm"]


def run(dry_run: bool = False) -> List[Row]:
    n = 800 if dry_run else 6000
    batch = 64 if dry_run else 256
    num_batches = 5 if dry_run else 50
    kern = Kernel("rbf", gamma=8.0)
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), n, n_classes=3,
                                       d=8)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    cfg = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=2,
                      m=min(600, Xtr.shape[0]), tol=1e-3)
    model = fit_ova(cfg, Xtr, ytr)
    sm = export_serving_model(model)

    rng = np.random.default_rng(0)
    idx = rng.integers(0, Xte.shape[0], size=(num_batches, batch))
    batches = jnp.asarray(np.asarray(Xte)[idx])

    rows: List[Row] = []
    payload = {
        "n_train": int(Xtr.shape[0]),
        "n_classes": 3,
        "n_sv": int(len(model.sv_union)),
        "batch": batch,
        "dry_run": dry_run,
        "strategies": {},
    }
    for strategy in STRATEGIES:
        pred, _ = serve_batch(sm, Xte, kern, strategy)
        acc = accuracy_multiclass(yte, pred)
        rep = run_request_loop(sm, kern, strategy, batches)
        rep["accuracy"] = acc
        payload["strategies"][strategy] = rep
        rows.append((f"serve_{strategy}", rep["lat_ms_mean"] * 1e3,
                     f"qps={rep['qps']:.0f} acc={acc:.4f}"))
    # merge: bench_slo shares this artifact (its "slo" section must survive)
    emit_json("BENCH_serve.json", payload, merge=True)
    return rows
