"""Paper Table 6: clustering time vs training time per DC-SVM level.

The paper's observation: clustering cost is roughly constant per level and a
small fraction of total training time.
"""
from __future__ import annotations

from benchmarks.common import bench_dataset, emit
from repro.core import DCSVMConfig, fit


def run(n: int = 6000) -> list:
    Xtr, ytr, _, _, kern, C = bench_dataset("covtype_like", n)
    cfg = DCSVMConfig(kernel=kern, C=C, k=4, levels=3, m=500, tol=1e-3)
    model = fit(cfg, Xtr, ytr)
    rows = []
    total_cluster = total_train = 0.0
    for st in model.level_stats:
        rows.append((f"table6.level{st['level']}",
                     (st["cluster_time"] + st["train_time"]) * 1e6,
                     f"cluster_s={st['cluster_time']:.2f};"
                     f"train_s={st['train_time']:.2f};nsv={st['n_sv']}"))
        total_cluster += st["cluster_time"]
        total_train += st["train_time"]
    rows.append(("table6.total", (total_cluster + total_train) * 1e6,
                 f"cluster_s={total_cluster:.2f};train_s={total_train:.2f}"))
    assert total_cluster < total_train * 2.0
    return rows


if __name__ == "__main__":
    emit(run())
