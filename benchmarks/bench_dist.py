"""Communication-efficient sharded conquer benchmark (ISSUE-6).

Measures the parallel-block conquer (CE-PBM: every device solves its own
top-B sub-QP per communication round) against the replicated single-block
baseline at 1/2/4/8 forced host devices.  Devices must be fixed before jax
initializes, so each device count runs in a worker subprocess
(``python -m benchmarks.bench_dist --worker --devices P ...``) that prints a
``DISTBENCH::{json}`` line; the parent collects the lines, asserts

  * both modes reach the dense single-device objective to 1e-3 relative, and
  * at the largest device count the parallel conquer needs STRICTLY fewer
    communication rounds to reach tol than the replicated baseline,

and writes BENCH_dist.json (rounds-to-tol + wall-clock per device count and
mode, plus the bytes-per-round accounting from DESIGN.md §11).

    PYTHONPATH=src python -m benchmarks.run --only dist [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEVICE_COUNTS = [1, 2, 4, 8]


def _worker(devices: int, n: int, block: int, tol: float,
            max_iters: int) -> None:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core import Kernel, gram
    from repro.core.distributed import ConquerConfig, conquer_step
    from repro.core.solver import solve_with_shrinking
    from repro.data import gaussian_mixture

    assert jax.device_count() == devices, jax.device_count()
    mesh = jax.make_mesh((devices,), ("i",))
    kern = Kernel("rbf", gamma=8.0)
    X, y = gaussian_mixture(jax.random.PRNGKey(0), n, d=8, modes_per_class=4)
    Q = (y[:, None] * y[None, :]) * gram(kern, X, X)
    ref = solve_with_shrinking(Q, 4.0, tol=tol / 10.0,
                               max_iters=50 * max_iters, block=64)
    f = lambda a: float(0.5 * a @ Q @ a - a.sum())
    fref = f(ref.alpha)

    out = {"devices": devices, "n": n, "block": block, "tol": tol}
    base = ConquerConfig(kernel=kern, C=4.0, tol=tol, max_iters=max_iters,
                         block=block, mode="parallel")
    for mode in ("parallel", "replicated"):
        cfg = dataclasses.replace(base, mode=mode)
        # warm call compiles; the timed call measures the solve alone
        conquer_step(mesh, "i", cfg, X, y, jnp.zeros(n))[0].block_until_ready()
        t0 = time.perf_counter()
        alpha, rounds, pg = conquer_step(mesh, "i", cfg, X, y, jnp.zeros(n))
        alpha.block_until_ready()
        wall = time.perf_counter() - t0
        out[mode] = {
            "rounds": int(rounds),
            "wall_s": wall,
            "pg_max": float(pg),
            "rel_obj_err": abs(f(alpha) - fref) / abs(fref),
        }
    print("DISTBENCH::" + json.dumps(out), flush=True)


def run(dry_run: bool = False) -> list:
    n, block, tol = (768, 16, 1e-3) if dry_run else (4096, 16, 1e-3)
    max_iters = 4000 if dry_run else 20000
    counts = [1, 8] if dry_run else DEVICE_COUNTS
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.pop("XLA_FLAGS", None)

    results = {"n": n, "block": block, "tol": tol, "per_devices": {}}
    rows = []
    for devices in counts:
        cmd = [sys.executable, "-m", "benchmarks.bench_dist", "--worker",
               "--devices", str(devices), "--n", str(n),
               "--block", str(block), "--tol", str(tol),
               "--max-iters", str(max_iters)]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             timeout=3600)
        if out.returncode != 0:
            raise RuntimeError(
                f"bench_dist worker (devices={devices}) failed:\n"
                f"{out.stdout}\n{out.stderr}")
        line = next(l for l in out.stdout.splitlines()
                    if l.startswith("DISTBENCH::"))
        rec = json.loads(line[len("DISTBENCH::"):])
        results["per_devices"][str(devices)] = rec
        for mode in ("parallel", "replicated"):
            m = rec[mode]
            assert m["rel_obj_err"] <= 1e-3, (devices, mode, m)
            rows.append((f"dist.conquer.{mode}.p{devices}",
                         m["wall_s"] * 1e6,
                         f"rounds={m['rounds']} "
                         f"rel={m['rel_obj_err']:.1e}"))

    # the headline claim: P simultaneous blocks -> strictly fewer
    # communication rounds than one global block at the same tolerance
    top = results["per_devices"][str(counts[-1])]
    assert top["parallel"]["rounds"] < top["replicated"]["rounds"], top
    results["rounds_ratio_at_max_devices"] = (
        top["replicated"]["rounds"] / top["parallel"]["rounds"])

    # bytes-per-round accounting (DESIGN.md §11): both modes gather O(P*B*d)
    # per round; parallel applies P*B coordinate updates per round instead
    # of B, so descent per byte scales with P
    d_feat = 8
    results["bytes_per_round_model"] = {
        "all_gather_floats": counts[-1] * block * (d_feat + 2),
        "updates_per_round": {"parallel": counts[-1] * block,
                              "replicated": block},
    }

    from benchmarks.common import emit_json
    emit_json("BENCH_dist.json", results)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--max-iters", type=int, default=20000)
    args = ap.parse_args()
    if args.worker:
        _worker(args.devices, args.n, args.block, args.tol, args.max_iters)
    else:
        from benchmarks.common import emit
        emit(run())


if __name__ == "__main__":
    main()
