"""SLO benchmark for the async serving engine: tail latency under Poisson
arrivals at swept offered QPS, against two registered model versions.

Unlike ``bench_serve`` (fixed batches through the synchronous request
loop), this drives the production path: requests with MIXED sizes arrive on
a Poisson clock, the engine's batch manager merges whatever is ready into
pad-bucketed batches, and each request's latency is measured submit ->
future resolution (queueing + batching + compute).  Two versions of the
model are registered and requests split across them — the multi-version
routing cost is part of what is measured.

The OVERLOAD sweep drives a bounded-queue engine (``max_queue_rows`` +
default deadline) at 0.5/1/2/4x its MEASURED capacity (closed-loop
saturation estimate) and records the degradation ladder's observables:
shed rate (typed ``EngineOverloaded`` — the in-process 429), deadline
expiries, goodput, and admitted-request tails.  It asserts the ladder
works: the 4x point sheds deterministically, admitted p99 stays within 3x
the 0.5x p99 (the queue bound caps the wait a request can accumulate), a
pre-expired deadline probe resolves ``DeadlineExceeded`` without touching
the device, and ZERO jit compiles happen after warmup across everything.
Merges the ``slo`` section into ``BENCH_serve.json``.
"""
from __future__ import annotations

import asyncio
import time
from typing import List

import numpy as np
import jax

from benchmarks.common import Row, emit_json
from repro.core import DCSVMConfig, Kernel, fit_ova
from repro.data import gaussian_mixture_multiclass, train_test_split
from repro.launch.engine import (
    AsyncServingEngine,
    DeadlineExceeded,
    EngineConfig,
    EngineOverloaded,
)
from repro.launch.registry import ModelRegistry

SIZES = np.array([1, 4, 16, 64])          # mixed request sizes
SIZE_P = np.array([0.35, 0.30, 0.25, 0.10])
MEAN_REQ_ROWS = float((SIZES * SIZE_P).sum())
OVERLOAD_MULTS = (0.5, 1.0, 2.0, 4.0)     # offered load / measured capacity


def _percentiles(lat_s: List[float]) -> dict:
    ms = np.asarray(lat_s) * 1e3
    return {
        "p50_ms": float(np.percentile(ms, 50)),
        "p95_ms": float(np.percentile(ms, 95)),
        "p99_ms": float(np.percentile(ms, 99)),
        "mean_ms": float(ms.mean()),
    }


async def _drive(engine: AsyncServingEngine, Xpool: np.ndarray, qps: float,
                 n_requests: int, seed: int) -> dict:
    """One Poisson trace at offered ``qps``: mixed sizes, versions
    alternating 1/2, per-request latency = submit -> resolved future."""
    rng = np.random.default_rng(seed)
    sizes = rng.choice(SIZES, size=n_requests, p=SIZE_P)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=n_requests))
    lats: List[float] = []

    async def one(delay: float, size: int, version: int) -> None:
        await asyncio.sleep(delay)
        X = Xpool[rng.integers(0, Xpool.shape[0], size=size)]
        t0 = time.perf_counter()
        await engine.submit(X, "mix", version=version, strategy="early")
        lats.append(time.perf_counter() - t0)

    t_all = time.perf_counter()
    await asyncio.gather(*[
        one(float(arrivals[i]), int(sizes[i]), 1 + i % 2)
        for i in range(n_requests)])
    wall = time.perf_counter() - t_all
    return {
        "offered_qps": float(qps),
        "achieved_rps": n_requests / max(wall, 1e-9),
        "achieved_qps": float(sizes.sum()) / max(wall, 1e-9),
        "requests": int(n_requests),
        "queries": int(sizes.sum()),
        **_percentiles(lats),
    }


async def _measure_capacity(engine: AsyncServingEngine, Xpool: np.ndarray,
                            n_requests: int, workers: int = 16) -> float:
    """Closed-loop saturation: ``workers`` concurrent callers push
    requests back-to-back through the warm engine, drawing sizes from the
    SAME mixed distribution the sweep offers.  Batch service time is
    dominated by per-batch overhead, so rows/sec throughput depends
    strongly on batch fill — ``workers`` must keep roughly ``max_batch``
    rows outstanding (16 callers x ~12 mean rows ~ 190) or the probe
    reports small-batch throughput and "4x capacity" never overloads the
    engine.  Run against an UNBOUNDED engine: the bounded ladder under
    test would shed a saturating closed loop.  Returns sustained
    queries/sec."""
    rng = np.random.default_rng(0)
    served = 0

    async def worker() -> None:
        nonlocal served
        for _ in range(n_requests):
            size = int(rng.choice(SIZES, p=SIZE_P))
            X = Xpool[rng.integers(0, Xpool.shape[0], size=size)]
            await engine.submit(X, "mix", strategy="early")
            served += size

    t0 = time.perf_counter()
    await asyncio.gather(*[worker() for _ in range(workers)])
    return served / (time.perf_counter() - t0)


async def _drive_overload(engine: AsyncServingEngine, Xpool: np.ndarray,
                          mult: float, req_rate: float, n_requests: int,
                          seed: int) -> dict:
    """One Poisson trace at ``mult``x capacity against the bounded-queue
    engine: every request either delivers, sheds with the typed
    ``EngineOverloaded``, or expires with ``DeadlineExceeded`` — anything
    else propagates and fails the bench."""
    rng = np.random.default_rng(seed)
    sizes = rng.choice(SIZES, size=n_requests, p=SIZE_P)
    arrivals = np.cumsum(rng.exponential(1.0 / req_rate, size=n_requests))
    lats: List[float] = []
    counts = {"shed": 0, "deadline_expired": 0}

    async def one(delay: float, size: int) -> int:
        await asyncio.sleep(delay)
        X = Xpool[rng.integers(0, Xpool.shape[0], size=size)]
        t0 = time.perf_counter()
        try:
            await engine.submit(X, "mix", strategy="early")
        except EngineOverloaded:
            counts["shed"] += 1
            return 0
        except DeadlineExceeded:
            counts["deadline_expired"] += 1
            return 0
        lats.append(time.perf_counter() - t0)
        return size

    t_all = time.perf_counter()
    rows = await asyncio.gather(*[
        one(float(arrivals[i]), int(sizes[i])) for i in range(n_requests)])
    wall = time.perf_counter() - t_all
    return {
        "mult": float(mult),
        "offered_qps": float(req_rate * MEAN_REQ_ROWS),
        "requests": int(n_requests),
        "shed": counts["shed"],
        "deadline_expired": counts["deadline_expired"],
        "delivered": len(lats),
        "shed_rate": counts["shed"] / n_requests,
        "goodput_qps": float(sum(rows)) / max(wall, 1e-9),
        **(_percentiles(lats) if lats
           else {k: float("nan")
                 for k in ("p50_ms", "p95_ms", "p99_ms", "mean_ms")}),
    }


def _overload_sweep(registry: ModelRegistry, Xpool: np.ndarray,
                    dry_run: bool) -> dict:
    """Measure capacity, sweep 0.5/1/2/4x offered load against a
    bounded-queue engine with a default deadline, probe the pre-expired
    deadline path, and assert the degradation-ladder acceptance bars."""
    max_batch = 128 if dry_run else 256
    # the queue bound is ONE batch worth of rows: an admitted request waits
    # at most ~2 batch service times (the in-flight batch + the queue ahead
    # of it), which is what keeps the admitted p99 a small multiple of the
    # lightly-loaded p99 no matter how hard the 4x point pushes
    cfg = EngineConfig(max_batch=max_batch, max_queue_rows=max_batch,
                       timeout_s=1.0)
    engine = AsyncServingEngine(registry, cfg)
    engine.warmup("mix", strategies=["early"])
    n_requests = 150 if dry_run else 400

    # capacity is probed against an unbounded engine (same max_batch, same
    # shared jit cache) — the bounded engine under test would shed the
    # saturating closed loop
    probe = AsyncServingEngine(registry, EngineConfig(max_batch=max_batch))
    probe.warmup("mix", strategies=["early"])

    async def sweep():
        out = []
        async with probe:
            cap = await _measure_capacity(probe, Xpool,
                                          n_requests=8 if dry_run else 25)
        async with engine:
            for i, mult in enumerate(OVERLOAD_MULTS):
                out.append(await _drive_overload(
                    engine, Xpool, mult, mult * cap / MEAN_REQ_ROWS,
                    n_requests, seed=200 + i))
            # deterministic deadline probe: an already-expired request must
            # resolve DeadlineExceeded without consuming a batch slot
            q_before = engine.stats()["queries"]
            try:
                await engine.submit(Xpool[:4], "mix", strategy="early",
                                    timeout_s=0.0)
                raise AssertionError("pre-expired request was served")
            except DeadlineExceeded:
                pass
            assert engine.stats()["queries"] == q_before, (
                "an expired request consumed a batch slot")
        return cap, out

    capacity_qps, results = asyncio.run(sweep())
    st = engine.stats()
    assert st["compiles_after_warmup"] == 0, (
        "the overload sweep recompiled — the bucketed jit cache went cold")
    r_lo, r_hi = results[0], results[-1]
    assert r_hi["shed"] > 0, (
        f"4x capacity ({r_hi['offered_qps']:.0f} qps offered) never shed — "
        "admission control is not engaging")
    assert r_hi["p99_ms"] <= 3.0 * r_lo["p99_ms"], (
        f"admitted p99 degraded {r_hi['p99_ms'] / r_lo['p99_ms']:.1f}x from "
        f"0.5x to 4x load ({r_lo['p99_ms']:.2f} -> {r_hi['p99_ms']:.2f} ms) "
        "— the queue bound is not capping the wait")
    return {
        "capacity_qps": float(capacity_qps),
        "max_queue_rows": cfg.max_queue_rows,
        "timeout_s": cfg.timeout_s,
        "deadline_probe": "DeadlineExceeded",
        "compiles_after_warmup": int(st["compiles_after_warmup"]),
        "shed_total": int(st["shed"]),
        "deadline_exceeded_total": int(st["deadline_exceeded"]),
        "sweep": results,
    }


def run(dry_run: bool = False) -> List[Row]:
    n = 700 if dry_run else 5000
    n_requests = 40 if dry_run else 400
    qps_sweep = [100.0] if dry_run else [100.0, 400.0, 1600.0]
    kern = Kernel("rbf", gamma=8.0)
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), n, n_classes=3,
                                       d=8)
    Xtr, ytr, Xte, _ = train_test_split(jax.random.PRNGKey(1), X, y)

    registry = ModelRegistry()
    # v1: early-stopped 1-level model (cheap, approximate); v2: the full
    # 2-level solve — the hot-swap pair a production rollout would hold
    cfg1 = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=1,
                       m=min(400, Xtr.shape[0]), tol=1e-3,
                       early_stop_level=1)
    cfg2 = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=2,
                       m=min(400, Xtr.shape[0]), tol=1e-3)
    man1 = registry.register("mix", fit_ova(cfg1, Xtr, ytr), with_bcm=False)
    man2 = registry.register("mix", fit_ova(cfg2, Xtr, ytr), with_bcm=False)

    engine = AsyncServingEngine(
        registry, EngineConfig(max_batch=128 if dry_run else 256))
    warm = engine.warmup("mix", strategies=["early"])
    Xpool = np.asarray(Xte)

    async def sweep() -> List[dict]:
        out = []
        async with engine:
            for i, qps in enumerate(qps_sweep):
                out.append(await _drive(engine, Xpool, qps, n_requests,
                                        seed=100 + i))
        return out

    results = asyncio.run(sweep())
    compiles = engine.stats()["compiles_after_warmup"]
    assert compiles == 0, (
        f"engine compiled {compiles} executable(s) inside the timed sweep — "
        "the bucketed jit cache went cold")

    overload = _overload_sweep(registry, Xpool, dry_run)

    payload = {
        "slo": {
            "n_train": int(Xtr.shape[0]),
            "versions": [man1.version, man2.version],
            "n_sv": [man1.n_sv, man2.n_sv],
            "warmup_compiles": int(warm),
            "compiles_after_warmup": int(compiles),
            "dry_run": dry_run,
            "sweep": results,
            "overload": overload,
        }
    }
    emit_json("BENCH_serve.json", payload, merge=True)
    rows: List[Row] = []
    for r in results:
        rows.append((f"slo_q{int(r['offered_qps'])}", r["p99_ms"] * 1e3,
                     f"p50={r['p50_ms']:.2f}ms p95={r['p95_ms']:.2f}ms "
                     f"rps={r['achieved_rps']:.0f} compiles=0"))
    for r in overload["sweep"]:
        rows.append((
            f"overload_{r['mult']:g}x", r["p99_ms"] * 1e3,
            f"shed={r['shed_rate'] * 100:.0f}% "
            f"goodput={r['goodput_qps']:.0f}q/s "
            f"p50={r['p50_ms']:.2f}ms compiles=0"))
    return rows
