"""SLO benchmark for the async serving engine: tail latency under Poisson
arrivals at swept offered QPS, against two registered model versions.

Unlike ``bench_serve`` (fixed batches through the synchronous request
loop), this drives the production path: requests with MIXED sizes arrive on
a Poisson clock, the engine's batch manager merges whatever is ready into
pad-bucketed batches, and each request's latency is measured submit ->
future resolution (queueing + batching + compute).  Two versions of the
model are registered and requests split across them — the multi-version
routing cost is part of what is measured.

Asserts the engine's core invariant: ZERO jit compiles after warmup over
the whole sweep (ragged sizes bucket onto warm signatures).  Merges the
``slo`` section into ``BENCH_serve.json``.
"""
from __future__ import annotations

import asyncio
import time
from typing import List

import numpy as np
import jax

from benchmarks.common import Row, emit_json
from repro.core import DCSVMConfig, Kernel, fit_ova
from repro.data import gaussian_mixture_multiclass, train_test_split
from repro.launch.engine import AsyncServingEngine, EngineConfig
from repro.launch.registry import ModelRegistry

SIZES = np.array([1, 4, 16, 64])          # mixed request sizes
SIZE_P = np.array([0.35, 0.30, 0.25, 0.10])


def _percentiles(lat_s: List[float]) -> dict:
    ms = np.asarray(lat_s) * 1e3
    return {
        "p50_ms": float(np.percentile(ms, 50)),
        "p95_ms": float(np.percentile(ms, 95)),
        "p99_ms": float(np.percentile(ms, 99)),
        "mean_ms": float(ms.mean()),
    }


async def _drive(engine: AsyncServingEngine, Xpool: np.ndarray, qps: float,
                 n_requests: int, seed: int) -> dict:
    """One Poisson trace at offered ``qps``: mixed sizes, versions
    alternating 1/2, per-request latency = submit -> resolved future."""
    rng = np.random.default_rng(seed)
    sizes = rng.choice(SIZES, size=n_requests, p=SIZE_P)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=n_requests))
    lats: List[float] = []

    async def one(delay: float, size: int, version: int) -> None:
        await asyncio.sleep(delay)
        X = Xpool[rng.integers(0, Xpool.shape[0], size=size)]
        t0 = time.perf_counter()
        await engine.submit(X, "mix", version=version, strategy="early")
        lats.append(time.perf_counter() - t0)

    t_all = time.perf_counter()
    await asyncio.gather(*[
        one(float(arrivals[i]), int(sizes[i]), 1 + i % 2)
        for i in range(n_requests)])
    wall = time.perf_counter() - t_all
    return {
        "offered_qps": float(qps),
        "achieved_rps": n_requests / max(wall, 1e-9),
        "achieved_qps": float(sizes.sum()) / max(wall, 1e-9),
        "requests": int(n_requests),
        "queries": int(sizes.sum()),
        **_percentiles(lats),
    }


def run(dry_run: bool = False) -> List[Row]:
    n = 700 if dry_run else 5000
    n_requests = 40 if dry_run else 400
    qps_sweep = [100.0] if dry_run else [100.0, 400.0, 1600.0]
    kern = Kernel("rbf", gamma=8.0)
    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), n, n_classes=3,
                                       d=8)
    Xtr, ytr, Xte, _ = train_test_split(jax.random.PRNGKey(1), X, y)

    registry = ModelRegistry()
    # v1: early-stopped 1-level model (cheap, approximate); v2: the full
    # 2-level solve — the hot-swap pair a production rollout would hold
    cfg1 = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=1,
                       m=min(400, Xtr.shape[0]), tol=1e-3,
                       early_stop_level=1)
    cfg2 = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=2,
                       m=min(400, Xtr.shape[0]), tol=1e-3)
    man1 = registry.register("mix", fit_ova(cfg1, Xtr, ytr), with_bcm=False)
    man2 = registry.register("mix", fit_ova(cfg2, Xtr, ytr), with_bcm=False)

    engine = AsyncServingEngine(
        registry, EngineConfig(max_batch=128 if dry_run else 256))
    warm = engine.warmup("mix", strategies=["early"])
    Xpool = np.asarray(Xte)

    async def sweep() -> List[dict]:
        out = []
        async with engine:
            for i, qps in enumerate(qps_sweep):
                out.append(await _drive(engine, Xpool, qps, n_requests,
                                        seed=100 + i))
        return out

    results = asyncio.run(sweep())
    compiles = engine.stats()["compiles_after_warmup"]
    assert compiles == 0, (
        f"engine compiled {compiles} executable(s) inside the timed sweep — "
        "the bucketed jit cache went cold")

    payload = {
        "slo": {
            "n_train": int(Xtr.shape[0]),
            "versions": [man1.version, man2.version],
            "n_sv": [man1.n_sv, man2.n_sv],
            "warmup_compiles": int(warm),
            "compiles_after_warmup": int(compiles),
            "dry_run": dry_run,
            "sweep": results,
        }
    }
    emit_json("BENCH_serve.json", payload, merge=True)
    rows: List[Row] = []
    for r in results:
        rows.append((f"slo_q{int(r['offered_qps'])}", r["p99_ms"] * 1e3,
                     f"p50={r['p50_ms']:.2f}ms p95={r['p95_ms']:.2f}ms "
                     f"rps={r['achieved_rps']:.0f} compiles=0"))
    return rows
