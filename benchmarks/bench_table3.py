"""Paper Tables 3/4: solver comparison (training time + test accuracy).

On two synthetic stand-ins (covtype-like, webspam-like): DC-SVM (early),
DC-SVM (exact), the LIBSVM-analogue exact CD solver from zero, CascadeSVM,
LLSVM (kmeans Nystrom), FastFood-analogue RFF, and LTPU.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_dataset, emit, timed
from repro.baselines import (
    train_cascade, train_exact, train_llsvm, train_ltpu, train_rff,
)
from repro.core import (
    DCSVMConfig, accuracy, fit, predict_early, predict_exact,
)


def one_dataset(ds: str, n: int) -> list:
    Xtr, ytr, Xte, yte, kern, C = bench_dataset(ds, n)
    rows = []

    cfg_e = DCSVMConfig(kernel=kern, C=C, k=4, levels=2, m=500, tol=1e-3,
                        early_stop_level=1)
    me, te = timed(fit, cfg_e, Xtr, ytr)
    rows.append((f"table3.{ds}.dcsvm_early", te * 1e6,
                 f"acc={accuracy(yte, predict_early(me, Xte)):.4f}"))

    cfg = DCSVMConfig(kernel=kern, C=C, k=4, levels=2, m=500, tol=1e-3)
    md, td = timed(fit, cfg, Xtr, ytr)
    acc_d = accuracy(yte, predict_exact(md, Xte))
    rows.append((f"table3.{ds}.dcsvm", td * 1e6, f"acc={acc_d:.4f}"))

    ex, tx = timed(train_exact, Xtr, ytr, kern, C, tol=1e-3)
    acc_x = accuracy(yte, ex.predict(Xte))
    rows.append((f"table3.{ds}.libsvm_analogue", tx * 1e6, f"acc={acc_x:.4f}"))

    ca, tc = timed(train_cascade, Xtr, ytr, kern, C, levels=3, tol=1e-3)
    rows.append((f"table3.{ds}.cascade", tc * 1e6,
                 f"acc={accuracy(yte, ca.predict(Xte)):.4f}"))

    ll, tl = timed(train_llsvm, Xtr, ytr, kern, C, num_landmarks=128)
    rows.append((f"table3.{ds}.llsvm", tl * 1e6,
                 f"acc={accuracy(yte, ll.predict(Xte)):.4f}"))

    rf, tr = timed(train_rff, Xtr, ytr, kern, C, num_features=512)
    rows.append((f"table3.{ds}.fastfood_rff", tr * 1e6,
                 f"acc={accuracy(yte, rf.predict(Xte)):.4f}"))

    lt, tt = timed(train_ltpu, Xtr, ytr, kern, num_units=128)
    rows.append((f"table3.{ds}.ltpu", tt * 1e6,
                 f"acc={accuracy(yte, lt.predict(Xte)):.4f}"))

    # paper's headline: exact DC-SVM matches the exact solver's accuracy
    assert abs(acc_d - acc_x) < 0.02, (acc_d, acc_x)
    return rows


def run(n: int = 4000) -> list:
    return one_dataset("covtype_like", n) + one_dataset("webspam_like", n)


if __name__ == "__main__":
    emit(run())
