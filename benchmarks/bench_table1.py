"""Paper Table 1: early-prediction strategies from a lower-level model.

Accuracy + per-query latency of (10) naive whole-model scoring, BCM
combination, and (11) the paper's cluster-routed early prediction, at k=16
and k=64 clusters.  The paper's claim: (11) wins on BOTH accuracy and time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_dataset, emit, timed
from repro.core import (
    DCSVMConfig, accuracy, decision_bcm, decision_early, decision_exact, fit,
)


def run(n: int = 8000) -> list:
    # covtype-like: substantial class overlap => large SV count, the paper's
    # regime (|S| >> routing sample m) where eq. 11's 1/k win materializes
    Xtr, ytr, Xte, yte, kern, C = bench_dataset("covtype_like", n)
    rows = []
    for k_level, k in ((2, 16), (3, 64)):
        cfg = DCSVMConfig(kernel=kern, C=C, k=4, levels=k_level, m=300,
                          tol=1e-3, early_stop_level=k_level)
        model, _ = timed(fit, cfg, Xtr, ytr)
        nq = Xte.shape[0]

        decision_exact(model, Xte)            # warm (jit compile)
        decision_early(model, Xte)
        d10, t10 = timed(decision_exact, model, Xte)
        acc10 = accuracy(yte, np.sign(np.asarray(d10)))
        dbc, tbc = timed(decision_bcm, model, Xte)
        accbc = accuracy(yte, np.sign(np.asarray(dbc)))
        d11, t11 = timed(decision_early, model, Xte)
        acc11 = accuracy(yte, np.sign(np.asarray(d11)))

        n_sv = int(np.sum(np.asarray(model.alpha) > 0))
        d = Xtr.shape[1]
        # exact per-query kernel-evaluation counts (the paper's O() claim):
        # naive touches every SV; early touches m (routing) + 2n/k (its
        # cluster's members at 2x-balanced capacity)
        evals_naive = n_sv
        evals_early = cfg.m + 2 * Xtr.shape[0] // k
        rows += [
            (f"table1.naive_eq10.k{k}", t10 / nq * 1e6,
             f"acc={acc10:.4f};kernel_evals={evals_naive}"),
            (f"table1.bcm.k{k}", tbc / nq * 1e6, f"acc={accbc:.4f}"),
            (f"table1.early_eq11.k{k}", t11 / nq * 1e6,
             f"acc={acc11:.4f};kernel_evals={evals_early};nsv={n_sv}"),
        ]
        # the paper's cost ordering: early prediction evaluates fewer kernel
        # entries per query once |S| >> m (wall-clock on this 1-core CPU at
        # n~6k is dispatch-overhead-bound, so we assert the exact op counts
        # and report both times)
        if n_sv > 6 * cfg.m:
            assert evals_early < evals_naive, (evals_early, evals_naive)
        # Paper Table 1 orderings are regime-dependent (the paper itself has
        # BCM above naive on webspam and below it on covtype).  On this
        # well-clustered synthetic stand-in the concatenated lower-level
        # alpha is already near-global, so naive/BCM stay strong; the robust,
        # assertable claim is: early prediction retains >=93% of the naive
        # accuracy at a fraction of the kernel evaluations per query
        # (see EXPERIMENTS.md §Paper for the honest discussion).
        assert acc11 >= 0.92 * acc10, (acc11, acc10)
    return rows


if __name__ == "__main__":
    emit(run())
