"""Convergence-trace benchmark: pg_max vs cumulative seconds for the conquer.

Runs the level-0 conquer engine (``solve_box_qp_matvec``) with a
device-resident ``ConvTrace`` ring threaded through the CD while-loop
(``repro.obs.trace``), fetches the per-iteration (pg_max, objective,
n_free, cache_hits) samples ONCE after the solve, and converts them into a
convergence curve — sample i is stamped ``wall * (i+1)/samples`` since the
outer iterations it records are uniform in wall time.  Also reports the
tracing overhead (traced vs untraced wall clock of the identical solve) and
asserts the traced trajectory lands on the untraced alpha bit-for-bit.

Merges the ``trace`` section into BENCH_conquer.json
(``emit_json(..., merge=True)`` keeps the kernels/outofcore sections).

    PYTHONPATH=src python -m benchmarks.run --only trace [--dry-run]
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import bench_dataset, emit, emit_json, timed
from repro.core.solver import solve_box_qp_matvec
from repro.obs.trace import trace_fetch, trace_init

ARTIFACT = "BENCH_conquer.json"


def _curve(fetched: dict, wall: float, col: str):
    vals = fetched.get(col)
    if not vals:
        return []
    m = len(vals)
    return [[wall * (i + 1) / m, v] for i, v in enumerate(vals)
            if not np.isnan(v)]


def run(dry_run: bool = False) -> list:
    n, block, tol = (160, 16, 1e-3) if dry_run else (1536, 32, 1e-3)
    max_iters = 400 if dry_run else 4000
    cap = 512
    Xtr, ytr, _, _, kern, C = bench_dataset("gaussian", n)
    Xtr, ytr = Xtr[:n], ytr[:n]

    rows, section = [], {"capacity": cap}
    for tag, kw in {
        "fused": dict(),
        "cached": dict(cache_cap=min(256, n)),
    }.items():
        def solve(trace=None):
            return solve_box_qp_matvec(
                Xtr, ytr, kern, C, tol=tol, max_iters=max_iters,
                block=block, sweeps=4, trace=trace, **kw)

        solve().alpha.block_until_ready()                    # warm untraced
        res0, t0 = timed(solve)
        solve(trace=trace_init(cap)).alpha.block_until_ready()  # warm traced
        res1, t1 = timed(solve, trace=trace_init(cap))
        assert bool(jnp.all(res0.alpha == res1.alpha)), tag  # bit-identity
        fetched = trace_fetch(res1.trace)
        curve = _curve(fetched, t1, "pg_max")
        assert curve, tag   # acceptance: >= 1 pg_max-vs-seconds curve
        section[tag] = {
            "wall_s": t0, "wall_s_traced": t1,
            "trace_overhead": (t1 - t0) / max(t0, 1e-9),
            "iters": int(res1.iters), "samples": fetched["samples"],
            "dropped": fetched["dropped"],
            "pg_max_vs_seconds": curve,
            "objective_vs_seconds": _curve(fetched, t1, "objective"),
        }
        if "cache_hits" in fetched:
            section[tag]["cache_hits_per_sample"] = fetched["cache_hits"]
        rows.append((f"trace.conquer.{tag}.{n}", t1 * 1e6,
                     f"samples={fetched['samples']};"
                     f"overhead={section[tag]['trace_overhead']:.1%}"))
    section["problem"] = {"n": int(n), "tol": tol, "block": block,
                          "dry_run": dry_run}
    emit_json(ARTIFACT, {"trace": section}, merge=True)
    return rows


if __name__ == "__main__":
    emit(run())
