"""Pallas kernel micro-benchmarks: interpret-mode correctness + jnp-ref
timing on this CPU container (TPU wall-clock is out of scope here; the
per-kernel roofline lives in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core.kernels import Kernel
from repro.kernels import ops, ref


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    kern = Kernel("rbf", gamma=8.0)
    ref_jit = jax.jit(lambda X, Y: ref.kermat_ref(X, Y, gamma=8.0))
    for n, m, d in ((1024, 1024, 64), (2048, 512, 128)):
        X = jax.random.uniform(jax.random.fold_in(key, n), (n, d))
        Y = jax.random.uniform(jax.random.fold_in(key, m), (m, d))
        want = ref_jit(X, Y)              # warm both paths (compile)
        got = ops.kernel_matrix(X, Y, kern)
        want, t_ref = timed(ref_jit, X, Y)
        got, t_pal = timed(ops.kernel_matrix, X, Y, kern)
        err = float(jnp.max(jnp.abs(got - want)))
        rows.append((f"kernels.kermat.{n}x{m}x{d}", t_pal * 1e6,
                     f"ref_us={t_ref*1e6:.0f};maxerr={err:.2e}"))
        assert err < 1e-4

    X = jax.random.uniform(key, (2048, 32))
    Xm = jax.random.uniform(jax.random.fold_in(key, 1), (256, 32))
    W = jax.nn.one_hot(jax.random.randint(key, (256,), 0, 16), 16)
    W = W / jnp.maximum(W.sum(0), 1.0)
    Kmm = ref.kermat_ref(Xm, Xm, gamma=8.0)
    s = jnp.einsum("mk,mn,nk->k", W, Kmm, W)
    (a_got, s_got), t = timed(ops.kmeans_assign, X, Xm, W, s, 8.0)
    a_ref, _ = ref.kmeans_assign_ref(X, Xm, W, jnp.asarray(s)[None, :], gamma=8.0)
    agree = float(jnp.mean((a_got == a_ref).astype(jnp.float32)))
    rows.append(("kernels.kmeans_assign.2048x256x16", t * 1e6,
                 f"agree={agree:.4f}"))

    y = jnp.sign(jax.random.normal(key, (2048,)))
    w = jax.random.normal(jax.random.fold_in(key, 2), (64,))
    got, t = timed(ops.cd_column_update, X, y, X[:64], w, kern)
    want = ref.cd_column_update_ref(X, y, X[:64], w, gamma=8.0)
    err = float(jnp.max(jnp.abs(got - want)))
    rows.append(("kernels.cd_update.2048x64", t * 1e6, f"maxerr={err:.2e}"))
    assert err < 1e-3
    return rows


if __name__ == "__main__":
    emit(run())
