"""Pallas kernel micro-benchmarks: interpret-mode correctness + jnp-ref
timing on this CPU container (TPU wall-clock is out of scope here; the
per-kernel roofline lives in EXPERIMENTS.md §Roofline).

Also benchmarks the conquer solver XLA vs Pallas vs cached path and emits
the BENCH_conquer.json artifact (wall time + column-cache hit rate).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, emit_json, timed
from repro.core.kernels import Kernel
from repro.core.solver import solve_box_qp_matvec
from repro.data import gaussian_mixture
from repro.kernels import ops, ref


def bench_conquer(dry_run: bool = False) -> list:
    """Conquer-path comparison: solve_box_qp_matvec on the XLA reference path
    vs the fused Pallas path vs the column-cached path, same problem, same
    tolerance.  Emits BENCH_conquer.json."""
    n, d, block, tol = (192, 8, 16, 1e-5) if dry_run else (1024, 32, 32, 1e-5)
    X, y = gaussian_mixture(jax.random.PRNGKey(0), n, d=d, modes_per_class=4,
                            spread=0.15)
    kern = Kernel("rbf", gamma=2.0)
    C = 4.0
    max_iters = 400 if dry_run else 2000

    def solve(**kw):
        return solve_box_qp_matvec(X, y, kern, C, tol=tol,
                                   max_iters=max_iters, block=block, **kw)

    variants = {
        "xla": dict(),
        "pallas": dict(use_pallas=True),
        "pallas_cache": dict(use_pallas=True, cache_cap=n),
    }
    rows, results = [], {}
    alphas = {}
    for name, kw in variants.items():
        solve(**kw).alpha.block_until_ready()     # warm (compile)
        res, t = timed(solve, **kw)
        alphas[name] = res.alpha
        entry = {"wall_s": t, "iters": int(res.iters),
                 "pg_max": float(res.pg_max)}
        derived = f"iters={int(res.iters)}"
        if res.cache_hits is not None:
            hits, misses = int(res.cache_hits), int(res.cache_misses)
            entry["cache_hits"] = hits
            entry["cache_misses"] = misses
            entry["cache_hit_rate"] = hits / max(hits + misses, 1)
            derived += f";hit_rate={entry['cache_hit_rate']:.3f}"
        results[name] = entry
        rows.append((f"conquer.{name}.{n}x{d}", t * 1e6, derived))

    max_dev = max(float(jnp.max(jnp.abs(alphas[k] - alphas["xla"])))
                  for k in variants)
    results["alpha_max_dev_vs_xla"] = max_dev
    results["problem"] = {"n": n, "d": d, "block": block, "tol": tol, "C": C,
                          "kernel": "rbf", "gamma": 2.0, "dry_run": dry_run}
    emit_json("BENCH_conquer.json", results)
    assert max_dev < 1e-4, max_dev
    return rows


def run(dry_run: bool = False) -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    kern = Kernel("rbf", gamma=8.0)
    ref_jit = jax.jit(lambda X, Y: ref.kermat_ref(X, Y, gamma=8.0))
    shapes = ((256, 256, 16),) if dry_run else ((1024, 1024, 64), (2048, 512, 128))
    for n, m, d in shapes:
        X = jax.random.uniform(jax.random.fold_in(key, n), (n, d))
        Y = jax.random.uniform(jax.random.fold_in(key, m), (m, d))
        want = ref_jit(X, Y)              # warm both paths (compile)
        got = ops.kernel_matrix(X, Y, kern)
        want, t_ref = timed(ref_jit, X, Y)
        got, t_pal = timed(ops.kernel_matrix, X, Y, kern)
        err = float(jnp.max(jnp.abs(got - want)))
        rows.append((f"kernels.kermat.{n}x{m}x{d}", t_pal * 1e6,
                     f"ref_us={t_ref*1e6:.0f};maxerr={err:.2e}"))
        assert err < 1e-4

    na = 512 if dry_run else 2048
    X = jax.random.uniform(key, (na, 32))
    Xm = jax.random.uniform(jax.random.fold_in(key, 1), (256, 32))
    W = jax.nn.one_hot(jax.random.randint(key, (256,), 0, 16), 16)
    W = W / jnp.maximum(W.sum(0), 1.0)
    Kmm = ref.kermat_ref(Xm, Xm, gamma=8.0)
    s = jnp.einsum("mk,mn,nk->k", W, Kmm, W)
    (a_got, s_got), t = timed(ops.kmeans_assign, X, Xm, W, s, 8.0)
    a_ref, _ = ref.kmeans_assign_ref(X, Xm, W, jnp.asarray(s)[None, :], gamma=8.0)
    agree = float(jnp.mean((a_got == a_ref).astype(jnp.float32)))
    rows.append((f"kernels.kmeans_assign.{na}x256x16", t * 1e6,
                 f"agree={agree:.4f}"))

    y = jnp.sign(jax.random.normal(key, (na,)))
    w = jax.random.normal(jax.random.fold_in(key, 2), (64,))
    got, t = timed(ops.cd_column_update, X, y, X[:64], w, kern)
    want = ref.cd_column_update_ref(X, y, X[:64], w, gamma=8.0)
    err = float(jnp.max(jnp.abs(got - want)))
    rows.append((f"kernels.cd_update.{na}x64", t * 1e6, f"maxerr={err:.2e}"))
    assert err < 1e-3

    v = jax.random.normal(jax.random.fold_in(key, 3), (na,))
    got, t = timed(ops.kernel_matvec, X, X, v, kern)
    want = ref.kernel_matvec_ref(X, X, v, gamma=8.0)
    err = float(jnp.max(jnp.abs(got - want))) / max(float(jnp.max(jnp.abs(want))), 1.0)
    rows.append((f"kernels.kernel_matvec.{na}x{na}", t * 1e6, f"relerr={err:.2e}"))
    assert err < 1e-4

    rows.extend(bench_conquer(dry_run))
    return rows


if __name__ == "__main__":
    emit(run())
