"""Blocked vs pairwise equality-family conquer benchmark (ISSUE-5).

Times the one-class conquer solve (``solve_eq_qp_matvec``) with the rank-2
pairwise engine (block=1) against the rank-2B blocked engine (block=B) on
both backends — on the XLA path the blocked update is a skinny
``(n, 2B) @ (2B,)`` matmul, on the Pallas path the fused rank-2B
``cd_column_update`` — plus the end-to-end multilevel one-class ``fit``
wall-clock with ``eq_block_size`` 1 vs B.  Asserts blocked/pairwise parity
on the strictly convex dual and MERGES its results into BENCH_oneclass.json
under the ``eq_block`` key (this benchmark and ``bench_oneclass`` document
the same workload).

    PYTHONPATH=src python -m benchmarks.run --only eq_block [--dry-run]
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, emit_json, timed
from repro.core import DCSVMConfig, Kernel, OneClassSVM, fit
from repro.core.solver import solve_eq_qp_matvec
from repro.data import gaussian_with_outliers, train_test_split

BLOCK = 8


def run(dry_run: bool = False) -> list:
    n, tol = (240, 1e-4) if dry_run else (1536, 1e-4)
    nu, gamma = 0.1, 4.0
    kern = Kernel("rbf", gamma=gamma)
    X, y = gaussian_with_outliers(jax.random.PRNGKey(0), n)
    Xtr, _, _, _ = train_test_split(jax.random.PRNGKey(1), X, y)
    ntr = Xtr.shape[0]
    ones = jnp.ones(ntr, Xtr.dtype)
    d = nu * ntr
    max_iters = 4_000 if dry_run else 40_000

    def solve(block, **kw):
        return solve_eq_qp_matvec(Xtr, ones, kern, 1.0, 1.0, d, tol=tol,
                                  max_iters=max_iters, block=block, **kw)

    rows, section, alphas = [], {"block": BLOCK}, {}
    for backend, kw in {"xla": dict(), "pallas": dict(use_pallas=True)}.items():
        for engine, block in {"pairwise": 1, "blocked": BLOCK}.items():
            solve(block, **kw).alpha.block_until_ready()     # warm (compile)
            res, t = timed(solve, block, **kw)
            alphas[engine, backend] = res.alpha
            feas = abs(float(np.asarray(res.alpha, np.float64).sum()) - d)
            section[f"conquer.{engine}.{backend}"] = {
                "wall_s": t, "iters": int(res.iters),
                "pg_max": float(res.pg_max), "eq_residual": feas}
            rows.append((f"eq_block.conquer.{engine}.{backend}.{ntr}",
                         t * 1e6, f"iters={int(res.iters)};eq_res={feas:.2e}"))
        # the RBF Gram is PD on distinct points: the dual optimum is unique,
        # so blocked must land on the pairwise solution
        dev = float(jnp.max(jnp.abs(alphas["blocked", backend]
                                    - alphas["pairwise", backend])))
        section[f"alpha_max_dev.{backend}"] = dev
        assert dev < 1e-3, (backend, dev)

    # end-to-end: multilevel one-class fit, rank-2 vs rank-2B cluster solves
    cfg = DCSVMConfig(kernel=kern, k=4, levels=1 if dry_run else 2,
                      m=min(500, ntr), tol=1e-3, kmeans_iters=10,
                      use_pallas=False)
    task = OneClassSVM(nu=nu)
    models = {}
    for engine, bs in {"pairwise": 1, "blocked": BLOCK}.items():
        c = dataclasses.replace(cfg, eq_block_size=bs)
        fit(c, Xtr, task=task)                               # warm (compile)
        models[engine], t = timed(lambda c=c: fit(c, Xtr, task=task))
        section[f"fit.{engine}"] = {
            "wall_s": t, "eq_block_size": bs,
            "rho": float(models[engine].rho),
            "n_sv": int(len(models[engine].sv_index))}
        rows.append((f"eq_block.fit.{engine}.{ntr}", t * 1e6,
                     f"eq_block_size={bs}"))
    rho_dev = abs(models["blocked"].rho - models["pairwise"].rho)
    section["fit_rho_dev"] = rho_dev
    assert rho_dev < 1e-2 * (1 + abs(models["pairwise"].rho)), rho_dev
    section["problem"] = {"n_train": int(ntr), "nu": nu, "gamma": gamma,
                          "tol": tol, "dry_run": dry_run}
    # BENCH_oneclass.json carries both benches; keep the other sections
    emit_json("BENCH_oneclass.json", {"eq_block": section}, merge=True)
    return rows


if __name__ == "__main__":
    emit(run())
