"""Paper Figure 2: support-vector identification per level.

Precision/recall of {i : alpha^l_i > 0} against the final SV set, per DC-SVM
level, compared with CascadeSVM's surviving set (which can only lose SVs).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import bench_dataset, emit, exact_reference, timed
from repro.baselines import train_cascade
from repro.core import DCSVMConfig, fit


def run(n: int = 2000) -> list:
    Xtr, ytr, _, _, kern, C = bench_dataset("gaussian", n)
    _, ref, _ = exact_reference(kern, C, Xtr, ytr)
    final_sv = set(np.nonzero(np.asarray(ref.alpha) > 0)[0].tolist())
    rows = []
    per_level = {}

    def cb(level, alpha, st):
        sv = set(np.nonzero(np.asarray(alpha) > 0)[0].tolist())
        per_level[level] = sv

    cfg = DCSVMConfig(kernel=kern, C=C, k=4, levels=3, m=400, tol=1e-4)
    _, dt = timed(fit, cfg, Xtr, ytr, callback=cb)
    for level in sorted(per_level, reverse=True):
        sv = per_level[level]
        prec = len(sv & final_sv) / max(len(sv), 1)
        rec = len(sv & final_sv) / max(len(final_sv), 1)
        rows.append((f"fig2.dcsvm.level{level}", dt * 1e6,
                     f"precision={prec:.3f};recall={rec:.3f};nsv={len(sv)}"))
        if level <= 1:
            assert rec > 0.85, (level, rec)

    cas, dt_c = timed(train_cascade, Xtr, ytr, kern, C, levels=3, tol=1e-4)
    sv_c = set(cas.sv_index.tolist())
    prec = len(sv_c & final_sv) / max(len(sv_c), 1)
    rec = len(sv_c & final_sv) / max(len(final_sv), 1)
    rows.append((f"fig2.cascade", dt_c * 1e6,
                 f"precision={prec:.3f};recall={rec:.3f};nsv={len(sv_c)}"))
    return rows


if __name__ == "__main__":
    emit(run())
