"""epsilon-SVR conquer benchmark: XLA vs Pallas on the generalized dual.

Solves the 2n-variable (alpha, alpha*) SVR dual of the Friedman #1
benchmark through ``solve_box_qp_matvec`` (signed weights through the fused
cd_column_update / kernel_matvec path) on both backends, then runs the full
multilevel ``fit`` + beta-form serving export.  Emits BENCH_svr.json with
wall times, backend beta parity, and test MSE vs the predict-the-mean
baseline.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, emit_json, timed
from repro.core import DCSVMConfig, EpsilonSVR, Kernel, fit, mse, predict_exact
from repro.core.solver import solve_box_qp_matvec
from repro.data import friedman1, train_test_split
from repro.launch.serve_svm import export_serving_model, serve_batch


def run(dry_run: bool = False) -> list:
    n, tol, block = (160, 1e-4, 16) if dry_run else (1024, 1e-4, 32)
    eps, C = 0.1, 4.0
    kern = Kernel("rbf", gamma=1.0)
    X, y = friedman1(jax.random.PRNGKey(0), n)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    task = EpsilonSVR(eps=eps)
    td = task.build(Xtr, ytr[None, :], C)
    s, p, cvec = td.S[0], td.P[0], td.Cvec[0]
    max_iters = 400 if dry_run else 2000

    def solve(**kw):
        return solve_box_qp_matvec(td.Xd, s, kern, cvec, tol=tol,
                                   max_iters=max_iters, block=block, p=p, **kw)

    rows, results, betas = [], {}, {}
    for name, kw in {"xla": dict(), "pallas": dict(use_pallas=True)}.items():
        solve(**kw).alpha.block_until_ready()       # warm (compile)
        res, t = timed(solve, **kw)
        betas[name] = td.collapse(res.alpha[None, :])[0]
        results[name] = {"wall_s": t, "iters": int(res.iters),
                         "pg_max": float(res.pg_max)}
        rows.append((f"svr.conquer.{name}.{2 * Xtr.shape[0]}x{Xtr.shape[1]}",
                     t * 1e6, f"iters={int(res.iters)}"))

    # beta (not the raw 2n dual) is the well-posed parity quantity: Q is
    # rank-deficient by construction on the duplicated rows
    beta_dev = float(jnp.max(jnp.abs(betas["pallas"] - betas["xla"])))
    results["beta_max_dev_vs_xla"] = beta_dev
    assert beta_dev < 1e-3, beta_dev

    # end-to-end: multilevel fit + compiled serving round trip.  ``exact``
    # serves the final model; ``early`` (eq. 11) is only meaningful with an
    # early-stopped model whose per-cluster SVRs were trained locally — an
    # exact model's beta is not cluster-separable.
    cfg = DCSVMConfig(kernel=kern, C=C, k=4, levels=1 if dry_run else 2,
                      m=min(500, Xtr.shape[0]), tol=1e-3, kmeans_iters=10,
                      use_pallas=False)
    model, t_fit = timed(lambda: fit(cfg, Xtr, ytr, task=task))
    test_mse = mse(yte, predict_exact(model, Xte))
    base_mse = float(jnp.mean((yte - jnp.mean(ytr)) ** 2))
    sm = export_serving_model(model, with_bcm=False)
    pred_exact_s, t_serve = timed(serve_batch, sm, Xte, kern, "exact")
    model_e = fit(dataclasses.replace(cfg, early_stop_level=1), Xtr, ytr,
                  task=task)
    sm_e = export_serving_model(model_e, with_bcm=False)
    pred_early_s, t_serve_e = timed(serve_batch, sm_e, Xte, kern, "early")
    results["fit"] = {"wall_s": t_fit, "n_sv": int(len(model.sv_index)),
                      "test_mse": test_mse, "baseline_mse": base_mse,
                      "serve_exact_mse": mse(yte, pred_exact_s[0]),
                      "serve_exact_wall_s": t_serve,
                      "serve_early_mse": mse(yte, pred_early_s[0]),
                      "serve_early_wall_s": t_serve_e}
    results["problem"] = {"n_train": int(Xtr.shape[0]), "dual_vars":
                          int(2 * Xtr.shape[0]), "eps": eps, "C": C,
                          "tol": tol, "block": block, "kernel": "rbf",
                          "gamma": 1.0, "dry_run": dry_run}
    assert test_mse < base_mse, (test_mse, base_mse)
    rows.append((f"svr.fit.{Xtr.shape[0]}", t_fit * 1e6,
                 f"test_mse={test_mse:.4f};baseline={base_mse:.4f}"))
    emit_json("BENCH_svr.json", results)
    return rows


if __name__ == "__main__":
    emit(run())
