"""Paper Figure 3: convergence — relative objective error vs time.

DC-SVM's objective trajectory (measured at each level boundary) against the
from-zero exact solver's final time; plus the warm-start iteration-count
ratio, the mechanism behind the paper's speedups.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import bench_dataset, emit, exact_reference
from repro.core import DCSVMConfig, fit, solve_box_qp


def run(n: int = 3000) -> list:
    Xtr, ytr, _, _, kern, C = bench_dataset("covtype_like", n)
    Q, ref, f_star = exact_reference(kern, C, Xtr, ytr, tol=1e-4)
    rows = []

    # from-zero single-coordinate CD (the LIBSVM-analogue trajectory)
    t0 = time.perf_counter()
    cold = solve_box_qp(Q, C, tol=1e-4, max_iters=500_000)
    cold.alpha.block_until_ready()
    t_cold = time.perf_counter() - t0
    rows.append(("fig3.exact_from_zero", t_cold * 1e6,
                 f"iters={int(cold.iters)};relerr=0.0"))

    # DC-SVM trajectory: objective after each level
    marks = []
    t_start = time.perf_counter()

    def cb(level, alpha, st):
        f = float(0.5 * alpha @ Q @ alpha - alpha.sum())
        marks.append((level, time.perf_counter() - t_start,
                      (f - f_star) / abs(f_star), st.get("iters")))

    cfg = DCSVMConfig(kernel=kern, C=C, k=4, levels=2, m=500, tol=1e-4)
    fit(cfg, Xtr, ytr, callback=cb)
    warm_iters = None
    for level, t, relerr, iters in marks:
        rows.append((f"fig3.dcsvm.level{level}", t * 1e6,
                     f"relerr={relerr:.2e};iters={iters}"))
        if level == 0:
            warm_iters = iters
    # the conquer step's warm start must slash the CD iteration count
    speedup = int(cold.iters) / max(int(warm_iters), 1)
    rows.append(("fig3.warmstart_iter_speedup", 0.0, f"x{speedup:.1f}"))
    assert speedup > 2.0, speedup
    # final relative error under the paper's 1e-3-style threshold
    assert marks[-1][2] < 1e-3, marks[-1]
    return rows


if __name__ == "__main__":
    emit(run())
