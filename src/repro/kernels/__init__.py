# Pallas TPU kernels for DC-SVM's compute hot-spots:
#   kermat.py        tiled kernel-matrix (Gram) computation  — O(n m d), the
#                    dominant FLOP sink of both clustering and training
#   kermatvec.py     streaming K(X, Z) @ v — the conquer-step gradient init,
#                    objective, and exact-serving matvec without materializing K
#   kmeans_assign.py fused two-step-kmeans assignment (K tile -> scores -> argmin)
#   cd_update.py     fused on-the-fly kernel-column block gradient update for
#                    the conquer-step block CD (recompute-in-VMEM; the optional
#                    device-resident column cache lives in core.colcache)
# ops.py exposes jit'd wrappers (interpret mode on CPU, compiled on TPU);
# ref.py holds the pure-jnp oracles the tests compare against.
from repro.kernels import ops, ref
