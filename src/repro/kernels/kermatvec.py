"""Fused tiled kernel-matrix x vector Pallas kernel.

out = K(X, Z) @ v without ever materializing K: each grid step computes one
(bm, bn) kernel tile in VMEM (MXU Gram matmul + VPU transform) and
immediately contracts it against the matching v tile, accumulating into the
(bm, 1) output block in f32 across the inner grid axis.  HBM traffic is
O(n d + m d + n) instead of the O(n m) a materialize-then-matvec pays —
this is the streaming-conquer replacement for the chunked ``lax.map`` in
``core.kernels.gram_matvec`` (DESIGN.md §3).

Grid order is (i, j) with j innermost: for a fixed output tile i all the
column tiles j run consecutively, so the output block stays resident in
VMEM across the accumulation (initialized at j == 0 via ``pl.when``).

VMEM per grid step (bm=bn=256, d<=3072, f32): X tile 3.0 MiB + Z tile
3.0 MiB + v/out slivers << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmv_body(x_ref, z_ref, v_ref, o_ref, *, kind: str, gamma: float,
              degree: int, coef0: float, compute_dtype=None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                       # (bm, d)
    z = z_ref[...]                                       # (bn, d)
    if compute_dtype is not None:
        # precision policy: quantize the Gram operands only — v and the
        # output block stay f32 (flash_attention idiom)
        x = x.astype(compute_dtype)
        z = z.astype(compute_dtype)
    g = jax.lax.dot_general(x, z, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if kind == "linear":
        k = g
    elif kind == "poly":
        k = (gamma * g + coef0) ** degree
    else:  # rbf
        xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[:, None]
        zz = jnp.sum(z.astype(jnp.float32) ** 2, axis=-1)[None, :]
        k = jnp.exp(-gamma * jnp.maximum(xx + zz - 2.0 * g, 0.0))
    o_ref[...] += jnp.dot(k, v_ref[...], preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "degree", "coef0", "bm", "bn",
                     "interpret", "compute_dtype"),
)
def kernel_matvec(
    X: jax.Array,
    Z: jax.Array,
    v: jax.Array,
    *,
    kind: str = "rbf",
    gamma: float = 1.0,
    degree: int = 3,
    coef0: float = 0.0,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = False,
    compute_dtype=None,
) -> jax.Array:
    """out (n,) = K(X, Z) @ v.  n % bm == 0, m % bn == 0 (ops.py pads)."""
    n, d = X.shape
    m, _ = Z.shape
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    body = functools.partial(_kmv_body, kind=kind, gamma=gamma, degree=degree,
                             coef0=coef0, compute_dtype=compute_dtype)
    out = pl.pallas_call(
        body,
        grid=(n // bm, m // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(X, Z, v[:, None])
    return out[:, 0]
