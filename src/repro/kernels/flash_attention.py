"""Flash attention (forward) Pallas kernel — fused online-softmax attention.

The dry-run showed every dense train/prefill cell is MEMORY-bound, and the
dominant bytes are the (B, H, Sq, Sk) score tensors the XLA graph round-trips
through HBM (~350 GB/layer on qwen3 train_4k).  This kernel is the
structural fix on the TPU target: scores, softmax statistics, and the
weighted accumulation all live in VMEM scratch; HBM traffic drops to
Q/K/V/O (the roofline-analytic adjustment is reported in EXPERIMENTS.md
§Perf H9 — the CPU dry-run cannot lower Pallas, so the HLO tables keep the
unfused numbers).

Tiling: grid (B*H, Sq/bq, Sk/bk), k-dim innermost ("arbitrary" semantics);
per-(q-tile) scratch: acc (bq, hd) f32, running max m and sum l.  Block
sizes default to (bq, bk) = (512, 512): VMEM per step ~(512*hd*3 + 512*512)
* 4B ~= 2.3 MiB at hd=128.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_body(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, -1e30)

    m_prev = m_ref[...]                                  # (bq, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                               # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                       # (bq, 1)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, bq: int = 512, bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (BH, Sq, hd), k/v: (BH, Sk, hd) — heads pre-flattened into batch.
    Sq % bq == 0 and Sk % bk == 0 (ops.py pads)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / np.sqrt(hd)
    body = functools.partial(_flash_body, scale=scale, causal=causal,
                             bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        body,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),    # acc
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # running sum l
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, *, causal=True):
    """Pure-jnp oracle (naive softmax attention), f32 math."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)
