"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kermat_ref(X, Y, *, kind="rbf", gamma=1.0, degree=3, coef0=0.0):
    g = X.astype(jnp.float32) @ Y.astype(jnp.float32).T
    if kind == "linear":
        return g
    if kind == "poly":
        return (gamma * g + coef0) ** degree
    xx = jnp.sum(X.astype(jnp.float32) ** 2, -1)[:, None]
    yy = jnp.sum(Y.astype(jnp.float32) ** 2, -1)[None, :]
    return jnp.exp(-gamma * jnp.maximum(xx + yy - 2 * g, 0.0))


def kmeans_assign_ref(X, Xm, W, s, *, gamma=1.0):
    k = kermat_ref(X, Xm, kind="rbf", gamma=gamma)
    scores = -2.0 * k @ W + s            # (n, kpad); padded s entries are +inf
    return jnp.argmin(scores, axis=-1).astype(jnp.int32), scores


def cd_column_update_ref(X, y, Xb, w, *, kind="rbf", gamma=1.0, degree=3,
                         coef0=0.0):
    k = kermat_ref(X, Xb, kind=kind, gamma=gamma, degree=degree, coef0=coef0)
    return y * (k @ w)


def kernel_matvec_ref(X, Z, v, *, kind="rbf", gamma=1.0, degree=3, coef0=0.0):
    k = kermat_ref(X, Z, kind=kind, gamma=gamma, degree=degree, coef0=coef0)
    return k @ v.astype(jnp.float32)
