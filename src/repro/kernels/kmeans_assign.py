"""Fused two-step-kernel-kmeans assignment Pallas kernel.

For each X tile (bm, d): compute the RBF cross-kernel tile K(Xt, Xm) (MXU),
immediately contract with the center weight matrix W (m, kpad) (second MXU
matmul), add the center self-terms s, and reduce to the per-row argmin — all
inside VMEM.  The (n, m) cross-kernel never touches HBM: this fusion removes
the dominant memory term of the O(nmd) assignment step.

VMEM per grid step (bm=256, m<=1024, d<=512, kpad=128, f32):
    Xt 0.5 MiB + Xm 2 MiB + K tile 1 MiB + W 0.5 MiB  << 16 MiB.
Outputs: scores (bm, kpad) distance-to-center, assign (bm, 1) int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_body(x_ref, xm_ref, w_ref, s_ref, scores_ref, assign_ref, *,
                 gamma: float):
    x = x_ref[...]                                     # (bm, d)
    xm = xm_ref[...]                                   # (m, d)
    g = jax.lax.dot_general(x, xm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[:, None]
    mm = jnp.sum(xm.astype(jnp.float32) ** 2, axis=-1)[None, :]
    k = jnp.exp(-gamma * jnp.maximum(xx + mm - 2.0 * g, 0.0))   # (bm, m)
    w = w_ref[...]                                     # (m, kpad)
    scores = -2.0 * jnp.dot(k, w, preferred_element_type=jnp.float32)
    scores = scores + s_ref[...]                       # (bm, kpad); pads = +inf
    scores_ref[...] = scores
    assign_ref[...] = jnp.argmin(scores, axis=-1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("gamma", "bm", "interpret"))
def kmeans_assign(
    X: jax.Array,
    Xm: jax.Array,
    W: jax.Array,
    s: jax.Array,
    *,
    gamma: float = 1.0,
    bm: int = 256,
    interpret: bool = False,
):
    """Returns (assign (n,), scores (n, kpad)).  RBF kernel only (the paper's
    clustering kernel); K(x,x)=1 is constant per row and dropped (argmin
    invariant).  s must be padded with +inf beyond the real k centers."""
    n, d = X.shape
    m, _ = Xm.shape
    kpad = W.shape[1]
    assert n % bm == 0 and s.shape == (1, kpad)
    grid = (n // bm,)
    body = functools.partial(_assign_body, gamma=gamma)
    scores, assign = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, kpad), lambda i: (0, 0)),
            pl.BlockSpec((1, kpad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, kpad), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, kpad), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(X, Xm, W, s)
    return assign[:, 0], scores
