"""Fused kernel-column block gradient update Pallas kernel.

The conquer-step block CD updates g += Q[:, idx] @ delta with Q columns
recomputed on the fly.  This kernel fuses, per X tile:

    K_tile = rbf(Xt, Xb)            (bm, B)  MXU + VPU exp
    g_out  = y_t * (K_tile @ w)     (bm, 1)  skinny MXU matmul

where w = y_b * delta and y is the generalized dual's sign vector s
(labels for C-SVC, mixed +1/-1 mirror signs for the epsilon-SVR stacked
dual — signs are data, not structure, so one kernel serves every task).
The (n, B) column block never hits HBM — only the
(n,) gradient delta does.  This is the recompute-in-VMEM replacement for
LIBSVM's kernel cache; the optional device-resident column cache that
serves fully-resident blocks without any recompute lives in
``repro.core.colcache`` (see DESIGN.md §2 for the tradeoff).

VMEM per grid step (bm=512, B<=256, d<=512): well under 4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cd_body(x_ref, y_ref, xb_ref, w_ref, o_ref, *, kind: str, gamma: float,
             degree: int, coef0: float, compute_dtype=None):
    x = x_ref[...]                                      # (bm, d)
    xb = xb_ref[...]                                    # (B, d)
    if compute_dtype is not None:
        # precision policy: quantize the Gram operands only — y, w and the
        # skinny contraction stay f32 (flash_attention idiom)
        x = x.astype(compute_dtype)
        xb = xb.astype(compute_dtype)
    g = jax.lax.dot_general(x, xb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if kind == "linear":
        k = g
    elif kind == "poly":
        k = (gamma * g + coef0) ** degree
    else:
        xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[:, None]
        bb = jnp.sum(xb.astype(jnp.float32) ** 2, axis=-1)[None, :]
        k = jnp.exp(-gamma * jnp.maximum(xx + bb - 2.0 * g, 0.0))
    w = w_ref[...]                                      # (B, 1)
    o = y_ref[...] * jnp.dot(k, w, preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "degree", "coef0", "bm", "interpret",
                     "compute_dtype"),
)
def cd_column_update(
    X: jax.Array,
    y: jax.Array,
    Xb: jax.Array,
    w: jax.Array,
    *,
    kind: str = "rbf",
    gamma: float = 1.0,
    degree: int = 3,
    coef0: float = 0.0,
    bm: int = 512,
    interpret: bool = False,
    compute_dtype=None,
) -> jax.Array:
    """Returns dg (n,) = y * (K(X, Xb) @ w).  y: (n,), w: (B,)."""
    n, d = X.shape
    B, _ = Xb.shape
    assert n % bm == 0
    body = functools.partial(_cd_body, kind=kind, gamma=gamma, degree=degree,
                             coef0=coef0, compute_dtype=compute_dtype)
    out = pl.pallas_call(
        body,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((B, d), lambda i: (0, 0)),
            pl.BlockSpec((B, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(X, y[:, None], Xb, w[:, None])
    return out[:, 0]
