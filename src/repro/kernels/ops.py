"""Public jit'd wrappers around the Pallas kernels.

Handles padding to tile multiples, dtype policy, and backend dispatch:
compiled Pallas on TPU, ``interpret=True`` (Python evaluation of the kernel
body) elsewhere — the correctness-validation mode this container uses.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import kermat as _kermat
from repro.kernels import kermatvec as _kermatvec
from repro.kernels import kmeans_assign as _assign
from repro.kernels import cd_update as _cd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(A: jax.Array, mult: int) -> Tuple[jax.Array, int]:
    n = A.shape[0]
    pad = (-n) % mult
    if pad:
        A = jnp.pad(A, ((0, pad),) + ((0, 0),) * (A.ndim - 1))
    return A, n


def _cd_static(compute_dtype, ref_dtype):
    """Normalize the precision policy to a canonical static string: ``None``
    — or a dtype equal to the data's own — keeps the exact historical kernel
    body (no cast inserted, one trace cache entry)."""
    if compute_dtype is None:
        return None
    cd = jnp.dtype(compute_dtype)
    return None if cd == jnp.dtype(ref_dtype) else str(cd)


def kernel_matrix(X: jax.Array, Y: jax.Array, kernel, bm: int = 256,
                  bn: int = 256, compute_dtype=None) -> jax.Array:
    """K(X, Y) via the tiled Pallas kernel. ``kernel`` is a core.kernels.Kernel.

    ``compute_dtype`` (e.g. "bfloat16") quantizes the operand tiles inside
    the kernel body; accumulation stays f32 (DESIGN.md §12)."""
    bm = min(bm, max(8, X.shape[0]))
    bn = min(bn, max(8, Y.shape[0]))
    Xp, n = _pad_rows(X, bm)
    Yp, m = _pad_rows(Y, bn)
    out = _kermat.kermat(
        Xp, Yp, kind=kernel.kind, gamma=float(kernel.gamma),
        degree=int(kernel.degree), coef0=float(kernel.coef0),
        bm=bm, bn=bn, interpret=_interpret(),
        compute_dtype=_cd_static(compute_dtype, X.dtype),
    )
    return out[:n, :m]


def kernel_matvec(X: jax.Array, Z: jax.Array, v: jax.Array, kernel,
                  bm: int = 256, bn: int = 256,
                  compute_dtype=None) -> jax.Array:
    """out (n,) = K(X, Z) @ v via the streaming Pallas kernel.

    Zero-padded Z rows carry zero v weights, so they contribute nothing to
    the accumulated output for every kernel kind.
    """
    bm = min(bm, max(8, X.shape[0]))
    bn = min(bn, max(8, Z.shape[0]))
    Xp, n = _pad_rows(X, bm)
    Zp, _ = _pad_rows(Z, bn)
    vp, _ = _pad_rows(v, bn)
    out = _kermatvec.kernel_matvec(
        Xp, Zp, vp, kind=kernel.kind, gamma=float(kernel.gamma),
        degree=int(kernel.degree), coef0=float(kernel.coef0),
        bm=bm, bn=bn, interpret=_interpret(),
        compute_dtype=_cd_static(compute_dtype, X.dtype),
    )
    return out[:n]


def q_rows(X: jax.Array, y: jax.Array, Xb: jax.Array, yb: jax.Array,
           kernel, bm: int = 256, bn: int = 256,
           compute_dtype=None) -> jax.Array:
    """Signed generalized-dual rows ``Q[b, :] = y_b * (K(X_b, X) ∘ y)`` of
    shape (B, n) via the tiled Pallas kernel matrix (Q is symmetric, so the
    block's rows double as its columns — the cache-refill unit shared by the
    matvec solver and the distributed conquer)."""
    Kb = kernel_matrix(Xb, X, kernel, bm=bm, bn=bn,
                       compute_dtype=compute_dtype)
    return yb[:, None] * (Kb * y[None, :])


def kmeans_assign(X: jax.Array, Xm: jax.Array, W: jax.Array, s: jax.Array,
                  gamma: float, bm: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Fused assignment. W: (m, k), s: (k,). Returns (assign (n,), scores (n, k))."""
    kreal = W.shape[1]
    kpad = max(128, -(-kreal // 128) * 128)
    Wp = jnp.pad(W, ((0, 0), (0, kpad - kreal)))
    sp = jnp.pad(s, (0, kpad - kreal), constant_values=jnp.inf)[None, :]
    bm = min(bm, max(8, X.shape[0]))
    Xp, n = _pad_rows(X, bm)
    assign, scores = _assign.kmeans_assign(
        Xp, Xm, Wp, sp, gamma=float(gamma), bm=bm, interpret=_interpret()
    )
    return assign[:n], scores[:n, :kreal]


def cd_column_update(X: jax.Array, y: jax.Array, Xb: jax.Array, w: jax.Array,
                     kernel, bm: int = 512, compute_dtype=None) -> jax.Array:
    """dg = y * (K(X, Xb) @ w) via the fused Pallas kernel.

    ``y`` is the generalized dual's sign vector ``s`` — class labels for
    C-SVC, the mixed (+1, -1) mirror signs of epsilon-SVR's duplicated-row
    dual — and ``w = s_b * delta``; both are plain data, so every task flows
    through the same kernel (parity pinned for non-tile-aligned SVR shapes
    in tests/test_conquer_pallas.py).
    """
    bm = min(bm, max(8, X.shape[0]))
    Xp, n = _pad_rows(X, bm)
    yp, _ = _pad_rows(y, bm)
    out = _cd.cd_column_update(
        Xp, yp, Xb, w, kind=kernel.kind, gamma=float(kernel.gamma),
        degree=int(kernel.degree), coef0=float(kernel.coef0),
        bm=bm, interpret=_interpret(),
        compute_dtype=_cd_static(compute_dtype, X.dtype),
    )
    return out[:n]
