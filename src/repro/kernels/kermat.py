"""Tiled kernel-matrix Pallas kernel.

K(X, Y) for X:(n,d), Y:(m,d) computed in (bm, bn) output tiles.  Each grid
step loads an (bm, d) X-tile and (bn, d) Y-tile into VMEM, runs the Gram
matmul on the MXU (f32 accumulation via preferred_element_type) and fuses the
kernel transform (exp / polynomial) on the VPU before writing the tile back —
the TPU adaptation of LIBSVM's kernel-row computation: recompute beats cache
at 197 TFLOP/s.

VMEM budget per grid step (bm=bn=256, d<=3072, f32):
    X tile 256*3072*4 = 3.0 MiB, Y tile 3.0 MiB, out 0.25 MiB  << 16 MiB.
MXU alignment: bm, bn multiples of 128; d padded to a multiple of 8 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kermat_body(x_ref, y_ref, o_ref, *, kind: str, gamma: float, degree: int,
                 coef0: float, compute_dtype=None):
    x = x_ref[...]
    y = y_ref[...]
    if compute_dtype is not None:
        # precision policy (flash_attention idiom): low-precision operand
        # tiles feed the MXU, accumulation stays f32 via
        # preferred_element_type; the rbf norms below square the *quantized*
        # tiles in f32 so the sqdist expansion cancels consistently
        x = x.astype(compute_dtype)
        y = y.astype(compute_dtype)
    g = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                        # (bm, bn) MXU
    if kind == "linear":
        o = g
    elif kind == "poly":
        o = (gamma * g + coef0) ** degree
    else:  # rbf
        xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[:, None]
        yy = jnp.sum(y.astype(jnp.float32) ** 2, axis=-1)[None, :]
        sq = jnp.maximum(xx + yy - 2.0 * g, 0.0)
        o = jnp.exp(-gamma * sq)
    o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "degree", "coef0", "bm", "bn",
                     "interpret", "compute_dtype"),
)
def kermat(
    X: jax.Array,
    Y: jax.Array,
    *,
    kind: str = "rbf",
    gamma: float = 1.0,
    degree: int = 3,
    coef0: float = 0.0,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = False,
    compute_dtype=None,
) -> jax.Array:
    """K(X, Y) -> (n, m). n % bm == 0, m % bn == 0 (ops.py pads)."""
    n, d = X.shape
    m, _ = Y.shape
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    grid = (n // bm, m // bn)
    body = functools.partial(_kermat_body, kind=kind, gamma=gamma,
                             degree=degree, coef0=coef0,
                             compute_dtype=compute_dtype)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(X, Y)
