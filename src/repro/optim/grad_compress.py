"""Error-feedback gradient compression for cross-replica reduction.

For the multi-pod mesh, the "pod" axis rides the (slow) DCN: compressing the
cross-pod gradient exchange is the classic distributed-optimization trick.
``compressed_psum`` implements an int8 + per-block-scale quantized all-reduce
under shard_map: quantize locally -> all_gather int8 payloads (+f32 scales)
-> dequantize-sum locally.  Bytes on the wire drop ~4x vs f32 psum (~2x vs
bf16).  ``compress_ef`` maintains the error-feedback residual that makes
quantized SGD/Adam provably convergent (the residual re-enters the next
step's gradient).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array

BLOCK = 256


def _pad_to_block(x: Array) -> Tuple[Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def compress(x: Array) -> Tuple[Array, Array]:
    """Blockwise symmetric int8 quantization. Returns (q int8, scales f32)."""
    flat, _ = _pad_to_block(x)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress(q: Array, scale: Array, shape) -> Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_ef(g: Array, residual: Array) -> Tuple[Array, Array, Array]:
    """Error-feedback compression: quantize (g + residual), carry the error.
    Returns (q, scale, new_residual)."""
    corrected = g + residual
    q, scale = compress(corrected)
    approx = decompress(q, scale, g.shape)
    return q, scale, corrected - approx


def compressed_psum(x_stacked: Array, mesh: Mesh, axis: str) -> Array:
    """Quantized all-reduce over ``axis``: int8 all_gather + local dequant-sum.

    ``x_stacked`` has a leading dim of size mesh.shape[axis] — one gradient
    per axis member (e.g. each pod's locally-reduced gradient).  Returns the
    same shape with every slice holding the (quantized) sum.
    """
    shape = x_stacked.shape[1:]
    n = 1
    for d in shape:
        n *= d

    def local(xl):                                     # xl: (1, ...)
        q, s = compress(xl[0])
        qg = lax.all_gather(q, axis)                   # (P, nblk, BLOCK) int8
        sg = lax.all_gather(s, axis)                   # (P, nblk)
        deq = qg.astype(jnp.float32) * sg[..., None]
        total = jnp.sum(deq, axis=0).reshape(-1)
        return total[:n].reshape(shape)[None]

    from repro.compat import shard_map
    fn = shard_map(local, mesh=mesh,
                   in_specs=P(axis, *(None,) * len(shape)),
                   out_specs=P(axis, *(None,) * len(shape)))
    return fn(x_stacked)
