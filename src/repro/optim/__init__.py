from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, opt_state_decls
from repro.optim.schedule import cosine_schedule
from repro.optim.grad_compress import (
    compress_ef,
    compressed_psum,
    decompress,
)
