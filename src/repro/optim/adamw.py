"""AdamW with global-norm clipping and mixed-precision master weights.

Memory posture (the 1000-node story): every large parameter matrix is
2D-sharded (embed-dim over "data", heads/mlp/vocab/expert over "model") via
its ParamDecl axes, so m/v/master simply INHERIT the param sharding and land
at N*12/chips bytes per chip — the ZeRO-3-like placement GSPMD gives for free
when weights are fully sharded (the forward/backward all-gathers one layer's
weights at a time out of the scan).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import ParamDecl

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True       # keep fp32 master copy for bf16 params


def _wants_master(cfg: AdamWConfig, param_dtype) -> bool:
    # a master copy only exists for reduced-precision params; for f32 params
    # it would alias the params themselves (and break donation)
    return cfg.master_fp32 and jnp.dtype(param_dtype) != jnp.float32


def opt_state_decls(cfg: AdamWConfig, decls,
                    param_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Decl tree for the optimizer state (same logical axes as params)."""
    def f32(d: ParamDecl) -> ParamDecl:
        return ParamDecl(d.shape, d.axes, init="zeros", dtype=jnp.float32)

    is_decl = lambda x: isinstance(x, ParamDecl)
    state = {
        "m": jax.tree.map(f32, decls, is_leaf=is_decl),
        "v": jax.tree.map(f32, decls, is_leaf=is_decl),
        "step": ParamDecl((), (), init="zeros", dtype=jnp.int32),
    }
    if _wants_master(cfg, param_dtype):
        state["master"] = jax.tree.map(f32, decls, is_leaf=is_decl)
    return state


def adamw_init(cfg: AdamWConfig, params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    dtypes = {p.dtype for p in jax.tree.leaves(params)}
    if cfg.master_fp32 and dtypes != {jnp.dtype(jnp.float32)}:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(
    cfg: AdamWConfig, grads, state: Dict[str, Any], params, lr: Array,
) -> Tuple[Any, Dict[str, Any], Dict[str, Array]]:
    """One AdamW step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    ref = state.get("master", params)

    def upd(g, m, v, p_ref):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p_ref
        new_ref = p_ref - lr * delta
        return m, v, new_ref

    flat_g = jax.tree.leaves(grads)
    tdef = jax.tree.structure(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_r = jax.tree.leaves(ref)
    new_m, new_v, new_r = [], [], []
    for g, m, v, r in zip(flat_g, flat_m, flat_v, flat_r):
        m2, v2, r2 = upd(g, m, v, r.astype(jnp.float32))
        new_m.append(m2)
        new_v.append(v2)
        new_r.append(r2)
    new_m = jax.tree.unflatten(tdef, new_m)
    new_v = jax.tree.unflatten(tdef, new_v)
    new_ref = jax.tree.unflatten(tdef, new_r)

    old_dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda r, dt: r.astype(dt), new_ref, old_dtypes)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_ref
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
