"""Roofline terms from a compiled (dry-run) artifact.

    compute    = HLO_FLOPs_total / (chips * peak_FLOPs)
    memory     = HLO_bytes_total / (chips * HBM_bw)
    collective = collective_bytes_per_device / link_bw

Sources: ``compiled.cost_analysis()`` supplies flops and bytes for the
PER-DEVICE partitioned module (SPMD): totals are per-device x chips, so the
chips cancel — we compute the terms directly from per-device numbers.
collective bytes are parsed from the partitioned HLO text (shapes there are
already per-device): sum of output bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, with a 2x multiplier on
all-reduce (ring AR moves ~2x payload per device).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link
    chips: int = 256


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.:  %ag = bf16[16,1024,512]{2,1,0} all-gather(%x), replica_groups=...
_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum per-device collective payload bytes from partitioned HLO text."""
    per_kind: Dict[str, float] = {k: 0.0 for k in _MULT}
    counts: Dict[str, int] = {k: 0 for k in _MULT}
    for line in hlo_text.splitlines():
        if ("all-gather" not in line and "all-reduce" not in line
                and "reduce-scatter" not in line and "all-to-all" not in line
                and "collective-permute" not in line):
            continue
        if "-done(" in line:
            continue                     # count the -start only
        m = _COLL_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            per_kind[kind] += _shape_bytes(dtype, dims) * _MULT[kind]
            counts[kind] += 1
            continue
        m = _TUPLE_COLL_RE.search(line)
        if m:
            kind = m.group(2)
            tot = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(1)))
            # tuple shapes of -start ops list (input, output): halve
            per_kind[kind] += 0.5 * tot * _MULT[kind]
            counts[kind] += 1
    total = float(sum(per_kind.values()))
    return {"total_bytes": total, "per_kind": per_kind, "counts": counts}


def roofline_terms(cost: Dict[str, float], coll_bytes: float,
                   hw: HW = HW()) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / hw.peak_flops
    t_memory = bytes_ / hw.hbm_bw
    t_coll = coll_bytes / hw.link_bw
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "coll_bytes_per_device": coll_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom[1],
        "t_bound_s": dom[0],
    }


def measure_compiled(compiled, hlo_text: Optional[str] = None) -> Dict[str, float]:
    """Raw per-device (flops, bytes, collective bytes) of one compiled program.

    CAVEAT (measured, see EXPERIMENTS.md): XLA cost_analysis counts a
    while-loop body ONCE regardless of trip count, so for scanned layer
    stacks these are UNDER-counts.  The dry-run corrects them with shallow
    unrolled probe compiles (probe_correct below)."""
    cost = compiled.cost_analysis() or {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": coll["total_bytes"],
        "coll_counts": coll["counts"],
        "coll_per_kind": coll["per_kind"],
    }


def probe_correct(probe1: Dict[str, float], probe2: Dict[str, float],
                  trips: int) -> Dict[str, float]:
    """Linear depth extrapolation from unrolled depth-1/depth-2 probes:
    body = p2 - p1;   total(L) = p1 + body * (L - 1)."""
    out = {}
    for k in ("flops", "bytes", "coll_bytes"):
        body = max(probe2[k] - probe1[k], 0.0)
        out[k] = probe1[k] + body * (trips - 1)
    return out


def summarize_cell(compiled, model_flops_total: float, hw: HW = HW(),
                   hlo_text: Optional[str] = None,
                   corrected: Optional[Dict[str, float]] = None,
                   kind: str = "train",
                   param_bytes: float = 0.0,
                   cache_bytes: float = 0.0) -> Dict[str, Any]:
    """Full roofline record for one compiled cell.

    ``corrected`` (from probe_correct) overrides the raw scanned-module
    counts for the three terms; the raw counts are kept for reference."""
    raw = measure_compiled(compiled, hlo_text)
    use = dict(raw)
    if corrected is not None:
        use.update(corrected)
    terms = roofline_terms({"flops": use["flops"], "bytes accessed": use["bytes"]},
                           use["coll_bytes"], hw)
    hlo_flops_total = terms["flops_per_device"] * hw.chips

    # kind-aware ideal time: training/prefill are compute-referenced
    # (model FLOPs at fleet peak); decode is bandwidth-referenced (params +
    # cache must stream from HBM once per token).
    t_ideal_compute = model_flops_total / (hw.chips * hw.peak_flops)
    t_ideal_bw = (param_bytes + cache_bytes) / hw.chips / hw.hbm_bw
    t_ideal = t_ideal_bw if kind == "decode" else t_ideal_compute
    terms.update({
        "raw_counts": raw,
        "collectives": {"counts": raw["coll_counts"],
                        "per_kind": raw["coll_per_kind"]},
        "model_flops_total": model_flops_total,
        "hlo_flops_total": hlo_flops_total,
        "useful_flop_frac": (model_flops_total / hlo_flops_total
                             if hlo_flops_total > 0 else 0.0),
        "t_ideal_s": t_ideal,
        "ideal_reference": "hbm_bw" if kind == "decode" else "compute_peak",
        "roofline_frac": (t_ideal / terms["t_bound_s"]
                          if terms["t_bound_s"] > 0 else 0.0),
    })
    try:
        mem = compiled.memory_analysis()
        terms["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:                                    # pragma: no cover
        terms["memory_analysis"] = {"error": str(e)}
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training (N active params, D tokens);
    2*N*D for inference (per forward)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch
