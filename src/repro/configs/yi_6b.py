"""yi-6b [arXiv:2403.04652] — llama-architecture GQA.

32L, d_model=4096, 32H (GQA kv=4), d_ff=11008, vocab=64000.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=5_000_000.0,
    train_microbatches=4,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv=2, d_ff=256,
        vocab=512, param_dtype="float32", activ_dtype="float32", remat="none",
    )
