"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B].

24L, d_model=1024, 16H (kv=16, MHA), d_ff=2816, vocab=151936; QKV bias.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    train_microbatches=2,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv=4, d_ff=256,
        vocab=512, param_dtype="float32", activ_dtype="float32", remat="none",
    )
