"""internvl2-26b [arXiv:2404.16821] — InternViT + InternLM2 VLM.

LM backbone only per the brief: 48L, d_model=6144, 48H (GQA kv=8),
d_ff=16384, vocab=92553.  The InternViT frontend is a STUB:
``input_specs`` provides precomputed patch embeddings (num_patches tokens,
counted inside the cell's seq_len); the LM loss masks patch positions.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=16384,
    vocab=92553,
    num_patches=1024,           # ViT stub output tokens (448px / 14 patch)
    rope_theta=1_000_000.0,
    train_microbatches=16,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=192, n_heads=6, n_kv=2, d_ff=384,
        vocab=512, num_patches=16,
        param_dtype="float32", activ_dtype="float32", remat="none",
    )
