"""whisper-medium [arXiv:2212.04356] — encoder-decoder audio model.

24 encoder + 24 decoder layers, d_model=1024, 16H (MHA), d_ff=4096,
vocab=51865.  The conv audio frontend is a STUB per the brief:
``input_specs`` supplies precomputed frame embeddings (B, 1500, d_model).
Deviation from the released checkpoints (DESIGN.md): decoder positions are
sinusoidal (not a learned 448-slot table) so the assigned decode_32k cell is
well-defined.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,                # decoder layers
    enc_layers=24,
    enc_dec=True,
    enc_frames=1500,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=4096,
    vocab=51865,
    mlp="gelu",
    tie_embeddings=True,
    norm_eps=1e-5,
    train_microbatches=4,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, enc_layers=2, enc_frames=64, d_model=128,
        n_heads=4, n_kv=4, d_ff=256, vocab=512,
        param_dtype="float32", activ_dtype="float32", remat="none",
    )
