"""Architecture + run-shape configuration and the config registry.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` whose
``CONFIG`` is a ``ModelConfig`` with the exact published hyper-parameters,
plus a ``reduced()`` variant for CPU smoke tests.  Shapes (the assigned
seq-len x batch cells) live here as ``SHAPES``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0            # shared (always-on) experts, DeepSeek-style
    d_expert: int = 0              # expert hidden dim (0 -> d_ff)
    every: int = 1                 # MoE every N layers (others dense)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor_m: float = 2.0     # mLSTM up-projection
    proj_factor_s: float = 1.334   # sLSTM ffn factor
    chunk: int = 64                # chunkwise-parallel mLSTM chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma: scale embeddings by sqrt(d_model)
    moe: Optional[MoEConfig] = None
    # layer stacking: an optional explicit prefix + a repeating period of
    # (mixer, ffn) slots; None period -> [("attn", "moe"|"dense")]
    prefix_pattern: Tuple[Tuple[str, str], ...] = ()
    period_pattern: Optional[Tuple[Tuple[str, str], ...]] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # dtypes: full configs run bf16 params/activations (the dry-run numbers);
    # reduced smoke configs switch to f32 for CPU numerics
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"
    # encoder-decoder (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_frames: int = 1500         # stub audio frontend output length
    # VLM stub frontend
    num_patches: int = 0           # >0: input_specs provides patch embeddings
    # training details
    remat: str = "full"            # full | dots | none
    scan_layers: bool = True
    sub_quadratic: bool = False    # True for SSM/hybrid/linear archs (long_500k)
    # gradient-accumulation microbatches for the train_4k cell, sized so the
    # per-chip activation temp fits v5e's 16 GiB HBM (§Perf H7)
    train_microbatches: int = 1

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        from repro.models.model import build_decls_any
        from repro.models.param import count_params
        return count_params(build_decls_any(self))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        from repro.models.lm import build_plan
        plan = build_plan(self)
        m = self.moe
        d_e = m.d_expert or self.d_ff
        n_moe = plan.n_periods * sum(1 for p in plan.period if p.ffn == "moe")
        n_moe += sum(1 for p in plan.prefix if p.ffn == "moe")
        per_expert = 3 * self.d_model * d_e
        inactive = n_moe * (m.num_experts - m.top_k) * per_expert
        return total - inactive


@dataclasses.dataclass(frozen=True)
class RunShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, RunShape] = {
    "train_4k": RunShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": RunShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": RunShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": RunShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "jamba_v01_52b",
    "qwen15_05b",
    "qwen3_8b",
    "gemma_2b",
    "yi_6b",
    "deepseek_moe_16b",
    "phi35_moe_42b",
    "internvl2_26b",
    "xlstm_125m",
    "whisper_medium",
]

# external ids (with dashes/dots) -> module names
ALIASES = {
    "jamba-v0.1-52b": "jamba_v01_52b",
    "qwen1.5-0.5b": "qwen15_05b",
    "qwen3-8b": "qwen3_8b",
    "gemma-2b": "gemma_2b",
    "yi-6b": "yi_6b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "internvl2-26b": "internvl2_26b",
    "xlstm-125m": "xlstm_125m",
    "whisper-medium": "whisper_medium",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced() if reduced else mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: RunShape) -> Tuple[bool, str]:
    """The brief's skip rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k requires sub-quadratic attention (skip per brief)"
    return True, ""
