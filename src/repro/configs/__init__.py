from repro.configs.base import (
    ALIASES,
    ARCH_IDS,
    SHAPES,
    MambaConfig,
    MoEConfig,
    ModelConfig,
    RunShape,
    XLSTMConfig,
    get_config,
    shape_applicable,
)
