"""deepseek-moe-16b [arXiv:2401.06066] — fine-grained MoE.

28L, d_model=2048, 16H (MHA kv=16), vocab=102400; fine-grained experts:
64 routed (top-6) + 2 shared, expert hidden 1408; first layer is a dense
FFN (intermediate 10944) per the DeepSeekMoE paper.
"""
import dataclasses

from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=10944,                 # dense first layer intermediate
    vocab=102400,
    prefix_pattern=(("attn", "dense"),),
    period_pattern=(("attn", "moe"),),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    rope_theta=10_000.0,
    train_microbatches=4,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv=4, d_ff=384,
        vocab=512,
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_expert=64),
        param_dtype="float32", activ_dtype="float32", remat="none",
    )
