"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32H (GQA kv=8), expert hidden 6400, vocab=32064;
16 experts, top-2 routing (every layer).
"""
import dataclasses

from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=6400,
    vocab=32064,
    moe=MoEConfig(num_experts=16, top_k=2),
    rope_theta=10_000.0,
    train_microbatches=16,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv=2, d_ff=256,
        vocab=512, moe=MoEConfig(num_experts=4, top_k=2),
        param_dtype="float32", activ_dtype="float32", remat="none",
    )
