"""qwen3-8b [hf:Qwen/Qwen3-8B].

36L, d_model=4096, 32H (GQA kv=8, head_dim=128), d_ff=12288, vocab=151936;
qk_norm (RMSNorm on per-head q/k).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    train_microbatches=4,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=256, vocab=512, param_dtype="float32", activ_dtype="float32",
        remat="none",
    )
