"""xlstm-125m [arXiv:2405.04517].

12L, d_model=768, 4 heads, vocab=50304; alternating mLSTM (matrix-memory,
chunkwise-parallel) and sLSTM (scalar-memory, recurrent) blocks; no separate
FFN on mLSTM blocks (d_ff=0 in the assignment — the block's own projections
carry the capacity); sLSTM blocks carry a small GELU FFN per the paper.
Attention-free: runs the long_500k cell.
"""
import dataclasses

from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    period_pattern=(("mlstm", "none"), ("slstm", "none")),
    xlstm=XLSTMConfig(proj_factor_m=2.0, proj_factor_s=1.334, chunk=64),
    sub_quadratic=True,
    train_microbatches=2,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv=4, vocab=512,
        param_dtype="float32", activ_dtype="float32", remat="none",
    )
