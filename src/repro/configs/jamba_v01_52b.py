"""jamba-v0.1-52b [arXiv:2403.19887; hf].

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536; hybrid
Mamba:attention 7:1 interleave (one attention layer per 8-layer period);
MoE 16 experts top-2 on every second layer.  Sub-quadratic (runs long_500k).
"""
import dataclasses

from repro.configs.base import MambaConfig, MoEConfig, ModelConfig

_PERIOD = (
    ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("attn", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=65536,
    rope_theta=0.0,            # jamba uses no positional encoding (mamba mixes)
    period_pattern=_PERIOD,
    moe=MoEConfig(num_experts=16, top_k=2, every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    sub_quadratic=True,
    train_microbatches=16,
)
# note: rope_theta=0.0 is a sentinel meaning "no rope on attention layers"?
# jamba DOES apply no explicit positional embedding; we keep rope on the 4
# attention layers (theta 1e4) to match common jamba reimplementations:
CONFIG = dataclasses.replace(CONFIG, rope_theta=10_000.0)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=8, d_model=128, n_heads=8, n_kv=2, d_ff=256, vocab=512,
        moe=MoEConfig(num_experts=4, top_k=2, every=2),
        param_dtype="float32", activ_dtype="float32", remat="none",
    )
