"""gemma-2b [arXiv:2403.08295].

18L, d_model=2048, 8H with MQA (kv=1), head_dim=256, d_ff=16384 (GeGLU),
vocab=256000; tied embeddings scaled by sqrt(d_model).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    mlp="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
    train_microbatches=2,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv=1, head_dim=32,
        d_ff=256, vocab=512, param_dtype="float32", activ_dtype="float32",
        remat="none",
    )
