"""Deterministic synthetic datasets for the DC-SVM experiments.

covtype/webspam/mnist8m are not downloadable in this offline container, so the
benchmark datasets are generators with matched *structural* properties:
multi-modal class-conditional densities (so kernel kmeans finds real
structure), non-linearly-separable boundaries (so the RBF kernel matters), and
controllable margin/noise.  All generators are pure functions of a PRNG key —
restart-safe and reproducible by construction.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array


def gaussian_mixture(
    key: Array,
    n: int,
    d: int = 10,
    modes_per_class: int = 8,
    spread: float = 0.18,
    label_noise: float = 0.0,
) -> Tuple[Array, Array]:
    """Each class is a mixture of ``modes_per_class`` Gaussians in [0,1]^d.

    The mode structure is what DC-SVM's kernel kmeans discovers; with RBF
    gamma ~ O(1/spread^2) the cross-cluster kernel mass D(pi) is small, the
    regime the paper's Theorem 1 targets.
    """
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    centers = jax.random.uniform(k1, (2 * modes_per_class, d))
    mode = jax.random.randint(k2, (n,), 0, 2 * modes_per_class)
    X = centers[mode] + spread * jax.random.normal(k3, (n, d))
    y = jnp.where(mode < modes_per_class, 1.0, -1.0)
    if label_noise > 0:
        flip = jax.random.bernoulli(k4, label_noise, (n,))
        y = jnp.where(flip, -y, y)
    X = jnp.clip(X, 0.0, 1.0).astype(jnp.float32)
    return X, y.astype(jnp.float32)


def gaussian_mixture_multiclass(
    key: Array,
    n: int,
    n_classes: int = 3,
    d: int = 10,
    modes_per_class: int = 4,
    spread: float = 0.12,
) -> Tuple[Array, Array]:
    """Multiclass analogue of ``gaussian_mixture``: class c is a mixture of
    ``modes_per_class`` Gaussians; labels are integers 0..n_classes-1 (the
    one-vs-all DC-SVM workload)."""
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.uniform(k1, (n_classes * modes_per_class, d))
    mode = jax.random.randint(k2, (n,), 0, n_classes * modes_per_class)
    X = centers[mode] + spread * jax.random.normal(k3, (n, d))
    y = mode // modes_per_class
    X = jnp.clip(X, 0.0, 1.0).astype(jnp.float32)
    return X, y.astype(jnp.int32)


def gaussian_mixture_imbalanced(
    key: Array,
    n: int,
    d: int = 10,
    modes_per_class: int = 4,
    spread: float = 0.15,
    pos_frac: float = 0.05,
) -> Tuple[Array, Array]:
    """Imbalanced binary mixture: the +1 class is a ~``pos_frac`` minority
    (default ~1:20) drawn from its own Gaussian modes.  The cost-sensitive
    ``WeightedCSVC`` workload: an unweighted hinge happily sacrifices
    minority recall here; ``c_i = C * w_{y_i}`` buys it back.  Split with
    ``stratified_split`` so tiny test minorities stay represented.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    centers = jax.random.uniform(k1, (2 * modes_per_class, d))
    is_pos = jax.random.bernoulli(k2, pos_frac, (n,))
    mode = jax.random.randint(k3, (n,), 0, modes_per_class)
    mode = jnp.where(is_pos, mode, mode + modes_per_class)
    X = centers[mode] + spread * jax.random.normal(k4, (n, d))
    y = jnp.where(is_pos, 1.0, -1.0)
    X = jnp.clip(X, 0.0, 1.0).astype(jnp.float32)
    return X, y.astype(jnp.float32)


def gaussian_with_outliers(
    key: Array,
    n: int,
    d: int = 6,
    modes: int = 3,
    spread: float = 0.06,
    outlier_frac: float = 0.05,
) -> Tuple[Array, Array]:
    """Anomaly-detection mixture: inliers from ``modes`` tight Gaussians
    (centers inside [0.25, 0.75]^d), outliers uniform over [0,1]^d.

    The one-class SVM workload: labels are +1 (inlier) / -1 (outlier) and
    are for EVALUATION only — training is label-free (the standard
    contaminated setting: the outliers stay in the training set, and
    ``nu`` should cover the expected contamination).  With a tight
    ``spread`` the uniform outliers land far from every mode with
    overwhelming probability in d >= 4.
    """
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    centers = jax.random.uniform(k1, (modes, d)) * 0.5 + 0.25
    is_out = jax.random.bernoulli(k2, outlier_frac, (n,))
    mode = jax.random.randint(k3, (n,), 0, modes)
    Xin = centers[mode] + spread * jax.random.normal(k4, (n, d))
    Xout = jax.random.uniform(k5, (n, d))
    X = jnp.where(is_out[:, None], Xout, Xin)
    y = jnp.where(is_out, -1.0, 1.0)
    return X.astype(jnp.float32), y.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Regression generators (the epsilon-SVR workload)
# ---------------------------------------------------------------------------

def sinc1d(key: Array, n: int, noise: float = 0.05,
           x_range: Tuple[float, float] = (-3.0, 3.0)) -> Tuple[Array, Array]:
    """1-D sinc regression y = sin(pi x)/(pi x) + noise — the classic SVR
    smoke test: smooth, bounded targets, visually checkable fit."""
    k1, k2 = jax.random.split(key)
    X = jax.random.uniform(k1, (n, 1), minval=x_range[0], maxval=x_range[1])
    y = jnp.sinc(X[:, 0]) + noise * jax.random.normal(k2, (n,))
    return X.astype(jnp.float32), y.astype(jnp.float32)


def friedman1(key: Array, n: int, d: int = 10, noise: float = 0.1,
              standardize: bool = True) -> Tuple[Array, Array]:
    """Friedman #1 (Friedman, 1991): x ~ U[0,1]^d (d >= 5; coordinates past
    the fifth are irrelevant distractors) and

        y = 10 sin(pi x1 x2) + 20 (x3 - 1/2)^2 + 10 x4 + 5 x5 + noise.

    ``standardize`` rescales y to zero mean / unit variance (empirically,
    per draw) so one epsilon/C setting works across sizes.
    """
    if d < 5:
        raise ValueError(f"friedman1 needs d >= 5, got {d}")
    k1, k2 = jax.random.split(key)
    X = jax.random.uniform(k1, (n, d))
    y = (10.0 * jnp.sin(jnp.pi * X[:, 0] * X[:, 1])
         + 20.0 * (X[:, 2] - 0.5) ** 2 + 10.0 * X[:, 3] + 5.0 * X[:, 4])
    y = y + noise * jax.random.normal(k2, (n,))
    if standardize:
        y = (y - jnp.mean(y)) / jnp.maximum(jnp.std(y), 1e-8)
    return X.astype(jnp.float32), y.astype(jnp.float32)


def checkerboard(key: Array, n: int, cells: int = 4, noise: float = 0.02) -> Tuple[Array, Array]:
    """2-D checkerboard — the classic RBF-SVM stress test (no linear model
    can exceed chance; local structure is everything)."""
    k1, k2 = jax.random.split(key)
    X = jax.random.uniform(k1, (n, 2))
    ix = jnp.floor(X[:, 0] * cells).astype(jnp.int32)
    iy = jnp.floor(X[:, 1] * cells).astype(jnp.int32)
    y = jnp.where((ix + iy) % 2 == 0, 1.0, -1.0)
    X = X + noise * jax.random.normal(k2, (n, 2))
    return X.astype(jnp.float32), y.astype(jnp.float32)


def two_spirals(key: Array, n: int, noise: float = 0.05, turns: float = 1.75) -> Tuple[Array, Array]:
    k1, k2 = jax.random.split(key)
    m = n // 2
    t = jnp.sqrt(jax.random.uniform(k1, (m,))) * turns * 2 * jnp.pi
    r = t / (turns * 2 * jnp.pi)
    x1 = jnp.stack([r * jnp.cos(t), r * jnp.sin(t)], 1)
    x2 = -x1
    X = jnp.concatenate([x1, x2], 0) + noise * jax.random.normal(k2, (2 * m, 2))
    y = jnp.concatenate([jnp.ones(m), -jnp.ones(m)])
    X = (X + 1.2) / 2.4   # scale into ~[0,1]^2 like the paper's preprocessing
    return X.astype(jnp.float32), y.astype(jnp.float32)


def covtype_like(key: Array, n: int) -> Tuple[Array, Array]:
    """Stand-in for covtype: 54-dim, many modes, moderate class overlap."""
    return gaussian_mixture(key, n, d=54, modes_per_class=16, spread=0.12,
                            label_noise=0.02)


def webspam_like(key: Array, n: int) -> Tuple[Array, Array]:
    """Stand-in for webspam: 254-dim sparse-ish features, clustered."""
    k1, k2 = jax.random.split(key)
    X, y = gaussian_mixture(k1, n, d=254, modes_per_class=10, spread=0.10)
    # sparsify: zero out ~70% of coordinates (webspam features are sparse)
    mask = jax.random.bernoulli(k2, 0.3, X.shape)
    return (X * mask).astype(jnp.float32), y


def train_test_split(key: Array, X: Array, y: Array, test_frac: float = 0.2):
    n = X.shape[0]
    perm = jax.random.permutation(key, n)
    nt = int(n * (1.0 - test_frac))
    tr, te = perm[:nt], perm[nt:]
    return X[tr], y[tr], X[te], y[te]


def stratified_split(key: Array, X: Array, y: Array, test_frac: float = 0.2):
    """Per-class train/test split: each label keeps ~``test_frac`` of its
    points in the test set.  Essential for heavily imbalanced data
    (``gaussian_mixture_imbalanced``), where a plain random split can leave
    the minority class absent from one side."""
    y_np = np.asarray(y)
    key_sh_tr, key_sh_te, key_cls = jax.random.split(key, 3)
    tr_parts, te_parts = [], []
    for i, label in enumerate(np.unique(y_np)):
        idx = np.nonzero(y_np == label)[0]
        perm = np.asarray(jax.random.permutation(
            jax.random.fold_in(key_cls, i), len(idx)))
        nt = max(1, int(len(idx) * (1.0 - test_frac)))
        tr_parts.append(idx[perm[:nt]])
        te_parts.append(idx[perm[nt:]])
    tr = np.concatenate(tr_parts)
    te = np.concatenate(te_parts)
    # reshuffle so class blocks don't stay contiguous
    tr = tr[np.asarray(jax.random.permutation(key_sh_tr, len(tr)))]
    te = te[np.asarray(jax.random.permutation(key_sh_te, len(te)))]
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    return X[tr], y[tr], X[te], y[te]
