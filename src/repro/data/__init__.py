from repro.data.synthetic import (
    gaussian_mixture,
    gaussian_mixture_imbalanced,
    gaussian_mixture_multiclass,
    gaussian_with_outliers,
    checkerboard,
    two_spirals,
    covtype_like,
    webspam_like,
    sinc1d,
    friedman1,
    train_test_split,
    stratified_split,
)
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
