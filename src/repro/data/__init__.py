from repro.data.synthetic import (
    gaussian_mixture,
    gaussian_mixture_multiclass,
    checkerboard,
    two_spirals,
    covtype_like,
    webspam_like,
    train_test_split,
)
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
