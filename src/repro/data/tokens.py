"""Deterministic, restart-safe synthetic token pipeline for LM training.

Every batch is a pure function of (seed, step): after a restart (or an
elastic re-shard onto a different mesh) the pipeline regenerates exactly the
same global batch and slices out the host's shard — no data-loader state to
checkpoint beyond the integer ``step`` itself.  This is the property a real
deterministic loader (e.g. grain with a fixed index sampler) provides; here
the tokens are synthesized from a mixture of Zipfian unigrams and repeated
n-gram motifs so the LM loss is non-trivial (learnable structure).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    motif_len: int = 16          # repeated n-gram length (gives learnable structure)
    motif_prob: float = 0.5


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg

    @partial(jax.jit, static_argnums=0)
    def global_batch_at(self, step: Array) -> Tuple[Array, Array]:
        """(tokens, targets), each (global_batch, seq_len), for a given step."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        B, T, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        # Zipfian unigram draw via inverse-CDF on exponential spacings
        u = jax.random.uniform(k1, (B, T), minval=1e-6, maxval=1.0)
        zipf = jnp.clip((u ** (-1.0 / 1.1) - 1.0), 0, V - 1).astype(jnp.int32)
        # motif channel: tile a per-sequence motif across the sequence
        motif = jax.random.randint(k2, (B, cfg.motif_len), 0, V)
        reps = -(-T // cfg.motif_len)
        tiled = jnp.tile(motif, (1, reps))[:, :T]
        use_motif = jax.random.bernoulli(k3, cfg.motif_prob, (B, T))
        tokens = jnp.where(use_motif, tiled, zipf)
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        return tokens, targets

    def host_shard_at(self, step: int, shard: int, num_shards: int) -> Tuple[Array, Array]:
        """Slice this host's rows out of the deterministic global batch."""
        tokens, targets = self.global_batch_at(jnp.asarray(step))
        B = self.cfg.global_batch
        rows = B // num_shards
        s = shard * rows
        return tokens[s : s + rows], targets[s : s + rows]
