"""Atomic, keep-K pytree checkpointing (fault-tolerance substrate).

Design for the 1000-node posture:
  * atomic publish: write to ``<dir>/tmp.<step>``, fsync, rename — a crash
    mid-save never corrupts the latest checkpoint;
  * keep-K rotation + ``latest`` manifest: restart resumes from the newest
    complete step with no coordinator;
  * resharding-on-load: arrays are stored DEVICE-AGNOSTIC (numpy); the loader
    re-places them under the *current* mesh's shardings, so an elastic
    restart onto a different mesh shape Just Works (PartitionSpecs are by
    axis name, not device index);
  * async save: the host-side serialization runs on a background thread so
    the training loop only pays for the device->host copy.

On a real multi-host pod each host writes its process-local shards (orbax
style); this container is single-process so the gather is trivial — the
interface (save/restore/latest_step) is the deployment-relevant part.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import numpy as np
import jax


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "fiub":             # bf16/fp8 etc: store as f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)


def load_pytree(path: str, target) -> Any:
    """Load into the structure of ``target`` (values replaced, dtypes cast).
    ``target`` may contain ShapeDtypeStructs or arrays."""
    with np.load(path, allow_pickle=False) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = [data[jax.tree_util.keystr(p)].astype(l.dtype)
                  for p, l in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- paths -------------------------------------------------------------
    def _step_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}.npz")

    def _manifest(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def steps(self):
        if not os.path.exists(self._manifest()):
            return []
        with open(self._manifest()) as f:
            return sorted(json.load(f)["steps"])

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, blocking: Optional[bool] = None) -> None:
        self.wait()                        # one in-flight save at a time
        host_tree = _flatten(tree)         # device->host copy happens NOW

        def work():
            tmp = os.path.join(self.dir, f".tmp_{step}.npz")
            np.savez(tmp, **host_tree)
            os.replace(tmp, self._step_path(step))
            steps = [s for s in self.steps() if s != step] + [step]
            steps = sorted(steps)
            dropped = steps[: max(0, len(steps) - self.keep)]
            steps = steps[max(0, len(steps) - self.keep):]
            with open(self._manifest() + ".tmp", "w") as f:
                json.dump({"steps": steps, "time": time.time()}, f)
            os.replace(self._manifest() + ".tmp", self._manifest())
            for s in dropped:
                try:
                    os.remove(self._step_path(s))
                except FileNotFoundError:
                    pass

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ---------------------------------------------------------------
    def restore(self, target, step: Optional[int] = None,
                shardings=None) -> Any:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        tree = load_pytree(self._step_path(step), target)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree
