"""Transformer building blocks: norms, RoPE, GQA/MQA attention, gated MLPs.

All layer parameter groups are declared STACKED over a leading layer axis so
the model assembly can `lax.scan` over layers (small HLO => fast 512-way SPMD
compiles; required for this container's single-core dry-runs and good
practice at scale).

Attention is q-chunked (scan over query blocks, f32 softmax): peak score
memory O(B * chunk * S) instead of O(B * S^2), which is what lets the
prefill_32k cells fit.  Decode attends one token against a (B, Smax, Hkv, hd)
cache with a length mask.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.param import ParamDecl
from repro.models.sharding import MeshCtx, maybe_constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# norms & embeddings
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def sinusoidal_positions(length: int, d: int, dtype=jnp.float32) -> Array:
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10_000 ** (2 * dim / d))
    ang = pos * inv
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_apply(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd), positions: (..., S). NeoX-style half rotation."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_decls(cfg, L: int) -> Dict[str, ParamDecl]:
    D, hd = cfg.d_model, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv
    d = {
        "wq": ParamDecl((L, D, Hq * hd), ("layers", "embed", "heads")),
        "wk": ParamDecl((L, D, Hkv * hd), ("layers", "embed", "heads")),
        "wv": ParamDecl((L, D, Hkv * hd), ("layers", "embed", "heads")),
        "wo": ParamDecl((L, Hq * hd, D), ("layers", "heads", "embed")),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDecl((L, Hq * hd), ("layers", "heads"), init="zeros")
        d["bk"] = ParamDecl((L, Hkv * hd), ("layers", "heads"), init="zeros")
        d["bv"] = ParamDecl((L, Hkv * hd), ("layers", "heads"), init="zeros")
    if cfg.qk_norm:
        d["q_scale"] = ParamDecl((L, hd), ("layers", None), init="ones")
        d["k_scale"] = ParamDecl((L, hd), ("layers", None), init="ones")
    return d


def _project_qkv(p, x, cfg, positions):
    B, S, D = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_scale"], cfg.norm_eps)
        k = rmsnorm(k, p["k_scale"], cfg.norm_eps)
    if positions is not None:                  # rope (None for whisper)
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool, chunk: int = 1024,
    q_offset: int = 0,
    ctx: Optional[MeshCtx] = None,
) -> Array:
    """Scan over query chunks; full K/V per chunk; f32 softmax.

    q: (B, Sq, Hq, hd), k/v: (B, Sk, Hkv, hd) with Hq = G * Hkv.
    Peak memory O(B * chunk * Hq * Sk) — the piece that makes 32k prefill fit.
    """
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(hd)
    chunk = min(chunk, Sq)
    pad_q = (-Sq) % chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nch = (Sq + pad_q) // chunk

    qc = jnp.moveaxis(q.reshape(B, nch, chunk, Hkv, G, hd), 1, 0)
    kpos = jnp.arange(Sk)

    def one(carry, args):
        qi, i = args                                   # (B, chunk, Hkv, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qi, k).astype(jnp.float32) * scale
        if causal:
            qpos = q_offset + i * chunk + jnp.arange(chunk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
        return carry, o

    _, out = jax.lax.scan(one, None, (qc, jnp.arange(nch)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq + pad_q, Hq, hd)
    return out[:, :Sq]


def attn_apply(
    p: Dict[str, Array], x: Array, cfg, positions: Array, *,
    causal: bool = True, chunk: int = 1024, ctx: Optional[MeshCtx] = None,
) -> Array:
    """Full-sequence attention (train / prefill)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    # NOTE §Perf H3: no explicit q/k constraints here — the projections are
    # already head-sharded by the weight sharding; extra constraints forced
    # GSPMD into 0.25GiB resharding all-gathers per layer.
    out = chunked_attention(q, k, v, causal=causal, chunk=chunk, ctx=ctx)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def attn_prefill(p, x, cfg, positions, *, chunk=1024, ctx=None):
    """Like attn_apply but also returns (k, v) for cache construction."""
    B, S, D = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = chunked_attention(q, k, v, causal=True, chunk=chunk, ctx=ctx)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    # keep the emitted cache sharded as it accumulates through the scan
    # (otherwise the stacked ys materialize batch-sharded only)
    kv_axes = (("batch", None, "heads", None) if cfg.n_kv >= 16
               else ("batch", "kv_seq", None, None))
    k = maybe_constrain(ctx, k, *kv_axes)
    v = maybe_constrain(ctx, v, *kv_axes)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), (k, v)


def attn_decode(
    p: Dict[str, Array], x: Array, cfg, pos: Array,
    cache_k: Array, cache_v: Array, *, ctx: Optional[MeshCtx] = None,
) -> Tuple[Array, Array, Array]:
    """One-token decode. x: (B, 1, D); cache_k/v: (B, Smax, Hkv, hd);
    pos: scalar current position. Returns (out, cache_k, cache_v)."""
    B, _, D = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    G = Hq // Hkv
    qh = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qh, cache_k).astype(jnp.float32)
    s = s / np.sqrt(hd)
    Smax = cache_k.shape[1]
    mask = jnp.arange(Smax)[None, None, None, :] <= pos
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w.astype(cache_v.dtype), cache_v)
    o = o.reshape(B, 1, Hq * hd)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_decls(cfg, L: int, d_ff: Optional[int] = None) -> Dict[str, ParamDecl]:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w1": ParamDecl((L, D, F), ("layers", "embed", "mlp")),
            "w3": ParamDecl((L, D, F), ("layers", "embed", "mlp")),
            "w2": ParamDecl((L, F, D), ("layers", "mlp", "embed")),
        }
    return {   # plain gelu (whisper)
        "w1": ParamDecl((L, D, F), ("layers", "embed", "mlp")),
        "b1": ParamDecl((L, F), ("layers", "mlp"), init="zeros"),
        "w2": ParamDecl((L, F, D), ("layers", "mlp", "embed")),
        "b2": ParamDecl((L, D), ("layers", None), init="zeros"),
    }


def mlp_apply(p: Dict[str, Array], x: Array, cfg, ctx: Optional[MeshCtx] = None) -> Array:
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("bsd,df->bsf", x, p["w1"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w3"])
        h = maybe_constrain(ctx, h, "batch", None, "mlp")
        return jnp.einsum("bsf,fd->bsd", h, p["w2"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"])
    return jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"]
