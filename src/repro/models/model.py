"""Unified model dispatch (decoder-only LM vs encoder-decoder) + input specs.

``batch_specs(cfg, shape)`` is the single source of truth for what each
(arch x run-shape) cell feeds the lowered program — ShapeDtypeStructs only
(dry-run rule: no allocation).  Modality frontends are stubs per the brief:
whisper gets precomputed frame embeddings, internvl2 gets precomputed patch
embeddings (counted inside seq_len).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import lm as LM
from repro.models.sharding import MeshCtx

Array = jax.Array


def build_decls_any(cfg):
    return ED.build_decls(cfg) if cfg.enc_dec else LM.build_decls(cfg)


def loss_fn(cfg, params, batch: Dict[str, Array], *, ctx: Optional[MeshCtx] = None,
            chunk: int = 1024):
    if cfg.enc_dec:
        return ED.loss(cfg, params, batch, ctx=ctx, chunk=chunk)
    return LM.lm_loss(cfg, params, batch, ctx=ctx, chunk=chunk)


def forward_prefill(cfg, params, batch: Dict[str, Array], S_max: int, *,
                    ctx: Optional[MeshCtx] = None, chunk: int = 1024):
    """Prefill program: full-sequence forward that builds the serving cache."""
    if cfg.enc_dec:
        return ED.prefill(cfg, params, batch["frames"], batch["tokens"], S_max,
                          ctx=ctx, chunk=chunk)
    logits, _, cache = LM.forward(cfg, params, batch["tokens"],
                                  prefix_embeds=batch.get("prefix_embeds"),
                                  ctx=ctx, chunk=chunk, mode="prefill")
    return logits[:, -1:], cache


def cache_decls_any(cfg, B: int, S_max: int):
    if cfg.enc_dec:
        return ED.cache_decls(cfg, B, S_max)
    return LM.cache_decls(cfg, B, S_max)


def decode_step_any(cfg, params, cache, tokens: Array, pos: Array, *,
                    ctx: Optional[MeshCtx] = None):
    if cfg.enc_dec:
        return ED.decode_step(cfg, params, cache, tokens, pos, ctx=ctx)
    return LM.decode_step(cfg, params, cache, tokens, pos, ctx=ctx)


def batch_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a run-shape cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    adt = jnp.dtype(cfg.activ_dtype)
    D = cfg.d_model

    if shape.kind in ("train", "prefill"):
        if cfg.enc_dec:
            specs = {
                "frames": jax.ShapeDtypeStruct((B, cfg.enc_frames, D), adt),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
        elif cfg.num_patches > 0:
            text = S - cfg.num_patches
            assert text > 0, (S, cfg.num_patches)
            specs = {
                "prefix_embeds": jax.ShapeDtypeStruct((B, cfg.num_patches, D), adt),
                "tokens": jax.ShapeDtypeStruct((B, text), i32),
            }
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct(specs["tokens"].shape, i32)
        return specs

    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
