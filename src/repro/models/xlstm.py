"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential recurrence) [Beck et al., 2405.04517].

mLSTM is computed in its chunkwise-parallel form (exact): a lax.scan over
sequence chunks carries the stabilized (C, n, m) state; within a chunk the
contribution is a small causal quadratic — O(S*c) memory, O(1)-state decode.
sLSTM is a true recurrence (h_{t-1} feeds the gates) and runs as a lax.scan
over time steps; decode is a single step of the same cell.

Both are attention-free: the xlstm arch runs the long_500k decode cell.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.param import ParamDecl
from repro.models.sharding import MeshCtx, maybe_constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_dims(cfg) -> Tuple[int, int, int]:
    di = int(cfg.xlstm.proj_factor_m * cfg.d_model)
    di = -(-di // cfg.n_heads) * cfg.n_heads
    return di, cfg.n_heads, di // cfg.n_heads


def mlstm_decls(cfg, L: int) -> Dict[str, ParamDecl]:
    D = cfg.d_model
    di, H, hd = mlstm_dims(cfg)
    return {
        "wup": ParamDecl((L, D, 2 * di), ("layers", "embed", "heads")),
        "wq": ParamDecl((L, di, di), ("layers", "heads", None)),
        "wk": ParamDecl((L, di, di), ("layers", "heads", None)),
        "wv": ParamDecl((L, di, di), ("layers", "heads", None)),
        "wif": ParamDecl((L, di, 2 * H), ("layers", "heads", None),
                         init="normal", scale=0.02),
        "bif": ParamDecl((L, 2 * H), ("layers", None), init="zeros"),
        "wdown": ParamDecl((L, di, D), ("layers", "heads", "embed")),
    }


class MLSTMState(NamedTuple):
    C: Array   # (B, H, hd, hd)
    n: Array   # (B, H, hd)
    m: Array   # (B, H)


def init_mlstm_state(cfg, B: int, dtype=jnp.float32) -> MLSTMState:
    _, H, hd = mlstm_dims(cfg)
    return MLSTMState(jnp.zeros((B, H, hd, hd), dtype),
                      jnp.zeros((B, H, hd), dtype),
                      jnp.full((B, H), -1e30, dtype))


def _mlstm_qkvif(p, x, cfg):
    B, S, D = x.shape
    di, H, hd = mlstm_dims(cfg)
    uz = jnp.einsum("bsd,de->bse", x, p["wup"])
    u, z = jnp.split(uz, 2, axis=-1)
    q = jnp.einsum("bsi,ij->bsj", u, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsi,ij->bsj", u, p["wk"]).reshape(B, S, H, hd) / np.sqrt(hd)
    v = jnp.einsum("bsi,ij->bsj", u, p["wv"]).reshape(B, S, H, hd)
    gates = jnp.einsum("bsi,ig->bsg", u, p["wif"]) + p["bif"]
    ig, fg = jnp.split(gates.astype(jnp.float32), 2, axis=-1)   # (B, S, H)
    logf = jax.nn.log_sigmoid(fg)
    return q, k, v, ig, logf, z


def mlstm_apply(p: Dict[str, Array], x: Array, cfg,
                ctx: Optional[MeshCtx] = None,
                state: Optional[MLSTMState] = None) -> Array:
    """Full-sequence chunkwise mLSTM. x: (B, S, D)."""
    B, S, D = x.shape
    di, H, hd = mlstm_dims(cfg)
    c = min(cfg.xlstm.chunk, S)
    assert S % c == 0, (S, c)
    nch = S // c
    q, k, v, ig, logf, z = _mlstm_qkvif(p, x, cfg)
    if state is None:
        state = init_mlstm_state(cfg, B)

    # chunk views, scan axis leading: (nch, B, c, H, ...)
    def chunked(a):
        return jnp.moveaxis(a.reshape(B, nch, c, *a.shape[2:]), 1, 0)

    qc, kc, vc, igc, logfc = map(chunked, (q, k, v, ig, logf))

    def step(carry, args):
        C, n, m = carry                                # (B,H,hd,hd),(B,H,hd),(B,H)
        qi, ki, vi, igi, lfi = args                    # (B,c,H,...)
        F = jnp.cumsum(lfi, axis=1)                    # (B,c,H) inclusive
        a_t = F                                         # cum log-forget at t
        b_s = igi - F                                   # i_s - F_s
        # intra-chunk gate logits D[t,s] = F_t + i_s - F_s  (s <= t)
        Dlog = a_t[:, :, None, :] + b_s[:, None, :, :]  # (B,c,c,H)
        causal = jnp.tril(jnp.ones((c, c), bool))
        Dlog = jnp.where(causal[None, :, :, None], Dlog, -jnp.inf)
        g_t = a_t + m[:, None, :]                       # state logit (B,c,H)
        m_t = jnp.maximum(jnp.max(Dlog, axis=2), g_t)   # (B,c,H)
        m_t = jnp.maximum(m_t, -1e30)
        w_intra = jnp.exp(Dlog - m_t[:, :, None, :])    # (B,c,c,H)
        w_state = jnp.exp(g_t - m_t)                    # (B,c,H)

        scores = jnp.einsum("bthd,bshd->btsh", qi, ki).astype(jnp.float32)
        wts = w_intra * scores                          # (B,c,c,H)
        num_intra = jnp.einsum("btsh,bshd->bthd", wts, vi.astype(jnp.float32))
        num_state = jnp.einsum("bhde,bthe->bthd",
                               C.astype(jnp.float32), qi.astype(jnp.float32))
        num = num_intra + w_state[..., None] * num_state
        den_intra = jnp.sum(wts, axis=2)                # (B,c,H)
        den_state = jnp.einsum("bhd,bthd->bth", n.astype(jnp.float32),
                               qi.astype(jnp.float32))
        den = den_intra + w_state * den_state
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h = (num / denom[..., None]).astype(x.dtype)    # (B,c,H,hd)

        # ---- state update to chunk end -------------------------------
        Fc = F[:, -1:, :]                               # (B,1,H) total log-forget
        m_new = jnp.maximum(Fc[:, 0] + m, jnp.max(igi + (Fc - F), axis=1))
        w_old = jnp.exp(Fc[:, 0] + m - m_new)           # (B,H)
        w_s = jnp.exp(igi + (Fc - F) - m_new[:, None, :])   # (B,c,H)
        C_new = w_old[..., None, None] * C.astype(jnp.float32) + \
            jnp.einsum("bsh,bshd,bshe->bhde", w_s, vi.astype(jnp.float32),
                       ki.astype(jnp.float32))
        n_new = w_old[..., None] * n.astype(jnp.float32) + \
            jnp.einsum("bsh,bshd->bhd", w_s, ki.astype(jnp.float32))
        return (C_new.astype(C.dtype), n_new.astype(n.dtype),
                m_new.astype(m.dtype)), h

    (_, _, _), hs = jax.lax.scan(step, tuple(state), (qc, kc, vc, igc, logfc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di)
    out = h * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", out, p["wdown"])


def mlstm_decode(p: Dict[str, Array], x: Array, cfg, state: MLSTMState,
                 ctx: Optional[MeshCtx] = None) -> Tuple[Array, MLSTMState]:
    """One-token decode via the exact recurrence. x: (B, 1, D)."""
    B, _, D = x.shape
    di, H, hd = mlstm_dims(cfg)
    q, k, v, ig, logf, z = _mlstm_qkvif(p, x, cfg)
    qi, ki, vi = q[:, 0], k[:, 0], v[:, 0]              # (B,H,hd)
    igi, lfi = ig[:, 0], logf[:, 0]                     # (B,H)
    C, n, m = state
    m_new = jnp.maximum(lfi + m, igi)
    w_old = jnp.exp(lfi + m - m_new)
    w_in = jnp.exp(igi - m_new)
    Cf = w_old[..., None, None] * C.astype(jnp.float32) + \
        w_in[..., None, None] * jnp.einsum("bhd,bhe->bhde",
                                           vi.astype(jnp.float32),
                                           ki.astype(jnp.float32))
    nf = w_old[..., None] * n.astype(jnp.float32) + \
        w_in[..., None] * ki.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", Cf, qi.astype(jnp.float32))
    den = jnp.einsum("bhd,bhd->bh", nf, qi.astype(jnp.float32))
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    h = (num / denom[..., None]).astype(x.dtype).reshape(B, 1, di)
    out = h * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", out, p["wdown"])
    return out, MLSTMState(Cf.astype(C.dtype), nf.astype(n.dtype),
                           m_new.astype(m.dtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_decls(cfg, L: int) -> Dict[str, ParamDecl]:
    D = cfg.d_model
    H = cfg.n_heads
    hd = D // H
    F = int(cfg.xlstm.proj_factor_s * D)
    return {
        "wx": ParamDecl((L, D, 4 * D), ("layers", "embed", "heads")),
        "rh": ParamDecl((L, H, hd, 4 * hd), ("layers", "heads", None, None),
                        init="normal", scale=0.05),
        "b": ParamDecl((L, 4 * D), ("layers", None), init="zeros"),
        "f_w1": ParamDecl((L, D, F), ("layers", "embed", "mlp")),
        "f_w2": ParamDecl((L, F, D), ("layers", "mlp", "embed")),
    }


class SLSTMState(NamedTuple):
    c: Array   # (B, D)
    n: Array   # (B, D)
    h: Array   # (B, D)
    m: Array   # (B, D)


def init_slstm_state(cfg, B: int, dtype=jnp.float32) -> SLSTMState:
    D = cfg.d_model
    return SLSTMState(jnp.zeros((B, D), dtype), jnp.zeros((B, D), dtype),
                      jnp.zeros((B, D), dtype), jnp.full((B, D), -1e30, dtype))


def _slstm_cell(p, xt: Array, state: SLSTMState, cfg) -> Tuple[SLSTMState, Array]:
    """One sLSTM step. xt: (B, D)."""
    B, D = xt.shape
    H = cfg.n_heads
    hd = D // H
    hprev = state.h.reshape(B, H, hd)
    # block-diagonal recurrence per head, regrouped to the (B, 4D) gate layout
    rec = jnp.einsum("bhe,hef->bhf", hprev, p["rh"])            # (B,H,4hd)
    rec = rec.reshape(B, H, 4, hd).transpose(0, 2, 1, 3).reshape(B, 4 * D)
    gates = (jnp.einsum("bd,dg->bg", xt, p["wx"]) + rec + p["b"]).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(gates, 4, axis=-1)               # (B, D) each
    zt = jnp.tanh(zt)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + state.m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(logf + state.m - m_new)
    c_new = f_s * state.c.astype(jnp.float32) + i_s * zt
    n_new = f_s * state.n.astype(jnp.float32) + i_s
    h_new = jax.nn.sigmoid(ot) * (c_new / jnp.maximum(n_new, 1e-6))
    st = SLSTMState(c_new.astype(state.c.dtype), n_new.astype(state.n.dtype),
                    h_new.astype(state.h.dtype), m_new.astype(state.m.dtype))
    return st, h_new.astype(xt.dtype)


def slstm_apply(p: Dict[str, Array], x: Array, cfg,
                ctx: Optional[MeshCtx] = None,
                state: Optional[SLSTMState] = None) -> Array:
    """Sequential scan over time. x: (B, S, D)."""
    B, S, D = x.shape
    if state is None:
        state = init_slstm_state(cfg, B)

    def step(st, xt):
        st, h = _slstm_cell(p, xt, st, cfg)
        return st, h

    _, hs = jax.lax.scan(step, state, jnp.moveaxis(x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                                  # (B, S, D)
    out = h + jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["f_w1"])) @ p["f_w2"]
    return out


def slstm_decode(p: Dict[str, Array], x: Array, cfg, state: SLSTMState,
                 ctx: Optional[MeshCtx] = None) -> Tuple[Array, SLSTMState]:
    st, h = _slstm_cell(p, x[:, 0], state, cfg)
    h = h[:, None, :]
    out = h + jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["f_w1"])) @ p["f_w2"]
    return out, st
