"""Parameter declaration trees.

A model's parameters are declared once as a pytree of ``ParamDecl`` leaves
(shape + logical sharding axes + initializer).  Three interpreters consume
the same tree, guaranteeing init/abstract/sharding stay in sync:

    init_tree(decls, key)          -> concrete params (deterministic per-path keys)
    abstract_tree(decls)           -> ShapeDtypeStructs (dry-run: NO allocation)
    spec_tree(decls, rules)        -> PartitionSpecs via logical->mesh-axis rules

Logical axis names ("embed", "vocab", "heads", "mlp", "expert", ...) decouple
model code from mesh shape: the same config lowers on the 16x16 single-pod
mesh and the 2x16x16 multi-pod mesh just by swapping the rule table
(elastic-scaling posture: re-shard on mesh change, no model-code edits).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Array = jax.Array
MeshAxis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis name per dim
    init: str = "fan_in"                     # fan_in|zeros|ones|normal|embed
    scale: Optional[float] = None            # stddev override
    dtype: Optional[Any] = None              # None -> param_dtype at init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def _leaves_with_paths(decls):
    return jax.tree_util.tree_flatten_with_path(decls, is_leaf=_is_decl)


def _init_one(decl: ParamDecl, key: Array, param_dtype) -> Array:
    dtype = decl.dtype or param_dtype
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, dtype)
    if decl.init == "normal":
        std = decl.scale if decl.scale is not None else 0.02
        return (std * jax.random.normal(key, decl.shape)).astype(dtype)
    if decl.init == "embed":
        std = decl.scale if decl.scale is not None else 1.0
        return (std * jax.random.normal(key, decl.shape)).astype(dtype)
    # fan_in: stddev = scale / sqrt(fan_in); fan_in = second-to-last dim
    fan_in = decl.shape[-2] if len(decl.shape) >= 2 else decl.shape[-1]
    std = (decl.scale if decl.scale is not None else 1.0) / np.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, decl.shape)).astype(dtype)


def init_tree(decls, key: Array, param_dtype=jnp.float32):
    """Materialize parameters. Keys are derived from the flattened path order
    (stable under tree extension at the end, deterministic across runs)."""
    leaves, treedef = _leaves_with_paths(decls)
    out = []
    for i, (path, decl) in enumerate(leaves):
        sub = jax.random.fold_in(key, i)
        out.append(_init_one(decl, sub, param_dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_tree(decls, param_dtype=jnp.float32):
    """ShapeDtypeStructs for .lower() — the dry-run path, zero allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or param_dtype),
        decls, is_leaf=_is_decl,
    )


def spec_tree(decls, rules: Mapping[str, MeshAxis]):
    """PartitionSpecs from logical axes through the rule table."""
    def one(d: ParamDecl) -> PartitionSpec:
        return PartitionSpec(*(rules.get(a) if a is not None else None
                               for a in d.axes))
    return jax.tree.map(one, decls, is_leaf=_is_decl)


def sharding_tree(decls, mesh: Mesh, rules: Mapping[str, MeshAxis]):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        spec_tree(decls, rules),
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def count_params(decls) -> int:
    leaves = jax.tree.leaves(decls, is_leaf=_is_decl)
    return int(sum(np.prod(d.shape) for d in leaves))


def zeros_like_tree(decls, param_dtype=jnp.float32):
    return jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype or param_dtype),
                        decls, is_leaf=_is_decl)
