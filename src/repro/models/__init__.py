from repro.models import layers, lm, encdec, model, moe, param, sharding, ssm, xlstm
