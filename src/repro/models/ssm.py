"""Mamba (selective SSM) block — Jamba's sequence mixer.

Training/prefill uses a parallel associative scan over the sequence
(O(S log S) depth, exact); decode carries (conv window, ssm state) and costs
O(1) per token — which is why the hybrid arch runs the long_500k cell while
pure-attention archs skip it.

TP sharding: the inner dimension (d_inner = expand * d_model) is sharded over
the "heads"/model axis; the scan itself is local to each shard (state is
per-channel), so the layer needs no collectives beyond the in/out projections
— the TPU-friendly property of channel-factored SSMs.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.param import ParamDecl
from repro.models.sharding import MeshCtx, maybe_constrain

Array = jax.Array


def mamba_dims(cfg) -> Tuple[int, int, int, int]:
    mc = cfg.mamba
    d_inner = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return d_inner, mc.d_state, mc.d_conv, dt_rank


def mamba_decls(cfg, L: int) -> Dict[str, ParamDecl]:
    D = cfg.d_model
    di, N, dc, dtr = mamba_dims(cfg)
    return {
        "in_proj": ParamDecl((L, D, 2 * di), ("layers", "embed", "heads")),
        "conv_w": ParamDecl((L, dc, di), ("layers", None, "heads"),
                            init="normal", scale=0.1),
        "conv_b": ParamDecl((L, di), ("layers", "heads"), init="zeros"),
        "x_proj": ParamDecl((L, di, dtr + 2 * N), ("layers", "heads", None)),
        "dt_proj": ParamDecl((L, dtr, di), ("layers", None, "heads"),
                             init="normal", scale=0.1),
        "dt_bias": ParamDecl((L, di), ("layers", "heads"), init="zeros"),
        "A_log": ParamDecl((L, di, N), ("layers", "heads", None), init="ones"),
        "D_skip": ParamDecl((L, di), ("layers", "heads"), init="ones"),
        "out_proj": ParamDecl((L, di, D), ("layers", "heads", "embed")),
    }


class MambaState(NamedTuple):
    conv: Array   # (B, d_conv - 1, d_inner) rolling input window
    ssm: Array    # (B, d_inner, d_state)


def init_mamba_state(cfg, B: int, dtype=jnp.float32) -> MambaState:
    di, N, dc, _ = mamba_dims(cfg)
    return MambaState(jnp.zeros((B, dc - 1, di), dtype),
                      jnp.zeros((B, di, N), dtype))


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along S. x: (B, S, di), w: (dc, di)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(dc))
    return out + b


def _ssm_scan(deltaA: Array, deltaBx: Array) -> Array:
    """h_t = deltaA_t * h_{t-1} + deltaBx_t via associative scan over axis 1."""
    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (deltaA, deltaBx), axis=1)
    return h


def mamba_apply(p: Dict[str, Array], x: Array, cfg,
                ctx: Optional[MeshCtx] = None,
                seq_chunk: int = 4096,
                return_state: bool = False):
    """Full-sequence forward. x: (B, S, D).

    The selective scan runs CHUNKED over the sequence (lax.scan over chunks
    carrying the (B, di, N) state; parallel associative scan within a chunk):
    the (B, S, di, N) discretized tensors never materialize for the full
    sequence — peak memory O(B * chunk * di * N), which is what lets the
    jamba prefill_32k/train cells fit HBM (§Perf follow-up to H7).
    """
    B, S, D = x.shape
    di, N, dc, dtr = mamba_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"]))
    xc = maybe_constrain(ctx, xc, "batch", None, "heads")

    dbc = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"])
    dt, Bc, Cc = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    delta = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"])
                            + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (di, N)

    c = min(seq_chunk, S)
    if S % c != 0:
        c = S
    nch = S // c

    def chunked(a):   # (B, S, ...) -> (nch, B, c, ...)
        return jnp.moveaxis(a.reshape(B, nch, c, *a.shape[2:]), 1, 0)

    def step(h_prev, args):
        d_c, bc_c, xc_c, cc_c = args                           # (B, c, ...)
        dA = jnp.exp(d_c.astype(jnp.float32)[..., None] * A)   # (B,c,di,N)
        dBx = (d_c * xc_c).astype(jnp.float32)[..., None] * \
            bc_c.astype(jnp.float32)[:, :, None, :]
        # fold the carried state into the first element of the chunk
        dBx = dBx.at[:, 0].add(dA[:, 0] * h_prev)
        h = _ssm_scan(dA, dBx)                                 # (B,c,di,N)
        y = jnp.einsum("bsin,bsn->bsi", h, cc_c.astype(jnp.float32))
        return h[:, -1], y.astype(x.dtype)

    h0 = jnp.zeros((B, di, N), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, (chunked(delta), chunked(Bc),
                                         chunked(xc), chunked(Cc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    y = y + p["D_skip"] * xc
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        window = xin[:, -(dc - 1):, :]
        state = MambaState(window.astype(x.dtype), h_last.astype(x.dtype))
        return out, state
    return out


def mamba_decode(p: Dict[str, Array], x: Array, cfg, state: MambaState,
                 ctx: Optional[MeshCtx] = None) -> Tuple[Array, MambaState]:
    """One-token decode. x: (B, 1, D). O(1) state update."""
    B, _, D = x.shape
    di, N, dc, dtr = mamba_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    xin, z = jnp.split(xz, 2, axis=-1)                          # (B, di)
    window = jnp.concatenate([state.conv, xin[:, None, :]], axis=1)  # (B, dc, di)
    xc = jnp.einsum("bci,ci->bi", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    new_conv = window[:, 1:, :]

    dbc = jnp.einsum("bi,ir->br", xc, p["x_proj"])
    dt, Bc, Cc = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    delta = jax.nn.softplus(jnp.einsum("br,ri->bi", dt, p["dt_proj"])
                            + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(delta.astype(jnp.float32)[..., None] * A)       # (B, di, N)
    dBx = (delta * xc).astype(jnp.float32)[..., None] * \
        Bc.astype(jnp.float32)[:, None, :]
    h = state.ssm.astype(jnp.float32) * dA + dBx
    y = jnp.einsum("bin,bn->bi", h, Cc.astype(jnp.float32)).astype(x.dtype)
    y = y + p["D_skip"] * xc
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    return out, MambaState(new_conv.astype(state.conv.dtype),
                           h.astype(state.ssm.dtype))
