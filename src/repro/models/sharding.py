"""Mesh context: logical-axis -> mesh-axis resolution with divisibility guards.

Model code names activation/parameter dims logically ("batch", "heads",
"mlp", ...).  ``MeshCtx`` resolves them against a concrete mesh, silently
dropping a mesh axis when the dim is not divisible by it (e.g. MQA's single
KV head cannot shard over the 16-way model axis — it stays replicated).
This keeps one model definition valid on any mesh shape (elastic posture).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxis = Union[None, str, Tuple[str, ...]]


def _axis_size(mesh: Mesh, axis: MeshAxis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def resolve_spec(mesh: Mesh, rules: Mapping[str, MeshAxis],
                 shape: Sequence[int], axes: Sequence[Optional[str]]) -> PartitionSpec:
    out = []
    for dim, name in zip(shape, axes):
        mesh_axis = rules.get(name) if name is not None else None
        if mesh_axis is not None and dim % _axis_size(mesh, mesh_axis) != 0:
            mesh_axis = None                      # divisibility guard
        out.append(mesh_axis)
    return PartitionSpec(*out)


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    rules: Mapping[str, MeshAxis]

    def spec(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> PartitionSpec:
        return resolve_spec(self.mesh, self.rules, shape, axes)

    def constrain(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        """with_sharding_constraint via logical axis names (None = replicated)."""
        spec = self.spec(x.shape, axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def sharding(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))


def maybe_constrain(ctx: Optional[MeshCtx], x: jax.Array, *axes) -> jax.Array:
    return ctx.constrain(x, *axes) if ctx is not None else x


def decl_shardings(ctx: MeshCtx, decls):
    """NamedShardings for a ParamDecl tree, divisibility-guarded."""
    from repro.models.param import ParamDecl

    def one(d: ParamDecl):
        return ctx.sharding(d.shape, d.axes)

    return jax.tree.map(one, decls,
                        is_leaf=lambda x: isinstance(x, ParamDecl))
