"""Encoder-decoder (Whisper-style) model.

The audio conv frontend is a STUB per the brief: ``input_specs`` supplies
precomputed frame embeddings (B, enc_frames, d_model); the transformer
backbone (24 enc + 24 dec layers for whisper-medium) is fully implemented.
Whisper specifics kept: pre-LayerNorm, GELU MLP, attention biases, tied
unembedding, sinusoidal encoder positions.  Deviation (DESIGN.md): decoder
positions are sinusoidal rather than a learned 448-slot table, because the
assigned decode_32k cell requires 32k positions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import layers as LY
from repro.models.param import ParamDecl
from repro.models.lm import scan_or_unroll as LM_scan
from repro.models.sharding import MeshCtx, maybe_constrain

Array = jax.Array


def _attn_decls(cfg, L: int, kv_from: str = "self") -> Dict[str, ParamDecl]:
    D, hd, H = cfg.d_model, cfg.hd, cfg.n_heads
    return {
        "wq": ParamDecl((L, D, H * hd), ("layers", "embed", "heads")),
        "wk": ParamDecl((L, D, H * hd), ("layers", "embed", "heads")),
        "wv": ParamDecl((L, D, H * hd), ("layers", "embed", "heads")),
        "wo": ParamDecl((L, H * hd, D), ("layers", "heads", "embed")),
        "bq": ParamDecl((L, H * hd), ("layers", "heads"), init="zeros"),
        "bv": ParamDecl((L, H * hd), ("layers", "heads"), init="zeros"),
        "bo": ParamDecl((L, D), ("layers", None), init="zeros"),
    }


def _ln_decls(L: int, D: int) -> Dict[str, ParamDecl]:
    return {
        "scale": ParamDecl((L, D), ("layers", None), init="ones"),
        "bias": ParamDecl((L, D), ("layers", None), init="zeros"),
    }


def _mlp_decls(cfg, L: int) -> Dict[str, ParamDecl]:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w1": ParamDecl((L, D, F), ("layers", "embed", "mlp")),
        "b1": ParamDecl((L, F), ("layers", "mlp"), init="zeros"),
        "w2": ParamDecl((L, F, D), ("layers", "mlp", "embed")),
        "b2": ParamDecl((L, D), ("layers", None), init="zeros"),
    }


def build_decls(cfg) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.vocab
    Le, Ld = cfg.enc_layers, cfg.n_layers
    return {
        "embed": ParamDecl((V, D), ("vocab", "embed"), init="embed",
                           scale=D ** -0.5),
        "enc": {
            "ln1": _ln_decls(Le, D), "attn": _attn_decls(cfg, Le),
            "ln2": _ln_decls(Le, D), "mlp": _mlp_decls(cfg, Le),
        },
        "enc_ln_post": _ln_decls(1, D),
        "dec": {
            "ln1": _ln_decls(Ld, D), "self_attn": _attn_decls(cfg, Ld),
            "lnx": _ln_decls(Ld, D), "cross_attn": _attn_decls(cfg, Ld),
            "ln2": _ln_decls(Ld, D), "mlp": _mlp_decls(cfg, Ld),
        },
        "dec_ln_post": _ln_decls(1, D),
    }


def _ln(x, p, eps):
    return LY.layernorm(x, p["scale"], p["bias"], eps)


def _proj_qkv(p, xq, xkv, cfg):
    B, Sq, D = xq.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (jnp.einsum("bsd,dh->bsh", xq, p["wq"]) + p["bq"]).reshape(B, Sq, H, hd)
    k = jnp.einsum("bsd,dh->bsh", xkv, p["wk"]).reshape(B, -1, H, hd)
    v = (jnp.einsum("bsd,dh->bsh", xkv, p["wv"]) + p["bv"]).reshape(B, -1, H, hd)
    return q, k, v


def _attn(p, xq, xkv, cfg, *, causal, chunk=1024, ctx=None):
    B, Sq, D = xq.shape
    q, k, v = _proj_qkv(p, xq, xkv, cfg)
    out = LY.chunked_attention(q, k, v, causal=causal, chunk=chunk, ctx=ctx)
    out = out.reshape(B, Sq, cfg.n_heads * cfg.hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]) + p["bo"]


def _mlp(p, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"])
    return jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"]


def _remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat != "none" else fn


def encode(cfg, params, frames: Array, *, ctx=None, chunk: int = 1024) -> Array:
    """frames: (B, F, D) stub frontend output -> encoder states (B, F, D)."""
    B, F, D = frames.shape
    h = frames.astype(jnp.dtype(cfg.activ_dtype))
    h = h + LY.sinusoidal_positions(F, D, h.dtype)[None]
    h = maybe_constrain(ctx, h, "batch", None, None)

    def body(h, p):
        a = _attn(p["attn"], _ln(h, p["ln1"], cfg.norm_eps),
                  _ln(h, p["ln1"], cfg.norm_eps), cfg, causal=False,
                  chunk=chunk, ctx=ctx)
        h = h + a
        h = h + _mlp(p["mlp"], _ln(h, p["ln2"], cfg.norm_eps))
        return h, None

    h, _ = LM_scan(cfg.scan_layers, _remat(cfg, body), h, params["enc"], cfg.enc_layers)
    ln_post = jax.tree.map(lambda a: a[0], params["enc_ln_post"])
    return _ln(h, ln_post, cfg.norm_eps)


def decode_train(cfg, params, enc_out: Array, tokens: Array, *,
                 ctx=None, chunk: int = 1024) -> Array:
    """Teacher-forced decoder. tokens: (B, S) -> logits (B, S, V)."""
    B, S = tokens.shape
    D = cfg.d_model
    h = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.activ_dtype))
    h = h + LY.sinusoidal_positions(S, D, h.dtype)[None]
    h = maybe_constrain(ctx, h, "batch", None, None)

    def body(h, p):
        hn = _ln(h, p["ln1"], cfg.norm_eps)
        h = h + _attn(p["self_attn"], hn, hn, cfg, causal=True, chunk=chunk, ctx=ctx)
        hx = _ln(h, p["lnx"], cfg.norm_eps)
        h = h + _attn(p["cross_attn"], hx, enc_out, cfg, causal=False,
                      chunk=chunk, ctx=ctx)
        h = h + _mlp(p["mlp"], _ln(h, p["ln2"], cfg.norm_eps))
        return h, None

    h, _ = LM_scan(cfg.scan_layers, _remat(cfg, body), h, params["dec"], cfg.n_layers)
    ln_post = jax.tree.map(lambda a: a[0], params["dec_ln_post"])
    h = _ln(h, ln_post, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return maybe_constrain(ctx, logits, "batch", None, "vocab")


def loss(cfg, params, batch: Dict[str, Array], *, ctx=None,
         chunk: int = 1024) -> Tuple[Array, Dict[str, Array]]:
    enc_out = encode(cfg, params, batch["frames"], ctx=ctx, chunk=chunk)
    logits = decode_train(cfg, params, enc_out, batch["tokens"], ctx=ctx,
                          chunk=chunk).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(batch["targets"], cfg.vocab, dtype=logits.dtype)
    nll = lse - jnp.sum(onehot * logits, axis=-1)
    l = jnp.mean(nll)
    return l, {"loss": l, "total_loss": l}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_decls(cfg, B: int, S_max: int) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.activ_dtype)
    Ld, H, hd, F = cfg.n_layers, cfg.n_heads, cfg.hd, cfg.enc_frames
    axes = (("layers", "batch", None, "heads", None) if H >= 16
            else ("layers", "batch", "kv_seq", None, None))
    kv = lambda S: ParamDecl((Ld, B, S, H, hd), axes, dtype=dt)
    cross = lambda S: ParamDecl((Ld, B, S, H, hd),
                                ("layers", "batch", None, "heads", None), dtype=dt)
    return {"self_k": kv(S_max), "self_v": kv(S_max),
            "cross_k": cross(F), "cross_v": cross(F)}


def prefill(cfg, params, frames: Array, tokens: Array, S_max: int, *,
            ctx=None, chunk: int = 1024):
    """Encode + build decoder caches for subsequent decode steps."""
    enc_out = encode(cfg, params, frames, ctx=ctx, chunk=chunk)
    B, S = tokens.shape
    D = cfg.d_model
    h = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.activ_dtype))
    h = h + LY.sinusoidal_positions(S, D, h.dtype)[None]

    def body(h, p):
        hn = _ln(h, p["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(p["self_attn"], hn, hn, cfg)
        o = LY.chunked_attention(q, k, v, causal=True, chunk=chunk, ctx=ctx)
        o = o.reshape(B, S, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bsh,hd->bsd", o, p["self_attn"]["wo"]) + p["self_attn"]["bo"]
        hx = _ln(h, p["lnx"], cfg.norm_eps)
        qx, kx, vx = _proj_qkv(p["cross_attn"], hx, enc_out, cfg)
        ox = LY.chunked_attention(qx, kx, vx, causal=False, chunk=chunk, ctx=ctx)
        ox = ox.reshape(B, S, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bsh,hd->bsd", ox, p["cross_attn"]["wo"]) + p["cross_attn"]["bo"]
        h = h + _mlp(p["mlp"], _ln(h, p["ln2"], cfg.norm_eps))
        return h, (k, v, kx, vx)

    h, (ks, vs, kxs, vxs) = LM_scan(cfg.scan_layers, body, h, params["dec"], cfg.n_layers)
    pad = S_max - S
    cache = {
        "self_k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "self_v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "cross_k": kxs, "cross_v": vxs,
    }
    ln_post = jax.tree.map(lambda a: a[0], params["dec_ln_post"])
    h = _ln(h, ln_post, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h[:, -1:], params["embed"].astype(h.dtype))
    return logits, cache


def decode_step(cfg, params, cache: Dict[str, Any], tokens: Array, pos: Array, *,
                ctx=None) -> Tuple[Array, Dict[str, Any]]:
    """One decoder token. tokens: (B, 1)."""
    B = tokens.shape[0]
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    h = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.activ_dtype))
    Smax = cache["self_k"].shape[2]
    pos_emb = LY.sinusoidal_positions(Smax, D, h.dtype)
    h = h + jax.lax.dynamic_slice(pos_emb, (pos, 0), (1, D))[None]
    h = maybe_constrain(ctx, h, "batch", None, None)

    def body(h, xs):
        p, sk, sv, ck, cv = xs
        hn = _ln(h, p["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(p["self_attn"], hn, hn, cfg)
        sk = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype), (0, pos, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype), (0, pos, 0, 0))
        s = jnp.einsum("bqhd,bshd->bhqs", q, sk).astype(jnp.float32) / np.sqrt(hd)
        mask = jnp.arange(Smax)[None, None, None, :] <= pos
        s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(sv.dtype)
        o = jnp.einsum("bhqs,bshd->bqhd", w, sv).reshape(B, 1, H * hd)
        h = h + jnp.einsum("bsh,hd->bsd", o, p["self_attn"]["wo"]) + p["self_attn"]["bo"]

        hx = _ln(h, p["lnx"], cfg.norm_eps)
        qx = (jnp.einsum("bsd,dh->bsh", hx, p["cross_attn"]["wq"])
              + p["cross_attn"]["bq"]).reshape(B, 1, H, hd)
        sxs = jnp.einsum("bqhd,bshd->bhqs", qx, ck).astype(jnp.float32) / np.sqrt(hd)
        wx = jax.nn.softmax(sxs, axis=-1).astype(cv.dtype)
        ox = jnp.einsum("bhqs,bshd->bqhd", wx, cv).reshape(B, 1, H * hd)
        h = h + jnp.einsum("bsh,hd->bsd", ox, p["cross_attn"]["wo"]) + p["cross_attn"]["bo"]
        h = h + _mlp(p["mlp"], _ln(h, p["ln2"], cfg.norm_eps))
        return h, (sk, sv)

    h, (new_sk, new_sv) = LM_scan(
        cfg.scan_layers, body, h,
        (params["dec"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]), cfg.n_layers)
    new_cache = dict(cache, self_k=new_sk, self_v=new_sv)
    ln_post = jax.tree.map(lambda a: a[0], params["dec_ln_post"])
    h = _ln(h, ln_post, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return maybe_constrain(ctx, logits, "batch", None, "vocab"), new_cache
