"""Mixture-of-Experts layer: top-k router, sort-based capacity dispatch, EP.

Dispatch algorithm (static shapes, GSPMD-friendly):
  1. router logits -> top-k (expert_id, weight) per token
  2. sort the (T*k) assignments by expert id; position-in-segment gives each
     assignment its capacity slot; slots >= capacity are DROPPED (standard
     dropped-token MoE with capacity_factor)
  3. scatter tokens into an (E, C, D) buffer; a sharding constraint places
     E on the "expert" (model) mesh axis — GSPMD materializes the all-to-all
  4. per-expert FFN via einsum over the stacked expert weights (MXU batch)
  5. gather back + combine with router weights; add shared experts
     (DeepSeek-style always-on experts) computed as a dense gated MLP.

Aux losses: Switch-style load-balancing loss and router z-loss, both returned
for the trainer to weigh in.

The structural kinship with the paper is intentional and documented
(DESIGN.md §5): route-to-local-expert is the same compute shape as DC-SVM's
early prediction (route-to-cluster, score with the local model) — with the
difference that the SVM serving path never drops an overflow query (extra
on-device rounds instead of capacity drops).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.param import ParamDecl
from repro.models.sharding import MeshCtx, maybe_constrain

Array = jax.Array


def moe_decls(cfg, L: int) -> Dict[str, ParamDecl]:
    m = cfg.moe
    D = cfg.d_model
    F = m.d_expert or cfg.d_ff
    E = m.num_experts
    d = {
        # router is tiny: replicate so shard_map bodies use it locally
        "router": ParamDecl((L, D, E), ("layers", None, None),
                            init="normal", scale=0.02),
        "w1": ParamDecl((L, E, D, F), ("layers", "expert", "embed", None)),
        "w3": ParamDecl((L, E, D, F), ("layers", "expert", "embed", None)),
        "w2": ParamDecl((L, E, F, D), ("layers", "expert", None, "embed")),
    }
    if m.num_shared > 0:
        Fs = F * m.num_shared
        d["sh_w1"] = ParamDecl((L, D, Fs), ("layers", "embed", "mlp"))
        d["sh_w3"] = ParamDecl((L, D, Fs), ("layers", "embed", "mlp"))
        d["sh_w2"] = ParamDecl((L, Fs, D), ("layers", "mlp", "embed"))
    return d


def capacity(cfg, tokens: int) -> int:
    m = cfg.moe
    c = int(np.ceil(tokens * m.top_k * m.capacity_factor / m.num_experts))
    return max(8, -(-c // 8) * 8)    # pad to a multiple of 8 for TPU layout


def moe_apply(
    p: Dict[str, Array], x: Array, cfg, ctx: Optional[MeshCtx] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """x: (B, S, D) -> (out, aux losses).

    With a mesh context the dispatch runs MANUALLY under shard_map
    (§Perf H5): tokens never leave their data shard except through the
    explicit (E, C_loc, D) all-to-all over the model axis.  Left to GSPMD,
    the global sort/scatter dispatch triggers involuntary full
    rematerialization — measured at 3.75 GiB of all-gather per MoE layer on
    deepseek-moe (see EXPERIMENTS.md §Perf)."""
    if ctx is not None and "model" in ctx.mesh.axis_names:
        return _moe_apply_sharded(p, x, cfg, ctx)
    return _moe_apply_dense(p, x, cfg, ctx)


def _moe_apply_dense(
    p: Dict[str, Array], x: Array, cfg, ctx: Optional[MeshCtx] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """Reference dispatch (single-device path; the shard_map path is tested
    for equivalence against this)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    C = capacity(cfg, T)
    xt = x.reshape(T, D)

    # ---- router --------------------------------------------------------
    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                     # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux: Switch load-balance + z-loss
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux_lb = E * jnp.sum(density * mean_prob)
    aux_z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based capacity dispatch -----------------------------------
    flat_e = top_e.reshape(-1)                                 # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)                                # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * K, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
    keep = pos < C
    pos_safe = jnp.where(keep, pos, 0)
    se_safe = jnp.where(keep, se, 0)

    buf = jnp.zeros((E, C, D), x.dtype)
    vals = jnp.where(keep[:, None], xt[st], 0.0)
    buf = buf.at[se_safe, pos_safe].add(vals)
    buf = maybe_constrain(ctx, buf, "expert", None, None)      # all-to-all here

    # ---- expert FFN (batched einsum over E) ------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    out_buf = maybe_constrain(ctx, out_buf, "expert", None, None)

    # ---- combine ---------------------------------------------------------
    gathered = out_buf[se_safe, pos_safe] * (sw * keep)[:, None]
    out = jnp.zeros((T, D), x.dtype).at[st].add(gathered)

    # ---- shared experts (dense, always-on) -------------------------------
    if m.num_shared > 0:
        hs = jax.nn.silu(jnp.einsum("td,df->tf", xt, p["sh_w1"]))
        hs = hs * jnp.einsum("td,df->tf", xt, p["sh_w3"])
        out = out + jnp.einsum("tf,fd->td", hs, p["sh_w2"])

    aux = {"moe_lb": aux_lb, "moe_z": aux_z,
           "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# shard_map dispatch (manual all-to-all, §Perf H5)
# ---------------------------------------------------------------------------

def _dispatch_local(xt: Array, router: Array, m, C: int):
    """Local routing + capacity dispatch for one shard's tokens.
    Returns (buf (E, C, D), combine info, aux scalars)."""
    T, D = xt.shape
    E, K = m.num_experts, m.top_k
    logits = jnp.einsum("td,de->te", xt, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux_lb = E * jnp.sum(density * mean_prob)
    aux_z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * K, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
    keep = pos < C
    pos_safe = jnp.where(keep, pos, 0)
    se_safe = jnp.where(keep, se, 0)
    buf = jnp.zeros((E, C, D), xt.dtype)
    buf = buf.at[se_safe, pos_safe].add(jnp.where(keep[:, None], xt[st], 0.0))
    info = (se_safe, pos_safe, st, sw, keep)
    aux = (aux_lb, aux_z, 1.0 - jnp.mean(keep.astype(jnp.float32)))
    return buf, info, aux


def _combine_local(out_buf: Array, info, T: int, D: int) -> Array:
    se_safe, pos_safe, st, sw, keep = info
    vals = out_buf[se_safe, pos_safe] * (sw * keep)[:, None]
    return jnp.zeros((T, D), out_buf.dtype).at[st].add(vals)


def _moe_apply_sharded(
    p: Dict[str, Array], x: Array, cfg, ctx: MeshCtx,
) -> Tuple[Array, Dict[str, Array]]:
    m = cfg.moe
    mesh = ctx.mesh
    B, S, D = x.shape
    E = m.num_experts
    F = m.d_expert or cfg.d_ff
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_data = int(np.prod([mesh.shape[a] for a in data_axes]))
    n_model = mesh.shape["model"]
    e_loc = max(E // n_model, 1)
    model_sharded = E % n_model == 0 and E >= n_model
    B_loc = B // n_data if B % n_data == 0 else B
    T_loc = B_loc * S
    C = capacity(cfg, T_loc)

    from jax.sharding import PartitionSpec as P

    batch_spec = data_axes if (B % n_data == 0 and data_axes) else None
    x_spec = P(batch_spec, None, None)
    r_spec = P(None, None)
    # expert weights: (E->model, D->data, F) — re-gathered over data in-body
    d_ax = "data" if "data" in mesh.axis_names else None
    d_sharded = d_ax is not None and D % mesh.shape["data"] == 0
    e_spec = "model" if model_sharded else None
    w13_spec = P(e_spec, "data" if d_sharded else None, None)
    w2_spec = P(e_spec, None, "data" if d_sharded else None)

    def body(xl, router, w1l, w3l, w2l):
        Bl = xl.shape[0]
        xt = xl.reshape(Bl * S, D)
        buf, info, aux = _dispatch_local(xt, router, m, C)     # (E, C, D)

        # gather expert weights over the data axis (FSDP-style, per layer)
        if d_sharded:
            w1g = jax.lax.all_gather(w1l, d_ax, axis=1, tiled=True)
            w3g = jax.lax.all_gather(w3l, d_ax, axis=1, tiled=True)
            w2g = jax.lax.all_gather(w2l, d_ax, axis=2, tiled=True)
        else:
            w1g, w3g, w2g = w1l, w3l, w2l

        if model_sharded and n_model > 1:
            # all-to-all over the model axis: peer j receives the j-th e_loc
            # expert block from every peer; regroup source-major -> expert-major
            bufx = jax.lax.all_to_all(buf, "model", split_axis=0,
                                      concat_axis=0, tiled=True)
            bufe = bufx.reshape(n_model, e_loc, C, D).transpose(1, 0, 2, 3)
            bufe = bufe.reshape(e_loc, n_model * C, D)
        else:
            bufe = buf
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bufe, w1g))
        h = h * jnp.einsum("ecd,edf->ecf", bufe, w3g)
        oute = jnp.einsum("ecf,efd->ecd", h, w2g)
        if model_sharded and n_model > 1:
            outx = oute.reshape(e_loc, n_model, C, D).transpose(1, 0, 2, 3)
            outx = outx.reshape(E, C, D)
            out_buf = jax.lax.all_to_all(outx, "model", split_axis=0,
                                         concat_axis=0, tiled=True)
        else:
            out_buf = oute
        out = _combine_local(out_buf, info, Bl * S, D).reshape(Bl, S, D)

        axes_all = tuple(mesh.axis_names)
        aux_lb = jax.lax.pmean(aux[0], axes_all)
        aux_z = jax.lax.pmean(aux[1], axes_all)
        aux_dr = jax.lax.pmean(aux[2], axes_all)
        return out, aux_lb[None], aux_z[None], aux_dr[None]

    from repro.compat import shard_map
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, r_spec, w13_spec, w13_spec, w2_spec),
        out_specs=(x_spec, P(None), P(None), P(None)),
        check_vma=False,
    )
    out, lb, z, dr = fn(x, p["router"], p["w1"], p["w3"], p["w2"])
    aux = {"moe_lb": lb[0], "moe_z": z[0], "moe_drop_frac": dr[0]}

    # shared experts: dense Megatron MLP under GSPMD (one AR per direction)
    if m.num_shared > 0:
        hs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["sh_w1"]))
        hs = hs * jnp.einsum("bsd,df->bsf", x, p["sh_w3"])
        out = out + jnp.einsum("bsf,fd->bsd", hs, p["sh_w2"])
    return out, aux
