"""Decoder-only LM assembly: pattern-driven layer stacks, scanned over periods.

A config compiles to a ``StackPlan``: an optional prefix of explicit layers
plus a repeating *period* of (mixer, ffn) slots that is `lax.scan`-ned over
``n_periods`` (stacked parameters).  This keeps the HLO size independent of
depth — the property that makes 512-way SPMD dry-run compiles tractable — and
expresses every assigned arch:

    dense        period [(attn, dense)]
    moe          period [(attn, moe)] (+ dense prefix layers, DeepSeek)
    jamba hybrid period of 8: 7 mamba + 1 attn, alternating dense/moe FFN
    xlstm        period [(mlstm, none), (slstm, none)]

Three execution modes share the slot code: "train" (full seq), "prefill"
(full seq + emit per-layer cache state), "decode" (one token + carry state).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import layers as LY
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.param import ParamDecl
from repro.models.sharding import MeshCtx, maybe_constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# stack plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    mixer: str                     # attn | mamba | mlstm | slstm
    ffn: str                       # dense | moe | none


@dataclasses.dataclass(frozen=True)
class StackPlan:
    prefix: Tuple[LayerPlan, ...]
    period: Tuple[LayerPlan, ...]
    n_periods: int


def build_plan(cfg) -> StackPlan:
    prefix = tuple(LayerPlan(m, f) for m, f in cfg.prefix_pattern)
    if cfg.period_pattern is not None:
        period = tuple(LayerPlan(m, f) for m, f in cfg.period_pattern)
    else:
        period = (LayerPlan("attn", "moe" if cfg.moe is not None else "dense"),)
    rest = cfg.n_layers - len(prefix)
    assert rest % len(period) == 0, (cfg.n_layers, len(prefix), len(period))
    return StackPlan(prefix, period, rest // len(period))


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

def _slot_decls(cfg, plan: LayerPlan, L: int) -> Dict[str, Any]:
    D = cfg.d_model
    d: Dict[str, Any] = {
        "norm1": ParamDecl((L, D), ("layers", None), init="ones"),
    }
    if plan.mixer == "attn":
        d["attn"] = LY.attn_decls(cfg, L)
    elif plan.mixer == "mamba":
        d["mamba"] = SSM.mamba_decls(cfg, L)
    elif plan.mixer == "mlstm":
        d["mlstm"] = XL.mlstm_decls(cfg, L)
    elif plan.mixer == "slstm":
        d["slstm"] = XL.slstm_decls(cfg, L)
    else:
        raise ValueError(plan.mixer)
    if plan.ffn == "dense":
        d["norm2"] = ParamDecl((L, D), ("layers", None), init="ones")
        d["mlp"] = LY.mlp_decls(cfg, L)
    elif plan.ffn == "moe":
        d["norm2"] = ParamDecl((L, D), ("layers", None), init="ones")
        d["moe"] = MOE.moe_decls(cfg, L)
    return d


def build_decls(cfg) -> Dict[str, Any]:
    plan = build_plan(cfg)
    D, V = cfg.d_model, cfg.vocab
    # untied: the lookup table is replicated over vocab (rows) and sharded on
    # the embedding dim -> the gather is LOCAL (GSPMD otherwise emits a
    # (B,S,D)-sized all-reduce per step; measured in §Perf H2).  Tied tables
    # stay 2D-sharded: the logits matmul needs vocab-sharded output.
    embed_axes = ("vocab", "embed") if cfg.tie_embeddings else (None, "embed")
    decls: Dict[str, Any] = {
        "embed": ParamDecl((V, D), embed_axes, init="embed", scale=D ** -0.5),
        "final_norm": ParamDecl((D,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        decls["unembed"] = ParamDecl((D, V), ("embed", "vocab"))
    if plan.prefix:
        decls["prefix"] = [
            _slot_decls(cfg, p, 1) for p in plan.prefix
        ]
    decls["stack"] = {
        f"slot{i}": _slot_decls(cfg, p, plan.n_periods)
        for i, p in enumerate(plan.period)
    }
    return decls


# ---------------------------------------------------------------------------
# slot application (three modes)
# ---------------------------------------------------------------------------

def _zero_aux(cfg) -> Dict[str, Array]:
    if cfg.moe is None:
        return {}
    z = jnp.zeros((), jnp.float32)
    return {"moe_lb": z, "moe_z": z, "moe_drop_frac": z}


def apply_slot(
    cfg, plan: LayerPlan, p: Dict[str, Any], h: Array, *,
    mode: str, positions: Optional[Array] = None, pos: Optional[Array] = None,
    state: Any = None, ctx: Optional[MeshCtx] = None, chunk: int = 1024,
):
    """Returns (h, aux, new_state). ``state`` semantics per mode:
    train: ignored/None out; prefill: None in, filled cache out;
    decode: state in, updated state out."""
    aux = _zero_aux(cfg)
    hin = LY.rmsnorm(h, p["norm1"], cfg.norm_eps)
    new_state = None

    if plan.mixer == "attn":
        if mode == "train":
            mix = LY.attn_apply(p["attn"], hin, cfg, positions, chunk=chunk, ctx=ctx)
        elif mode == "prefill":
            mix, (k, v) = LY.attn_prefill(p["attn"], hin, cfg, positions,
                                          chunk=chunk, ctx=ctx)
            new_state = {"k": k, "v": v}
        else:
            mix, ck, cv = LY.attn_decode(p["attn"], hin, cfg, pos,
                                         state["k"], state["v"], ctx=ctx)
            new_state = {"k": ck, "v": cv}
    elif plan.mixer == "mamba":
        if mode == "train":
            mix = SSM.mamba_apply(p["mamba"], hin, cfg, ctx=ctx)
        elif mode == "prefill":
            mix, new_state = SSM.mamba_apply(p["mamba"], hin, cfg, ctx=ctx,
                                             return_state=True)
        else:
            mix, new_state = SSM.mamba_decode(p["mamba"], hin, cfg, state, ctx=ctx)
    elif plan.mixer == "mlstm":
        if mode in ("train", "prefill"):
            mix = XL.mlstm_apply(p["mlstm"], hin, cfg, ctx=ctx)
            if mode == "prefill":
                new_state = _mlstm_prefill_state(cfg, p["mlstm"], hin)
        else:
            mix, new_state = XL.mlstm_decode(p["mlstm"], hin, cfg, state, ctx=ctx)
    elif plan.mixer == "slstm":
        if mode in ("train", "prefill"):
            mix = XL.slstm_apply(p["slstm"], hin, cfg, ctx=ctx)
            if mode == "prefill":
                new_state = _slstm_prefill_state(cfg, p["slstm"], hin)
        else:
            mix, new_state = XL.slstm_decode(p["slstm"], hin, cfg, state, ctx=ctx)
    else:
        raise ValueError(plan.mixer)
    h = h + mix

    if plan.ffn != "none":
        hn = LY.rmsnorm(h, p["norm2"], cfg.norm_eps)
        if plan.ffn == "dense":
            f = LY.mlp_apply(p["mlp"], hn, cfg, ctx=ctx)
        else:
            f, aux = MOE.moe_apply(p["moe"], hn, cfg, ctx=ctx)
            aux = {**_zero_aux(cfg), **aux}
        h = h + f
    return h, aux, new_state


# prefill states for recurrent mixers (mamba returns its state in-line)
def _mlstm_prefill_state(cfg, p, hin):
    B, S, _ = hin.shape
    c = min(cfg.xlstm.chunk, S)
    q, k, v, ig, logf, _ = XL._mlstm_qkvif(p, hin, cfg)
    st = XL.init_mlstm_state(cfg, B)
    # sequential per-token state update done chunk-wise via the same math as
    # mlstm_apply's carry; reuse decode recurrence over a scan for exactness
    def step(carry, args):
        C, n, m = carry
        ki, vi, igi, lfi = args
        m_new = jnp.maximum(lfi + m, igi)
        w_old = jnp.exp(lfi + m - m_new)
        w_in = jnp.exp(igi - m_new)
        C = w_old[..., None, None] * C + w_in[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", vi.astype(jnp.float32), ki.astype(jnp.float32))
        n = w_old[..., None] * n + w_in[..., None] * ki.astype(jnp.float32)
        return (C, n, m_new), None

    (C, n, m), _ = jax.lax.scan(
        step, (st.C.astype(jnp.float32), st.n.astype(jnp.float32), st.m),
        (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
         jnp.moveaxis(ig, 1, 0), jnp.moveaxis(logf, 1, 0)))
    return XL.MLSTMState(C.astype(hin.dtype), n.astype(hin.dtype), m)


def _slstm_prefill_state(cfg, p, hin):
    B, S, _ = hin.shape
    st = XL.init_slstm_state(cfg, B)

    def step(s, xt):
        s, _ = XL._slstm_cell(p, xt, s, cfg)
        return s, None

    st, _ = jax.lax.scan(step, st, jnp.moveaxis(hin, 1, 0))
    return st


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens, prefix_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        h = h * np.sqrt(cfg.d_model).astype(np.float32)
    h = h.astype(_adtype(cfg))
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    return h


def _adtype(cfg):
    return jnp.dtype(cfg.activ_dtype)


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)   # "full": save only layer boundaries


def scan_or_unroll(use_scan: bool, body, carry, xs, length: int):
    """lax.scan or an unrolled python loop (identical semantics).

    The unrolled form exists for the roofline probes: XLA's cost_analysis
    counts a while-loop body ONCE regardless of trip count, so the dry-run
    derives corrected totals from shallow unrolled probe compiles."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if not ys or ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


def forward(
    cfg, params, tokens: Array, *,
    prefix_embeds: Optional[Array] = None,
    ctx: Optional[MeshCtx] = None,
    chunk: int = 1024,
    mode: str = "train",
) -> Tuple[Array, Dict[str, Array], Any]:
    """Returns (logits, aux, cache_or_None). tokens: (B, S)."""
    plan = build_plan(cfg)
    h = _embed(cfg, params, tokens, prefix_embeds)
    h = maybe_constrain(ctx, h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux = _zero_aux(cfg)
    prefill_states: Dict[str, Any] = {}

    for i, p_plan in enumerate(plan.prefix):
        pp = jax.tree.map(lambda a: a[0], params["prefix"][i])
        h, a, st = apply_slot(cfg, p_plan, pp, h, mode=mode,
                              positions=positions, ctx=ctx, chunk=chunk)
        aux = {k: aux[k] + a[k] for k in aux}
        if mode == "prefill":
            prefill_states[f"prefix{i}"] = jax.tree.map(lambda x: x[None], st) \
                if st is not None else None

    def body(carry, xs):
        h, aux = carry
        states = {}
        for i, p_plan in enumerate(plan.period):
            h, a, st = apply_slot(cfg, p_plan, xs[f"slot{i}"], h, mode=mode,
                                  positions=positions, ctx=ctx, chunk=chunk)
            aux = {k: aux[k] + a[k] for k in aux}
            states[f"slot{i}"] = st
        if mode == "prefill":
            return (h, aux), states
        return (h, aux), None

    scan_body = _remat(cfg, body) if mode == "train" else body
    (h, aux), states = scan_or_unroll(cfg.scan_layers, scan_body, (h, aux),
                                      params["stack"], plan.n_periods)
    if mode == "prefill":
        prefill_states["stack"] = states

    h = LY.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"])
    logits = maybe_constrain(ctx, logits, "batch", None, "vocab")
    cache = prefill_states if mode == "prefill" else None
    return logits, aux, cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(cfg, params, batch: Dict[str, Array], *,
            ctx: Optional[MeshCtx] = None, chunk: int = 1024,
            z_loss: float = 1e-4) -> Tuple[Array, Dict[str, Array]]:
    """Cross-entropy with vocab-sharded logits (one-hot contraction, no
    all-gather of the logit tensor) + router aux losses."""
    tokens, targets = batch["tokens"], batch["targets"]
    prefix = batch.get("prefix_embeds")
    logits, aux, _ = forward(cfg, params, tokens, prefix_embeds=prefix,
                             ctx=ctx, chunk=chunk, mode="train")
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:, :]     # loss on text positions only
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=logits.dtype)
    tgt = jnp.sum(onehot * logits, axis=-1)
    nll = lse - tgt
    loss = jnp.mean(nll)
    metrics = {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
    if z_loss > 0:
        zl = z_loss * jnp.mean(lse ** 2)
        loss = loss + zl
        metrics["z_loss"] = zl
    if cfg.moe is not None:
        loss = loss + 1e-2 * aux["moe_lb"] + cfg.moe.router_z_loss * aux["moe_z"]
        metrics.update({k: aux[k] for k in aux})
    metrics["total_loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def cache_decls(cfg, B: int, S_max: int) -> Dict[str, Any]:
    """Cache structure as ParamDecls (shape + dtype + logical sharding axes).

    KV caches shard batch over the data axes and kv-heads over the model axis
    (divisibility-guarded: MQA's single head stays replicated); recurrent
    states shard their channel dim over the model axis."""
    plan = build_plan(cfg)
    dt = _adtype(cfg)
    P = ParamDecl

    def slot_state(p_plan: LayerPlan, L: int):
        if p_plan.mixer == "attn":
            hkv, hd = cfg.n_kv, cfg.hd
            # >=16 kv heads shard over the model axis directly; fewer (GQA 8,
            # MQA 1) shard the sequence dim instead (§Perf H8)
            axes = (("layers", "batch", None, "heads", None) if hkv >= 16
                    else ("layers", "batch", "kv_seq", None, None))
            kv = P((L, B, S_max, hkv, hd), axes, dtype=dt)
            return {"k": kv, "v": kv}
        if p_plan.mixer == "mamba":
            di, N, dc, _ = SSM.mamba_dims(cfg)
            return SSM.MambaState(
                P((L, B, dc - 1, di), ("layers", "batch", None, "heads"), dtype=dt),
                P((L, B, di, N), ("layers", "batch", "heads", None), dtype=dt))
        if p_plan.mixer == "mlstm":
            _, H, hd = XL.mlstm_dims(cfg)
            return XL.MLSTMState(
                P((L, B, H, hd, hd), ("layers", "batch", "heads", None, None), dtype=dt),
                P((L, B, H, hd), ("layers", "batch", "heads", None), dtype=dt),
                P((L, B, H), ("layers", "batch", "heads"), dtype=jnp.float32))
        if p_plan.mixer == "slstm":
            D = cfg.d_model
            s = P((L, B, D), ("layers", "batch", "heads"), dtype=dt)
            return XL.SLSTMState(s, s, s,
                                 P((L, B, D), ("layers", "batch", "heads"),
                                   dtype=jnp.float32))
        raise ValueError(p_plan.mixer)

    cache: Dict[str, Any] = {"stack": {
        f"slot{i}": slot_state(p, plan.n_periods)
        for i, p in enumerate(plan.period)
    }}
    for i, p_plan in enumerate(plan.prefix):
        cache[f"prefix{i}"] = slot_state(p_plan, 1)
    return cache


def init_cache(cfg, B: int, S_max: int) -> Dict[str, Any]:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_decls(cfg, B, S_max),
                        is_leaf=lambda x: isinstance(x, ParamDecl))


def decode_step(
    cfg, params, cache: Dict[str, Any], tokens: Array, pos: Array, *,
    ctx: Optional[MeshCtx] = None,
) -> Tuple[Array, Dict[str, Any]]:
    """One decode step. tokens: (B, 1); pos: scalar int32 (current position).
    Returns (logits (B, 1, V), updated cache)."""
    plan = build_plan(cfg)
    h = _embed(cfg, params, tokens)
    h = maybe_constrain(ctx, h, "batch", None, None)
    new_cache: Dict[str, Any] = {}

    for i, p_plan in enumerate(plan.prefix):
        pp = jax.tree.map(lambda a: a[0], params["prefix"][i])
        st = jax.tree.map(lambda a: a[0], cache[f"prefix{i}"])
        h, _, st2 = apply_slot(cfg, p_plan, pp, h, mode="decode", pos=pos,
                               state=st, ctx=ctx)
        new_cache[f"prefix{i}"] = jax.tree.map(lambda a: a[None], st2)

    def body(h, xs):
        p_slice, c_slice = xs
        new_states = {}
        for i, p_plan in enumerate(plan.period):
            h, _, st = apply_slot(cfg, p_plan, p_slice[f"slot{i}"], h,
                                  mode="decode", pos=pos,
                                  state=c_slice[f"slot{i}"], ctx=ctx)
            new_states[f"slot{i}"] = st
        return h, new_states

    h, new_stack = scan_or_unroll(cfg.scan_layers, body, h,
                                  (params["stack"], cache["stack"]),
                                  plan.n_periods)
    new_cache["stack"] = new_stack

    h = LY.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"])
    logits = maybe_constrain(ctx, logits, "batch", None, "vocab")
    return logits, new_cache
