"""Versioned model registry for the serving engine.

Production serving means many models and versions behind one endpoint, not
one exported ``ServingModel`` (the *DCSVM: Fast Multi-class Classification*
deployment shape).  The registry maps ``name -> {version -> entry}`` where
every entry carries

* the compacted device-resident ``ServingModel`` (``export_serving_model``
  output, ``device_put`` once at registration), and
* a self-describing ``ModelManifest``: task, kernel hyper-parameters,
  C/eps/nu, decision offsets (rho, per-cluster rho_c), cluster count,
  allowed serving strategies, and the export options that shaped the packed
  blocks — everything a front end needs to route, validate, and reproduce a
  request without reaching back to the training pipeline.  Manifests
  round-trip through JSON (``to_json`` / ``from_json``) so a registry's
  contents can be exposed, diffed, and audited.

Routing is a plain ``name -> default version`` table.  A hot swap is one
atomic repoint of that table (``set_default``): requests resolved after the
swap see the new version, requests already resolved keep the old entry
alive until they complete — the engine drains the old version's queue and
only then calls ``drop`` (DESIGN.md §14's swap/drain protocol).
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.kernels import Kernel
from repro.launch.serve_svm import ServingModel, export_serving_model

ALL_STRATEGIES = ("exact", "early", "bcm")


@dataclasses.dataclass(frozen=True)
class ModelManifest:
    """Self-describing serving metadata for one registered model version."""

    name: str
    version: int
    task: str                        # "svc" | "svr" | "ocsvm"
    kernel: Dict[str, Any]           # kind / gamma / degree / coef0
    C: float
    eps: Optional[float]             # epsilon-SVR tube half-width
    nu: Optional[float]              # one-class / nu-SVC support mass
    rho: float                       # global decision offset
    rho_c: Tuple[float, ...]         # per-cluster offsets (early ocsvm)
    k: int                           # routing clusters
    n_classes: int                   # 0 = svr, 1 = ocsvm, >= 2 = svc
    n_sv: int                        # SV union size after export
    strategies: Tuple[str, ...]      # strategies this export can serve
    max_sv_per_cluster: int          # export cap (blocks subsampled above)
    with_bcm: bool                   # BCM Grams prefactored at export
    cap_policy: str = "bucket"       # early_capacity derives from the padded
                                     # bucket shape, never the ragged batch
    created_unix: float = 0.0

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["rho_c"] = list(self.rho_c)
        d["strategies"] = list(self.strategies)
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ModelManifest":
        d = dict(d)
        d["rho_c"] = tuple(float(v) for v in d.get("rho_c", ()))
        d["strategies"] = tuple(d.get("strategies", ()))
        d["kernel"] = dict(d["kernel"])
        return cls(**d)

    def make_kernel(self) -> Kernel:
        return Kernel(**self.kernel)


def build_manifest(name: str, version: int, model, sm: ServingModel, *,
                   max_sv_per_cluster: int, with_bcm: bool) -> ModelManifest:
    """Derive the manifest from a trained model + its serving export."""
    cfg = model.config
    task = getattr(model, "task", None)
    strategies = tuple(s for s in ALL_STRATEGIES
                       if with_bcm or s != "bcm")
    return ModelManifest(
        name=name,
        version=version,
        task=sm.task,
        kernel=dataclasses.asdict(cfg.kernel),
        C=float(cfg.C),
        eps=(float(task.eps) if task is not None and hasattr(task, "eps")
             else None),
        nu=(float(task.nu) if task is not None and hasattr(task, "nu")
            else None),
        rho=float(np.asarray(sm.rho)),
        rho_c=tuple(np.asarray(sm.rho_c, np.float64).tolist()),
        k=int(sm.k),
        n_classes=int(sm.n_classes),
        n_sv=int(sm.Xall.shape[0]),
        strategies=strategies,
        max_sv_per_cluster=int(max_sv_per_cluster),
        with_bcm=bool(with_bcm),
        created_unix=time.time(),
    )


@dataclasses.dataclass
class RegistryEntry:
    """One registered version: manifest + device-resident serving model."""

    manifest: ModelManifest
    sm: ServingModel
    kern: Kernel

    @property
    def version(self) -> int:
        return self.manifest.version


class ModelRegistry:
    """Thread-safe versioned registry with an atomic default-route table."""

    def __init__(self) -> None:
        self._entries: Dict[Tuple[str, int], RegistryEntry] = {}
        self._route: Dict[str, int] = {}
        self._lock = threading.Lock()

    # -- registration ----------------------------------------------------
    def register(self, name: str, model, *, version: Optional[int] = None,
                 max_sv_per_cluster: int = 4096, with_bcm: bool = True,
                 make_default: Optional[bool] = None) -> ModelManifest:
        """Export ``model`` (a ``DCSVMModel`` or ``MulticlassModel``) and
        register it under ``name``.  ``version=None`` auto-increments past
        the newest registered version.  The first version of a name becomes
        the default route; later ones only when ``make_default=True``
        (``set_default`` / the engine's hot swap repoints explicitly)."""
        if version is not None:
            # coerce ONCE at entry: pre-fix the pre-lock check keyed on
            # (name, int(version)) but the insert used (name, version), so
            # register(version="2") and register(version=2) silently
            # coexisted as distinct keys
            version = int(version)
            if (name, version) in self._entries:
                raise ValueError(f"{name}:{version} is already registered")
        sm = export_serving_model(model,
                                  max_sv_per_cluster=max_sv_per_cluster,
                                  with_bcm=with_bcm)
        with self._lock:
            if version is None:
                version = max(self.versions(name), default=0) + 1
            if (name, version) in self._entries:
                raise ValueError(f"{name}:{version} is already registered")
            manifest = build_manifest(
                name, version, model, sm,
                max_sv_per_cluster=max_sv_per_cluster, with_bcm=with_bcm)
            self._entries[(name, version)] = RegistryEntry(
                manifest=manifest, sm=sm, kern=model.config.kernel)
            if make_default or (make_default is None
                                and name not in self._route):
                self._route[name] = version
        return manifest

    # -- resolution / routing --------------------------------------------
    def resolve(self, name: str, version: Optional[int] = None
                ) -> RegistryEntry:
        """Resolve a request's (name, version) to a concrete entry;
        ``version=None`` follows the default route table.  Takes the lock:
        the route read and the entry lookup must be one atomic snapshot, or
        a concurrent ``drop``/``set_default`` can surface a half-removed
        entry (route repointed, entry gone — or vice versa)."""
        with self._lock:
            if version is None:
                version = self._route.get(name)
                if version is None:
                    raise KeyError(f"no model registered under name {name!r}")
            entry = self._entries.get((name, int(version)))
        if entry is None:
            raise KeyError(f"model {name!r} has no version {version}")
        return entry

    def default_version(self, name: str) -> Optional[int]:
        return self._route.get(name)

    def set_default(self, name: str, version: int) -> Optional[int]:
        """Atomically repoint the route table (the hot-swap primitive).
        Returns the previous default version (None if first)."""
        with self._lock:
            if (name, version) not in self._entries:
                raise KeyError(f"model {name!r} has no version {version}")
            old = self._route.get(name)
            self._route[name] = version
            return old

    # -- inventory -------------------------------------------------------
    def names(self) -> List[str]:
        return sorted({n for n, _ in self._entries})

    def versions(self, name: str) -> List[int]:
        return sorted(v for n, v in self._entries if n == name)

    def drop(self, name: str, version: int) -> None:
        """Drop a version (after the engine drained it).  Refuses to drop
        the routed default — swap first."""
        with self._lock:
            if self._route.get(name) == version:
                raise ValueError(
                    f"{name}:{version} is the routed default; set_default "
                    "to another version before dropping it")
            if self._entries.pop((name, version), None) is None:
                raise KeyError(f"model {name!r} has no version {version}")

    # -- exposition ------------------------------------------------------
    def manifests(self) -> List[Dict[str, Any]]:
        return [self._entries[key].manifest.to_json()
                for key in sorted(self._entries)]

    def to_json(self) -> Dict[str, Any]:
        return {"route": dict(self._route), "models": self.manifests()}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
