"""Compiled SVM serving engine for DC-SVM models of every task.

Turns a trained ``DCSVMModel`` (binary / weighted C-SVC or epsilon-SVR) or
``MulticlassModel`` into a compacted, device-resident ``ServingModel`` and
serves batched requests through one jitted program per strategy —
regression models flow through the same route→gather→score program and
only skip the final argmax (``ServingModel.task``):

* ``exact`` — K(Xq, SV-union) @ W, argmax over classes (paper eq. 10).
* ``early`` — paper eq. 11: route each query to its nearest kernel-kmeans
  cluster and score against ONLY that cluster's packed SV block (the 1/k
  serving win).  Routing + bucketed scoring + argmax is one fused program
  (``predict.bucketed_cluster_scores``).
* ``bcm``   — precision-weighted combination of the k local models; the
  per-cluster regularized SV Grams are prefactored at export time.

Export drops every non-SV, packs the per-cluster SV blocks into a dense
(k, max_sv, d) layout with masks (zero weights on padding slots, masked
kernel columns where padding would leak — see DESIGN.md §5), and
``device_put``s the whole model once; the request loop never touches host
memory.

    PYTHONPATH=src python -m repro.launch.serve_svm --n 4000 --classes 3 \
        --strategy early --batch 256 --batches 50
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dcsvm import DCSVMConfig, DCSVMModel
from repro.core.kernels import Kernel, gram, resolve_use_pallas
from repro.core.kkmeans import KKMeansModel
from repro.core.multiclass import MulticlassModel, fit_ova
from repro.core.predict import _early_program, bucket_size, early_capacity
from repro.obs.metrics import MetricsRegistry

Array = jax.Array


class ServingModel(NamedTuple):
    """Device-resident compacted model (a pytree — passes through jit).

    Binary classifiers are exported with two weight columns (-w, +w) and
    classes (-1, +1) so the argmax request loop is identical for every
    model.  Regression (epsilon-SVR) models are exported with ONE weight
    column of collapsed beta coefficients and an EMPTY ``classes`` array —
    the ``task`` field is derived from that static shape, so the jitted
    route→gather→score program is shared and only the final argmax is
    skipped for regression.  One-class SVM models are exported with one
    beta column, a length-1 ``classes`` array (the static task marker) and
    the decision offset ``rho``: predictions are sign(score - rho), +1 =
    inlier.  Two-constraint nu-SVC (``NuSVC(with_bias=True)``) shares this
    offset-threshold path with ``rho = -b`` (the recovered bias), so its
    biased decision function round-trips through serving with no extra
    machinery.
    """

    # routing (implicit kernel-kmeans centers, empty centers masked upstream)
    Xm: Array          # (m, d)
    Wm: Array          # (m, k)
    sm: Array          # (k,)
    # early strategy: per-cluster packed SV blocks
    Xsv: Array         # (k, max_sv, d)
    Wsv: Array         # (k, max_sv, n_classes)  zero on padding
    svmask: Array      # (k, max_sv)             True on real SVs
    # exact strategy: SV union
    Xall: Array        # (ns, d)
    Wall: Array        # (ns, n_classes)
    # bcm strategy: Cholesky factor of the regularized masked SV Gram per
    # cluster (identity padding) — factored ONCE at export, so a request
    # only pays triangular solves
    Lchol: Array       # (k, max_sv, max_sv) lower-triangular
    classes: Array     # (n_classes,) — empty for regression, (1,) for ocsvm
    rho: Array = np.float32(0.0)   # decision offset (one-class SVM only)
    rho_c: Array = np.zeros((0,), np.float32)   # (k,) per-cluster offsets of
                       # an early-stopped one-class export (empty otherwise):
                       # the early strategy subtracts the routed cluster's
                       # local multiplier inside the fused program

    @property
    def k(self) -> int:
        return self.Xsv.shape[0]

    @property
    def n_classes(self) -> int:
        return self.classes.shape[0]

    @property
    def task(self) -> str:
        """"svr" | "ocsvm" | "svc" — derived from the static ``classes``
        shape so the branch is jit-safe (no host sync, no non-array pytree
        leaf): 0 classes = regression, 1 = one-class, >= 2 = classifier."""
        if self.classes.shape[0] == 0:
            return "svr"
        if self.classes.shape[0] == 1:
            return "ocsvm"
        return "svc"


def export_serving_model(model, noise: float = 1e-2,
                         max_sv_per_cluster: int = 4096,
                         with_bcm: bool = True) -> ServingModel:
    """Compact a trained model for serving: drop non-SVs, pack per-cluster
    SV blocks, prefactor the BCM Grams, device_put once.

    Clusters holding more than ``max_sv_per_cluster`` SVs are strided down
    to bound the packed block size — that makes ``early``/``bcm`` serving
    an approximation of the training-side decision (a warning is emitted);
    raise the cap for an exact round-trip.

    ``with_bcm=False`` skips building/factoring the k (max_sv, max_sv) BCM
    Grams — they are the export's dominant memory cost (k * max_sv^2
    floats), wasted if only ``exact``/``early`` will be served.
    """
    part = model.partition
    if part is None:
        raise ValueError("serving export requires a partitioned model")
    kern = model.config.kernel
    alpha = np.asarray(model.alpha)
    task = getattr(model, "task", None)
    rho = 0.0
    rho_c = np.zeros((0,), np.float32)
    model_rho_c = getattr(model, "rho_clusters", None)
    if model_rho_c is not None:
        rho_c = np.asarray(model_rho_c, np.float32)
    if task is not None and getattr(task, "has_rho_offset", False):
        # one-class: one beta column + the offset; classes has the static
        # length-1 marker shape and serve_batch thresholds score - rho at 0
        w = np.asarray(model.weights)
        W = w[:, None]
        classes = np.asarray([1.0], np.float32)
        active = w != 0
        rho = float(model.rho or 0.0)
    elif task is not None and task.is_regression:
        # regression: one beta column, no classes — serve_batch skips argmax
        w = np.asarray(model.weights)                        # collapsed beta
        W = w[:, None]                                       # (n, 1)
        classes = np.zeros((0,), np.float32)
        active = w != 0
    elif isinstance(model, DCSVMModel) or alpha.ndim == 1:
        w = np.asarray(model.weights)                        # y * alpha
        W = np.stack([-w, w], axis=1)                        # (n, 2)
        classes = np.array([-1.0, 1.0], np.float32)
        active = w != 0
    else:
        W = np.asarray(model.alpha * model.Y).T              # (n, n_classes)
        classes = np.asarray(model.classes)
        active = np.any(alpha > 0, axis=0)

    X = np.asarray(model.X)
    n_cls = W.shape[1]
    d = X.shape[1]

    sv_lists = []
    n_thinned = 0
    for c in range(part.k):
        members = part.idx[c][part.mask[c]]
        sv = members[active[members]]
        if len(sv) > max_sv_per_cluster:
            sv = sv[:: len(sv) // max_sv_per_cluster + 1]
            n_thinned += 1
        sv_lists.append(sv)
    if n_thinned:
        import warnings

        warnings.warn(
            f"{n_thinned} cluster(s) exceeded max_sv_per_cluster="
            f"{max_sv_per_cluster}; their SV blocks were subsampled, so "
            "early/bcm serving approximates the training-side decision",
            stacklevel=2)
    msv = max(1, max(len(s) for s in sv_lists))
    Xsv = np.zeros((part.k, msv, d), X.dtype)
    Wsv = np.zeros((part.k, msv, n_cls), np.float32)
    svmask = np.zeros((part.k, msv), bool)
    for c, sv in enumerate(sv_lists):
        Xsv[c, : len(sv)] = X[sv]
        Wsv[c, : len(sv)] = W[sv]
        svmask[c, : len(sv)] = True

    union = np.nonzero(active)[0]
    if len(union) == 0:
        union = np.array([0])
    Xall = X[union]
    Wall = W[union].astype(np.float32)

    # BCM: masked per-cluster Gram + noise on the real block, identity on
    # padding (padding rows of Xsv are zeros; for RBF K(x, 0) != 0, so the
    # mask — not the zero rows — is what keeps padding out of the solve)
    Xsv_j = jnp.asarray(Xsv)
    if with_bcm:
        mm = svmask[:, :, None] & svmask[:, None, :]
        Kreg = jax.vmap(lambda Xc: kern.pairwise(Xc, Xc))(Xsv_j)
        Kreg = jnp.where(jnp.asarray(mm), Kreg, 0.0)
        eye = jnp.eye(msv, dtype=Kreg.dtype)
        Kreg = Kreg + jnp.where(jnp.asarray(svmask)[:, :, None], noise, 1.0) * eye
        Lchol = jnp.linalg.cholesky(Kreg)
    else:
        Lchol = jnp.zeros((part.k, 0, 0), jnp.float32)

    sm = ServingModel(
        Xm=jnp.asarray(np.asarray(part.model.Xm)),
        Wm=jnp.asarray(np.asarray(part.model.W)),
        sm=jnp.asarray(np.asarray(part.model.s)),
        Xsv=Xsv_j, Wsv=jnp.asarray(Wsv), svmask=jnp.asarray(svmask),
        Xall=jnp.asarray(Xall), Wall=jnp.asarray(Wall),
        Lchol=Lchol, classes=jnp.asarray(classes),
        rho=jnp.asarray(rho, jnp.float32), rho_c=jnp.asarray(rho_c),
    )
    return jax.device_put(sm)


# ---------------------------------------------------------------------------
# jitted request programs (scores (nq, n_classes); argmax happens on device)
# ---------------------------------------------------------------------------

def _cluster_offsets(sm: ServingModel) -> Array:
    """(k,) decision offsets, one per cluster: the per-cluster multipliers
    rho_c of an early-stopped one-class export when present, else the
    global rho broadcast (0 for every non-ocsvm model, so applying these
    unconditionally is a uniform no-op outside the equality family)."""
    if sm.rho_c.shape[0]:
        return sm.rho_c
    return jnp.broadcast_to(jnp.asarray(sm.rho, jnp.float32), (sm.k,))


@partial(jax.jit, static_argnames=("kern", "use_pallas"))
def serve_scores_exact(sm: ServingModel, Xq: Array, kern: Kernel,
                       use_pallas: bool = False) -> Array:
    # sm.rho == 0 for non-ocsvm models; every scorer applies its own offset
    # so serve_batch never has to know which strategy already subtracted it
    return gram(kern, Xq, sm.Xall, use_pallas=use_pallas) @ sm.Wall - sm.rho


def serve_scores_early(sm: ServingModel, Xq: Array, kern: Kernel, cap: int,
                       use_pallas: bool = False) -> Array:
    """Route + bucketed SV-block scoring — the same jitted program as
    training-side early prediction (``predict._early_program``), fed the
    packed serving blocks.  The routed cluster's offset (per-cluster rho_c
    of an early-stopped one-class export, global rho otherwise) is applied
    inside the fused program."""
    route = KKMeansModel(Xm=sm.Xm, W=sm.Wm, s=sm.sm)
    return _early_program(kern, Xq, route, sm.Xsv, sm.Wsv, cap,
                          use_pallas=use_pallas,
                          offsets=_cluster_offsets(sm)[:, None])


@partial(jax.jit, static_argnames=("kern",))
def serve_scores_bcm(sm: ServingModel, Xq: Array, kern: Kernel,
                     noise: float = 1e-2) -> Array:
    diag = kern.diag(Xq)

    def per_cluster(Xc, Wc, Lc, mc, off):
        Kqs = kern.pairwise(Xq, Xc) * mc[None, :]
        # committee member c votes with ITS local decision f_c - rho_c
        f = Kqs @ Wc - off                                   # (nq, C)
        # Lchol was factored at export: two triangular solves per request
        sol = jax.scipy.linalg.cho_solve((Lc, True), Kqs.T)  # (s, nq)
        var = jnp.maximum(diag - jnp.einsum("qs,sq->q", Kqs, sol), noise)
        prec = jnp.where(jnp.any(mc), 1.0 / var, 0.0)        # skip empty blocks
        return f * prec[:, None], prec

    fs, ps = jax.vmap(per_cluster)(sm.Xsv, sm.Wsv, sm.Lchol, sm.svmask,
                                   _cluster_offsets(sm))
    return jnp.sum(fs, 0) / (jnp.sum(ps, 0) + 1e-12)[:, None]


def serve_batch(sm: ServingModel, Xq: Array, kern: Kernel, strategy: str,
                use_pallas: Optional[bool] = None,
                bucket: Optional[int] = None) -> Tuple[Array, Array]:
    """One batched request: returns (predictions, scores).

    Predictions are class labels (argmax over score columns) for
    classification models, raw regression values for ``task == "svr"``
    models (the single beta-score column, no argmax), and +/-1
    inlier/outlier labels for ``task == "ocsvm"`` (sign of score - rho; the
    returned scores are the offset decision values) — every branch is on a
    static shape, so each path stays one compiled program per strategy.

    ``bucket``, when given, pads the batch with zero query rows to exactly
    ``bucket`` rows before scoring and slices the results back to the real
    rows.  Everything shape-derived — the jit signature AND the early
    strategy's static buffer capacity (``early_capacity``) — then depends
    only on the bucket, so ragged request sizes sharing a bucket share ONE
    compiled program (unbucketed, every distinct batch size recompiled the
    early program through its shape-derived ``cap``).  Per-row scores are
    independent of the padding rows, so bucketed results on the real rows
    match the unbucketed ones."""
    nq = Xq.shape[0]
    if bucket is not None:
        pad = int(bucket) - nq
        if pad < 0:
            raise ValueError(f"bucket={bucket} smaller than the batch ({nq})")
        if pad:
            Xq = jnp.concatenate(
                [Xq, jnp.zeros((pad, Xq.shape[1]), Xq.dtype)])
    up = resolve_use_pallas(use_pallas)
    if strategy == "exact":
        scores = serve_scores_exact(sm, Xq, kern, use_pallas=up)
    elif strategy == "early":
        # cap derives from the (possibly padded) batch shape: with a bucket
        # it is a pure function of the bucket, keeping the jit cache warm
        cap = early_capacity(Xq.shape[0], sm.k)
        scores = serve_scores_early(sm, Xq, kern, cap, use_pallas=up)
    elif strategy == "bcm":
        if sm.Lchol.shape[1] == 0:
            raise ValueError("model was exported with with_bcm=False; "
                             "re-export to serve the bcm strategy")
        scores = serve_scores_bcm(sm, Xq, kern)
    else:
        raise ValueError(f"unknown strategy: {strategy}")
    scores = scores[:nq]
    if sm.task == "svr":
        return scores[:, 0], scores
    if sm.task == "ocsvm":
        # every scorer already applied its offset (rho / per-cluster rho_c)
        raw = scores[:, 0]
        return jnp.where(raw >= 0, 1.0, -1.0).astype(raw.dtype), raw[:, None]
    return sm.classes[jnp.argmax(scores, axis=1)], scores


def serving_cache_size() -> int:
    """Total jit-cache entries across every serving program — the compile
    counter's raw signal.  Any growth between two reads means a serving
    call compiled a fresh executable (a new batch/bucket shape, strategy,
    model signature, or capacity); the engine and the request loop read it
    around their timed regions to pin "zero recompiles after warmup"."""
    from repro.core.predict import _decision_scan

    progs = (_early_program, _decision_scan, serve_scores_exact,
             serve_scores_bcm)
    return sum(p._cache_size() for p in progs)


def run_request_loop(sm: ServingModel, kern: Kernel, strategy: str,
                     batches, use_pallas: Optional[bool] = None,
                     warmup: int = 2,
                     metrics: Optional[MetricsRegistry] = None,
                     bucketed: bool = False) -> dict:
    """Drive the jitted request program over a query stream, sync per
    response (a real serving loop), and report latency/throughput.

    ``batches`` is either a stacked (num_batches, batch, d) array (one
    static shape — the historical fixed-batch loop) or a sequence of
    (nq_i, d) arrays with RAGGED sizes; ``bucketed=True`` pads each batch
    to its power-of-two bucket (``predict.bucket_size``) so ragged sizes
    share compiled programs.

    Warmup covers EVERY distinct compiled signature (batch shape x bucket)
    appearing in the stream, not just the first batch's: with ragged
    batches, a first-shape-only warmup leaves later shapes to compile
    inside the timed region, and those multi-hundred-ms outliers corrupt
    p95/p99.  The report's ``compiles_timed`` (jit-cache growth across the
    timed loop, ``serving_cache_size``) pins the invariant: after warmup
    the timed region must serve with ZERO recompiles.

    With ``metrics``, each response latency feeds a per-strategy streaming
    histogram (``serve_latency_seconds``) and the loop maintains
    request/query counters; ``early`` additionally records the per-cluster
    route distribution and how many extra on-device overflow rounds the
    bucketed program paid (queries past ``early_capacity`` slots per
    cluster).  Routing stats are computed OUTSIDE the timed loop — the
    measured latencies stay those of the serving program alone."""
    if isinstance(batches, (list, tuple)):
        blist = [jnp.asarray(b) for b in batches]
    else:
        blist = [batches[i] for i in range(batches.shape[0])]
    sizes = [int(b.shape[0]) for b in blist]
    buckets = [bucket_size(n) if bucketed else None for n in sizes]
    uniform = len(set(sizes)) == 1

    # warm every distinct (shape, bucket) signature before timing
    distinct = {}
    for b, bk in zip(blist, buckets):
        distinct.setdefault((b.shape, bk), (b, bk))
    for _ in range(max(1, warmup)):
        for b, bk in distinct.values():
            pred, _ = serve_batch(sm, b, kern, strategy, use_pallas,
                                  bucket=bk)
            pred.block_until_ready()

    hist = (metrics.histogram("serve_latency_seconds", strategy=strategy)
            if metrics is not None else None)
    lat = []
    cache0 = serving_cache_size()
    t_all = time.perf_counter()
    for b, bk in zip(blist, buckets):
        t0 = time.perf_counter()
        pred, _ = serve_batch(sm, b, kern, strategy, use_pallas, bucket=bk)
        pred.block_until_ready()
        lat.append(time.perf_counter() - t0)
        if hist is not None:
            hist.observe(lat[-1])
    wall = time.perf_counter() - t_all
    compiles_timed = serving_cache_size() - cache0
    if metrics is not None:
        metrics.counter("serve_requests_total", strategy=strategy).inc(
            len(blist))
        metrics.counter("serve_queries_total", strategy=strategy).inc(
            sum(sizes))
        if compiles_timed:
            metrics.counter("serve_compiles_total", strategy=strategy).inc(
                compiles_timed)
        if strategy == "early":
            _record_route_metrics(sm, kern, blist, buckets, metrics,
                                  resolve_use_pallas(use_pallas))
    lat_ms = np.sort(np.asarray(lat)) * 1e3
    return {
        "strategy": strategy,
        "batch": sizes[0] if uniform else 0,   # 0 = ragged stream
        "batches": len(blist),
        "queries": int(sum(sizes)),
        "compiles_timed": int(compiles_timed),
        "qps": sum(sizes) / max(wall, 1e-9),
        "lat_ms_mean": float(lat_ms.mean()),
        "lat_ms_p50": float(np.percentile(lat_ms, 50)),
        "lat_ms_p95": float(np.percentile(lat_ms, 95)),
        "lat_ms_p99": float(np.percentile(lat_ms, 99)),
    }


def _record_route_metrics(sm: ServingModel, kern: Kernel, blist, buckets,
                          metrics: MetricsRegistry, use_pallas: bool) -> None:
    """Early-strategy routing telemetry: per-cluster query distribution and
    the number of EXTRA bucketed scoring rounds caused by per-batch cluster
    loads above ``early_capacity`` (the fused program's per-round buffer)."""
    from repro.core.kkmeans import assign_points

    route_model = KKMeansModel(Xm=sm.Xm, W=sm.Wm, s=sm.sm)
    assign, _ = assign_points(kern, route_model, jnp.concatenate(blist),
                              use_pallas=use_pallas)
    assign = np.asarray(assign)
    total = np.bincount(assign, minlength=sm.k)
    for c in range(sm.k):
        if total[c]:
            metrics.counter("serve_route_total", cluster=str(c)).inc(
                int(total[c]))
    overflow = 0
    off = 0
    for b, bk in zip(blist, buckets):
        row = assign[off: off + b.shape[0]]
        off += b.shape[0]
        if row.size == 0:
            continue
        # the program's capacity is bucket-derived when serving bucketed
        cap = early_capacity(bk if bk is not None else b.shape[0], sm.k)
        overflow += max(
            0, -(-int(np.bincount(row, minlength=sm.k).max()) // cap) - 1)
    metrics.counter("serve_early_overflow_rounds_total").inc(overflow)


def _serve_async(args, model, Xpool: np.ndarray) -> None:
    """--serve-async: register the model, warm every bucket signature, and
    drive a Poisson trace of mixed-size requests through the continuous-
    batching engine (imports are local: registry/engine import this
    module)."""
    import asyncio

    from repro.launch.engine import (
        AsyncServingEngine, DeadlineExceeded, EngineConfig, EngineOverloaded,
    )
    from repro.launch.registry import ModelRegistry

    registry = ModelRegistry()
    man = registry.register("default", model,
                            with_bcm=(args.strategy == "bcm"))
    if args.registry:
        registry.save(args.registry)
        print(f"registry manifests -> {args.registry}")
    engine = AsyncServingEngine(registry, EngineConfig(
        max_batch=args.batch,
        max_queue_rows=args.max_queue if args.max_queue > 0 else None,
        timeout_s=args.timeout_s if args.timeout_s > 0 else None))
    warm = engine.warmup(strategies=[args.strategy])
    rng = np.random.default_rng(args.seed)
    n_req = args.batches
    sizes = rng.choice([1, 4, 16, 64], size=n_req, p=[0.35, 0.3, 0.25, 0.1])
    arrivals = np.cumsum(rng.exponential(1.0 / args.qps, size=n_req))
    lats: list = []
    outcomes = {"shed": 0, "expired": 0}

    async def one(delay: float, size: int) -> None:
        await asyncio.sleep(delay)
        Xq = Xpool[rng.integers(0, Xpool.shape[0], size=size)]
        t0 = time.perf_counter()
        try:
            await engine.submit(Xq, "default", strategy=args.strategy)
        except EngineOverloaded:
            outcomes["shed"] += 1           # the in-process 429
            return
        except DeadlineExceeded:
            outcomes["expired"] += 1
            return
        lats.append(time.perf_counter() - t0)

    async def drive() -> None:
        async with engine:
            await asyncio.gather(*[
                one(float(arrivals[i]), int(sizes[i])) for i in range(n_req)])

    asyncio.run(drive())
    stats = engine.stats()
    # tails over ADMITTED-and-delivered requests only: shed/expired
    # requests fail fast by design and must not pollute the latency report
    ms = (np.asarray(lats) * 1e3 if lats else np.asarray([float("nan")]))
    print(f"async {args.strategy} v{man.version}: {n_req} requests "
          f"({int(sizes.sum())} queries) at {args.qps:.0f} offered rps | "
          f"delivered {len(lats)} shed {outcomes['shed']} "
          f"expired {outcomes['expired']} | "
          f"admitted lat ms p50 {np.percentile(ms, 50):.2f} "
          f"p95 {np.percentile(ms, 95):.2f} p99 {np.percentile(ms, 99):.2f} "
          f"| warmup compiles {warm}, after warmup "
          f"{stats['compiles_after_warmup']}")
    if args.metrics_out:
        prom = engine.metrics.dump(args.metrics_out)
        print(f"metrics -> {args.metrics_out} and {prom}", flush=True)


def main(argv=None) -> None:
    from repro.core.dcsvm import fit
    from repro.core.predict import accuracy_multiclass, f1, mse, recall
    from repro.core.tasks import EpsilonSVR, OneClassSVM
    from repro.data import (
        friedman1, gaussian_mixture_multiclass, gaussian_with_outliers,
        train_test_split,
    )

    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="svc", choices=["svc", "svr", "ocsvm"])
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--classes", type=int, default=3)
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--strategy", default="early",
                    choices=["exact", "early", "bcm"])
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=50)
    ap.add_argument("--gamma", type=float, default=8.0)
    ap.add_argument("--C", type=float, default=4.0)
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--nu", type=float, default=0.1,
                    help="one-class support/outlier mass bound")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="",
                    help="dump serving metrics (latency histograms, "
                         "request/route counters) as JSON at this path plus "
                         "Prometheus text exposition next to it (.prom)")
    ap.add_argument("--serve-async", action="store_true",
                    help="serve through the asyncio continuous-batching "
                         "engine (launch/engine.py): Poisson arrivals with "
                         "mixed request sizes against the versioned "
                         "registry, instead of the fixed-batch sync loop")
    ap.add_argument("--qps", type=float, default=500.0,
                    help="offered Poisson request rate for --serve-async")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="--serve-async admission bound on queued query "
                         "rows; submits past it shed with EngineOverloaded "
                         "(0 = unbounded)")
    ap.add_argument("--timeout-s", type=float, default=0.0,
                    help="--serve-async default per-request deadline; "
                         "requests expiring in queue resolve with "
                         "DeadlineExceeded before batch formation "
                         "(0 = none)")
    ap.add_argument("--registry", default="",
                    help="write the model registry's manifests JSON here "
                         "(--serve-async)")
    args = ap.parse_args(argv)

    kern = Kernel("rbf", gamma=args.gamma)
    t0 = time.perf_counter()
    if args.task == "svr":
        X, y = friedman1(jax.random.PRNGKey(args.seed), args.n)
    elif args.task == "ocsvm":
        X, y = gaussian_with_outliers(jax.random.PRNGKey(args.seed), args.n)
    else:
        X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(args.seed),
                                           args.n, n_classes=args.classes)
    Xtr, ytr, Xte, yte = train_test_split(
        jax.random.PRNGKey(args.seed + 1), X, y)
    cfg = DCSVMConfig(kernel=kern, C=args.C, k=args.k, levels=args.levels,
                      m=min(1000, Xtr.shape[0]), tol=1e-3, seed=args.seed)
    if args.task == "svr":
        model = fit(cfg, Xtr, ytr, task=EpsilonSVR(eps=args.eps))
        print(f"fit svr: {time.perf_counter()-t0:.1f}s  "
              f"n_sv={len(model.sv_index)}/{Xtr.shape[0]}")
    elif args.task == "ocsvm":
        model = fit(cfg, Xtr, task=OneClassSVM(nu=args.nu))  # label-free
        print(f"fit ocsvm: {time.perf_counter()-t0:.1f}s  "
              f"n_sv={len(model.sv_index)}/{Xtr.shape[0]}  "
              f"rho={model.rho:.4f}")
    else:
        model = fit_ova(cfg, Xtr, ytr)
        print(f"fit_ova: {time.perf_counter()-t0:.1f}s  "
              f"n_sv={len(model.sv_union)}/{Xtr.shape[0]}")

    sm = export_serving_model(model)
    pred, _ = serve_batch(sm, Xte, kern, args.strategy)
    if sm.task == "svr":
        print(f"serving mse ({args.strategy}): {mse(yte, pred):.5f}")
    elif sm.task == "ocsvm":
        print(f"serving outlier recall ({args.strategy}): "
              f"{recall(yte, pred, -1.0):.4f}  f1: {f1(yte, pred, -1.0):.4f}")
    else:
        acc = accuracy_multiclass(yte, pred)
        print(f"serving accuracy ({args.strategy}): {acc:.4f}")

    if args.serve_async:
        _serve_async(args, model, np.asarray(Xte))
        return

    rng = np.random.default_rng(args.seed)
    idx = rng.integers(0, Xte.shape[0], size=(args.batches, args.batch))
    batches = jnp.asarray(np.asarray(Xte)[idx])
    registry = MetricsRegistry() if args.metrics_out else None
    if registry is not None:
        registry.counter("serve_strategy_selected_total",
                         strategy=args.strategy).inc()
    rep = run_request_loop(sm, kern, args.strategy, batches, metrics=registry)
    print(f"{rep['strategy']}: {rep['qps']:.0f} q/s | "
          f"lat ms mean {rep['lat_ms_mean']:.2f} "
          f"p50 {rep['lat_ms_p50']:.2f} p95 {rep['lat_ms_p95']:.2f} "
          f"p99 {rep['lat_ms_p99']:.2f}")
    if registry is not None:
        prom = registry.dump(args.metrics_out)
        print(f"metrics -> {args.metrics_out} and {prom}", flush=True)


if __name__ == "__main__":
    main()
