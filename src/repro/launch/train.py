"""LM training driver (fault-tolerant).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 200 --batch 8 --seq 128

Fault tolerance in this loop:
  * atomic keep-K checkpoints of (params, opt_state) + the integer data
    cursor — restart resumes bit-exact (the data pipeline is a pure function
    of (seed, step));
  * SIGTERM/SIGINT triggers a final blocking checkpoint (preemption grace);
  * the mesh is rebuilt from whatever devices exist at restart and the
    checkpoint is re-placed under the new shardings (elastic posture —
    PartitionSpecs are axis-name based, not device-index based).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.configs import SHAPES, RunShape, get_config
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train
from repro.models.param import init_tree
from repro.optim import AdamWConfig, adamw_init


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_host_mesh()
    shape = RunShape("cli_train", args.seq, args.batch, "train")
    opt_cfg = AdamWConfig(lr=args.lr)
    build = build_train(cfg, mesh, shape, opt_cfg=opt_cfg,
                        chunk=min(1024, args.seq),
                        microbatches=args.microbatches,
                        total_steps=args.steps)

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab, global_batch=args.batch, seq_len=args.seq,
        seed=args.seed))

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start_step = 0
    if mgr.latest_step() is not None:
        start_step = mgr.latest_step()
        target = {"params": build.abstract_args[0], "opt": build.abstract_args[1]}
        shardings = {"params": build.param_shardings, "opt": build.opt_shardings}
        state = mgr.restore(target, shardings=shardings)
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start_step}", flush=True)
    else:
        params = init_tree(build.decls, jax.random.PRNGKey(args.seed),
                           jnp.dtype(cfg.param_dtype))
        params = jax.device_put(params, build.param_shardings)
        opt = adamw_init(opt_cfg, params)
        opt = jax.device_put(opt, build.opt_shardings)

    stop = {"now": False}

    def handle(sig, frame):
        stop["now"] = True
        print("preemption signal: checkpointing and exiting", flush=True)

    signal.signal(signal.SIGTERM, handle)
    signal.signal(signal.SIGINT, handle)

    t_start = time.perf_counter()
    tokens_per_step = args.batch * args.seq
    for step in range(start_step, args.steps):
        batch_tok, batch_tgt = pipe.global_batch_at(jnp.asarray(step))
        batch = {"tokens": batch_tok, "targets": batch_tgt}
        params, opt, metrics = build.step_fn(params, opt, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t_start
            print(f"step {step:5d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e} "
                  f"tok/s={(step - start_step + 1) * tokens_per_step / dt:.0f}",
                  flush=True)
        if (step + 1) % args.ckpt_every == 0 or stop["now"] or step == args.steps - 1:
            mgr.save(step + 1, {"params": params, "opt": opt},
                     blocking=stop["now"])
        if stop["now"]:
            mgr.wait()
            sys.exit(0)
    mgr.wait()
    print("training complete", flush=True)


if __name__ == "__main__":
    main()
