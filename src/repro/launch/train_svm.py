"""DC-SVM end-to-end training driver (the paper's workload, all tasks).

    PYTHONPATH=src python -m repro.launch.train_svm --n 20000 --levels 3 \
        --dataset covtype_like --ckpt-dir /tmp/dcsvm_ckpt
    PYTHONPATH=src python -m repro.launch.train_svm --task svr \
        --dataset friedman1 --eps 0.1
    PYTHONPATH=src python -m repro.launch.train_svm --task weighted-svc \
        --dataset imbalanced --class-weight 20
    PYTHONPATH=src python -m repro.launch.train_svm --task one-class \
        --dataset outliers --nu 0.1
    PYTHONPATH=src python -m repro.launch.train_svm --task nu-svc --nu 0.3

Tasks: ``svc`` (hinge C-SVC), ``weighted-svc`` (cost-sensitive box
``c_i = C * w_{y_i}``; ``--class-weight POS[,NEG]``), ``svr``
(epsilon-insensitive regression; ``--eps``), ``nu-svc`` (nu-parameterized
classification; ``--nu`` bounds the support mass, ``--nu-bias`` restores
the bias term via the two-constraint dual solved per label group) and
``one-class`` (label-free anomaly detection via the equality-constrained
dual; ``--nu`` bounds the outlier fraction).  Regression reports MSE/MAE,
weighted classification additionally reports per-class recall, one-class
reports outlier precision/recall/F1 against the generator's ground-truth
labels.  ``--eq-block B`` runs the equality-family conquer with the
rank-2B blocked pairwise engine (B maximal-violating pairs per iteration;
1 = the paper-faithful SMO-style rank-2 engine).

Fault tolerance: after every level the (alpha, level, assign) state is
checkpointed; restart resumes at the next level (the expensive bottom levels
are never recomputed).  With --distributed the divide/conquer steps run
shard_mapped over all local devices: the conquer defaults to parallel block
minimization (every device solves its own top-B block per communication
round, --dist-mode replicated recovers the one-global-block baseline) and
covers svc, weighted-svc and svr through the generalized TaskDual path.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.core import (
    DCSVMConfig, EpsilonSVR, Kernel, NuSVC, OneClassSVM, WeightedCSVC,
    accuracy, f1, fit, mae, mse, precision, predict_early, predict_exact,
    recall,
)
from repro.core.dcsvm import DCSVMModel
from repro.data import (
    checkerboard, covtype_like, friedman1, gaussian_mixture,
    gaussian_mixture_imbalanced, gaussian_with_outliers, sinc1d,
    stratified_split, train_test_split, webspam_like,
)

DATASETS = {
    "covtype_like": covtype_like,
    "webspam_like": webspam_like,
    "checkerboard": lambda k, n: checkerboard(k, n, cells=4),
    "gaussian": lambda k, n: gaussian_mixture(k, n, d=16, modes_per_class=8),
    "imbalanced": lambda k, n: gaussian_mixture_imbalanced(k, n, d=10),
    "outliers": gaussian_with_outliers,
    "sinc1d": sinc1d,
    "friedman1": friedman1,
}
REGRESSION_DATASETS = {"sinc1d", "friedman1"}
ONECLASS_DATASETS = {"outliers"}


def parse_class_weight(spec: str):
    """"POS" or "POS,NEG" -> (w_pos, w_neg)."""
    parts = [float(v) for v in spec.split(",") if v]
    if len(parts) == 1:
        return parts[0], 1.0
    if len(parts) == 2:
        return parts[0], parts[1]
    raise ValueError(f"--class-weight expects POS[,NEG], got {spec!r}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="svc",
                    choices=["svc", "weighted-svc", "svr", "nu-svc",
                             "one-class"])
    ap.add_argument("--dataset", default="gaussian", choices=sorted(DATASETS))
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--C", type=float, default=4.0)
    ap.add_argument("--gamma", type=float, default=8.0)
    ap.add_argument("--kernel", default="rbf", choices=["rbf", "poly", "linear"])
    ap.add_argument("--class-weight", default="10",
                    help="weighted-svc cost multipliers POS[,NEG] on top of C")
    ap.add_argument("--eps", type=float, default=0.1,
                    help="epsilon-SVR insensitivity tube half-width")
    ap.add_argument("--nu", type=float, default=0.1,
                    help="nu-svc / one-class support-mass bound in (0, 1]")
    ap.add_argument("--nu-bias", action="store_true",
                    help="nu-svc only: restore the bias term (two-constraint "
                         "dual, solved per label group)")
    ap.add_argument("--eq-block", type=int, default=1,
                    help="equality-family rank-2B block size B (pairs per "
                         "outer iteration); 1 = rank-2 pairwise engine")
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--m", type=int, default=1000)
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--block", type=int, default=0)
    ap.add_argument("--early", type=int, default=0,
                    help="stop at this level and use early prediction")
    ap.add_argument("--distributed", action="store_true",
                    help="shard the divide/conquer over all local devices "
                         "(svc, weighted-svc and svr; force host devices "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--dist-mode", default="parallel",
                    choices=["parallel", "replicated"],
                    help="conquer scheme: 'parallel' = P simultaneous local "
                         "block solves per communication round (CE-PBM), "
                         "'replicated' = one global block per round")
    ap.add_argument("--dist-cache", type=int, default=0,
                    help="per-device kernel-row LRU capacity for the "
                         "parallel conquer (0 = recompute rows on the fly)")
    ap.add_argument("--compute-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="Gram matmul-operand precision (accumulation stays "
                         "f32); float32 keeps the bit-exact default paths")
    ap.add_argument("--host-spill", action="store_true",
                    help="level-0 out-of-core solve: kernel-row panels live "
                         "in host RAM, a device LRU holds the working set "
                         "within --gram-budget bytes")
    ap.add_argument("--gram-budget", type=int, default=0,
                    help="byte budget for Gram storage tiers (0 = default)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON of the fit's span "
                         "tree (divide/conquer phases) to this path and "
                         "print the aggregated span table; load in Perfetto "
                         "or chrome://tracing")
    ap.add_argument("--trace-cap", type=int, default=0,
                    help="device-resident convergence-trace ring capacity "
                         "for the level-0 solve (keeps the LAST N "
                         "per-iteration samples; 0 = tracing off, solver "
                         "jaxprs bit-identical to the untraced build)")
    ap.add_argument("--stats-json", default="",
                    help="dump per-level training stats (times, SV counts, "
                         "cache counters, convergence traces) as JSON")
    args = ap.parse_args(argv)

    is_reg = args.dataset in REGRESSION_DATASETS
    if (args.task == "svr") != is_reg:
        ap.error(f"--task {args.task} needs a "
                 f"{'regression' if args.task == 'svr' else 'classification'} "
                 f"dataset; --dataset {args.dataset} is not one "
                 f"(regression: {sorted(REGRESSION_DATASETS)})")
    if args.task == "one-class" and args.dataset not in ONECLASS_DATASETS:
        ap.error(f"--task one-class needs a dataset with inlier/outlier "
                 f"ground truth for evaluation: {sorted(ONECLASS_DATASETS)}; "
                 f"got --dataset {args.dataset}")

    task = None
    if args.task == "weighted-svc":
        w_pos, w_neg = parse_class_weight(args.class_weight)
        task = WeightedCSVC(w_pos=w_pos, w_neg=w_neg)
    elif args.task == "svr":
        task = EpsilonSVR(eps=args.eps)
    elif args.task == "nu-svc":
        task = NuSVC(nu=args.nu, with_bias=args.nu_bias)
    elif args.task == "one-class":
        task = OneClassSVM(nu=args.nu)
    if args.nu_bias and args.task != "nu-svc":
        ap.error("--nu-bias applies to --task nu-svc only")

    key = jax.random.PRNGKey(args.seed)
    X, y = DATASETS[args.dataset](key, args.n)
    split = stratified_split if args.dataset == "imbalanced" else train_test_split
    Xtr, ytr, Xte, yte = split(jax.random.fold_in(key, 1), X, y)
    kern = Kernel(args.kernel, gamma=args.gamma)
    extra = {}
    if args.compute_dtype != "float32":     # float32 = the bit-exact default
        extra["compute_dtype"] = args.compute_dtype
    if args.gram_budget > 0:
        extra["gram_budget"] = args.gram_budget
    if args.trace_cap > 0:
        extra["trace"] = args.trace_cap
    cfg = DCSVMConfig(kernel=kern, C=args.C, k=args.k, levels=args.levels,
                      m=args.m, tol=args.tol, block=args.block,
                      eq_block_size=args.eq_block,
                      early_stop_level=args.early, seed=args.seed,
                      host_spill=args.host_spill, **extra)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    tracer = None
    span_ctx = contextlib.nullcontext()
    if args.trace:
        from repro.obs.spans import SpanTracer
        tracer = SpanTracer()
        span_ctx = tracer.activate()

    t0 = time.perf_counter()
    with span_ctx:
        model = _train(args, cfg, task, Xtr, ytr, mgr)
    t_train = time.perf_counter() - t0

    if tracer is not None:
        tracer.write_chrome_trace(args.trace)
        print(f"chrome trace -> {args.trace}", flush=True)
        print(tracer.summary(), flush=True)
    if args.stats_json:
        payload = {"task": args.task, "dataset": args.dataset,
                   "n": int(Xtr.shape[0]), "train_time": t_train,
                   "levels": model.level_stats}
        with open(args.stats_json, "w") as f:
            json.dump(payload, f, indent=1, default=_json_default)
        print(f"stats -> {args.stats_json}", flush=True)
    _evaluate(args, model, Xte, yte, Xtr, t_train)
    if mgr is not None:
        mgr.wait()


def _json_default(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.ndarray, jax.Array)):
        return np.asarray(v).tolist()
    raise TypeError(f"not JSON-serializable: {type(v)!r}")


def _train(args, cfg, task, Xtr, ytr, mgr) -> DCSVMModel:
    def cb(level, alpha, st):
        print(f"level {level}: clusters={st.get('clusters', 1)} "
              f"n_sv={st['n_sv']} cluster_t={st.get('cluster_time', 0):.1f}s "
              f"train_t={st['train_time']:.1f}s", flush=True)
        if mgr is not None:
            mgr.save(cfg.levels - level + 1,
                     {"alpha": alpha, "level": jnp.asarray(level)},
                     blocking=False)

    if args.distributed:
        if args.task in ("nu-svc", "one-class"):
            raise SystemExit(
                "--distributed covers the box-constrained duals (svc, "
                "weighted-svc, svr); the equality-constrained tasks "
                f"({args.task}) need the pairwise engine — drop "
                "--distributed")
        from repro.core.distributed import fit_distributed_model
        from repro.launch.mesh import make_conquer_mesh
        mesh = make_conquer_mesh("i")
        model = fit_distributed_model(
            cfg, mesh, "i", Xtr, ytr, task=task,
            conquer_block=max(args.block, 64),
            mode=args.dist_mode, cache_cap=args.dist_cache)
        for st in model.level_stats:
            print({k: v for k, v in st.items() if k != "trace"}, flush=True)
        return model
    return fit(cfg, Xtr, ytr, callback=cb, task=task)


def _evaluate(args, model: DCSVMModel, Xte, yte, Xtr, t_train: float) -> None:
    if model.is_early:
        pred = predict_early(model, Xte)
        mode = f"early prediction (level {args.early})"
    else:
        pred = predict_exact(model, Xte)
        mode = "exact"
    n_sv = len(model.sv_index)
    if args.task == "svr":
        metrics = f"test mse {mse(yte, pred):.5f} mae {mae(yte, pred):.5f}"
    elif args.task == "one-class":
        metrics = (f"outlier recall {recall(yte, pred, -1.0):.4f} "
                   f"precision {precision(yte, pred, -1.0):.4f} "
                   f"f1 {f1(yte, pred, -1.0):.4f} | "
                   f"pred outlier rate {float(np.mean(np.asarray(pred) < 0)):.4f} "
                   f"(nu={args.nu}) rho={model.rho:.4f}")
    else:
        metrics = f"test acc {accuracy(yte, pred):.4f}"
        if args.task == "weighted-svc":
            metrics += (f" | recall +1 {recall(yte, pred, 1.0):.4f}"
                        f" -1 {recall(yte, pred, -1.0):.4f}")
    print(f"done in {t_train:.1f}s | {mode} | {metrics} | "
          f"SVs {n_sv}/{Xtr.shape[0]}", flush=True)


if __name__ == "__main__":
    main()
