"""DC-SVM end-to-end training driver (the paper's workload).

    PYTHONPATH=src python -m repro.launch.train_svm --n 20000 --levels 3 \
        --dataset covtype_like --ckpt-dir /tmp/dcsvm_ckpt

Fault tolerance: after every level the (alpha, level, assign) state is
checkpointed; restart resumes at the next level (the expensive bottom levels
are never recomputed).  With --distributed the divide/conquer steps run
shard_mapped over all local devices.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.core import (
    DCSVMConfig, Kernel, accuracy, fit, predict_early, predict_exact,
)
from repro.core.dcsvm import DCSVMModel
from repro.data import (
    checkerboard, covtype_like, gaussian_mixture, train_test_split,
    webspam_like,
)

DATASETS = {
    "covtype_like": covtype_like,
    "webspam_like": webspam_like,
    "checkerboard": lambda k, n: checkerboard(k, n, cells=4),
    "gaussian": lambda k, n: gaussian_mixture(k, n, d=16, modes_per_class=8),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="gaussian", choices=sorted(DATASETS))
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--C", type=float, default=4.0)
    ap.add_argument("--gamma", type=float, default=8.0)
    ap.add_argument("--kernel", default="rbf", choices=["rbf", "poly"])
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--m", type=int, default=1000)
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--block", type=int, default=0)
    ap.add_argument("--early", type=int, default=0,
                    help="stop at this level and use early prediction")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    X, y = DATASETS[args.dataset](key, args.n)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.fold_in(key, 1), X, y)
    kern = Kernel(args.kernel, gamma=args.gamma)
    cfg = DCSVMConfig(kernel=kern, C=args.C, k=args.k, levels=args.levels,
                      m=args.m, tol=args.tol, block=args.block,
                      early_stop_level=args.early, seed=args.seed)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    def cb(level, alpha, st):
        print(f"level {level}: clusters={st.get('clusters', 1)} "
              f"n_sv={st['n_sv']} cluster_t={st.get('cluster_time', 0):.1f}s "
              f"train_t={st['train_time']:.1f}s", flush=True)
        if mgr is not None:
            mgr.save(cfg.levels - level + 1,
                     {"alpha": alpha, "level": jnp.asarray(level)},
                     blocking=False)

    t0 = time.perf_counter()
    if args.distributed:
        from repro.core.distributed import fit_distributed
        from repro.launch.mesh import make_host_mesh
        mesh = jax.make_mesh((jax.device_count(),), ("i",))
        alpha, stats = fit_distributed(cfg, mesh, "i", Xtr, ytr)
        model = DCSVMModel(cfg, Xtr, ytr, alpha, None, False,
                           stats)
        for st in stats:
            print(st, flush=True)
    else:
        model = fit(cfg, Xtr, ytr, callback=cb)
    t_train = time.perf_counter() - t0

    if model.is_early:
        acc = accuracy(yte, predict_early(model, Xte))
        mode = f"early prediction (level {args.early})"
    else:
        acc = accuracy(yte, predict_exact(model, Xte))
        mode = "exact"
    n_sv = int(np.sum(np.asarray(model.alpha) > 0))
    print(f"done in {t_train:.1f}s | {mode} | test acc {acc:.4f} | "
          f"SVs {n_sv}/{Xtr.shape[0]}", flush=True)
    if mgr is not None:
        mgr.wait()


if __name__ == "__main__":
    main()
