"""Asyncio serving engine: continuous batching over the versioned registry.

The ColossalAI async-inference shape grown around ``serve_batch``: an
asyncio front end wrapping a request->future map over a batch manager that
pops ready requests into pad-bucketed batches.

* ``submit`` resolves the request's (model, version) against the registry's
  route table ONCE at enqueue (so a hot swap repoints later requests while
  queued ones keep their resolved version), attaches an ``asyncio.Future``,
  and parks the request on its (name, version, strategy) group queue.
* The batch-manager task pops the group with the oldest waiting request,
  drains up to ``max_batch`` query rows from it (continuous batching: one
  slow group never blocks another; late arrivals ride the next pop),
  concatenates the rows, and serves them through ``serve_batch`` padded to
  a power-of-two bucket (``predict.bucket_size``).  Everything the jit
  cache keys on — batch shape AND the early strategy's static
  ``early_capacity`` — derives from the bucket, so ragged request sizes
  collapse onto O(log max_batch) compiled programs and the cache stays
  warm forever.
* Results scatter back per request id: each future resolves with exactly
  its own (pred, scores) rows, bit-identical to a direct ``serve_batch``
  call on the same rows (per-row scores are independent of batch-mates and
  padding).

``warmup`` pre-compiles every (version, strategy, bucket) signature outside
the request path and marks the compile-counter baseline; after that the
engine serves with ZERO recompiles (``serve_compiles_total`` pins it).
Metrics: queue depth gauge, batch-fill-ratio histogram, per-version /
per-strategy latency histograms, request/query counters, compile counter.

Hot swap: ``swap`` atomically repoints the registry route, then drains the
old version's queue and drops it — in-flight requests complete on the
version they resolved (DESIGN.md §14).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.predict import bucket_size
from repro.launch.registry import ModelRegistry, RegistryEntry
from repro.launch.serve_svm import serve_batch, serving_cache_size
from repro.obs.metrics import MetricsRegistry

GroupKey = Tuple[str, int, str]        # (name, version, strategy)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 256      # max query rows popped into one bucketed batch
    min_bucket: int = 8       # smallest pad bucket (predict.bucket_size lo)
    use_pallas: Optional[bool] = None

    @property
    def max_bucket(self) -> int:
        """Power-of-two ceiling of ``max_batch`` — the largest bucket the
        batch manager ever forms from merged requests (a single oversized
        request still buckets past it, in ``max_bucket`` multiples)."""
        return max(self.min_bucket, 1 << (int(self.max_batch) - 1).bit_length())


@dataclasses.dataclass
class _Request:
    rid: int
    X: jnp.ndarray            # (nq, d) query rows
    nq: int
    future: asyncio.Future    # resolves to (pred[nq], scores[nq, C])
    t_enq: float


class AsyncServingEngine:
    """Single-process async serving front end over a ``ModelRegistry``."""

    def __init__(self, registry: ModelRegistry,
                 config: EngineConfig = EngineConfig(),
                 metrics: Optional[MetricsRegistry] = None) -> None:
        self.registry = registry
        self.config = config
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._queues: Dict[GroupKey, Deque[_Request]] = {}
        self._event: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._closed = False
        self._rid = 0
        # compile accounting: everything below the mark is warmup
        self._cache_mark = serving_cache_size()
        m = self.metrics
        m.describe("serve_queue_depth", "query rows currently queued")
        m.describe("serve_batch_fill_ratio",
                   "real rows / bucket rows per served batch")
        m.describe("serve_latency_seconds",
                   "request latency, enqueue to future resolution")
        m.describe("serve_compiles_total",
                   "jit compiles observed after warmup (should stay 0)")

    # -- lifecycle -------------------------------------------------------
    async def start(self) -> "AsyncServingEngine":
        if self._task is not None:
            raise RuntimeError("engine already started")
        self._event = asyncio.Event()
        self._closed = False
        self._task = asyncio.get_running_loop().create_task(self._batch_loop())
        return self

    async def stop(self) -> None:
        """Drain every queue, then stop the batch manager."""
        if self._task is None:
            return
        await self.drain()
        self._closed = True
        self._event.set()
        await self._task
        self._task = None

    async def __aenter__(self) -> "AsyncServingEngine":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- request path ----------------------------------------------------
    async def submit(self, Xq, name: str = "default",
                     version: Optional[int] = None,
                     strategy: str = "early"):
        """Enqueue one request; await returns (pred, scores) for exactly
        the submitted rows.  Version resolution happens here, against the
        route table as of NOW — the hot-swap boundary."""
        if self._task is None or self._closed:
            raise RuntimeError("engine is not running (use `async with` "
                               "or await start())")
        entry = self.registry.resolve(name, version)
        man = entry.manifest
        if strategy not in man.strategies:
            raise ValueError(
                f"{name}:{man.version} does not serve {strategy!r} "
                f"(manifest allows {list(man.strategies)})")
        X = jnp.asarray(Xq, entry.sm.Xsv.dtype)
        if X.ndim == 1:
            X = X[None, :]
        self._rid += 1
        req = _Request(rid=self._rid, X=X, nq=int(X.shape[0]),
                       future=asyncio.get_running_loop().create_future(),
                       t_enq=time.perf_counter())
        key: GroupKey = (name, man.version, strategy)
        self._queues.setdefault(key, deque()).append(req)
        self.metrics.gauge("serve_queue_depth").set(self._depth())
        self._event.set()
        return await req.future

    # -- batch manager ---------------------------------------------------
    def _depth(self) -> int:
        return sum(r.nq for dq in self._queues.values() for r in dq)

    def _oldest_group(self) -> Optional[GroupKey]:
        live = [(dq[0].t_enq, k) for k, dq in self._queues.items() if dq]
        return min(live)[1] if live else None

    def _pop_ready(self, key: GroupKey) -> List[_Request]:
        """Continuous batching pop: drain the group's queue head until the
        next request would overflow ``max_batch`` rows (a single oversized
        request is served alone)."""
        dq = self._queues[key]
        reqs = [dq.popleft()]
        total = reqs[0].nq
        while dq and total + dq[0].nq <= self.config.max_batch:
            r = dq.popleft()
            reqs.append(r)
            total += r.nq
        return reqs

    async def _batch_loop(self) -> None:
        while True:
            key = self._oldest_group()
            if key is None:
                if self._closed:
                    return
                self._event.clear()
                await self._event.wait()
                continue
            reqs = self._pop_ready(key)
            try:
                self._serve_group(key, reqs)
            except Exception as e:                 # noqa: BLE001 — scatter
                for r in reqs:                     # failures to the callers
                    if not r.future.done():
                        r.future.set_exception(e)
            self.metrics.gauge("serve_queue_depth").set(self._depth())
            # yield so producers/consumers run between batches
            await asyncio.sleep(0)

    def _serve_group(self, key: GroupKey, reqs: Sequence[_Request]) -> None:
        name, version, strategy = key
        entry: RegistryEntry = self.registry.resolve(name, version)
        nq = sum(r.nq for r in reqs)
        bucket = bucket_size(nq, lo=self.config.min_bucket,
                             hi=self.config.max_bucket)
        X = reqs[0].X if len(reqs) == 1 else jnp.concatenate(
            [r.X for r in reqs])
        pred, scores = serve_batch(entry.sm, X, entry.kern, strategy,
                                   use_pallas=self.config.use_pallas,
                                   bucket=bucket)
        pred.block_until_ready()
        t_done = time.perf_counter()

        m = self.metrics
        ver = str(version)
        m.counter("serve_requests_total", model=name, version=ver,
                  strategy=strategy).inc(len(reqs))
        m.counter("serve_queries_total", model=name, version=ver,
                  strategy=strategy).inc(nq)
        m.histogram("serve_batch_fill_ratio").observe(nq / bucket)
        hist = m.histogram("serve_latency_seconds", model=name, version=ver,
                           strategy=strategy)
        cache = serving_cache_size()
        if cache > self._cache_mark:
            m.counter("serve_compiles_total").inc(cache - self._cache_mark)
            self._cache_mark = cache
        off = 0
        for r in reqs:
            if not r.future.done():                # (cancelled callers skip)
                r.future.set_result(
                    (pred[off: off + r.nq], scores[off: off + r.nq]))
            hist.observe(t_done - r.t_enq)
            off += r.nq

    # -- warmup ----------------------------------------------------------
    def warmup(self, name: Optional[str] = None,
               strategies: Optional[Sequence[str]] = None,
               buckets: Optional[Sequence[int]] = None) -> int:
        """Compile every (version, strategy, bucket) signature outside the
        request path, then mark the compile-counter baseline: any compile
        the engine observes afterwards increments ``serve_compiles_total``.
        Returns the number of executables compiled during warmup."""
        names = [name] if name is not None else self.registry.names()
        if buckets is None:
            b, buckets = self.config.min_bucket, []
            while b <= self.config.max_bucket:
                buckets.append(b)
                b *= 2
        before = serving_cache_size()
        for nm in names:
            for ver in self.registry.versions(nm):
                entry = self.registry.resolve(nm, ver)
                d = entry.sm.Xsv.shape[-1]
                strats = (strategies if strategies is not None
                          else entry.manifest.strategies)
                for strat in strats:
                    for b in buckets:
                        Xz = jnp.zeros((b, d), entry.sm.Xsv.dtype)
                        pred, _ = serve_batch(
                            entry.sm, Xz, entry.kern, strat,
                            use_pallas=self.config.use_pallas, bucket=b)
                        pred.block_until_ready()
        compiled = serving_cache_size() - before
        self.metrics.counter("serve_warmup_compiles_total").inc(compiled)
        self._cache_mark = serving_cache_size()
        return compiled

    # -- hot swap / drain ------------------------------------------------
    def _queued_matching(self, name: Optional[str],
                         version: Optional[int]) -> int:
        return sum(
            len(dq) for (nm, ver, _), dq in self._queues.items()
            if (name is None or nm == name)
            and (version is None or ver == version))

    async def drain(self, name: Optional[str] = None,
                    version: Optional[int] = None) -> None:
        """Wait until no queued request references (name, version);
        ``None`` matches everything (full drain)."""
        while self._queued_matching(name, version):
            self._event.set()
            await asyncio.sleep(0)

    async def swap(self, name: str, version: int,
                   drop_old: bool = True) -> Optional[int]:
        """Hot-swap ``name`` to ``version``: atomically repoint the route
        table (new submits resolve the new version immediately), then drain
        requests still queued on the old version and drop it.  Returns the
        previous default version."""
        old = self.registry.set_default(name, version)
        if drop_old and old is not None and old != version:
            await self.drain(name, old)
            self.registry.drop(name, old)
        return old

    # -- introspection ---------------------------------------------------
    def stats(self) -> Dict[str, object]:
        j = self.metrics.to_json()
        compiles = sum(v for k, v in j["counters"].items()
                       if k.startswith("serve_compiles_total"))
        return {
            "queue_depth": self._depth(),
            "requests": sum(v for k, v in j["counters"].items()
                            if k.startswith("serve_requests_total")),
            "queries": sum(v for k, v in j["counters"].items()
                           if k.startswith("serve_queries_total")),
            "compiles_after_warmup": int(compiles),
            "models": self.registry.to_json()["route"],
        }
