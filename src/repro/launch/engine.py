"""Asyncio serving engine: continuous batching over the versioned registry.

The ColossalAI async-inference shape grown around ``serve_batch``: an
asyncio front end wrapping a request->future map over a batch manager that
pops ready requests into pad-bucketed batches.

* ``submit`` resolves the request's (model, version) against the registry's
  route table ONCE at enqueue (so a hot swap repoints later requests while
  queued ones keep their resolved version), attaches an ``asyncio.Future``,
  and parks the request on its (name, version, strategy) group queue.
* The batch-manager task pops the group with the oldest waiting request,
  drains up to ``max_batch`` query rows from it (continuous batching: one
  slow group never blocks another; late arrivals ride the next pop),
  assembles the rows INTO A HOST buffer already padded to a power-of-two
  bucket (``predict.bucket_size``), and serves it through ``serve_batch``.
  Everything the jit cache keys on — batch shape AND the early strategy's
  static ``early_capacity`` — derives from the bucket, so ragged request
  sizes collapse onto O(log max_batch) compiled programs and the cache
  stays warm forever.  Assembly and result scatter are numpy, never traced
  ops: an eager ``jnp.concatenate``/slice per ragged shape would compile a
  tiny throwaway XLA executable for every distinct (sizes...) tuple — a
  hidden compile storm the ``serve_batch`` cache counter can't see that
  turned first-trace p50 from ~4ms into ~600ms under mixed sizes.
* Results scatter back per request id: each future resolves with exactly
  its own (pred, scores) rows, bit-identical to a direct ``serve_batch``
  call on the same rows (per-row scores are independent of batch-mates and
  padding).

Overload robustness (DESIGN.md §15's degradation ladder: admit → queue →
shed):

* **Admission control** — ``EngineConfig.max_queue_rows`` bounds the total
  queued query rows; a ``submit`` that would push past the bound fails
  fast with ``EngineOverloaded`` (the in-process 429) and increments
  ``serve_shed_total``.  Nothing is enqueued, so an overloaded engine's
  queue — and its admitted-request tail latency — stays bounded.
* **Per-request deadlines** — ``submit(..., timeout_s=)`` (or the engine
  default ``EngineConfig.timeout_s``) arms a deadline timer; a request
  whose deadline expires while QUEUED resolves with ``DeadlineExceeded``
  and is reaped in ``_pop_ready`` before batch formation, so dead rows
  never burn device time (``serve_deadline_exceeded_total``).  A request
  admitted into a batch has its timer cancelled: the deadline bounds queue
  wait, not device compute.  ``timeout_s<=0`` is pre-expired — it resolves
  immediately without ever enqueueing.  ``serve_queue_wait_seconds`` /
  ``serve_compute_seconds`` histograms separate wait from compute.
* **Event-loop liveness** — the blocking ``serve_batch``/
  ``block_until_ready()`` device sync runs in an executor thread, so
  submits, deadline timers, and drain wakeups keep firing DURING a batch.
* **Supervision** — batch-FORMATION errors (e.g. a popped group whose
  registry entry is gone: a swap/drain-protocol violation) kill the loop;
  the death is observed, not swallowed: queued futures are failed,
  drainers are woken, and ``submit``/``drain``/``stop`` re-raise the
  loop's exception instead of hanging.  Per-batch SERVE errors still
  scatter to just the affected callers.

``warmup`` pre-compiles every (version, strategy, bucket) signature outside
the request path and marks the compile-counter baseline; after that the
engine serves with ZERO recompiles (``serve_compiles_total`` pins it).

Hot swap: ``swap`` atomically repoints the registry route, then drains the
old version's queue and drops it — in-flight requests complete on the
version they resolved; queued requests whose deadline expires during the
drain are reaped, not served (DESIGN.md §14/§15).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.predict import bucket_size
from repro.launch.registry import ModelRegistry, RegistryEntry
from repro.launch.serve_svm import serve_batch, serving_cache_size
from repro.obs.metrics import MetricsRegistry

GroupKey = Tuple[str, int, str]        # (name, version, strategy)


class EngineOverloaded(RuntimeError):
    """Admission refused: the bounded queue is full (in-process 429)."""


class DeadlineExceeded(asyncio.TimeoutError):
    """The request's deadline expired before it reached a batch."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 256      # max query rows popped into one bucketed batch
    min_bucket: int = 8       # smallest pad bucket (predict.bucket_size lo)
    use_pallas: Optional[bool] = None
    max_queue_rows: Optional[int] = None   # admission bound on queued rows
    timeout_s: Optional[float] = None      # default per-request deadline

    @property
    def max_bucket(self) -> int:
        """Power-of-two ceiling of ``max_batch`` — the largest bucket the
        batch manager ever forms from merged requests (a single oversized
        request still buckets past it, in ``max_bucket`` multiples)."""
        return max(self.min_bucket, 1 << (int(self.max_batch) - 1).bit_length())


@dataclasses.dataclass
class _Request:
    rid: int
    X: jnp.ndarray            # (nq, d) query rows
    nq: int
    future: asyncio.Future    # resolves to (pred[nq], scores[nq, C])
    t_enq: float
    deadline: Optional[float] = None            # t_enq + timeout_s
    timer: Optional[asyncio.TimerHandle] = None
    t_pop: float = 0.0        # batch-formation time (set at pop)


class AsyncServingEngine:
    """Single-process async serving front end over a ``ModelRegistry``."""

    def __init__(self, registry: ModelRegistry,
                 config: EngineConfig = EngineConfig(),
                 metrics: Optional[MetricsRegistry] = None) -> None:
        self.registry = registry
        self.config = config
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._queues: Dict[GroupKey, Deque[_Request]] = {}
        self._inflight: Dict[GroupKey, int] = {}   # popped, not yet resolved
        self._event: Optional[asyncio.Event] = None    # work arrived
        self._served: Optional[asyncio.Event] = None   # queue progressed
        self._task: Optional[asyncio.Task] = None
        self._closed = False
        self._rid = 0
        # compile accounting: everything below the mark is warmup
        self._cache_mark = serving_cache_size()
        m = self.metrics
        m.describe("serve_queue_depth", "query rows currently queued")
        m.describe("serve_batch_fill_ratio",
                   "real rows / bucket rows per served batch")
        m.describe("serve_latency_seconds",
                   "request latency, enqueue to future resolution")
        m.describe("serve_queue_wait_seconds",
                   "delivered-request wait, enqueue to batch formation")
        m.describe("serve_compute_seconds",
                   "batch compute, formation to device sync")
        m.describe("serve_shed_total",
                   "requests refused at admission (queue full)")
        m.describe("serve_deadline_exceeded_total",
                   "requests expired before batch formation")
        m.describe("serve_compiles_total",
                   "jit compiles observed after warmup (should stay 0)")

    # -- lifecycle -------------------------------------------------------
    async def start(self) -> "AsyncServingEngine":
        if self._task is not None:
            raise RuntimeError("engine already started")
        self._event = asyncio.Event()
        self._served = asyncio.Event()
        self._closed = False
        self._task = asyncio.get_running_loop().create_task(self._batch_loop())
        self._task.add_done_callback(self._on_loop_done)
        return self

    async def stop(self) -> None:
        """Drain every queue, then stop the batch manager.  If the batch
        loop died, the drain (or the final await) re-raises its exception
        in bounded time instead of spinning on a queue that will never
        empty."""
        if self._task is None:
            return
        try:
            await self.drain()
        finally:
            self._closed = True
            self._event.set()
            task, self._task = self._task, None
            await task          # surfaces the loop's exception if it died

    async def __aenter__(self) -> "AsyncServingEngine":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- supervision -----------------------------------------------------
    def _raise_if_loop_dead(self) -> None:
        """Fail fast when the batch-loop task died with an exception —
        re-raise it from the caller (submit/drain/stop) instead of letting
        queues that will never drain hang the process."""
        t = self._task
        if t is not None and t.done() and not t.cancelled():
            exc = t.exception()
            if exc is not None:
                raise exc

    def _on_loop_done(self, task: asyncio.Task) -> None:
        """The batch loop is supervised: on death, fail every queued
        future (no caller awaits forever) and wake drainers so they
        observe the exception instead of sleeping on a dead queue."""
        exc = None if task.cancelled() else task.exception()
        if exc is not None:
            for dq in self._queues.values():
                while dq:
                    r = dq.popleft()
                    if r.timer is not None:
                        r.timer.cancel()
                    if not r.future.done():
                        r.future.set_exception(exc)
        if self._served is not None:
            self._served.set()

    # -- request path ----------------------------------------------------
    async def submit(self, Xq, name: str = "default",
                     version: Optional[int] = None,
                     strategy: str = "early",
                     timeout_s: Optional[float] = None):
        """Enqueue one request; await returns (pred, scores) for exactly
        the submitted rows.  Version resolution happens here, against the
        route table as of NOW — the hot-swap boundary.

        Raises ``EngineOverloaded`` when admission would push the queued
        rows past ``max_queue_rows``; resolves with ``DeadlineExceeded``
        when the deadline (``timeout_s`` or the engine default) expires
        before the request reaches a batch."""
        self._raise_if_loop_dead()
        if self._task is None or self._closed:
            raise RuntimeError("engine is not running (use `async with` "
                               "or await start())")
        entry = self.registry.resolve(name, version)
        man = entry.manifest
        if strategy not in man.strategies:
            raise ValueError(
                f"{name}:{man.version} does not serve {strategy!r} "
                f"(manifest allows {list(man.strategies)})")
        # requests are held HOST-side: queued rows cost no device memory,
        # and batch assembly stays numpy (no per-ragged-shape op compiles)
        X = np.asarray(Xq, dtype=entry.sm.Xsv.dtype)
        if X.ndim == 1:
            X = X[None, :]
        nq = int(X.shape[0])
        cap = self.config.max_queue_rows
        if cap is not None and self._depth() + nq > cap:
            self.metrics.counter("serve_shed_total", model=name).inc()
            raise EngineOverloaded(
                f"queue full: {self._depth()} queued rows + {nq} new > "
                f"max_queue_rows={cap}")
        loop = asyncio.get_running_loop()
        self._rid += 1
        tmo = timeout_s if timeout_s is not None else self.config.timeout_s
        req = _Request(rid=self._rid, X=X, nq=nq,
                       future=loop.create_future(),
                       t_enq=time.perf_counter(),
                       deadline=None)
        if tmo is not None:
            req.deadline = req.t_enq + tmo
            if tmo <= 0:               # pre-expired: never enqueue, never
                self._expire(req)      # burn a batch slot
                return await req.future
            req.timer = loop.call_later(tmo, self._expire, req)
        key: GroupKey = (name, man.version, strategy)
        self._queues.setdefault(key, deque()).append(req)
        self.metrics.gauge("serve_queue_depth").set(self._depth())
        self._event.set()
        return await req.future

    def _expire(self, req: _Request) -> None:
        """Deadline timer body: resolve the queued request with
        ``DeadlineExceeded`` and wake the loop so the dead row is reaped
        before the next batch forms.  Timers run on the event loop, which
        stays live during device compute (executor offload) — expiry fires
        on time even mid-batch."""
        req.timer = None
        if req.future.done():
            return
        req.future.set_exception(DeadlineExceeded(
            f"request {req.rid} ({req.nq} rows) expired after "
            f"{time.perf_counter() - req.t_enq:.4f}s in queue"))
        self.metrics.counter("serve_deadline_exceeded_total").inc()
        if self._event is not None:
            self._event.set()

    # -- batch manager ---------------------------------------------------
    def _depth(self) -> int:
        return sum(r.nq for dq in self._queues.values() for r in dq)

    def _oldest_group(self) -> Optional[GroupKey]:
        live = [(dq[0].t_enq, k) for k, dq in self._queues.items() if dq]
        return min(live)[1] if live else None

    def _pop_ready(self, key: GroupKey) -> List[_Request]:
        """Continuous batching pop: drain the group's queue head until the
        next request would overflow ``max_batch`` rows (a single oversized
        request is served alone).  Requests whose future is already done —
        caller-cancelled or deadline-expired — are REAPED here, before
        batch formation: they contribute no rows, no device time, and no
        latency observation.  A live request admitted into the batch has
        its deadline timer cancelled (the deadline bounds queue wait)."""
        dq = self._queues[key]
        reqs: List[_Request] = []
        total = 0
        t_pop = time.perf_counter()
        while dq:
            r = dq[0]
            if r.future.done():                    # reap dead rows
                dq.popleft()
                if r.timer is not None:
                    r.timer.cancel()
                    r.timer = None
                continue
            if reqs and total + r.nq > self.config.max_batch:
                break
            dq.popleft()
            if r.timer is not None:
                r.timer.cancel()
                r.timer = None
            r.t_pop = t_pop
            reqs.append(r)
            total += r.nq
        return reqs

    async def _batch_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            key = self._oldest_group()
            if key is None:
                if self._closed:
                    return
                self._event.clear()
                await self._event.wait()
                continue
            reqs = self._pop_ready(key)
            if not reqs:
                # the pop only reaped dead requests — that still progressed
                # the queue, so wake drainers before the next scan
                self.metrics.gauge("serve_queue_depth").set(self._depth())
                self._served.set()
                continue
            # batch-formation errors (a popped group whose entry vanished:
            # a swap/drain-protocol violation) are engine-fatal — they kill
            # the loop and surface through submit/drain/stop, never hang.
            # The popped requests are failed here; still-queued ones are
            # failed by the supervisor (_on_loop_done).
            try:
                entry: RegistryEntry = self.registry.resolve(key[0], key[1])
            except BaseException as e:
                for r in reqs:
                    if not r.future.done():
                        r.future.set_exception(e)
                raise
            try:
                await self._serve_group(loop, entry, key, reqs)
            except Exception as e:                 # noqa: BLE001 — scatter
                for r in reqs:                     # failures to the callers
                    if not r.future.done():
                        r.future.set_exception(e)
            self.metrics.gauge("serve_queue_depth").set(self._depth())
            self._served.set()

    async def _serve_group(self, loop: asyncio.AbstractEventLoop,
                           entry: RegistryEntry, key: GroupKey,
                           reqs: Sequence[_Request]) -> None:
        name, version, strategy = key
        nq = sum(r.nq for r in reqs)
        bucket = bucket_size(nq, lo=self.config.min_bucket,
                             hi=self.config.max_bucket)
        # one host alloc at exactly the bucket shape: serve_batch sees a
        # full-bucket batch (pad path untouched), so every eager op inside
        # it runs at a warmup-covered signature — no hidden compiles for
        # ragged sizes, on top of the jitted scorers' bucket signatures
        X = np.zeros((bucket, reqs[0].X.shape[1]), reqs[0].X.dtype)
        off = 0
        for r in reqs:
            X[off: off + r.nq] = r.X
            off += r.nq

        def compute():
            # one H2D transfer of the full bucket (jnp.asarray, not raw
            # numpy: the jit fast path keys numpy args separately, which
            # would double every warmed signature)
            pred, scores = serve_batch(entry.sm, jnp.asarray(X), entry.kern,
                                       strategy,
                                       use_pallas=self.config.use_pallas,
                                       bucket=bucket)
            # device->host once, in the executor thread (this is also the
            # device sync); scatter below is then pure numpy slicing
            return np.asarray(pred)[:nq], np.asarray(scores)[:nq]

        # the device sync runs OFF the event loop so submits, deadline
        # timers, and drain wakeups keep firing during the batch
        self._inflight[key] = self._inflight.get(key, 0) + len(reqs)
        try:
            pred, scores = await loop.run_in_executor(None, compute)
        finally:
            self._inflight[key] -= len(reqs)
            if not self._inflight[key]:
                del self._inflight[key]
        t_done = time.perf_counter()

        m = self.metrics
        ver = str(version)
        m.histogram("serve_batch_fill_ratio").observe(nq / bucket)
        m.histogram("serve_compute_seconds").observe(t_done - reqs[0].t_pop)
        hist = m.histogram("serve_latency_seconds", model=name, version=ver,
                           strategy=strategy)
        wait_h = m.histogram("serve_queue_wait_seconds", lo=1e-6)
        cache = serving_cache_size()
        if cache > self._cache_mark:
            m.counter("serve_compiles_total").inc(cache - self._cache_mark)
            self._cache_mark = cache
        # only DELIVERED requests are counted and observed: a request
        # cancelled mid-compute neither lands in the histograms (no p99
        # skew) nor in the request/query counters
        delivered = d_rows = 0
        off = 0
        for r in reqs:
            if not r.future.done():
                r.future.set_result(
                    (pred[off: off + r.nq], scores[off: off + r.nq]))
                hist.observe(t_done - r.t_enq)
                wait_h.observe(r.t_pop - r.t_enq)
                delivered += 1
                d_rows += r.nq
            off += r.nq
        if delivered:
            m.counter("serve_requests_total", model=name, version=ver,
                      strategy=strategy).inc(delivered)
            m.counter("serve_queries_total", model=name, version=ver,
                      strategy=strategy).inc(d_rows)

    # -- warmup ----------------------------------------------------------
    def warmup(self, name: Optional[str] = None,
               strategies: Optional[Sequence[str]] = None,
               buckets: Optional[Sequence[int]] = None) -> int:
        """Compile every (version, strategy, bucket) signature outside the
        request path, then mark the compile-counter baseline: any compile
        the engine observes afterwards increments ``serve_compiles_total``.
        Returns the number of executables compiled during warmup."""
        names = [name] if name is not None else self.registry.names()
        if buckets is None:
            b, buckets = self.config.min_bucket, []
            while b <= self.config.max_bucket:
                buckets.append(b)
                b *= 2
        before = serving_cache_size()
        for nm in names:
            for ver in self.registry.versions(nm):
                entry = self.registry.resolve(nm, ver)
                d = entry.sm.Xsv.shape[-1]
                strats = (strategies if strategies is not None
                          else entry.manifest.strategies)
                for strat in strats:
                    for b in buckets:
                        Xz = jnp.zeros((b, d), entry.sm.Xsv.dtype)
                        pred, _ = serve_batch(
                            entry.sm, Xz, entry.kern, strat,
                            use_pallas=self.config.use_pallas, bucket=b)
                        pred.block_until_ready()
        compiled = serving_cache_size() - before
        self.metrics.counter("serve_warmup_compiles_total").inc(compiled)
        self._cache_mark = serving_cache_size()
        return compiled

    # -- hot swap / drain ------------------------------------------------
    def _queued_matching(self, name: Optional[str],
                         version: Optional[int]) -> int:
        """Requests still owed work for (name, version): queued PLUS
        popped-but-in-flight (the executor offload means a batch can be on
        the device while its requests are off the queues)."""
        def match(nm: str, ver: int) -> bool:
            return ((name is None or nm == name)
                    and (version is None or ver == version))
        return (sum(len(dq) for (nm, ver, _), dq in self._queues.items()
                    if match(nm, ver))
                + sum(n for (nm, ver, _), n in self._inflight.items()
                      if match(nm, ver)))

    async def drain(self, name: Optional[str] = None,
                    version: Optional[int] = None) -> None:
        """Wait until no queued or in-flight request references
        (name, version); ``None`` matches everything (full drain).
        Event-driven: the batch loop sets ``_served`` after every batch
        (and after every reap), so a drain costs one wakeup per queue
        progression instead of a 100%-CPU ``sleep(0)`` spin.  Re-raises
        the batch loop's exception if it died — a dead loop means the
        queue will never empty."""
        while True:
            self._raise_if_loop_dead()
            if self._served is not None:
                self._served.clear()
            if not self._queued_matching(name, version):
                return
            if self._task is None:
                raise RuntimeError("engine is not running")
            self._event.set()
            await self._served.wait()

    async def swap(self, name: str, version: int,
                   drop_old: bool = True) -> Optional[int]:
        """Hot-swap ``name`` to ``version``: atomically repoint the route
        table (new submits resolve the new version immediately), then drain
        requests still queued on the old version and drop it.  Queued
        requests whose deadline expires during the drain are reaped, not
        served — the drain completes either way.  Returns the previous
        default version."""
        old = self.registry.set_default(name, version)
        if drop_old and old is not None and old != version:
            await self.drain(name, old)
            self.registry.drop(name, old)
        return old

    # -- introspection ---------------------------------------------------
    def stats(self) -> Dict[str, object]:
        j = self.metrics.to_json()

        def total(prefix: str) -> int:
            return int(sum(v for k, v in j["counters"].items()
                           if k.startswith(prefix)))

        return {
            "queue_depth": self._depth(),
            "requests": total("serve_requests_total"),
            "queries": total("serve_queries_total"),
            "shed": total("serve_shed_total"),
            "deadline_exceeded": total("serve_deadline_exceeded_total"),
            "compiles_after_warmup": total("serve_compiles_total"),
            "models": self.registry.to_json()["route"],
        }
