"""Production meshes and logical-axis rule tables.

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips; the "pod" axis composes as an outer data-parallel axis
whose collectives ride the DCN (gradient all-reduce only — weights and
optimizer state shard over the intra-pod axes, keeping the DCN quiet).

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before its first jax call).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import numpy as np
import jax
from jax.sharding import Mesh

try:  # AxisType landed after the jax pinned in some containers
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on the installed jax
    AxisType = None

MeshAxis = Union[None, str, Tuple[str, ...]]


def _auto_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _auto_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = jax.device_count()
    if model_axis < 1 or n % model_axis != 0:
        raise ValueError(
            f"model_axis={model_axis} must be a positive divisor of the "
            f"device count ({n}); force more host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return _auto_mesh((n // model_axis, model_axis), ("data", "model"))


def make_conquer_mesh(axis: str = "shard") -> Mesh:
    """Flat 1-axis mesh over every local device — the layout the distributed
    DC-SVM divide/conquer runs on (rows of the dual sharded over ``axis``)."""
    return jax.make_mesh((jax.device_count(),), (axis,))


def rules_for(mesh: Mesh) -> Dict[str, MeshAxis]:
    """Logical-axis -> mesh-axis table (see models/param.py).

    batch   -> all data-like axes (pod + data)
    embed   -> "data"  (2D weight sharding: the FSDP-like dim)
    heads/mlp/vocab/expert -> "model" (the TP/EP dim)
    layers  -> never sharded (scan axis)
    """
    has_pod = "pod" in mesh.axis_names
    return {
        "batch": ("pod", "data") if has_pod else ("data",),
        "embed": "data",
        "heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "layers": None,
        # KV caches shard their SEQUENCE dim over the model axis: works for
        # any kv-head count (GQA kv=8 and MQA kv=1 cannot shard 16-way), and
        # decode's softmax/weighted-sum reduce over the shards with tiny
        # per-token collectives instead of moving the cache (§Perf H8)
        "kv_seq": "model",
    }


def flat_axis_size(mesh: Mesh, axes: MeshAxis) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))
