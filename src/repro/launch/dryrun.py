import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell.

For each cell on each requested mesh:
    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(**abstract)
    compiled = lowered.compile()
    record memory_analysis / cost_analysis / collective schedule

The 512 placeholder host devices exist ONLY here (the env var above precedes
every jax import, including the transitive ones below).  Results land in
experiments/dryrun/<arch>__<shape>__<mesh>.json and feed EXPERIMENTS.md
§Dry-run and §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # every runnable cell
    python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import dataclasses
import json
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.roofline.analysis import (
    HW, measure_compiled, model_flops, probe_correct, summarize_cell,
)


def _probe_config(cfg, n_periods: int):
    """Depth-reduced, UNROLLED, single-microbatch variant for the
    cost-analysis probes (scan bodies are counted once by cost_analysis; a
    1-microbatch step does the same total arithmetic as the scanned one)."""
    from repro.models.lm import build_plan
    if cfg.enc_dec:
        return dataclasses.replace(cfg, n_layers=n_periods,
                                   enc_layers=n_periods, scan_layers=False,
                                   train_microbatches=1)
    plan = build_plan(cfg)
    n_layers = len(plan.prefix) + n_periods * len(plan.period)
    return dataclasses.replace(cfg, n_layers=n_layers, scan_layers=False,
                               train_microbatches=1)


def _trips(cfg) -> int:
    from repro.models.lm import build_plan
    if cfg.enc_dec:
        return cfg.n_layers            # enc and dec stacks scale together
    return build_plan(cfg).n_periods


def _probe_measure(cfg, mesh, shape, chunk, n_periods):
    pcfg = _probe_config(cfg, n_periods)
    build = build_cell(pcfg, mesh, shape, chunk=chunk)
    compiled = build.step_fn.lower(*build.abstract_args).compile()
    return measure_compiled(compiled)


def _cache_bytes(cfg, shape) -> float:
    from repro.models import model as M
    from repro.models.param import ParamDecl
    total = 0
    for d in jax.tree.leaves(M.cache_decls_any(cfg, shape.global_batch,
                                               shape.seq_len),
                             is_leaf=lambda x: isinstance(x, ParamDecl)):
        total += int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
    return float(total)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = OUT_DIR, chunk: int = 1024,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "family": cfg.family,
           "params_total": cfg.param_count(),
           "params_active": cfg.active_param_count()}
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = 512 if mesh_name == "multi" else 256
    t0 = time.perf_counter()
    try:
        build = build_cell(cfg, mesh, shape, chunk=chunk)
        lowered = build.step_fn.lower(*build.abstract_args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        # shallow unrolled probes correct the while-loop undercount
        corrected = None
        try:
            p1 = _probe_measure(cfg, mesh, shape, chunk, 1)
            p2 = _probe_measure(cfg, mesh, shape, chunk, 2)
            corrected = probe_correct(p1, p2, _trips(cfg))
        except Exception as e:
            rec["probe_error"] = f"{type(e).__name__}: {e}"

        hw = HW(chips=chips)
        param_bytes = cfg.param_count() * jnp.dtype(cfg.param_dtype).itemsize
        summary = summarize_cell(
            compiled, model_flops(cfg, shape), hw,
            corrected=corrected, kind=shape.kind,
            param_bytes=float(param_bytes),
            cache_bytes=_cache_bytes(cfg, shape) if shape.kind == "decode" else 0.0)
        rec.update(status="ok", lower_s=t_lower, compile_s=t_compile,
                   roofline=summary)
        mem = summary.get("memory_analysis", {})
        if verbose:
            print(f"[ok] {arch} x {shape_name} x {mesh_name}: "
                  f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
                  f"bottleneck={summary['bottleneck']} "
                  f"t_bound={summary['t_bound_s']*1e3:.2f}ms "
                  f"roofline_frac={summary['roofline_frac']:.3f} "
                  f"temp_bytes={mem.get('temp_size_in_bytes', '?')}",
                  flush=True)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[ERR] {arch} x {shape_name} x {mesh_name}: {e}",
                  flush=True)
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    results = []
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip-existing] {arch} x {shape_name} x {mesh_name}")
                        continue
                results.append(run_cell(arch, shape_name, mesh_name,
                                        out_dir=args.out, chunk=args.chunk))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"dry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
