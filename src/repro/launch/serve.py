"""Serving driver: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import RunShape, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_decode, build_prefill, make_ctx
from repro.models import model as M
from repro.models.param import ParamDecl, init_tree


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_host_mesh()
    S_max = args.prompt_len + args.gen
    shape = RunShape("serve", S_max, args.batch, "decode")
    bd = build_decode(cfg, mesh, shape)

    params = init_tree(M.build_decls_any(cfg), jax.random.PRNGKey(args.seed),
                       jnp.dtype(cfg.param_dtype))
    params = jax.device_put(params, bd.param_shardings)

    key = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    # prefill
    pshape = RunShape("serve_prefill", args.prompt_len, args.batch, "prefill")
    bp = build_prefill(cfg, mesh, pshape, chunk=min(1024, args.prompt_len))
    batch = {"tokens": prompts}
    if cfg.enc_dec:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.enc_frames, cfg.d_model))
    if cfg.num_patches > 0:
        batch["prefix_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model))
    t0 = time.perf_counter()
    logits, raw_cache = bp.step_fn(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # expand raw prefill cache into the S_max decode cache layout
    target = M.cache_decls_any(cfg, args.batch, S_max)

    def fit_cache(decl, arr):
        pads = [(0, t - s) for t, s in zip(decl.shape, arr.shape)]
        return jnp.pad(arr, pads).astype(decl.dtype)

    cache = jax.tree.map(fit_cache, target, raw_cache,
                         is_leaf=lambda x: isinstance(x, ParamDecl))
    cache = jax.device_put(cache, bd.cache_shardings)

    # decode loop
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        tok, cache = bd.step_fn(params, cache, tok,
                                jnp.asarray(args.prompt_len + i, jnp.int32))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len} tokens")
    print(f"decode: {t_decode*1e3:.1f} ms for {args.batch}x{args.gen-1} tokens "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for row in gen[:2]:
        print("  ", row[:16].tolist())


if __name__ == "__main__":
    main()
