"""Step builders: jitted train/prefill/decode programs with full shardings.

Everything the dry-run and the real launchers share lives here:
  * parameter/optimizer/cache shardings from the decl trees,
  * batch shardings (batch dim over the data-like mesh axes),
  * the train step (value_and_grad -> clip -> AdamW, optional microbatch
    gradient accumulation),
  * the serve steps (prefill -> cache, greedy decode step).

The lowered programs take ShapeDtypeStructs, so ``.lower()`` allocates
nothing — exactly what the 512-device dry-run needs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunShape
from repro.launch.mesh import rules_for
from repro.models import model as M
from repro.models.param import ParamDecl, abstract_tree, init_tree
from repro.models.sharding import MeshCtx, decl_shardings
from repro.optim.adamw import AdamWConfig, adamw_update, opt_state_decls
from repro.optim.schedule import cosine_schedule

Array = jax.Array
_is_decl = lambda x: isinstance(x, ParamDecl)


def make_ctx(mesh: Mesh) -> MeshCtx:
    return MeshCtx(mesh, rules_for(mesh))


# ---------------------------------------------------------------------------
# shardings / abstract values
# ---------------------------------------------------------------------------

def param_artifacts(cfg: ModelConfig, ctx: MeshCtx):
    decls = M.build_decls_any(cfg)
    return (decls,
            abstract_tree(decls, jnp.dtype(cfg.param_dtype)),
            decl_shardings(ctx, decls))


def opt_artifacts(cfg: ModelConfig, opt_cfg: AdamWConfig, ctx: MeshCtx, decls):
    odecls = opt_state_decls(opt_cfg, decls, jnp.dtype(cfg.param_dtype))
    return (odecls,
            abstract_tree(odecls, jnp.float32),
            decl_shardings(ctx, odecls))


def batch_shardings(ctx: MeshCtx, specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, sds in specs.items():
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        out[k] = ctx.sharding(sds.shape, axes)
    return out


def cache_artifacts(cfg: ModelConfig, ctx: MeshCtx, B: int, S: int):
    cdecls = M.cache_decls_any(cfg, B, S)
    return (cdecls,
            abstract_tree(cdecls, jnp.dtype(cfg.activ_dtype)),
            decl_shardings(ctx, cdecls))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainBuild:
    step_fn: Any                 # jitted train step
    abstract_args: Tuple         # (params, opt, batch) ShapeDtypeStructs
    param_shardings: Any
    opt_shardings: Any
    batch_shardings: Any
    decls: Any
    opt_decls: Any


def build_train(cfg: ModelConfig, mesh: Mesh, shape: RunShape,
                opt_cfg: Optional[AdamWConfig] = None,
                chunk: int = 1024,
                microbatches: int = 0,
                total_steps: int = 100_000) -> TrainBuild:
    opt_cfg = opt_cfg or AdamWConfig()
    if microbatches <= 0:
        microbatches = max(1, cfg.train_microbatches)
    # a microbatch must still cover every data-parallel device, or batch
    # sharding drops to replication (measured: jamba train on the multi-pod
    # mesh ballooned to 318 GiB/chip with 16 microbatches of 16 rows < 32
    # data devices) — clamp to global_batch / n_data
    from repro.launch.mesh import flat_axis_size
    n_data = flat_axis_size(mesh, rules_for(mesh).get("batch"))
    microbatches = min(microbatches, max(1, shape.global_batch // max(n_data, 1)))
    while shape.global_batch % microbatches != 0:
        microbatches -= 1
    ctx = make_ctx(mesh)
    decls, p_abs, p_shard = param_artifacts(cfg, ctx)
    odecls, o_abs, o_shard = opt_artifacts(cfg, opt_cfg, ctx, decls)
    specs = M.batch_specs(cfg, shape)
    b_shard = batch_shardings(ctx, specs)
    schedule = cosine_schedule(opt_cfg.lr, warmup=2000, total=total_steps)

    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch, ctx=ctx, chunk=chunk)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            # lax.scan accumulation: the scan FORCES microbatch sequencing,
            # which is what actually bounds the activation peak (measured:
            # qwen3 train 54.9 -> 14.7 GiB with mb=4; an unrolled python loop
            # lets the scheduler interleave microbatches and the peak stays
            # at 45 GiB).  The dry-run's cost probes run with microbatches=1
            # so per-step totals stay correctly counted (§Perf note).
            def split(x):
                Bm = x.shape[0] // microbatches
                return x.reshape(microbatches, Bm, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def one(acc, b):
                (l, met), g = jax.value_and_grad(loss_of, has_aux=True)(params, b)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / microbatches,
                    acc, g)
                return acc, met

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            grads, mets = jax.lax.scan(one, zeros, mb)
            metrics = jax.tree.map(lambda m: m.mean(), mets)
        else:
            (l, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch)
        lr = schedule(opt_state["step"])
        params, opt_state, opt_m = adamw_update(opt_cfg, grads, opt_state,
                                                params, lr)
        return params, opt_state, {**metrics, **opt_m}

    step = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1),
    )
    return TrainBuild(step, (p_abs, o_abs, specs), p_shard, o_shard, b_shard,
                      decls, odecls)


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeBuild:
    step_fn: Any
    abstract_args: Tuple
    param_shardings: Any
    cache_shardings: Any


def build_prefill(cfg: ModelConfig, mesh: Mesh, shape: RunShape,
                  chunk: int = 1024) -> ServeBuild:
    ctx = make_ctx(mesh)
    decls, p_abs, p_shard = param_artifacts(cfg, ctx)
    specs = M.batch_specs(cfg, shape)
    b_shard = batch_shardings(ctx, specs)
    B, S = shape.global_batch, shape.seq_len
    cdecls, c_abs, c_shard = cache_artifacts(cfg, ctx, B, S)

    def prefill_step(params, batch):
        logits, cache = M.forward_prefill(cfg, params, batch, S_max=S,
                                          ctx=ctx, chunk=chunk)
        # whisper prefill emits an S-sized cache already; LM emits raw states
        return logits, cache

    step = jax.jit(prefill_step,
                   in_shardings=(p_shard, b_shard),
                   out_shardings=None)
    return ServeBuild(step, (p_abs, specs), p_shard, c_shard)


def build_decode(cfg: ModelConfig, mesh: Mesh, shape: RunShape) -> ServeBuild:
    """One greedy decode step against a seq_len-deep cache."""
    ctx = make_ctx(mesh)
    decls, p_abs, p_shard = param_artifacts(cfg, ctx)
    B, S = shape.global_batch, shape.seq_len
    cdecls, c_abs, c_shard = cache_artifacts(cfg, ctx, B, S)
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shard = ctx.sharding((B, 1), ("batch", None))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())

    def serve_step(params, cache, tokens, pos):
        logits, cache = M.decode_step_any(cfg, params, cache, tokens, pos, ctx=ctx)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    step = jax.jit(
        serve_step,
        in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
        out_shardings=(tok_shard, c_shard),
        donate_argnums=(1,),
    )
    return ServeBuild(step, (p_abs, c_abs, tok_sds, pos_sds), p_shard, c_shard)


def build_cell(cfg: ModelConfig, mesh: Mesh, shape: RunShape, chunk: int = 1024):
    """The lowering entry point for one (arch x shape) cell."""
    if shape.kind == "train":
        return build_train(cfg, mesh, shape, chunk=chunk)
    if shape.kind == "prefill":
        return build_prefill(cfg, mesh, shape, chunk=chunk)
    return build_decode(cfg, mesh, shape)
