# The paper's Section-5 comparison set, reimplemented in JAX:
#   exact_cd   — LIBSVM analogue: whole-problem greedy CD + shrinking, zero init
#   cascade    — CascadeSVM [Graf et al., 2005]: random binary partition tree,
#                only SVs propagate upward
#   nystrom    — LLSVM [Zhang et al., 2008/Wang et al., 2011]: kmeans-Nystrom
#                low-rank feature map + linear SVM
#   rff        — FastFood/RFF analogue [Le et al., 2013]: random Fourier
#                features + linear SVM
#   ltpu       — Locally-Tuned Processing Units [Moody & Darken, 1989]
#   (BCM prediction lives in repro.core.predict — it is a prediction-time
#    combiner over the DC-SVM cluster models, as in the paper's Table 1)
from repro.baselines.exact_cd import ExactSVM, train_exact
from repro.baselines.cascade import CascadeSVM, train_cascade
from repro.baselines.nystrom import LLSVM, train_llsvm
from repro.baselines.rff import RFFSVM, train_rff
from repro.baselines.ltpu import LTPU, train_ltpu
