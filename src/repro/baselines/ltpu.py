"""LTPU: Locally-Tuned Processing Units [Moody & Darken, 1989].

An RBF network: kmeans centers as units, gaussian activations with the SVM's
gamma (as in the paper's setup), linear read-out weights by ridge regression
(the paper used LIBLINEAR; ridge on +-1 targets is the equivalent
least-squares read-out and keeps this baseline dependency-free).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, gram
from repro.baselines.nystrom import _plain_kmeans

Array = jax.Array


@dataclasses.dataclass
class LTPU:
    kernel: Kernel
    centers: Array
    w: Array
    train_time: float

    def decision(self, Xq: Array) -> Array:
        return gram(self.kernel, Xq, self.centers) @ self.w

    def predict(self, Xq: Array) -> Array:
        return jnp.sign(self.decision(Xq))


def train_ltpu(
    X: Array,
    y: Array,
    kernel: Kernel,
    num_units: int = 128,
    reg: float = 1e-3,
    seed: int = 0,
) -> LTPU:
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    t0 = time.perf_counter()
    centers = _plain_kmeans(X, num_units, jax.random.PRNGKey(seed))
    Phi = gram(kernel, X, centers)                      # (n, u)
    A = Phi.T @ Phi + reg * jnp.eye(num_units)
    w = jnp.linalg.solve(A, Phi.T @ y)
    w.block_until_ready()
    return LTPU(kernel, centers, w, time.perf_counter() - t0)
