"""Random Fourier features (FastFood analogue) [Rahimi-Recht; Le et al. 2013].

z(x) = sqrt(2/D) cos(W x + b),  W ~ N(0, 2*gamma I)  =>  E[z(x)'z(z)] = rbf.
(FastFood's Hadamard trick only changes the cost of forming Wx, not the
estimator; with offline-synthesized W the statistical behaviour is identical,
which is what the paper's accuracy comparison exercises.)
Linear SVM on z features via the same box-QP CD solver.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel
from repro.core import solver as S

Array = jax.Array


@dataclasses.dataclass
class RFFSVM:
    Wproj: Array
    bias: Array
    w: Array
    train_time: float

    def features(self, Xq: Array) -> Array:
        D = self.Wproj.shape[1]
        return jnp.sqrt(2.0 / D) * jnp.cos(Xq @ self.Wproj + self.bias)

    def decision(self, Xq: Array) -> Array:
        return self.features(Xq) @ self.w

    def predict(self, Xq: Array) -> Array:
        return jnp.sign(self.decision(Xq))


def train_rff(
    X: Array,
    y: Array,
    kernel: Kernel,
    C: float,
    num_features: int = 512,
    tol: float = 1e-3,
    max_iters: int = 200_000,
    seed: int = 0,
) -> RFFSVM:
    assert kernel.kind == "rbf", "RFF approximates shift-invariant kernels"
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    t0 = time.perf_counter()
    d = X.shape[1]
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    Wproj = jnp.sqrt(2.0 * kernel.gamma) * jax.random.normal(k1, (d, num_features))
    bias = jax.random.uniform(k2, (num_features,), maxval=2 * jnp.pi)
    feats = jnp.sqrt(2.0 / num_features) * jnp.cos(X @ Wproj + bias)
    Q = (y[:, None] * y[None, :]) * (feats @ feats.T)
    res = S.solve_box_qp_block(Q, C, tol=tol, max_iters=max_iters,
                               block=min(64, X.shape[0]))
    w = feats.T @ (res.alpha * y)
    w.block_until_ready()
    return RFFSVM(Wproj, bias, w, time.perf_counter() - t0)
