"""CascadeSVM [Graf et al., NIPS 2005].

Random (NOT kernel-kmeans) binary partition tree: split the data into 2^L
random chunks, train an SVM per chunk, pass only the support vectors of each
pair of siblings to the parent, retrain, repeat to the root.  The paper's
Figure 2 shows why DC-SVM beats this: (1) random partitions have large D(pi),
(2) a point discarded at a lower level can never come back (false negatives
are permanent), so cascade converges to an approximation unless iterated.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, gram
from repro.core import solver as S

Array = jax.Array


@dataclasses.dataclass
class CascadeSVM:
    kernel: Kernel
    C: float
    Xsv: Array
    ysv: Array
    alpha_sv: Array
    train_time: float
    sv_index: np.ndarray     # indices into the original training set

    def decision(self, Xq: Array) -> Array:
        w = self.alpha_sv * self.ysv
        return gram(self.kernel, Xq, self.Xsv) @ w

    def predict(self, Xq: Array) -> Array:
        return jnp.sign(self.decision(Xq))


def _solve_chunk(kernel: Kernel, C: float, X: Array, y: Array, tol: float,
                 max_iters: int) -> Array:
    K = gram(kernel, X, X)
    Q = (y[:, None] * y[None, :]) * K
    return S.solve_box_qp(Q, C, tol=tol, max_iters=max_iters).alpha


def train_cascade(
    X: Array,
    y: Array,
    kernel: Kernel,
    C: float,
    levels: int = 3,
    tol: float = 1e-3,
    max_iters: int = 100_000,
    seed: int = 0,
) -> CascadeSVM:
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    n = X.shape[0]
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    chunks: List[np.ndarray] = np.array_split(perm, 2 ** levels)

    # leaves: train each chunk, keep only its SVs
    surviving: List[np.ndarray] = []
    for idx in chunks:
        idx_j = jnp.asarray(idx)
        a = _solve_chunk(kernel, C, X[idx_j], y[idx_j], tol, max_iters)
        surviving.append(idx[np.asarray(a) > 0])

    # cascade: merge sibling SV sets, retrain, keep SVs
    while len(surviving) > 1:
        merged = []
        for i in range(0, len(surviving), 2):
            idx = np.concatenate(surviving[i : i + 2])
            idx_j = jnp.asarray(idx)
            a = _solve_chunk(kernel, C, X[idx_j], y[idx_j], tol, max_iters)
            merged.append(idx[np.asarray(a) > 0])
        surviving = merged

    final_idx = surviving[0]
    idx_j = jnp.asarray(final_idx)
    a = _solve_chunk(kernel, C, X[idx_j], y[idx_j], tol, max_iters)
    keep = np.asarray(a) > 0
    return CascadeSVM(kernel, C, X[idx_j][jnp.asarray(keep)],
                      y[idx_j][jnp.asarray(keep)], a[jnp.asarray(keep)],
                      time.perf_counter() - t0, final_idx[keep])
