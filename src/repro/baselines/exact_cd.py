"""LIBSVM analogue: exact whole-problem solver, zero-initialized.

Greedy coordinate descent with shrinking on the full dual — the same solver
family LIBSVM uses (working-set selection by maximal violation), adapted to
the bias-free dual (working set of size 1 suffices).  This is the paper's
primary exact baseline: DC-SVM's claim is that warm-starting THIS solver from
the divide step's concatenated solution slashes its iteration count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, gram
from repro.core import solver as S

Array = jax.Array


@dataclasses.dataclass
class ExactSVM:
    kernel: Kernel
    C: float
    X: Array
    y: Array
    alpha: Array
    iters: int
    pg_max: float
    train_time: float

    def decision(self, Xq: Array, chunk: int = 4096) -> Array:
        w = self.alpha * self.y
        out = jnp.zeros(Xq.shape[0], Xq.dtype)
        n = self.X.shape[0]
        for s in range(0, n, chunk):
            e = min(s + chunk, n)
            out = out + gram(self.kernel, Xq, self.X[s:e]) @ w[s:e]
        return out

    def predict(self, Xq: Array) -> Array:
        return jnp.sign(self.decision(Xq))


def train_exact(
    X: Array,
    y: Array,
    kernel: Kernel,
    C: float,
    tol: float = 1e-3,
    max_iters: int = 300_000,
    shrink_rounds: int = 3,
    block: int = 0,
    alpha0: Optional[Array] = None,
    full_gram_threshold: int = 16384,
) -> ExactSVM:
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    t0 = time.perf_counter()
    n = X.shape[0]
    if n <= full_gram_threshold:
        K = gram(kernel, X, X)
        Q = (y[:, None] * y[None, :]) * K
        res = S.solve_with_shrinking(Q, C, alpha0=alpha0, tol=tol,
                                     max_iters=max_iters, rounds=shrink_rounds,
                                     block=block)
    else:
        res = S.solve_box_qp_matvec(X, y, kernel, C, alpha0=alpha0, tol=tol,
                                    max_iters=max_iters,
                                    block=max(block, 64))
    res.alpha.block_until_ready()
    return ExactSVM(kernel, C, X, y, res.alpha, int(res.iters),
                    float(res.pg_max), time.perf_counter() - t0)
