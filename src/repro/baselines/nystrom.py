"""LLSVM: kmeans-Nystrom low-rank linearization [Zhang et al.; Wang et al. 2011].

Approximate K ~= K_nb K_bb^-1 K_bn with b landmark points chosen by kmeans,
map every point to phi(x) = K_bb^{-1/2} k_b(x)  (rank-b feature space), and
train a LINEAR SVM there with the same box-QP CD solver.  An *approximate*
solver in the paper's taxonomy: fast, but accuracy saturates with b.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, gram
from repro.core import solver as S

Array = jax.Array


def _plain_kmeans(X: Array, b: int, key: Array, iters: int = 15) -> Array:
    """Standard (input-space) kmeans for landmark selection."""
    n = X.shape[0]
    idx = jax.random.choice(key, n, shape=(b,), replace=False)
    centers = X[idx]

    def body(_, centers):
        d = jnp.sum((X[:, None, :] - centers[None, :, :]) ** 2, -1)
        a = jnp.argmin(d, 1)
        H = jax.nn.one_hot(a, b, dtype=X.dtype)
        cnt = jnp.maximum(H.sum(0), 1.0)
        return (H.T @ X) / cnt[:, None]

    return jax.lax.fori_loop(0, iters, body, centers)


@dataclasses.dataclass
class LLSVM:
    kernel: Kernel
    C: float
    landmarks: Array          # (b, d)
    whiten: Array             # (b, b) = K_bb^{-1/2}
    w: Array                  # (b,) linear weights in feature space
    train_time: float

    def features(self, Xq: Array) -> Array:
        return gram(self.kernel, Xq, self.landmarks) @ self.whiten

    def decision(self, Xq: Array) -> Array:
        return self.features(Xq) @ self.w

    def predict(self, Xq: Array) -> Array:
        return jnp.sign(self.decision(Xq))


def train_llsvm(
    X: Array,
    y: Array,
    kernel: Kernel,
    C: float,
    num_landmarks: int = 128,
    tol: float = 1e-3,
    max_iters: int = 200_000,
    reg: float = 1e-6,
    seed: int = 0,
) -> LLSVM:
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    t0 = time.perf_counter()
    landmarks = _plain_kmeans(X, num_landmarks, jax.random.PRNGKey(seed))
    Kbb = gram(kernel, landmarks, landmarks)
    evals, evecs = jnp.linalg.eigh(Kbb + reg * jnp.eye(num_landmarks))
    whiten = evecs @ jnp.diag(jax.lax.rsqrt(jnp.maximum(evals, reg))) @ evecs.T
    feats = gram(kernel, X, landmarks) @ whiten          # (n, b)
    # linear SVM dual: Q = (y y') (F F'); solve with the same CD machinery,
    # exploiting the low rank via the matvec Q a = y * (F (F' (y a)))
    Q = (y[:, None] * y[None, :]) * (feats @ feats.T)
    res = S.solve_box_qp_block(Q, C, tol=tol, max_iters=max_iters,
                               block=min(64, X.shape[0]))
    w = feats.T @ (res.alpha * y)
    w.block_until_ready()
    return LLSVM(kernel, C, landmarks, whiten, w, time.perf_counter() - t0)
