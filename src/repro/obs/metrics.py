"""Serving metrics: labeled counters + streaming log-bucket latency
histograms, with Prometheus text-format and JSON exposition.

The histogram uses FIXED log-spaced bucket bounds (10 us .. 10 s, four
buckets per decade) so observation is O(log nbuckets) bisect with no
rebalancing and no per-request allocation — the serving loop can call
``observe`` at line rate.  Quantiles are estimated by linear interpolation
inside the covering bucket, the standard Prometheus-side approximation.
"""
from __future__ import annotations

import bisect
import json
import math
import os
from typing import Any, Dict, List, Tuple


def _log_bounds(lo: float, hi: float, per_decade: int) -> List[float]:
    n = int(math.ceil(per_decade * math.log10(hi / lo))) + 1
    return [lo * 10.0 ** (i / per_decade) for i in range(n)]


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written instantaneous value (queue depth, in-flight requests)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n


class LatencyHistogram:
    """Streaming histogram over fixed log-spaced bucket upper bounds."""

    def __init__(self, lo: float = 1e-5, hi: float = 10.0,
                 per_decade: int = 4) -> None:
        self.bounds = _log_bounds(lo, hi, per_decade)  # upper bound per bucket
        self.counts = [0] * (len(self.bounds) + 1)     # last = +Inf overflow
        self.total = 0
        self.sum = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def quantile(self, q: float) -> float:
        """Approximate q-quantile via interpolation inside the hit bucket."""
        if self.total == 0:
            return math.nan
        rank = q * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else max(self.vmin, 0.0)
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax) if self.vmax >= lo else hi
                frac = (rank - seen) / c
                return lo + frac * (hi - lo)
            seen += c
        return self.vmax

    def to_json(self) -> Dict[str, Any]:
        return {
            "count": self.total,
            "sum": self.sum,
            "min": None if self.total == 0 else self.vmin,
            "max": None if self.total == 0 else self.vmax,
            "p50": None if self.total == 0 else self.quantile(0.50),
            "p95": None if self.total == 0 else self.quantile(0.95),
            "p99": None if self.total == 0 else self.quantile(0.99),
            "buckets": {  # only occupied buckets, keyed by upper bound
                ("+Inf" if i == len(self.bounds) else f"{self.bounds[i]:.6g}"): c
                for i, c in enumerate(self.counts) if c
            },
        }


def _key(name: str, labels: Dict[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create registry of labeled counters and histograms."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, LatencyHistogram] = {}
        self._hist_meta: Dict[str, Tuple[str, Dict[str, str]]] = {}
        self._help: Dict[str, str] = {}

    def counter(self, name: str, **labels: str) -> Counter:
        return self._counters.setdefault(_key(name, labels), Counter())

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._gauges.setdefault(_key(name, labels), Gauge())

    def histogram(self, name: str, lo: float = 1e-5, hi: float = 10.0,
                  per_decade: int = 4, **labels: str) -> LatencyHistogram:
        """Get-or-create; the bucket layout (``lo``/``hi``/``per_decade``)
        only applies on first creation — later calls return the existing
        series unchanged, so every label of one metric shares one layout."""
        key = _key(name, labels)
        if key not in self._hists:
            self._hists[key] = LatencyHistogram(lo=lo, hi=hi,
                                                per_decade=per_decade)
            self._hist_meta[key] = (name, labels)
        return self._hists[key]

    def describe(self, name: str, text: str) -> None:
        """Attach a ``# HELP`` line to a metric base name."""
        self._help[name] = text

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "histograms": {k: h.to_json() for k, h in sorted(self._hists.items())},
        }
        if self._gauges:
            out["gauges"] = {k: g.value for k, g in sorted(self._gauges.items())}
        return out

    def _header(self, lines: List[str], seen: set, base: str,
                kind: str) -> None:
        """HELP + TYPE lines, once per (base name, kind).  The seen set is
        PER KIND: a counter and a histogram sharing a base name must both
        get their TYPE line (one shared set suppressed the second kind's)."""
        if base in seen:
            return
        seen.add(base)
        lines.append(f"# HELP {base} {self._help.get(base, base)}")
        lines.append(f"# TYPE {base} {kind}")

    def to_prometheus_text(self) -> str:
        lines: List[str] = []
        seen_counters: set = set()
        seen_gauges: set = set()
        seen_hists: set = set()
        for key, c in sorted(self._counters.items()):
            self._header(lines, seen_counters, key.split("{", 1)[0], "counter")
            lines.append(f"{key} {c.value}")
        for key, g in sorted(self._gauges.items()):
            self._header(lines, seen_gauges, key.split("{", 1)[0], "gauge")
            lines.append(f"{key} {g.value:g}")
        for key, h in sorted(self._hists.items()):
            name, labels = self._hist_meta[key]
            self._header(lines, seen_hists, name, "histogram")
            cum = 0
            for i, cnt in enumerate(h.counts):
                cum += cnt
                le = "+Inf" if i == len(h.bounds) else f"{h.bounds[i]:.6g}"
                lines.append(
                    f"{_key(name + '_bucket', {**labels, 'le': le})} {cum}")
            lines.append(f"{_key(name + '_sum', labels)} {h.sum:.9g}")
            lines.append(f"{_key(name + '_count', labels)} {h.total}")
        # an empty registry exposes nothing, not a bare newline
        return "\n".join(lines) + "\n" if lines else ""

    def dump(self, json_path: str) -> str:
        """Write JSON to ``json_path`` and Prometheus text next to it
        (same stem, ``.prom`` extension).  Returns the prom path."""
        with open(json_path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        prom_path = os.path.splitext(json_path)[0] + ".prom"
        with open(prom_path, "w") as f:
            f.write(self.to_prometheus_text())
        return prom_path
