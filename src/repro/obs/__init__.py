"""Observability layer: device-resident convergence traces, span-tree
wall-clock tracing with profiler hooks, and serving metrics exposition.

Three cooperating pieces (DESIGN.md §13):

- ``obs.trace``   — ``ConvTrace``, a jit-safe ring buffer pytree that solver
  while-loops write per-iteration samples into; fetched once at fit exit.
- ``obs.spans``   — ``span(name)`` context manager building a wall-clock span
  tree over fit phases, mirrored into ``jax.profiler.TraceAnnotation`` so
  XLA/Perfetto profiles carry the same names; exports Chrome trace JSON.
- ``obs.metrics`` — streaming log-bucket latency histograms + labeled
  counters with Prometheus-text and JSON exposition for the serving loop.
"""
from repro.obs.trace import (  # noqa: F401
    TRACE_COLS,
    ConvTrace,
    trace_init,
    trace_record,
    trace_fetch,
    trace_summary,
)
from repro.obs.spans import SpanTracer, span  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    LatencyHistogram,
    MetricsRegistry,
)
