"""Device-resident convergence traces.

``ConvTrace`` is a preallocated ring buffer that lives on device as an
ordinary pytree leaf pair, so solver while-loops can record one sample per
outer iteration with a single ``dynamic_update_slice`` — no host sync, no
growing shapes, vmap/shard_map safe.  The buffer is fetched to host ONCE at
the end of a fit alongside the existing cache/spill counters (the same
discipline the transfer_guard tests pin for those counters).

Columns are fixed (``TRACE_COLS``); a recorder fills the columns it knows
and leaves the rest NaN, so one layout serves every solver family:

- box CD loops:   pg_max, objective, n_free        (+ cache_hits delta)
- equality loops: pg_max (max violation), objective, n_free
- CE-PBM conquer: pg_max, objective, n_free, gamma (combination step γ*)

Capacity is static.  When a solve runs longer than ``cap`` iterations the
ring keeps the LAST ``cap`` samples and ``trace_fetch`` reports how many
leading samples were dropped — the tail is where convergence curves live.

Gating is by Python ``None`` (static), the same pattern as
``compute_dtype=None``: with ``trace=None`` every solver builds exactly the
pre-trace jaxpr, so default trajectories stay bit-identical.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np
from jax import lax

Array = Any

# Fixed column layout of the ring buffer (order matters — recorded rows and
# fetch unpack by position).
TRACE_COLS = ("pg_max", "objective", "n_free", "gamma", "cache_hits")
NCOLS = len(TRACE_COLS)


class ConvTrace(NamedTuple):
    """Ring buffer of per-iteration convergence samples (device resident)."""

    buf: Array    # (cap, NCOLS) f32, NaN where a column was not recorded
    count: Array  # ()           i32, total samples ever recorded


def trace_init(capacity: int) -> ConvTrace:
    """Fresh trace with room for ``capacity`` samples."""
    if capacity <= 0:
        raise ValueError(f"trace capacity must be positive, got {capacity}")
    return ConvTrace(
        buf=jnp.full((int(capacity), NCOLS), jnp.nan, jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def trace_record(
    tr: ConvTrace,
    pg_max: Optional[Array] = None,
    objective: Optional[Array] = None,
    n_free: Optional[Array] = None,
    gamma: Optional[Array] = None,
    cache_hits: Optional[Array] = None,
) -> ConvTrace:
    """Append one sample row (jit-safe; wraps around past capacity).

    ``None`` columns (a *static* choice per call site) are stored as NaN.
    """
    cap = tr.buf.shape[0]
    vals = (pg_max, objective, n_free, gamma, cache_hits)
    row = jnp.stack(
        [jnp.float32(jnp.nan) if v is None else jnp.asarray(v, jnp.float32)
         for v in vals]
    )
    pos = lax.rem(tr.count, jnp.int32(cap))
    buf = lax.dynamic_update_slice(tr.buf, row[None, :], (pos, jnp.int32(0)))
    return ConvTrace(buf=buf, count=tr.count + 1)


def _fetch_one(buf: np.ndarray, count: int) -> Dict[str, Any]:
    cap = buf.shape[0]
    kept = min(count, cap)
    if count <= cap:
        window = buf[:kept]
    else:  # ring wrapped: oldest surviving sample sits at count % cap
        start = count % cap
        window = np.concatenate([buf[start:], buf[:start]], axis=0)
    out: Dict[str, Any] = {
        "samples": int(kept),
        "dropped": int(count - kept),
    }
    for j, name in enumerate(TRACE_COLS):
        col = window[:, j]
        if kept and not np.all(np.isnan(col)):
            out[name] = [float(v) for v in col]
    return out


def trace_fetch(tr: ConvTrace) -> Any:
    """Host fetch (the ONE device->host sync), chronological order.

    Returns a dict with ``samples``/``dropped`` plus one list per column
    that was ever recorded (all-NaN columns are omitted).  A trace with
    leading batch dims (e.g. vmapped per-class solves) returns a nested
    list of dicts mirroring the batch shape.
    """
    buf = np.asarray(tr.buf)
    count = np.asarray(tr.count)
    if count.ndim == 0:
        return _fetch_one(buf, int(count))
    return [trace_fetch(ConvTrace(b, c)) for b, c in zip(buf, count)]


def trace_summary(fetched: Any) -> Dict[str, Any]:
    """Compact scalar summary of a fetched trace (batched: merged over all).

    Used for stats dumps where the full curve would be noise: sample and
    drop totals plus first/last pg_max and objective.  A raw (unfetched)
    ``ConvTrace`` is accepted too and fetched first.
    """
    if isinstance(fetched, ConvTrace):
        fetched = trace_fetch(fetched)
    if isinstance(fetched, list):
        flat = [trace_summary(f) for f in fetched]
        out: Dict[str, Any] = {
            "samples": sum(f["samples"] for f in flat),
            "dropped": sum(f["dropped"] for f in flat),
        }
        pgs = [f for f in flat if "pg_first" in f]
        if pgs:
            out["pg_first"] = max(f["pg_first"] for f in pgs)
            out["pg_last"] = max(f["pg_last"] for f in pgs)
        return out
    out = {"samples": fetched["samples"], "dropped": fetched["dropped"]}
    pg = fetched.get("pg_max")
    if pg:
        out["pg_first"] = pg[0]
        out["pg_last"] = pg[-1]
    obj = fetched.get("objective")
    if obj:
        out["obj_last"] = obj[-1]
    return out
