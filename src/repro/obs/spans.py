"""Span-tree wall-clock tracing with jax.profiler hooks.

``span("divide/level0/solve")`` wraps a fit phase.  Every span enters a
``jax.profiler.TraceAnnotation`` with the same name, so when the user runs
the XLA profiler the device timeline carries the identical labels as our
host-side tree — that naming contract is the whole point (DESIGN.md §13).

Host-side recording only happens while a ``SpanTracer`` is activated
(``with tracer.activate(): fit(...)``); otherwise ``span`` costs one
TraceAnnotation enter/exit, which is a no-op when no profiler session is
running.  The tracer exports Chrome trace-event JSON (complete ``X``
events, microsecond timestamps — loadable in Perfetto / chrome://tracing)
and an aggregated text summary table.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

import jax

# Module-global active tracer; spans record into it when set.  Single
# host thread drives fits here, so a plain global (not a contextvar) is
# enough and keeps the hot path one attribute load.
_ACTIVE: Optional["SpanTracer"] = None


@dataclass
class Span:
    name: str
    t0: float
    t1: Optional[float] = None
    children: List["Span"] = field(default_factory=list)

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else time.perf_counter()) - self.t0


class SpanTracer:
    """Collects a tree of wall-clock spans for one fit/serve run."""

    def __init__(self) -> None:
        self.origin = time.perf_counter()
        self.roots: List[Span] = []
        self._stack: List[Span] = []

    @contextmanager
    def span(self, name: str) -> Iterator[Span]:
        s = Span(name=name, t0=time.perf_counter())
        (self._stack[-1].children if self._stack else self.roots).append(s)
        self._stack.append(s)
        try:
            yield s
        finally:
            s.t1 = time.perf_counter()
            self._stack.pop()

    @contextmanager
    def activate(self) -> Iterator["SpanTracer"]:
        global _ACTIVE
        prev = _ACTIVE
        _ACTIVE = self
        try:
            yield self
        finally:
            _ACTIVE = prev

    # -- exports ---------------------------------------------------------
    def _walk(self):
        stack = [(s, 0) for s in reversed(self.roots)]
        while stack:
            s, depth = stack.pop()
            yield s, depth
            stack.extend((c, depth + 1) for c in reversed(s.children))

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON: complete ``X`` events, ts/dur in µs."""
        events = []
        for s, _ in self._walk():
            events.append({
                "name": s.name,
                "ph": "X",
                "ts": (s.t0 - self.origin) * 1e6,
                "dur": max(s.duration, 0.0) * 1e6,
                "pid": 0,
                "tid": 0,
            })
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def summary(self) -> str:
        """Aggregated text table: per-name count, total and self seconds."""
        agg: Dict[str, List[float]] = {}
        for s, _ in self._walk():
            child_total = sum(c.duration for c in s.children)
            tot, own, cnt = agg.get(s.name, (0.0, 0.0, 0))
            agg[s.name] = [tot + s.duration,
                           own + max(s.duration - child_total, 0.0),
                           cnt + 1]
        rows = sorted(agg.items(), key=lambda kv: -kv[1][0])
        w = max([len("span")] + [len(k) for k in agg])
        lines = [f"{'span':<{w}}  {'count':>5}  {'total_s':>9}  {'self_s':>9}",
                 f"{'-' * w}  {'-' * 5}  {'-' * 9}  {'-' * 9}"]
        for name, (tot, own, cnt) in rows:
            lines.append(f"{name:<{w}}  {cnt:>5}  {tot:>9.4f}  {own:>9.4f}")
        return "\n".join(lines)


@contextmanager
def span(name: str) -> Iterator[None]:
    """Name a fit phase: host span tree (when a tracer is active) + device
    profiler annotation (always — free unless a profiler session runs)."""
    tracer = _ACTIVE
    with jax.profiler.TraceAnnotation(name):
        if tracer is None:
            yield
        else:
            with tracer.span(name):
                yield
