"""DC-SVM: multilevel divide-and-conquer kernel machines (paper Algorithm 1).

The driver is parameterized by a ``repro.core.tasks.Task`` reducing the
workload (C-SVC, weighted C-SVC, epsilon-SVR) to one generalized dual
``min 1/2 u'Qu + p'u, 0 <= u <= c`` with ``Q = (s s') ∘ K`` — clustering
stays label-free on the base points and is expanded to the task's dual
coordinates, so one partition serves every task (DESIGN.md §7).

Level l (= levels .. 1): partition all n points into k^l balanced clusters by
two-step kernel kmeans (sampling from the lower level's support vectors when
``adaptive`` — Theorem 3), then solve the k^l independent sub-QPs warm-started
from the lower level's alpha.  All clusters of one level are solved in a
single vmapped CD call (or a lax.map sweep when the per-level Gram budget is
exceeded).

Level 0: optional refine pass on the level-1 support vectors, then the full
problem — warm-started greedy CD (Theorem 1 says the warm start is within
C^2 D(pi)/sigma_n of alpha*, so few iterations are needed; Theorem 2 says the
SV pattern is largely correct already, so the greedy selection rarely touches
non-SVs).

``early_stop_level = l`` stops after level l and returns an early-prediction
model (paper eq. 11): route a query to its nearest cluster, score with that
cluster's local model only.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kernels import (DEFAULT_GRAM_BUDGET, Kernel, gram,
                                gram_matvec, resolve_use_pallas)
from repro.core.kkmeans import Partition, two_step_kernel_kmeans
from repro.core import gramop
from repro.core import solver as S
from repro.core.tasks import CSVC, Task, TaskDual, resolve_task
from repro.obs.spans import span
from repro.obs.trace import trace_fetch, trace_init, trace_summary

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DCSVMConfig:
    kernel: Kernel = Kernel("rbf", gamma=1.0)
    C: float = 1.0
    k: int = 4                     # branching factor (paper: 4)
    levels: int = 4                # l_max (paper: 4 => 256 bottom clusters)
    m: int = 1000                  # kmeans sample size (paper: 1000)
    kmeans_iters: int = 20
    tol: float = 1e-3              # projected-gradient stopping tolerance
    max_iters: int = 30_000        # per-(sub)problem CD iteration cap
    block: int = 0                 # 0 = paper-faithful 1-coordinate CD; >0 = block CD
    sweeps: int = 4                # inner sweeps for block CD
    eq_block_size: int = 1         # equality-family rank-2B block: B maximal-
                                   # violating pairs per outer iteration
                                   # (solve_eq_qp_block / blocked matvec);
                                   # <= 1 falls back to the rank-2 pairwise
                                   # engine (solve_eq_qp)
    adaptive: bool = True          # sample kmeans points from lower-level SVs
    refine: bool = True            # refine pass on level-1 SVs before final solve
    balanced: bool = True
    use_pallas: Optional[bool] = None  # None = auto (Pallas on TPU, XLA elsewhere)
    early_stop_level: int = 0      # 0 = exact solve; l >= 1 = stop after level l
    gram_budget: int = DEFAULT_GRAM_BUDGET  # BYTE budget for a level's stacked
                                   # cluster Grams / caches / spill panels
                                   # (2**29 B == the historical 2**27 f32 slots,
                                   # so default residency decisions are
                                   # unchanged)
    compute_dtype: Optional[str] = None  # Gram matmul-operand precision, e.g.
                                   # "bfloat16" (f32 accumulation, flash-
                                   # attention idiom).  None = the f32 default:
                                   # bit-identical to the pre-policy paths
    host_spill: bool = False       # level 0 out-of-core: kernel-row panels
                                   # spilled to host RAM, device LRU +
                                   # double-buffered prefetch (core.gramop)
    gram_dedup: bool = True        # base-indexed Gram view for tasks with
                                   # duplicated dual rows (SVR): kernel rows
                                   # computed/cached on the n base points,
                                   # signs expanded exactly at read (~4x fewer
                                   # cluster kernel evals, 2x cache rows)
    full_gram_threshold: int = 16384   # above this, level 0 uses the matvec solver
    col_cache_cap: int = 0         # kernel-column LRU slots for the matvec solver.
                                   # 0 (default) = fully fused recompute path; opt
                                   # in by sizing it >= the expected active set
                                   # (~#SV) — block serving is all-or-nothing, so
                                   # an undersized cache pays its (cap, n) memory
                                   # for ~zero hits (DESIGN.md §2)
    shrink_rounds: int = 3
    seed: int = 0
    trace: Optional[int] = None    # convergence-trace ring capacity for the
                                   # level-0 solve: keep the LAST ``trace``
                                   # per-iteration samples (pg_max, objective,
                                   # n_free, cache hits) in a device-resident
                                   # ring, fetched ONCE at fit exit into
                                   # level_stats.  None = no trace state in
                                   # any solver loop; the jaxpr is
                                   # bit-identical to the untraced build
                                   # (same static-gate contract as
                                   # compute_dtype=None; DESIGN.md §13)


@dataclasses.dataclass
class DCSVMModel:
    config: DCSVMConfig
    X: Array                       # base training points (n, d)
    y: Array                       # labels in {-1, +1} (SVR: real targets)
    alpha: Array                   # dual solution over the task's dual
                                   # coordinates (n for SVC, 2n for SVR)
    partition: Optional[Partition] # base-point partition at the stopping
                                   # level (early prediction / serving)
    is_early: bool
    level_stats: List[Dict[str, Any]]
    task: Task = dataclasses.field(default_factory=CSVC)
    beta: Optional[Array] = None   # collapsed decision coefficients (n,):
                                   # f(x) = sum_i beta_i K(x_i, x)
    rho: Optional[float] = None    # decision offset (equality-constrained
                                   # tasks: f(x) = sum_i beta_i K(x_i,x) - rho)
    rho_clusters: Optional[Array] = None   # (k,) per-cluster offsets of an
                                   # early-stopped equality model: each local
                                   # sub-QP carries its own multiplier, so
                                   # eq.-11 routing subtracts the assigned
                                   # cluster's rho_c, not the global rho

    @property
    def weights(self) -> Array:
        """Decision coefficients beta over the base points; models built
        before the task refactor (beta=None) fall back to the hinge form
        ``y ∘ alpha`` (identical for classification)."""
        return self.beta if self.beta is not None else self.alpha * self.y

    @property
    def sv_index(self) -> np.ndarray:
        return np.nonzero(np.asarray(self.weights) != 0)[0]


# ---------------------------------------------------------------------------
# per-level solve: all clusters at once
# ---------------------------------------------------------------------------

def _map_classes(fn, args, fits_budget: bool):
    """Apply ``fn`` over the leading class axis of ``args``: vmapped when the
    batched per-class intermediates fit the Gram budget, otherwise a
    sequential ``lax.map`` sweep (one class's Q live at a time)."""
    if fits_budget:
        return jax.vmap(fn)(*args)
    return jax.lax.map(lambda t: fn(*t), args)


def _split_eq_targets(Ac: Array, Cc: Array, mask: Array, Gc: Array,
                      d_total: Array, n_groups: int) -> Array:
    """Proportional split of the global equality target(s) over clusters.

    ``Ac``/``Cc``/``Gc``: (k, n_rows, nc) gathered equality coefficients,
    boxes, and constraint-group ids, ``mask``: (k, nc), ``d_total``:
    (n_rows, n_groups).  Per group g, each cluster's sub-target ``d_c,g``
    sits at the same relative position inside the cluster's attainable
    interval [lo_c, hi_c] = [sum_{a<0} a c, sum_{a>0} a c] (over the
    cluster's group-g members) as ``d_g`` sits inside the global one — so
    every sub-QP is feasible and the sub-targets sum exactly to ``d_g``
    (the concatenated cluster solutions are a feasible global warm start);
    a cluster with no group-g members gets ``d_c,g = 0``.  For the
    all-positive ``a`` of the shipping tasks this is the
    capacity-proportional split d_c = d * cap_c/cap per group.  Returns
    (k, n_rows, n_groups).
    """
    m = mask[:, None, :]
    out = []
    for g in range(n_groups):
        contrib = jnp.where(m & (Gc == g), Ac * Cc, 0.0)
        hi_c = jnp.sum(jnp.maximum(contrib, 0.0), axis=-1)     # (k, n_rows)
        lo_c = jnp.sum(jnp.minimum(contrib, 0.0), axis=-1)
        lo = jnp.sum(lo_c, axis=0)                             # (n_rows,)
        hi = jnp.sum(hi_c, axis=0)
        span = jnp.maximum(hi - lo, 1e-12)
        frac = (jnp.clip(d_total[:, g], lo, hi) - lo) / span
        out.append(lo_c + frac[None, :] * (hi_c - lo_c))
    return jnp.stack(out, axis=-1)


def _solve_clusters(
    cfg: DCSVMConfig, Xc: Array, sc: Array, pc: Array, cc: Array, ac: Array,
    mask: Array, use_pallas: bool = False,
    aeq: Optional[Array] = None, geq: Optional[Array] = None,
    deq: Optional[Array] = None, n_groups: int = 1,
    Xcb: Optional[Array] = None, lbc: Optional[Array] = None,
) -> Array:
    """Solve the independent generalized sub-QPs of one level.
    Xc: (k, nc, d), mask: (k, nc); sc/pc/cc/ac are class-stacked
    (k, n_rows, nc) sign vectors, linear terms, per-coordinate boxes and
    warm-start duals — binary is one row.  The Gram is task- and
    label-independent, so one Gram per cluster serves every row and all
    k * n_rows sub-QPs run in a single vmapped CD call.

    ``aeq``/``geq``/``deq`` (equality family): (k, n_rows, nc) coefficients
    and group ids plus the (k, n_rows, n_groups) per-cluster targets from
    ``_split_eq_targets`` — each sub-QP keeps its own hyperplane(s)
    ``a'u_c = d_c,g`` via the pairwise (``eq_block_size <= 1``) or rank-2B
    blocked engine (warm starts are projected feasible inside the
    solver)."""
    k, nc, _ = Xc.shape
    n_cls = sc.shape[1]
    has_eq = aeq is not None
    dedup = Xcb is not None

    def one(Xi, Si, Pi, Ci, Ai, mi, *rest):
        if dedup:
            # base-indexed view: the cluster's kernel evaluations run on its
            # nb unique base points (nc = 2 nb for SVR's mirrored dual), and
            # the dual-coordinate Gram is a gather — the same dot products,
            # so bit-identical to the direct (nc, nc) Gram at 1/4 the evals
            Xbi, lbi = rest[0], rest[1]
            rest = rest[2:]
            Kb = gram(cfg.kernel, Xbi, Xbi, use_pallas=use_pallas,
                      compute_dtype=cfg.compute_dtype)
            Ki = Kb[lbi][:, lbi]
        else:
            Ki = gram(cfg.kernel, Xi, Xi, use_pallas=use_pallas,
                      compute_dtype=cfg.compute_dtype)
        eq = rest
        # zero pad rows/cols so pad slots cannot leak into real gradients
        mm = mi[:, None] & mi[None, :]
        Kz = jnp.where(mm, Ki, 0.0)
        eye_pad = jnp.where(mi, 0.0, 1.0) * jnp.eye(nc, dtype=Ki.dtype)

        def per_class(si, pi, ci, ai, *eqi):
            Qi = (si[:, None] * si[None, :]) * Kz + eye_pad
            ai = jnp.where(mi, ai, 0.0)
            if has_eq:
                aqi, gqi, dqi = eqi
                eq_kw = dict(alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                             active_mask=mi, p=pi, gid=gqi,
                             n_groups=n_groups)
                cb = jnp.where(mi, ci, 0.0)
                ab = jnp.where(mi, aqi, 0.0)
                if cfg.eq_block_size > 1:
                    res = S.solve_eq_qp_block(
                        Qi, cb, ab, dqi, block=cfg.eq_block_size,
                        sweeps=cfg.sweeps, **eq_kw,
                    )
                else:
                    res = S.solve_eq_qp(Qi, cb, ab, dqi, **eq_kw)
            elif cfg.block > 0 and cfg.block < nc:
                res = S.solve_box_qp_block(
                    Qi, ci, alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                    block=cfg.block, sweeps=cfg.sweeps, active_mask=mi, p=pi,
                )
            else:
                res = S.solve_box_qp(
                    Qi, ci, alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                    active_mask=mi, p=pi,
                )
            return res.alpha

        return jax.vmap(per_class)(Si, Pi, Ci, Ai, *eq)      # (n_cls, nc)

    args = (Xc, sc, pc, cc, ac, mask) \
        + ((Xcb, lbc) if dedup else ()) \
        + ((aeq, geq, deq) if has_eq else ())
    # sequential sweep bounds peak memory at one cluster's Grams
    return _map_classes(one, args,
                        gramop.fits_budget(k * n_cls * nc * nc,
                                           cfg.gram_budget))


def _solve_subset(cfg: DCSVMConfig, td: TaskDual, alpha: Array, idx: Array,
                  use_pallas: bool = False) -> Array:
    """Refine pass: solve the sub-QP restricted to ``idx`` (level-1 SVs,
    dual coordinates).

    ``alpha`` is class-stacked (n_rows, n_dual); the subset Gram is shared
    across rows (per-row Q batches fall back to a sequential sweep when
    they would blow the Gram budget)."""
    Xs = td.Xd[idx]
    Ks = gram(cfg.kernel, Xs, Xs, use_pallas=use_pallas,
              compute_dtype=cfg.compute_dtype)
    ss, ps, cs, as_ = td.S[:, idx], td.P[:, idx], td.Cvec[:, idx], alpha[:, idx]
    fits = gramop.fits_budget(td.S.shape[0] * Xs.shape[0] ** 2,
                              cfg.gram_budget)

    if td.has_equality:
        # per-group sub-targets: the full targets minus the frozen
        # complement's a'u (the complement is the non-SV set, i.e. u = 0,
        # so d_sub == d — computed explicitly to stay correct for any idx)
        G = td.n_groups
        gids = td.group_ids
        oh = gids[..., None] == jnp.arange(G)            # (n_rows, nd, G)
        au = (td.A * alpha)[..., None] * oh
        ds = td.Deq - jnp.sum(au, axis=1) + jnp.sum(au[:, idx], axis=1)

        def per_class_eq(si, pi, ci, ai, aqi, gqi, dqi):
            Qs = (si[:, None] * si[None, :]) * Ks
            eq_kw = dict(alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                         p=pi, gid=gqi, n_groups=G)
            if cfg.eq_block_size > 1:
                res = S.solve_eq_qp_block(Qs, ci, aqi, dqi,
                                          block=cfg.eq_block_size,
                                          sweeps=cfg.sweeps, **eq_kw)
            else:
                res = S.solve_eq_qp(Qs, ci, aqi, dqi, **eq_kw)
            return res.alpha

        new = _map_classes(per_class_eq,
                           (ss, ps, cs, as_, td.A[:, idx], gids[:, idx], ds),
                           fits)
        return alpha.at[:, idx].set(new)

    def per_class(si, pi, ci, ai):
        Qs = (si[:, None] * si[None, :]) * Ks
        if cfg.block > 0:
            res = S.solve_box_qp_block(
                Qs, ci, alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                block=min(cfg.block, Qs.shape[0]), sweeps=cfg.sweeps, p=pi,
            )
        else:
            res = S.solve_box_qp(Qs, ci, alpha0=ai, tol=cfg.tol,
                                 max_iters=cfg.max_iters, p=pi)
        return res.alpha

    new = _map_classes(per_class, (ss, ps, cs, as_), fits)
    return alpha.at[:, idx].set(new)


def _stack_results(results: List[S.SolveResult]) -> S.SolveResult:
    """Stack per-class SolveResults along a new leading axis, field-wise.
    ``None`` fields (no cache, no trace) stay ``None``; pytree fields
    (ConvTrace) are stacked leaf-wise."""
    def stack_field(f):
        vals = [getattr(r, f) for r in results]
        if any(v is None for v in vals):
            return None
        return jax.tree.map(lambda *vs: jnp.stack(vs), *vals)
    return S.SolveResult(*(stack_field(f) for f in S.SolveResult._fields))


def _solve_full(cfg: DCSVMConfig, td: TaskDual, alpha: Array,
                use_pallas: bool = False):
    """Top-level (level 0) solve on the whole generalized dual, warm-started.

    ``alpha`` is class-stacked (n_rows, n_dual): the dense path shares one
    Gram across all rows and solves the row QPs in a single vmapped call —
    unless the n_rows (n, n) Q batch would blow the Gram budget, in which
    case rows run as a sequential sweep (one Q live at a time); the matvec
    path vmaps the matvec solver over the class axis (the per-row cache
    budget is split accordingly)."""
    n = td.n_dual
    n_cls = td.S.shape[0]

    def _tr():
        # fresh per-class ring; created INSIDE the per-class closures so the
        # class vmap stacks it to (n_cls, cap, NCOLS) / (n_cls,)
        return trace_init(cfg.trace) if cfg.trace else None

    dedup = cfg.gram_dedup and td.n_base != n and not td.has_equality
    # host_spill routes the box family out-of-core even under the dense
    # threshold (the flag's meaning is "never materialize the level-0 Gram");
    # equality tasks stay on their dense/matvec engines
    spill = cfg.host_spill and not td.has_equality
    if n <= cfg.full_gram_threshold and not spill:
        if dedup:
            # base-indexed dense Gram: n_base^2 kernel evals instead of
            # n_dual^2, gathered to dual coordinates (bit-identical values)
            Xb, bidx = td.base_view()
            K = gram(cfg.kernel, Xb, Xb, use_pallas=use_pallas,
                     compute_dtype=cfg.compute_dtype)[bidx][:, bidx]
        else:
            K = gram(cfg.kernel, td.Xd, td.Xd, use_pallas=use_pallas,
                     compute_dtype=cfg.compute_dtype)

        if td.has_equality:
            def per_class_eq(si, pi, ci, ai, aqi, gqi, dqi):
                Q = (si[:, None] * si[None, :]) * K
                return S.solve_eq_qp_shrink(
                    Q, ci, aqi, dqi, alpha0=ai, tol=cfg.tol,
                    max_iters=cfg.max_iters, rounds=cfg.shrink_rounds, p=pi,
                    block=cfg.eq_block_size, sweeps=cfg.sweeps, gid=gqi,
                    n_groups=td.n_groups, trace=_tr(),
                )

            return _map_classes(
                per_class_eq,
                (td.S, td.P, td.Cvec, alpha, td.A, td.group_ids, td.Deq),
                gramop.fits_budget(n_cls * n * n, cfg.gram_budget))

        def per_class(si, pi, ci, ai):
            Q = (si[:, None] * si[None, :]) * K
            return S.solve_with_shrinking(
                Q, ci, alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                rounds=cfg.shrink_rounds, block=cfg.block, p=pi,
                trace=_tr(),
            )

        return _map_classes(per_class, (td.S, td.P, td.Cvec, alpha),
                            gramop.fits_budget(n_cls * n * n, cfg.gram_budget))

    if td.has_equality:
        def per_class_eq_mv(si, pi, ci, ai, aqi, gqi, dqi):
            return S.solve_eq_qp_matvec(
                td.Xd, si, cfg.kernel, ci, aqi, dqi, alpha0=ai, tol=cfg.tol,
                max_iters=cfg.max_iters, use_pallas=use_pallas, p=pi,
                block=cfg.eq_block_size, sweeps=cfg.sweeps, gid=gqi,
                n_groups=td.n_groups, compute_dtype=cfg.compute_dtype,
                trace=_tr(),
            )

        return jax.vmap(per_class_eq_mv)(td.S, td.P, td.Cvec, alpha,
                                         td.A, td.group_ids, td.Deq)

    Xb, bidx = td.base_view() if dedup else (None, None)

    if spill:
        # out-of-core level 0: per class, raw kernel-row panels spilled to
        # host RAM with a device panel LRU (core.gramop) — gram_budget is
        # the DEVICE byte budget; Gram size is bounded by host memory
        results = []
        for r in range(td.S.shape[0]):
            op = gramop.GramOperator(
                Xd=td.Xd, s=td.S[r], Xb=Xb, bidx=bidx, kernel=cfg.kernel,
                use_pallas=use_pallas, compute_dtype=cfg.compute_dtype,
                budget_bytes=cfg.gram_budget)
            results.append(gramop.solve_box_qp_spill(
                op, td.Cvec[r], alpha0=alpha[r], tol=cfg.tol,
                max_iters=cfg.max_iters, block=max(cfg.block, 64),
                sweeps=cfg.sweeps, p=td.P[r],
                device_budget_bytes=cfg.gram_budget // max(n_cls, 1),
                trace=_tr()))
        return _stack_results(results)

    # the (cap, kwidth) cache buffer(s) count against the same BYTE budget
    # as the stacked cluster Grams; bf16 storage fits twice the f32 rows
    store = jnp.dtype(cfg.compute_dtype or jnp.float32).itemsize
    kwidth = td.n_base if dedup else n
    cache_cap = min(cfg.col_cache_cap, n,
                    cfg.gram_budget // max(kwidth * n_cls * store, 1))

    def per_class_mv(si, pi, ci, ai):
        return S.solve_box_qp_matvec(
            td.Xd, si, cfg.kernel, ci, alpha0=ai, tol=cfg.tol,
            max_iters=cfg.max_iters, block=max(cfg.block, 64), sweeps=cfg.sweeps,
            use_pallas=use_pallas, cache_cap=cache_cap, p=pi,
            compute_dtype=cfg.compute_dtype, Xbase=Xb, base_index=bidx,
            trace=_tr(),
        )

    return jax.vmap(per_class_mv)(td.S, td.P, td.Cvec, alpha)


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def _fit_algorithm1(
    cfg: DCSVMConfig,
    X: Array,
    td: TaskDual,
    callback: Optional[Callable[[int, Array, Dict[str, Any]], None]] = None,
):
    """Shared Algorithm-1 driver for every task (binary / one-vs-all C-SVC,
    weighted C-SVC, epsilon-SVR).

    ``td`` is the task's generalized dual (``repro.core.tasks``): class-
    stacked (n_rows, n_dual) sign/linear/box vectors over the dual points
    ``td.Xd`` (binary = one row).  The divide step is task- and label-
    independent — kernel kmeans clusters the n *base* points, and the base
    partition is expanded to dual coordinates through ``td.base_index``, so
    one partition serves every task/row and SVR's two mirrored coordinates
    of a sample always share a cluster.  All n_rows * k^l sub-QPs of a
    level run in a single vmapped CD call (``_solve_clusters``).  Returns
    ``(alpha (n_rows, n_dual), base partition, stats, is_early)``; the
    callback receives the class-stacked dual alpha.
    """
    n = X.shape[0]
    nd = td.n_dual
    base_index = np.asarray(td.base_index)
    use_pallas = resolve_use_pallas(cfg.use_pallas)
    key = jax.random.PRNGKey(cfg.seed)
    alpha = jnp.zeros(td.S.shape, X.dtype)
    sv_idx: Optional[np.ndarray] = None     # dual coordinates with alpha > 0
    sv_base: Optional[np.ndarray] = None    # their (unique) base points
    stats: List[Dict[str, Any]] = []
    partition: Optional[Partition] = None
    rng = np.random.default_rng(cfg.seed)

    for l in range(cfg.levels, 0, -1):
        kl = cfg.k ** l
        if kl >= n // 2:   # degenerate level (clusters of ~1 point): skip
            continue
        t0 = time.perf_counter()
        key, sub = jax.random.split(key)
        sample_idx = None
        if cfg.adaptive and sv_base is not None and len(sv_base) > kl:
            take = min(cfg.m, len(sv_base))
            sample_idx = rng.choice(sv_base, size=take, replace=False)
        with span(f"divide/level{l}/cluster"):
            partition = two_step_kernel_kmeans(
                cfg.kernel, X, kl, sub, m=cfg.m, iters=cfg.kmeans_iters,
                sample_idx=sample_idx, balanced=cfg.balanced,
                use_pallas=use_pallas,
            )
        # expand the base partition to dual coordinates: SVR's mirrored
        # (alpha_i, alpha*_i) pair inherits sample i's cluster
        dpart = partition if nd == n else Partition.build(
            np.asarray(partition.assign)[base_index].astype(np.int32),
            kl, partition.model)
        t_cluster = time.perf_counter() - t0

        t0 = time.perf_counter()
        Xcb = lbc = None
        if cfg.gram_dedup and nd != n:
            # base-indexed cluster Grams: map each dual slot to its base
            # point's local slot inside the BASE partition's cluster (the
            # mirrored pair shares a cluster by construction), so each
            # cluster computes an (nb, nb) Gram instead of (2nb, 2nb)
            pidx, pmask = np.asarray(partition.idx), np.asarray(partition.mask)
            pos = np.zeros(n, np.int64)
            ci_, si_ = np.nonzero(pmask)
            pos[pidx[ci_, si_]] = si_
            didx = np.asarray(dpart.idx)
            lbc = jnp.asarray(
                np.where(np.asarray(dpart.mask),
                         pos[base_index[np.maximum(didx, 0)]], 0),
                jnp.int32)
            Xcb = partition.gather(X)
        Xc = dpart.gather(td.Xd)
        mask = jnp.asarray(dpart.mask)
        # (k, nc, n_rows) gathers -> (k, n_rows, nc) class-stacked batch
        sc = jnp.moveaxis(dpart.gather(td.S.T), -1, 1)
        pc = jnp.moveaxis(dpart.gather(td.P.T), -1, 1)
        cc = jnp.moveaxis(dpart.gather(td.Cvec.T), -1, 1)
        ac = jnp.moveaxis(dpart.gather(alpha.T), -1, 1)
        ac = jnp.where(mask[:, None, :], ac, 0.0)
        aeqc = geqc = deqc = None
        if td.has_equality:
            # split the global target(s) a'u = d_g proportionally over
            # clusters per constraint group; the pairwise/blocked sub-solver
            # projects each gathered warm start onto its own hyperplane(s)
            aeqc = jnp.moveaxis(dpart.gather(td.A.T), -1, 1)
            geqc = jnp.moveaxis(dpart.gather(td.group_ids.T), -1, 1)
            deqc = _split_eq_targets(aeqc, cc, mask, geqc,
                                     jnp.asarray(td.Deq), td.n_groups)
        with span(f"divide/level{l}/solve"):
            ac = _solve_clusters(cfg, Xc, sc, pc, cc, ac, mask,
                                 use_pallas=use_pallas, aeq=aeqc, geq=geqc,
                                 deq=deqc, n_groups=max(td.n_groups, 1),
                                 Xcb=Xcb, lbc=lbc)
            alpha = dpart.scatter(jnp.moveaxis(ac, 1, -1), nd).T
            alpha.block_until_ready()
        t_train = time.perf_counter() - t0

        sv_idx = np.nonzero(np.any(np.asarray(alpha) > 0, axis=0))[0]
        sv_base = np.unique(base_index[sv_idx])
        st = dict(level=l, clusters=kl, cluster_time=t_cluster, train_time=t_train,
                  n_sv=int(len(sv_base)))
        stats.append(st)
        if callback is not None:
            callback(l, alpha, st)
        if cfg.early_stop_level == l:
            return alpha, partition, stats, True

    # ---- level 0: refine + full solve -----------------------------------
    t0 = time.perf_counter()
    if cfg.refine and sv_idx is not None and 0 < len(sv_idx) < nd:
        with span("conquer/refine"):
            alpha = _solve_subset(cfg, td, alpha, jnp.asarray(sv_idx),
                                  use_pallas=use_pallas)
    with span("conquer/solve"):
        res = _solve_full(cfg, td, alpha, use_pallas=use_pallas)
        alpha = res.alpha
        alpha.block_until_ready()
    sv_base0 = np.unique(
        base_index[np.any(np.asarray(alpha) > 0, axis=0)])
    st = dict(level=0, clusters=1, cluster_time=0.0,
              train_time=time.perf_counter() - t0,
              n_sv=int(len(sv_base0)),
              iters=int(np.sum(np.asarray(res.iters))),
              pg_max=float(np.max(np.asarray(res.pg_max))))
    if res.cache_hits is not None:
        hits = int(np.sum(np.asarray(res.cache_hits)))
        misses = int(np.sum(np.asarray(res.cache_misses)))
        st["cache_hits"] = hits
        st["cache_misses"] = misses
        st["cache_hit_rate"] = hits / max(hits + misses, 1)
    for name in ("cache_evictions", "spills", "spill_hits"):
        v = getattr(res, name, None)
        if v is not None:
            st[name] = int(np.sum(np.asarray(v)))
    if getattr(res, "trace", None) is not None:
        # the ONLY device->host trace transfer of the whole fit
        fetched = trace_fetch(res.trace)
        st["trace"] = fetched
        st["trace_summary"] = trace_summary(fetched)
    stats.append(st)
    if callback is not None:
        callback(0, alpha, st)
    return alpha, partition, stats, False


def _recover_rho_clusters(cfg: DCSVMConfig, td: TaskDual, task: Task,
                          alpha: Array, partition: Partition) -> Array:
    """Per-cluster decision offsets of an early-stopped model: cluster c's
    local sub-QP was solved with its own constraint(s) a'u_c = d_c,g, so
    its offset is the LOCAL multiplier combination rho_c (the global
    interval of a concatenated early solution is meaningless — the local
    levels differ by O(1)).  The offset recovery is delegated to
    ``task.recover_offset`` (single-constraint bracket midpoint for
    one-class SVM; the per-group r_+/r_- bias combination for two-
    constraint nu-SVC).  One per-cluster Gram matvec, same memory shape as
    a level solve — including the level solve's budget fallback (a
    sequential sweep when the stacked cluster Grams exceed
    ``gram_budget``).  Equality tasks keep n_dual == n_base, so the base
    partition indexes the dual coordinates directly."""
    use_pallas = resolve_use_pallas(cfg.use_pallas)
    Xc = partition.gather(td.Xd)
    mask = jnp.asarray(partition.mask)
    sc = partition.gather(td.S[0])
    pc = partition.gather(td.P[0])
    cc = partition.gather(td.Cvec[0])
    aq = partition.gather(td.A[0])
    gq = partition.gather(td.group_ids[0])
    uc = partition.gather(alpha[0])

    def one(Xi, si, pi, ci, ai, gi_, ui, mi):
        Ki = gram(cfg.kernel, Xi, Xi, use_pallas=use_pallas,
                  compute_dtype=cfg.compute_dtype)
        mm = mi[:, None] & mi[None, :]
        Kz = jnp.where(mm, Ki, 0.0)
        ui = jnp.where(mi, ui, 0.0)
        gi = si * (Kz @ (si * ui)) + pi
        return task.recover_offset(ui, gi, jnp.where(mi, ci, 0.0),
                                   jnp.where(mi, ai, 0.0), gi_,
                                   active_mask=mi)

    return _map_classes(one, (Xc, sc, pc, cc, aq, gq, uc, mask),
                        gramop.fits_budget(partition.k * partition.nc ** 2,
                                           cfg.gram_budget))


def _recover_rho(cfg: DCSVMConfig, td: TaskDual, task: Task,
                 alpha: Array) -> float:
    """Decision offset rho at the returned dual (one-class SVM's equality
    multiplier; minus the bias for two-constraint nu-SVC): recomputes the
    full gradient with one kernel matvec and reads the task's combination
    of the KKT multiplier bracket(s)."""
    up = resolve_use_pallas(cfg.use_pallas)
    s = td.S[0]
    g = s * gram_matvec(cfg.kernel, td.Xd, s * alpha[0], use_pallas=up,
                        compute_dtype=cfg.compute_dtype) \
        + td.P[0]
    return float(task.recover_offset(alpha[0], g, td.Cvec[0], td.A[0],
                                     td.group_ids[0]))


def fit(
    cfg: DCSVMConfig,
    X: Array,
    y: Optional[Array] = None,
    callback: Optional[Callable[[int, Array, Dict[str, Any]], None]] = None,
    task: Optional[Task] = None,
) -> DCSVMModel:
    """Train DC-SVM on any supported task (default: C-SVC on +/-1 labels).

    ``task`` selects the workload (``tasks.CSVC`` / ``tasks.WeightedCSVC`` /
    ``tasks.EpsilonSVR`` / ``tasks.NuSVC`` / ``tasks.OneClassSVM``); for
    regression ``y`` holds real targets; for label-free tasks (one-class
    SVM) ``y`` may be omitted.  ``callback(level, alpha, stats)`` fires
    after each level (level 0 = final solve) — benchmarks use it for
    time/objective curves; ``alpha`` is the task's dual vector (2n
    coordinates for SVR).
    """
    X = jnp.asarray(X)
    task = resolve_task(task)
    if y is None:
        if not task.label_free:
            raise ValueError(f"task {task.name!r} requires labels y")
        y = jnp.zeros(X.shape[0], X.dtype)
    y = jnp.asarray(y, X.dtype)
    td = task.build(X, y[None, :], cfg.C)
    cb = None if callback is None else (lambda l, a, st: callback(l, a[0], st))
    alpha, partition, stats, is_early = _fit_algorithm1(cfg, X, td, cb)
    beta = td.collapse(alpha)[0]
    rho = rho_clusters = None
    if task.has_rho_offset:
        rho = _recover_rho(cfg, td, task, alpha)
        if is_early and partition is not None:
            rho_clusters = _recover_rho_clusters(cfg, td, task, alpha,
                                                 partition)
    return DCSVMModel(cfg, X, y, alpha[0], partition, is_early, stats,
                      task=task, beta=beta, rho=rho,
                      rho_clusters=rho_clusters)


def objective_value(cfg: DCSVMConfig, X: Array, y: Array, alpha: Array,
                    num_chunks: Optional[int] = None, p=-1.0) -> Array:
    """f(alpha) = 1/2 alpha' Q alpha + p' alpha on the FULL generalized dual
    (Q = (s s') ∘ K), computed without materializing Q.  ``y`` is the task's
    sign vector ``s`` over the dual points ``X``; the default ``p = -1``
    is the hinge objective.  On the Pallas path the Q @ alpha matvec streams
    through the fused ``kernel_matvec`` kernel instead of the chunked
    ``lax.map``; ``num_chunks=None`` sizes the chunking to the config's
    byte budget (chunking is bit-identical — it only partitions rows)."""
    Kv = gram_matvec(cfg.kernel, X, y * alpha, num_chunks=num_chunks,
                     use_pallas=resolve_use_pallas(cfg.use_pallas),
                     compute_dtype=cfg.compute_dtype,
                     budget_bytes=cfg.gram_budget)
    pvec = jnp.broadcast_to(jnp.asarray(p, alpha.dtype), alpha.shape)
    return 0.5 * jnp.vdot(alpha, y * Kv) + jnp.vdot(pvec, alpha)
