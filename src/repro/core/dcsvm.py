"""DC-SVM: multilevel divide-and-conquer kernel SVM (paper Algorithm 1).

Level l (= levels .. 1): partition all n points into k^l balanced clusters by
two-step kernel kmeans (sampling from the lower level's support vectors when
``adaptive`` — Theorem 3), then solve the k^l independent sub-QPs warm-started
from the lower level's alpha.  All clusters of one level are solved in a
single vmapped CD call (or a lax.map sweep when the per-level Gram budget is
exceeded).

Level 0: optional refine pass on the level-1 support vectors, then the full
problem — warm-started greedy CD (Theorem 1 says the warm start is within
C^2 D(pi)/sigma_n of alpha*, so few iterations are needed; Theorem 2 says the
SV pattern is largely correct already, so the greedy selection rarely touches
non-SVs).

``early_stop_level = l`` stops after level l and returns an early-prediction
model (paper eq. 11): route a query to its nearest cluster, score with that
cluster's local model only.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, gram, gram_matvec, resolve_use_pallas
from repro.core.kkmeans import Partition, two_step_kernel_kmeans
from repro.core import solver as S

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DCSVMConfig:
    kernel: Kernel = Kernel("rbf", gamma=1.0)
    C: float = 1.0
    k: int = 4                     # branching factor (paper: 4)
    levels: int = 4                # l_max (paper: 4 => 256 bottom clusters)
    m: int = 1000                  # kmeans sample size (paper: 1000)
    kmeans_iters: int = 20
    tol: float = 1e-3              # projected-gradient stopping tolerance
    max_iters: int = 30_000        # per-(sub)problem CD iteration cap
    block: int = 0                 # 0 = paper-faithful 1-coordinate CD; >0 = block CD
    sweeps: int = 4                # inner sweeps for block CD
    adaptive: bool = True          # sample kmeans points from lower-level SVs
    refine: bool = True            # refine pass on level-1 SVs before final solve
    balanced: bool = True
    use_pallas: Optional[bool] = None  # None = auto (Pallas on TPU, XLA elsewhere)
    early_stop_level: int = 0      # 0 = exact solve; l >= 1 = stop after level l
    gram_budget: int = 2**27       # max floats for a level's stacked cluster Grams
    full_gram_threshold: int = 16384   # above this, level 0 uses the matvec solver
    col_cache_cap: int = 0         # kernel-column LRU slots for the matvec solver.
                                   # 0 (default) = fully fused recompute path; opt
                                   # in by sizing it >= the expected active set
                                   # (~#SV) — block serving is all-or-nothing, so
                                   # an undersized cache pays its (cap, n) memory
                                   # for ~zero hits (DESIGN.md §2)
    shrink_rounds: int = 3
    seed: int = 0


@dataclasses.dataclass
class DCSVMModel:
    config: DCSVMConfig
    X: Array                       # training points (referenced by the kernel model)
    y: Array                       # labels in {-1, +1}
    alpha: Array                   # dual solution (exact or level-l early)
    partition: Optional[Partition] # partition at the stopping level (early prediction)
    is_early: bool
    level_stats: List[Dict[str, Any]]

    @property
    def sv_index(self) -> np.ndarray:
        return np.nonzero(np.asarray(self.alpha) > 0)[0]


# ---------------------------------------------------------------------------
# per-level solve: all clusters at once
# ---------------------------------------------------------------------------

def _map_classes(fn, args, fits_budget: bool):
    """Apply ``fn`` over the leading class axis of ``args``: vmapped when the
    batched per-class intermediates fit the Gram budget, otherwise a
    sequential ``lax.map`` sweep (one class's Q live at a time)."""
    if fits_budget:
        return jax.vmap(fn)(*args)
    return jax.lax.map(lambda t: fn(*t), args)


def _solve_clusters(
    cfg: DCSVMConfig, Xc: Array, yc: Array, ac: Array, mask: Array,
    use_pallas: bool = False,
) -> Array:
    """Solve the independent sub-QPs of one level.  Xc: (k, nc, d),
    mask: (k, nc); yc/ac are class-stacked (k, n_classes, nc) — binary is
    one class row.  The Gram is label-independent, so one Gram per cluster
    serves every class and all k * n_classes sub-QPs run in a single
    vmapped CD call."""
    k, nc, _ = Xc.shape
    n_cls = yc.shape[1]

    def one(Xi, Yi, Ai, mi):
        Ki = gram(cfg.kernel, Xi, Xi, use_pallas=use_pallas)
        # zero pad rows/cols so pad slots cannot leak into real gradients
        mm = mi[:, None] & mi[None, :]
        Kz = jnp.where(mm, Ki, 0.0)
        eye_pad = jnp.where(mi, 0.0, 1.0) * jnp.eye(nc, dtype=Ki.dtype)

        def per_class(yi, ai):
            Qi = (yi[:, None] * yi[None, :]) * Kz + eye_pad
            ai = jnp.where(mi, ai, 0.0)
            if cfg.block > 0 and cfg.block < nc:
                res = S.solve_box_qp_block(
                    Qi, cfg.C, alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                    block=cfg.block, sweeps=cfg.sweeps, active_mask=mi,
                )
            else:
                res = S.solve_box_qp(
                    Qi, cfg.C, alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                    active_mask=mi,
                )
            return res.alpha

        return jax.vmap(per_class)(Yi, Ai)                   # (n_cls, nc)

    # sequential sweep bounds peak memory at one cluster's Grams
    return _map_classes(one, (Xc, yc, ac, mask),
                        k * n_cls * nc * nc <= cfg.gram_budget)


def _solve_subset(cfg: DCSVMConfig, X: Array, y: Array, alpha: Array, idx: Array,
                  use_pallas: bool = False) -> Array:
    """Refine pass: solve the sub-QP restricted to ``idx`` (level-1 SVs).

    ``y``/``alpha`` are class-stacked (n_classes, n); the subset Gram is
    shared across classes (per-class Q batches fall back to a sequential
    sweep when they would blow the Gram budget)."""
    Xs = X[idx]
    Ks = gram(cfg.kernel, Xs, Xs, use_pallas=use_pallas)
    ys, as_ = y[:, idx], alpha[:, idx]

    def per_class(yi, ai):
        Qs = (yi[:, None] * yi[None, :]) * Ks
        if cfg.block > 0:
            res = S.solve_box_qp_block(
                Qs, cfg.C, alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                block=min(cfg.block, Qs.shape[0]), sweeps=cfg.sweeps,
            )
        else:
            res = S.solve_box_qp(Qs, cfg.C, alpha0=ai, tol=cfg.tol,
                                 max_iters=cfg.max_iters)
        return res.alpha

    new = _map_classes(per_class, (ys, as_),
                       y.shape[0] * Xs.shape[0] ** 2 <= cfg.gram_budget)
    return alpha.at[:, idx].set(new)


def _solve_full(cfg: DCSVMConfig, X: Array, y: Array, alpha: Array,
                use_pallas: bool = False):
    """Top-level (level 0) solve on the whole problem, warm-started.

    ``y``/``alpha`` are class-stacked (n_classes, n): the dense path shares
    one Gram across all classes and solves the class QPs in a single
    vmapped call — unless the n_classes (n, n) Q batch would blow the Gram
    budget, in which case classes run as a sequential sweep (one Q live at
    a time); the matvec path vmaps the matvec solver over the class axis
    (the per-class cache budget is split accordingly)."""
    n = X.shape[0]
    n_cls = y.shape[0]
    if n <= cfg.full_gram_threshold:
        K = gram(cfg.kernel, X, X, use_pallas=use_pallas)

        def per_class(yi, ai):
            Q = (yi[:, None] * yi[None, :]) * K
            return S.solve_with_shrinking(
                Q, cfg.C, alpha0=ai, tol=cfg.tol, max_iters=cfg.max_iters,
                rounds=cfg.shrink_rounds, block=cfg.block,
            )

        return _map_classes(per_class, (y, alpha),
                            n_cls * n * n <= cfg.gram_budget)

    # the (cap, n) cache buffer(s) count against the same memory budget as
    # the stacked cluster Grams
    cache_cap = min(cfg.col_cache_cap, n, cfg.gram_budget // max(n * n_cls, 1))

    def per_class_mv(yi, ai):
        return S.solve_box_qp_matvec(
            X, yi, cfg.kernel, cfg.C, alpha0=ai, tol=cfg.tol,
            max_iters=cfg.max_iters, block=max(cfg.block, 64), sweeps=cfg.sweeps,
            use_pallas=use_pallas, cache_cap=cache_cap,
        )

    return jax.vmap(per_class_mv)(y, alpha)


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def _fit_algorithm1(
    cfg: DCSVMConfig,
    X: Array,
    Y: Array,
    callback: Optional[Callable[[int, Array, Dict[str, Any]], None]] = None,
):
    """Shared Algorithm-1 driver for binary and one-vs-all training.

    ``Y`` is the class-stacked (n_classes, n) +/-1 label matrix (binary =
    one row).  The divide step is label-independent, so one partition and
    one per-cluster Gram serve every row; all n_classes * k^l sub-QPs of a
    level run in a single vmapped CD call (``_solve_clusters``).  Returns
    ``(alpha (n_classes, n), partition, stats, is_early)``; the callback
    receives the class-stacked alpha.
    """
    n = X.shape[0]
    use_pallas = resolve_use_pallas(cfg.use_pallas)
    key = jax.random.PRNGKey(cfg.seed)
    alpha = jnp.zeros(Y.shape, X.dtype)
    sv_idx: Optional[np.ndarray] = None
    stats: List[Dict[str, Any]] = []
    partition: Optional[Partition] = None
    rng = np.random.default_rng(cfg.seed)

    for l in range(cfg.levels, 0, -1):
        kl = cfg.k ** l
        if kl >= n // 2:   # degenerate level (clusters of ~1 point): skip
            continue
        t0 = time.perf_counter()
        key, sub = jax.random.split(key)
        sample_idx = None
        if cfg.adaptive and sv_idx is not None and len(sv_idx) > kl:
            take = min(cfg.m, len(sv_idx))
            sample_idx = rng.choice(sv_idx, size=take, replace=False)
        partition = two_step_kernel_kmeans(
            cfg.kernel, X, kl, sub, m=cfg.m, iters=cfg.kmeans_iters,
            sample_idx=sample_idx, balanced=cfg.balanced, use_pallas=use_pallas,
        )
        t_cluster = time.perf_counter() - t0

        t0 = time.perf_counter()
        Xc = partition.gather(X)
        mask = jnp.asarray(partition.mask)
        # (k, nc, n_classes) gathers -> (k, n_classes, nc) class-stacked batch
        Yc = jnp.moveaxis(partition.gather(Y.T), -1, 1)
        ac = jnp.moveaxis(partition.gather(alpha.T), -1, 1)
        ac = jnp.where(mask[:, None, :], ac, 0.0)
        ac = _solve_clusters(cfg, Xc, Yc, ac, mask, use_pallas=use_pallas)
        alpha = partition.scatter(jnp.moveaxis(ac, 1, -1), n).T
        alpha.block_until_ready()
        t_train = time.perf_counter() - t0

        sv_idx = np.nonzero(np.any(np.asarray(alpha) > 0, axis=0))[0]
        st = dict(level=l, clusters=kl, cluster_time=t_cluster, train_time=t_train,
                  n_sv=int(len(sv_idx)))
        stats.append(st)
        if callback is not None:
            callback(l, alpha, st)
        if cfg.early_stop_level == l:
            return alpha, partition, stats, True

    # ---- level 0: refine + full solve -----------------------------------
    t0 = time.perf_counter()
    if cfg.refine and sv_idx is not None and 0 < len(sv_idx) < n:
        alpha = _solve_subset(cfg, X, Y, alpha, jnp.asarray(sv_idx),
                              use_pallas=use_pallas)
    res = _solve_full(cfg, X, Y, alpha, use_pallas=use_pallas)
    alpha = res.alpha
    alpha.block_until_ready()
    st = dict(level=0, clusters=1, cluster_time=0.0,
              train_time=time.perf_counter() - t0,
              n_sv=int(np.sum(np.any(np.asarray(alpha) > 0, axis=0))),
              iters=int(np.sum(np.asarray(res.iters))),
              pg_max=float(np.max(np.asarray(res.pg_max))))
    if res.cache_hits is not None:
        hits = int(np.sum(np.asarray(res.cache_hits)))
        misses = int(np.sum(np.asarray(res.cache_misses)))
        st["cache_hits"] = hits
        st["cache_misses"] = misses
        st["cache_hit_rate"] = hits / max(hits + misses, 1)
    stats.append(st)
    if callback is not None:
        callback(0, alpha, st)
    return alpha, partition, stats, False


def fit(
    cfg: DCSVMConfig,
    X: Array,
    y: Array,
    callback: Optional[Callable[[int, Array, Dict[str, Any]], None]] = None,
) -> DCSVMModel:
    """Train DC-SVM.  ``callback(level, alpha, stats)`` fires after each level
    (level 0 = final solve) — benchmarks use it for time/objective curves."""
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    cb = None if callback is None else (lambda l, a, st: callback(l, a[0], st))
    alpha, partition, stats, is_early = _fit_algorithm1(cfg, X, y[None, :], cb)
    return DCSVMModel(cfg, X, y, alpha[0], partition, is_early, stats)


def objective_value(cfg: DCSVMConfig, X: Array, y: Array, alpha: Array,
                    num_chunks: int = 8) -> Array:
    """f(alpha) on the FULL problem, computed without materializing Q.

    On the Pallas path the Q @ alpha matvec streams through the fused
    ``kernel_matvec`` kernel instead of the chunked ``lax.map``."""
    Kv = gram_matvec(cfg.kernel, X, y * alpha, num_chunks=num_chunks,
                     use_pallas=resolve_use_pallas(cfg.use_pallas))
    return 0.5 * jnp.vdot(alpha, y * Kv) - jnp.sum(alpha)
