"""Task abstraction: one generalized dual for every supported kernel machine.

Every task reduces to the box-constrained QP the solvers in
``repro.core.solver`` operate on,

    min_u  1/2 u' Q u + p' u     s.t.  0 <= u <= c,      Q = (s s') ∘ K~

where ``K~`` is the kernel matrix over the task's *dual points* (the
training points, possibly duplicated) and ``s`` is a task-specific sign
vector.  The reduction table:

    task          dual points     s                 p             c
    ------------  --------------  ----------------  ------------  -------------
    CSVC          X        (n)    y                 -1            C
    WeightedCSVC  X        (n)    y                 -1            C * w_{y_i}
    EpsilonSVR    [X; X]   (2n)   (+1 ... -1 ...)   eps -/+ y     C

For epsilon-SVR the 2n-variable ``u = (alpha, alpha*)`` pair collapses back
to n decision coefficients ``beta_i = alpha_i - alpha*_i`` — in general
``beta = scatter-add of (s ∘ u) over base_index`` — and the decision
function for EVERY task is

    f(x) = sum_i beta_i K(x_i, x)

(for classification ``beta = y ∘ alpha``), so prediction and serving are
task-uniform: they only ever see base points and collapsed coefficients.

The divide step stays label-free: DC-SVM clusters the n *base* points and
``TaskDual.base_index`` expands the base partition to dual coordinates, so
one partition serves every task and the two mirrored coordinates of an SVR
sample always land in the same cluster (required for the per-cluster
sub-QPs to see both halves of each pair — see DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.solver import equality_interval_grouped, equality_rho

Array = jax.Array


class TaskDual(NamedTuple):
    """One task instance reduced to the generalized dual, class-stacked.

    ``Xd``: (n_dual, d) dual points; ``S``/``P``/``Cvec``: (n_rows, n_dual)
    sign vector, linear term, and per-coordinate upper bound — ``n_rows`` is
    the leading class axis shared with the OVA machinery (binary and
    regression use one row).  ``base_index``: (n_dual,) original sample per
    dual coordinate (identity except for SVR's duplicated rows).

    ``A``/``Deq`` select the dual family: ``None`` for the box family, else
    the (n_rows, n_dual) equality coefficients and (n_rows, n_groups)
    targets of the per-group constraints ``sum_{i in g} a_i u_i = d_g``
    (one-class SVM / nu-SVC — solved by the pairwise/blocked engine).
    ``Geq`` (n_rows, n_dual) int32 assigns each coordinate to its
    constraint group; ``None`` means one global constraint (group 0).  The
    two-constraint nu-SVC dual (``e'u = nu n`` and ``y'u = 0``) decomposes
    into one mass constraint per class group, so ``Geq`` is the class
    indicator and ``Deq`` carries nu*n/2 per group (DESIGN.md §10).
    """

    Xd: Array
    S: Array
    P: Array
    Cvec: Array
    base_index: np.ndarray
    A: Optional[Array] = None
    Deq: Optional[Array] = None
    Geq: Optional[Array] = None

    @property
    def has_equality(self) -> bool:
        return self.A is not None

    @property
    def n_groups(self) -> int:
        """Number of equality-constraint groups (static: read off Deq's
        trailing shape); 0 for the box family."""
        return 0 if self.Deq is None else self.Deq.shape[-1]

    @property
    def group_ids(self) -> Array:
        """(n_rows, n_dual) int32 constraint-group ids (zeros when the task
        carries one global constraint)."""
        if self.Geq is not None:
            return self.Geq
        return jnp.zeros(self.S.shape, jnp.int32)

    @property
    def n_dual(self) -> int:
        return self.Xd.shape[0]

    @property
    def n_rows(self) -> int:
        """Leading class-stack size (1 for binary / regression)."""
        return self.S.shape[0]

    @property
    def n_base(self) -> int:
        return int(self.base_index.max()) + 1 if self.base_index.size else 0

    def base_view(self):
        """Deduped Gram view ``(Xb, bidx)`` with ``Xd == Xb[bidx]``
        row-for-row: ``Xb`` holds the first dual point of each base id (for
        SVR's [X; X] stacking that is X itself), ``bidx`` the int32 base id
        per dual coordinate.  Kernel rows computed against ``Xb`` and
        gathered through ``bidx`` are bit-identical to rows computed on the
        duplicated ``Xd`` (same dot products), at n_base-width storage —
        the ``core.gramop`` dedup contract."""
        bi = np.asarray(self.base_index)
        _, first = np.unique(bi, return_index=True)  # first row per base id
        return self.Xd[jnp.asarray(first)], jnp.asarray(bi, jnp.int32)

    def collapse(self, alpha: Array) -> Array:
        """(n_rows, n_dual) dual solution -> (n_rows, n_base) decision
        coefficients ``beta = scatter-add of s ∘ u over base_index``."""
        n = self.n_base
        out = jnp.zeros(alpha.shape[:-1] + (n,), alpha.dtype)
        return out.at[..., jnp.asarray(self.base_index)].add(self.S * alpha)


@dataclasses.dataclass(frozen=True)
class Task:
    """Base task: hyper-parameters + the reduction to the generalized dual."""

    name = "base"
    is_regression = False
    label_free = False       # True: ``fit`` ignores y (one-class SVM)
    has_rho_offset = False   # True: decision f(x) = sum beta_i K(x_i,x) - rho

    def build(self, X: Array, Y: Array, C: float) -> TaskDual:
        """Reduce (X, class-stacked Y, cost C) to the generalized dual."""
        raise NotImplementedError

    def recover_offset(self, alpha: Array, grad: Array, cvec: Array,
                       avec: Array, gid: Array,
                       active_mask: Optional[Array] = None) -> Array:
        """Decision offset rho (``f(x) = sum_i beta_i K(x_i, x) - rho``) of
        an equality-constrained task, read off the KKT multiplier
        bracket(s) at the returned dual.  Default: the single-constraint
        bracket midpoint (one-class SVM).  Pure jnp — called inside
        jit/vmap for per-cluster offsets of early-stopped models."""
        return equality_rho(alpha, grad, cvec, avec, active_mask=active_mask)


@dataclasses.dataclass(frozen=True)
class CSVC(Task):
    """Standard C-SVC hinge dual — exactly the pre-task solver behavior:
    ``p = -1, s = y, c = C`` (class-stacked Y for one-vs-all)."""

    name = "svc"

    def build(self, X: Array, Y: Array, C: float) -> TaskDual:
        Y = jnp.asarray(Y)
        return TaskDual(
            Xd=X,
            S=Y,
            P=jnp.full_like(Y, -1.0),
            Cvec=jnp.full_like(Y, C),
            base_index=np.arange(Y.shape[-1]),
        )


@dataclasses.dataclass(frozen=True)
class WeightedCSVC(Task):
    """Cost-sensitive C-SVC for imbalanced data: per-class box
    ``c_i = C * w_{y_i}`` (optionally refined by a per-sample weight vector).
    Upweighting the minority class raises the price of its margin
    violations, recovering recall the plain hinge trades away."""

    w_pos: float = 1.0
    w_neg: float = 1.0
    # optional per-sample multiplier on top of the class weights; anything
    # array-like of shape (n,) (instances carrying one are not hashable)
    sample_weight: Optional[object] = None

    name = "weighted-svc"

    def build(self, X: Array, Y: Array, C: float) -> TaskDual:
        Y = jnp.asarray(Y)
        w = jnp.where(Y > 0, self.w_pos, self.w_neg)
        if self.sample_weight is not None:
            w = w * jnp.asarray(self.sample_weight, Y.dtype)[None, :]
        return TaskDual(
            Xd=X,
            S=Y,
            P=jnp.full_like(Y, -1.0),
            Cvec=C * w,
            base_index=np.arange(Y.shape[-1]),
        )


@dataclasses.dataclass(frozen=True)
class EpsilonSVR(Task):
    """epsilon-insensitive support vector regression, 2n-variable dual.

    With ``u = (alpha, alpha*)`` stacked over duplicated rows of X:

        min 1/2 (a-a*)' K (a-a*) + eps * sum(a+a*) - y'(a-a*)
        =   min 1/2 u' ((s s') ∘ K~) u + p' u,   0 <= u <= C

    with ``s = (+1..., -1...)`` and ``p = (eps - y, eps + y)``.  At any
    optimum the pair is complementary (min(a_i, a*_i) = 0: the two
    coordinate gradients sum to 2*eps > 0), so the collapsed
    ``beta_i = a_i - a*_i`` is the unique decision coefficient vector and
    ``|f(x_i) - y_i| < eps  =>  beta_i = 0`` (the eps-tube property).
    """

    eps: float = 0.1

    name = "svr"
    is_regression = True

    def build(self, X: Array, Y: Array, C: float) -> TaskDual:
        y = jnp.asarray(Y)
        y = y[0] if y.ndim == 2 else y
        n = y.shape[0]
        ones = jnp.ones(n, X.dtype)
        return TaskDual(
            Xd=jnp.concatenate([X, X], axis=0),
            S=jnp.concatenate([ones, -ones])[None, :],
            P=jnp.concatenate([self.eps - y, self.eps + y])[None, :].astype(X.dtype),
            Cvec=jnp.full((1, 2 * n), C, X.dtype),
            base_index=np.concatenate([np.arange(n), np.arange(n)]),
        )


@dataclasses.dataclass(frozen=True)
class OneClassSVM(Task):
    """Schölkopf one-class SVM in the LIBSVM parameterization (label-free).

        min 1/2 a' K a   s.t.  0 <= a_i <= 1,  sum_i a_i = nu * n

    — the equality-constrained family with ``s = 1, p = 0, c = 1, a = 1,
    d = nu n``.  The multiplier of the equality constraint IS the decision
    offset rho: f(x) = sum_i alpha_i K(x_i, x) - rho, with f(x) >= 0 on
    inliers.  ``nu`` bounds both sides of the support: at most a nu
    fraction of training points fall outside (f < 0) and at least a nu
    fraction are support vectors.  Identical scaling to sklearn/libsvm, so
    decisions are directly comparable (tests/test_oneclass_nusvm.py).
    """

    nu: float = 0.5

    name = "ocsvm"
    label_free = True
    has_rho_offset = True

    def build(self, X: Array, Y: Array, C: float) -> TaskDual:
        if not 0.0 < self.nu <= 1.0:
            raise ValueError(f"one-class nu must lie in (0, 1], got {self.nu}")
        n = X.shape[0]
        ones = jnp.ones((1, n), X.dtype)
        return TaskDual(
            Xd=X,
            S=ones,
            P=jnp.zeros((1, n), X.dtype),
            Cvec=ones,
            base_index=np.arange(n),
            A=ones,
            Deq=jnp.asarray([[self.nu * n]], X.dtype),
        )


@dataclasses.dataclass(frozen=True)
class NuSVC(Task):
    """nu-parameterized classifier, with or without the bias term.

    ``with_bias=False`` (default — the PR-4 behavior): the bias-free dual

        min 1/2 u' Q u   s.t.  0 <= u <= 1,  sum_i u_i = nu * n

    with ``Q = (y y') ∘ K`` (no linear term).  Dropping the bias drops the
    ``y'u = 0`` coupling exactly as the paper's hinge dual does, leaving the
    single mass constraint ``e'u = nu n``: nu directly controls the support
    mass (margin-error fraction <= nu <= SV fraction).  Equivalent to the
    bias-free C-SVC: if ``alpha`` solves C-SVC at cost C then ``alpha / C``
    solves NuSVC at ``nu = sum(alpha) / (C n)`` and the decision functions
    agree up to the positive scale C (pinned in tests/test_oneclass_nusvm.py).

    ``with_bias=True``: the full (libsvm) nu-SVC dual restores ``y'u = 0``
    alongside ``e'u = nu n``.  With +/-1 labels the two constraints
    decompose into one mass constraint per class group,

        sum_{y_i = +1} u_i = sum_{y_i = -1} u_i = nu * n / 2,

    so the pairwise/blocked engine applies per label group (``Geq`` is the
    class indicator; pairs are drawn within a group).  The bias is
    recovered from the per-group multipliers r_+/r_-: ``b = (r_- - r_+)/2``
    and the margin ``rho_m = (r_+ + r_-)/2`` — the decision
    ``f(x) = sum_i u_i y_i K(x_i, x) + b`` is exposed through the uniform
    offset convention ``f = sum beta_i K - rho`` with ``rho = -b``
    (``has_rho_offset``), so prediction and serving reuse the one-class
    sign-threshold path unchanged; dividing by rho_m reproduces libsvm's
    rescaled decision function (pinned against sklearn.svm.NuSVC).
    Feasible iff ``nu <= 2 min(n_+, n_-) / n`` (checked at build).
    """

    nu: float = 0.5
    with_bias: bool = False

    name = "nu-svc"

    @property
    def has_rho_offset(self) -> bool:
        return self.with_bias

    def build(self, X: Array, Y: Array, C: float) -> TaskDual:
        if not 0.0 < self.nu <= 1.0:
            raise ValueError(f"nu-SVC nu must lie in (0, 1], got {self.nu}")
        Y = jnp.asarray(Y)
        n = Y.shape[-1]
        if not self.with_bias:
            return TaskDual(
                Xd=X,
                S=Y,
                P=jnp.zeros_like(Y),
                Cvec=jnp.ones_like(Y),
                base_index=np.arange(n),
                A=jnp.ones_like(Y),
                Deq=jnp.full((Y.shape[0], 1), self.nu * n, X.dtype),
            )
        n_pos = np.asarray(Y > 0).sum(axis=-1)
        n_min = np.minimum(n_pos, n - n_pos)
        if np.any(self.nu * n > 2 * n_min + 1e-9):
            raise ValueError(
                f"nu-SVC with bias needs nu <= 2 min(n+, n-)/n = "
                f"{2 * n_min.min() / n:.4f} (each class must carry mass "
                f"nu*n/2 with u <= 1); got nu = {self.nu}")
        return TaskDual(
            Xd=X,
            S=Y,
            P=jnp.zeros_like(Y),
            Cvec=jnp.ones_like(Y),
            base_index=np.arange(n),
            A=jnp.ones_like(Y),
            Deq=jnp.full((Y.shape[0], 2), 0.5 * self.nu * n, X.dtype),
            Geq=jnp.where(Y > 0, 0, 1).astype(jnp.int32),
        )

    def recover_offset(self, alpha: Array, grad: Array, cvec: Array,
                       avec: Array, gid: Array,
                       active_mask: Optional[Array] = None) -> Array:
        # rho = -b: for free SVs of group +/-, h = g_i equals r_+/- with
        # r_+ = rho_m - b, r_- = rho_m + b  =>  -b = (r_+ - r_-) / 2
        if not self.with_bias:
            return Task.recover_offset(self, alpha, grad, cvec, avec, gid,
                                       active_mask=active_mask)
        lo, hi = equality_interval_grouped(alpha, grad, cvec, avec, gid, 2,
                                           active_mask=active_mask)
        mid = 0.5 * (lo + hi)
        r = jnp.where(jnp.isfinite(mid), mid,
                      jnp.where(jnp.isfinite(lo), lo, hi))
        # A group with no coordinates at all (a single-class cluster of an
        # early-stopped model) has an EMPTY bracket and no multiplier: its
        # local bias is undefined, and substituting a 0 level would shift
        # every routed query by half the present group's level — toward the
        # ABSENT class.  Substitute the present group's level instead:
        # offset 0, the degenerate cluster scores with its raw
        # (own-class-signed) local decision.
        has = jnp.isfinite(r)
        r0 = jnp.where(has[0], r[0], jnp.where(has[1], r[1], 0.0))
        r1 = jnp.where(has[1], r[1], jnp.where(has[0], r[0], 0.0))
        return 0.5 * (r0 - r1)


def resolve_task(task: Optional[Task]) -> Task:
    """``None`` -> the default C-SVC hinge task."""
    return CSVC() if task is None else task
