"""Prediction strategies for DC-SVM models (paper Sec. 4, Table 1).

* ``decision_exact``  — f(x) = sum_i alpha_i y_i K(x, x_i); used with the
  final alpha (exact model) or with a level-l alpha (paper eq. 10, the
  "naive" early strategy).
* ``decision_early``  — paper eq. 11: route x to its nearest kernel-kmeans
  cluster and score with ONLY that cluster's local model.  This is exactly
  prediction under the block-diagonal kernel K-bar of Lemma 1, and is the
  paper's recommended early strategy (O(|S| d / k) per query).
* ``decision_bcm``    — Bayesian Committee Machine combination [Tresp, 2000]
  of the k local models, the paper's Table-1 baseline: precision-weighted
  average of local decisions with a GP-style predictive variance per cluster.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dcsvm import DCSVMModel
from repro.core.kernels import Kernel, gram, resolve_use_pallas
from repro.core.kkmeans import assign_points

Array = jax.Array


@partial(jax.jit, static_argnames=("kern", "chunk"))
def _decision_scan(kern: Kernel, Xq: Array, Xs: Array, w: Array,
                   chunk: int) -> Array:
    """sum_s w_s K(Xq, Xs) as ONE compiled scan over SV chunks (no per-chunk
    Python dispatch).  Zero-padded SV rows carry zero weights."""
    ns, d = Xs.shape
    chunk = min(chunk, ns)
    pad = (-ns) % chunk
    Xsp = jnp.pad(Xs, ((0, pad), (0, 0)))
    wp = jnp.pad(w, (0, pad))

    def step(acc, xw):
        Xc, wc = xw
        return acc + kern.pairwise(Xq, Xc) @ wc, None

    out, _ = jax.lax.scan(
        step, jnp.zeros(Xq.shape[0], Xq.dtype),
        (Xsp.reshape(-1, chunk, d), wp.reshape(-1, chunk)))
    return out


def decision_exact(model: DCSVMModel, Xq: Array, chunk: int = 4096,
                   use_pallas: Optional[bool] = None) -> Array:
    """f(x) over all support vectors (eq. 10 when alpha is a level-l
    solution).  Pallas path: one streaming ``kernel_matvec`` call — the
    (nq, |S|) kernel block never hits HBM; otherwise a single fused scan
    over SV chunks."""
    sv = model.sv_index
    if len(sv) == 0:
        return jnp.zeros(Xq.shape[0], Xq.dtype)
    if use_pallas is None:
        use_pallas = model.config.use_pallas
    Xs = model.X[jnp.asarray(sv)]
    w = (model.alpha * model.y)[jnp.asarray(sv)]
    kern = model.config.kernel
    if resolve_use_pallas(use_pallas):
        from repro.kernels import ops as kops

        return kops.kernel_matvec(Xq, Xs, w, kern).astype(Xq.dtype)
    return _decision_scan(kern, Xq, Xs, w, chunk)


def predict_exact(model: DCSVMModel, Xq: Array) -> Array:
    return jnp.sign(decision_exact(model, Xq))


def decision_early(model: DCSVMModel, Xq: Array,
                   use_pallas: Optional[bool] = None) -> Array:
    """Paper eq. 11: nearest-cluster routing + local-model scoring.

    Vectorized MoE-style dispatch (the same compute shape as our MoE layer):
    route every query to its cluster, sort queries by cluster id, batch each
    cluster's queries against ONLY that cluster's members — one vmapped
    kernel matvec, total work O(nq * (n/k) * d) = the paper's 1/k serving
    win.  On the Pallas path each cluster's scoring streams through the
    fused ``kernel_matvec`` kernel (vmapped over clusters).
    """
    part = model.partition
    assert part is not None, "early prediction requires a partitioned model"
    kern = model.config.kernel
    if use_pallas is None:
        use_pallas = model.config.use_pallas
    use_pallas = resolve_use_pallas(use_pallas)
    cid, _ = assign_points(kern, part.model, Xq, use_pallas=use_pallas)
    nq = Xq.shape[0]
    k = part.k

    order = jnp.argsort(cid)
    sc = cid[order]
    seg_start = jnp.searchsorted(sc, jnp.arange(k), side="left")
    pos = jnp.arange(nq) - seg_start[sc]
    # capacity = 2x balanced load; the rare overflow queries take the exact
    # per-query gather path below (never dropped)
    cap = int(min(nq, max(8, -(-2 * nq // k))))
    keep = pos < cap
    pos_safe = jnp.where(keep, pos, 0)
    sc_safe = jnp.where(keep, sc, 0)
    qbuf = jnp.zeros((k, cap, Xq.shape[1]), Xq.dtype)
    qbuf = qbuf.at[sc_safe, pos_safe].set(
        jnp.where(keep[:, None], Xq[order], 0.0))

    members = jnp.asarray(np.maximum(part.idx, 0))           # (k, nc)
    mmask = jnp.asarray(part.mask)
    Xm = model.X[members]                                    # (k, nc, d)
    wm = jnp.where(mmask, (model.alpha * model.y)[members], 0.0)

    if use_pallas:
        from repro.kernels import ops as kops

        def one(qc, Xc, wc):
            return kops.kernel_matvec(qc, Xc, wc, kern)      # (cap,)
    else:
        def one(qc, Xc, wc):
            return kern.pairwise(qc, Xc) @ wc                # (cap,)

    scores = jax.vmap(one)(qbuf, Xm, wm)                     # (k, cap)
    vals = jnp.where(keep, scores[sc_safe, pos_safe], 0.0)
    out = jnp.zeros(nq, scores.dtype).at[order].set(vals)

    n_of = int(jnp.sum(~keep))
    if n_of:                                                 # exact fallback
        qidx = order[jnp.nonzero(~keep, size=n_of)[0]]
        Xo = Xq[qidx]
        co = cid[qidx]
        Ko = jax.vmap(lambda xq, Xc, wc: kern.pairwise(xq[None], Xc)[0] @ wc)(
            Xo, Xm[co], wm[co])
        out = out.at[qidx].set(Ko)
    return out


def predict_early(model: DCSVMModel, Xq: Array) -> Array:
    return jnp.sign(decision_early(model, Xq))


def decision_bcm(model: DCSVMModel, Xq: Array, noise: float = 1e-2,
                 max_sv_per_cluster: int = 512) -> Array:
    """BCM combination of the k local models (paper's Table-1 baseline).

    Each cluster contributes its local decision f_c(x) weighted by the
    inverse GP predictive variance sigma_c^2(x) = K(x,x) - k_c' (K_cc +
    noise I)^-1 k_c computed on (a subsample of) the cluster's support
    vectors.  Precision-weighted averaging follows Tresp (2000); we use the
    common precision-normalized form (the (k-1)/K(x,x) prior correction is
    absorbed into the normalization, which only rescales decisions and does
    not change the sign/accuracy).
    """
    part = model.partition
    assert part is not None
    kern = model.config.kernel
    w = model.alpha * model.y
    nq = Xq.shape[0]
    num = np.zeros(nq, np.float64)
    den = np.zeros(nq, np.float64) + 1e-12
    alpha_np = np.asarray(model.alpha)
    for c in range(part.k):
        members = part.idx[c][part.mask[c]]
        sv = members[alpha_np[members] > 0]
        if len(sv) == 0:
            continue
        if len(sv) > max_sv_per_cluster:
            sv = sv[:: len(sv) // max_sv_per_cluster + 1]
        Xs = model.X[jnp.asarray(sv)]
        Kss = np.asarray(gram(kern, Xs, Xs)) + noise * np.eye(len(sv))
        Kqs = np.asarray(gram(kern, Xq, Xs))
        f_c = Kqs @ np.asarray(w[jnp.asarray(sv)])
        sol = np.linalg.solve(Kss, Kqs.T)                     # (s, nq)
        var = np.asarray(kern.diag(Xq)) - np.einsum("qs,sq->q", Kqs, sol)
        var = np.maximum(var, noise)
        num += f_c / var
        den += 1.0 / var
    return jnp.asarray((num / den).astype(np.float32))


def predict_bcm(model: DCSVMModel, Xq: Array) -> Array:
    return jnp.sign(decision_bcm(model, Xq))


def accuracy(y_true: Array, y_pred: Array) -> float:
    return float(jnp.mean((jnp.sign(y_true) == jnp.sign(y_pred)).astype(jnp.float32)))
