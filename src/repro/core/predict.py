"""Prediction strategies for DC-SVM models (paper Sec. 4, Table 1).

All strategies are task-uniform: they score with the collapsed decision
coefficients ``beta`` (``model.weights``) over the base points —
``beta = y ∘ alpha`` for classification, ``beta = alpha - alpha*`` for
epsilon-SVR — so one code path serves C-SVC, weighted C-SVC, and
regression.  ``predict_*`` applies ``sign`` for classification and returns
the raw decision value for regression tasks.

* ``decision_exact``  — f(x) = sum_i beta_i K(x, x_i); used with the
  final alpha (exact model) or with a level-l alpha (paper eq. 10, the
  "naive" early strategy).
* ``decision_early``  — paper eq. 11: route x to its nearest kernel-kmeans
  cluster and score with ONLY that cluster's local model.  This is exactly
  prediction under the block-diagonal kernel K-bar of Lemma 1, and is the
  paper's recommended early strategy (O(|S| d / k) per query).
* ``decision_bcm``    — Bayesian Committee Machine combination [Tresp, 2000]
  of the k local models, the paper's Table-1 baseline: precision-weighted
  average of local decisions with a GP-style predictive variance per cluster.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dcsvm import DCSVMModel
from repro.core.kernels import Kernel, gram, resolve_use_pallas
from repro.core.kkmeans import KKMeansModel, assign_points

Array = jax.Array


# ---------------------------------------------------------------------------
# Bucketed per-cluster scoring (shared by early prediction, its OVA variant,
# and the serving engine)
# ---------------------------------------------------------------------------

def bucketed_cluster_scores(kern: Kernel, Xq: Array, cid: Array,
                            Xblocks: Array, Wblocks: Array, cap: int,
                            use_pallas: bool = False,
                            offsets: Optional[Array] = None,
                            compute_dtype: Optional[str] = None) -> Array:
    """Score every query against ONLY its assigned cluster's block.

    ``Xblocks``: (k, nc, d) per-cluster member coordinates, ``Wblocks``:
    (k, nc, C) per-member weights (zero on padding slots).  Returns (nq, C).
    ``offsets`` (k, C), when given, is subtracted from each query's score
    according to its assigned cluster — the per-cluster decision offsets
    rho_c of early-stopped equality-constrained models (one-class SVM).

    Queries are bucketed into a (k, cap, d) buffer and all clusters are
    scored in one vmapped kernel matvec.  Clusters holding more than ``cap``
    queries are handled by additional rounds of the SAME fused program
    inside an on-device ``lax.while_loop`` — the common no-overflow case
    runs exactly one round, and no path ever forces a host sync.  Queries
    outside the current round target a dropped out-of-bounds buffer slot,
    so they can never collide with (and overwrite) a real query's slot.
    """
    nq, d = Xq.shape
    k = Xblocks.shape[0]
    n_out = Wblocks.shape[-1]
    if nq == 0:
        return jnp.zeros((0, n_out), Xq.dtype)
    acc = jnp.promote_types(Xq.dtype, jnp.float32)

    order = jnp.argsort(cid)
    sc = cid[order]
    seg_start = jnp.searchsorted(sc, jnp.arange(k), side="left")
    pos = jnp.arange(nq) - seg_start[sc]        # rank of each query in its cluster
    pos_max = jnp.max(pos)

    if use_pallas and n_out == 1:
        from repro.kernels import ops as kops

        def one(qc, Xc, wc):
            return kops.kernel_matvec(qc, Xc, wc[:, 0], kern,
                                      compute_dtype=compute_dtype)[:, None]
    elif use_pallas:
        from repro.kernels import ops as kops

        def one(qc, Xc, wc):
            return kops.kernel_matrix(qc, Xc, kern,
                                      compute_dtype=compute_dtype) @ wc
    else:
        def one(qc, Xc, wc):
            return kern.pairwise(qc, Xc, compute_dtype=compute_dtype) @ wc

    def body(carry):
        out, r = carry
        base = r * cap
        in_r = (pos >= base) & (pos < base + cap)
        row = jnp.where(in_r, sc, k)                             # k = dropped
        col = jnp.where(in_r, pos - base, 0)
        qbuf = jnp.zeros((k, cap, d), Xq.dtype).at[row, col].set(
            Xq[order], mode="drop")
        scores = jax.vmap(one)(qbuf, Xblocks, Wblocks)           # (k, cap, C)
        vals = jnp.where(in_r[:, None],
                         scores[jnp.where(in_r, sc, 0), col], 0.0)
        return out.at[order].add(vals.astype(acc)), r + 1

    def cond(carry):
        _, r = carry
        return r * cap <= pos_max

    out0 = jnp.zeros((nq, n_out), acc)
    out, _ = jax.lax.while_loop(cond, body, (out0, jnp.zeros((), jnp.int32)))
    if offsets is not None:
        out = out - offsets[cid]
    return out.astype(Xq.dtype)


@partial(jax.jit, static_argnames=("kern", "cap", "use_pallas", "compute_dtype"))
def _early_program(kern: Kernel, Xq: Array, route_model: KKMeansModel,
                   Xblocks: Array, Wblocks: Array, cap: int,
                   use_pallas: bool = False,
                   offsets: Optional[Array] = None,
                   compute_dtype: Optional[str] = None) -> Array:
    """Route + bucketed local scoring as ONE compiled program."""
    cid, _ = assign_points(kern, route_model, Xq, use_pallas=use_pallas)
    return bucketed_cluster_scores(kern, Xq, cid, Xblocks, Wblocks, cap,
                                   use_pallas=use_pallas, offsets=offsets,
                                   compute_dtype=compute_dtype)


@partial(jax.jit, static_argnames=("kern", "chunk", "use_pallas",
                                   "compute_dtype"))
def _decision_scan(kern: Kernel, Xq: Array, Xs: Array, W: Array,
                   chunk: int, use_pallas: bool = False,
                   compute_dtype: Optional[str] = None) -> Array:
    """K(Xq, Xs) @ W as ONE compiled scan over SV chunks (no per-chunk
    Python dispatch, and never more than an (nq, chunk) kernel block live).
    W is (ns, C) — one weight column per output (C = 1 binary,
    C = n_classes one-vs-all).  Zero-padded SV rows carry zero weights."""
    ns, d = Xs.shape
    chunk = min(chunk, ns)
    pad = (-ns) % chunk
    Xsp = jnp.pad(Xs, ((0, pad), (0, 0)))
    Wp = jnp.pad(W, ((0, pad), (0, 0)))
    if use_pallas:
        from repro.kernels import ops as kops

    def step(acc, xw):
        Xc, wc = xw
        Kc = (kops.kernel_matrix(Xq, Xc, kern, compute_dtype=compute_dtype)
              if use_pallas
              else kern.pairwise(Xq, Xc, compute_dtype=compute_dtype))
        return acc + Kc @ wc, None

    out, _ = jax.lax.scan(
        step, jnp.zeros((Xq.shape[0], W.shape[1]), Xq.dtype),
        (Xsp.reshape(-1, chunk, d), Wp.reshape(-1, chunk, W.shape[1])))
    return out


def _is_regression(model) -> bool:
    task = getattr(model, "task", None)
    return bool(task is not None and task.is_regression)


def _offset(model) -> float:
    """Decision offset rho of equality-constrained tasks (one-class SVM:
    f(x) = sum_i beta_i K(x_i, x) - rho); 0 for every box-family task."""
    rho = getattr(model, "rho", None)
    return 0.0 if rho is None else float(rho)


def _labels(model, d: Array) -> Array:
    """Decision values -> predictions: raw values for regression, +/-1 for
    classification.  One-class models threshold with ``d >= 0 -> +1``
    (inlier), matching ``serve_batch``'s ocsvm path exactly — ``jnp.sign``
    would emit 0 for boundary points (f(x) == rho) and the two sides of the
    serving round trip would disagree on them."""
    if _is_regression(model):
        return d
    task = getattr(model, "task", None)
    if task is not None and getattr(task, "has_rho_offset", False):
        return jnp.where(d >= 0, 1.0, -1.0).astype(d.dtype)
    return jnp.sign(d)


def decision_exact(model: DCSVMModel, Xq: Array, chunk: int = 4096,
                   use_pallas: Optional[bool] = None) -> Array:
    """f(x) = sum_i beta_i K(x_i, x) over all support vectors (eq. 10 when
    alpha is a level-l solution); task-uniform through ``model.weights``.
    Pallas path: one streaming ``kernel_matvec`` call — the (nq, |S|)
    kernel block never hits HBM; otherwise a single fused scan over SV
    chunks."""
    sv = model.sv_index
    off = _offset(model)
    if len(sv) == 0:
        return jnp.zeros(Xq.shape[0], Xq.dtype) - off
    if use_pallas is None:
        use_pallas = model.config.use_pallas
    Xs = model.X[jnp.asarray(sv)]
    w = model.weights[jnp.asarray(sv)]
    kern = model.config.kernel
    cd = getattr(model.config, "compute_dtype", None)
    if resolve_use_pallas(use_pallas):
        from repro.kernels import ops as kops

        return kops.kernel_matvec(Xq, Xs, w, kern,
                                  compute_dtype=cd).astype(Xq.dtype) - off
    return _decision_scan(kern, Xq, Xs, w[:, None], chunk,
                          compute_dtype=cd)[:, 0] - off


def predict_exact(model: DCSVMModel, Xq: Array) -> Array:
    """Class labels for classification tasks; raw regression values for
    epsilon-SVR (the decision function IS the prediction)."""
    return _labels(model, decision_exact(model, Xq))


def _early_blocks(model, w: Array):
    """Per-cluster member blocks (k, nc, d) and weights (k, nc, C) for a
    partitioned model; ``w`` is (n,) or (n, C)."""
    part = model.partition
    members = jnp.asarray(np.maximum(part.idx, 0))           # (k, nc)
    mmask = jnp.asarray(part.mask)
    Xm = model.X[members]                                    # (k, nc, d)
    if w.ndim == 1:
        w = w[:, None]
    wm = jnp.where(mmask[..., None], w[members], 0.0)        # (k, nc, C)
    return Xm, wm


def early_capacity(nq: int, k: int) -> int:
    """Query-buffer slots per cluster: 2x the balanced load.  Overflow past
    this capacity is handled by extra on-device rounds, never dropped.

    ``cap`` is a STATIC argument of the fused early program — every distinct
    value is a fresh jit signature and a fresh compile.  Serving paths must
    therefore derive it from a padded bucket size (``bucket_size``), never
    from the live ragged batch size: feeding raw ``Xq.shape[0]`` here is
    exactly the per-batch-size recompile bug the bucketed serving path
    exists to fix."""
    return int(min(nq, max(8, -(-2 * nq // k))))


def bucket_size(nq: int, lo: int = 8, hi: int = 4096) -> int:
    """Pad bucket for a ragged request batch: the smallest power of two
    >= ``nq``, clamped below by ``lo``; batches past ``hi`` round up to a
    multiple of ``hi``.  Ragged arrival sizes collapse onto O(log hi)
    distinct (batch, cap) jit signatures, so the serving caches stay warm
    forever once each bucket has compiled."""
    if nq <= 0:
        return lo
    if nq > hi:
        return -(-nq // hi) * hi
    return max(lo, 1 << (nq - 1).bit_length())


def decision_early(model: DCSVMModel, Xq: Array,
                   use_pallas: Optional[bool] = None) -> Array:
    """Paper eq. 11: nearest-cluster routing + local-model scoring.

    Vectorized MoE-style dispatch (the same compute shape as our MoE layer):
    route every query to its cluster, sort queries by cluster id, batch each
    cluster's queries against ONLY that cluster's members — one vmapped
    kernel matvec, total work O(nq * (n/k) * d) = the paper's 1/k serving
    win.  On the Pallas path each cluster's scoring streams through the
    fused ``kernel_matvec`` kernel (vmapped over clusters).

    Routing and scoring run as ONE compiled program; queries overflowing a
    cluster's buffer capacity are handled by extra rounds of the same
    program inside the device-side loop (see ``bucketed_cluster_scores``) —
    no host sync on any path.
    """
    part = model.partition
    assert part is not None, "early prediction requires a partitioned model"
    kern = model.config.kernel
    if use_pallas is None:
        use_pallas = model.config.use_pallas
    use_pallas = resolve_use_pallas(use_pallas)
    Xm, wm = _early_blocks(model, model.weights)
    cap = early_capacity(Xq.shape[0], part.k)
    # early-stopped equality models: each cluster's local sub-QP carries its
    # own multiplier, so the offset is per assigned cluster, not global
    rho_c = getattr(model, "rho_clusters", None)
    offsets = None if rho_c is None else jnp.asarray(rho_c)[:, None]
    off = 0.0 if offsets is not None else _offset(model)
    return _early_program(kern, Xq, part.model, Xm, wm, cap,
                          use_pallas=use_pallas, offsets=offsets,
                          compute_dtype=getattr(model.config, "compute_dtype",
                                                None))[:, 0] - off


def predict_early(model: DCSVMModel, Xq: Array) -> Array:
    return _labels(model, decision_early(model, Xq))


def decision_bcm(model: DCSVMModel, Xq: Array, noise: float = 1e-2,
                 max_sv_per_cluster: int = 512) -> Array:
    """BCM combination of the k local models (paper's Table-1 baseline).

    Each cluster contributes its local decision f_c(x) weighted by the
    inverse GP predictive variance sigma_c^2(x) = K(x,x) - k_c' (K_cc +
    noise I)^-1 k_c computed on (a subsample of) the cluster's support
    vectors.  Precision-weighted averaging follows Tresp (2000); we use the
    common precision-normalized form (the (k-1)/K(x,x) prior correction is
    absorbed into the normalization, which only rescales decisions and does
    not change the sign/accuracy).

    Equality-family offsets are applied PER COMMITTEE MEMBER before the
    combination: an early-stopped one-class model's clusters carry their
    own multipliers rho_c, so member c contributes f_c(x) - rho_c (a
    globally trained model's members share the one global rho).
    """
    W = model.weights[:, None]
    active = np.asarray(model.weights) != 0
    rho_c = getattr(model, "rho_clusters", None)
    if rho_c is not None:
        offsets = np.asarray(rho_c, np.float64)
    else:
        offsets = np.full(model.partition.k, _offset(model))
    scores = _bcm_scores(model, Xq, W, active, noise, max_sv_per_cluster,
                         offsets=offsets)
    return scores[:, 0]


def _bcm_scores(model, Xq: Array, W: Array, active: np.ndarray, noise: float,
                max_sv_per_cluster: int,
                offsets: Optional[np.ndarray] = None) -> Array:
    """Shared BCM combination: W is (n, C) decision weights, ``active`` marks
    the support vectors eligible per cluster.  The GP predictive variance is
    label-independent, so one variance per cluster weights all C outputs.
    ``offsets`` (k,) is subtracted from cluster c's local decision before
    the precision weighting (equality-family rho_c; None = no offsets)."""
    part = model.partition
    assert part is not None
    kern = model.config.kernel
    nq = Xq.shape[0]
    num = np.zeros((nq, W.shape[1]), np.float64)
    den = np.zeros((nq, 1), np.float64) + 1e-12
    W_np = np.asarray(W)
    for c in range(part.k):
        members = part.idx[c][part.mask[c]]
        sv = members[active[members]]
        if len(sv) == 0:
            continue
        if len(sv) > max_sv_per_cluster:
            sv = sv[:: len(sv) // max_sv_per_cluster + 1]
        Xs = model.X[jnp.asarray(sv)]
        Kss = np.asarray(gram(kern, Xs, Xs)) + noise * np.eye(len(sv))
        Kqs = np.asarray(gram(kern, Xq, Xs))
        f_c = Kqs @ W_np[sv]                                  # (nq, C)
        if offsets is not None:
            f_c = f_c - offsets[c]
        sol = np.linalg.solve(Kss, Kqs.T)                     # (s, nq)
        var = np.asarray(kern.diag(Xq)) - np.einsum("qs,sq->q", Kqs, sol)
        var = np.maximum(var, noise)[:, None]
        num += f_c / var
        den += 1.0 / var
    return jnp.asarray((num / den).astype(np.float32))


def predict_bcm(model: DCSVMModel, Xq: Array) -> Array:
    return _labels(model, decision_bcm(model, Xq))


def accuracy(y_true: Array, y_pred: Array) -> float:
    return float(jnp.mean((jnp.sign(y_true) == jnp.sign(y_pred)).astype(jnp.float32)))


def mse(y_true: Array, y_pred: Array) -> float:
    """Mean squared error (regression tasks)."""
    return float(jnp.mean((jnp.asarray(y_true) - jnp.asarray(y_pred)) ** 2))


def mae(y_true: Array, y_pred: Array) -> float:
    """Mean absolute error (regression tasks)."""
    return float(jnp.mean(jnp.abs(jnp.asarray(y_true) - jnp.asarray(y_pred))))


def recall(y_true: Array, y_pred: Array, label: float = 1.0) -> float:
    """Recall of one class (minority-class metric for weighted C-SVC)."""
    t = np.asarray(y_true) == label
    if not t.any():
        return float("nan")
    return float(np.mean(np.asarray(y_pred)[t] == label))


def precision(y_true: Array, y_pred: Array, label: float = 1.0) -> float:
    """Precision of one class (anomaly metric: label=-1 for outliers)."""
    p = np.asarray(y_pred) == label
    if not p.any():
        return float("nan")
    return float(np.mean(np.asarray(y_true)[p] == label))


def f1(y_true: Array, y_pred: Array, label: float = 1.0) -> float:
    """F1 of one class — the anomaly-detection headline metric for
    one-class SVM (label=-1 marks outliers)."""
    t = np.asarray(y_true) == label
    p = np.asarray(y_pred) == label
    tp = float(np.sum(t & p))
    denom = 2.0 * tp + float(np.sum(~t & p)) + float(np.sum(t & ~p))
    return 0.0 if denom == 0 else 2.0 * tp / denom


# ---------------------------------------------------------------------------
# One-vs-all (multiclass) variants: per-class decision values + argmax.
# ``model`` is a core.multiclass.MulticlassModel (duck-typed: needs config,
# X, Y (n_classes, n), alpha (n_classes, n), classes, partition, sv_union).
# ---------------------------------------------------------------------------

def _ova_weights(model) -> Array:
    """(n, n_classes) decision weights: column c is alpha_c * y_c."""
    return (model.alpha * model.Y).T


def decision_exact_ova(model, Xq: Array, chunk: int = 4096,
                       use_pallas: Optional[bool] = None) -> Array:
    """(nq, n_classes) exact decision values over the SV union — one shared
    kernel evaluation per (query, SV) pair serves every class (the class
    axis is a plain matmul against the stacked weight columns)."""
    sv = model.sv_union
    n_cls = model.Y.shape[0]
    if len(sv) == 0:
        return jnp.zeros((Xq.shape[0], n_cls), Xq.dtype)
    if use_pallas is None:
        use_pallas = model.config.use_pallas
    Xs = model.X[jnp.asarray(sv)]
    Ws = _ova_weights(model)[jnp.asarray(sv)]                # (ns, n_classes)
    kern = model.config.kernel
    return _decision_scan(kern, Xq, Xs, Ws, chunk,
                          use_pallas=resolve_use_pallas(use_pallas),
                          compute_dtype=getattr(model.config, "compute_dtype",
                                                None))


def decision_early_ova(model, Xq: Array,
                       use_pallas: Optional[bool] = None) -> Array:
    """Eq.-11 early prediction for one-vs-all: each query is routed ONCE and
    all n_classes local machines score it against the same gathered cluster
    block (the kernel rows are shared; only the weight columns differ)."""
    part = model.partition
    assert part is not None, "early prediction requires a partitioned model"
    if use_pallas is None:
        use_pallas = model.config.use_pallas
    use_pallas = resolve_use_pallas(use_pallas)
    Xm, wm = _early_blocks(model, _ova_weights(model))
    cap = early_capacity(Xq.shape[0], part.k)
    return _early_program(model.config.kernel, Xq, part.model, Xm, wm, cap,
                          use_pallas=use_pallas,
                          compute_dtype=getattr(model.config, "compute_dtype",
                                                None))


def decision_bcm_ova(model, Xq: Array, noise: float = 1e-2,
                     max_sv_per_cluster: int = 512) -> Array:
    """BCM combination for one-vs-all — the per-cluster GP variance is
    label-independent, so one variance weighting serves all classes."""
    active = np.any(np.asarray(model.alpha) > 0, axis=0)
    return _bcm_scores(model, Xq, _ova_weights(model), active, noise,
                       max_sv_per_cluster)


def _argmax_classes(model, scores: Array) -> Array:
    return jnp.asarray(model.classes)[jnp.argmax(scores, axis=1)]


def predict_exact_ova(model, Xq: Array) -> Array:
    return _argmax_classes(model, decision_exact_ova(model, Xq))


def predict_early_ova(model, Xq: Array) -> Array:
    return _argmax_classes(model, decision_early_ova(model, Xq))


def predict_bcm_ova(model, Xq: Array) -> Array:
    return _argmax_classes(model, decision_bcm_ova(model, Xq))


def accuracy_multiclass(y_true, y_pred) -> float:
    return float(np.mean(np.asarray(y_true) == np.asarray(y_pred)))
