"""Unified kernel-operator layer: one Gram abstraction for every consumer.

Every layer that touches kernel elements — the divide step's cluster Grams,
the conquer solvers' row blocks and matvecs, the distributed per-device
residency, the column cache, and serving's bucketed scores — routes through
a ``GramOperator``: kernel + data + precision policy + memory tiers in one
pytree, so precision, chunking, and caching are decided in exactly one place
(DESIGN.md §12).

Three concerns live here:

1. **Precision policy** (``compute_dtype``).  ``None`` (the default) keeps
   every computation bit-identical to the pre-policy code path.  A low
   precision like ``"bfloat16"`` casts the *matmul operand tiles* only —
   accumulation stays f32 via ``preferred_element_type`` and the kernel
   transform (exp / polynomial) runs in f32, exactly the
   ``kernels/flash_attention.py`` idiom.  The relative tile error is then
   bounded by the bf16 mantissa (2^-8) on the Gram inner products, not
   amplified by the length-d reduction.

2. **Memory hierarchy** (``solve_box_qp_spill``).  Kernel rows are panelized
   into device-budget-sized tiles: device panel LRU (tier 1) over pinned
   host-RAM numpy buffers (tier 2, written through on first compute), with a
   double-buffered async ``jax.device_put`` so the copy of the next panel
   overlaps the current panel's jitted block-CD sub-solve.  Gram size is
   therefore bounded by *host* RAM, not device memory — the out-of-core
   regime the ROADMAP item calls for.

3. **Base-indexed Gram view** (``Xb``/``bidx``).  Tasks with duplicated dual
   rows (epsilon-SVR's stacked (alpha, alpha*) mirror) dedup kernel storage
   to the n base rows: cached/spilled rows are *raw* kernel rows of width
   ``n_base`` and the task signs expand at read time via
   ``Q[i, j] = s_i * K[i, bidx_j] * s_j`` (multiplication by +/-1 is exact,
   so the expansion is bit-transparent).  That is a 4x cluster-level Gram
   saving and a 2x row-cache saving for SVR.

All budgets are denominated in BYTES (``DEFAULT_GRAM_BUDGET``), so bf16
storage really does fit twice the rows of f32.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.kernels import (DEFAULT_GRAM_BUDGET, Kernel, auto_num_chunks,
                                gram_matvec)

Array = jax.Array


def fits_budget(n_elems: int, budget_bytes: int, dtype=jnp.float32) -> bool:
    """Does an ``n_elems``-element buffer of ``dtype`` fit ``budget_bytes``?
    The one predicate behind every Gram-residency decision (dense cluster
    batches, per-device shard residency, cache sizing)."""
    return int(n_elems) * jnp.dtype(dtype).itemsize <= int(budget_bytes)


def resolve_compute_dtype(compute_dtype, ref_dtype) -> Optional[str]:
    """Normalize the precision policy: ``None`` — or a dtype equal to the
    data's own — means "no cast", keeping the exact pre-policy jaxpr."""
    if compute_dtype is None:
        return None
    cd = jnp.dtype(compute_dtype)
    if cd == jnp.dtype(ref_dtype):
        return None
    return str(cd)


@dataclasses.dataclass(frozen=True)
class GramOperator:
    """Kernel + dual data + precision policy + base-index dedup, as a pytree.

    ``Xd`` (n_dual, d) are the task's dual points and ``s`` (n_dual,) its
    sign vector, defining ``Q = (s s') ∘ K(Xd, Xd)``.  When ``Xb``/``bidx``
    are set (``Xd == Xb[bidx]`` row-for-row), kernel rows are computed and
    stored against the ``n_base`` base rows only and sign-expanded at read.
    ``kernel``/``use_pallas``/``compute_dtype``/``budget_bytes`` are static
    (pytree aux data), so the operator can cross ``jax.jit`` boundaries and
    be ``dataclasses.replace``d per class row inside a ``vmap``.
    """

    Xd: Array
    s: Array
    Xb: Optional[Array] = None
    bidx: Optional[Array] = None
    kernel: Kernel = Kernel("rbf", gamma=1.0)
    use_pallas: bool = False
    compute_dtype: Optional[str] = None
    budget_bytes: int = DEFAULT_GRAM_BUDGET

    # -- structure --------------------------------------------------------
    @property
    def n_dual(self) -> int:
        return self.Xd.shape[0]

    @property
    def dedup(self) -> bool:
        return self.bidx is not None

    @property
    def kwidth(self) -> int:
        """Width of a raw kernel row — the cache/spill storage unit."""
        return self.Xb.shape[0] if self.dedup else self.n_dual

    def storage_dtype(self, acc):
        """Row-storage dtype for the cache/spill tiers: the compute dtype
        when a low-precision policy is active, else the accumulator's."""
        if self.compute_dtype is not None:
            return jnp.dtype(self.compute_dtype)
        return jnp.dtype(acc)

    def cache_keys(self, idx: Array) -> Array:
        """Cache key per selected dual coordinate: the base id under dedup
        (mirrored SVR coordinates share one cached row), else the
        coordinate itself."""
        return self.bidx[idx] if self.dedup else idx

    # -- kernel access ----------------------------------------------------
    def _cd(self) -> Optional[str]:
        return resolve_compute_dtype(self.compute_dtype, self.Xd.dtype)

    def kmat(self, A: Array, B: Array) -> Array:
        """Policy-tiled K(A, B) — Pallas kermat tiles or the XLA pairwise."""
        if self.use_pallas:
            from repro.kernels import ops as kops

            return kops.kernel_matrix(A, B, self.kernel,
                                      compute_dtype=self.compute_dtype)
        return self.kernel.pairwise(A, B, compute_dtype=self._cd())

    def kernel_rows(self, idx: Array) -> Array:
        """Raw (B, kwidth) kernel rows ``K(Xd[idx], base points)`` — the
        sign-free unit the column cache and the host-spill panels store."""
        pts = self.Xb if self.dedup else self.Xd
        return self.kmat(self.Xd[idx], pts)

    def expand_rows(self, kr: Array, idx: Array) -> Array:
        """Raw rows (B, kwidth) -> signed Q rows (B, n_dual): gather the
        base columns out to dual coordinates, then apply the task signs
        (exact: ``s`` is +/-1)."""
        cols = kr[:, self.bidx] if self.dedup else kr
        return self.s[idx][:, None] * (cols * self.s[None, :])

    def q_rows(self, idx: Array) -> Array:
        """Signed (B, n_dual) rows of Q for a selected block."""
        return self.expand_rows(self.kernel_rows(idx), idx)

    def q_block(self, idx: Array) -> Array:
        """Signed (n_dual, B) columns of Q (the XLA no-cache orientation)."""
        Xsel = self.Xd[idx]
        if self.dedup:
            Kb = self.kmat(self.Xb, Xsel)[self.bidx]
        else:
            Kb = self.kmat(self.Xd, Xsel)
        return (self.s[:, None] * self.s[idx][None, :]) * Kb

    def qbb(self, idx: Array) -> Array:
        """The (B, B) working-set block of Q."""
        Xsel, ssel = self.Xd[idx], self.s[idx]
        Kbb = self.kernel.pairwise(Xsel, Xsel, compute_dtype=self._cd())
        return (ssel[:, None] * ssel[None, :]) * Kbb

    def qdiag(self) -> Array:
        return self.s * self.s * self.kernel.diag(self.Xd)

    def matvec(self, v: Array, num_chunks: Optional[int] = None,
               via_base: bool = False) -> Array:
        """Q @ v without materializing Q.  ``via_base=True`` collapses the
        weights onto the base rows first (an n_base-sized matvec — 4x fewer
        kernel evaluations for SVR, at the cost of a re-associated sum), and
        is opt-in so the default path stays bit-identical to the historical
        full-width matvec."""
        if via_base and self.dedup:
            w = jnp.zeros(self.Xb.shape[0], v.dtype).at[self.bidx].add(
                self.s * v)
            kv = gram_matvec(self.kernel, self.Xb, w, num_chunks=num_chunks,
                             use_pallas=self.use_pallas,
                             compute_dtype=self.compute_dtype,
                             budget_bytes=self.budget_bytes)
            return self.s * kv[self.bidx]
        return self.s * gram_matvec(self.kernel, self.Xd, self.s * v,
                                    num_chunks=num_chunks,
                                    use_pallas=self.use_pallas,
                                    compute_dtype=self.compute_dtype,
                                    budget_bytes=self.budget_bytes)

    def col_update(self, g: Array, idx: Array, delta: Array) -> Array:
        """g += Q[:, idx] @ delta — the rank-B gradient update.  Fused
        Pallas ``cd_column_update`` (the (n, B) block never leaves VMEM) on
        the Pallas path, on-the-fly column matmul on XLA."""
        Xsel, ssel = self.Xd[idx], self.s[idx]
        if self.use_pallas:
            from repro.kernels import ops as kops

            if self.dedup:
                base = kops.cd_column_update(
                    self.Xb, jnp.ones(self.Xb.shape[0], self.Xd.dtype),
                    Xsel, ssel * delta, self.kernel,
                    compute_dtype=self.compute_dtype)
                return g + (self.s * base[self.bidx]).astype(g.dtype)
            return g + kops.cd_column_update(
                self.Xd, self.s, Xsel, ssel * delta, self.kernel,
                compute_dtype=self.compute_dtype).astype(g.dtype)
        Qb = self.q_block(idx).astype(g.dtype)
        return g + Qb @ delta


jax.tree_util.register_pytree_node(
    GramOperator,
    lambda op: ((op.Xd, op.s, op.Xb, op.bidx),
                (op.kernel, op.use_pallas, op.compute_dtype, op.budget_bytes)),
    lambda aux, kids: GramOperator(kids[0], kids[1], kids[2], kids[3],
                                   kernel=aux[0], use_pallas=aux[1],
                                   compute_dtype=aux[2], budget_bytes=aux[3]),
)


# ---------------------------------------------------------------------------
# Host-RAM spill tier: out-of-core block CD over kernel-row panels
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("block", "sweeps", "inner", "rows_p"))
def _panel_block_cd(op: GramOperator, tile: Array, pstart, alpha: Array,
                    g: Array, cvec: Array, tol, *, block: int, sweeps: int,
                    inner: int, rows_p: int):
    """Greedy block CD restricted to one device-resident panel of raw kernel
    rows.  ``tile`` is (rows_p, kwidth) in storage dtype; selection is
    Gauss-Southwell within the panel, the rank-B gradient update runs over
    ALL coordinates (sign expansion of the B selected raw rows), so the
    maintained global gradient stays exact across panel visits.

    Panels live in BASE-row space: under dedup a dual coordinate is
    in-panel when its *base id* is — so SVR's mirrored pair (i, i+n)
    always co-resides and the working set can move the strongly coupled
    pair jointly (panel-restricted CD would zigzag if the mirrors were
    split across panels)."""
    from repro.core.solver import _solve_small_qp, proj_grad

    n = alpha.shape[0]
    acc = g.dtype
    key = op.bidx if op.dedup else jnp.arange(n)
    in_panel = (key >= pstart) & (key < pstart + rows_p)

    def panel_pg(alpha, g):
        return jnp.max(jnp.where(in_panel,
                                 jnp.abs(proj_grad(alpha, g, cvec)), 0.0))

    def body(state):
        alpha, g, it, _ = state
        sc = jnp.where(in_panel, jnp.abs(proj_grad(alpha, g, cvec)),
                       -jnp.inf)
        _, sel = lax.top_k(sc, block)
        # the last panel may hold fewer than ``block`` coordinates: freeze
        # out-of-panel picks (box [0, 0]) so junk tile rows cannot move them
        valid = in_panel[sel]
        local = jnp.clip(key[sel] - pstart, 0, rows_p - 1)
        kr = tile[local].astype(acc)
        Qrows = op.expand_rows(kr, sel)                     # (B, n) signed
        ab = jnp.where(valid, alpha[sel], 0.0).astype(acc)
        cb = jnp.where(valid, cvec[sel], 0.0)
        new_ab = _solve_small_qp(Qrows[:, sel], g[sel], ab, cb, sweeps)
        delta = jnp.where(valid, new_ab - ab, 0.0)
        alpha = alpha.at[sel].add(delta.astype(alpha.dtype))
        g = g + delta @ Qrows
        return alpha, g, it + 1, panel_pg(alpha, g)

    def cond(state):
        _, _, it, pg = state
        return (pg > tol) & (it < inner)

    state0 = (alpha, g, jnp.zeros((), jnp.int32), panel_pg(alpha, g))
    alpha, g, it, _ = lax.while_loop(cond, body, state0)
    return alpha, g, it


def solve_box_qp_spill(
    op: GramOperator,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 500,
    block: int = 64,
    sweeps: int = 4,
    p=-1.0,
    device_budget_bytes: Optional[int] = None,
    max_rounds: int = 512,
    trace=None,
):
    """Out-of-core block CD for the box dual: Gram bounded by HOST memory.

    Raw kernel rows are computed once per panel (``rows_p`` rows sized to
    ``device_budget_bytes``), written through to a host-RAM numpy buffer
    (the spill tier) and served from a device panel LRU.  Each outer round
    is a Gauss-Seidel sweep over panels — a jitted within-panel block-CD
    sub-solve per panel, monotone in the global objective because the
    maintained gradient is exact — with the NEXT panel's host->device copy
    dispatched (async ``jax.device_put``) before the current sub-solve, so
    transfer overlaps compute.  After every sweep the gradient is recomputed
    from scratch (one streaming matvec) and convergence is judged on the
    full projected gradient, identical to the in-memory solver's criterion.

    Counter semantics on the returned ``SolveResult`` (panel units):
    ``cache_hits``/``cache_misses`` = device-tier panel hits / panels
    computed, ``cache_evictions`` = device panels dropped, ``spills`` =
    panels written to the host tier, ``spill_hits`` = panels re-loaded from
    it.

    ``trace`` (an ``obs.trace.ConvTrace``) records one sample per OUTER
    round at the fresh-gradient refresh — pg_max, objective, free-set size
    and the round's device-panel-hit delta.  Unlike the in-memory solvers
    this loop already host-syncs each round on ``pg`` by design, so the
    samples are recorded host-side at the same sync point; ``None`` is a
    strict no-op.
    """
    from repro.core.solver import (SolveResult, _broadcast, _n_free,
                                   objective, proj_grad)
    from repro.obs.spans import span
    from repro.obs.trace import trace_record

    X = op.Xd
    n = op.n_dual
    acc = jnp.promote_types(X.dtype, jnp.float32)
    budget = (op.budget_bytes if device_budget_bytes is None
              else int(device_budget_bytes))
    store = op.storage_dtype(acc)
    nb = op.kwidth                  # panel row space: base ids under dedup
    row_bytes = nb * jnp.dtype(store).itemsize
    block = max(1, min(block, n))
    rows_p = int(max(block, min(nb, budget // max(row_bytes, 1))))
    starts = list(range(0, nb, rows_p))
    cap_panels = max(1, budget // max(rows_p * row_bytes, 1))
    inner = max(4, rows_p // block)

    alpha = (jnp.zeros(n, X.dtype) if alpha0 is None
             else jnp.asarray(alpha0, X.dtype))
    cvec = _broadcast(C, n, X.dtype)
    pvec = _broadcast(p, n, X.dtype)

    def fresh_grad(alpha):
        return (op.matvec(alpha, via_base=op.dedup) + pvec).astype(acc)

    g = fresh_grad(alpha)
    host: dict = {}
    dev: OrderedDict = OrderedDict()
    hits = misses = evictions = spills = spill_hits = 0

    def evict_to(cap):
        nonlocal evictions
        while len(dev) > cap:
            dev.popitem(last=False)
            evictions += 1

    def fetch(pid):
        nonlocal hits, misses, spills, spill_hits
        if pid in dev:
            dev.move_to_end(pid)
            hits += 1
            return dev[pid]
        with span("spill/fetch_panel"):
            if pid in host:
                tile = jax.device_put(host[pid])
                spill_hits += 1
            else:
                idxp = jnp.clip(starts[pid] + jnp.arange(rows_p), 0, nb - 1)
                pts = op.Xb if op.dedup else op.Xd
                tile = op.kmat(pts[idxp], pts).astype(store)
                host[pid] = np.asarray(tile)      # write-through host spill
                spills += 1
                misses += 1
        dev[pid] = tile
        evict_to(cap_panels)
        return tile

    it_total = 0
    pg = float(jnp.max(jnp.abs(proj_grad(alpha, g, cvec))))
    rounds = 0
    hits_mark = 0
    while pg > tol and it_total < max_iters and rounds < max_rounds:
        for pid in range(len(starts)):
            tile = fetch(pid)
            nxt = (pid + 1) % len(starts)
            if len(starts) > 1 and nxt not in dev and nxt in host:
                # double buffer: device_put dispatches without blocking, so
                # the next panel's copy overlaps this panel's sub-solve
                dev[nxt] = jax.device_put(host[nxt])
                spill_hits += 1
                evict_to(cap_panels + 1)
            with span("spill/panel_solve"):
                alpha, g, its = _panel_block_cd(
                    op, tile, jnp.int32(starts[pid]), alpha, g, cvec, tol,
                    block=block, sweeps=sweeps, inner=inner, rows_p=rows_p)
                it_total += int(its)
            if it_total >= max_iters:
                break
        # refresh from scratch: panel sweeps keep the gradient exact in
        # infinite precision, but rounding drift accumulates over rounds
        g = fresh_grad(alpha)
        pg = float(jnp.max(jnp.abs(proj_grad(alpha, g, cvec))))
        rounds += 1
        if trace is not None:
            # this loop host-syncs on pg every round anyway; the sample
            # rides the same sync point (panel units for the hit delta)
            trace = trace_record(trace, pg_max=pg,
                                 objective=objective(alpha, g, pvec),
                                 n_free=_n_free(alpha, cvec),
                                 cache_hits=hits - hits_mark)
            hits_mark = hits

    i32 = lambda v: jnp.asarray(v, jnp.int32)
    return SolveResult(alpha, g, i32(it_total), jnp.asarray(pg, acc),
                       cache_hits=i32(hits), cache_misses=i32(misses),
                       cache_evictions=i32(evictions), spills=i32(spills),
                       spill_hits=i32(spill_hits), trace=trace)
