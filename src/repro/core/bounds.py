"""Theorem 1 / Theorem 3 quantities (paper Sec. 3-4, Figure 1).

* D(pi)            — off-diagonal kernel mass across clusters (Thm 1)
* D_{S}(pi)        — the same restricted to an index set S (Thm 3)
* theorem1_bound   — (1/2) C^2 D(pi), the upper bound on f(a-bar) - f(a*)
* theorem2_margin  — the gradient threshold above which a subproblem non-SV
                     is provably a non-SV of the full problem
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, gram, offdiag_mass

Array = jax.Array


def d_pi(kernel: Kernel, X: Array, assign: Array, num_chunks: int = 8) -> Array:
    """D(pi) = sum over cross-cluster pairs of |K(x_i, x_j)|."""
    return offdiag_mass(kernel, X, jnp.asarray(assign), num_chunks=num_chunks)


def d_pi_subset(kernel: Kernel, X: Array, assign: Array, subset: Array) -> Array:
    """Theorem-3 restriction: D over pairs within ``subset`` only."""
    Xs = X[subset]
    ls = jnp.asarray(assign)[subset]
    Ks = jnp.abs(gram(kernel, Xs, Xs))
    cross = ls[:, None] != ls[None, :]
    return jnp.sum(Ks * cross)


def theorem1_bound(kernel: Kernel, X: Array, assign: Array, C: float) -> float:
    return float(0.5 * C * C * d_pi(kernel, X, assign))


def theorem3_bound(kernel: Kernel, X: Array, assign: Array, C: float, subset: Array) -> float:
    return float(0.5 * C * C * d_pi_subset(kernel, X, assign, subset))


def theorem2_margin(kernel: Kernel, X: Array, assign: Array, C: float,
                    sigma_n: float) -> float:
    """C D(pi) (1 + sqrt(n) K_max / sqrt(sigma_n D(pi))).

    sigma_n is the smallest eigenvalue of the kernel matrix (caller supplies;
    computing it exactly is O(n^3) so tests use small n or a lower bound).
    """
    n = X.shape[0]
    D = float(d_pi(kernel, X, assign))
    if D <= 0.0:
        return 0.0
    return C * D * (1.0 + np.sqrt(n) * kernel.k_max / np.sqrt(sigma_n * D))
