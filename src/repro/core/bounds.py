"""Theorem 1 / Theorem 3 quantities (paper Sec. 3-4, Figure 1).

* D(pi)            — off-diagonal kernel mass across clusters (Thm 1)
* D_{S}(pi)        — the same restricted to an index set S (Thm 3)
* theorem1_bound   — (1/2) C^2 D(pi), the upper bound on f(a-bar) - f(a*)
* theorem2_margin  — the gradient threshold above which a subproblem non-SV
                     is provably a non-SV of the full problem
* oneclass_early_gap_bound — |f_early - f| bound for eq.-11 one-class
                     serving in terms of D(pi), sigma_n, the cross-cluster
                     kernel mass at the query, and the rho_c spread
                     (pinned by benchmarks/bench_oneclass.py and
                     tests/test_oneclass_nusvm.py)
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, gram, offdiag_mass

Array = jax.Array


def d_pi(kernel: Kernel, X: Array, assign: Array, num_chunks: int = 8) -> Array:
    """D(pi) = sum over cross-cluster pairs of |K(x_i, x_j)|."""
    return offdiag_mass(kernel, X, jnp.asarray(assign), num_chunks=num_chunks)


def d_pi_subset(kernel: Kernel, X: Array, assign: Array, subset: Array) -> Array:
    """Theorem-3 restriction: D over pairs within ``subset`` only."""
    Xs = X[subset]
    ls = jnp.asarray(assign)[subset]
    Ks = jnp.abs(gram(kernel, Xs, Xs))
    cross = ls[:, None] != ls[None, :]
    return jnp.sum(Ks * cross)


def theorem1_bound(kernel: Kernel, X: Array, assign: Array, C: float) -> float:
    return float(0.5 * C * C * d_pi(kernel, X, assign))


def theorem3_bound(kernel: Kernel, X: Array, assign: Array, C: float, subset: Array) -> float:
    return float(0.5 * C * C * d_pi_subset(kernel, X, assign, subset))


def oneclass_early_gap_bound(kernel: Kernel, X: Array, assign: Array,
                             alpha_early: Array, rho: float,
                             rho_clusters: Array, Xq: Array, cid_q: Array,
                             sigma_n: float,
                             alpha_exact: Optional[Array] = None) -> dict:
    """Bound on the one-class early-prediction error |f_early(x) - f(x)|
    (ROADMAP item: Lemma-1 translated to the equality family).

    With ``abar`` the concatenated per-cluster solution (the early model),
    ``a*`` the full optimum, and ``c = c(x)`` the routed cluster,

        f_early(x) - f(x) = sum_i (abar_i - a*_i) K(x_i, x)
                            - sum_{i not in c} abar_i K(x_i, x)
                            + (rho - rho_c),

    so per query

        |f_early - f| <= ||abar - a*||_2 ||K(., x)||_2        (term_drift)
                         + sum_{i not in c} abar_i |K(x_i,x)|  (term_cross)
                         + max_c |rho_c - rho|                 (term_rho).

    Theorem 1 (C = 1 for the libsvm one-class box) gives the a-priori drift
    bound ``||abar - a*||_2 <= sqrt(D(pi) / sigma_n)`` via sigma_n-strong
    convexity, hence ``term_drift <= k_max sqrt(n) sqrt(D(pi)/sigma_n)``;
    it is loose exactly where Theorem 1 is (sigma_n of an RBF Gram is
    tiny).  When ``alpha_exact`` is given, the dict also carries the
    semi-empirical ``bound_measured`` that replaces the Theorem-1 estimate
    with the measured ``||abar - a*||_2`` — the quantity the benchmark
    reports for tightness.  Both are valid upper bounds; the fixed-seed
    test asserts both hold.
    """
    Kq = np.abs(np.asarray(gram(kernel, Xq, X), np.float64))    # (nq, n)
    abar = np.asarray(alpha_early, np.float64)
    assign_n = np.asarray(assign)
    cid_n = np.asarray(cid_q)
    out_of_cluster = assign_n[None, :] != cid_n[:, None]        # (nq, n)
    term_cross = float(np.max(np.sum(Kq * abar[None, :] * out_of_cluster,
                                     axis=1)))
    D = float(d_pi(kernel, X, assign))
    n = X.shape[0]
    sigma_n = max(float(sigma_n), 1e-12)
    knorm = kernel.k_max * np.sqrt(n)
    term_drift = float(knorm * np.sqrt(max(D, 0.0) / sigma_n))
    rho_c = np.asarray(rho_clusters, np.float64)
    term_rho = float(np.max(np.abs(rho_c - float(rho))))
    out = {
        "term_cross": term_cross,
        "term_drift": term_drift,
        "term_rho": term_rho,
        "d_pi": D,
        "sigma_n": sigma_n,
        "bound": term_cross + term_drift + term_rho,
    }
    if alpha_exact is not None:
        drift = float(np.linalg.norm(abar - np.asarray(alpha_exact,
                                                       np.float64)))
        out["alpha_drift_l2"] = drift
        out["term_drift_measured"] = float(knorm * drift)
        out["bound_measured"] = out["term_drift_measured"] + term_cross \
            + term_rho
    return out


def theorem2_margin(kernel: Kernel, X: Array, assign: Array, C: float,
                    sigma_n: float) -> float:
    """C D(pi) (1 + sqrt(n) K_max / sqrt(sigma_n D(pi))).

    sigma_n is the smallest eigenvalue of the kernel matrix (caller supplies;
    computing it exactly is O(n^3) so tests use small n or a lower bound).
    """
    n = X.shape[0]
    D = float(d_pi(kernel, X, assign))
    if D <= 0.0:
        return 0.0
    return C * D * (1.0 + np.sqrt(n) * kernel.k_max / np.sqrt(sigma_n * D))
