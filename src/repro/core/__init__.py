# DC-SVM core: the paper's primary contribution as a composable JAX module.
from repro.core import colcache
from repro.core.kernels import (
    Kernel,
    gram,
    gram_matvec,
    offdiag_mass,
    resolve_use_pallas,
    sqdist,
)
from repro.core.solver import (
    SolveResult,
    kkt_residual,
    objective,
    proj_grad,
    solve_box_qp,
    solve_box_qp_block,
    solve_box_qp_matvec,
    solve_with_shrinking,
)
from repro.core.kkmeans import (
    KKMeansModel,
    Partition,
    assign_points,
    balanced_assign,
    kernel_kmeans,
    route,
    two_step_kernel_kmeans,
)
from repro.core.tasks import (
    CSVC,
    EpsilonSVR,
    Task,
    TaskDual,
    WeightedCSVC,
    resolve_task,
)
from repro.core.dcsvm import DCSVMConfig, DCSVMModel, fit, objective_value
from repro.core.multiclass import MulticlassModel, fit_ova, labels_to_ova
from repro.core.predict import (
    accuracy,
    accuracy_multiclass,
    bucketed_cluster_scores,
    decision_bcm,
    decision_bcm_ova,
    decision_early,
    decision_early_ova,
    decision_exact,
    decision_exact_ova,
    early_capacity,
    mae,
    mse,
    predict_bcm,
    predict_bcm_ova,
    predict_early,
    predict_early_ova,
    predict_exact,
    predict_exact_ova,
    recall,
)
from repro.core import bounds
