# DC-SVM core: the paper's primary contribution as a composable JAX module.
from repro.core import colcache
from repro.core.kernels import (
    DEFAULT_GRAM_BUDGET,
    Kernel,
    auto_num_chunks,
    gram,
    gram_matvec,
    offdiag_mass,
    resolve_use_pallas,
    sqdist,
)
from repro.core.gramop import (
    GramOperator,
    fits_budget,
    solve_box_qp_spill,
)
from repro.core.solver import (
    SolveResult,
    equality_interval,
    equality_interval_grouped,
    equality_rho,
    equality_rho_grouped,
    kkt_residual,
    kkt_residual_eq,
    objective,
    proj_grad,
    project_box_equality,
    solve_box_qp,
    solve_box_qp_block,
    solve_box_qp_matvec,
    solve_eq_qp,
    solve_eq_qp_block,
    solve_eq_qp_matvec,
    solve_eq_qp_shrink,
    solve_with_shrinking,
)
from repro.core.kkmeans import (
    KKMeansModel,
    Partition,
    assign_points,
    balanced_assign,
    kernel_kmeans,
    route,
    two_step_kernel_kmeans,
)
from repro.core.tasks import (
    CSVC,
    EpsilonSVR,
    NuSVC,
    OneClassSVM,
    Task,
    TaskDual,
    WeightedCSVC,
    resolve_task,
)
from repro.core.dcsvm import DCSVMConfig, DCSVMModel, fit, objective_value
from repro.core.multiclass import (
    MulticlassModel,
    fit_ova,
    labels_to_ova,
    ova_cost_vectors,
)
from repro.core.predict import (
    accuracy,
    accuracy_multiclass,
    bucketed_cluster_scores,
    decision_bcm,
    decision_bcm_ova,
    decision_early,
    decision_early_ova,
    decision_exact,
    decision_exact_ova,
    early_capacity,
    f1,
    mae,
    mse,
    precision,
    predict_bcm,
    predict_bcm_ova,
    predict_early,
    predict_early_ova,
    predict_exact,
    predict_exact_ova,
    recall,
)
from repro.core import bounds
