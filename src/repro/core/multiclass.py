"""Multiclass one-vs-all DC-SVM with a shared partition (DCSVM, arXiv:1810.09828).

One-vs-all trains ``n_classes`` binary machines, class c against the rest.
The divide step is label-independent — kernel kmeans only looks at X — so a
single partition (and a single per-cluster Gram) is shared by every class:
``fit_ova`` stacks the per-class +/-1 label vectors into a (n_classes, n)
matrix and the extended ``_solve_clusters`` / ``_solve_full`` solve all
``n_classes * k^l`` sub-QPs of a level in ONE vmapped CD call.

The trained ``MulticlassModel`` carries alpha as (n_classes, n); prediction
is argmax over the per-class decision values (``repro.core.predict``'s
``*_ova`` variants), including the paper's eq.-11 early (clustered) serving
path, which routes each query once and scores all classes against the same
gathered cluster block.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dcsvm import DCSVMConfig, DCSVMModel, _fit_algorithm1
from repro.core.kkmeans import Partition
from repro.core.tasks import CSVC

Array = jax.Array


@dataclasses.dataclass
class MulticlassModel:
    config: DCSVMConfig
    X: Array                       # (n, d) training points
    classes: np.ndarray            # (n_classes,) original label values
    Y: Array                       # (n_classes, n) one-vs-all labels in {-1, +1}
    alpha: Array                   # (n_classes, n) per-class dual solutions
    partition: Optional[Partition]
    is_early: bool
    level_stats: List[Dict[str, Any]]

    @property
    def n_classes(self) -> int:
        return len(self.classes)

    @property
    def sv_union(self) -> np.ndarray:
        """Indices with alpha > 0 in ANY class machine (serving working set)."""
        return np.nonzero(np.any(np.asarray(self.alpha) > 0, axis=0))[0]

    def binary(self, c: int) -> DCSVMModel:
        """View of class-c's one-vs-rest machine as a binary DCSVMModel."""
        return DCSVMModel(self.config, self.X, self.Y[c], self.alpha[c],
                          self.partition, self.is_early, self.level_stats)


def labels_to_ova(y, n_classes: Optional[int] = None, dtype=jnp.float32):
    """(n,) labels -> (classes, (n_classes, n) +/-1 matrix).

    Without ``n_classes`` the classes are the sorted unique observed labels.
    With ``n_classes`` the labels must be integers in [0, n_classes) and the
    class set is exactly 0..n_classes-1 — classes absent from ``y`` get an
    all-negative machine (useful for sharded training where a shard may not
    see every class).
    """
    y_np = np.asarray(y)
    if n_classes is None:
        classes, y_idx = np.unique(y_np, return_inverse=True)
    else:
        y_idx = y_np.astype(np.int64)
        if not np.array_equal(y_idx, y_np):
            raise ValueError("n_classes requires integer labels")
        if y_np.size and (y_idx.min() < 0 or y_idx.max() >= n_classes):
            raise ValueError(
                f"labels must lie in [0, {n_classes}); got "
                f"[{y_idx.min()}, {y_idx.max()}]")
        classes = np.arange(n_classes)
    onehot = y_idx[None, :] == np.arange(len(classes))[:, None]
    return classes, jnp.asarray(np.where(onehot, 1.0, -1.0), dtype)


def ova_cost_vectors(Y: Array, C: float, class_weight, classes) -> Array:
    """Per-class cost vectors for weighted one-vs-all: machine c's box is
    ``c_i = C * w_c`` on its positive (class-c) side and ``C`` on the rest —
    the class-stacked generalization of ``WeightedCSVC``'s binary box.

    ``class_weight`` is a dict {class label: weight} (absent classes get
    1.0) or an array-like of per-class weights aligned with ``classes``.
    """
    n_cls = Y.shape[0]
    if isinstance(class_weight, dict):
        w = np.ones(n_cls)
        lookup = {c: i for i, c in enumerate(np.asarray(classes).tolist())}
        for label, wi in class_weight.items():
            if label not in lookup:
                raise ValueError(f"class_weight key {label!r} not in classes "
                                 f"{np.asarray(classes).tolist()}")
            w[lookup[label]] = float(wi)
    else:
        w = np.asarray(class_weight, np.float64)
        if w.shape != (n_cls,):
            raise ValueError(f"class_weight must have one weight per class "
                             f"({n_cls}), got shape {w.shape}")
    wj = jnp.asarray(w, Y.dtype)
    return C * jnp.where(Y > 0, wj[:, None], 1.0)


def fit_ova(
    cfg: DCSVMConfig,
    X: Array,
    y: Array,
    n_classes: Optional[int] = None,
    callback: Optional[Callable[[int, Array, Dict[str, Any]], None]] = None,
    class_weight=None,
) -> MulticlassModel:
    """Train one-vs-all DC-SVM: Algorithm 1 with a class-stacked conquer.

    Delegates to the shared ``dcsvm._fit_algorithm1`` driver (the same code
    path as binary ``fit``) with the (n_classes, n) label matrix;
    ``callback(level, alpha, stats)`` receives the class-stacked alpha.
    Adaptive clustering samples from the union of the per-class
    support-vector sets.  ``class_weight`` (dict {class: weight} or
    per-class array) upweights each machine's positive box
    (``ova_cost_vectors``) — the minority-recall knob for imbalanced
    multiclass data; the class-stacked ``Cvec`` already supports per-row
    boxes, so this is pure plumbing.
    """
    X = jnp.asarray(X)
    classes, Y = labels_to_ova(y, n_classes, X.dtype)
    td = CSVC().build(X, Y, cfg.C)
    if class_weight is not None:
        td = td._replace(Cvec=ova_cost_vectors(Y, cfg.C, class_weight, classes))
    alpha, partition, stats, is_early = _fit_algorithm1(cfg, X, td, callback)
    return MulticlassModel(cfg, X, classes, Y, alpha, partition, is_early,
                           stats)
