"""Two-step kernel kmeans (Ghitta et al., 2011 as used by DC-SVM).

Step 1: run kernel kmeans on m sampled points (m << n) entirely in kernel
space — O(m^2) memory.  Step 2: assign every point to its nearest center via
the (n x m) cross-kernel — O(nmd) compute, never O(n^2).

Centers are represented implicitly: a center c is the kernel-space mean of
the sampled points assigned to it, so distances only need

    d(x, c) = K(x,x) - 2 * K(x, X_m) @ w_c + s_c,
    w_c = H[:, c] / |V_c|,   s_c = w_c' K_mm w_c.

The returned ``KKMeansModel`` carries (X_m, W, s) and is the routing model
used at serving time by early prediction (paper eq. 11).

Balanced partitioning: SPMD shards must be equal-sized, and the paper itself
prefers balanced partitions (Sec. 3).  ``balanced_assign`` does a greedy
capacity-constrained assignment ordered by assignment confidence (host-side
numpy: partitioning is one-off data preparation, not a jitted hot path).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.kernels import Kernel, gram

Array = jax.Array


class KKMeansModel(NamedTuple):
    """Implicit kernel-space centers: d(x,c) = K(x,x) - 2 K(x,Xm) W[:,c] + s[c]."""

    Xm: Array       # (m, d) sampled points
    W: Array        # (m, k) normalized one-hot weights H / counts
    s: Array        # (k,)  per-center self-term  w_c' K_mm w_c

    @property
    def k(self) -> int:
        return self.W.shape[1]


def _center_terms(Kmm: Array, assign: Array, k: int) -> Tuple[Array, Array]:
    H = jax.nn.one_hot(assign, k, dtype=Kmm.dtype)              # (m, k)
    counts = jnp.maximum(H.sum(axis=0), 1.0)
    W = H / counts[None, :]
    M = Kmm @ W                                                 # (m, k)
    s = jnp.einsum("mk,mk->k", W, M)
    return W, s


@partial(jax.jit, static_argnames=("k", "iters"))
def kernel_kmeans(Kmm: Array, k: int, key: Array, iters: int = 20) -> Tuple[Array, Array, Array]:
    """Kernel kmeans on an (m, m) kernel matrix. Returns (assign, W, s)."""
    m = Kmm.shape[0]
    diag = jnp.diagonal(Kmm)
    # balanced random init (round-robin over a permutation)
    perm = jax.random.permutation(key, m)
    assign0 = jnp.zeros(m, jnp.int32).at[perm].set(jnp.arange(m, dtype=jnp.int32) % k)

    def body(_, assign):
        W, s = _center_terms(Kmm, assign, k)
        D = diag[:, None] - 2.0 * (Kmm @ W) + s[None, :]
        new_assign = jnp.argmin(D, axis=1).astype(jnp.int32)
        # reseed ALL empty clusters in one shot: the e-th empty cluster takes
        # the e-th point farthest from its own center.  Reseeding one per
        # iteration leaves up to k-2 phantom centers when argmin collapses
        # many clusters at once (fixed-point at iters < #empties); a phantom
        # center's distance column degenerates to K(x,x) and can capture
        # arbitrary queries at serving time.
        counts = jnp.sum(jax.nn.one_hot(new_assign, k, dtype=Kmm.dtype), axis=0)
        empty = counts <= 0.0
        eids = jnp.nonzero(empty, size=k, fill_value=-1)[0]          # (k,)
        dist_own = D[jnp.arange(m), new_assign]
        order = jnp.argsort(-dist_own)                               # (m,) distinct
        rank = jnp.arange(k)
        # at most m clusters can be populated by m points: empties ranked
        # past m stay empty (the k > m degenerate case must not crash)
        valid = (eids >= 0) & (rank < m)
        targets = jnp.where(valid, order[jnp.clip(rank, 0, m - 1)], m)
        new_assign = new_assign.at[targets].set(                     # m = dropped
            jnp.where(valid, eids, 0).astype(jnp.int32), mode="drop")
        return new_assign

    assign = lax.fori_loop(0, iters, body, assign0)
    W, s = _center_terms(Kmm, assign, k)
    return assign, W, s


@partial(jax.jit, static_argnames=("kernel", "use_pallas"))
def assign_points(
    kernel: Kernel, model: KKMeansModel, X: Array, use_pallas: bool = False
) -> Tuple[Array, Array]:
    """Nearest-center assignment for arbitrary points. Returns (assign, D).

    Empty centers (zero W column — no sampled point assigned) have no
    kernel-space location: their distance column degenerates to K(x,x)
    (a constant 1 for RBF), so without masking a phantom center can win
    ``argmin`` and silently capture queries.  Their distances are forced
    to +inf so only populated centers are routable.
    """
    Knm = gram(kernel, X, model.Xm, use_pallas=use_pallas)      # (n, m)
    D = kernel.diag(X)[:, None] - 2.0 * (Knm @ model.W) + model.s[None, :]
    empty = jnp.sum(model.W, axis=0) <= 0.0                     # (k,)
    D = jnp.where(empty[None, :], jnp.inf, D)
    return jnp.argmin(D, axis=1).astype(jnp.int32), D


def route(kernel: Kernel, model: KKMeansModel, X: Array) -> Array:
    """Serving-time router: cluster id per query point (early prediction)."""
    return assign_points(kernel, model, X)[0]


def balanced_assign(D: np.ndarray, capacity: int) -> np.ndarray:
    """Greedy capacity-constrained assignment from an (n, k) distance matrix.

    Points are processed in order of confidence (gap between best and
    second-best center); each takes its nearest center that still has room.
    Guarantees every cluster gets at most ``capacity`` points; with
    n <= k * capacity every point is assigned.
    """
    D = np.asarray(D, dtype=np.float64)
    n, k = D.shape
    if n > k * capacity:
        raise ValueError(f"capacity {capacity} x {k} clusters < n={n}")
    order_pref = np.argsort(D, axis=1)                 # per-point center ranking
    if k > 1:
        part = np.partition(D, 1, axis=1)
        confidence = part[:, 1] - part[:, 0]           # big gap = assign first
    else:
        confidence = np.zeros(n)
    point_order = np.argsort(-confidence)
    remaining = np.full(k, capacity, dtype=np.int64)
    out = np.full(n, -1, dtype=np.int32)
    for i in point_order:
        for c in order_pref[i]:
            if remaining[c] > 0:
                out[i] = c
                remaining[c] -= 1
                break
    assert (out >= 0).all()
    return out


@dataclasses.dataclass(frozen=True)
class Partition:
    """A (near-)balanced partition of n points into k clusters, padded layout.

    ``idx[c]`` holds the original indices of cluster c padded with -1 up to
    ``nc`` slots; ``mask[c]`` marks real entries.  The padded layout lets the
    divide step gather every cluster into a dense (k, nc, d) tensor and solve
    all k subproblems in ONE vmapped CD call (pad slots are excluded via the
    solver's active mask).
    """

    assign: np.ndarray      # (n,) cluster id per original index
    idx: np.ndarray         # (k, nc) original indices, -1 for padding
    mask: np.ndarray        # (k, nc) True for real points
    k: int
    nc: int                 # slots per cluster (k * nc >= n)
    model: KKMeansModel     # routing model (implicit centers)

    @staticmethod
    def build(assign: np.ndarray, k: int, model: KKMeansModel) -> "Partition":
        n = assign.shape[0]
        counts = np.bincount(assign, minlength=k)
        nc = int(counts.max())
        idx = np.full((k, nc), -1, dtype=np.int64)
        mask = np.zeros((k, nc), dtype=bool)
        for c in range(k):
            members = np.nonzero(assign == c)[0]
            idx[c, : len(members)] = members
            mask[c, : len(members)] = True
        return Partition(assign=assign, idx=idx, mask=mask, k=k, nc=nc, model=model)

    def gather(self, A: Array) -> Array:
        """Gather per-cluster values: (n, ...) -> (k, nc, ...); pads read row 0."""
        return jnp.asarray(A)[np.maximum(self.idx, 0)]

    def scatter(self, Ac: Array, n: int, fill: float = 0.0) -> Array:
        """Scatter (k, nc, ...) back to (n, ...). Pad slots are dropped."""
        flat_idx = jnp.asarray(np.where(self.mask, self.idx, n).reshape(-1))
        flat_val = jnp.asarray(Ac).reshape((self.k * self.nc,) + Ac.shape[2:])
        out = jnp.full((n + 1,) + flat_val.shape[1:], fill, flat_val.dtype)
        out = out.at[flat_idx].set(flat_val)
        return out[:n]


def two_step_kernel_kmeans(
    kernel: Kernel,
    X: Array,
    k: int,
    key: Array,
    m: int = 1000,
    iters: int = 20,
    sample_idx: Optional[Array] = None,
    balanced: bool = True,
    use_pallas: bool = False,
) -> Partition:
    """The paper's clustering step. ``sample_idx`` overrides the random sample
    (adaptive clustering passes the current support-vector set here)."""
    n = X.shape[0]
    m = min(m, n)
    # independent streams for the m-point sample and the kmeans init: reusing
    # ``key`` for both correlates the sample with the init permutation
    key_sample, key_init = jax.random.split(key)
    if sample_idx is None:
        sample_idx = jax.random.choice(key_sample, n, shape=(m,), replace=False)
    else:
        sample_idx = jnp.asarray(sample_idx)
        m = sample_idx.shape[0]
    Xm = X[sample_idx]
    Kmm = gram(kernel, Xm, Xm, use_pallas=use_pallas)
    _, W, s = kernel_kmeans(Kmm, k, key_init, iters=iters)
    model = KKMeansModel(Xm=Xm, W=W, s=s)
    assign, D = assign_points(kernel, model, X, use_pallas=use_pallas)
    if balanced:
        capacity = -(-n // k)  # ceil
        assign = balanced_assign(np.asarray(D), capacity)
    else:
        assign = np.asarray(assign)
    return Partition.build(np.asarray(assign, np.int32), k, model)
