"""Distributed DC-SVM: the paper's algorithm mapped onto a device mesh via
shard_map, with a communication-efficient parallel-block conquer.

Two SPMD programs over the generalized box dual
``min 1/2 u'Qu + p'u, 0 <= u <= c`` with ``Q = (s s') ∘ K`` (C-SVC,
weighted C-SVC, epsilon-SVR — everything ``repro.core.tasks`` reduces to
the box family):

1. ``divide_step`` — clusters sharded across devices; each device solves
   its local clusters with the vmapped CD solver against *locally resident*
   Gram blocks (built once per cluster on-device; a sequential ``lax.map``
   sweep caps peak memory at one cluster's Grams when the per-device batch
   exceeds ``gram_budget``).  ZERO collectives: DC-SVM's divide step is
   embarrassingly parallel *by construction* (Lemma 1 makes the subproblems
   exactly independent), which is why the algorithm maps so well onto a pod.

2. ``conquer_step`` — parallel block minimization (CE-PBM; Hsieh, Si &
   Dhillon 2016) on the full problem.  Rows of (X, s, alpha, g) are sharded
   over the mesh axis; per communication round:

     a. every device takes its LOCAL top-B coordinates by |projected
        gradient| and solves its OWN BxB sub-QP against on-the-fly kernel
        columns — P independent block solves per round;
     b. ONE all-gather ships the P rank-B updates (feature rows, signs,
        deltas, indices) — O(P * B * d) bytes, the only bulk communication;
     c. each device applies the rank-P*B gradient update as a single skinny
        matmul ``g_l += gamma * (s_l ∘ (K(X_l, X_sel) @ (s_sel ∘ delta)))``
        (fused Pallas ``cd_column_update`` on the Pallas path; the
        ``core.colcache`` LRU serves repeat blocks without recomputing);
     d. the combination step size ``gamma = clip(-g'Δ / Δ'QΔ, 0, 1)``
        (solver.combination_step_size) keeps the P simultaneous block
        updates convergent WITHOUT backtracking — ``Δ'QΔ`` from the
        replicated gathered-block Gram, ``g'Δ`` from one scalar psum, so
        the loop condition stays uniform across devices.  Scaled steps
        that a block solve aimed AT a box bound snap onto it once within
        an O(tol) band (a ``(1-gamma)``-contraction never lands exactly,
        and the projected gradient would report the gap forever);
     e. owners write the post-snap block values into their alpha shard
        (blocks live on disjoint shards, so there are no collisions), and
        the exactly-applied step — not the proposal — is what entered the
        gradient matmul in (c), keeping the maintained gradient drift-free.

   That is P× more coordinate updates per round at the same bytes on the
   wire as a single replicated global block step.  ``mode="replicated"``
   keeps the legacy scheme — exact global Gauss-Southwell-B where all
   devices deterministically solve the SAME global top-B block — as the
   communication-round baseline (benchmarks/bench_dist.py).

``fit_distributed`` runs the multilevel pipeline device-resident: SV
detection between levels is a scatter-add on device, adaptive kmeans
sampling draws on device (``_sv_sample``), and alpha never round-trips
through NumPy until the caller asks for it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.core import colcache, gramop
from repro.core.kernels import Kernel, gram, resolve_use_pallas
from repro.core.solver import (_solve_small_qp, combination_step_size,
                               proj_grad)
from repro.core import solver as S
from repro.core.tasks import Task, TaskDual, resolve_task
from repro.obs.trace import (ConvTrace, trace_fetch, trace_init,
                             trace_record, trace_summary)
from repro.obs.spans import span

Array = jax.Array


# ---------------------------------------------------------------------------
# divide step
# ---------------------------------------------------------------------------

def divide_step(
    mesh: Mesh,
    axis: str,
    cfg,
    Xc: Array,
    sc: Array,
    pc: Array,
    cc: Array,
    ac: Array,
    mask: Array,
) -> Array:
    """Solve one level's clusters of the generalized dual, sharded over
    ``axis``.

    ``Xc``: (k, nc, d) with k a multiple of the axis size; ``sc``/``pc``/
    ``cc``/``ac``/``mask``: (k, nc) per-cluster sign vectors, linear terms,
    boxes, warm starts and pad masks.  Each device's Gram blocks are built
    and consumed locally (per-device Gram residency: no cluster data or
    kernel block ever crosses the mesh); when the local stacked Grams
    ``(k/P) * nc^2`` exceed ``cfg.gram_budget`` the vmapped solve falls back
    to a sequential ``lax.map`` sweep — one cluster Gram live at a time.
    Returns the updated (k, nc) dual variables.
    """
    tol, max_iters = cfg.tol, cfg.max_iters
    kernel, block, sweeps = cfg.kernel, cfg.block, cfg.sweeps
    use_pallas = resolve_use_pallas(cfg.use_pallas)
    compute_dtype = getattr(cfg, "compute_dtype", None)
    P_ = mesh.shape[axis]
    k, nc, _ = Xc.shape
    if k % P_ != 0:
        raise ValueError(
            f"cluster count {k} must be a multiple of the mesh axis size "
            f"{P_} (fit_distributed rounds k up for you)")
    # per-device residency decided on the BYTE budget (f32 cluster Grams)
    resident = gramop.fits_budget((k // P_) * nc * nc, cfg.gram_budget)

    def local(Xl, sl, pl, cl, al, ml):
        def one(Xi, si, pi, ci, ai, mi):
            Ki = gram(kernel, Xi, Xi, use_pallas=use_pallas,
                      compute_dtype=compute_dtype)
            mm = mi[:, None] & mi[None, :]
            Qi = (si[:, None] * si[None, :]) * jnp.where(mm, Ki, 0.0)
            Qi = Qi + jnp.where(mi, 0.0, 1.0) * jnp.eye(nc, dtype=Qi.dtype)
            ai = jnp.where(mi, ai, 0.0)
            if block > 0 and block < nc:
                res = S.solve_box_qp_block(Qi, ci, alpha0=ai, tol=tol,
                                           max_iters=max_iters, block=block,
                                           sweeps=sweeps, active_mask=mi,
                                           p=pi)
            else:
                res = S.solve_box_qp(Qi, ci, alpha0=ai, tol=tol,
                                     max_iters=max_iters, active_mask=mi,
                                     p=pi)
            return res.alpha

        if resident:
            return jax.vmap(one)(Xl, sl, pl, cl, al, ml)
        return lax.map(lambda t: one(*t), (Xl, sl, pl, cl, al, ml))

    spec = P(axis)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=spec,
    )
    return fn(Xc, sc, pc, cc, ac, mask)


# ---------------------------------------------------------------------------
# conquer step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConquerConfig:
    kernel: Kernel
    C: float = 1.0           # scalar box; per-coordinate via conquer_step(c=...)
    tol: float = 1e-3
    max_iters: int = 2_000   # communication-round cap
    block: int = 64          # per-device block size B
    sweeps: int = 4
    mode: str = "parallel"   # "parallel" = CE-PBM (P local blocks/round);
                             # "replicated" = legacy global top-B baseline
    use_pallas: Optional[bool] = None  # None = auto (Pallas on TPU)
    cache_cap: int = 0       # LRU slots for (P*B, n_local) Q-row slices;
                             # 0 = fully fused recompute (parallel mode only)
    grad_chunks: int = 16    # row chunks for the XLA initial-gradient matvec
    compute_dtype: Optional[str] = None  # Gram operand precision (bf16 tiles,
                             # f32 accumulation); None = exact f32 default.
                             # Cached Q-row slices store in this dtype too,
                             # doubling the rows a byte budget holds
    trace_cap: int = 0       # convergence-trace ring capacity (obs.trace);
                             # 0 = off (jaxpr identical to the pre-trace
                             # program); > 0 records one sample per round
                             # and conquer_step returns a 4th ConvTrace
                             # element


def conquer_step(
    mesh: Mesh,
    axis: str,
    cfg: ConquerConfig,
    X: Array,
    s: Array,
    alpha0: Array,
    p=-1.0,
    c=None,
    valid: Optional[Array] = None,
) -> Tuple[Array, Array, Array]:
    """Distributed conquer on the full generalized dual, warm-started.

    ``X``: (n, d) dual points, ``s``/``alpha0``: (n,) sign vector and warm
    start — any n: rows are padded internally with masked c=0 coordinates
    up to a multiple of the axis size and sliced back on return.  ``p`` and
    ``c`` may be scalars or (n,) vectors (weighted boxes / the SVR linear
    term); ``valid`` masks coordinates out of selection (used for padding).
    Returns ``(alpha, rounds, pg_max)`` where ``rounds`` counts
    communication rounds and ``pg_max`` is the projected-gradient residual
    recomputed AT the returned alpha (the pre-fix code reported the
    stopping value of the previous iterate).

    With ``cfg.trace_cap > 0`` one convergence sample per communication
    round — post-update pg_max / objective / free-set size (psum-reduced,
    so the ring is replicated across devices) plus the CE-PBM combination
    step γ* — is recorded on device and a 4th ``ConvTrace`` element is
    returned; fetch it with ``obs.trace.trace_fetch`` AFTER the loop.  The
    trace adds two scalar psums per round and nothing else; ``trace_cap=0``
    (the default) builds the identical pre-trace program.
    """
    if cfg.mode not in ("parallel", "replicated"):
        raise ValueError(f"unknown conquer mode {cfg.mode!r} "
                         f"(expected 'parallel' or 'replicated')")
    kernel = cfg.kernel
    use_pallas = resolve_use_pallas(cfg.use_pallas)
    compute_dtype = getattr(cfg, "compute_dtype", None)
    if use_pallas:
        from repro.kernels import ops as kops

    def pairwise(A, Bm):
        return kernel.pairwise(A, Bm, compute_dtype=compute_dtype)

    P_ = mesh.shape[axis]
    n0, d = X.shape
    dtype = X.dtype
    acc = jnp.promote_types(dtype, jnp.float32)
    s = jnp.asarray(s, dtype)
    alpha0 = jnp.asarray(alpha0, dtype)
    cvec = jnp.broadcast_to(
        jnp.asarray(cfg.C if c is None else c, dtype), (n0,))
    pvec = jnp.broadcast_to(jnp.asarray(p, dtype), (n0,))
    vvec = (jnp.ones(n0, bool) if valid is None
            else jnp.asarray(valid).astype(bool))

    # ---- pad to a multiple of the device count with inert coordinates ----
    pad = (-n0) % P_
    if pad:
        X = jnp.concatenate([X, jnp.zeros((pad, d), dtype)])
        s = jnp.concatenate([s, jnp.ones(pad, dtype)])
        alpha0 = jnp.concatenate([alpha0, jnp.zeros(pad, dtype)])
        cvec = jnp.concatenate([cvec, jnp.zeros(pad, dtype)])
        pvec = jnp.concatenate([pvec, jnp.zeros(pad, dtype)])
        vvec = jnp.concatenate([vvec, jnp.zeros(pad, bool)])
    n = n0 + pad
    n_l = n // P_
    B = max(1, min(cfg.block, n_l))
    cache_cap = 0 if cfg.mode != "parallel" else cfg.cache_cap
    if cache_cap > 0:
        cache_cap = max(cache_cap, P_ * B)   # insert needs one full block

    def cross_matvec(Xl, Z, w):
        """K(X_l, Z) @ w without materializing the (n_l, n) block."""
        if use_pallas:
            return kops.kernel_matvec(Xl, Z, w, kernel,
                                      compute_dtype=compute_dtype)
        nl = Xl.shape[0]
        chunks = max(1, min(cfg.grad_chunks, nl))
        padl = (-nl) % chunks
        Xp = jnp.pad(Xl, ((0, padl), (0, 0))) if padl else Xl
        out = lax.map(lambda Xi: pairwise(Xi, Z) @ w,
                      Xp.reshape(chunks, -1, d))
        return out.reshape(-1)[:nl]

    def local(Xl, sl, al, pl, cl, vl):
        me = lax.axis_index(axis)
        # ---- initial local gradient: g_l = Q[l, :] @ alpha + p ------------
        Xg = lax.all_gather(Xl, axis).reshape(n, d)
        wg = lax.all_gather(sl * al, axis).reshape(n)
        g_l = (sl * cross_matvec(Xl, Xg, wg)).astype(acc) + pl.astype(acc)

        def scores_of(al, g_l):
            # pads (and caller-invalidated rows) never enter selection;
            # proj_grad alone is not enough — a c=0 coordinate still
            # reports max(g, 0) as "violation" at its (degenerate) bound
            return jnp.abs(jnp.where(vl, proj_grad(al, g_l, cl), 0.0))

        def qdelta(Xsel, ssel, w):
            """(QΔ) restricted to local rows: s_l ∘ (K(X_l, X_sel) @ w),
            w = s_sel ∘ Δ_sel — the rank-P*B skinny matmul (fused Pallas
            cd_column_update on the Pallas path)."""
            if use_pallas:
                return kops.cd_column_update(
                    Xl, sl, Xsel, w, kernel,
                    compute_dtype=compute_dtype).astype(acc)
            return (sl * (pairwise(Xl, Xsel) @ w)).astype(acc)

        def propose(al, g_l):
            """One CE-PBM proposal: local GS-B block, local BxB solve, one
            all-gather of the P rank-B updates, combination step size.

            gamma is decided BEFORE the gradient update: ``dQd`` comes from
            the replicated (P*B, P*B) selected-block Gram (O((PB)^2 d)
            flops, zero communication) and ``gTd`` from a scalar psum.
            Coordinates whose block solve targeted a box bound are SNAPPED
            onto it when the gamma-scaled step lands within eps — without
            this, gamma < 1 makes bound-bound coordinates approach their
            bound geometrically but never reach it, so their projected
            gradient (which treats any interior point as free) stays O(1)
            forever and the stopping test cannot fire.  eps is tied to
            cfg.tol so a snapped coordinate's residual bound-distance can
            never re-trip selection.  The skinny gradient matmul then uses
            the exactly-APPLIED step (all-gathered, P*B floats), so the
            maintained gradient stays drift-free through snapping.
            """
            sc_ = scores_of(al, g_l)
            _, ib = lax.top_k(sc_, B)
            Xb, sb, ab, gb, cb = Xl[ib], sl[ib], al[ib], g_l[ib], cl[ib]
            Qbb = ((sb[:, None] * sb[None, :])
                   * pairwise(Xb, Xb)).astype(acc)
            target = _solve_small_qp(Qbb, gb, ab.astype(acc), cb, cfg.sweeps)
            delta = target - ab.astype(acc)
            gath = {k2: lax.all_gather(v, axis) for k2, v in
                    dict(x=Xb, s=sb, d=delta,
                         i=ib.astype(jnp.int32)).items()}
            Xsel = gath["x"].reshape(P_ * B, d)
            ssel = gath["s"].reshape(-1)
            dsel = gath["d"].reshape(-1)
            gidx = (jnp.arange(P_, dtype=jnp.int32)[:, None] * n_l
                    + gath["i"]).reshape(-1)
            Qsel = ((ssel[:, None] * ssel[None, :])
                    * pairwise(Xsel, Xsel)).astype(acc)
            dQd = jnp.vdot(dsel, Qsel @ dsel)
            gTd = lax.psum(jnp.vdot(gb.astype(acc), delta), axis)
            gamma = combination_step_size(gTd, dQd)
            a_new = (ab.astype(acc) + gamma * delta).astype(dtype)
            eps = (0.1 * cfg.tol * (1.0 + cb)).astype(dtype)
            a_new = jnp.where((target <= 0.0) & (a_new <= eps),
                              jnp.zeros((), dtype), a_new)
            a_new = jnp.where((target >= cb.astype(acc))
                              & (a_new >= cb - eps), cb, a_new)
            applied = a_new.astype(acc) - ab.astype(acc)
            asel = lax.all_gather(applied, axis).reshape(-1)
            pg = lax.pmax(jnp.max(sc_), axis)
            return ib, a_new, Xsel, ssel, asel, gidx, pg, gamma

        def q_rows_local(Xsel, ssel):
            """(P*B, n_l) Q-row slices of the selected block against the
            local shard — the cache-refill unit."""
            if use_pallas:
                return kops.q_rows(Xl, sl, Xsel, ssel, kernel,
                                   compute_dtype=compute_dtype).astype(acc)
            return ((ssel[:, None] * sl[None, :])
                    * pairwise(Xsel, Xl)).astype(acc)

        tcap = cfg.trace_cap

        def cond(state):
            it, pg = state[-2], state[-1]
            return (pg > cfg.tol) & (it < cfg.max_iters)

        def cond_t(state):
            it, pg = state[-3], state[-2]
            return (pg > cfg.tol) & (it < cfg.max_iters)

        def record_round(tr, al, g_l, pg, gamma=None, cache_hits=None):
            """One post-update sample per round; the psum-reduced columns
            make every device's ring identical, so the caller reads shard 0."""
            alc = al.astype(acc)
            obj = lax.psum(0.5 * jnp.vdot(alc, g_l)
                           + 0.5 * jnp.vdot(pl.astype(acc), alc), axis)
            nfree = lax.psum(jnp.sum(((al > 0.0) & (al < cl) & vl)
                                     .astype(jnp.int32)), axis)
            return trace_record(tr, pg_max=pg, objective=obj, n_free=nfree,
                                gamma=gamma, cache_hits=cache_hits)

        pg0 = lax.pmax(jnp.max(scores_of(al, g_l)), axis)
        tr = None

        if cfg.mode == "parallel" and cache_cap == 0:
            def step(al, g_l):
                ib, a_new, Xsel, ssel, asel, _, pg, gamma = propose(al, g_l)
                g_l = g_l + qdelta(Xsel, ssel, ssel * asel)
                al = al.at[ib].set(a_new)
                return al, g_l, pg, gamma

            if tcap == 0:
                def body(state):
                    al, g_l, it, _ = state
                    al, g_l, pg, _ = step(al, g_l)
                    return al, g_l, it + 1, pg

                state0 = (al, g_l, jnp.zeros((), jnp.int32), pg0)
                al, g_l, rounds, _ = lax.while_loop(cond, body, state0)
            else:
                def body(state):
                    al, g_l, it, _, tr = state
                    al, g_l, pg, gamma = step(al, g_l)
                    tr = record_round(tr, al, g_l, pg, gamma)
                    return al, g_l, it + 1, pg, tr

                state0 = (al, g_l, jnp.zeros((), jnp.int32), pg0,
                          trace_init(tcap))
                al, g_l, rounds, _, tr = lax.while_loop(cond_t, body, state0)

        elif cfg.mode == "parallel":
            def step(al, g_l, cache):
                ib, a_new, Xsel, ssel, asel, gidx, pg, gamma = \
                    propose(al, g_l)
                slots, hit = colcache.lookup(cache, gidx)
                served = jnp.all(hit)
                Qrows = lax.cond(
                    served,
                    lambda: cache.cols[jnp.where(hit, slots, 0)].astype(acc),
                    lambda: q_rows_local(Xsel, ssel),
                )
                cache = colcache.update(cache, gidx, Qrows, served, slots,
                                        hit)
                g_l = g_l + asel @ Qrows
                al = al.at[ib].set(a_new)
                return al, g_l, cache, pg, gamma

            # cached Q-row slices store in the policy dtype: a bf16 policy
            # fits twice the rows of f32 under the same byte budget
            store = (jnp.dtype(compute_dtype) if compute_dtype is not None
                     else acc)
            cache0 = colcache.init(cache_cap, n, dtype=store, width=n_l)

            if tcap == 0:
                def body(state):
                    al, g_l, cache, it, _ = state
                    al, g_l, cache, pg, _ = step(al, g_l, cache)
                    return al, g_l, cache, it + 1, pg

                state0 = (al, g_l, cache0, jnp.zeros((), jnp.int32), pg0)
                al, g_l, _, rounds, _ = lax.while_loop(cond, body, state0)
            else:
                def body(state):
                    al, g_l, cache, it, _, tr = state
                    hits0 = cache.hits
                    al, g_l, cache, pg, gamma = step(al, g_l, cache)
                    # per-round local cache-hit delta (identical across
                    # devices — lookups key on the replicated gidx)
                    tr = record_round(tr, al, g_l, pg, gamma,
                                      cache_hits=cache.hits - hits0)
                    return al, g_l, cache, it + 1, pg, tr

                state0 = (al, g_l, cache0, jnp.zeros((), jnp.int32), pg0,
                          trace_init(tcap))
                al, g_l, _, rounds, _, tr = lax.while_loop(cond_t, body,
                                                           state0)

        else:   # replicated: legacy exact global GS-B baseline
            def rep_step(al, g_l):
                sc_ = scores_of(al, g_l)
                sb, ib = lax.top_k(sc_, B)              # local candidates
                cand = dict(sc=sb, x=Xl[ib], g=g_l[ib], a=al[ib], y=sl[ib],
                            c=cl[ib], i=ib.astype(jnp.int32))
                gath = {k2: lax.all_gather(v, axis) for k2, v in
                        cand.items()}
                flat = gath["sc"].reshape(-1)
                _, sel = lax.top_k(flat, B)             # same global top-B
                xb = gath["x"].reshape(P_ * B, d)[sel]
                gb = gath["g"].reshape(-1)[sel]
                ab = gath["a"].reshape(-1)[sel]
                yb = gath["y"].reshape(-1)[sel]
                cb = gath["c"].reshape(-1)[sel]
                owner = (sel // B).astype(jnp.int32)
                lidx = gath["i"].reshape(-1)[sel]
                Qbb = ((yb[:, None] * yb[None, :])
                       * kernel.pairwise(xb, xb)).astype(acc)
                new_ab = _solve_small_qp(Qbb, gb, ab.astype(acc), cb,
                                         cfg.sweeps)
                delta = (new_ab - ab).astype(acc)
                g_l = g_l + qdelta(xb, yb, yb * delta)
                own = owner == me
                safe_idx = jnp.where(own, lidx, 0)
                al = al.at[safe_idx].add(
                    jnp.where(own, delta, 0.0).astype(dtype))
                pg = lax.pmax(jnp.max(sc_), axis)
                return al, g_l, pg

            if tcap == 0:
                def body(state):
                    al, g_l, it, _ = state
                    al, g_l, pg = rep_step(al, g_l)
                    return al, g_l, it + 1, pg

                state0 = (al, g_l, jnp.zeros((), jnp.int32), pg0)
                al, g_l, rounds, _ = lax.while_loop(cond, body, state0)
            else:
                def body(state):
                    al, g_l, it, _, tr = state
                    al, g_l, pg = rep_step(al, g_l)
                    # no combination step in the replicated baseline:
                    # the gamma column stays NaN
                    tr = record_round(tr, al, g_l, pg)
                    return al, g_l, it + 1, pg, tr

                state0 = (al, g_l, jnp.zeros((), jnp.int32), pg0,
                          trace_init(tcap))
                al, g_l, rounds, _, tr = lax.while_loop(cond_t, body, state0)

        # residual at the RETURNED alpha, not the pre-update stopping value
        pg_exit = lax.pmax(jnp.max(scores_of(al, g_l)), axis)
        if tcap == 0:
            return al, rounds[None], pg_exit[None]
        # the ring is replicated (psum/pmax-reduced columns): ship every
        # device's copy out and let the caller read shard 0
        return al, rounds[None], pg_exit[None], tr.buf[None], tr.count[None]

    spec = P(axis)
    traced = cfg.trace_cap > 0
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=(spec, P(axis), P(axis)) + ((P(axis), P(axis)) if traced
                                              else ()),
    )
    out = fn(X, s, alpha0, pvec, cvec, vvec)
    alpha, rounds, pg = out[:3]
    if traced:
        return (alpha[:n0], rounds[0], jnp.max(pg),
                ConvTrace(buf=out[3][0], count=out[4][0]))
    return alpha[:n0], rounds[0], jnp.max(pg)


# ---------------------------------------------------------------------------
# full distributed DC-SVM driver
# ---------------------------------------------------------------------------

def _sv_sample(key: Array, sv_mask: Array, m: int) -> Array:
    """Device-side adaptive kmeans sample: m indices with every support
    vector first (random order) and random non-SV fill when fewer than m
    SVs exist — the static-shape, no-host-round-trip replacement for
    ``rng.choice(sv_idx)``."""
    u = jax.random.uniform(key, sv_mask.shape)
    _, idx = lax.top_k(jnp.where(sv_mask, 1.0 + u, u), m)
    return idx


def fit_distributed(
    cfg,
    mesh: Mesh,
    axis: str,
    X: Array,
    y: Optional[Array] = None,
    task: Optional[Task] = None,
    conquer_block: int = 64,
    conquer_iters: int = 5_000,
    mode: str = "parallel",
    cache_cap: int = 0,
):
    """Multilevel DC-SVM with every level's cluster solves sharded over
    ``axis`` and the final conquer running parallel block minimization.

    ``cfg`` is a core.dcsvm.DCSVMConfig; ``task`` selects the workload
    (C-SVC default, WeightedCSVC, EpsilonSVR — any single-row box-family
    task; the equality-constrained family is single-host for now).  Cluster
    counts are rounded up to a multiple of the axis size so every device
    gets equal work (balanced clusters double as straggler mitigation:
    lockstep SPMD with equal tiles); any dataset size works — the conquer
    pads internally.  The pipeline is device-resident between levels: SV
    detection is a scatter-add over ``base_index`` on device and the
    adaptive kmeans sample draws on device, so alpha never round-trips
    through NumPy.  Returns ``(alpha (n_dual,), stats list)``.
    """
    from repro.core.kkmeans import Partition, two_step_kernel_kmeans

    task = resolve_task(task)
    X = jnp.asarray(X)
    n = X.shape[0]
    if y is None:
        if not task.label_free:
            raise ValueError(f"task {task.name!r} requires labels y")
        y = jnp.zeros(n, X.dtype)
    y = jnp.asarray(y, X.dtype)
    td = task.build(X, y[None, :], cfg.C)
    if td.has_equality:
        raise NotImplementedError(
            f"distributed fit covers the box dual family (svc / "
            f"weighted-svc / svr); task {task.name!r} carries an equality "
            f"constraint — use core.dcsvm.fit")
    if td.n_rows != 1:
        raise ValueError("distributed fit is single-row (binary labels or "
                         f"regression); got n_rows={td.n_rows}")
    nd = td.n_dual
    base_index = np.asarray(td.base_index)
    bidx = jnp.asarray(base_index)
    s1, p1, c1 = td.S[0], td.P[0], td.Cvec[0]
    use_pallas = resolve_use_pallas(cfg.use_pallas)
    P_ = mesh.shape[axis]
    key = jax.random.PRNGKey(cfg.seed)
    alpha = jnp.zeros(nd, X.dtype)
    sv_base = None            # (n,) on-device SV mass per base point
    stats = []

    for l in range(cfg.levels, 0, -1):
        kl = max(cfg.k ** l, P_)
        kl = -(-kl // P_) * P_          # multiple of device count
        if kl >= n // 2:
            continue
        key, sub, ksamp = jax.random.split(key, 3)
        sample_idx = None
        if cfg.adaptive and sv_base is not None:
            sample_idx = _sv_sample(ksamp, sv_base > 0, min(cfg.m, n))
        with span(f"divide/level{l}/cluster"):
            part = two_step_kernel_kmeans(cfg.kernel, X, kl, sub, m=cfg.m,
                                          iters=cfg.kmeans_iters,
                                          sample_idx=sample_idx,
                                          balanced=True,
                                          use_pallas=use_pallas)
        # expand the base partition to dual coordinates (SVR's mirrored
        # pair of a sample shares its cluster)
        dpart = part if nd == n else Partition.build(
            np.asarray(part.assign)[base_index].astype(np.int32), kl,
            part.model)
        mask = jnp.asarray(dpart.mask)
        ac = jnp.where(mask, dpart.gather(alpha), 0.0)
        with span(f"divide/level{l}/solve"):
            ac = divide_step(mesh, axis, cfg, dpart.gather(td.Xd),
                             dpart.gather(s1), dpart.gather(p1),
                             dpart.gather(c1), ac, mask)
            alpha = dpart.scatter(ac, nd)
        # device-resident SV tracking: dual mass scatter-added per base
        # point (the box family keeps alpha >= 0, so mass > 0 <=> any SV)
        sv_base = jnp.zeros(n, X.dtype).at[bidx].add(alpha)
        stats.append(dict(level=l, clusters=kl,
                          n_sv=jnp.sum(sv_base > 0)))

    trace_cap = getattr(cfg, "trace", None) or 0
    ccfg = ConquerConfig(kernel=cfg.kernel, C=cfg.C, tol=cfg.tol,
                         max_iters=conquer_iters, block=conquer_block,
                         sweeps=cfg.sweeps, mode=mode,
                         use_pallas=cfg.use_pallas, cache_cap=cache_cap,
                         compute_dtype=getattr(cfg, "compute_dtype", None),
                         trace_cap=trace_cap)
    with span("conquer/distributed"):
        out = conquer_step(mesh, axis, ccfg, td.Xd, s1, alpha, p=p1, c=c1)
        alpha, rounds, pg = out[:3]
    sv_base = jnp.zeros(n, X.dtype).at[bidx].add(alpha)
    st0 = dict(level=0, rounds=rounds, pg_max=pg,
               n_sv=jnp.sum(sv_base > 0))
    if trace_cap > 0:
        # the single sanctioned device->host fetch of the round trace,
        # alongside the exit-time counter sync below
        st0["trace"] = trace_fetch(out[3])
        st0["trace_summary"] = trace_summary(st0["trace"])
    stats.append(st0)
    return alpha, _finalize_stats(stats)


def _finalize_stats(stats):
    """One host sync at exit: convert the accumulated device scalars."""
    out = []
    for st in stats:
        fin = {}
        for k2, v in st.items():
            if isinstance(v, jax.Array):
                v = v.item()
                v = int(v) if float(v).is_integer() else float(v)
            fin[k2] = v
        out.append(fin)
    return out


def fit_distributed_model(
    cfg,
    mesh: Mesh,
    axis: str,
    X: Array,
    y: Optional[Array] = None,
    task: Optional[Task] = None,
    **kw,
):
    """``fit_distributed`` wrapped into a ``DCSVMModel`` (collapsed beta
    over the base points), so distributed training feeds the same
    prediction / serving path as the single-host driver."""
    from repro.core.dcsvm import DCSVMModel

    task = resolve_task(task)
    X = jnp.asarray(X)
    if y is None:
        y = jnp.zeros(X.shape[0], X.dtype)
    y = jnp.asarray(y, X.dtype)
    alpha, stats = fit_distributed(cfg, mesh, axis, X, y, task=task, **kw)
    td = task.build(X, y[None, :], cfg.C)
    beta = td.collapse(alpha[None, :])[0]
    return DCSVMModel(cfg, X, y, alpha, None, False, stats, task=task,
                      beta=beta)
