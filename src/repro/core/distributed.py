"""Distributed DC-SVM: the paper's algorithm mapped onto a TPU pod via shard_map.

Two SPMD programs:

1. ``divide_step`` — clusters sharded across devices; each device solves its
   local clusters with the vmapped CD solver.  ZERO collectives: DC-SVM's
   divide step is embarrassingly parallel *by construction* (Lemma 1 makes
   the subproblems exactly independent), which is why the algorithm maps so
   well onto a pod.  With the multi-pod mesh, clusters are assigned to pods
   first (outer axis), so the divide step is also DCN-quiet.

2. ``conquer_step`` — distributed block greedy CD on the full problem.
   Layout: rows of (X, y, alpha, g) sharded over the flattened mesh axis;
   per outer iteration:
     a. each device takes its local top-B coordinates by |projected gradient|
     b. one all-gather of the candidates' (score, feature-row, g, alpha, y)
        — O(P * B * d) bytes, the only communication
     c. every device deterministically selects the same global top-B,
        solves the same small BxB QP (replicated compute, no broadcast)
     d. local rank-B gradient update  g_l += (y_l y_b K(X_l, X_b)) @ delta
        — the O(n d B) hot loop, fully local (Pallas `cd_update` on TPU)
     e. owners scatter the alpha update into their shard
   Selection is exact global Gauss-Southwell-B (same trajectory as the
   single-device solver whenever per-device candidate counts B are not
   exceeded by clustered violations).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.kernels import Kernel
from repro.core.solver import SolveResult, _solve_small_qp, proj_grad
from repro.core import solver as S

Array = jax.Array


# ---------------------------------------------------------------------------
# divide step
# ---------------------------------------------------------------------------

def divide_step(
    mesh: Mesh,
    axis: str,
    cfg,
    Xc: Array,
    yc: Array,
    ac: Array,
    mask: Array,
) -> Array:
    """Solve all clusters, sharded over ``axis``. Xc: (k, nc, d) with k a
    multiple of the axis size. Returns updated (k, nc) alphas."""
    C, tol, max_iters = cfg.C, cfg.tol, cfg.max_iters
    kernel, block, sweeps = cfg.kernel, cfg.block, cfg.sweeps

    def local(Xl, yl, al, ml):
        def one(Xi, yi, ai, mi):
            nc = Xi.shape[0]
            Ki = kernel.pairwise(Xi, Xi)
            Qi = (yi[:, None] * yi[None, :]) * Ki
            mm = mi[:, None] & mi[None, :]
            Qi = jnp.where(mm, Qi, 0.0)
            Qi = Qi + jnp.where(mi, 0.0, 1.0) * jnp.eye(nc, dtype=Qi.dtype)
            ai = jnp.where(mi, ai, 0.0)
            if block > 0 and block < nc:
                res = S.solve_box_qp_block(Qi, C, alpha0=ai, tol=tol,
                                           max_iters=max_iters, block=block,
                                           sweeps=sweeps, active_mask=mi)
            else:
                res = S.solve_box_qp(Qi, C, alpha0=ai, tol=tol,
                                     max_iters=max_iters, active_mask=mi)
            return res.alpha

        return jax.vmap(one)(Xl, yl, al, ml)

    spec = P(axis)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=spec,
    )
    return fn(Xc, yc, ac, mask)


# ---------------------------------------------------------------------------
# conquer step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConquerConfig:
    kernel: Kernel
    C: float
    tol: float = 1e-3
    max_iters: int = 2_000
    block: int = 64          # global block size AND per-device candidate count
    sweeps: int = 4


def conquer_step(
    mesh: Mesh,
    axis: str,
    cfg: ConquerConfig,
    X: Array,
    y: Array,
    alpha0: Array,
) -> Tuple[Array, Array, Array]:
    """Distributed block greedy CD on the full problem, warm-started.

    X: (n, d), y/alpha0: (n,) with n a multiple of the axis size.
    Returns (alpha, iters, pg_max)."""
    kernel, C, B = cfg.kernel, cfg.C, cfg.block
    P_ = mesh.shape[axis]
    n = X.shape[0]
    assert n % P_ == 0, (n, P_)

    def local(Xl, yl, al):
        # ---- initial local gradient: g_l = Q[l, :] @ alpha - 1 -------------
        Xg = lax.all_gather(Xl, axis).reshape(n, Xl.shape[1])
        wg = lax.all_gather(yl * al, axis).reshape(n)
        g_l = yl * (kernel.pairwise(Xl, Xg) @ wg) - 1.0

        def cond(state):
            _, _, it, pg_max = state
            return (pg_max > cfg.tol) & (it < cfg.max_iters)

        def body(state):
            al, g_l, it, _ = state
            pg = proj_grad(al, g_l, C)
            scores = jnp.abs(pg)
            sb, ib = lax.top_k(scores, B)                     # local candidates
            cand = dict(
                s=sb, x=Xl[ib], g=g_l[ib], a=al[ib], y=yl[ib],
                idx=ib.astype(jnp.int32),
            )
            gath = {k: lax.all_gather(v, axis) for k, v in cand.items()}  # (P, B, ...)
            flat_s = gath["s"].reshape(-1)                    # (P*B,)
            _, sel = lax.top_k(flat_s, B)                     # global top-B
            xb = gath["x"].reshape(-1, Xl.shape[1])[sel]      # (B, d) replicated
            gb = gath["g"].reshape(-1)[sel]
            ab = gath["a"].reshape(-1)[sel]
            yb = gath["y"].reshape(-1)[sel]
            owner = (sel // B).astype(jnp.int32)
            lidx = gath["idx"].reshape(-1)[sel]

            Qbb = (yb[:, None] * yb[None, :]) * kernel.pairwise(xb, xb)
            new_ab = _solve_small_qp(Qbb, gb, ab, C, cfg.sweeps)
            delta = new_ab - ab

            # local rank-B gradient update (Pallas cd_update on TPU)
            Kb = kernel.pairwise(Xl, xb)                      # (n_l, B)
            g_l = g_l + (yl[:, None] * (Kb * yb[None, :])) @ delta

            # owners scatter alpha updates into their shard
            me = lax.axis_index(axis)
            own = owner == me
            safe_idx = jnp.where(own, lidx, 0)
            al = al.at[safe_idx].add(jnp.where(own, delta, 0.0))

            pg_max = lax.pmax(jnp.max(scores), axis)
            return al, g_l, it + 1, pg_max

        pg0 = lax.pmax(jnp.max(jnp.abs(proj_grad(al, g_l, C))), axis)
        al, g_l, iters, pg_max = lax.while_loop(cond, body, (al, g_l, 0, pg0))
        return al, jnp.asarray(iters)[None], pg_max[None]

    spec = P(axis)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=(spec, P(axis), P(axis)),
    )
    alpha, iters, pg = fn(X, y, alpha0)
    return alpha, iters[0], jnp.max(pg)


# ---------------------------------------------------------------------------
# full distributed DC-SVM driver
# ---------------------------------------------------------------------------

def fit_distributed(
    cfg,
    mesh: Mesh,
    axis: str,
    X: Array,
    y: Array,
    conquer_block: int = 64,
    conquer_iters: int = 5_000,
):
    """Multilevel DC-SVM where every level's cluster solves run sharded over
    ``axis`` and the final conquer runs the distributed block CD.

    ``cfg`` is a core.dcsvm.DCSVMConfig.  Cluster counts are rounded up to a
    multiple of the axis size so every device gets equal work (balanced
    clusters double as straggler mitigation: lockstep SPMD with equal tiles).
    Returns (alpha, stats list).
    """
    from repro.core.kkmeans import two_step_kernel_kmeans

    P_ = mesh.shape[axis]
    n = X.shape[0]
    key = jax.random.PRNGKey(cfg.seed)
    rngnp = np.random.default_rng(cfg.seed)
    alpha = jnp.zeros(n, X.dtype)
    sv_idx = None
    stats = []

    for l in range(cfg.levels, 0, -1):
        kl = max(cfg.k ** l, P_)
        kl = -(-kl // P_) * P_          # multiple of device count
        if kl >= n // 2:
            continue
        key, sub = jax.random.split(key)
        sample_idx = None
        if cfg.adaptive and sv_idx is not None and len(sv_idx) > kl:
            sample_idx = rngnp.choice(sv_idx, size=min(cfg.m, len(sv_idx)),
                                      replace=False)
        part = two_step_kernel_kmeans(cfg.kernel, X, kl, sub, m=cfg.m,
                                      iters=cfg.kmeans_iters,
                                      sample_idx=sample_idx,
                                      balanced=True)
        Xc = part.gather(X)
        yc = part.gather(y)
        mask = jnp.asarray(part.mask)
        ac = jnp.where(mask, part.gather(alpha), 0.0)
        ac = divide_step(mesh, axis, cfg, Xc, yc, ac, mask)
        alpha = part.scatter(ac, n)
        sv_idx = np.nonzero(np.asarray(alpha) > 0)[0]
        stats.append(dict(level=l, clusters=kl, n_sv=int(len(sv_idx))))

    ccfg = ConquerConfig(kernel=cfg.kernel, C=cfg.C, tol=cfg.tol,
                         max_iters=conquer_iters, block=conquer_block,
                         sweeps=cfg.sweeps)
    alpha, iters, pg = conquer_step(mesh, axis, ccfg, X, y, alpha)
    stats.append(dict(level=0, iters=int(iters), pg_max=float(pg),
                      n_sv=int(np.sum(np.asarray(alpha) > 0))))
    return alpha, stats
