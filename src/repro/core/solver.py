"""Box-constrained QP solvers for the generalized kernel-machine dual.

    min_u  f(u) = 1/2 u' Q u + p' u     s.t.  0 <= u <= c

with per-coordinate linear term ``p`` and per-coordinate upper bound ``c``
(both broadcast from scalars).  The classic C-SVC hinge dual is the default
instantiation ``p = -1, c = C`` — every task in ``repro.core.tasks`` (C-SVC,
weighted C-SVC, epsilon-SVR) reduces to this one problem with
``Q = (s s') ∘ K`` for a task-specific sign vector ``s``.

Because the paper drops the bias term there is no equality constraint, so
single-coordinate updates are exactly solvable in closed form:

    u_i <- clip(u_i - g_i / Q_ii, 0, c_i),      g = Q u + p.

Solvers (all pure JAX, `lax` control flow, vmap-able over a leading batch of
independent subproblems — the divide step solves all clusters of one level in
a single vmapped call):

* ``solve_box_qp``        — greedy (Gauss-Southwell) CD, the paper-faithful
                            solver (LIBSVM's selection rule without bias).
* ``solve_box_qp_block``  — beyond-paper batched variant: select top-B
                            coordinates by projected gradient, solve the BxB
                            sub-QP, rank-B gradient update (MXU-friendly).
* ``solve_box_qp_matvec`` — block CD with on-the-fly kernel columns; never
                            materializes Q (top-level conquer at large n).

Stopping criterion everywhere: max_i |projected gradient| < tol — identical
semantics to LIBSVM's epsilon on the violating pair, adapted to the
bias-free dual.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import colcache
from repro.core.kernels import Kernel

Array = jax.Array


def _broadcast(v, n: int, dtype) -> Array:
    """Scalar-or-vector parameter -> (n,) vector (p and c are per-coordinate
    in the generalized dual; the scalar hinge defaults broadcast)."""
    return jnp.broadcast_to(jnp.asarray(v, dtype), (n,))


class SolveResult(NamedTuple):
    alpha: Array
    grad: Array          # g = Q a + p at the returned alpha
    iters: Array         # number of outer iterations executed
    pg_max: Array        # final max |projected gradient|
    cache_hits: Optional[Array] = None    # column-cache rows served (matvec solver)
    cache_misses: Optional[Array] = None  # column-cache rows recomputed


def objective(alpha: Array, grad: Array, p=-1.0) -> Array:
    """f(u) = 1/2 u'Qu + p'u evaluated from the maintained gradient.

    With g = Qu + p we have u'g = u'Qu + p'u, hence

        f(u) = 1/2 (u'g - p'u) + p'u = 1/2 u'g + 1/2 p'u.

    The default ``p = -1`` recovers the hinge form 1/2 a'g - 1/2 e'a.
    """
    pu = jnp.sum(jnp.asarray(p, alpha.dtype) * alpha)
    return 0.5 * jnp.vdot(alpha, grad) + 0.5 * pu


def proj_grad(alpha: Array, grad: Array, C) -> Array:
    """Projected gradient of the box QP (the KKT residual).  ``C`` is the
    upper bound, scalar or per-coordinate."""
    at_lo = alpha <= 0.0
    at_hi = alpha >= C
    pg = jnp.where(at_lo, jnp.minimum(grad, 0.0), grad)
    pg = jnp.where(at_hi, jnp.maximum(grad, 0.0), pg)
    return pg


def kkt_residual(Q: Array, alpha: Array, C, p=-1.0) -> Array:
    g = Q @ alpha + jnp.asarray(p, alpha.dtype)
    return jnp.max(jnp.abs(proj_grad(alpha, g, C)))


# ---------------------------------------------------------------------------
# Greedy single-coordinate CD (paper-faithful conquer/sub-solver)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters",))
def solve_box_qp(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    active_mask: Optional[Array] = None,
    p=-1.0,
) -> SolveResult:
    """Greedy coordinate descent on a dense Q. vmap over leading dims is fine.

    ``C`` (upper bound) and ``p`` (linear term) are scalar or per-coordinate
    vectors; the defaults ``C`` scalar, ``p = -1`` are the C-SVC hinge dual.
    ``active_mask`` freezes coordinates (shrinking): masked-out coordinates
    are never selected (their pg is treated as 0 for selection AND stopping,
    matching LIBSVM's shrunk working set).
    """
    n = Q.shape[0]
    diag = jnp.maximum(jnp.diagonal(Q), 1e-12)
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    pvec = _broadcast(p, n, Q.dtype)
    g = Q @ alpha + pvec
    mask = jnp.ones(n, bool) if active_mask is None else active_mask

    def cond(state):
        _, _, it, pg_max = state
        return (pg_max > tol) & (it < max_iters)

    def body(state):
        alpha, g, it, _ = state
        pg = jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)
        i = jnp.argmax(jnp.abs(pg))
        new_ai = jnp.clip(alpha[i] - g[i] / diag[i], 0.0, cvec[i])
        delta = new_ai - alpha[i]
        alpha = alpha.at[i].set(new_ai)
        g = g + delta * Q[:, i]
        # stopping value computed from the *pre-update* pg (cheap, standard)
        return alpha, g, it + 1, jnp.max(jnp.abs(pg))

    # one priming evaluation so the loop can exit immediately at the optimum
    pg0 = jnp.max(jnp.abs(jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)))
    alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
    return SolveResult(alpha, g, iters, pg_max)


# ---------------------------------------------------------------------------
# Block greedy CD (beyond-paper batched variant)
# ---------------------------------------------------------------------------

def _solve_small_qp(Qbb: Array, gb: Array, ab: Array, cb, sweeps: int) -> Array:
    """Cyclic CD on the BxB subproblem. g_b is the gradient at entry; we
    maintain it locally.  ``cb`` is the upper bound, scalar or the (B,)
    slice of the per-coordinate box.  Returns the new a_b."""
    B = Qbb.shape[0]
    cb = _broadcast(cb, B, Qbb.dtype)
    diag = jnp.maximum(jnp.diagonal(Qbb), 1e-12)

    def body(t, carry):
        a, g = carry
        j = t % B
        new_aj = jnp.clip(a[j] - g[j] / diag[j], 0.0, cb[j])
        delta = new_aj - a[j]
        a = a.at[j].set(new_aj)
        g = g + delta * Qbb[:, j]
        return a, g

    a, _ = lax.fori_loop(0, sweeps * B, body, (ab, gb))
    return a


@partial(jax.jit, static_argnames=("block", "sweeps", "max_iters"))
def solve_box_qp_block(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 2_000,
    block: int = 32,
    sweeps: int = 4,
    active_mask: Optional[Array] = None,
    p=-1.0,
) -> SolveResult:
    """Top-B greedy block CD: each outer iteration moves B coordinates.

    Selection by |projected gradient| (Gauss-Southwell-B). The rank-B gradient
    update `g += Q[:, idx] @ delta` is a skinny matmul — the MXU-friendly
    reshaping of the paper's one-at-a-time CD.  ``C``/``p`` may be
    per-coordinate vectors (generalized dual).
    """
    n = Q.shape[0]
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    g = Q @ alpha + _broadcast(p, n, Q.dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask

    def cond(state):
        _, _, it, pg_max = state
        return (pg_max > tol) & (it < max_iters)

    def body(state):
        alpha, g, it, _ = state
        pg = jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)
        scores = jnp.abs(pg)
        _, idx = lax.top_k(scores, block)
        Qbb = Q[idx][:, idx]
        ab, gb = alpha[idx], g[idx]
        new_ab = _solve_small_qp(Qbb, gb, ab, cvec[idx], sweeps)
        delta = new_ab - ab
        alpha = alpha.at[idx].set(new_ab)
        g = g + Q[:, idx] @ delta
        return alpha, g, it + 1, jnp.max(scores)

    pg0 = jnp.max(jnp.abs(jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)))
    alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
    return SolveResult(alpha, g, iters, pg_max)


# ---------------------------------------------------------------------------
# Matvec-free block CD: kernel columns computed on the fly (large n)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("kernel", "block", "sweeps", "max_iters",
                                   "grad_chunks", "use_pallas", "cache_cap"))
def solve_box_qp_matvec(
    X: Array,
    y: Array,
    kernel: Kernel,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 500,
    block: int = 64,
    sweeps: int = 4,
    grad_chunks: int = 16,
    use_pallas: bool = False,
    cache_cap: int = 0,
    p=-1.0,
) -> SolveResult:
    """Block greedy CD where Q columns are recomputed from (X, y) per step.

    ``y`` is the generalized sign vector ``s`` of Q = (s s') ∘ K — class
    labels for C-SVC, the (+1, -1) mirror signs for epsilon-SVR's stacked
    (alpha, alpha*) coordinates.  ``C`` and ``p`` may be per-coordinate
    (weighted classes / the SVR linear term eps -/+ y).

    Never materializes Q.  Three gradient-update paths:

    * ``use_pallas=False, cache_cap=0`` — XLA reference: the (n, B) column
      block via ``kernel.pairwise`` each outer iteration.
    * ``use_pallas=True, cache_cap=0`` — fully fused: rank-B update through
      ``repro.kernels.ops.cd_column_update`` (the (n, B) kernel block lives
      only in VMEM, per tile) and gradient init through the streaming
      ``kernel_matvec`` kernel.
    * ``cache_cap>0`` — device-resident LRU column cache (``core.colcache``):
      a block whose B rows are all cached is served from HBM with no kernel
      compute at all (``lax.cond`` skips it); otherwise the B rows are
      recomputed (Pallas ``kermat`` on the fused path) and refilled into the
      cache.  Hit/miss row counts are returned on ``SolveResult``.
    """
    n = X.shape[0]
    alpha = jnp.zeros(n, X.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, X.dtype)

    # initial gradient g = Q @ alpha + p: streaming Pallas matvec on the
    # fused path, chunked lax.map otherwise
    from repro.core.kernels import gram_matvec

    if use_pallas:
        from repro.kernels import ops as kops

    # accumulation dtype: at least f32 (Pallas kernels accumulate in f32),
    # f64 preserved when x64 is enabled
    acc = jnp.promote_types(X.dtype, jnp.float32)

    def q_matvec(v):
        return y * gram_matvec(kernel, X, y * v, num_chunks=grad_chunks,
                               use_pallas=use_pallas)

    g = (q_matvec(alpha) + _broadcast(p, n, X.dtype)).astype(acc)

    def select(alpha, g):
        pg = proj_grad(alpha, g, cvec)
        scores = jnp.abs(pg)
        _, idx = lax.top_k(scores, block)
        return idx, jnp.max(scores)

    def solve_block(Qbb, alpha, g, idx):
        ab, gb = alpha[idx], g[idx]
        new_ab = _solve_small_qp(Qbb, gb, ab, cvec[idx], sweeps)
        return new_ab, new_ab - ab

    def q_rows(idx):
        """(B, n) rows of Q for the selected block (Q is symmetric)."""
        Xb, yb = X[idx], y[idx]
        if use_pallas:
            Kb = kops.kernel_matrix(Xb, X, kernel)
        else:
            Kb = kernel.pairwise(Xb, X)
        return ((yb[:, None] * y[None, :]) * Kb).astype(acc)

    if cache_cap > 0:
        cap = max(cache_cap, block)  # must hold at least one full block

        def body(state):
            alpha, g, cache, it, _ = state
            idx, pg_max = select(alpha, g)
            slots, hit = colcache.lookup(cache, idx)
            served = jnp.all(hit)
            Qrows = lax.cond(
                served,
                lambda: cache.cols[jnp.where(hit, slots, 0)],
                lambda: q_rows(idx),
            )
            cache = colcache.update(cache, idx, Qrows, served, slots, hit)
            new_ab, delta = solve_block(Qrows[:, idx], alpha, g, idx)
            alpha = alpha.at[idx].set(new_ab)
            g = g + delta @ Qrows
            return alpha, g, cache, it + 1, pg_max

        def cond(state):
            _, _, _, it, pg_max = state
            return (pg_max > tol) & (it < max_iters)

        pg0 = jnp.max(jnp.abs(proj_grad(alpha, g, cvec)))
        alpha, g, cache, iters, pg_max = lax.while_loop(
            cond, body, (alpha, g, colcache.init(cap, n, dtype=acc), 0, pg0))
        return SolveResult(alpha, g, iters, pg_max, cache.hits, cache.misses)

    def body(state):
        alpha, g, it, _ = state
        idx, pg_max = select(alpha, g)
        Xb, yb = X[idx], y[idx]
        if use_pallas:
            # fused: dg = y * (K(X, Xb) @ (yb * delta)); the (n, B) block
            # never leaves VMEM — only the (B, B) working-set block is formed
            Kbb = kernel.pairwise(Xb, Xb)
            Qbb = ((yb[:, None] * yb[None, :]) * Kbb).astype(acc)
            new_ab, delta = solve_block(Qbb, alpha, g, idx)
            alpha = alpha.at[idx].set(new_ab)
            g = g + kops.cd_column_update(X, y, Xb, yb * delta, kernel)
        else:
            Kb = kernel.pairwise(X, Xb)              # (n, B) on the fly
            Qb = ((y[:, None] * yb[None, :]) * Kb).astype(acc)
            Qbb = Qb[idx]                            # slice, don't recompute
            new_ab, delta = solve_block(Qbb, alpha, g, idx)
            alpha = alpha.at[idx].set(new_ab)
            g = g + Qb @ delta
        return alpha, g, it + 1, pg_max

    def cond(state):
        _, _, it, pg_max = state
        return (pg_max > tol) & (it < max_iters)

    pg0 = jnp.max(jnp.abs(proj_grad(alpha, g, cvec)))
    alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
    return SolveResult(alpha, g, iters, pg_max)


# ---------------------------------------------------------------------------
# Shrinking wrapper (LIBSVM-style outer rounds)
# ---------------------------------------------------------------------------

def solve_with_shrinking(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    rounds: int = 3,
    shrink_margin: float = 10.0,
    block: int = 0,
    p=-1.0,
) -> SolveResult:
    """Outer shrinking rounds around the CD solver.

    Each round: solve on the active set to ``tol``; variables pinned at a
    bound with |g| > shrink_margin * tol are removed from the active set for
    the next round; the final round always re-activates everything so the
    returned KKT residual is on the FULL problem (LIBSVM's un-shrink check).
    ``C``/``p`` may be per-coordinate vectors (generalized dual).

    ``pg_max`` is recomputed at the returned alpha (one Q @ alpha matvec):
    the inner solvers report the stopping value from the last *pre-update*
    iterate, which is not the residual of the solution they return.
    """
    n = Q.shape[0]
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    mask = jnp.ones(n, bool)
    solver = solve_box_qp if block <= 0 else partial(solve_box_qp_block, block=block)
    res = None
    # iteration counts accumulate on device; converting per round would force
    # a host sync between rounds and serialize dispatch
    total_iters = jnp.zeros((), jnp.int32)
    for r in range(rounds):
        final = r == rounds - 1
        m = jnp.ones(n, bool) if final else mask
        res = solver(Q, C, alpha0=alpha, tol=tol, max_iters=max_iters,
                     active_mask=m, p=p)
        alpha, g = res.alpha, res.grad
        total_iters = total_iters + res.iters
        strongly_lo = (alpha <= 0.0) & (g > shrink_margin * tol)
        strongly_hi = (alpha >= cvec) & (g < -shrink_margin * tol)
        mask = ~(strongly_lo | strongly_hi)
    pg_full = kkt_residual(Q, res.alpha, cvec, p=p)
    return SolveResult(res.alpha, res.grad, total_iters, pg_full)
