"""QP solvers for the two generalized kernel-machine dual families.

Box family (the paper's bias-free hinge dual and its task generalizations):

    min_u  f(u) = 1/2 u' Q u + p' u     s.t.  0 <= u <= c

with per-coordinate linear term ``p`` and per-coordinate upper bound ``c``
(both broadcast from scalars).  The classic C-SVC hinge dual is the default
instantiation ``p = -1, c = C`` — C-SVC, weighted C-SVC and epsilon-SVR in
``repro.core.tasks`` reduce to this one problem with ``Q = (s s') ∘ K`` for
a task-specific sign vector ``s``.  Because the paper drops the bias term
there is no equality constraint, so single-coordinate updates are exactly
solvable in closed form:

    u_i <- clip(u_i - g_i / Q_ii, 0, c_i),      g = Q u + p.

Equality-constrained family (one-class SVM, nu-SVC — DESIGN.md §9):

    min_u  f(u) = 1/2 u' Q u + p' u     s.t.  0 <= u <= c,  a' u = d

with a nonzero coefficient vector ``a`` (possibly mixed-sign).  Single
coordinates can no longer move alone; the solver takes SMO-style *pairwise*
steps along the constraint-neutral direction ``e_i/a_i - e_j/a_j`` chosen by
the maximal-violating-pair rule, so every iterate stays on the hyperplane.

Solvers (all pure JAX, `lax` control flow, vmap-able over a leading batch of
independent subproblems — the divide step solves all clusters of one level in
a single vmapped call):

* ``solve_box_qp``        — greedy (Gauss-Southwell) CD, the paper-faithful
                            solver (LIBSVM's selection rule without bias).
* ``solve_box_qp_block``  — beyond-paper batched variant: select top-B
                            coordinates by projected gradient, solve the BxB
                            sub-QP, rank-B gradient update (MXU-friendly).
* ``solve_box_qp_matvec`` — block CD with on-the-fly kernel columns; never
                            materializes Q (top-level conquer at large n).
* ``solve_eq_qp``         — pairwise maximal-violating-pair CD on a dense Q
                            for the equality-constrained family.
* ``solve_eq_qp_shrink``  — LIBSVM-style outer shrinking rounds around it.
* ``solve_eq_qp_matvec``  — the same pairwise engine with on-the-fly kernel
                            columns (fused Pallas path available).

Stopping criterion: max |projected gradient| < tol for the box family;
``rho_lo - rho_hi < tol`` (the maximal-violating-pair gap of the equality
multiplier bracket, LIBSVM's working-set criterion) for the equality family.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import colcache
from repro.core.kernels import Kernel

Array = jax.Array


def _broadcast(v, n: int, dtype) -> Array:
    """Scalar-or-vector parameter -> (n,) vector (p and c are per-coordinate
    in the generalized dual; the scalar hinge defaults broadcast)."""
    return jnp.broadcast_to(jnp.asarray(v, dtype), (n,))


class SolveResult(NamedTuple):
    alpha: Array
    grad: Array          # g = Q a + p at the returned alpha
    iters: Array         # number of outer iterations executed
    pg_max: Array        # final max |projected gradient|
    cache_hits: Optional[Array] = None    # column-cache rows served (matvec solver)
    cache_misses: Optional[Array] = None  # column-cache rows recomputed


def objective(alpha: Array, grad: Array, p=-1.0) -> Array:
    """f(u) = 1/2 u'Qu + p'u evaluated from the maintained gradient.

    With g = Qu + p we have u'g = u'Qu + p'u, hence

        f(u) = 1/2 (u'g - p'u) + p'u = 1/2 u'g + 1/2 p'u.

    The default ``p = -1`` recovers the hinge form 1/2 a'g - 1/2 e'a.
    """
    pu = jnp.sum(jnp.asarray(p, alpha.dtype) * alpha)
    return 0.5 * jnp.vdot(alpha, grad) + 0.5 * pu


def proj_grad(alpha: Array, grad: Array, C) -> Array:
    """Projected gradient of the box QP (the KKT residual).  ``C`` is the
    upper bound, scalar or per-coordinate."""
    at_lo = alpha <= 0.0
    at_hi = alpha >= C
    pg = jnp.where(at_lo, jnp.minimum(grad, 0.0), grad)
    pg = jnp.where(at_hi, jnp.maximum(grad, 0.0), pg)
    return pg


def kkt_residual(Q: Array, alpha: Array, C, p=-1.0) -> Array:
    g = Q @ alpha + jnp.asarray(p, alpha.dtype)
    return jnp.max(jnp.abs(proj_grad(alpha, g, C)))


# ---------------------------------------------------------------------------
# Greedy single-coordinate CD (paper-faithful conquer/sub-solver)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters",))
def solve_box_qp(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    active_mask: Optional[Array] = None,
    p=-1.0,
) -> SolveResult:
    """Greedy coordinate descent on a dense Q. vmap over leading dims is fine.

    ``C`` (upper bound) and ``p`` (linear term) are scalar or per-coordinate
    vectors; the defaults ``C`` scalar, ``p = -1`` are the C-SVC hinge dual.
    ``active_mask`` freezes coordinates (shrinking): masked-out coordinates
    are never selected (their pg is treated as 0 for selection AND stopping,
    matching LIBSVM's shrunk working set).
    """
    n = Q.shape[0]
    diag = jnp.maximum(jnp.diagonal(Q), 1e-12)
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    pvec = _broadcast(p, n, Q.dtype)
    g = Q @ alpha + pvec
    mask = jnp.ones(n, bool) if active_mask is None else active_mask

    def cond(state):
        _, _, it, pg_max = state
        return (pg_max > tol) & (it < max_iters)

    def body(state):
        alpha, g, it, _ = state
        pg = jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)
        i = jnp.argmax(jnp.abs(pg))
        new_ai = jnp.clip(alpha[i] - g[i] / diag[i], 0.0, cvec[i])
        delta = new_ai - alpha[i]
        alpha = alpha.at[i].set(new_ai)
        g = g + delta * Q[:, i]
        # stopping value computed from the *pre-update* pg (cheap, standard)
        return alpha, g, it + 1, jnp.max(jnp.abs(pg))

    # one priming evaluation so the loop can exit immediately at the optimum
    pg0 = jnp.max(jnp.abs(jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)))
    alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
    return SolveResult(alpha, g, iters, pg_max)


# ---------------------------------------------------------------------------
# Block greedy CD (beyond-paper batched variant)
# ---------------------------------------------------------------------------

def _solve_small_qp(Qbb: Array, gb: Array, ab: Array, cb, sweeps: int) -> Array:
    """Cyclic CD on the BxB subproblem. g_b is the gradient at entry; we
    maintain it locally.  ``cb`` is the upper bound, scalar or the (B,)
    slice of the per-coordinate box.  Returns the new a_b."""
    B = Qbb.shape[0]
    cb = _broadcast(cb, B, Qbb.dtype)
    diag = jnp.maximum(jnp.diagonal(Qbb), 1e-12)

    def body(t, carry):
        a, g = carry
        j = t % B
        new_aj = jnp.clip(a[j] - g[j] / diag[j], 0.0, cb[j])
        delta = new_aj - a[j]
        a = a.at[j].set(new_aj)
        g = g + delta * Qbb[:, j]
        return a, g

    a, _ = lax.fori_loop(0, sweeps * B, body, (ab, gb))
    return a


@partial(jax.jit, static_argnames=("block", "sweeps", "max_iters"))
def solve_box_qp_block(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 2_000,
    block: int = 32,
    sweeps: int = 4,
    active_mask: Optional[Array] = None,
    p=-1.0,
) -> SolveResult:
    """Top-B greedy block CD: each outer iteration moves B coordinates.

    Selection by |projected gradient| (Gauss-Southwell-B). The rank-B gradient
    update `g += Q[:, idx] @ delta` is a skinny matmul — the MXU-friendly
    reshaping of the paper's one-at-a-time CD.  ``C``/``p`` may be
    per-coordinate vectors (generalized dual).
    """
    n = Q.shape[0]
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    g = Q @ alpha + _broadcast(p, n, Q.dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask

    def cond(state):
        _, _, it, pg_max = state
        return (pg_max > tol) & (it < max_iters)

    def body(state):
        alpha, g, it, _ = state
        pg = jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)
        scores = jnp.abs(pg)
        _, idx = lax.top_k(scores, block)
        Qbb = Q[idx][:, idx]
        ab, gb = alpha[idx], g[idx]
        new_ab = _solve_small_qp(Qbb, gb, ab, cvec[idx], sweeps)
        delta = new_ab - ab
        alpha = alpha.at[idx].set(new_ab)
        g = g + Q[:, idx] @ delta
        return alpha, g, it + 1, jnp.max(scores)

    pg0 = jnp.max(jnp.abs(jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)))
    alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
    return SolveResult(alpha, g, iters, pg_max)


# ---------------------------------------------------------------------------
# Matvec-free block CD: kernel columns computed on the fly (large n)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("kernel", "block", "sweeps", "max_iters",
                                   "grad_chunks", "use_pallas", "cache_cap"))
def solve_box_qp_matvec(
    X: Array,
    y: Array,
    kernel: Kernel,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 500,
    block: int = 64,
    sweeps: int = 4,
    grad_chunks: int = 16,
    use_pallas: bool = False,
    cache_cap: int = 0,
    p=-1.0,
) -> SolveResult:
    """Block greedy CD where Q columns are recomputed from (X, y) per step.

    ``y`` is the generalized sign vector ``s`` of Q = (s s') ∘ K — class
    labels for C-SVC, the (+1, -1) mirror signs for epsilon-SVR's stacked
    (alpha, alpha*) coordinates.  ``C`` and ``p`` may be per-coordinate
    (weighted classes / the SVR linear term eps -/+ y).

    Never materializes Q.  Three gradient-update paths:

    * ``use_pallas=False, cache_cap=0`` — XLA reference: the (n, B) column
      block via ``kernel.pairwise`` each outer iteration.
    * ``use_pallas=True, cache_cap=0`` — fully fused: rank-B update through
      ``repro.kernels.ops.cd_column_update`` (the (n, B) kernel block lives
      only in VMEM, per tile) and gradient init through the streaming
      ``kernel_matvec`` kernel.
    * ``cache_cap>0`` — device-resident LRU column cache (``core.colcache``):
      a block whose B rows are all cached is served from HBM with no kernel
      compute at all (``lax.cond`` skips it); otherwise the B rows are
      recomputed (Pallas ``kermat`` on the fused path) and refilled into the
      cache.  Hit/miss row counts are returned on ``SolveResult``.
    """
    n = X.shape[0]
    alpha = jnp.zeros(n, X.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, X.dtype)

    # initial gradient g = Q @ alpha + p: streaming Pallas matvec on the
    # fused path, chunked lax.map otherwise
    from repro.core.kernels import gram_matvec

    if use_pallas:
        from repro.kernels import ops as kops

    # accumulation dtype: at least f32 (Pallas kernels accumulate in f32),
    # f64 preserved when x64 is enabled
    acc = jnp.promote_types(X.dtype, jnp.float32)

    def q_matvec(v):
        return y * gram_matvec(kernel, X, y * v, num_chunks=grad_chunks,
                               use_pallas=use_pallas)

    g = (q_matvec(alpha) + _broadcast(p, n, X.dtype)).astype(acc)

    def select(alpha, g):
        pg = proj_grad(alpha, g, cvec)
        scores = jnp.abs(pg)
        _, idx = lax.top_k(scores, block)
        return idx, jnp.max(scores)

    def solve_block(Qbb, alpha, g, idx):
        ab, gb = alpha[idx], g[idx]
        new_ab = _solve_small_qp(Qbb, gb, ab, cvec[idx], sweeps)
        return new_ab, new_ab - ab

    def q_rows(idx):
        """(B, n) rows of Q for the selected block (Q is symmetric)."""
        Xb, yb = X[idx], y[idx]
        if use_pallas:
            Kb = kops.kernel_matrix(Xb, X, kernel)
        else:
            Kb = kernel.pairwise(Xb, X)
        return ((yb[:, None] * y[None, :]) * Kb).astype(acc)

    if cache_cap > 0:
        cap = max(cache_cap, block)  # must hold at least one full block

        def body(state):
            alpha, g, cache, it, _ = state
            idx, pg_max = select(alpha, g)
            slots, hit = colcache.lookup(cache, idx)
            served = jnp.all(hit)
            Qrows = lax.cond(
                served,
                lambda: cache.cols[jnp.where(hit, slots, 0)],
                lambda: q_rows(idx),
            )
            cache = colcache.update(cache, idx, Qrows, served, slots, hit)
            new_ab, delta = solve_block(Qrows[:, idx], alpha, g, idx)
            alpha = alpha.at[idx].set(new_ab)
            g = g + delta @ Qrows
            return alpha, g, cache, it + 1, pg_max

        def cond(state):
            _, _, _, it, pg_max = state
            return (pg_max > tol) & (it < max_iters)

        pg0 = jnp.max(jnp.abs(proj_grad(alpha, g, cvec)))
        alpha, g, cache, iters, pg_max = lax.while_loop(
            cond, body, (alpha, g, colcache.init(cap, n, dtype=acc), 0, pg0))
        return SolveResult(alpha, g, iters, pg_max, cache.hits, cache.misses)

    def body(state):
        alpha, g, it, _ = state
        idx, pg_max = select(alpha, g)
        Xb, yb = X[idx], y[idx]
        if use_pallas:
            # fused: dg = y * (K(X, Xb) @ (yb * delta)); the (n, B) block
            # never leaves VMEM — only the (B, B) working-set block is formed
            Kbb = kernel.pairwise(Xb, Xb)
            Qbb = ((yb[:, None] * yb[None, :]) * Kbb).astype(acc)
            new_ab, delta = solve_block(Qbb, alpha, g, idx)
            alpha = alpha.at[idx].set(new_ab)
            g = g + kops.cd_column_update(X, y, Xb, yb * delta, kernel)
        else:
            Kb = kernel.pairwise(X, Xb)              # (n, B) on the fly
            Qb = ((y[:, None] * yb[None, :]) * Kb).astype(acc)
            Qbb = Qb[idx]                            # slice, don't recompute
            new_ab, delta = solve_block(Qbb, alpha, g, idx)
            alpha = alpha.at[idx].set(new_ab)
            g = g + Qb @ delta
        return alpha, g, it + 1, pg_max

    def cond(state):
        _, _, it, pg_max = state
        return (pg_max > tol) & (it < max_iters)

    pg0 = jnp.max(jnp.abs(proj_grad(alpha, g, cvec)))
    alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
    return SolveResult(alpha, g, iters, pg_max)


# ---------------------------------------------------------------------------
# Shrinking wrapper (LIBSVM-style outer rounds)
# ---------------------------------------------------------------------------

def solve_with_shrinking(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    rounds: int = 3,
    shrink_margin: float = 10.0,
    block: int = 0,
    p=-1.0,
) -> SolveResult:
    """Outer shrinking rounds around the CD solver.

    Each round: solve on the active set to ``tol``; variables pinned at a
    bound with |g| > shrink_margin * tol are removed from the active set for
    the next round; the final round always re-activates everything so the
    returned KKT residual is on the FULL problem (LIBSVM's un-shrink check).
    ``C``/``p`` may be per-coordinate vectors (generalized dual).

    ``pg_max`` is recomputed at the returned alpha (one Q @ alpha matvec):
    the inner solvers report the stopping value from the last *pre-update*
    iterate, which is not the residual of the solution they return.
    """
    if rounds < 1:
        raise ValueError(f"shrinking needs rounds >= 1, got {rounds}")
    n = Q.shape[0]
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    mask = jnp.ones(n, bool)
    solver = solve_box_qp if block <= 0 else partial(solve_box_qp_block, block=block)
    res = None
    # iteration counts accumulate on device; converting per round would force
    # a host sync between rounds and serialize dispatch
    total_iters = jnp.zeros((), jnp.int32)
    for r in range(rounds):
        final = r == rounds - 1
        m = jnp.ones(n, bool) if final else mask
        res = solver(Q, C, alpha0=alpha, tol=tol, max_iters=max_iters,
                     active_mask=m, p=p)
        alpha, g = res.alpha, res.grad
        total_iters = total_iters + res.iters
        strongly_lo = (alpha <= 0.0) & (g > shrink_margin * tol)
        strongly_hi = (alpha >= cvec) & (g < -shrink_margin * tol)
        mask = ~(strongly_lo | strongly_hi)
    pg_full = kkt_residual(Q, res.alpha, cvec, p=p)
    return SolveResult(res.alpha, res.grad, total_iters, pg_full)


# ---------------------------------------------------------------------------
# Equality-constrained dual: pairwise (SMO-style) maximal-violating-pair CD
#
#     min 1/2 u'Qu + p'u   s.t.  0 <= u <= c,  a'u = d      (a_i != 0)
#
# KKT: there exists a multiplier rho with, per coordinate, h_i = g_i / a_i
# (g = Qu + p) satisfying  h_i = rho on free coordinates and one-sided
# inequalities at the bounds.  Every coordinate therefore contributes a
# one-sided bound on rho; optimality <=> the bracket [rho_lo, rho_hi] is
# non-empty.  The solver repeatedly picks the maximal violating pair
# (j = argmax of the lower bounds, i = argmin of the upper bounds) and takes
# the exact minimizer along u + t (e_i/a_i - e_j/a_j), which preserves a'u
# for every t.  See DESIGN.md §9 for the derivation.
# ---------------------------------------------------------------------------

def _safe_a(avec: Array) -> Array:
    return jnp.where(avec == 0.0, 1.0, avec)


def _eq_direction_sets(alpha: Array, cvec: Array, avec: Array, mask: Array):
    """Slot membership for the pairwise step u += t (e_i/a_i - e_j/a_j), t>0.

    ``i_plus``: coordinates that can occupy the i slot (their u moves by
    +t/a_i, so they need room upward when a_i > 0, downward when a_i < 0);
    ``i_minus``: the j slot (u moves by -t/a_j).  Coordinates with a == 0
    never couple to the constraint and are excluded — they belong to the box
    family and must be handled by the box solvers.
    """
    ok = mask & (avec != 0.0)
    up = alpha < cvec
    dn = alpha > 0.0
    i_plus = ok & jnp.where(avec > 0, up, dn)
    i_minus = ok & jnp.where(avec > 0, dn, up)
    return i_plus, i_minus


def equality_interval(alpha: Array, grad: Array, C, a,
                      active_mask: Optional[Array] = None):
    """Bracket [rho_lo, rho_hi] of the equality multiplier at ``alpha``.

    KKT holds iff rho_lo <= rho_hi; the gap ``rho_lo - rho_hi`` is the
    maximal-violating-pair violation (LIBSVM's working-set criterion,
    generalized to arbitrary nonzero ``a``).  Empty sides return -inf/+inf.
    """
    n = alpha.shape[0]
    cvec = _broadcast(C, n, alpha.dtype)
    avec = _broadcast(a, n, alpha.dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask
    i_plus, i_minus = _eq_direction_sets(alpha, cvec, avec, mask)
    h = grad / _safe_a(avec)
    rho_lo = jnp.max(jnp.where(i_minus, h, -jnp.inf))
    rho_hi = jnp.min(jnp.where(i_plus, h, jnp.inf))
    return rho_lo, rho_hi


def kkt_residual_eq(Q: Array, alpha: Array, C, a, p=0.0) -> Array:
    """Maximal-violating-pair gap at ``alpha`` on the FULL problem (the
    equality-family analogue of ``kkt_residual``); 0 at any KKT point."""
    g = Q @ alpha + jnp.asarray(p, alpha.dtype)
    rho_lo, rho_hi = equality_interval(alpha, g, C, a)
    return jnp.maximum(rho_lo - rho_hi, 0.0)


def equality_rho(alpha: Array, grad: Array, C, a,
                 active_mask: Optional[Array] = None) -> Array:
    """Recover the equality multiplier rho (one-class SVM's decision offset)
    from the bracket midpoint; falls back to the finite side when a bound
    set is empty (all coordinates pinned at one bound)."""
    rho_lo, rho_hi = equality_interval(alpha, grad, C, a,
                                       active_mask=active_mask)
    mid = 0.5 * (rho_lo + rho_hi)
    rho = jnp.where(jnp.isfinite(mid), mid,
                    jnp.where(jnp.isfinite(rho_lo), rho_lo,
                              jnp.where(jnp.isfinite(rho_hi), rho_hi, 0.0)))
    return rho


def project_box_equality(alpha: Array, C, a, d,
                         active_mask: Optional[Array] = None,
                         iters: int = 64) -> Array:
    """Project onto {0 <= u <= c} ∩ {a'u = d} by moving along ``a``.

    phi(t) = a' clip(u - t a, 0, c) is monotone non-increasing in t, so the
    feasible point is found by bisection — exact whenever d lies in the
    attainable interval [sum_{a<0} a c, sum_{a>0} a c] (clamped otherwise).
    Coordinates outside ``active_mask`` (and a == 0 coordinates) are frozen
    at their clipped values but still counted toward a'u, so shrunk /
    padded coordinates keep their contribution.  Pure lax control flow:
    jit- and vmap-safe, used for feasible warm starts in the divide step.

    Already-feasible starts (to the rounding noise of measuring a'u) are
    returned bit-exact: the bisection's residual-noise-sized t would
    otherwise displace every bound coordinate by O(eps) off its bound,
    re-entering them into the pairwise solver's violating sets for nothing.
    """
    n = alpha.shape[0]
    dtype = alpha.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask
    amove = jnp.where(mask, avec, 0.0)
    base = jnp.clip(alpha, 0.0, cvec)
    d = jnp.asarray(d, dtype)

    def at_t(t):
        return jnp.clip(base - t * amove, 0.0, cvec)

    def resid(t):
        return jnp.vdot(avec, at_t(t)) - d

    # |t| >= c_i / |a_i| saturates every moving coordinate
    T = jnp.max(jnp.where(amove != 0.0,
                          cvec / jnp.maximum(jnp.abs(amove), 1e-12), 0.0)) + 1.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        go_right = resid(mid) > 0.0
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    lo, hi = lax.fori_loop(0, iters, body, (-T, T))
    noise = 8.0 * jnp.finfo(dtype).eps \
        * (jnp.sum(jnp.abs(avec * base)) + jnp.abs(d) + 1.0)
    return jnp.where(jnp.abs(resid(0.0)) <= noise, base, at_t(0.5 * (lo + hi)))


def _pair_step(alpha: Array, cvec: Array, avec: Array, i, j, t):
    """Apply the pairwise step of length ``t >= 0`` along e_i/a_i - e_j/a_j,
    clipped to both coordinates' boxes.  Returns (new_ai, di, new_aj, dj)
    with the realized deltas for the rank-2 gradient update.

    The coordinate whose box cap binds becomes the PRIMARY and lands
    EXACTLY on its bound (so it leaves the violating index sets); the other
    coordinate is slaved to the primary's realized delta, which preserves
    a'u to one rounding.  Driving the step from one fixed side instead
    stalls: when t is below the f32 ulp of the other coordinate its delta
    underflows to zero, the slaved bound coordinate never reaches its
    bound, and the same maximal-violating pair is selected forever.
    """
    ai, aj = avec[i], avec[j]
    t_hi_i = jnp.where(ai > 0, ai * (cvec[i] - alpha[i]), -ai * alpha[i])
    t_hi_j = jnp.where(aj > 0, aj * alpha[j], aj * (alpha[j] - cvec[j]))
    t = jnp.clip(t, 0.0, jnp.minimum(t_hi_i, t_hi_j))
    hit_i = t >= t_hi_i
    hit_j = t >= t_hi_j
    bound_i = jnp.where(ai > 0, cvec[i], 0.0)     # i slot moves toward here
    bound_j = jnp.where(aj > 0, 0.0, cvec[j])     # j slot moves toward here
    # j primary: j lands exactly on its bound, i is slaved
    dj_p = bound_j - alpha[j]
    ai_from_j = jnp.clip(alpha[i] - (aj * dj_p) / ai, 0.0, cvec[i])
    # i primary: exact bound when its cap binds, else the clipped t-step
    ai_from_t = jnp.where(hit_i, bound_i,
                          jnp.clip(alpha[i] + t / ai, 0.0, cvec[i]))
    new_ai = jnp.where(hit_j, ai_from_j, ai_from_t)
    di = new_ai - alpha[i]
    new_aj = jnp.where(hit_j, bound_j,
                       jnp.clip(alpha[j] - (ai * di) / aj, 0.0, cvec[j]))
    dj = new_aj - alpha[j]
    return new_ai, di, new_aj, dj


def _restore_equality(alpha: Array, grad: Array, Q_col, cvec: Array,
                      avec: Array, d, mask: Array):
    """One exact feasibility-restoration step: absorb the accumulated f32
    rounding drift of a'u - d into a single coordinate.

    The correction coordinate must stay STRICTLY interior before and after
    the move: nudging a bound coordinate off its bound re-enters it into the
    KKT index sets with its full multiplier discrepancy, turning an O(eps)
    feasibility fix into an O(1) jump of the maximal-violating-pair gap.  An
    interior coordinate moved by O(drift) changes the gap only by
    O(||Q|| drift).  Falls back to any maskable coordinate when the iterate
    is a vertex.  ``Q_col(k)`` returns column k of Q for the gradient fix-up.
    """
    r = jnp.vdot(avec, alpha) - jnp.asarray(d, alpha.dtype)
    cand = jnp.clip(alpha - r / _safe_a(avec), 0.0, cvec)
    resid = r + avec * (cand - alpha)
    ok = mask & (avec != 0.0)
    interior = ok & (alpha > 0.0) & (alpha < cvec) \
        & (cand > 0.0) & (cand < cvec)
    score_int = jnp.where(interior, jnp.abs(resid), jnp.inf)
    k_int = jnp.argmin(score_int)
    k_any = jnp.argmin(jnp.where(ok, jnp.abs(resid), jnp.inf))
    k = jnp.where(jnp.isfinite(score_int[k_int]), k_int, k_any)
    delta = cand[k] - alpha[k]
    alpha = alpha.at[k].set(cand[k])
    grad = grad + delta * Q_col(k)
    return alpha, grad


def _pairwise_mvp_loop(alpha, cvec, avec, mask, qdiag, qij_fn, rank2_fn,
                       full_grad, tol, max_iters, refresh_every):
    """Shared pairwise maximal-violating-pair engine (dense and matvec
    front-ends differ only in how Q entries and the rank-2 gradient update
    are produced).

    Structure: an outer loop of refresh blocks, each an inner loop of up to
    ``refresh_every`` rank-2 steps on the maintained gradient, followed by
    an UNCONDITIONAL from-scratch gradient recompute and a stopping test on
    the fresh gradient.  Two reasons over a single loop with a conditional
    refresh: (1) under vmap (every divide-step caller) a batched-predicate
    ``lax.cond`` executes both branches, which would silently run the full
    recompute every iteration; (2) the convergence test at a block boundary
    sees the TRUE gradient, so f32 drift accumulated across the block's
    rank-2 updates cannot make the stopping test lie at tight tolerances.
    Returns (alpha, grad, iters, pg_max) with ``iters`` counting pair steps
    and ``pg_max`` the last fresh-gradient violation.
    """
    safe = _safe_a(avec)

    def select(alpha, g):
        i_plus, i_minus = _eq_direction_sets(alpha, cvec, avec, mask)
        h = g / safe
        hi_side = jnp.where(i_plus, h, jnp.inf)
        lo_side = jnp.where(i_minus, h, -jnp.inf)
        i = jnp.argmin(hi_side)
        j = jnp.argmax(lo_side)
        return i, j, lo_side[j] - hi_side[i]

    def inner_cond(state):
        _, _, _, k, viol = state
        return (viol > tol) & (k < refresh_every)

    def inner_body(state):
        alpha, g, it, k, _ = state
        i, j, viol = select(alpha, g)
        ai, aj = avec[i], avec[j]
        # exact minimizer along v = e_i/a_i - e_j/a_j: phi'(0) = h_i - h_j,
        # phi'' = Q_ii/a_i^2 + Q_jj/a_j^2 - 2 Q_ij/(a_i a_j) >= 0 (Q PSD)
        curv = qdiag[i] / (ai * ai) + qdiag[j] / (aj * aj) \
            - 2.0 * qij_fn(i, j) / (ai * aj)
        t = jnp.maximum(viol, 0.0) / jnp.maximum(curv, 1e-12)
        new_ai, di, new_aj, dj = _pair_step(alpha, cvec, avec, i, j, t)
        alpha = alpha.at[i].set(new_ai).at[j].set(new_aj)
        g = rank2_fn(g, i, j, di, dj)
        return alpha, g, it + 1, k + 1, jnp.maximum(viol, 0.0)

    def outer_cond(state):
        _, _, it, viol = state
        return (viol > tol) & (it < max_iters)

    def outer_body(state):
        alpha, g, it, viol = state
        block = jnp.minimum(refresh_every, max_iters - it)
        alpha, g, it, _, _ = lax.while_loop(
            lambda st: inner_cond(st) & (st[3] < block), inner_body,
            (alpha, g, it, 0, viol))
        g = full_grad(alpha)
        _, _, viol = select(alpha, g)
        return alpha, g, it, jnp.maximum(viol, 0.0)

    g = full_grad(alpha)
    _, _, viol0 = select(alpha, g)
    return lax.while_loop(outer_cond, outer_body,
                          (alpha, g, 0, jnp.maximum(viol0, 0.0)))


@partial(jax.jit, static_argnames=("max_iters", "refresh_every"))
def solve_eq_qp(
    Q: Array,
    C,
    a,
    d,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    active_mask: Optional[Array] = None,
    p=0.0,
    refresh_every: int = 256,
) -> SolveResult:
    """Pairwise maximal-violating-pair CD on a dense Q; every iterate stays
    on the hyperplane a'u = d.  vmap over leading dims is fine.

    The (possibly infeasible) warm start is first projected onto the
    feasible set along ``a`` (``project_box_equality``), so cluster
    sub-solutions gathered by the divide step are always valid starts.
    ``C``/``a``/``p`` broadcast from scalars; ``active_mask`` freezes
    coordinates (shrinking / padding) — frozen coordinates keep their value
    and their a'u contribution.  Stops when the multiplier bracket gap
    rho_lo - rho_hi, measured on a freshly recomputed gradient every
    ``refresh_every`` pair steps (one Q @ u matvec, amortized
    O(n/refresh_every) per step — see ``_pairwise_mvp_loop``), drops below
    ``tol``.
    """
    n = Q.shape[0]
    dtype = Q.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    pvec = _broadcast(p, n, dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask
    alpha = jnp.zeros(n, dtype) if alpha0 is None else alpha0
    alpha = project_box_equality(alpha, cvec, avec, d, active_mask=mask)

    alpha, g, iters, pg_max = _pairwise_mvp_loop(
        alpha, cvec, avec, mask,
        qdiag=jnp.diagonal(Q),
        qij_fn=lambda i, j: Q[i, j],
        rank2_fn=lambda g, i, j, di, dj: g + di * Q[:, i] + dj * Q[:, j],
        full_grad=lambda al: Q @ al + pvec,
        tol=tol, max_iters=max_iters, refresh_every=refresh_every)
    alpha, g = _restore_equality(alpha, g, lambda k: Q[:, k], cvec, avec, d,
                                 mask)
    return SolveResult(alpha, g, iters, pg_max)


def solve_eq_qp_shrink(
    Q: Array,
    C,
    a,
    d,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    rounds: int = 3,
    shrink_margin: float = 10.0,
    p=0.0,
) -> SolveResult:
    """Outer shrinking rounds around the pairwise engine (the equality-family
    ``solve_with_shrinking``): coordinates pinned at a bound whose multiplier
    bound h_i sits beyond the current rho estimate by more than
    ``shrink_margin * tol`` are frozen for the next round; the final round
    re-activates everything and the returned residual is the full-problem
    maximal-violating-pair gap.  Frozen coordinates keep their a'u
    contribution, so every round solves the SAME constrained problem.
    """
    if rounds < 1:
        raise ValueError(f"shrinking needs rounds >= 1, got {rounds}")
    n = Q.shape[0]
    dtype = Q.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    alpha = jnp.zeros(n, dtype) if alpha0 is None else alpha0
    mask = jnp.ones(n, bool)
    res = None
    total_iters = jnp.zeros((), jnp.int32)
    for r in range(rounds):
        final = r == rounds - 1
        m = jnp.ones(n, bool) if final else mask
        res = solve_eq_qp(Q, C, a, d, alpha0=alpha, tol=tol,
                          max_iters=max_iters, active_mask=m, p=p)
        alpha, g = res.alpha, res.grad
        total_iters = total_iters + res.iters
        rho = equality_rho(alpha, g, cvec, avec)
        h = g / _safe_a(avec)
        mtol = shrink_margin * tol
        at_lo = alpha <= 0.0
        at_hi = alpha >= cvec
        lock_lo = at_lo & jnp.where(avec > 0, h > rho + mtol, h < rho - mtol)
        lock_hi = at_hi & jnp.where(avec > 0, h < rho - mtol, h > rho + mtol)
        mask = ~(lock_lo | lock_hi)
    pg_full = kkt_residual_eq(Q, res.alpha, cvec, avec, p=p)
    return SolveResult(res.alpha, res.grad, total_iters, pg_full)


@partial(jax.jit, static_argnames=("kernel", "max_iters", "grad_chunks",
                                   "use_pallas", "refresh_every"))
def solve_eq_qp_matvec(
    X: Array,
    y: Array,
    kernel: Kernel,
    C,
    a,
    d,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 5_000,
    grad_chunks: int = 16,
    use_pallas: bool = False,
    p=0.0,
    refresh_every: int = 512,
) -> SolveResult:
    """Pairwise maximal-violating-pair CD with on-the-fly kernel columns:
    Q = (y y') ∘ K(X, X) is never materialized.  ``y`` is the task sign
    vector ``s`` (all ones for one-class SVM, labels for nu-SVC); ``a`` may
    be mixed-sign.  On the fused path (``use_pallas=True``) the rank-2
    gradient update streams through ``repro.kernels.ops.cd_column_update``
    and the gradient init through the streaming ``kernel_matvec`` — the
    whole solve is ONE jitted program with no host transfer.
    """
    n = X.shape[0]
    dtype = X.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    pvec = _broadcast(p, n, dtype)
    mask = jnp.ones(n, bool)
    alpha = jnp.zeros(n, dtype) if alpha0 is None else alpha0
    alpha = project_box_equality(alpha, cvec, avec, d)

    from repro.core.kernels import gram_matvec

    if use_pallas:
        from repro.kernels import ops as kops

    acc = jnp.promote_types(dtype, jnp.float32)

    def full_grad(al):
        return (y * gram_matvec(kernel, X, y * al, num_chunks=grad_chunks,
                                use_pallas=use_pallas)
                + pvec).astype(acc)

    def qij_fn(i, j):
        Xb = X[jnp.stack([i, j])]
        return (y[i] * y[j] * kernel.pairwise(Xb, Xb)[0, 1]).astype(acc)

    def rank2_fn(g, i, j, di, dj):
        idx = jnp.stack([i, j])
        Xb, yb = X[idx], y[idx]
        delta = jnp.stack([di, dj])
        if use_pallas:
            # fused rank-2 update: the (n, 2) kernel block stays in VMEM
            return g + kops.cd_column_update(X, y, Xb, yb * delta,
                                             kernel).astype(acc)
        Kb = kernel.pairwise(X, Xb)                          # (n, 2)
        Qb = ((y[:, None] * yb[None, :]) * Kb).astype(acc)
        return g + Qb @ delta

    alpha, g, iters, pg_max = _pairwise_mvp_loop(
        alpha, cvec, avec, mask,
        qdiag=(y * y * kernel.diag(X)).astype(acc),
        qij_fn=qij_fn, rank2_fn=rank2_fn, full_grad=full_grad,
        tol=tol, max_iters=max_iters, refresh_every=refresh_every)

    def q_col(k):
        Kk = kernel.pairwise(X, X[k][None, :])[:, 0]
        return (y * y[k] * Kk).astype(acc)

    alpha, g = _restore_equality(alpha, g, q_col, cvec, avec, d, mask)
    return SolveResult(alpha, g, iters, pg_max)
