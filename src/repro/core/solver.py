"""QP solvers for the two generalized kernel-machine dual families.

Box family (the paper's bias-free hinge dual and its task generalizations):

    min_u  f(u) = 1/2 u' Q u + p' u     s.t.  0 <= u <= c

with per-coordinate linear term ``p`` and per-coordinate upper bound ``c``
(both broadcast from scalars).  The classic C-SVC hinge dual is the default
instantiation ``p = -1, c = C`` — C-SVC, weighted C-SVC and epsilon-SVR in
``repro.core.tasks`` reduce to this one problem with ``Q = (s s') ∘ K`` for
a task-specific sign vector ``s``.  Because the paper drops the bias term
there is no equality constraint, so single-coordinate updates are exactly
solvable in closed form:

    u_i <- clip(u_i - g_i / Q_ii, 0, c_i),      g = Q u + p.

Equality-constrained family (one-class SVM, nu-SVC — DESIGN.md §9):

    min_u  f(u) = 1/2 u' Q u + p' u     s.t.  0 <= u <= c,  a' u = d

with a nonzero coefficient vector ``a`` (possibly mixed-sign).  Single
coordinates can no longer move alone; the solver takes SMO-style *pairwise*
steps along the constraint-neutral direction ``e_i/a_i - e_j/a_j`` chosen by
the maximal-violating-pair rule, so every iterate stays on the hyperplane.

Solvers (all pure JAX, `lax` control flow, vmap-able over a leading batch of
independent subproblems — the divide step solves all clusters of one level in
a single vmapped call):

* ``solve_box_qp``        — greedy (Gauss-Southwell) CD, the paper-faithful
                            solver (LIBSVM's selection rule without bias).
* ``solve_box_qp_block``  — beyond-paper batched variant: select top-B
                            coordinates by projected gradient, solve the BxB
                            sub-QP, rank-B gradient update (MXU-friendly).
* ``solve_box_qp_matvec`` — block CD with on-the-fly kernel columns; never
                            materializes Q (top-level conquer at large n).
* ``solve_eq_qp``         — pairwise maximal-violating-pair CD on a dense Q
                            for the equality-constrained family.
* ``solve_eq_qp_block``   — rank-2B blocked variant: B maximal-violating
                            pairs per outer iteration, solved as a coupled
                            2Bx2B sub-QP with one coupling row per group
                            (MXU-shaped like ``solve_box_qp_block``).
* ``solve_eq_qp_shrink``  — LIBSVM-style outer shrinking rounds around the
                            pairwise / blocked engines.
* ``solve_eq_qp_matvec``  — the same pairwise engine with on-the-fly kernel
                            columns (fused Pallas path available); with
                            ``block > 1`` the gradient update is the fused
                            rank-2B ``cd_column_update``.

Group decomposition (``gid``/``n_groups``): the equality solvers accept a
partition of the coordinates into ``n_groups`` disjoint groups, each with
its OWN single constraint ``sum_{i in g} a_i u_i = d_g``.  Pairs are always
drawn within one group, so every constraint is preserved exactly.  This is
how the two-constraint nu-SVC dual (``e'u = nu n`` and ``y'u = 0``) is
solved: with +/-1 labels the pair decomposes into one mass constraint per
class group (DESIGN.md §10).  ``n_groups = 1`` (the default) is the plain
one-constraint family.

Stopping criterion: max |projected gradient| < tol for the box family;
``max_g (rho_lo_g - rho_hi_g) < tol`` (the maximal-violating-pair gap of
the per-group equality multiplier brackets, LIBSVM's working-set
criterion) for the equality family.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import colcache, gramop
from repro.core.kernels import Kernel
from repro.obs.trace import ConvTrace, trace_record

Array = jax.Array


def _broadcast(v, n: int, dtype) -> Array:
    """Scalar-or-vector parameter -> (n,) vector (p and c are per-coordinate
    in the generalized dual; the scalar hinge defaults broadcast)."""
    return jnp.broadcast_to(jnp.asarray(v, dtype), (n,))


class SolveResult(NamedTuple):
    alpha: Array
    grad: Array          # g = Q a + p at the returned alpha
    iters: Array         # number of outer iterations executed
    pg_max: Array        # final max |projected gradient|
    cache_hits: Optional[Array] = None    # column-cache rows served (matvec solver)
    cache_misses: Optional[Array] = None  # column-cache rows recomputed
    cache_evictions: Optional[Array] = None  # live rows/panels displaced (LRU)
    spills: Optional[Array] = None        # panels written to the host tier
    spill_hits: Optional[Array] = None    # panels re-loaded from the host tier
    trace: Optional[ConvTrace] = None     # convergence ring buffer (obs.trace)


def objective(alpha: Array, grad: Array, p=-1.0) -> Array:
    """f(u) = 1/2 u'Qu + p'u evaluated from the maintained gradient.

    With g = Qu + p we have u'g = u'Qu + p'u, hence

        f(u) = 1/2 (u'g - p'u) + p'u = 1/2 u'g + 1/2 p'u.

    The default ``p = -1`` recovers the hinge form 1/2 a'g - 1/2 e'a.
    """
    pu = jnp.sum(jnp.asarray(p, alpha.dtype) * alpha)
    return 0.5 * jnp.vdot(alpha, grad) + 0.5 * pu


def _n_free(alpha: Array, cvec: Array, mask: Optional[Array] = None) -> Array:
    """Free-set size (strictly interior coordinates) for trace recording."""
    free = (alpha > 0.0) & (alpha < cvec)
    if mask is not None:
        free &= mask
    return jnp.sum(free.astype(jnp.int32))


def proj_grad(alpha: Array, grad: Array, C) -> Array:
    """Projected gradient of the box QP (the KKT residual).  ``C`` is the
    upper bound, scalar or per-coordinate."""
    at_lo = alpha <= 0.0
    at_hi = alpha >= C
    pg = jnp.where(at_lo, jnp.minimum(grad, 0.0), grad)
    pg = jnp.where(at_hi, jnp.maximum(grad, 0.0), pg)
    return pg


def kkt_residual(Q: Array, alpha: Array, C, p=-1.0) -> Array:
    g = Q @ alpha + jnp.asarray(p, alpha.dtype)
    return jnp.max(jnp.abs(proj_grad(alpha, g, C)))


def combination_step_size(gTd: Array, dQd: Array) -> Array:
    """CE-PBM combined step size: backtracking-free exact line search on the
    dual quadratic (Hsieh, Si & Dhillon 2016, the distributed conquer).

    P devices simultaneously minimize their own block sub-QPs and propose
    the combined direction ``Δ = Σ_p Δ_p`` (disjoint coordinate support).
    Applying every block at full length can overshoot — each local solve
    ignores the cross-block curvature — so the combined update is
    ``α + γ Δ`` with

        γ* = argmin_γ f(α + γΔ) = -g'Δ / Δ'QΔ,   clipped to [0, 1].

    Both α and α + Δ are box-feasible and the blocks touch disjoint
    coordinates, so every γ in [0, 1] stays feasible.  Descent needs no
    backtracking loop: at the interior minimizer the decrease is
    ``-(g'Δ)² / (2 Δ'QΔ) <= 0``, and when γ* clips at 1 it is still
    ``<= -Δ'QΔ / 2``.  Each block solve only ever decreases its own
    sub-model, so ``g'Δ <= -½ Σ_p Δ_p' Q_pp Δ_p <= 0`` and the unclipped
    γ* is nonnegative; ``Δ'QΔ <= 0`` (PSD Q) only when Δ vanishes, where
    γ = 1 is a no-op.  Takes the two already-reduced scalars so the
    distributed caller can psum them instead of gathering gradients.
    """
    gamma = jnp.where(dQd > 0.0, -gTd / jnp.where(dQd > 0.0, dQd, 1.0), 1.0)
    return jnp.clip(gamma, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Greedy single-coordinate CD (paper-faithful conquer/sub-solver)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters",))
def solve_box_qp(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    active_mask: Optional[Array] = None,
    p=-1.0,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """Greedy coordinate descent on a dense Q. vmap over leading dims is fine.

    ``C`` (upper bound) and ``p`` (linear term) are scalar or per-coordinate
    vectors; the defaults ``C`` scalar, ``p = -1`` are the C-SVC hinge dual.
    ``active_mask`` freezes coordinates (shrinking): masked-out coordinates
    are never selected (their pg is treated as 0 for selection AND stopping,
    matching LIBSVM's shrunk working set).

    ``trace`` (static gate, ``None`` = identical pre-trace jaxpr) records one
    (pg_max, objective, n_free) sample per iteration into the ring buffer,
    evaluated at the pre-update iterate like the stopping value.
    """
    n = Q.shape[0]
    diag = jnp.maximum(jnp.diagonal(Q), 1e-12)
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    pvec = _broadcast(p, n, Q.dtype)
    g = Q @ alpha + pvec
    mask = jnp.ones(n, bool) if active_mask is None else active_mask

    def step(alpha, g):
        pg = jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)
        i = jnp.argmax(jnp.abs(pg))
        new_ai = jnp.clip(alpha[i] - g[i] / diag[i], 0.0, cvec[i])
        delta = new_ai - alpha[i]
        # stopping value computed from the *pre-update* pg (cheap, standard)
        return alpha.at[i].set(new_ai), g + delta * Q[:, i], jnp.max(jnp.abs(pg))

    # one priming evaluation so the loop can exit immediately at the optimum
    pg0 = jnp.max(jnp.abs(jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)))

    if trace is None:
        def cond(state):
            _, _, it, pg_max = state
            return (pg_max > tol) & (it < max_iters)

        def body(state):
            alpha, g, it, _ = state
            alpha, g, pg_max = step(alpha, g)
            return alpha, g, it + 1, pg_max

        alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
        return SolveResult(alpha, g, iters, pg_max)

    def cond_t(state):
        _, _, it, pg_max, _ = state
        return (pg_max > tol) & (it < max_iters)

    def body_t(state):
        alpha, g, it, _, tr = state
        tr = trace_record(tr, pg_max=jnp.max(jnp.abs(jnp.where(
                              mask, proj_grad(alpha, g, cvec), 0.0))),
                          objective=objective(alpha, g, pvec),
                          n_free=_n_free(alpha, cvec, mask))
        alpha, g, pg_max = step(alpha, g)
        return alpha, g, it + 1, pg_max, tr

    alpha, g, iters, pg_max, tr = lax.while_loop(
        cond_t, body_t, (alpha, g, 0, pg0, trace))
    return SolveResult(alpha, g, iters, pg_max, trace=tr)


# ---------------------------------------------------------------------------
# Block greedy CD (beyond-paper batched variant)
# ---------------------------------------------------------------------------

def _solve_small_qp(Qbb: Array, gb: Array, ab: Array, cb, sweeps: int) -> Array:
    """Cyclic CD on the BxB subproblem. g_b is the gradient at entry; we
    maintain it locally.  ``cb`` is the upper bound, scalar or the (B,)
    slice of the per-coordinate box.  Returns the new a_b."""
    B = Qbb.shape[0]
    cb = _broadcast(cb, B, Qbb.dtype)
    diag = jnp.maximum(jnp.diagonal(Qbb), 1e-12)

    def body(t, carry):
        a, g = carry
        j = t % B
        new_aj = jnp.clip(a[j] - g[j] / diag[j], 0.0, cb[j])
        delta = new_aj - a[j]
        a = a.at[j].set(new_aj)
        g = g + delta * Qbb[:, j]
        return a, g

    a, _ = lax.fori_loop(0, sweeps * B, body, (ab, gb))
    return a


@partial(jax.jit, static_argnames=("block", "sweeps", "max_iters"))
def solve_box_qp_block(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 2_000,
    block: int = 32,
    sweeps: int = 4,
    active_mask: Optional[Array] = None,
    p=-1.0,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """Top-B greedy block CD: each outer iteration moves B coordinates.

    Selection by |projected gradient| (Gauss-Southwell-B). The rank-B gradient
    update `g += Q[:, idx] @ delta` is a skinny matmul — the MXU-friendly
    reshaping of the paper's one-at-a-time CD.  ``C``/``p`` may be
    per-coordinate vectors (generalized dual).  ``trace`` records one sample
    per outer (rank-B) iteration; ``None`` keeps the pre-trace jaxpr.
    """
    n = Q.shape[0]
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    pvec = _broadcast(p, n, Q.dtype)
    g = Q @ alpha + pvec
    mask = jnp.ones(n, bool) if active_mask is None else active_mask

    def step(alpha, g):
        pg = jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)
        scores = jnp.abs(pg)
        _, idx = lax.top_k(scores, block)
        Qbb = Q[idx][:, idx]
        ab, gb = alpha[idx], g[idx]
        new_ab = _solve_small_qp(Qbb, gb, ab, cvec[idx], sweeps)
        delta = new_ab - ab
        return alpha.at[idx].set(new_ab), g + Q[:, idx] @ delta, jnp.max(scores)

    pg0 = jnp.max(jnp.abs(jnp.where(mask, proj_grad(alpha, g, cvec), 0.0)))

    if trace is None:
        def cond(state):
            _, _, it, pg_max = state
            return (pg_max > tol) & (it < max_iters)

        def body(state):
            alpha, g, it, _ = state
            alpha, g, pg_max = step(alpha, g)
            return alpha, g, it + 1, pg_max

        alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
        return SolveResult(alpha, g, iters, pg_max)

    def cond_t(state):
        _, _, it, pg_max, _ = state
        return (pg_max > tol) & (it < max_iters)

    def body_t(state):
        alpha, g, it, _, tr = state
        tr = trace_record(tr, pg_max=jnp.max(jnp.abs(jnp.where(
                              mask, proj_grad(alpha, g, cvec), 0.0))),
                          objective=objective(alpha, g, pvec),
                          n_free=_n_free(alpha, cvec, mask))
        alpha, g, pg_max = step(alpha, g)
        return alpha, g, it + 1, pg_max, tr

    alpha, g, iters, pg_max, tr = lax.while_loop(
        cond_t, body_t, (alpha, g, 0, pg0, trace))
    return SolveResult(alpha, g, iters, pg_max, trace=tr)


# ---------------------------------------------------------------------------
# Matvec-free block CD: kernel columns computed on the fly (large n)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("kernel", "block", "sweeps", "max_iters",
                                   "grad_chunks", "use_pallas", "cache_cap",
                                   "compute_dtype"))
def solve_box_qp_matvec(
    X: Array,
    y: Array,
    kernel: Kernel,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 500,
    block: int = 64,
    sweeps: int = 4,
    grad_chunks: int = 16,
    use_pallas: bool = False,
    cache_cap: int = 0,
    p=-1.0,
    compute_dtype: Optional[str] = None,
    Xbase: Optional[Array] = None,
    base_index: Optional[Array] = None,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """Block greedy CD where Q columns are recomputed from (X, y) per step.

    ``y`` is the generalized sign vector ``s`` of Q = (s s') ∘ K — class
    labels for C-SVC, the (+1, -1) mirror signs for epsilon-SVR's stacked
    (alpha, alpha*) coordinates.  ``C`` and ``p`` may be per-coordinate
    (weighted classes / the SVR linear term eps -/+ y).

    Kernel access goes through one ``core.gramop.GramOperator`` carrying the
    precision policy (``compute_dtype`` — ``None`` keeps the pre-policy
    bit-identical path) and the optional base-indexed dedup view
    (``Xbase``/``base_index`` with ``X == Xbase[base_index]`` row-for-row:
    SVR's 2n mirrored dual rows cache/store against the n base rows, signs
    expanded exactly at read).  Never materializes Q.  Three paths:

    * ``use_pallas=False, cache_cap=0`` — XLA reference: the (n, B) column
      block via ``kernel.pairwise`` each outer iteration.
    * ``use_pallas=True, cache_cap=0`` — fully fused: rank-B update through
      ``repro.kernels.ops.cd_column_update`` (the (n, B) kernel block lives
      only in VMEM, per tile) and gradient init through the streaming
      ``kernel_matvec`` kernel.
    * ``cache_cap>0`` — device-resident LRU cache of *raw* kernel rows
      (``core.colcache``, stored in the operator's storage dtype): a block
      whose B rows are all cached is served from HBM with no kernel compute
      at all (``lax.cond`` skips it); otherwise the B rows are recomputed
      (Pallas ``kermat`` on the fused path) and refilled into the cache.
      Hit/miss/eviction row counts are returned on ``SolveResult``.
    """
    op = gramop.GramOperator(Xd=X, s=y, Xb=Xbase, bidx=base_index,
                             kernel=kernel, use_pallas=use_pallas,
                             compute_dtype=compute_dtype)
    return solve_box_qp_op(op, C, alpha0=alpha0, tol=tol, max_iters=max_iters,
                           block=block, sweeps=sweeps, grad_chunks=grad_chunks,
                           cache_cap=cache_cap, p=p, trace=trace)


def solve_box_qp_op(
    op: "gramop.GramOperator",
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 500,
    block: int = 64,
    sweeps: int = 4,
    grad_chunks: int = 16,
    cache_cap: int = 0,
    p=-1.0,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """The engine behind ``solve_box_qp_matvec``: block greedy CD against a
    ``GramOperator``.  Call inside jit (the operator's kernel / backend /
    precision fields are pytree aux data, hence trace-static).

    ``trace`` (static ``None`` gate) records one sample per outer iteration
    — on the cached path additionally the per-iteration cache-hit delta —
    entirely on device; nothing is fetched until the caller reads the
    returned ``SolveResult.trace``.
    """
    X = op.Xd
    n = op.n_dual
    alpha = jnp.zeros(n, X.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, X.dtype)
    pvec = _broadcast(p, n, X.dtype)

    # accumulation dtype: at least f32 (Pallas kernels accumulate in f32),
    # f64 preserved when x64 is enabled
    acc = jnp.promote_types(X.dtype, jnp.float32)

    # initial gradient g = Q @ alpha + p: streaming Pallas matvec on the
    # fused path, chunked lax.map otherwise
    g = (op.matvec(alpha, num_chunks=grad_chunks) + pvec).astype(acc)

    def select(alpha, g):
        pg = proj_grad(alpha, g, cvec)
        scores = jnp.abs(pg)
        _, idx = lax.top_k(scores, block)
        return idx, jnp.max(scores)

    def solve_block(Qbb, alpha, g, idx):
        ab, gb = alpha[idx], g[idx]
        new_ab = _solve_small_qp(Qbb, gb, ab, cvec[idx], sweeps)
        return new_ab, new_ab - ab

    def record(tr, alpha, g, pg_max, cache_hits=None):
        # pre-update sample, matching the stopping value's iterate
        return trace_record(tr, pg_max=pg_max,
                            objective=objective(alpha, g, pvec),
                            n_free=_n_free(alpha, cvec),
                            cache_hits=cache_hits)

    if cache_cap > 0:
        cap = max(cache_cap, block)  # must hold at least one full block

        def cache_step(alpha, g, cache):
            idx, pg_max = select(alpha, g)
            keys = op.cache_keys(idx)
            slots, hit = colcache.lookup(cache, keys)
            served = jnp.all(hit)
            kr = lax.cond(
                served,
                lambda: cache.cols[jnp.where(hit, slots, 0)].astype(acc),
                lambda: op.kernel_rows(idx).astype(acc),
            )
            cache = colcache.update(cache, keys, kr, served, slots, hit)
            Qrows = op.expand_rows(kr, idx)
            new_ab, delta = solve_block(Qrows[:, idx], alpha, g, idx)
            return alpha.at[idx].set(new_ab), g + delta @ Qrows, cache, pg_max

        pg0 = jnp.max(jnp.abs(proj_grad(alpha, g, cvec)))
        cache0 = colcache.init(cap, op.kwidth, dtype=op.storage_dtype(acc),
                               width=op.kwidth)

        if trace is None:
            def body(state):
                alpha, g, cache, it, _ = state
                alpha, g, cache, pg_max = cache_step(alpha, g, cache)
                return alpha, g, cache, it + 1, pg_max

            def cond(state):
                _, _, _, it, pg_max = state
                return (pg_max > tol) & (it < max_iters)

            alpha, g, cache, iters, pg_max = lax.while_loop(
                cond, body, (alpha, g, cache0, 0, pg0))
            return SolveResult(alpha, g, iters, pg_max, cache.hits,
                               cache.misses, cache_evictions=cache.evictions)

        def body_t(state):
            alpha, g, cache, it, _, tr = state
            hits0 = cache.hits
            alpha2, g2, cache, pg_max = cache_step(alpha, g, cache)
            tr = record(tr, alpha, g, pg_max, cache_hits=cache.hits - hits0)
            return alpha2, g2, cache, it + 1, pg_max, tr

        def cond_t(state):
            _, _, _, it, pg_max, _ = state
            return (pg_max > tol) & (it < max_iters)

        alpha, g, cache, iters, pg_max, tr = lax.while_loop(
            cond_t, body_t, (alpha, g, cache0, 0, pg0, trace))
        return SolveResult(alpha, g, iters, pg_max, cache.hits, cache.misses,
                           cache_evictions=cache.evictions, trace=tr)

    if op.use_pallas:
        def step(alpha, g):
            idx, pg_max = select(alpha, g)
            # fused: dg = s * (K(X, Xb) @ (sb * delta)); the (n, B) block
            # never leaves VMEM — only the (B, B) working-set block is formed
            Qbb = op.qbb(idx).astype(acc)
            new_ab, delta = solve_block(Qbb, alpha, g, idx)
            return alpha.at[idx].set(new_ab), op.col_update(g, idx, delta), \
                pg_max
    else:
        def step(alpha, g):
            idx, pg_max = select(alpha, g)
            Qb = op.q_block(idx).astype(acc)         # (n, B) on the fly
            Qbb = Qb[idx]                            # slice, don't recompute
            new_ab, delta = solve_block(Qbb, alpha, g, idx)
            return alpha.at[idx].set(new_ab), g + Qb @ delta, pg_max

    pg0 = jnp.max(jnp.abs(proj_grad(alpha, g, cvec)))

    if trace is None:
        def body(state):
            alpha, g, it, _ = state
            alpha, g, pg_max = step(alpha, g)
            return alpha, g, it + 1, pg_max

        def cond(state):
            _, _, it, pg_max = state
            return (pg_max > tol) & (it < max_iters)

        alpha, g, iters, pg_max = lax.while_loop(cond, body, (alpha, g, 0, pg0))
        return SolveResult(alpha, g, iters, pg_max)

    def body_t(state):
        alpha, g, it, _, tr = state
        alpha2, g2, pg_max = step(alpha, g)
        tr = record(tr, alpha, g, pg_max)
        return alpha2, g2, it + 1, pg_max, tr

    def cond_t(state):
        _, _, it, pg_max, _ = state
        return (pg_max > tol) & (it < max_iters)

    alpha, g, iters, pg_max, tr = lax.while_loop(
        cond_t, body_t, (alpha, g, 0, pg0, trace))
    return SolveResult(alpha, g, iters, pg_max, trace=tr)


# ---------------------------------------------------------------------------
# Shrinking wrapper (LIBSVM-style outer rounds)
# ---------------------------------------------------------------------------

def solve_with_shrinking(
    Q: Array,
    C,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    rounds: int = 3,
    shrink_margin: float = 10.0,
    block: int = 0,
    p=-1.0,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """Outer shrinking rounds around the CD solver.

    Each round: solve on the active set to ``tol``; variables pinned at a
    bound with |g| > shrink_margin * tol are removed from the active set for
    the next round; the final round always re-activates everything so the
    returned KKT residual is on the FULL problem (LIBSVM's un-shrink check).
    ``C``/``p`` may be per-coordinate vectors (generalized dual).

    ``pg_max`` is recomputed at the returned alpha (one Q @ alpha matvec):
    the inner solvers report the stopping value from the last *pre-update*
    iterate, which is not the residual of the solution they return.
    """
    if rounds < 1:
        raise ValueError(f"shrinking needs rounds >= 1, got {rounds}")
    n = Q.shape[0]
    alpha = jnp.zeros(n, Q.dtype) if alpha0 is None else alpha0
    cvec = _broadcast(C, n, Q.dtype)
    mask = jnp.ones(n, bool)
    solver = solve_box_qp if block <= 0 else partial(solve_box_qp_block, block=block)
    res = None
    # iteration counts accumulate on device; converting per round would force
    # a host sync between rounds and serialize dispatch
    total_iters = jnp.zeros((), jnp.int32)
    tr = trace  # one ring threaded through every round (None stays None)
    for r in range(rounds):
        final = r == rounds - 1
        m = jnp.ones(n, bool) if final else mask
        res = solver(Q, C, alpha0=alpha, tol=tol, max_iters=max_iters,
                     active_mask=m, p=p, trace=tr)
        tr = res.trace
        alpha, g = res.alpha, res.grad
        total_iters = total_iters + res.iters
        strongly_lo = (alpha <= 0.0) & (g > shrink_margin * tol)
        strongly_hi = (alpha >= cvec) & (g < -shrink_margin * tol)
        mask = ~(strongly_lo | strongly_hi)
    pg_full = kkt_residual(Q, res.alpha, cvec, p=p)
    return SolveResult(res.alpha, res.grad, total_iters, pg_full, trace=tr)


# ---------------------------------------------------------------------------
# Equality-constrained dual: pairwise (SMO-style) maximal-violating-pair CD
#
#     min 1/2 u'Qu + p'u   s.t.  0 <= u <= c,  a'u = d      (a_i != 0)
#
# KKT: there exists a multiplier rho with, per coordinate, h_i = g_i / a_i
# (g = Qu + p) satisfying  h_i = rho on free coordinates and one-sided
# inequalities at the bounds.  Every coordinate therefore contributes a
# one-sided bound on rho; optimality <=> the bracket [rho_lo, rho_hi] is
# non-empty.  The solver repeatedly picks the maximal violating pair
# (j = argmax of the lower bounds, i = argmin of the upper bounds) and takes
# the exact minimizer along u + t (e_i/a_i - e_j/a_j), which preserves a'u
# for every t.  See DESIGN.md §9 for the derivation.
# ---------------------------------------------------------------------------

def _safe_a(avec: Array) -> Array:
    return jnp.where(avec == 0.0, 1.0, avec)


def _eq_direction_sets(alpha: Array, cvec: Array, avec: Array, mask: Array):
    """Slot membership for the pairwise step u += t (e_i/a_i - e_j/a_j), t>0.

    ``i_plus``: coordinates that can occupy the i slot (their u moves by
    +t/a_i, so they need room upward when a_i > 0, downward when a_i < 0);
    ``i_minus``: the j slot (u moves by -t/a_j).  Coordinates with a == 0
    never couple to the constraint and are excluded — they belong to the box
    family and must be handled by the box solvers.
    """
    ok = mask & (avec != 0.0)
    up = alpha < cvec
    dn = alpha > 0.0
    i_plus = ok & jnp.where(avec > 0, up, dn)
    i_minus = ok & jnp.where(avec > 0, dn, up)
    return i_plus, i_minus


def _as_gid(gid, n: int) -> Array:
    """``None``-or-array group ids -> (n,) int32 (single group by default)."""
    if gid is None:
        return jnp.zeros(n, jnp.int32)
    return jnp.asarray(gid, jnp.int32)


def _broadcast_d(d, n_groups: int, dtype) -> Array:
    """Scalar-or-vector equality target(s) -> (n_groups,) vector."""
    return jnp.broadcast_to(jnp.asarray(d, dtype).reshape(-1), (n_groups,))


def equality_interval_grouped(alpha: Array, grad: Array, C, a, gid,
                              n_groups: int,
                              active_mask: Optional[Array] = None):
    """Per-group brackets [rho_lo_g, rho_hi_g] of the equality multipliers
    at ``alpha`` — (n_groups,) arrays; empty sides return -inf/+inf."""
    n = alpha.shape[0]
    cvec = _broadcast(C, n, alpha.dtype)
    avec = _broadcast(a, n, alpha.dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask
    ingrp = _as_gid(gid, n)[None, :] == jnp.arange(n_groups)[:, None]
    i_plus, i_minus = _eq_direction_sets(alpha, cvec, avec, mask)
    h = grad / _safe_a(avec)
    rho_lo = jnp.max(jnp.where(ingrp & i_minus, h, -jnp.inf), axis=1)
    rho_hi = jnp.min(jnp.where(ingrp & i_plus, h, jnp.inf), axis=1)
    return rho_lo, rho_hi


def equality_interval(alpha: Array, grad: Array, C, a,
                      active_mask: Optional[Array] = None):
    """Bracket [rho_lo, rho_hi] of the equality multiplier at ``alpha``.

    KKT holds iff rho_lo <= rho_hi; the gap ``rho_lo - rho_hi`` is the
    maximal-violating-pair violation (LIBSVM's working-set criterion,
    generalized to arbitrary nonzero ``a``).  Empty sides return -inf/+inf.
    """
    rho_lo, rho_hi = equality_interval_grouped(alpha, grad, C, a, None, 1,
                                               active_mask=active_mask)
    return rho_lo[0], rho_hi[0]


def kkt_residual_eq(Q: Array, alpha: Array, C, a, p=0.0, gid=None,
                    n_groups: int = 1) -> Array:
    """Maximal-violating-pair gap at ``alpha`` on the FULL problem (the
    equality-family analogue of ``kkt_residual``), maximized over the
    constraint groups; 0 at any KKT point."""
    g = Q @ alpha + jnp.asarray(p, alpha.dtype)
    rho_lo, rho_hi = equality_interval_grouped(alpha, g, C, a, gid, n_groups)
    return jnp.maximum(jnp.max(rho_lo - rho_hi), 0.0)


def equality_rho_grouped(alpha: Array, grad: Array, C, a, gid, n_groups: int,
                         active_mask: Optional[Array] = None) -> Array:
    """Per-group equality multipliers (n_groups,) from the bracket
    midpoints, with the same finite-side fallback as ``equality_rho``."""
    rho_lo, rho_hi = equality_interval_grouped(alpha, grad, C, a, gid,
                                               n_groups,
                                               active_mask=active_mask)
    mid = 0.5 * (rho_lo + rho_hi)
    return jnp.where(jnp.isfinite(mid), mid,
                     jnp.where(jnp.isfinite(rho_lo), rho_lo,
                               jnp.where(jnp.isfinite(rho_hi), rho_hi, 0.0)))


def equality_rho(alpha: Array, grad: Array, C, a,
                 active_mask: Optional[Array] = None) -> Array:
    """Recover the equality multiplier rho (one-class SVM's decision offset)
    from the bracket midpoint; falls back to the finite side when a bound
    set is empty (all coordinates pinned at one bound)."""
    rho_lo, rho_hi = equality_interval(alpha, grad, C, a,
                                       active_mask=active_mask)
    mid = 0.5 * (rho_lo + rho_hi)
    rho = jnp.where(jnp.isfinite(mid), mid,
                    jnp.where(jnp.isfinite(rho_lo), rho_lo,
                              jnp.where(jnp.isfinite(rho_hi), rho_hi, 0.0)))
    return rho


def project_box_equality(alpha: Array, C, a, d,
                         active_mask: Optional[Array] = None,
                         iters: int = 64) -> Array:
    """Project onto {0 <= u <= c} ∩ {a'u = d} by moving along ``a``.

    phi(t) = a' clip(u - t a, 0, c) is monotone non-increasing in t, so the
    feasible point is found by bisection — exact whenever d lies in the
    attainable interval [sum_{a<0} a c, sum_{a>0} a c] (clamped otherwise).
    Coordinates outside ``active_mask`` (and a == 0 coordinates) are frozen
    at their clipped values but still counted toward a'u, so shrunk /
    padded coordinates keep their contribution.  Pure lax control flow:
    jit- and vmap-safe, used for feasible warm starts in the divide step.

    Already-feasible starts (to the rounding noise of measuring a'u) are
    returned bit-exact: the bisection's residual-noise-sized t would
    otherwise displace every bound coordinate by O(eps) off its bound,
    re-entering them into the pairwise solver's violating sets for nothing.
    """
    n = alpha.shape[0]
    dtype = alpha.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask
    amove = jnp.where(mask, avec, 0.0)
    base = jnp.clip(alpha, 0.0, cvec)
    d = jnp.asarray(d, dtype)

    def at_t(t):
        return jnp.clip(base - t * amove, 0.0, cvec)

    def resid(t):
        return jnp.vdot(avec, at_t(t)) - d

    # |t| >= c_i / |a_i| saturates every moving coordinate
    T = jnp.max(jnp.where(amove != 0.0,
                          cvec / jnp.maximum(jnp.abs(amove), 1e-12), 0.0)) + 1.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        go_right = resid(mid) > 0.0
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    lo, hi = lax.fori_loop(0, iters, body, (-T, T))
    noise = 8.0 * jnp.finfo(dtype).eps \
        * (jnp.sum(jnp.abs(avec * base)) + jnp.abs(d) + 1.0)
    return jnp.where(jnp.abs(resid(0.0)) <= noise, base, at_t(0.5 * (lo + hi)))


def _pair_step(alpha: Array, cvec: Array, avec: Array, i, j, t):
    """Apply the pairwise step of length ``t >= 0`` along e_i/a_i - e_j/a_j,
    clipped to both coordinates' boxes.  Returns (new_ai, di, new_aj, dj)
    with the realized deltas for the rank-2 gradient update.

    The coordinate whose box cap binds becomes the PRIMARY and lands
    EXACTLY on its bound (so it leaves the violating index sets); the other
    coordinate is slaved to the primary's realized delta, which preserves
    a'u to one rounding.  Driving the step from one fixed side instead
    stalls: when t is below the f32 ulp of the other coordinate its delta
    underflows to zero, the slaved bound coordinate never reaches its
    bound, and the same maximal-violating pair is selected forever.
    """
    ai, aj = avec[i], avec[j]
    t_hi_i = jnp.where(ai > 0, ai * (cvec[i] - alpha[i]), -ai * alpha[i])
    t_hi_j = jnp.where(aj > 0, aj * alpha[j], aj * (alpha[j] - cvec[j]))
    t = jnp.clip(t, 0.0, jnp.minimum(t_hi_i, t_hi_j))
    hit_i = t >= t_hi_i
    hit_j = t >= t_hi_j
    bound_i = jnp.where(ai > 0, cvec[i], 0.0)     # i slot moves toward here
    bound_j = jnp.where(aj > 0, 0.0, cvec[j])     # j slot moves toward here
    # j primary: j lands exactly on its bound, i is slaved
    dj_p = bound_j - alpha[j]
    ai_from_j = jnp.clip(alpha[i] - (aj * dj_p) / ai, 0.0, cvec[i])
    # i primary: exact bound when its cap binds, else the clipped t-step
    ai_from_t = jnp.where(hit_i, bound_i,
                          jnp.clip(alpha[i] + t / ai, 0.0, cvec[i]))
    new_ai = jnp.where(hit_j, ai_from_j, ai_from_t)
    di = new_ai - alpha[i]
    new_aj = jnp.where(hit_j, bound_j,
                       jnp.clip(alpha[j] - (ai * di) / aj, 0.0, cvec[j]))
    dj = new_aj - alpha[j]
    return new_ai, di, new_aj, dj


def _restore_equality(alpha: Array, grad: Array, Q_col, cvec: Array,
                      avec: Array, d, mask: Array):
    """One exact feasibility-restoration step: absorb the accumulated f32
    rounding drift of a'u - d into a single coordinate.

    The correction coordinate must stay STRICTLY interior before and after
    the move: nudging a bound coordinate off its bound re-enters it into the
    KKT index sets with its full multiplier discrepancy, turning an O(eps)
    feasibility fix into an O(1) jump of the maximal-violating-pair gap.  An
    interior coordinate moved by O(drift) changes the gap only by
    O(||Q|| drift).  Falls back to any maskable coordinate when the iterate
    is a vertex.  ``Q_col(k)`` returns column k of Q for the gradient fix-up.
    """
    r = jnp.vdot(avec, alpha) - jnp.asarray(d, alpha.dtype)
    cand = jnp.clip(alpha - r / _safe_a(avec), 0.0, cvec)
    resid = r + avec * (cand - alpha)
    ok = mask & (avec != 0.0)
    interior = ok & (alpha > 0.0) & (alpha < cvec) \
        & (cand > 0.0) & (cand < cvec)
    score_int = jnp.where(interior, jnp.abs(resid), jnp.inf)
    k_int = jnp.argmin(score_int)
    k_any = jnp.argmin(jnp.where(ok, jnp.abs(resid), jnp.inf))
    k = jnp.where(jnp.isfinite(score_int[k_int]), k_int, k_any)
    delta = cand[k] - alpha[k]
    alpha = alpha.at[k].set(cand[k])
    grad = grad + delta * Q_col(k)
    return alpha, grad


def _project_box_equality_grouped(alpha, cvec, avec, dvec, gid, n_groups,
                                  mask, iters: int = 64):
    """Project onto the box intersected with EVERY group's hyperplane.

    Groups are disjoint, so the per-group projections commute: each moves
    only its own coordinates along its own (group-masked) ``a``.  The
    static-group Python loop unrolls under jit/vmap."""
    for g in range(n_groups):
        sel = gid == g
        alpha = project_box_equality(alpha, cvec, jnp.where(sel, avec, 0.0),
                                     dvec[g], active_mask=mask & sel,
                                     iters=iters)
    return alpha


def _restore_equality_grouped(alpha, grad, Q_col, cvec, avec, dvec, gid,
                              n_groups, mask):
    """Per-group feasibility restoration: absorb each group's accumulated
    a'u - d_g rounding drift into one strictly interior coordinate OF THAT
    GROUP (see ``_restore_equality``)."""
    for g in range(n_groups):
        sel = gid == g
        alpha, grad = _restore_equality(alpha, grad, Q_col, cvec,
                                        jnp.where(sel, avec, 0.0), dvec[g],
                                        mask & sel)
    return alpha, grad


def _pairwise_mvp_loop(alpha, cvec, avec, mask, gid, n_groups, qdiag, qij_fn,
                       rank2_fn, full_grad, tol, max_iters, refresh_every,
                       trace=None, pvec=None):
    """Shared pairwise maximal-violating-pair engine (dense and matvec
    front-ends differ only in how Q entries and the rank-2 gradient update
    are produced).

    Structure: an outer loop of refresh blocks, each an inner loop of up to
    ``refresh_every`` rank-2 steps on the maintained gradient, followed by
    an UNCONDITIONAL from-scratch gradient recompute and a stopping test on
    the fresh gradient.  Two reasons over a single loop with a conditional
    refresh: (1) under vmap (every divide-step caller) a batched-predicate
    ``lax.cond`` executes both branches, which would silently run the full
    recompute every iteration; (2) the convergence test at a block boundary
    sees the TRUE gradient, so f32 drift accumulated across the block's
    rank-2 updates cannot make the stopping test lie at tight tolerances.
    Returns (alpha, grad, iters, pg_max) with ``iters`` counting pair steps
    and ``pg_max`` the last fresh-gradient violation.  Pairs are drawn
    within one group (``gid``/``n_groups``): the selected pair belongs to
    the group with the widest multiplier-bracket violation, so every
    group's constraint is preserved exactly and the stopping test is the
    max gap over groups.

    ``trace`` (static ``None`` gate) records one (pg_max=violation,
    objective, n_free) sample per pair step; when enabled the loop returns
    a 5-tuple with the trace appended.  ``pvec`` supplies the linear term
    for the objective column and is only required when tracing.
    """
    safe = _safe_a(avec)
    ingrp = gid[None, :] == jnp.arange(n_groups)[:, None]      # (G, n)

    def select(alpha, g):
        i_plus, i_minus = _eq_direction_sets(alpha, cvec, avec, mask)
        h = g / safe
        hi_side = jnp.where(ingrp & i_plus, h, jnp.inf)        # (G, n)
        lo_side = jnp.where(ingrp & i_minus, h, -jnp.inf)
        ig = jnp.argmin(hi_side, axis=1)
        jg = jnp.argmax(lo_side, axis=1)
        gr = jnp.arange(n_groups)
        gaps = lo_side[gr, jg] - hi_side[gr, ig]
        gs = jnp.argmax(gaps)
        return ig[gs], jg[gs], gaps[gs]

    def pair_step(alpha, g):
        i, j, viol = select(alpha, g)
        # ``safe`` (a with 0 -> 1), not raw a: if the violating sets collapse
        # to one side mid-block, argmin/argmax over an all-inf side return an
        # arbitrary index whose a may be 0 (padding) — the step length is 0
        # there (viol <= 0), but raw-a division would still produce
        # inf - inf = NaN in curv and poison the iterate.  Real pairs always
        # have a != 0, so safe == a on every selected coordinate that moves.
        ai, aj = safe[i], safe[j]
        # exact minimizer along v = e_i/a_i - e_j/a_j: phi'(0) = h_i - h_j,
        # phi'' = Q_ii/a_i^2 + Q_jj/a_j^2 - 2 Q_ij/(a_i a_j) >= 0 (Q PSD)
        curv = qdiag[i] / (ai * ai) + qdiag[j] / (aj * aj) \
            - 2.0 * qij_fn(i, j) / (ai * aj)
        t = jnp.maximum(viol, 0.0) / jnp.maximum(curv, 1e-12)
        new_ai, di, new_aj, dj = _pair_step(alpha, cvec, safe, i, j, t)
        alpha = alpha.at[i].set(new_ai).at[j].set(new_aj)
        g = rank2_fn(g, i, j, di, dj)
        return alpha, g, jnp.maximum(viol, 0.0)

    def inner_cond(state):
        _, _, _, k, viol = state
        return (viol > tol) & (k < refresh_every)

    def inner_body(state):
        alpha, g, it, k, _ = state
        alpha, g, viol = pair_step(alpha, g)
        return alpha, g, it + 1, k + 1, viol

    def outer_cond(state):
        _, _, it, viol = state
        return (viol > tol) & (it < max_iters)

    def outer_body(state):
        alpha, g, it, viol = state
        block = jnp.minimum(refresh_every, max_iters - it)
        alpha, g, it, _, _ = lax.while_loop(
            lambda st: inner_cond(st) & (st[3] < block), inner_body,
            (alpha, g, it, 0, viol))
        g = full_grad(alpha)
        _, _, viol = select(alpha, g)
        return alpha, g, it, jnp.maximum(viol, 0.0)

    g = full_grad(alpha)
    _, _, viol0 = select(alpha, g)

    if trace is None:
        return lax.while_loop(outer_cond, outer_body,
                              (alpha, g, 0, jnp.maximum(viol0, 0.0)))

    def inner_body_t(state):
        alpha, g, it, k, _, tr = state
        alpha2, g2, viol = pair_step(alpha, g)
        tr = trace_record(tr, pg_max=viol,
                          objective=objective(alpha, g, pvec),
                          n_free=_n_free(alpha, cvec, mask))
        return alpha2, g2, it + 1, k + 1, viol, tr

    def outer_body_t(state):
        alpha, g, it, viol, tr = state
        block = jnp.minimum(refresh_every, max_iters - it)
        alpha, g, it, _, _, tr = lax.while_loop(
            lambda st: (st[4] > tol) & (st[3] < block),
            inner_body_t, (alpha, g, it, 0, viol, tr))
        g = full_grad(alpha)
        _, _, viol = select(alpha, g)
        return alpha, g, it, jnp.maximum(viol, 0.0), tr

    return lax.while_loop(
        lambda st: (st[3] > tol) & (st[2] < max_iters), outer_body_t,
        (alpha, g, 0, jnp.maximum(viol0, 0.0), trace))


@partial(jax.jit, static_argnames=("max_iters", "refresh_every", "n_groups"))
def solve_eq_qp(
    Q: Array,
    C,
    a,
    d,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    active_mask: Optional[Array] = None,
    p=0.0,
    refresh_every: int = 256,
    gid=None,
    n_groups: int = 1,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """Pairwise maximal-violating-pair CD on a dense Q; every iterate stays
    on the hyperplane(s) a'u = d.  vmap over leading dims is fine.

    The (possibly infeasible) warm start is first projected onto the
    feasible set along ``a`` (``project_box_equality``), so cluster
    sub-solutions gathered by the divide step are always valid starts.
    ``C``/``a``/``p`` broadcast from scalars; ``active_mask`` freezes
    coordinates (shrinking / padding) — frozen coordinates keep their value
    and their a'u contribution.  ``gid``/``n_groups`` decompose the
    coordinates into disjoint groups with one constraint each (``d`` is
    then the (n_groups,) target vector; a scalar broadcasts); pairs are
    drawn within one group.  Stops when the multiplier bracket gap
    max_g (rho_lo_g - rho_hi_g), measured on a freshly recomputed gradient
    every ``refresh_every`` pair steps (one Q @ u matvec, amortized
    O(n/refresh_every) per step — see ``_pairwise_mvp_loop``), drops below
    ``tol``.
    """
    n = Q.shape[0]
    dtype = Q.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    pvec = _broadcast(p, n, dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask
    gidv = _as_gid(gid, n)
    dvec = _broadcast_d(d, n_groups, dtype)
    alpha = jnp.zeros(n, dtype) if alpha0 is None else alpha0
    alpha = _project_box_equality_grouped(alpha, cvec, avec, dvec, gidv,
                                          n_groups, mask)

    out = _pairwise_mvp_loop(
        alpha, cvec, avec, mask, gidv, n_groups,
        qdiag=jnp.diagonal(Q),
        qij_fn=lambda i, j: Q[i, j],
        rank2_fn=lambda g, i, j, di, dj: g + di * Q[:, i] + dj * Q[:, j],
        full_grad=lambda al: Q @ al + pvec,
        tol=tol, max_iters=max_iters, refresh_every=refresh_every,
        trace=trace, pvec=pvec)
    alpha, g, iters, pg_max = out[:4]
    tr = out[4] if trace is not None else None
    alpha, g = _restore_equality_grouped(alpha, g, lambda k: Q[:, k], cvec,
                                         avec, dvec, gidv, n_groups, mask)
    return SolveResult(alpha, g, iters, pg_max, trace=tr)


# ---------------------------------------------------------------------------
# Rank-2B blocked pairwise CD: B maximal-violating pairs per outer iteration,
# solved as a coupled 2Bx2B sub-QP that carries one coupling row per group
# (a_b'u_b = const) — the equality-family analogue of solve_box_qp_block.
# Derivation and the B=1 reduction to the pairwise step: DESIGN.md §10.
# ---------------------------------------------------------------------------

_SELECT_BIG = 1e30   # finite tier-2 selection score: "no violation, but a
                     # real in-group coordinate" — sorts strictly above the
                     # -inf non-candidates, strictly below any real h score


def _solve_small_eq_qp(Qbb: Array, gb: Array, ub: Array, ab: Array, cb: Array,
                       gidb: Array, n_groups: int, active: Array,
                       steps: int) -> Array:
    """Grouped MVP pair-sweeps on the (m, m) sub-QP around the entry point.

    Each inner step selects the block-local maximal violating pair (within
    one group) and takes the exact clipped minimizer along
    ``e_i/a_i - e_j/a_j`` — the same rank-2 step as the pairwise engine, so
    EVERY inner iterate stays on each group's hyperplane
    ``a_b'u_b = const``.  ``active`` freezes slots (padding from a
    short-sided selection; possibly duplicate indices — frozen slots never
    move, so duplicates stay inert).  The local gradient ``gb`` is
    maintained by rank-2 updates on the (m,) slice; at block optimality the
    selected step length underflows to an exact no-op, so running all
    ``steps`` iterations is safe.  This is ``_solve_small_qp`` generalized
    to carry the coupling rows.
    """
    diag = jnp.diagonal(Qbb)
    safe = _safe_a(ab)
    ingrp = gidb[None, :] == jnp.arange(n_groups)[:, None]

    def body(_, carry):
        u, g = carry
        i_plus, i_minus = _eq_direction_sets(u, cb, ab, active)
        h = g / safe
        hi_side = jnp.where(ingrp & i_plus, h, jnp.inf)
        lo_side = jnp.where(ingrp & i_minus, h, -jnp.inf)
        ig = jnp.argmin(hi_side, axis=1)
        jg = jnp.argmax(lo_side, axis=1)
        gr = jnp.arange(n_groups)
        gaps = lo_side[gr, jg] - hi_side[gr, ig]
        gs = jnp.argmax(gaps)
        i, j = ig[gs], jg[gs]
        viol = gaps[gs]
        # safe (0 -> 1), not raw ab: a one-sided block returns arbitrary
        # indices with possibly-zero a (frozen padding slots) — the step is
        # 0 there, but raw-a division would turn it into NaN
        ai, aj = safe[i], safe[j]
        curv = diag[i] / (ai * ai) + diag[j] / (aj * aj) \
            - 2.0 * Qbb[i, j] / (ai * aj)
        t = jnp.maximum(viol, 0.0) / jnp.maximum(curv, 1e-12)
        new_ui, di, new_uj, dj = _pair_step(u, cb, safe, i, j, t)
        u = u.at[i].set(new_ui).at[j].set(new_uj)
        g = g + di * Qbb[:, i] + dj * Qbb[:, j]
        return u, g

    u, _ = lax.fori_loop(0, steps, body, (ub, gb))
    return u


def _blocked_mvp_loop(alpha, cvec, avec, mask, gid, n_groups, block, sweeps,
                      qbb_fn, rank2b_fn, full_grad, tol, max_iters,
                      refresh_every, trace=None, pvec=None):
    """Shared rank-2B blocked engine (dense and matvec front-ends differ
    only in how the sub-block of Q and the rank-2B gradient update are
    produced).

    Selection per outer iteration and group: the top-``block`` i-slot
    candidates (smallest multiplier bounds h among the upward-movable set)
    and, disjointly, the top-``block`` j-slot candidates (largest h among
    the downward-movable set) — so the global maximal violating pair is
    always inside the block and one blocked iteration makes at least as
    much progress as one exact pairwise step.  Tier-2 fallback: when a side
    has fewer than ``block`` violating candidates, remaining slots are
    filled with arbitrary distinct in-group coordinates (still useful: the
    sub-QP may move them); slots that cannot be filled at all (group
    smaller than 2*block) come back non-finite and are frozen in the
    sub-QP, their writes routed onto a valid slot so duplicate scatter
    writes are identical and therefore deterministic.

    Same outer structure as ``_pairwise_mvp_loop``: refresh blocks of up to
    ``refresh_every`` rank-2B iterations on the maintained gradient, then
    an unconditional from-scratch recompute and a stopping test on the
    fresh gradient (vmap-safe, drift-bounded).  ``iters`` counts outer
    blocked iterations.  ``trace``/``pvec`` as in ``_pairwise_mvp_loop``
    (one sample per rank-2B iteration; 5-tuple return when enabled).
    """
    n = alpha.shape[0]
    safe = _safe_a(avec)
    ingrp = gid[None, :] == jnp.arange(n_groups)[:, None]      # (G, n)
    okg = ingrp & (mask & (avec != 0.0))[None, :]
    steps = 2 * sweeps * block

    def sides(alpha, g):
        i_plus, i_minus = _eq_direction_sets(alpha, cvec, avec, mask)
        h = g / safe
        return i_plus, i_minus, h

    def gap(i_plus, i_minus, h):
        hi = jnp.min(jnp.where(ingrp & i_plus, h, jnp.inf), axis=1)
        lo = jnp.max(jnp.where(ingrp & i_minus, h, -jnp.inf), axis=1)
        return jnp.max(lo - hi)

    def select(alpha, g):
        i_plus, i_minus, h = sides(alpha, g)
        viol = gap(i_plus, i_minus, h)
        big = jnp.asarray(_SELECT_BIG, h.dtype)
        sc_i = jnp.where(ingrp & i_plus, -h, jnp.where(okg, -big, -jnp.inf))
        iv, ii = lax.top_k(sc_i, block)                        # (G, B)
        taken = jnp.zeros(n, jnp.int32).at[ii.reshape(-1)].max(
            jnp.isfinite(iv).reshape(-1).astype(jnp.int32)).astype(bool)
        open_j = ~taken[None, :]
        sc_j = jnp.where(ingrp & i_minus & open_j, h,
                         jnp.where(okg & open_j, -big, -jnp.inf))
        jv, jj = lax.top_k(sc_j, block)
        idx = jnp.concatenate([ii, jj], axis=1).reshape(-1)    # (G * 2B,)
        valid = jnp.concatenate([jnp.isfinite(iv), jnp.isfinite(jv)],
                                axis=1).reshape(-1)
        return idx, valid, viol

    def block_step(alpha, g):
        idx, valid, viol = select(alpha, g)
        ub, gb = alpha[idx], g[idx]
        new_ub = _solve_small_eq_qp(qbb_fn(idx), gb, ub, avec[idx], cvec[idx],
                                    gid[idx], n_groups, valid, steps)
        # invalid slots may duplicate a valid slot's index: route their
        # writes onto one valid slot so duplicate writes carry identical
        # values (deterministic under scatter), and zero their deltas
        s0 = jnp.argmax(valid)
        alpha = alpha.at[jnp.where(valid, idx, idx[s0])].set(
            jnp.where(valid, new_ub, new_ub[s0]))
        delta = jnp.where(valid, new_ub - ub, 0.0)
        g = rank2b_fn(g, idx, delta)
        return alpha, g, jnp.maximum(viol, 0.0)

    def inner_cond(state):
        _, _, _, k, viol = state
        return (viol > tol) & (k < refresh_every)

    def inner_body(state):
        alpha, g, it, k, _ = state
        alpha, g, viol = block_step(alpha, g)
        return alpha, g, it + 1, k + 1, viol

    def outer_cond(state):
        _, _, it, viol = state
        return (viol > tol) & (it < max_iters)

    def outer_body(state):
        alpha, g, it, viol = state
        blk = jnp.minimum(refresh_every, max_iters - it)
        alpha, g, it, _, _ = lax.while_loop(
            lambda st: inner_cond(st) & (st[3] < blk), inner_body,
            (alpha, g, it, 0, viol))
        g = full_grad(alpha)
        return alpha, g, it, jnp.maximum(gap(*sides(alpha, g)), 0.0)

    g = full_grad(alpha)
    viol0 = jnp.maximum(gap(*sides(alpha, g)), 0.0)

    if trace is None:
        return lax.while_loop(outer_cond, outer_body, (alpha, g, 0, viol0))

    def inner_body_t(state):
        alpha, g, it, k, _, tr = state
        alpha2, g2, viol = block_step(alpha, g)
        tr = trace_record(tr, pg_max=viol,
                          objective=objective(alpha, g, pvec),
                          n_free=_n_free(alpha, cvec, mask))
        return alpha2, g2, it + 1, k + 1, viol, tr

    def outer_body_t(state):
        alpha, g, it, viol, tr = state
        blk = jnp.minimum(refresh_every, max_iters - it)
        alpha, g, it, _, _, tr = lax.while_loop(
            lambda st: (st[4] > tol) & (st[3] < blk), inner_body_t,
            (alpha, g, it, 0, viol, tr))
        g = full_grad(alpha)
        return alpha, g, it, jnp.maximum(gap(*sides(alpha, g)), 0.0), tr

    return lax.while_loop(
        lambda st: (st[3] > tol) & (st[2] < max_iters), outer_body_t,
        (alpha, g, 0, viol0, trace))


@partial(jax.jit, static_argnames=("block", "sweeps", "max_iters",
                                   "refresh_every", "n_groups"))
def solve_eq_qp_block(
    Q: Array,
    C,
    a,
    d,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 5_000,
    block: int = 8,
    sweeps: int = 4,
    active_mask: Optional[Array] = None,
    p=0.0,
    refresh_every: int = 32,
    gid=None,
    n_groups: int = 1,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """Rank-2B blocked pairwise CD on a dense Q: each outer iteration
    selects the ``block`` maximal-violating pairs per group from the KKT
    multiplier bracket and solves the coupled 2Bx2B sub-QP (one coupling
    row per group) with grouped MVP pair-sweeps, then applies the rank-2B
    gradient update ``g += Q[:, idx] @ delta`` — a skinny matmul, the
    MXU-friendly reshaping of the pairwise engine exactly as
    ``solve_box_qp_block`` is of ``solve_box_qp``.

    Every iterate stays on every group's hyperplane (the sub-QP moves only
    along within-group pair directions), and the feasibility-restore and
    rho-bracket machinery of the rank-2 engine is reused unchanged.  At
    ``block = 1`` this is the pairwise step with ``sweeps`` extra polishing
    steps on the selected pair; ``DCSVMConfig.eq_block_size = 1`` routes to
    ``solve_eq_qp`` instead.  vmap over leading dims is fine.
    """
    n = Q.shape[0]
    dtype = Q.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    pvec = _broadcast(p, n, dtype)
    mask = jnp.ones(n, bool) if active_mask is None else active_mask
    gidv = _as_gid(gid, n)
    dvec = _broadcast_d(d, n_groups, dtype)
    B = max(1, min(block, n // (2 * n_groups)))
    alpha = jnp.zeros(n, dtype) if alpha0 is None else alpha0
    alpha = _project_box_equality_grouped(alpha, cvec, avec, dvec, gidv,
                                          n_groups, mask)

    out = _blocked_mvp_loop(
        alpha, cvec, avec, mask, gidv, n_groups, B, sweeps,
        qbb_fn=lambda idx: Q[idx][:, idx],
        rank2b_fn=lambda g, idx, delta: g + Q[:, idx] @ delta,
        full_grad=lambda al: Q @ al + pvec,
        tol=tol, max_iters=max_iters, refresh_every=refresh_every,
        trace=trace, pvec=pvec)
    alpha, g, iters, pg_max = out[:4]
    tr = out[4] if trace is not None else None
    alpha, g = _restore_equality_grouped(alpha, g, lambda k: Q[:, k], cvec,
                                         avec, dvec, gidv, n_groups, mask)
    return SolveResult(alpha, g, iters, pg_max, trace=tr)


def solve_eq_qp_shrink(
    Q: Array,
    C,
    a,
    d,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 10_000,
    rounds: int = 3,
    shrink_margin: float = 10.0,
    p=0.0,
    block: int = 0,
    sweeps: int = 4,
    gid=None,
    n_groups: int = 1,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """Outer shrinking rounds around the pairwise engine (the equality-family
    ``solve_with_shrinking``): coordinates pinned at a bound whose multiplier
    bound h_i sits beyond THEIR GROUP's current rho estimate by more than
    ``shrink_margin * tol`` are frozen for the next round; the final round
    re-activates everything and the returned residual is the full-problem
    maximal-violating-pair gap.  Frozen coordinates keep their a'u
    contribution, so every round solves the SAME constrained problem.
    ``block > 1`` runs the rank-2B blocked engine (``solve_eq_qp_block``)
    inside each round instead of the rank-2 pairwise engine.
    """
    if rounds < 1:
        raise ValueError(f"shrinking needs rounds >= 1, got {rounds}")
    n = Q.shape[0]
    dtype = Q.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    gidv = _as_gid(gid, n)
    alpha = jnp.zeros(n, dtype) if alpha0 is None else alpha0
    mask = jnp.ones(n, bool)
    res = None
    total_iters = jnp.zeros((), jnp.int32)
    tr = trace  # one ring threaded through every round (None stays None)
    for r in range(rounds):
        final = r == rounds - 1
        m = jnp.ones(n, bool) if final else mask
        if block > 1:
            res = solve_eq_qp_block(Q, C, a, d, alpha0=alpha, tol=tol,
                                    max_iters=max_iters, block=block,
                                    sweeps=sweeps, active_mask=m, p=p,
                                    gid=gidv, n_groups=n_groups, trace=tr)
        else:
            res = solve_eq_qp(Q, C, a, d, alpha0=alpha, tol=tol,
                              max_iters=max_iters, active_mask=m, p=p,
                              gid=gidv, n_groups=n_groups, trace=tr)
        tr = res.trace
        alpha, g = res.alpha, res.grad
        total_iters = total_iters + res.iters
        rho = equality_rho_grouped(alpha, g, cvec, avec, gidv,
                                   n_groups)[gidv]
        h = g / _safe_a(avec)
        mtol = shrink_margin * tol
        at_lo = alpha <= 0.0
        at_hi = alpha >= cvec
        lock_lo = at_lo & jnp.where(avec > 0, h > rho + mtol, h < rho - mtol)
        lock_hi = at_hi & jnp.where(avec > 0, h < rho - mtol, h > rho + mtol)
        mask = ~(lock_lo | lock_hi)
    pg_full = kkt_residual_eq(Q, res.alpha, cvec, avec, p=p, gid=gidv,
                              n_groups=n_groups)
    return SolveResult(res.alpha, res.grad, total_iters, pg_full, trace=tr)


@partial(jax.jit, static_argnames=("kernel", "max_iters", "grad_chunks",
                                   "use_pallas", "refresh_every", "block",
                                   "sweeps", "n_groups", "compute_dtype"))
def solve_eq_qp_matvec(
    X: Array,
    y: Array,
    kernel: Kernel,
    C,
    a,
    d,
    alpha0: Optional[Array] = None,
    tol: float = 1e-3,
    max_iters: int = 5_000,
    grad_chunks: int = 16,
    use_pallas: bool = False,
    p=0.0,
    refresh_every: int = 512,
    block: int = 1,
    sweeps: int = 4,
    gid=None,
    n_groups: int = 1,
    compute_dtype: Optional[str] = None,
    trace: Optional[ConvTrace] = None,
) -> SolveResult:
    """Pairwise / blocked maximal-violating-pair CD with on-the-fly kernel
    columns: Q = (y y') ∘ K(X, X) is never materialized.  ``y`` is the task
    sign vector ``s`` (all ones for one-class SVM, labels for nu-SVC);
    ``a`` may be mixed-sign.  On the fused path (``use_pallas=True``) the
    rank-2 (``block <= 1``) or rank-2B (``block > 1``) gradient update
    streams through ``repro.kernels.ops.cd_column_update`` — the (n, 2B)
    kernel block lives only in VMEM — and the gradient init through the
    streaming ``kernel_matvec``: the whole solve is ONE jitted program with
    no host transfer.  ``refresh_every`` counts pair steps on the rank-2
    path and is rescaled by 2B on the blocked path, so the gradient-drift
    budget between from-scratch refreshes is comparable.
    """
    n = X.shape[0]
    dtype = X.dtype
    cvec = _broadcast(C, n, dtype)
    avec = _broadcast(a, n, dtype)
    pvec = _broadcast(p, n, dtype)
    mask = jnp.ones(n, bool)
    gidv = _as_gid(gid, n)
    dvec = _broadcast_d(d, n_groups, dtype)
    alpha = jnp.zeros(n, dtype) if alpha0 is None else alpha0
    alpha = _project_box_equality_grouped(alpha, cvec, avec, dvec, gidv,
                                          n_groups, mask)

    op = gramop.GramOperator(Xd=X, s=y, kernel=kernel, use_pallas=use_pallas,
                             compute_dtype=compute_dtype)

    acc = jnp.promote_types(dtype, jnp.float32)

    def full_grad(al):
        return (op.matvec(al, num_chunks=grad_chunks) + pvec).astype(acc)

    def rank2b_fn(g, idx, delta):
        """Rank-|idx| gradient update, shared by the rank-2 and rank-2B
        paths: fused cd_column_update on the Pallas path (the (n, |idx|)
        kernel block stays in VMEM), an on-the-fly column matmul on XLA."""
        return op.col_update(g, idx, delta)

    if block > 1:
        B = max(1, min(block, n // (2 * n_groups)))

        def qbb_fn(idx):
            return op.qbb(idx).astype(acc)

        out = _blocked_mvp_loop(
            alpha, cvec, avec, mask, gidv, n_groups, B, sweeps,
            qbb_fn=qbb_fn, rank2b_fn=rank2b_fn, full_grad=full_grad,
            tol=tol, max_iters=max_iters,
            refresh_every=max(1, refresh_every // (2 * B)),
            trace=trace, pvec=pvec)
    else:
        def qij_fn(i, j):
            return op.qbb(jnp.stack([i, j]))[0, 1].astype(acc)

        def rank2_fn(g, i, j, di, dj):
            return rank2b_fn(g, jnp.stack([i, j]), jnp.stack([di, dj]))

        out = _pairwise_mvp_loop(
            alpha, cvec, avec, mask, gidv, n_groups,
            qdiag=op.qdiag().astype(acc),
            qij_fn=qij_fn, rank2_fn=rank2_fn, full_grad=full_grad,
            tol=tol, max_iters=max_iters, refresh_every=refresh_every,
            trace=trace, pvec=pvec)
    alpha, g, iters, pg_max = out[:4]
    tr = out[4] if trace is not None else None

    def q_col(k):
        # XLA pairwise regardless of backend (one skinny column), under the
        # operator's precision policy
        Kk = kernel.pairwise(X, X[k][None, :],
                             compute_dtype=op._cd())[:, 0]
        return (y * y[k] * Kk).astype(acc)

    alpha, g = _restore_equality_grouped(alpha, g, q_col, cvec, avec, dvec,
                                         gidv, n_groups, mask)
    return SolveResult(alpha, g, iters, pg_max, trace=tr)
