"""Kernel functions for DC-SVM.

A ``Kernel`` is a small dataclass carrying the kernel hyper-parameters plus
pure-jnp pairwise evaluation.  All heavy Gram computation goes through
``gram(kernel, X, Y)`` / ``gram_matvec`` which tile the computation; the
Pallas fast paths (``repro.kernels.ops.kernel_matrix`` / ``kernel_matvec``)
are selected via ``use_pallas`` (``resolve_use_pallas(None)`` auto-picks
compiled Pallas on TPU and jnp/XLA elsewhere).

The paper uses the RBF kernel K(x,z) = exp(-gamma ||x-z||^2) for the main
experiments and the degree-3 polynomial kernel K(x,z) = (gamma x'z + coef0)^d
for Section 5's polynomial experiments.  Both are implemented here, plus
linear (the gamma->0 degenerate baseline used in unit tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# Gram memory budget in BYTES (2**29 = 512 MiB = the historical 2**27 f32
# element slots, so default behavior is unchanged).  Byte denomination makes
# bf16 storage fit twice the rows the same budget allows f32 — the policy
# knob the GramOperator layer (core.gramop) sizes caches, chunking, and
# spill panels against.
DEFAULT_GRAM_BUDGET = 2 ** 29


def auto_num_chunks(n_rows: int, n_cols: int, itemsize: int = 4,
                    budget_bytes: Optional[int] = None) -> int:
    """Smallest chunk count whose (n_rows/chunks, n_cols) row block fits the
    byte budget — replaces the historical hardcoded ``num_chunks=8``, which
    over-chunks small problems and under-chunks at extreme n.  Chunking only
    partitions output rows, so any chunk count is bit-identical."""
    budget = DEFAULT_GRAM_BUDGET if budget_bytes is None else int(budget_bytes)
    total = int(n_rows) * int(n_cols) * int(itemsize)
    return max(1, min(int(n_rows), -(-total // max(budget, 1))))


def _resolve_cd(compute_dtype, ref_dtype):
    """``None`` — or a policy dtype equal to the data's own — means "don't
    cast": the original (bit-identical) expressions are used."""
    if compute_dtype is None:
        return None
    cd = jnp.dtype(compute_dtype)
    return None if cd == jnp.dtype(ref_dtype) else cd


@dataclasses.dataclass(frozen=True)
class Kernel:
    """Kernel hyper-parameters. ``kind`` in {"rbf", "poly", "linear"}."""

    kind: str = "rbf"
    gamma: float = 1.0
    degree: int = 3
    coef0: float = 0.0

    def __post_init__(self):
        if self.kind not in ("rbf", "poly", "linear"):
            raise ValueError(f"unknown kernel kind: {self.kind}")

    # -- pure-jnp pairwise evaluation ------------------------------------
    def pairwise(self, X: Array, Y: Array, compute_dtype=None) -> Array:
        """K(X, Y): (n, d) x (m, d) -> (n, m), pure jnp (XLA) path.

        ``compute_dtype`` (e.g. "bfloat16") casts the matmul operands only;
        the Gram contraction accumulates in f32 (``preferred_element_type``)
        and the kernel transform runs in f32 — the flash-attention precision
        idiom.  ``None`` keeps the historical exact path."""
        cd = _resolve_cd(compute_dtype, X.dtype)
        if cd is None:
            if self.kind == "linear":
                return X @ Y.T
            if self.kind == "poly":
                return (self.gamma * (X @ Y.T) + self.coef0) ** self.degree
            return jnp.exp(-self.gamma * sqdist(X, Y))
        Xc, Yc = X.astype(cd), Y.astype(cd)
        g = jax.lax.dot_general(Xc, Yc, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if self.kind == "linear":
            return g
        if self.kind == "poly":
            return (self.gamma * g + self.coef0) ** self.degree
        # rbf: norms from the *quantized* tiles, accumulated in f32, so the
        # expansion xx + yy - 2g cancels consistently with the matmul inputs
        xx = jnp.sum(Xc.astype(jnp.float32) ** 2, axis=-1)[:, None]
        yy = jnp.sum(Yc.astype(jnp.float32) ** 2, axis=-1)[None, :]
        return jnp.exp(-self.gamma * jnp.maximum(xx + yy - 2.0 * g, 0.0))

    def diag(self, X: Array) -> Array:
        """K(x_i, x_i) for all rows — O(n), never forms the Gram matrix."""
        if self.kind == "linear":
            return jnp.sum(X * X, axis=-1)
        if self.kind == "poly":
            return (self.gamma * jnp.sum(X * X, axis=-1) + self.coef0) ** self.degree
        return jnp.ones(X.shape[0], X.dtype)

    @property
    def k_max(self) -> float:
        """Upper bound on K(x,x) used by the Theorem-2 margin (RBF: 1)."""
        return 1.0 if self.kind == "rbf" else float("inf")


def sqdist(X: Array, Y: Array) -> Array:
    """Squared euclidean distances via the Gram expansion (MXU-friendly)."""
    xx = jnp.sum(X * X, axis=-1)[:, None]
    yy = jnp.sum(Y * Y, axis=-1)[None, :]
    sq = xx + yy - 2.0 * (X @ Y.T)
    return jnp.maximum(sq, 0.0)


# ---------------------------------------------------------------------------
# Gram computation.  ``use_pallas`` routes the tile computation through the
# Pallas kernel (validated in interpret mode on CPU; compiled on TPU).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("kernel", "use_pallas", "compute_dtype"))
def gram(kernel: Kernel, X: Array, Y: Array, use_pallas: bool = False,
         compute_dtype: Optional[str] = None) -> Array:
    """Full kernel matrix K(X, Y) of shape (n, m)."""
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.kernel_matrix(X, Y, kernel, compute_dtype=compute_dtype)
    return kernel.pairwise(X, Y, compute_dtype=compute_dtype)


@partial(jax.jit, static_argnames=("kernel", "compute_dtype"))
def gram_blocks(kernel: Kernel, Xc: Array,
                compute_dtype: Optional[str] = None) -> Array:
    """Per-cluster Gram matrices: (k, nc, d) -> (k, nc, nc) via vmap."""
    return jax.vmap(
        lambda Xi: kernel.pairwise(Xi, Xi, compute_dtype=compute_dtype))(Xc)


def resolve_use_pallas(flag: Optional[bool]) -> bool:
    """Backend policy: ``None`` auto-detects (compiled Pallas on TPU, jnp/XLA
    elsewhere — interpret-mode Pallas is a correctness tool, not a fast path
    on CPU)."""
    if flag is None:
        return jax.default_backend() == "tpu"
    return bool(flag)


@partial(jax.jit, static_argnames=("kernel", "num_chunks", "use_pallas",
                                   "compute_dtype", "budget_bytes"))
def gram_matvec(kernel: Kernel, X: Array, v: Array,
                num_chunks: Optional[int] = None, use_pallas: bool = False,
                compute_dtype: Optional[str] = None,
                budget_bytes: Optional[int] = None) -> Array:
    """K(X, X) @ v computed without materializing the Gram matrix.

    ``use_pallas=True`` streams (bm, bn) kernel tiles through VMEM and
    accumulates the matvec in-register (one fused ``kernel_matvec`` call);
    otherwise row chunks via ``lax.map`` — O(n^2 d) compute either way, but
    the fused path's HBM traffic is O(n d) instead of O(n^2 / chunks).
    ``num_chunks=None`` derives the chunk count from the byte budget
    (``auto_num_chunks`` — any chunking is bit-identical, it only partitions
    output rows).  Used for the top-level conquer step when the full Gram
    does not fit.
    """
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.kernel_matvec(X, X, v, kernel,
                                  compute_dtype=compute_dtype)
    n = X.shape[0]
    if num_chunks is None:
        num_chunks = auto_num_chunks(n, n, budget_bytes=budget_bytes)
    pad = (-n) % num_chunks
    Xp = jnp.pad(X, ((0, pad), (0, 0))) if pad else X
    rows = (n + pad) // num_chunks
    Xr = Xp.reshape(num_chunks, rows, -1)

    def one(Xi):
        return kernel.pairwise(Xi, X, compute_dtype=compute_dtype) @ v

    return jax.lax.map(one, Xr).reshape(-1)[:n]


def offdiag_mass(kernel: Kernel, X: Array, labels: Array, num_chunks: int = 8) -> Array:
    """D(pi) = sum_{i,j: pi(i) != pi(j)} |K(x_i, x_j)|   (Theorem 1 quantity).

    Chunked over rows so it never materializes the full Gram.
    """
    n = X.shape[0]
    pad = (-n) % num_chunks
    if pad:
        Xp = jnp.concatenate([X, jnp.zeros((pad, X.shape[1]), X.dtype)], 0)
        lp = jnp.concatenate([labels, jnp.full((pad,), -1, labels.dtype)], 0)
        valid = jnp.concatenate([jnp.ones(n, bool), jnp.zeros(pad, bool)], 0)
    else:
        Xp, lp, valid = X, labels, jnp.ones(n, bool)
    rows = Xp.shape[0] // num_chunks
    Xr = Xp.reshape(num_chunks, rows, -1)
    lr = lp.reshape(num_chunks, rows)
    vr = valid.reshape(num_chunks, rows)

    def one(args):
        Xi, li, vi = args
        Krow = jnp.abs(kernel.pairwise(Xi, Xp))          # (rows, n_pad)
        mask = (li[:, None] != lp[None, :]) & vi[:, None] & valid[None, :]
        return jnp.sum(Krow * mask)

    return jnp.sum(jax.lax.map(one, (Xr, lr, vr)))
