"""Device-resident LRU cache of Q rows for the conquer-step block CD.

The TPU analog of LIBSVM's kernel cache (DESIGN.md §2): a fixed-capacity
``(cap, n)`` buffer of Q rows plus int32 index tables, all plain JAX arrays,
so lookup / touch / evict-insert run INSIDE the jitted CD ``while_loop`` —
no host round-trips and no dynamic shapes.  A block of Gauss-Southwell
selections is served from the cache only when *every* selected row is
resident (``lax.cond`` then skips the kernel recompute entirely); otherwise
the whole block is recomputed on the MXU and refilled into the cache,
evicting the least-recently-used slots.

Invariants:
  * ``owner[s]``    training index whose Q row occupies slot ``s`` (-1 empty)
  * ``slot_of[i]``  slot holding row i, or -1; when stale slots exist (a row
                    re-inserted before its old slot was evicted) ``slot_of``
                    always points at the freshest copy
  * ``stamp[s]``    tick of the last touch — the LRU eviction key
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


class ColumnCache(NamedTuple):
    cols: Array      # (cap, n) cached Q rows (f32)
    owner: Array     # (cap,)   int32 training index per slot, -1 = empty
    slot_of: Array   # (n,)     int32 slot per training index, -1 = uncached
    stamp: Array     # (cap,)   int32 last-use tick (LRU key)
    tick: Array      # ()       int32 logical clock
    hits: Array      # ()       int32 rows served from the cache
    misses: Array    # ()       int32 rows recomputed
    evictions: Array  # ()      int32 live rows displaced by inserts


def init(cap: int, n: int, dtype=jnp.float32, width: int = None) -> ColumnCache:
    """``width`` decouples the cached-row length from the index space: the
    distributed conquer caches (n_local,)-wide Q-row *slices* keyed by GLOBAL
    coordinate index (n = global count, width = local shard width).  Default
    ``None`` keeps the single-device shape (cap, n)."""
    return ColumnCache(
        cols=jnp.zeros((cap, n if width is None else width), dtype),
        owner=jnp.full((cap,), -1, jnp.int32),
        slot_of=jnp.full((n,), -1, jnp.int32),
        stamp=jnp.full((cap,), jnp.int32(-2 ** 30)),
        tick=jnp.zeros((), jnp.int32),
        hits=jnp.zeros((), jnp.int32),
        misses=jnp.zeros((), jnp.int32),
        evictions=jnp.zeros((), jnp.int32),
    )


def lookup(cache: ColumnCache, idx: Array) -> Tuple[Array, Array]:
    """Slots (B,) and hit mask (B,) for a block of row indices."""
    slots = cache.slot_of[idx]
    return slots, slots >= 0


def _touch(cache: ColumnCache, idx: Array, slots: Array, hit: Array) -> ColumnCache:
    stamp = cache.stamp.at[slots].set(cache.tick)
    return cache._replace(stamp=stamp)


def _insert(cache: ColumnCache, idx: Array, slots: Array, hit: Array,
            rows: Array) -> ColumnCache:
    cap = cache.owner.shape[0]
    n = cache.slot_of.shape[0]
    # Slots already owned by idx are now duplicates-to-be: age them to the
    # front of the eviction order so re-inserts reuse their own slots first.
    stamp = cache.stamp.at[jnp.where(hit, slots, cap)].set(
        -2 ** 30, mode="drop")
    _, victims = lax.top_k(-stamp, idx.shape[0])
    victims = victims.astype(jnp.int32)
    evicted = cache.owner[victims]
    ev_safe = jnp.where(evicted >= 0, evicted, 0)
    # un-map evicted owners, but only where they still point at the victim
    # slot (stale duplicates keep slot_of aimed at their fresh copy)
    still_mapped = (evicted >= 0) & (cache.slot_of[ev_safe] == victims)
    slot_of = cache.slot_of.at[jnp.where(still_mapped, ev_safe, n)].set(
        -1, mode="drop")
    cols = cache.cols.at[victims].set(rows.astype(cache.cols.dtype))
    owner = cache.owner.at[victims].set(idx.astype(jnp.int32))
    slot_of = slot_of.at[idx].set(victims)
    stamp = stamp.at[victims].set(cache.tick)
    return cache._replace(
        cols=cols, owner=owner, slot_of=slot_of, stamp=stamp,
        evictions=cache.evictions + jnp.sum(still_mapped, dtype=jnp.int32))


def update(cache: ColumnCache, idx: Array, rows: Array, served: Array,
           slots: Array, hit: Array) -> ColumnCache:
    """Refresh LRU state after serving block ``idx``.

    ``served`` (scalar bool): the block came straight from the cache — touch
    the slots.  Otherwise ``rows`` were recomputed — evict the LRU slots and
    insert them.  Hit/miss counters account whole blocks (serving is
    all-or-nothing, matching the ``lax.cond`` in the solver).
    """
    nb = jnp.int32(idx.shape[0])
    cache = cache._replace(
        tick=cache.tick + 1,
        hits=cache.hits + jnp.where(served, nb, 0),
        misses=cache.misses + jnp.where(served, 0, nb),
    )
    return lax.cond(
        served,
        lambda c: _touch(c, idx, slots, hit),
        lambda c: _insert(c, idx, slots, hit, rows),
        cache,
    )
