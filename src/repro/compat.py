"""Version shims for the jax pinned in this container.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (where the
``check_vma`` kwarg was still called ``check_rep``).  Import ``shard_map``
from here so both APIs work.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kw):
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        # the experimental tracer has no replication rule for while_loop /
        # pallas_call; checking is a debug aid, not a semantics change
        kw.setdefault("check_rep", False)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
