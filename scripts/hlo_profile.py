"""Rank collectives / largest ops in a cell's partitioned HLO (hillclimb tool).

    PYTHONPATH=src python scripts/hlo_profile.py --arch deepseek_moe_16b \
        --shape train_4k [--mesh single] [--top 15]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import re
from collections import defaultdict

from repro.configs import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.roofline.analysis import _DTYPE_BYTES, _SHAPE_RE

COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")


def shape_bytes(dtype, dims):
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--chunk", type=int, default=1024)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    build = build_cell(cfg, mesh, SHAPES[args.shape], chunk=args.chunk)
    compiled = build.step_fn.lower(*build.abstract_args).compile()
    text = compiled.as_text()

    items = []
    for line in text.splitlines():
        kind = next((k for k in COLL if f" {k}(" in line or f" {k}-start(" in line), None)
        if kind is None or "-done(" in line:
            continue
        shapes = _SHAPE_RE.findall(line.split("=", 1)[1].split("(", 1)[0])
        b = sum(shape_bytes(d, s) for d, s in shapes)
        meta = re.search(r'op_name="([^"]+)"', line)
        items.append((b, kind, meta.group(1)[-110:] if meta else line.strip()[:110]))
    items.sort(reverse=True)
    total = sum(b for b, _, _ in items)
    print(f"{len(items)} collectives, {total/2**30:.2f} GiB total (per-device shapes)")
    agg = defaultdict(float)
    for b, kind, name in items:
        agg[kind] += b
    for kind, b in sorted(agg.items(), key=lambda kv: -kv[1]):
        print(f"  {kind:20s} {b/2**30:9.2f} GiB")
    print("\ntop ops:")
    for b, kind, name in items[: args.top]:
        print(f"  {b/2**30:8.3f} GiB {kind:18s} {name}")


if __name__ == "__main__":
    main()
