"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
experiments/dryrun/*.json.

    PYTHONPATH=src python scripts/make_report.py > experiments/roofline_tables.md

With ``--stats PATH`` it instead renders the per-level training table from a
``train_svm --stats-json`` dump (times, SV counts, cache counters and the
level-0 convergence-trace summary):

    PYTHONPATH=src python scripts/make_report.py --stats /tmp/stats.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "jamba_v01_52b", "qwen15_05b", "qwen3_8b", "gemma_2b", "yi_6b",
    "deepseek_moe_16b", "phi35_moe_42b", "internvl2_26b", "xlstm_125m",
    "whisper_medium",
]


def load(mesh: str):
    out = {}
    for f in glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_bytes(b):
    if b is None:
        return "?"
    return f"{b/2**30:.1f}GiB"


def dryrun_table(mesh: str) -> str:
    recs = load(mesh)
    lines = [
        f"### Dry-run — {mesh} mesh "
        f"({'2x16x16=512' if mesh == 'multi' else '16x16=256'} chips)",
        "",
        "| arch | shape | status | compile | HBM temp/dev | args/dev | collectives (AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | SKIP | — | — | — | {r['reason'][:48]} |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | **ERROR** | — | — | — | {r['error'][:48]} |")
                continue
            rl = r["roofline"]
            ma = rl.get("memory_analysis", {})
            c = rl["collectives"]["counts"]
            cc = (f"{c.get('all-gather',0)}/{c.get('all-reduce',0)}/"
                  f"{c.get('reduce-scatter',0)}/{c.get('all-to-all',0)}/"
                  f"{c.get('collective-permute',0)}")
            lines.append(
                f"| {arch} | {shape} | ok | {r['compile_s']:.0f}s "
                f"| {fmt_bytes(ma.get('temp_size_in_bytes'))} "
                f"| {fmt_bytes(ma.get('argument_size_in_bytes'))} | {cc} |")
    return "\n".join(lines)


def roofline_table(mesh: str = "single") -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| MODEL_FLOPs | HLO/MODEL | roofline frac | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        "compute": "more TP/DP (scale out) or lower-precision matmuls",
        "memory": "fused (flash) attention keeps scores in VMEM; bf16 intermediates",
        "collective": "reshard to cut all-gathers; overlap collectives with compute",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None or r["status"] != "ok":
                continue
            rl = r["roofline"]
            inv_useful = (1.0 / rl["useful_flop_frac"]
                          if rl.get("useful_flop_frac") else 0.0)
            lines.append(
                f"| {arch} | {shape} | {rl['t_compute_s']*1e3:.1f}ms "
                f"| {rl['t_memory_s']*1e3:.1f}ms "
                f"| {rl['t_collective_s']*1e3:.1f}ms "
                f"| **{rl['bottleneck']}** "
                f"| {rl['model_flops_total']:.2e} "
                f"| {inv_useful:.2f} "
                f"| {rl['roofline_frac']:.3f} ({rl['ideal_reference']}) "
                f"| {fixes[rl['bottleneck']]} |")
    return "\n".join(lines)


def stats_table(path: str) -> str:
    """Per-level markdown table from a ``train_svm --stats-json`` dump."""
    with open(path) as f:
        s = json.load(f)
    lines = [
        f"### Training levels — task={s.get('task', '?')} "
        f"dataset={s.get('dataset', '?')} n={s.get('n', '?')} "
        f"({s.get('train_time', 0.0):.1f}s total)",
        "",
        "| level | clusters | cluster_s | train_s | n_sv | iters | pg_max "
        "| cache hit rate | trace |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for st in s.get("levels", []):
        def num(key, fmt="{}", default="—"):
            v = st.get(key)
            return default if v is None else fmt.format(v)

        ts = st.get("trace_summary") or {}
        trace = "—"
        if ts:
            trace = f"{ts.get('samples', 0)} samples"
            if ts.get("dropped"):
                trace += f" (+{ts['dropped']} dropped)"
            if ts.get("pg_first") is not None:
                trace += (f", pg {ts['pg_first']:.2e} -> "
                          f"{ts['pg_last']:.2e}")
        lines.append(
            f"| {st.get('level', '?')} | {st.get('clusters', '?')} "
            f"| {num('cluster_time', '{:.2f}')} "
            f"| {num('train_time', '{:.2f}')} | {num('n_sv')} "
            f"| {num('iters')} | {num('pg_max', '{:.2e}')} "
            f"| {num('cache_hit_rate', '{:.2%}')} | {trace} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stats", default="",
                    help="render the per-level training table from a "
                         "train_svm --stats-json dump instead of the "
                         "dry-run/roofline tables")
    args = ap.parse_args()
    if args.stats:
        print(stats_table(args.stats))
        return
    print(dryrun_table("single"))
    print()
    print(dryrun_table("multi"))
    print()
    print("### Roofline — single-pod baseline (probe-corrected)")
    print()
    print(roofline_table("single"))


if __name__ == "__main__":
    main()
