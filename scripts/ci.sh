#!/usr/bin/env bash
# Per-PR gate: tier-1 tests + a benchmarks smoke pass so regressions in the
# fused conquer path (and its BENCH_conquer.json artifact) are caught early.
#
#   scripts/ci.sh            # full tier-1 + kernels bench smoke
#   scripts/ci.sh --fast     # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# tier-1 (ROADMAP.md)
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    # benchmarks smoke: tiny shapes, asserts Pallas/XLA parity on every
    # kernel and on the conquer solver, writes BENCH_conquer.json
    python -m benchmarks.run --only kernels --dry-run
fi
