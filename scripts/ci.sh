#!/usr/bin/env bash
# Per-PR gate: tier-1 tests + a benchmarks smoke pass so regressions in the
# fused conquer path / serving engine (and their BENCH_*.json artifacts) are
# caught early.
#
#   scripts/ci.sh            # full tier-1 + kernels/serve bench smoke
#   scripts/ci.sh --fast     # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# guard: no tracked bytecode / cache artifacts may (re)appear in git
if git ls-files | grep -E '(__pycache__|\.py[cod]$|\.pytest_cache|\.egg-info|BENCH_.*\.json$)' >/dev/null; then
    echo "ERROR: tracked bytecode/cache artifacts found:" >&2
    git ls-files | grep -E '(__pycache__|\.py[cod]$|\.pytest_cache|\.egg-info|BENCH_.*\.json$)' >&2
    exit 1
fi

# tier-1 (ROADMAP.md)
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    # benchmarks smoke: tiny shapes, asserts Pallas/XLA parity on every
    # kernel, on the conquer solver, and on the generalized SVR dual;
    # writes BENCH_conquer.json + BENCH_serve.json + BENCH_svr.json
    python -m benchmarks.run --only kernels,serve,svr --dry-run
fi
