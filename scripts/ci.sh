#!/usr/bin/env bash
# Per-PR gate: tier-1 tests + a benchmarks smoke pass so regressions in the
# fused conquer path / serving engine (and their BENCH_*.json artifacts) are
# caught early.
#
#   scripts/ci.sh            # full tier-1 + kernels/serve/slo/svr/oneclass/
#                            # eq-block/dist bench smoke (dist spawns 1- and
#                            # 8-forced-host-device subprocesses)
#   scripts/ci.sh --fast     # quick local loop: tests only, and the
#                            # hypothesis-backed property suite is skipped
#                            # via its pytest marker (-m "not properties")
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# guard: no tracked bytecode / cache artifacts may (re)appear in git
if git ls-files | grep -E '(__pycache__|\.py[cod]$|\.pytest_cache|\.egg-info|BENCH_.*\.json$)' >/dev/null; then
    echo "ERROR: tracked bytecode/cache artifacts found:" >&2
    git ls-files | grep -E '(__pycache__|\.py[cod]$|\.pytest_cache|\.egg-info|BENCH_.*\.json$)' >&2
    exit 1
fi

# tier-1 (ROADMAP.md).  When hypothesis is installed, pin its PRNG and keep
# the example budget bounded so the property suite stays deterministic and
# fast; without hypothesis the suite falls back to fixed-seed parametrization
# (tests/test_solver_properties.py) and needs no flag.
HYP_ARGS=()
if python -c "import hypothesis" >/dev/null 2>&1; then
    HYP_ARGS=(--hypothesis-seed=0)
fi
# the ${arr[@]+...} guard keeps the empty-array expansion safe under
# `set -u` on bash < 4.4 (macOS system bash)
if [[ "${1:-}" == "--fast" ]]; then
    # quick local loop: skip the (hypothesis-backed or fixed-seed-grid)
    # solver conformance suite via its marker; everything else still runs
    python -m pytest -x -q -m "not properties" ${HYP_ARGS[@]+"${HYP_ARGS[@]}"}
    # GramOperator smoke: the precision/spill curve asserts the out-of-core
    # solves hit the in-memory objective (f32 to 1e-3, bf16 to 5e-2)
    python -m benchmarks.run --only outofcore --dry-run
    # telemetry smoke: span-tree Chrome trace + per-level stats dump from
    # the training driver, metrics exposition from the serving driver —
    # then validate the artifact schemas (the JSON keys downstream
    # dashboards key on)
    TDIR="$(mktemp -d)"
    trap 'rm -rf "$TDIR"' EXIT
    python -m repro.launch.train_svm --n 400 --levels 1 --m 64 \
        --dataset gaussian --trace "$TDIR/trace.json" --trace-cap 32 \
        --stats-json "$TDIR/stats.json"
    python -m repro.launch.serve_svm --n 600 --classes 3 --levels 1 \
        --strategy early --batch 64 --batches 4 \
        --metrics-out "$TDIR/metrics.json"
    # async serving smoke: in-process engine over the versioned registry,
    # short Poisson trace of mixed request sizes — asserts a finite p99,
    # zero compiles after warmup, and the manifest/metrics schemas
    python -m repro.launch.serve_svm --n 600 --classes 3 --levels 1 \
        --strategy early --batch 64 --batches 24 --serve-async --qps 200 \
        --registry "$TDIR/registry.json" \
        --metrics-out "$TDIR/async_metrics.json" | tee "$TDIR/async.out"
    # overload burst: ~2x+ capacity offered instantaneously against a
    # bounded queue + deadline — asserts the degradation ladder: requests
    # shed (serve_shed_total > 0), admitted p99 stays finite, and the jit
    # cache stays warm (zero compiles after warmup)
    python -m repro.launch.serve_svm --n 600 --classes 3 --levels 1 \
        --strategy early --batch 64 --batches 40 --serve-async \
        --qps 100000 --max-queue 64 --timeout-s 2 \
        --metrics-out "$TDIR/overload_metrics.json" | tee "$TDIR/overload.out"
    python scripts/make_report.py --stats "$TDIR/stats.json" >/dev/null
    python - "$TDIR" <<'EOF'
import json, re, sys
d = sys.argv[1]
t = json.load(open(f"{d}/trace.json"))
assert t["traceEvents"], "empty chrome trace"
assert all(e["ph"] == "X" and e["dur"] >= 0 for e in t["traceEvents"])
s = json.load(open(f"{d}/stats.json"))
assert s["levels"], "no level stats"
assert "trace" in s["levels"][-1] and "trace_summary" in s["levels"][-1]
m = json.load(open(f"{d}/metrics.json"))
assert m["counters"] and m["histograms"]
assert any(k.startswith("serve_latency_seconds") for k in m["histograms"])
prom = open(f"{d}/metrics.prom").read()
assert "serve_latency_seconds_bucket" in prom
assert "# HELP" in prom
# async engine artifacts: manifest schema, engine metrics, finite p99,
# zero compiles after warmup
r = json.load(open(f"{d}/registry.json"))
assert r["route"] == {"default": 1}
man = r["models"][0]
for key in ("name", "version", "task", "kernel", "C", "rho", "rho_c", "k",
            "n_classes", "n_sv", "strategies", "max_sv_per_cluster",
            "with_bcm", "cap_policy"):
    assert key in man, f"manifest missing {key}"
assert man["cap_policy"] == "bucket" and man["kernel"]["kind"] == "rbf"
am = json.load(open(f"{d}/async_metrics.json"))
assert any(k.startswith("serve_latency_seconds") for k in am["histograms"])
assert any(k.startswith("serve_batch_fill_ratio") for k in am["histograms"])
assert "serve_queue_depth" in am.get("gauges", {})
assert not any(k.startswith("serve_compiles_total")
               for k in am["counters"]), "engine recompiled after warmup"
out = open(f"{d}/async.out").read()
p99 = float(re.search(r"p99 ([0-9.]+)", out).group(1))
assert p99 == p99 and p99 > 0, "p99 not finite"
assert re.search(r"after warmup 0", out), "compiles after warmup != 0"
# overload burst: sheds happened, typed and counted; admitted p99 finite;
# the deadline/queue-wait instrumentation flowed through the registry;
# still zero compiles after warmup under overload
om = json.load(open(f"{d}/overload_metrics.json"))
shed = sum(v for k, v in om["counters"].items()
           if k.startswith("serve_shed_total"))
assert shed > 0, "2x+ overload burst never shed — admission control dead"
assert any(k.startswith("serve_queue_wait_seconds")
           for k in om["histograms"]), "queue-wait histogram missing"
assert not any(k.startswith("serve_compiles_total")
               for k in om["counters"]), "engine recompiled under overload"
oout = open(f"{d}/overload.out").read()
op99 = float(re.search(r"p99 ([0-9.]+)", oout).group(1))
assert op99 == op99 and op99 > 0, "admitted p99 not finite under overload"
assert re.search(r"shed ([1-9][0-9]*)", oout), "shed count not reported"
assert re.search(r"after warmup 0", oout), "compiles after warmup != 0"
print("telemetry + async serving + overload smoke ok")
EOF
else
    python -m pytest -x -q ${HYP_ARGS[@]+"${HYP_ARGS[@]}"}
    # benchmarks smoke: tiny shapes, asserts Pallas/XLA parity on every
    # kernel, on the conquer solver, on the generalized SVR + one-class
    # duals, on the blocked (rank-2B) vs pairwise equality engines, on the
    # sharded parallel-block conquer (multi-device subprocesses assert
    # fewer rounds-to-tol than the replicated baseline at 8 devices), on
    # the GramOperator precision/spill tiers, and on the traced-vs-untraced
    # conquer (trace asserts bit-identity and emits the pg_max-vs-seconds
    # curve; kernels/outofcore/trace all merge sections into
    # BENCH_conquer.json); writes BENCH_{conquer,serve,svr,oneclass,dist}.json
    python -m benchmarks.run \
        --only kernels,outofcore,trace,serve,slo,svr,oneclass,eq_block,dist \
        --dry-run
fi
