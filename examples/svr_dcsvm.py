"""epsilon-SVR through the full DC-SVM pipeline: divide -> conquer -> serve.

Trains an epsilon-insensitive SVR on the Friedman #1 benchmark through the
SAME multilevel engine as classification (one generalized dual: the
2n-variable (alpha, alpha*) problem clusters by base sample so mirrored
coordinates share a sub-QP), then compacts the collapsed beta coefficients
into a ServingModel and serves batched regression requests through the
compiled route->gather->score program.

Two models are exported: the exact final solve (served with the ``exact``
strategy) and an early-stopped level-1 model whose per-cluster local SVRs
are what paper eq. 11 routes to (served with ``early`` — for regression
the block-diagonal early approximation only makes sense with locally
trained models; an exact model's beta is not cluster-separable).

    PYTHONPATH=src python examples/svr_dcsvm.py [--n 4000 --levels 2]
"""
import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig, EpsilonSVR, Kernel, fit, mae, mse, predict_early,
    predict_exact,
)
from repro.data import friedman1, train_test_split
from repro.launch.serve_svm import (
    export_serving_model, run_request_loop, serve_batch,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--C", type=float, default=4.0)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    X, y = friedman1(jax.random.PRNGKey(0), args.n)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    kern = Kernel("rbf", gamma=args.gamma)
    cfg = DCSVMConfig(kernel=kern, C=args.C, k=4, levels=args.levels,
                      m=min(1000, Xtr.shape[0]), tol=1e-3)
    task = EpsilonSVR(eps=args.eps)

    print(f"n_train={Xtr.shape[0]} dual_vars={2 * Xtr.shape[0]} "
          f"levels={cfg.levels} eps={args.eps}")
    t0 = time.perf_counter()

    def cb(level, alpha, st):
        print(f"  level {level}: clusters={st['clusters']} n_sv={st['n_sv']} "
              f"train_t={st['train_time']:.1f}s", flush=True)

    model = fit(cfg, Xtr, ytr, callback=cb, task=task)
    print(f"total train {time.perf_counter() - t0:.1f}s  "
          f"SVs {len(model.sv_index)}/{Xtr.shape[0]}")

    base = float(jnp.mean((yte - jnp.mean(ytr)) ** 2))
    pred = predict_exact(model, Xte)
    print(f"  predict_exact : mse {mse(yte, pred):.5f} mae {mae(yte, pred):.5f}"
          f"  (predict-the-mean baseline mse {base:.5f})")

    # eq.-11 early prediction wants LOCALLY trained models: stop at level 1
    # and let each cluster keep its own SVR
    model_early = fit(dataclasses.replace(cfg, early_stop_level=1), Xtr, ytr,
                      task=task)
    pred_e = predict_early(model_early, Xte)
    print(f"  predict_early : mse {mse(yte, pred_e):.5f} "
          f"mae {mae(yte, pred_e):.5f}  (level-1 local models)")

    # serving: compacted beta-form models, same compiled engine as SVC
    rng = np.random.default_rng(0)
    idx = rng.integers(0, Xte.shape[0], size=(20, args.batch))
    batches = jnp.asarray(np.asarray(Xte)[idx])
    for strategy, m in [("exact", model), ("early", model_early)]:
        sm = export_serving_model(m, with_bcm=False)
        pred_s, _ = serve_batch(sm, Xte, kern, strategy)
        rep = run_request_loop(sm, kern, strategy, batches)
        print(f"  serve[{strategy}]: mse {mse(yte, pred_s):.5f} | "
              f"{rep['qps']:.0f} q/s | p50 {rep['lat_ms_p50']:.2f} ms "
              f"p95 {rep['lat_ms_p95']:.2f} ms")


if __name__ == "__main__":
    main()
