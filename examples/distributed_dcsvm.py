"""Distributed DC-SVM on an 8-device (virtual) mesh via shard_map.

Demonstrates the pod-mapping of the paper: the divide step solves clusters
device-parallel with zero collectives (per-device Gram residency); the
conquer step runs communication-efficient parallel block minimization —
every device solves its OWN top-B sub-QP per round and one all-gather ships
the P rank-B updates, so descent per communication round scales with the
device count.  The replicated mode (one global block per round) is timed for
comparison.

    PYTHONPATH=src python examples/distributed_dcsvm.py
(sets XLA_FLAGS itself — run as a fresh process)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DCSVMConfig, Kernel, gram, kkt_residual
from repro.core.distributed import ConquerConfig, conquer_step, fit_distributed
from repro.data import gaussian_mixture


def main():
    print(f"devices: {jax.device_count()}")
    mesh = jax.make_mesh((jax.device_count(),), ("i",))
    kern = Kernel("rbf", gamma=8.0)
    X, y = gaussian_mixture(jax.random.PRNGKey(0), 4096, d=8, modes_per_class=4)
    C = 4.0

    cfg = DCSVMConfig(kernel=kern, C=C, k=4, levels=2, m=400, tol=1e-3)
    t0 = time.perf_counter()
    alpha, stats = fit_distributed(cfg, mesh, "i", X, y, conquer_block=32)
    t = time.perf_counter() - t0
    for st in stats:
        print("  ", st)

    Q = (y[:, None] * y[None, :]) * gram(kern, X, X)
    print(f"distributed DC-SVM: {t:.1f}s | "
          f"KKT residual {float(kkt_residual(Q, alpha, C)):.2e} | "
          f"SVs {int(jnp.sum(alpha > 0))}")

    # conquer-only from zero: P parallel blocks vs one replicated block
    ccfg = ConquerConfig(kernel=kern, C=C, tol=1e-3, max_iters=10_000,
                         block=32, mode="parallel")
    for mode in ("parallel", "replicated"):
        mcfg = dataclasses.replace(ccfg, mode=mode)
        t0 = time.perf_counter()
        a2, rounds, pg = conquer_step(mesh, "i", mcfg, X, y,
                                      jnp.zeros(X.shape[0]))
        t2 = time.perf_counter() - t0
        print(f"conquer from zero [{mode:>10}]: {t2:.1f}s, "
              f"{int(rounds)} communication rounds, "
              f"pg_max {float(pg):.2e}")


if __name__ == "__main__":
    main()
