"""Train a ~100M-param LM from the assigned-architecture zoo for a few
hundred steps on the deterministic synthetic pipeline (CPU-runnable).

Uses the REAL production train step (sharded, AdamW, checkpointed) on the
host mesh; on a pod the same code runs with make_production_mesh().

    PYTHONPATH=src python examples/lm_pretrain.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import RunShape, get_config
from repro.ckpt import CheckpointManager
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train
from repro.models.param import count_params, init_tree
from repro.optim import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_pretrain_ckpt")
    args = ap.parse_args()

    # ~100M-param dense config (qwen1.5 family at GPT-2-small geometry) —
    # recurrent archs (xlstm/jamba) are CPU-hostile; dense trains fast here
    cfg = get_config("qwen15_05b")
    cfg = dataclasses.replace(cfg, n_layers=10, d_model=768, n_heads=12,
                              n_kv=12, d_ff=2048, vocab=32768,
                              param_dtype="float32", activ_dtype="float32",
                              remat="none")
    mesh = make_host_mesh()
    shape = RunShape("pretrain", args.seq, args.batch, "train")
    opt_cfg = AdamWConfig(lr=3e-4)
    build = build_train(cfg, mesh, shape, opt_cfg=opt_cfg,
                        chunk=min(512, args.seq), total_steps=args.steps)
    n_params = count_params(build.decls)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    params = init_tree(build.decls, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw_init(opt_cfg, params)
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab, global_batch=args.batch, seq_len=args.seq))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    losses = []
    t0 = time.perf_counter()
    for step in range(args.steps):
        tok, tgt = pipe.global_batch_at(jnp.asarray(step))
        params, opt, metrics = build.step_fn(params, opt,
                                             {"tokens": tok, "targets": tgt})
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            tps = (step + 1) * args.batch * args.seq / (time.perf_counter() - t0)
            print(f"step {step:4d} loss={losses[-1]:.4f} tok/s={tps:.0f}",
                  flush=True)
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, {"params": params, "opt": opt})
    mgr.wait()
    first, last = sum(losses[:20]) / 20, sum(losses[-20:]) / 20
    print(f"mean loss first-20 {first:.4f} -> last-20 {last:.4f} "
          f"(must drop: {'OK' if last < first - 0.3 else 'NO'})")


if __name__ == "__main__":
    main()
