"""END-TO-END DRIVER: large multilevel DC-SVM training run with
fault-tolerant checkpointing and the full Algorithm-1 pipeline, on a
covtype-style synthetic dataset (the paper's flagship experiment shape).

This is the paper-kind end-to-end run: ~20k training points, 3 levels
(64 -> 16 -> 4 clusters), adaptive clustering from lower-level support
vectors, refine pass, exact conquer to the paper's stopping criterion,
then both exact and early-prediction evaluation.

    PYTHONPATH=src python examples/end_to_end_dcsvm.py [--n 20000]
"""
import argparse
import time

import numpy as np
import jax

from repro.ckpt import CheckpointManager
from repro.core import (
    DCSVMConfig, Kernel, accuracy, fit, objective_value,
    predict_early, predict_exact,
)
from repro.data import covtype_like, train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--ckpt-dir", default="/tmp/dcsvm_e2e")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    X, y = covtype_like(key, args.n)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    kern = Kernel("rbf", gamma=32.0)
    cfg = DCSVMConfig(kernel=kern, C=8.0, k=4, levels=args.levels, m=1000,
                      tol=1e-3, adaptive=True, refine=True,
                      full_gram_threshold=24_000)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    print(f"n_train={Xtr.shape[0]} n_test={Xte.shape[0]} d={Xtr.shape[1]} "
          f"levels={cfg.levels} (bottom: {cfg.k**cfg.levels} clusters)")
    t0 = time.perf_counter()

    def cb(level, alpha, st):
        el = time.perf_counter() - t0
        print(f"  [t={el:7.1f}s] level {level}: clusters={st.get('clusters', 1)}"
              f" n_sv={st['n_sv']}"
              f" cluster_t={st.get('cluster_time', 0.0):.1f}s"
              f" train_t={st['train_time']:.1f}s", flush=True)
        mgr.save(cfg.levels - level + 1, {"alpha": alpha})

    model = fit(cfg, Xtr, ytr, callback=cb)
    t_total = time.perf_counter() - t0
    mgr.wait()

    f_final = float(objective_value(cfg, Xtr, ytr, model.alpha))
    acc = accuracy(yte, predict_exact(model, Xte))
    n_sv = int(np.sum(np.asarray(model.alpha) > 0))
    print(f"total {t_total:.1f}s | f(alpha)={f_final:.2f} | "
          f"SVs {n_sv}/{Xtr.shape[0]} | exact test acc {acc:.4f}")

    cfg_e = DCSVMConfig(**{**cfg.__dict__, "early_stop_level": 1})
    t0 = time.perf_counter()
    me = fit(cfg_e, Xtr, ytr)
    t_early = time.perf_counter() - t0
    acc_e = accuracy(yte, predict_early(me, Xte))
    print(f"DC-SVM (early): {t_early:.1f}s, acc {acc_e:.4f} "
          f"({t_total / max(t_early, 1e-9):.1f}x faster than exact)")


if __name__ == "__main__":
    main()
