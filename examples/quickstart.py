"""DC-SVM quickstart: train a kernel SVM with divide-and-conquer, compare
against the exact from-zero solver, and serve with early prediction.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig, Kernel, accuracy, fit, gram, kkt_residual,
    predict_early, predict_exact, solve_with_shrinking,
)
from repro.data import gaussian_mixture, train_test_split


def main():
    # 1. a multi-modal, non-linearly-separable dataset (covtype-style)
    key = jax.random.PRNGKey(0)
    X, y = gaussian_mixture(key, 4000, d=16, modes_per_class=8, spread=0.12)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    kern = Kernel("rbf", gamma=16.0)
    C = 4.0

    # 2. exact baseline: greedy CD from zero (the LIBSVM-analogue)
    t0 = time.perf_counter()
    Q = (ytr[:, None] * ytr[None, :]) * gram(kern, Xtr, Xtr)
    exact = solve_with_shrinking(Q, C, tol=1e-3, max_iters=300_000)
    exact.alpha.block_until_ready()
    t_exact = time.perf_counter() - t0
    print(f"exact solver: {t_exact:.1f}s, {int(exact.iters)} CD iterations")

    # 3. DC-SVM: two levels of divide-and-conquer, then warm-started conquer
    cfg = DCSVMConfig(kernel=kern, C=C, k=4, levels=2, m=500, tol=1e-3)
    t0 = time.perf_counter()
    model = fit(cfg, Xtr, ytr)
    t_dc = time.perf_counter() - t0
    f_exact = 0.5 * exact.alpha @ Q @ exact.alpha - exact.alpha.sum()
    f_dc = 0.5 * model.alpha @ Q @ model.alpha - model.alpha.sum()
    print(f"DC-SVM: {t_dc:.1f}s | objective {float(f_dc):.4f} "
          f"vs exact {float(f_exact):.4f} "
          f"(rel err {abs(float(f_dc - f_exact) / f_exact):.2e})")
    print(f"KKT residual: {float(kkt_residual(Q, model.alpha, C)):.2e}")
    print(f"test accuracy: {accuracy(yte, predict_exact(model, Xte)):.4f}")

    # 4. early-prediction serving: stop at level 1, route queries to clusters
    cfg_early = DCSVMConfig(kernel=kern, C=C, k=4, levels=2, m=500,
                            tol=1e-3, early_stop_level=1)
    early = fit(cfg_early, Xtr, ytr)
    t0 = time.perf_counter()
    acc = accuracy(yte, predict_early(early, Xte))
    t_pred = (time.perf_counter() - t0) / Xte.shape[0]
    print(f"early prediction (eq. 11): acc {acc:.4f}, {t_pred*1e6:.0f} us/query")


if __name__ == "__main__":
    main()
