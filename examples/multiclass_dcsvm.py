"""Multiclass one-vs-all DC-SVM, trained once and served three ways.

Trains all ``n_classes`` one-vs-rest machines with a SHARED partition and a
single vmapped CD call per level (the Gram is label-independent), then
compares the three serving strategies (exact / early / bcm) on accuracy and
latency through the compiled serving engine.

    PYTHONPATH=src python examples/multiclass_dcsvm.py [--n 6000 --classes 4]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig, Kernel, accuracy_multiclass, fit_ova,
    predict_bcm_ova, predict_early_ova, predict_exact_ova,
)
from repro.data import gaussian_mixture_multiclass, train_test_split
from repro.launch.serve_svm import (
    export_serving_model, run_request_loop, serve_batch,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=6000)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    X, y = gaussian_mixture_multiclass(jax.random.PRNGKey(0), args.n,
                                       n_classes=args.classes, d=10)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    kern = Kernel("rbf", gamma=8.0)
    cfg = DCSVMConfig(kernel=kern, C=4.0, k=4, levels=args.levels,
                      m=min(1000, Xtr.shape[0]), tol=1e-3)

    print(f"n_train={Xtr.shape[0]} n_classes={args.classes} "
          f"levels={cfg.levels} ({cfg.k ** cfg.levels} bottom clusters, "
          f"{args.classes * cfg.k ** cfg.levels} sub-QPs per bottom level)")
    t0 = time.perf_counter()

    def cb(level, alpha, st):
        print(f"  level {level}: clusters={st['clusters']} n_sv={st['n_sv']} "
              f"train_t={st['train_time']:.1f}s", flush=True)

    model = fit_ova(cfg, Xtr, ytr, callback=cb)
    print(f"total train {time.perf_counter() - t0:.1f}s")

    for name, fn in [("exact", predict_exact_ova), ("early", predict_early_ova),
                     ("bcm", predict_bcm_ova)]:
        print(f"  predict_{name}_ova acc: "
              f"{accuracy_multiclass(yte, fn(model, Xte)):.4f}")

    sm = export_serving_model(model)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, Xte.shape[0], size=(20, args.batch))
    batches = jnp.asarray(np.asarray(Xte)[idx])
    for strategy in ["exact", "early", "bcm"]:
        pred, _ = serve_batch(sm, Xte, kern, strategy)
        acc = accuracy_multiclass(yte, pred)
        rep = run_request_loop(sm, kern, strategy, batches)
        print(f"  serve[{strategy}]: acc {acc:.4f} | {rep['qps']:.0f} q/s | "
              f"p50 {rep['lat_ms_p50']:.2f} ms p95 {rep['lat_ms_p95']:.2f} ms")


if __name__ == "__main__":
    main()
