"""Where the paper's technique meets the model zoo: train a DC-SVM
classification head on frozen features from a zoo LM.

A tiny LM embeds token sequences; DC-SVM learns a non-linear classifier on
the pooled features WITHOUT backprop through the LM — the classic kernel-
head fine-tune, solved exactly by divide-and-conquer.

    PYTHONPATH=src python examples/svm_head_on_lm_features.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    DCSVMConfig, Kernel, accuracy, fit, predict_exact,
)
from repro.models import lm as LM
from repro.models import model as M
from repro.models.param import init_tree


def make_labeled_sequences(key, n, seq, vocab):
    """Synthetic task: label = does the motif token appear in the sequence."""
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (n, seq), 0, vocab)
    motif = 7
    has = jax.random.bernoulli(k2, 0.5, (n,))
    pos = jax.random.randint(k3, (n,), 0, seq)
    tokens = jnp.where(has[:, None] & (jnp.arange(seq)[None] == pos[:, None]),
                       motif, tokens)
    y = jnp.where(has, 1.0, -1.0)
    return tokens, y


def main():
    cfg = get_config("qwen15_05b", reduced=True)
    params = init_tree(M.build_decls_any(cfg), jax.random.PRNGKey(0),
                       jnp.float32)
    key = jax.random.PRNGKey(1)
    tokens, y = make_labeled_sequences(key, 2000, 32, cfg.vocab)

    @jax.jit
    def embed(tok):
        logits, _, _ = LM.forward(cfg, params, tok, chunk=16)
        return logits.mean(axis=1)          # mean-pooled last-layer readout

    feats = []
    for s in range(0, tokens.shape[0], 256):
        feats.append(embed(tokens[s:s + 256]))
    X = jnp.concatenate(feats)
    X = (X - X.mean(0)) / (X.std(0) + 1e-6)
    # project to a manageable feature dim for the kernel head
    key_p = jax.random.PRNGKey(2)
    P = jax.random.normal(key_p, (X.shape[1], 32)) / np.sqrt(X.shape[1])
    X = X @ P

    ntr = 1600
    Xtr, ytr, Xte, yte = X[:ntr], y[:ntr], X[ntr:], y[ntr:]
    svm_cfg = DCSVMConfig(kernel=Kernel("rbf", gamma=0.5), C=4.0, k=4,
                          levels=1, m=400, tol=1e-3)
    t0 = time.perf_counter()
    model = fit(svm_cfg, Xtr, ytr)
    acc = accuracy(yte, predict_exact(model, Xte))
    print(f"DC-SVM head on frozen LM features: {time.perf_counter()-t0:.1f}s, "
          f"test acc {acc:.3f} (motif-detection task)")


if __name__ == "__main__":
    main()
