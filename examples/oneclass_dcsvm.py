"""One-class SVM through the full DC-SVM pipeline: divide -> conquer -> serve.

Label-free anomaly detection on the contaminated gaussian_with_outliers
mixture through the SAME multilevel engine as classification — the only
structural difference is the dual family: the one-class dual carries the
equality constraint ``sum alpha = nu * n`` the bias-free hinge deliberately
drops, so every sub-QP is solved by the pairwise (SMO-style) engine and the
divide step splits the mass target proportionally over clusters
(DESIGN.md §9).  The trained model's decision is

    f(x) = sum_i alpha_i K(x_i, x) - rho     (f >= 0 <=> inlier)

with rho recovered from the equality multiplier.  The model is compacted
into a ServingModel (one beta column + rho) and served through the same
compiled route->gather->score program as every other task.

    PYTHONPATH=src python examples/oneclass_dcsvm.py [--n 4000 --nu 0.1]
"""
import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DCSVMConfig, Kernel, OneClassSVM, f1, fit, precision, predict_early,
    predict_exact, recall,
)
from repro.data import gaussian_with_outliers, train_test_split
from repro.launch.serve_svm import (
    export_serving_model, run_request_loop, serve_batch,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--nu", type=float, default=0.1)
    ap.add_argument("--gamma", type=float, default=4.0)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    X, y = gaussian_with_outliers(jax.random.PRNGKey(0), args.n)
    Xtr, ytr, Xte, yte = train_test_split(jax.random.PRNGKey(1), X, y)
    kern = Kernel("rbf", gamma=args.gamma)
    cfg = DCSVMConfig(kernel=kern, k=4, levels=args.levels,
                      m=min(1000, Xtr.shape[0]), tol=1e-4)
    task = OneClassSVM(nu=args.nu)

    print(f"n_train={Xtr.shape[0]} nu={args.nu} levels={cfg.levels} "
          f"(training is label-free; labels grade the detector)")
    t0 = time.perf_counter()

    def cb(level, alpha, st):
        print(f"  level {level}: clusters={st['clusters']} n_sv={st['n_sv']} "
              f"train_t={st['train_time']:.1f}s", flush=True)

    model = fit(cfg, Xtr, callback=cb, task=task)
    n = Xtr.shape[0]
    print(f"total train {time.perf_counter() - t0:.1f}s  "
          f"SVs {len(model.sv_index)}/{n}  rho={model.rho:.4f}  "
          f"sum alpha={float(model.alpha.sum()):.2f} (= nu*n = {args.nu * n:.0f})")

    # nu's two-sided property on the training set
    f_tr = predict_exact(model, Xtr)
    out_frac = float(jnp.mean(f_tr < 0))
    sv_frac = len(model.sv_index) / n
    print(f"  nu sandwich: outlier-fraction {out_frac:.3f} <= nu={args.nu} "
          f"<= SV-fraction {sv_frac:.3f}")

    def report(tag, pred):
        print(f"  {tag}: outlier recall {recall(yte, pred, -1.0):.4f} "
              f"precision {precision(yte, pred, -1.0):.4f} "
              f"f1 {f1(yte, pred, -1.0):.4f}")

    report("predict_exact", predict_exact(model, Xte))

    # eq.-11 early prediction: per-cluster local one-class models, each
    # feasible for its proportional share of the mass target
    model_early = fit(dataclasses.replace(cfg, early_stop_level=1), Xtr,
                      task=task)
    report("predict_early", predict_early(model_early, Xte))

    # serving: one beta column + rho, same compiled engine as SVC/SVR
    rng = np.random.default_rng(0)
    idx = rng.integers(0, Xte.shape[0], size=(20, args.batch))
    batches = jnp.asarray(np.asarray(Xte)[idx])
    for strategy, m in [("exact", model), ("early", model_early)]:
        sm = export_serving_model(m, with_bcm=False)
        assert sm.task == "ocsvm"
        pred_s, _ = serve_batch(sm, Xte, kern, strategy)
        rep = run_request_loop(sm, kern, strategy, batches)
        print(f"  serve[{strategy}]: f1 {f1(yte, pred_s, -1.0):.4f} | "
              f"{rep['qps']:.0f} q/s | p50 {rep['lat_ms_p50']:.2f} ms "
              f"p95 {rep['lat_ms_p95']:.2f} ms")


if __name__ == "__main__":
    main()
